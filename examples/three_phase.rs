//! Fig.-4 three-phase schedule, functionally: run the trained MNIST MLP's
//! block-circulant layer through the staged executor (phase 1 all FFTs →
//! phase 2 all spectral MACs → phase 3 all IFFTs) and through the naive
//! non-decoupled schedule (ablation AB1), showing that
//!
//!   * both compute the same layer,
//!   * the decoupled schedule performs q + p transforms per image where
//!     the naive one performs 2·p·q, and
//!   * the executed op counts are exactly the workload the FPGA cycle
//!     simulator charges for Table 1.
//!
//! Run: `cargo run --release --example three_phase`

use circnn::circulant::BlockCirculant;
use circnn::models::{self, Layer};
use circnn::native::staged::{bc_dense_naive_schedule, bc_dense_staged};
use circnn::util::rng::SplitMix;

fn main() {
    let model = models::by_name("mnist_mlp_1").unwrap();
    let Some(Layer::BcDense { n, m, k }) = model
        .layers
        .iter()
        .find(|l| matches!(l, Layer::BcDense { .. }))
        .copied()
    else {
        unreachable!("mnist_mlp_1 has a BC dense layer");
    };
    let (p, q) = (m / k, n / k);
    println!("layer: {n}x{m} block-circulant, k={k} ({p}x{q} blocks)\n");

    let mut rng = SplitMix::new(1);
    let mut bc = BlockCirculant::new(p, q, k, rng.normal_vec(p * q * k));
    bc.precompute();
    let batch = 64;
    let xs = rng.normal_vec(batch * n);
    let bias = rng.normal_vec(m);

    let mut staged = vec![0.0f32; batch * m];
    let t0 = std::time::Instant::now();
    let c_dec = bc_dense_staged(&bc, &xs, batch, &bias, true, &mut staged);
    let t_dec = t0.elapsed();

    let mut naive = vec![0.0f32; batch * m];
    let t0 = std::time::Instant::now();
    let c_nv = bc_dense_naive_schedule(&bc, &xs, batch, &bias, true, &mut naive);
    let t_nv = t0.elapsed();

    let max_diff = staged
        .iter()
        .zip(&naive)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    println!("outputs agree: max |Δ| = {max_diff:.2e} over {} values\n", staged.len());
    assert!(max_diff < 1e-3, "schedules must compute the same layer");

    let (d1, n1) = (c_dec.per_image(batch), c_nv.per_image(batch));
    println!("per image           {:>12} {:>12}", "decoupled", "naive (AB1)");
    println!("forward FFTs        {:>12} {:>12}   (q vs p*q)", d1.ffts, n1.ffts);
    println!("inverse FFTs        {:>12} {:>12}   (p vs p*q)", d1.iffts, n1.iffts);
    println!("spectral MAC groups {:>12} {:>12}", d1.mult_groups, n1.mult_groups);
    println!(
        "\nbatch of {batch}: decoupled {:.2?} vs naive {:.2?}  ({:.2}x)",
        t_dec,
        t_nv,
        t_nv.as_secs_f64() / t_dec.as_secs_f64()
    );

    // the counts the cycle simulator charges (models::FftWork) must match
    // what was just executed — the trust anchor for Table 1
    let row = model
        .accounting()
        .into_iter()
        .find(|r| r.kind == "bc_dense")
        .unwrap();
    assert_eq!(d1.ffts, row.fft_work.ffts_total);
    assert_eq!(d1.iffts, row.fft_work.iffts_total);
    assert_eq!(d1.mult_groups, row.fft_work.mult_groups_total);
    // naive_transforms is the p*q count charged to *each* transform kind
    assert_eq!(n1.ffts, row.fft_work.naive_transforms);
    assert_eq!(n1.iffts, row.fft_work.naive_transforms);
    println!(
        "\nexecuted transforms == simulator workload (FftWork): \
         Table 1's cycle counts charge exactly this datapath"
    );
}
