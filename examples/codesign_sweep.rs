//! The co-optimization frontier (experiments S2 + hardware axis).
//!
//! The paper's Fig.-5 loop jointly picks the block size k: larger k means
//! more compression and higher simulated throughput, smaller k means higher
//! accuracy.  This example joins the two axes:
//!
//! * accuracy per k from `artifacts/sweep.json` (written by `make sweep`,
//!   Python training runs); falls back to the trend-only table if absent;
//! * storage / throughput / efficiency per k from the Rust model
//!   accounting + FPGA simulator.
//!
//! Run: `cargo run --release --example codesign_sweep`

use circnn::fpga::device::CYCLONE_V;
use circnn::fpga::report::DesignReport;
use circnn::fpga::schedule::ScheduleConfig;
use circnn::models::{Layer, Model};
use circnn::util::json::Json;

/// The sweep MLP (mirrors train.block_size_sweep): 256 -> 256 -> 10 at k.
fn sweep_model(k: usize) -> Model {
    Model {
        name: "sweep_mlp",
        dataset: "mnist_s",
        input: (28, 28, 1),
        layers: vec![
            Layer::PriorPool { out_dim: 256 },
            Layer::Flatten,
            Layer::BcDense { n: 256, m: 256, k },
            Layer::Dense { n: 256, m: 10 },
        ],
        serve_batch: 64,
        paper_accuracy: 0.0,
        paper_kfps: 0.0,
        paper_kfps_per_w: 0.0,
    }
}

fn load_sweep_accuracies() -> Option<Vec<(usize, f64)>> {
    let path = circnn::runtime::Manifest::default_dir().join("sweep.json");
    let text = std::fs::read_to_string(path).ok()?;
    let root = Json::parse(&text).ok()?;
    let rows = root.get("block_size_sweep")?.as_arr()?;
    Some(
        rows.iter()
            .filter_map(|r| {
                Some((
                    r.get("k")?.as_usize()?,
                    r.get("accuracy")?.as_f64()?,
                ))
            })
            .collect(),
    )
}

fn main() {
    let accs = load_sweep_accuracies();
    if accs.is_none() {
        eprintln!("note: artifacts/sweep.json missing (run `make sweep`) — accuracy column empty");
    }
    println!(
        "{:>5} {:>10} {:>10} {:>12} {:>12} {:>12}",
        "k", "acc", "storage x", "kFPS (sim)", "kFPS/W", "circ mults"
    );
    println!("{}", "-".repeat(68));
    for k in [2usize, 4, 8, 16, 32, 64, 128] {
        let m = sweep_model(k);
        let cfg = ScheduleConfig::auto_for(&m, &CYCLONE_V);
        let rep = DesignReport::build(&m, &CYCLONE_V, &cfg);
        let acc = accs
            .as_ref()
            .and_then(|a| a.iter().find(|(kk, _)| *kk == k))
            .map(|(_, a)| format!("{:.2}%", a * 100.0))
            .unwrap_or_else(|| "-".into());
        println!(
            "{:>5} {:>10} {:>9.1}x {:>12.1} {:>12.1} {:>12}",
            k,
            acc,
            m.storage_report(12).reduction,
            rep.kfps,
            rep.kfps_per_w,
            m.circ_mults_per_image()
        );
    }
    println!(
        "\nthe co-design tradeoff (paper Fig. 5): accuracy falls and efficiency rises with k;\n\
         the paper picks k in 64-128 for FC layers — the knee of this frontier."
    );
}
