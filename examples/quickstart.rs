//! Quickstart: the whole stack in ~60 lines.
//!
//! Loads the AOT-compiled block-circulant MNIST MLP (trained and lowered by
//! `make artifacts`; weights baked into the HLO), classifies a few synthetic
//! test images through the PJRT runtime, then asks the FPGA simulator what
//! the same network does on the paper's CyClone V design point.
//!
//! Run: `cargo run --release --example quickstart`
//!
//! Wider tour: `docs/ARCHITECTURE.md` (dataflow + twin discipline),
//! `docs/PROTOCOL.md` (the TCP wire format), `docs/OPERATIONS.md`
//! (serving flags, knobs, metrics, load-shedding walkthrough).

use circnn::data;
use circnn::fpga::device::CYCLONE_V;
use circnn::fpga::report::DesignReport;
use circnn::fpga::schedule::ScheduleConfig;
use circnn::models;
use circnn::runtime::engine::{argmax_rows, literal_f32, Engine};
use circnn::runtime::Manifest;

fn main() -> anyhow::Result<()> {
    // 1. artifacts: the contract produced by the Python build path
    let manifest = Manifest::load(Manifest::default_dir())?;
    let entry = manifest.model("mnist_mlp_1")?;
    println!(
        "model {}: trained accuracy {:.2}% (12-bit circulant; dense twin {:.2}%), {:.0}x smaller",
        entry.name,
        entry.accuracy.circulant_12bit * 100.0,
        entry.accuracy.dense_f32 * 100.0,
        entry.storage_reduction
    );

    // 2. runtime: compile the Pallas-kernel-backed artifact once, execute
    //    from Rust (Python is NOT running — the HLO is self-contained)
    let art = entry
        .artifacts_pallas
        .iter()
        .chain(&entry.artifacts)
        .find(|a| a.batch == 64)
        .expect("batch-64 artifact");
    let engine = Engine::cpu()?;
    let exe = engine.load(manifest.path_of(&art.file))?;
    println!("compiled {} on {}", art.file, engine.platform());

    let ds = data::dataset(&entry.dataset).unwrap();
    let (mut images, labels) = data::batch(&ds, 0, 64, true);
    images.resize(64 * ds.pixels(), 0.0);
    let out = exe.run1(&[literal_f32(&images, &art.input_shape)?])?;
    let logits = out.to_vec::<f32>()?;
    let preds = argmax_rows(&logits, 10);
    let correct = preds.iter().zip(&labels).filter(|(p, y)| p == y).count();
    println!("classified 64 images: {correct}/64 correct");
    for i in 0..5 {
        println!("  image {i}: predicted {} true {}", preds[i], labels[i]);
    }

    // 3. co-design: what does this network cost on the paper's FPGA?
    let model = models::by_name("mnist_mlp_1").unwrap();
    let rep =
        DesignReport::build(&model, &CYCLONE_V, &ScheduleConfig::auto_for(&model, &CYCLONE_V));
    println!(
        "\nFPGA sim ({}): {:.0} kFPS, {:.0} kFPS/W, {:.1} ns/image, \
         {:.0}% multiplier utilization, model+batch in {} KiB of BRAM",
        rep.device,
        rep.kfps,
        rep.kfps_per_w,
        rep.ns_per_image,
        rep.utilization * 100.0,
        rep.bram_used / 1024
    );
    println!("(paper row: 8.6e4 kFPS, 1.57e5 kFPS/W on the physical part)");
    Ok(())
}
