//! Full hardware design report: every Table-1 model on both devices, with
//! phase/cycle breakdowns, memory maps, dense-baseline comparison, and the
//! AB1-AB3 ablations — the simulator's complete output surface.
//!
//! Run: `cargo run --release --example fpga_report`

use circnn::baselines::dense_fpga::dense_design;
use circnn::experiments::ablations;
use circnn::fpga::device::{CYCLONE_V, KINTEX_7};
use circnn::fpga::report::DesignReport;
use circnn::fpga::schedule::ScheduleConfig;
use circnn::models;

fn main() {
    for dev in [&CYCLONE_V, &KINTEX_7] {
        println!(
            "=== {} ({:.0} MHz, {} mults, {} KiB BRAM, {:.2} W max) ===",
            dev.name,
            dev.fmax_hz / 1e6,
            dev.total_mults(),
            dev.bram_bytes / 1024,
            dev.power_w(1.0)
        );
        for m in models::registry() {
            let cfg = ScheduleConfig::auto_for(&m, dev);
            let rep = DesignReport::build(&m, dev, &cfg);
            let dense = dense_design(&m, dev, &cfg);
            println!(
                "\n{} (batch {}):",
                m.name, cfg.batch
            );
            println!(
                "  circulant: {:>12.2} kFPS  {:>12.2} kFPS/W  {:>9.1} ns/img  util {:>5.1}%",
                rep.kfps,
                rep.kfps_per_w,
                rep.ns_per_image,
                rep.utilization * 100.0
            );
            println!(
                "  dense:     {:>12.2} kFPS  {:>12.2} kFPS/W  on-chip: {}",
                dense.kfps,
                dense.kfps_per_w,
                if dense.fits_on_chip { "yes" } else { "NO (off-chip derated)" }
            );
            println!(
                "  algorithmic gain: {:.1}x throughput, {:.1}x efficiency",
                rep.kfps / dense.kfps,
                rep.kfps_per_w / dense.kfps_per_w
            );
            let ph = rep.sched.phase;
            println!(
                "  cycles/batch {}: fft {} | mult {} | ifft {} | dense {} | fills {}",
                rep.sched.cycles_per_batch, ph.fft, ph.mult, ph.ifft, ph.dense, ph.fills
            );
            let mem = rep.sched.memory;
            println!(
                "  BRAM: weights {} + activations {} + twiddles {} = {} / {} bytes",
                mem.weight_bytes,
                mem.activation_bytes,
                mem.twiddle_bytes,
                mem.total_bytes,
                mem.capacity_bytes
            );
        }
        println!();
    }

    println!("=== ablations (CyClone V) ===");
    print!("{}", ablations::render());
}
