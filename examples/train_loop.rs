//! End-to-end training driver (experiment E2E, training half).
//!
//! Runs the AOT-exported Adam train-step (Layer-2 JAX, lowered to HLO with
//! the full FFT-domain backward pass of Eqns. 2-3) from Rust for several
//! hundred steps on the synthetic MNIST stream, logging the loss curve to
//! `artifacts/train_loss.csv`.  Python does not run: the optimizer state
//! is an opaque ordered list of literals the driver feeds back each step.
//!
//! Run: `cargo run --release --example train_loop`

use std::io::Write;
use std::time::Instant;

use circnn::data;
use circnn::runtime::engine::{literal_f32, literal_i32, Engine};
use circnn::runtime::Manifest;

fn main() -> anyhow::Result<()> {
    let steps: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(300);

    let man = Manifest::load(Manifest::default_dir())?;
    let entry = man.model("mnist_mlp_1")?;
    let tr = entry
        .training
        .as_ref()
        .expect("training artifacts (make artifacts)");
    let ds = data::dataset(&entry.dataset).unwrap();

    let engine = Engine::cpu()?;
    let init = engine.load(man.path_of(&tr.init_file))?;
    let step = engine.load(man.path_of(&tr.step_file))?;
    println!(
        "training {} from scratch: {} steps, batch {}, {} param tensors",
        entry.name,
        steps,
        tr.batch,
        tr.param_names.len()
    );

    let mut state = init.run(&[])?;
    let mut losses = Vec::with_capacity(steps);
    let t0 = Instant::now();
    for s in 0..steps {
        let (xs, ys) = data::batch(&ds, (s * tr.batch) as u64, tr.batch, false);
        let x = literal_f32(&xs, &[tr.batch, 28, 28, 1])?;
        let y = literal_i32(&ys.iter().map(|&v| v as i32).collect::<Vec<_>>(), &[tr.batch])?;
        let mut args = std::mem::take(&mut state);
        args.push(x);
        args.push(y);
        let mut out = step.run(&args)?;
        let loss = out[tr.loss_index].to_vec::<f32>()?[0];
        out.truncate(tr.loss_index);
        state = out;
        losses.push(loss);
        if s % 25 == 0 || s + 1 == steps {
            println!("  step {s:4}  loss {loss:.4}  ({:.1} steps/s)", (s + 1) as f64 / t0.elapsed().as_secs_f64());
        }
    }
    let dt = t0.elapsed();

    // write the loss curve
    let path = Manifest::default_dir().join("train_loss.csv");
    let mut f = std::fs::File::create(&path)?;
    writeln!(f, "step,loss")?;
    for (s, l) in losses.iter().enumerate() {
        writeln!(f, "{s},{l}")?;
    }
    println!(
        "\n{} steps in {:.2}s ({:.1} steps/s); loss {:.4} -> {:.4}; curve at {}",
        steps,
        dt.as_secs_f64(),
        steps as f64 / dt.as_secs_f64(),
        losses[0],
        losses[losses.len() - 1],
        path.display()
    );
    assert!(
        losses[losses.len() - 1] < losses[0] * 0.5,
        "training did not converge"
    );
    println!("loss halved: FFT-domain backward pass (Eqns. 2-3) works end-to-end from Rust");
    Ok(())
}
