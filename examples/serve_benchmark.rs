//! End-to-end serving benchmark (experiment E2E, serving half).
//!
//! Starts the coordinator (router -> dynamic batcher -> PJRT executor) and
//! drives it with concurrent synthetic clients at several batching
//! policies, reporting throughput, latency percentiles, mean batch
//! occupancy and padding waste — the serving-side counterpart of the
//! paper's batch-processing study (Fig. 4 / AB3).
//!
//! Per-config latency percentiles come straight from the telemetry
//! registry's `request_latency_us` histogram
//! ([`circnn::coordinator::Metrics::latency_percentile_us`]) and are
//! merged into `BENCH_circulant.json`'s `derived` map as
//! `serve_latency_{p50,p95,p99}_us_b<batch>_c<clients>` — plain
//! informational keys, outside the `_speedup_`/`_ratio_` CI contract.
//!
//! Run: `cargo run --release --example serve_benchmark`

use std::time::Duration;

use circnn::coordinator::{BatchPolicy, Server, ServerConfig};
use circnn::data;
use circnn::runtime::Manifest;

fn drive(
    model: &str,
    clients: usize,
    requests: usize,
    policy: BatchPolicy,
    derived: &mut Vec<(String, f64)>,
) -> anyhow::Result<()> {
    let server = Server::start(ServerConfig {
        policy,
        ..ServerConfig::default()
    })?;
    let man = Manifest::load(Manifest::default_dir())?;
    let ds = data::dataset(&man.model(model)?.dataset).unwrap();

    let t0 = std::time::Instant::now();
    let mut correct = 0usize;
    std::thread::scope(|scope| {
        let mut handles = Vec::new();
        for c in 0..clients {
            let server = &server;
            handles.push(scope.spawn(move || {
                let per = requests / clients;
                let mut ok = 0usize;
                for i in 0..per {
                    let idx = (c * per + i) as u64;
                    let (img, label) = data::sample(&ds, idx);
                    match server.infer(model, &img) {
                        Ok(resp) if resp.label == label => ok += 1,
                        Ok(_) => {}
                        Err(circnn::coordinator::InferError::Rejected) => {}
                        Err(e) => eprintln!("client {c}: {e}"),
                    }
                }
                ok
            }));
        }
        for h in handles {
            correct += h.join().unwrap();
        }
    });
    let dt = t0.elapsed();
    let m = server.metrics();
    println!(
        "max_batch={:<3} delay={:>4}us clients={clients}: {:>8.1} req/s  \
         train-split acc {:>5.1}%  {}",
        policy.max_batch,
        policy.max_delay.as_micros(),
        requests as f64 / dt.as_secs_f64(),
        100.0 * correct as f64 / requests as f64,
        m.summary()
    );
    let tag = format!("b{}_c{clients}", policy.max_batch);
    for (p, name) in [(50.0, "p50"), (95.0, "p95"), (99.0, "p99")] {
        derived.push((
            format!("serve_latency_{name}_us_{tag}"),
            m.latency_percentile_us(p) as f64,
        ));
    }
    server.shutdown();
    Ok(())
}

fn main() -> anyhow::Result<()> {
    let model = "mnist_mlp_1";
    let requests = 4096;
    println!("serving benchmark: {model}, {requests} requests per config\n");

    let mut derived: Vec<(String, f64)> = Vec::new();
    // the paper's design point: large interleaved batches
    for (max_batch, delay_us, clients) in [
        (1usize, 200u64, 8usize), // no batching (per-image pipeline, AB3-like)
        (8, 500, 8),
        (64, 2000, 8),  // paper's 50-100 batch regime
        (64, 2000, 32), // more concurrency -> fuller batches
    ] {
        drive(
            model,
            clients,
            requests,
            BatchPolicy {
                max_batch,
                max_delay: Duration::from_micros(delay_us),
                max_queue: 8192,
            },
            &mut derived,
        )?;
    }
    println!("\nexpected shape (paper Fig. 4): larger interleaved batches lift throughput;\n\
              per-image execution pays pipeline fills / fixed overheads per request.");

    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_circulant.json");
    circnn::util::benchkit::merge_derived(path, "circulant", &derived)?;
    println!("merged {} serve latency keys into {path}", derived.len());
    Ok(())
}
