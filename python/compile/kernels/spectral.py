"""Layer-1 Pallas kernel: phase-2 spectral multiply-accumulate.

This is the element-wise multiplier array of the paper's three-phase FPGA
datapath: given the precomputed half-spectra of the weight defining vectors
``Wf (p, q, kh)`` and of the input blocks ``Xf (batch, q, kh)``, produce

    Yf[b, i] = sum_j  Wf[i, j] o Xf[b, j]          (complex, element-wise)

On the FPGA this phase re-uses the FFT unit's hardware multipliers; on
TPU-shaped hardware it is pure VPU work over the ``kh`` lanes (deliberately
*not* an MXU op — the paper's point is replacing the dense matmul with
element-wise spectral work).

Grid: ``(batch_tiles, p)``.  Each step holds one weight block-row
``(q, kh)`` and one input tile ``(bt, q, kh)`` in VMEM — for the paper's
largest FC configuration (k=128, q<=32) that is under 1 MiB, matching the
BRAM-resident design point.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BATCH_TILE = 32


def _batch_tile(batch: int) -> int:
    tile = min(DEFAULT_BATCH_TILE, batch)
    while batch % tile != 0:
        tile -= 1
    return tile


def _spectral_kernel(wfr_ref, wfi_ref, xfr_ref, xfi_ref, yr_ref, yi_ref):
    # wf*: (1, q, kh) — block-row i of the weight spectra
    # xf*: (bt, q, kh) — input-tile spectra
    wfr, wfi = wfr_ref[0], wfi_ref[0]
    xfr, xfi = xfr_ref[...], xfi_ref[...]
    # complex multiply-accumulate over the q block-columns
    yr = jnp.sum(xfr * wfr[None] - xfi * wfi[None], axis=1)
    yi = jnp.sum(xfr * wfi[None] + xfi * wfr[None], axis=1)
    yr_ref[...] = yr[:, None, :]
    yi_ref[...] = yi[:, None, :]


def spectral_matmul_pallas(wfr, wfi, xfr, xfi):
    """Phase-2 kernel: ``(p,q,kh)`` x ``(batch,q,kh)`` -> ``(batch,p,kh)`` spectra."""
    p, q, kh = wfr.shape
    batch = xfr.shape[0]
    bt = _batch_tile(batch)
    w_spec = pl.BlockSpec((1, q, kh), lambda b, i: (i, 0, 0))
    x_spec = pl.BlockSpec((bt, q, kh), lambda b, i: (b, 0, 0))
    y_spec = pl.BlockSpec((bt, 1, kh), lambda b, i: (b, i, 0))
    out = jax.ShapeDtypeStruct((batch, p, kh), xfr.dtype)
    return pl.pallas_call(
        _spectral_kernel,
        grid=(batch // bt, p),
        in_specs=[w_spec, w_spec, x_spec, x_spec],
        out_specs=(y_spec, y_spec),
        out_shape=(out, out),
        interpret=True,
    )(wfr, wfi, xfr, xfi)
