"""Layer-1 fused Pallas kernel: one whole block-circulant FC layer.

Fuses the paper's three-phase datapath — (1) rFFT of the q input blocks,
(2) spectral multiply-accumulate against the precomputed weight spectra,
(3) Hermitian IFFT + bias + ReLU — into a single ``pallas_call``, exactly
the schedule Fig. 4 time-multiplexes onto the FPGA's one FFT unit.

The decoupling optimizations are structural here:
  * ``FFT(w_ij)`` is precomputed (kernel takes spectra, not weights);
  * ``FFT(x_j)`` is computed once per block-column (q rFFTs, not p*q);
  * the IFFT sits outside the sum over j (p IFFTs, not p*q);
  * only the ``k//2+1`` half-spectrum is stored/multiplied.

Grid: 1-D over batch tiles.  Per grid step the VMEM working set is the
input tile ``(bt, n)``, its spectra ``(bt, q, kh)``, the full weight spectra
``(p, q, kh)`` and the output tile ``(bt, m)`` — the "whole model on chip"
design point of the paper, which the VMEM-footprint estimator in
DESIGN.md §9 checks against the 2 MiB budget.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import fft_core

DEFAULT_BATCH_TILE = 16


def _batch_tile(batch: int) -> int:
    tile = min(DEFAULT_BATCH_TILE, batch)
    while batch % tile != 0:
        tile -= 1
    return tile


def _layer_kernel(x_ref, wfr_ref, wfi_ref, b_ref, o_ref, *, k: int, relu: bool):
    x = x_ref[...]  # (bt, n)
    wfr, wfi = wfr_ref[...], wfi_ref[...]  # (p, q, kh)
    bias = b_ref[...]  # (m,)
    bt = x.shape[0]
    p, q, kh = wfr.shape
    # Phase 1: q rFFTs per sample (decoupled: computed once, reused for all i).
    xb = x.reshape(bt, q, k)
    xfr, xfi = fft_core.rfft_halfspec(xb)  # (bt, q, kh)
    # Phase 2: spectral multiply-accumulate over j for every block-row i.
    accr = jnp.einsum("pqk,bqk->bpk", wfr, xfr) - jnp.einsum("pqk,bqk->bpk", wfi, xfi)
    acci = jnp.einsum("pqk,bqk->bpk", wfr, xfi) + jnp.einsum("pqk,bqk->bpk", wfi, xfr)
    # Phase 3: p Hermitian IFFTs, bias, activation (the FPGA folds bias+ReLU
    # into the IFFT pipeline's two extra stages).
    y = fft_core.irfft_halfspec(accr, acci, k)  # (bt, p, k)
    y = y.reshape(bt, p * k) + bias[None, :]
    if relu:
        y = jnp.maximum(y, 0.0)
    o_ref[...] = y


def circulant_layer_pallas(x, wfr, wfi, bias, *, k: int, relu: bool = True):
    """Fused block-circulant FC layer.

    ``x``: ``(batch, q*k)`` activations; ``wfr``/``wfi``: ``(p, q, k//2+1)``
    precomputed weight half-spectra; ``bias``: ``(p*k,)``.
    Returns ``(batch, p*k)``.
    """
    batch, n = x.shape
    p, q, kh = wfr.shape
    if n != q * k:
        raise ValueError(f"input width {n} != q*k = {q * k}")
    m = p * k
    bt = _batch_tile(batch)
    x_spec = pl.BlockSpec((bt, n), lambda i: (i, 0))
    w_spec = pl.BlockSpec((p, q, kh), lambda i: (0, 0, 0))
    b_spec = pl.BlockSpec((m,), lambda i: (0,))
    o_spec = pl.BlockSpec((bt, m), lambda i: (i, 0))
    return pl.pallas_call(
        lambda a, b, c, d, e: _layer_kernel(a, b, c, d, e, k=k, relu=relu),
        grid=(batch // bt,),
        in_specs=[x_spec, w_spec, w_spec, b_spec],
        out_specs=o_spec,
        out_shape=jax.ShapeDtypeStruct((batch, m), x.dtype),
        interpret=True,
    )(x, wfr, wfi, bias)


def vmem_footprint_bytes(batch_tile: int, n: int, m: int, p: int, q: int, k: int) -> int:
    """Estimated VMEM working set per grid step, in bytes (f32).

    Used by the perf pass (DESIGN.md §9) to check the "whole working set on
    chip" budget for every model/block-size configuration.
    """
    kh = k // 2 + 1
    x_tile = batch_tile * n
    x_spec = 2 * batch_tile * q * kh
    w_spec = 2 * p * q * kh
    acc = 2 * batch_tile * p * kh
    out = batch_tile * m + m
    return 4 * (x_tile + x_spec + w_spec + acc + out)
