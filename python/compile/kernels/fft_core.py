"""Shared radix-2 FFT building blocks for the Pallas kernels.

The FPGA datapath in the paper is a single pipelined k-point FFT unit,
time-multiplexed across FFTs and IFFTs (IFFT = conjugate trick on the same
butterfly structure).  We reproduce exactly that dataflow here: an iterative
radix-2 decimation-in-time FFT expressed as ``log2(k)`` vectorized butterfly
stages over separated real/imag planes.  These helpers are pure ``jnp``
functions so they can be called *inside* Pallas kernels (interpret mode) and
from plain JAX code alike — one numeric structure shared by the kernel, the
model, and the cycle-level simulator on the Rust side.

Spectra are kept as separated real/imag ``float32`` planes throughout: this
mirrors the FPGA's separate real/imag datapaths, and both the Pallas
interpreter and the PJRT literal API are friendlier to f32 planes than to
``complex64``.
"""

from __future__ import annotations



import numpy as np
import jax.numpy as jnp


def bit_reversal_permutation(k: int):
    """Bit-reversal permutation for a k-point radix-2 FFT (k a power of 2).

    Built from ``iota`` + shifts (traced ops, not a captured constant) so it
    is legal inside a Pallas kernel body.  This is the input reorder the
    FPGA performs with its addressing unit before the butterfly cascade.
    """
    if k & (k - 1) != 0 or k < 1:
        raise ValueError(f"k must be a power of 2, got {k}")
    bits = k.bit_length() - 1
    idx = jnp.arange(k, dtype=jnp.int32)
    rev = jnp.zeros_like(idx)
    for b in range(bits):
        rev = rev | (((idx >> b) & 1) << (bits - 1 - b))
    return rev


def _twiddles(stage: int, inverse: bool, dtype):
    """Twiddle factors for one butterfly stage (traced ops).

    Stage ``s`` (0-based) combines blocks of size ``2**s`` into ``2**(s+1)``;
    the half-block twiddles are ``exp(-+ 2*pi*i * t / 2**(s+1))`` for
    ``t in [0, 2**s)``.  On the FPGA these constants live in a small ROM per
    pipeline stage; here they are computed at trace time with ``iota`` +
    ``cos``/``sin`` so Pallas does not see captured constants.
    """
    half = 1 << stage
    t = jnp.arange(half, dtype=dtype)
    sign = 1.0 if inverse else -1.0
    ang = sign * 2.0 * np.pi * t / (2.0 * half)
    return jnp.cos(ang), jnp.sin(ang)


def fft_stages(xr, xi, *, inverse: bool = False):
    """Iterative radix-2 DIT FFT over the last axis of (real, imag) planes.

    ``xr``/``xi`` have shape ``(..., k)`` with ``k`` a power of two known at
    trace time.  Returns ``(yr, yi)`` of the same shape.  For ``inverse=True``
    computes the *unscaled* inverse DFT; callers divide by ``k`` (the FPGA
    folds the 1/k scaling into the final pipeline stage, we do the same at
    the call site so the butterfly cascade is identical for FFT and IFFT —
    the paper's "IFFT on the same FFT structure with a simple pre-processing
    step").
    """
    k = xr.shape[-1]
    stages = k.bit_length() - 1
    perm = bit_reversal_permutation(k)
    xr = jnp.take(xr, perm, axis=-1)
    xi = jnp.take(xi, perm, axis=-1)
    lead = xr.shape[:-1]
    for s in range(stages):
        half = 1 << s
        m = half * 2
        twr, twi = _twiddles(s, inverse, xr.dtype)
        xr = xr.reshape(lead + (k // m, m))
        xi = xi.reshape(lead + (k // m, m))
        ur, ui = xr[..., :half], xi[..., :half]
        vr_, vi_ = xr[..., half:], xi[..., half:]
        # complex multiply v * twiddle
        vr = vr_ * twr - vi_ * twi
        vi = vr_ * twi + vi_ * twr
        xr = jnp.concatenate([ur + vr, ur - vr], axis=-1)
        xi = jnp.concatenate([ui + vi, ui - vi], axis=-1)
        xr = xr.reshape(lead + (k,))
        xi = xi.reshape(lead + (k,))
    return xr, xi


def fft(xr, xi):
    """Forward k-point FFT over the last axis (real/imag planes)."""
    return fft_stages(xr, xi, inverse=False)


def ifft(xr, xi):
    """Inverse k-point FFT over the last axis, including the 1/k scaling."""
    k = xr.shape[-1]
    yr, yi = fft_stages(xr, xi, inverse=True)
    return yr / k, yi / k


def rfft_halfspec(x):
    """Real-input FFT returning only the first ``k//2 + 1`` bins.

    The paper's hardware optimization: for real-valued ``x`` the spectrum is
    conjugate-symmetric, so only half needs to be stored or multiplied.
    Returns ``(yr, yi)`` of shape ``(..., k//2 + 1)``.
    """
    k = x.shape[-1]
    yr, yi = fft_stages(x, jnp.zeros_like(x), inverse=False)
    kh = k // 2 + 1
    return yr[..., :kh], yi[..., :kh]


def irfft_halfspec(yr, yi, k: int):
    """Inverse of :func:`rfft_halfspec`: half-spectrum -> real signal.

    Reconstructs the full conjugate-symmetric spectrum then runs the inverse
    butterfly cascade; the imaginary output plane is discarded (it is zero up
    to rounding for a symmetric spectrum).  This mirrors the FPGA's
    Hermitian-symmetric IFFT pre-processing stage.
    """
    kh = k // 2 + 1
    if yr.shape[-1] != kh:
        raise ValueError(f"expected half-spectrum of {kh} bins, got {yr.shape[-1]}")
    # mirror bins 1..k/2-1 conjugated, reversed
    tail_r = yr[..., 1:-1][..., ::-1]
    tail_i = -yi[..., 1:-1][..., ::-1]
    fr = jnp.concatenate([yr, tail_r], axis=-1)
    fi = jnp.concatenate([yi, tail_i], axis=-1)
    xr, _ = ifft(fr, fi)
    return xr


def complex_mul(ar, ai, br, bi):
    """Element-wise complex multiply on separated planes."""
    return ar * br - ai * bi, ar * bi + ai * br
