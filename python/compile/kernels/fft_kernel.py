"""Layer-1 Pallas kernels: the k-point FFT/IFFT butterfly datapath.

Each kernel is the software twin of the paper's single pipelined FFT unit:
``log2(k)`` butterfly stages over separated real/imag planes, preceded by a
bit-reversal reorder, with IFFT realized on the same structure via the
conjugate/pre-processing trick (see :mod:`fft_core`).

Kernels run with ``interpret=True`` — the CPU PJRT plugin cannot execute
Mosaic custom calls — and are validated against the O(k^2) DFT oracle in
:mod:`ref` by ``python/tests/test_fft_kernel.py``.

Grid layout: 1-D grid over row tiles; each grid step transforms a
``(rows_per_tile, k)`` block held in VMEM.  For the block sizes the paper
uses (k in 4..256) a tile of 128 rows needs at most
``128 * 256 * 4 B * 2 planes = 256 KiB`` of VMEM — comfortably inside a TPU
core's ~16 MiB and matching the paper's "whole working set on chip" design
point.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import fft_core

DEFAULT_ROW_TILE = 128


def _row_tile(rows: int) -> int:
    tile = min(DEFAULT_ROW_TILE, rows)
    while rows % tile != 0:
        tile -= 1
    return tile


def _fft_kernel(xr_ref, xi_ref, or_ref, oi_ref, *, inverse: bool):
    xr, xi = xr_ref[...], xi_ref[...]
    yr, yi = fft_core.fft_stages(xr, xi, inverse=inverse)
    if inverse:
        k = xr.shape[-1]
        yr, yi = yr / k, yi / k
    or_ref[...] = yr
    oi_ref[...] = yi


def fft_pallas(xr, xi, *, inverse: bool = False):
    """k-point FFT (or scaled IFFT) of ``(rows, k)`` real/imag planes."""
    rows, k = xr.shape
    tile = _row_tile(rows)
    spec = pl.BlockSpec((tile, k), lambda i: (i, 0))
    out = jax.ShapeDtypeStruct((rows, k), xr.dtype)
    return pl.pallas_call(
        lambda a, b, c, d: _fft_kernel(a, b, c, d, inverse=inverse),
        grid=(rows // tile,),
        in_specs=[spec, spec],
        out_specs=(spec, spec),
        out_shape=(out, out),
        interpret=True,
    )(xr, xi)


def _rfft_kernel(x_ref, or_ref, oi_ref):
    x = x_ref[...]
    yr, yi = fft_core.fft_stages(x, jnp.zeros_like(x), inverse=False)
    kh = x.shape[-1] // 2 + 1
    or_ref[...] = yr[..., :kh]
    oi_ref[...] = yi[..., :kh]


def rfft_pallas(x):
    """Real-input FFT of ``(rows, k)`` -> half-spectrum ``(rows, k//2+1)`` planes.

    Implements the paper's real-FFT symmetry optimization: only the first
    ``k//2+1`` bins leave the kernel, halving spectrum storage and the
    phase-2 multiplier count.
    """
    rows, k = x.shape
    kh = k // 2 + 1
    tile = _row_tile(rows)
    in_spec = pl.BlockSpec((tile, k), lambda i: (i, 0))
    out_spec = pl.BlockSpec((tile, kh), lambda i: (i, 0))
    out = jax.ShapeDtypeStruct((rows, kh), x.dtype)
    return pl.pallas_call(
        _rfft_kernel,
        grid=(rows // tile,),
        in_specs=[in_spec],
        out_specs=(out_spec, out_spec),
        out_shape=(out, out),
        interpret=True,
    )(x)


def _irfft_kernel(yr_ref, yi_ref, o_ref, *, k: int):
    o_ref[...] = fft_core.irfft_halfspec(yr_ref[...], yi_ref[...], k)


def irfft_pallas(yr, yi, k: int):
    """Hermitian-symmetric IFFT: half-spectrum ``(rows, k//2+1)`` -> real ``(rows, k)``."""
    rows, kh = yr.shape
    if kh != k // 2 + 1:
        raise ValueError(f"half-spectrum width {kh} does not match k={k}")
    tile = _row_tile(rows)
    in_spec = pl.BlockSpec((tile, kh), lambda i: (i, 0))
    out_spec = pl.BlockSpec((tile, k), lambda i: (i, 0))
    return pl.pallas_call(
        lambda a, b, c: _irfft_kernel(a, b, c, k=k),
        grid=(rows // tile,),
        in_specs=[in_spec, in_spec],
        out_specs=out_spec,
        out_shape=jax.ShapeDtypeStruct((rows, k), yr.dtype),
        interpret=True,
    )(yr, yi)
