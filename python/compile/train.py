"""Layer-2 training: Adam, quant-aware fine-tuning, and Bayesian VI.

The paper trains the defining vectors ``w_ij`` directly — Eqns. (2)/(3)
show the gradients are themselves FFT->elementwise->IFFT computations, and
JAX autodiff recovers exactly that structure from our forward definition
(verified by ``test_train.py::test_gradient_matches_explicit_matrix``).

Bayesian learning follows the paper's variational-inference co-optimization
step: every weight is ``w = mu + softplus(rho) * eps`` with a standard
normal prior; training learns (mu, rho) by maximizing the ELBO (data
log-likelihood minus KL), inference uses the mean ``mu`` — "the inference
phase (implemented in hardware) will be the same, using the average
estimate of each weight."
"""

from __future__ import annotations

import functools
import time

import numpy as np
import jax
import jax.numpy as jnp

from . import data as data_mod
from . import model as model_mod


# ---------------------------------------------------------------------------
# loss / metrics
# ---------------------------------------------------------------------------

def cross_entropy(logits, labels):
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.mean(jnp.take_along_axis(logp, labels[:, None], axis=1))


def accuracy(logits, labels):
    return jnp.mean((jnp.argmax(logits, axis=-1) == labels).astype(jnp.float32))


# ---------------------------------------------------------------------------
# hand-rolled Adam (no optax in this environment)
# ---------------------------------------------------------------------------

def adam_init(params):
    zeros = jax.tree_util.tree_map(jnp.zeros_like, params)
    return {"m": zeros, "v": jax.tree_util.tree_map(jnp.zeros_like, params),
            "t": jnp.zeros((), jnp.int32)}


def adam_update(params, grads, state, lr=1e-3, b1=0.9, b2=0.999, eps=1e-8):
    t = state["t"] + 1
    m = jax.tree_util.tree_map(lambda m, g: b1 * m + (1 - b1) * g, state["m"], grads)
    v = jax.tree_util.tree_map(lambda v, g: b2 * v + (1 - b2) * g * g, state["v"], grads)
    mhat_scale = 1.0 / (1 - b1 ** t.astype(jnp.float32))
    vhat_scale = 1.0 / (1 - b2 ** t.astype(jnp.float32))
    params = jax.tree_util.tree_map(
        lambda p, m_, v_: p - lr * (m_ * mhat_scale) / (jnp.sqrt(v_ * vhat_scale) + eps),
        params, m, v)
    return params, {"m": m, "v": v, "t": t}


# ---------------------------------------------------------------------------
# point training
# ---------------------------------------------------------------------------

def make_train_step(model: model_mod.ModelSpec, *, dense_twin=False,
                    quant_bits=None, lr=1e-3):
    """Jitted (params, opt, x, y) -> (params, opt, loss) Adam step."""

    def loss_fn(params, x, y):
        logits = model_mod.apply(params, x, model, dense_twin=dense_twin,
                                 quant_bits=quant_bits)
        return cross_entropy(logits, y)

    @jax.jit
    def step(params, opt, x, y):
        loss, grads = jax.value_and_grad(loss_fn)(params, x, y)
        params, opt = adam_update(params, grads, opt, lr=lr)
        return params, opt, loss

    return step


def train(model: model_mod.ModelSpec, *, steps=400, batch=64, train_size=4096,
          dense_twin=False, quant_bits=None, lr=1e-3, seed=0, log_every=0):
    """Train on the synthetic dataset; returns (params, loss_history)."""
    xs, ys = data_mod.batch(model.dataset, 0, train_size)
    xs, ys = jnp.asarray(xs), jnp.asarray(ys)
    key = jax.random.PRNGKey(seed)
    params = model_mod.init_params(key, model, dense_twin=dense_twin)
    opt = adam_init(params)
    step = make_train_step(model, dense_twin=dense_twin, quant_bits=quant_bits, lr=lr)
    losses = []
    n_batches = train_size // batch
    for s in range(steps):
        lo = (s % n_batches) * batch
        params, opt, loss = step(params, opt, xs[lo:lo + batch], ys[lo:lo + batch])
        losses.append(float(loss))
        if log_every and s % log_every == 0:
            print(f"  [{model.name}] step {s:4d} loss {float(loss):.4f}", flush=True)
    return params, losses


def evaluate(params, model: model_mod.ModelSpec, *, test_size=1024, batch=128,
             dense_twin=False, quant_bits=None):
    """Test-split accuracy."""
    xs, ys = data_mod.batch(model.dataset, 0, test_size, test=True)
    xs, ys = jnp.asarray(xs), jnp.asarray(ys)
    fwd = jax.jit(functools.partial(model_mod.apply, model=model,
                                    dense_twin=dense_twin, quant_bits=quant_bits))
    correct = 0
    for lo in range(0, test_size, batch):
        logits = fwd(params, xs[lo:lo + batch])
        correct += int(jnp.sum(jnp.argmax(logits, -1) == ys[lo:lo + batch]))
    return correct / test_size


# ---------------------------------------------------------------------------
# Bayesian variational inference
# ---------------------------------------------------------------------------

def vi_init(params, rho0=-5.0):
    """Wrap point params into (mu, rho) variational parameters."""
    return {
        "mu": params,
        "rho": jax.tree_util.tree_map(lambda p: jnp.full_like(p, rho0), params),
    }


def vi_sample(vparams, key):
    leaves, treedef = jax.tree_util.tree_flatten(vparams["mu"])
    keys = jax.random.split(key, len(leaves))
    rho_leaves = jax.tree_util.tree_leaves(vparams["rho"])
    sampled = [mu + jax.nn.softplus(rho) * jax.random.normal(k, mu.shape)
               for mu, rho, k in zip(leaves, rho_leaves, keys)]
    return jax.tree_util.tree_unflatten(treedef, sampled)


def vi_kl(vparams, prior_sigma=0.1):
    """KL( N(mu, sigma^2) || N(0, prior_sigma^2) ), summed over weights."""
    total = 0.0
    for mu, rho in zip(jax.tree_util.tree_leaves(vparams["mu"]),
                       jax.tree_util.tree_leaves(vparams["rho"])):
        sigma = jax.nn.softplus(rho)
        total = total + jnp.sum(
            jnp.log(prior_sigma / sigma)
            + (sigma ** 2 + mu ** 2) / (2 * prior_sigma ** 2) - 0.5)
    return total


def train_bayes(model: model_mod.ModelSpec, *, steps=400, batch=64,
                train_size=512, kl_weight=1e-4, lr=1e-3, seed=0):
    """Variational-inference training (paper: most effective for small data).

    Returns (mean_params, loss_history): inference uses the mean estimate,
    exactly as the paper's hardware does.
    """
    xs, ys = data_mod.batch(model.dataset, 0, train_size)
    xs, ys = jnp.asarray(xs), jnp.asarray(ys)
    key = jax.random.PRNGKey(seed)
    key, init_key = jax.random.split(key)
    vparams = vi_init(model_mod.init_params(init_key, model))
    opt = adam_init(vparams)

    def elbo_loss(vparams, x, y, k):
        sampled = vi_sample(vparams, k)
        logits = model_mod.apply(sampled, x, model)
        return cross_entropy(logits, y) + kl_weight * vi_kl(vparams)

    @jax.jit
    def step(vparams, opt, x, y, k):
        loss, grads = jax.value_and_grad(elbo_loss)(vparams, x, y, k)
        vparams, opt = adam_update(vparams, grads, opt, lr=lr)
        return vparams, opt, loss

    losses = []
    n_batches = max(1, train_size // batch)
    for s in range(steps):
        key, sub = jax.random.split(key)
        lo = (s % n_batches) * batch
        vparams, opt, loss = step(vparams, opt, xs[lo:lo + batch], ys[lo:lo + batch], sub)
        losses.append(float(loss))
    return vparams["mu"], losses


# ---------------------------------------------------------------------------
# block-size sweep (the co-optimization loop's accuracy axis, exp S2)
# ---------------------------------------------------------------------------

def block_size_sweep(ks=(2, 4, 8, 16, 32, 64), *, steps=300, seed=0):
    """Accuracy vs block size on the MNIST-like task (fixed 256-256 MLP)."""
    results = []
    for k in ks:
        spec = model_mod._mlp("sweep_mlp", "mnist_s", 256, [256], k, (0, 0, 0))
        params, _ = train(spec, steps=steps, seed=seed)
        acc = evaluate(params, spec)
        storage = model_mod.storage_report(spec)
        results.append(dict(k=k, accuracy=acc, reduction=storage["reduction"]))
    return results
