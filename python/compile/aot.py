"""AOT export: train the registry models, lower to HLO text, emit manifest.

This is the single build-time entry point (``make artifacts``); Python never
runs on the request path.  For every model in the registry it:

  1. trains on the synthetic dataset (params cached in ``artifacts/params``),
  2. evaluates circulant@12-bit and the dense twin,
  3. bakes the quantized parameters into a jitted forward pass and lowers it
     to **HLO text** (not ``.serialize()`` — the image's xla_extension 0.5.1
     rejects jax>=0.5's 64-bit-id protos; the text parser reassigns ids, see
     /opt/xla-example/README.md), one artifact per serving batch size,
  4. additionally exports a Pallas-kernel-backed variant of ``mnist_mlp_1``
     (proof that the L1 kernel lowers into the same interchange format), and
  5. exports a training pipeline (init + train-step with flattened params)
     for the end-to-end Rust training example,

then writes ``artifacts/manifest.json`` describing every artifact, the
per-model accounting (Fig. 3 storage, equivalent GOPS), measured accuracies
next to the paper's Table-1 rows, and dataset checksums for the Rust mirror.

Usage: ``cd python && python -m compile.aot --out-dir ../artifacts [--fast]``
"""

from __future__ import annotations

import argparse
import json
import os
import time

import numpy as np
import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import data as data_mod
from . import layers
from . import model as model_mod
from . import train as train_mod

QUANT_BITS = 12
SERVE_BATCHES = (1, 64)

# steps tuned so `make artifacts` stays in single-digit minutes on CPU
TRAIN_STEPS = {
    "mnist_mlp_1": 600, "mnist_mlp_2": 600, "mnist_lenet": 400,
    "svhn_cnn": 400, "cifar_cnn": 400, "cifar_wrn": 300,
}
DENSE_TWIN_STEPS = 300


def to_hlo_text(lowered) -> str:
    """jax lowered -> XLA HLO text (the interchange format, see module doc).

    ``print_large_constants=True`` is load-bearing: the default elides big
    literals as ``{...}``, which the consuming parser silently reads as
    zeros — with baked-in trained weights that turns the whole model into
    a zero function.  (Found the hard way; pinned by
    ``test_aot.test_hlo_text_includes_large_constants`` and the Rust
    runtime round-trip test.)
    """
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text(print_large_constants=True)


def _params_path(out_dir, name):
    return os.path.join(out_dir, "params", f"{name}.npz")


def _flatten_params(params):
    """Stable flattening of the per-layer param list -> ordered (name, array)."""
    flat = []
    for i, p in enumerate(params):
        if p is None:
            continue
        for field in sorted(p.keys()):
            flat.append((f"L{i:02d}_{field}", p[field]))
    return flat


def _unflatten_params(model, arrays):
    """Inverse of `_flatten_params` given the model's spec skeleton."""
    params, it = [], iter(arrays)
    skeleton = model_mod.init_params(jax.random.PRNGKey(0), model)
    for p in skeleton:
        if p is None:
            params.append(None)
        else:
            params.append({field: next(it) for field in sorted(p.keys())})
    return params


def save_params(path, params):
    os.makedirs(os.path.dirname(path), exist_ok=True)
    flat = _flatten_params(params)
    np.savez(path, **{k: np.asarray(v) for k, v in flat})


def load_params(path, model):
    with np.load(path) as z:
        names = sorted(z.files)
        arrays = [jnp.asarray(z[n]) for n in names]
    return _unflatten_params(model, arrays)


def train_or_load(model, out_dir, *, fast=False, force=False):
    path = _params_path(out_dir, model.name)
    if os.path.exists(path) and not force:
        return load_params(path, model), True
    steps = TRAIN_STEPS[model.name] if model.name in TRAIN_STEPS else 300
    if fast:
        steps = min(steps, 60)
    t0 = time.time()
    params, losses = train_mod.train(model, steps=steps, quant_bits=QUANT_BITS)
    print(f"  trained {model.name}: {steps} steps in {time.time()-t0:.1f}s "
          f"loss {losses[0]:.3f}->{losses[-1]:.3f}", flush=True)
    save_params(path, params)
    return params, False


def export_inference(model, params, out_dir, *, backend="jnp", suffix=""):
    """Bake (quantized) params into the forward pass; one HLO per batch size."""
    h, w, c = model.input_shape
    entries = []
    for batch in SERVE_BATCHES:
        def fwd(x):
            return (model_mod.apply(params, x, model, backend=backend,
                                    quant_bits=QUANT_BITS),)
        spec = jax.ShapeDtypeStruct((batch, h, w, c), jnp.float32)
        text = to_hlo_text(jax.jit(fwd).lower(spec))
        fname = f"{model.name}{suffix}_b{batch}.hlo.txt"
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(text)
        entries.append(dict(batch=batch, file=fname,
                            input_shape=[batch, h, w, c], output_shape=[batch, 10]))
    return entries


def export_training(model, out_dir, *, train_batch=64):
    """Init + train-step artifacts with flattened params (exp E2E).

    ``<name>_train_init.hlo.txt``: () -> tuple(flat initial params)
    ``<name>_train_step.hlo.txt``: (*flat_params, *flat_opt_m, *flat_opt_v,
        t, x, y) -> tuple(*new_params, *new_m, *new_v, new_t, loss)
    The Rust driver treats the whole optimizer state as an opaque ordered
    list of literals it feeds back each step.
    """
    h, w, c = model.input_shape
    key = jax.random.PRNGKey(0)
    params0 = model_mod.init_params(key, model)
    flat0 = _flatten_params(params0)
    names = [n for n, _ in flat0]
    arrays0 = [v for _, v in flat0]

    def rebuild(arrays):
        return _unflatten_params(model, list(arrays))

    def loss_fn(arrays, x, y):
        logits = model_mod.apply(rebuild(arrays), x, model, quant_bits=QUANT_BITS)
        return train_mod.cross_entropy(logits, y)

    lr, b1, b2, eps = 1e-3, 0.9, 0.999, 1e-8

    def train_step(*args):
        nparam = len(names)
        arrays = list(args[:nparam])
        ms = list(args[nparam:2 * nparam])
        vs = list(args[2 * nparam:3 * nparam])
        t = args[3 * nparam]
        x, y = args[3 * nparam + 1], args[3 * nparam + 2]
        loss, grads = jax.value_and_grad(loss_fn)(arrays, x, y)
        t = t + 1
        tf = t.astype(jnp.float32)
        out_p, out_m, out_v = [], [], []
        for pth, g, m_, v_ in zip(arrays, grads, ms, vs):
            m_ = b1 * m_ + (1 - b1) * g
            v_ = b2 * v_ + (1 - b2) * g * g
            mhat = m_ / (1 - b1 ** tf)
            vhat = v_ / (1 - b2 ** tf)
            out_p.append(pth - lr * mhat / (jnp.sqrt(vhat) + eps))
            out_m.append(m_)
            out_v.append(v_)
        return tuple(out_p + out_m + out_v + [t, loss])

    def train_init():
        zeros = [jnp.zeros_like(a) for a in arrays0]
        return tuple(list(arrays0) + zeros + [jnp.zeros_like(a) for a in arrays0]
                     + [jnp.zeros((), jnp.int32)])

    init_text = to_hlo_text(jax.jit(train_init).lower())
    init_file = f"{model.name}_train_init.hlo.txt"
    with open(os.path.join(out_dir, init_file), "w") as f:
        f.write(init_text)

    specs = ([jax.ShapeDtypeStruct(a.shape, a.dtype) for a in arrays0] * 3
             + [jax.ShapeDtypeStruct((), jnp.int32),
                jax.ShapeDtypeStruct((train_batch, h, w, c), jnp.float32),
                jax.ShapeDtypeStruct((train_batch,), jnp.int32)])
    step_text = to_hlo_text(jax.jit(train_step).lower(*specs))
    step_file = f"{model.name}_train_step.hlo.txt"
    with open(os.path.join(out_dir, step_file), "w") as f:
        f.write(step_text)

    return dict(
        init_file=init_file, step_file=step_file, batch=train_batch,
        param_names=names,
        param_shapes=[list(a.shape) for a in arrays0],
        state_layout="params*N, adam_m*N, adam_v*N, t(i32), then step args x,y",
        loss_index=3 * len(names) + 1,
    )


def build_manifest(out_dir, *, fast=False):
    os.makedirs(out_dir, exist_ok=True)
    manifest = dict(
        version=1,
        quant_bits=QUANT_BITS,
        generated_unix=int(time.time()),
        datasets={
            name: dict(shape=list(data_mod.DATASETS[name][:3]),
                       num_classes=data_mod.NUM_CLASSES,
                       modes=data_mod.MODES,
                       noise_amp=float(data_mod.NOISE_AMP),
                       checksum=str(data_mod.checksum(name)))
            for name in data_mod.DATASETS
        },
        models=[],
    )

    for name, model in model_mod.REGISTRY.items():
        print(f"[aot] {name}", flush=True)
        params, cached = train_or_load(model, out_dir, fast=fast)
        acc = train_mod.evaluate(params, model, quant_bits=QUANT_BITS)
        acc_f32 = train_mod.evaluate(params, model, quant_bits=None)

        # dense twin (uncompressed baseline) accuracy
        twin_path = _params_path(out_dir, name + "_dense")
        twin_model = model
        if os.path.exists(twin_path):
            twin_params = load_params_dense(twin_path, twin_model)
        else:
            steps = min(DENSE_TWIN_STEPS, 60) if fast else DENSE_TWIN_STEPS
            twin_params, _ = train_mod.train(twin_model, steps=steps, dense_twin=True)
            save_params(twin_path, twin_params)
        twin_acc = train_mod.evaluate(twin_params, twin_model, dense_twin=True)

        artifacts = export_inference(model, params, out_dir)
        entry = dict(
            name=name,
            dataset=model.dataset,
            description=model.description,
            input_shape=list(model.input_shape),
            serve_batch=model.batch,
            accuracy=dict(circulant_12bit=acc, circulant_f32=acc_f32,
                          dense_f32=twin_acc),
            paper=dict(accuracy=model.paper_accuracy, kfps=model.paper_kfps,
                       kfps_per_w=model.paper_kfps_per_w),
            storage=model_mod.storage_report(model, bits=QUANT_BITS),
            equivalent_ops_per_image=model_mod.equivalent_ops_per_image(model),
            layers=model_mod.accounting(model),
            artifacts=artifacts,
        )
        if name == "mnist_mlp_1":
            entry["artifacts_pallas"] = export_inference(
                model, params, out_dir, backend="pallas", suffix="_pallas")
            entry["training"] = export_training(model, out_dir)
        manifest["models"].append(entry)
        print(f"  acc circ12={acc:.4f} circ32={acc_f32:.4f} dense={twin_acc:.4f} "
              f"storage x{entry['storage']['reduction']:.1f}", flush=True)

    path = os.path.join(out_dir, "manifest.json")
    with open(path, "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"[aot] wrote {path}", flush=True)
    return manifest


def load_params_dense(path, model):
    with np.load(path) as z:
        names = sorted(z.files)
        arrays = [jnp.asarray(z[n]) for n in names]
    params, it = [], iter(arrays)
    skeleton = model_mod.init_params(jax.random.PRNGKey(0), model, dense_twin=True)
    for p in skeleton:
        if p is None:
            params.append(None)
        else:
            params.append({field: next(it) for field in sorted(p.keys())})
    return params


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--fast", action="store_true",
                    help="cut training steps (CI / test mode)")
    args = ap.parse_args()
    build_manifest(args.out_dir, fast=args.fast)


if __name__ == "__main__":
    main()
