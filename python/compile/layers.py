"""Layer-2 building blocks: block-circulant FC and CONV layers.

Three interchangeable execution backends compute the same numbers:

* ``"jnp"`` — ``jnp.fft.rfft``/``irfft`` (lowers to the plain HLO ``fft`` op
  the Rust PJRT runtime executes; the AOT export path).
* ``"pallas"`` — the fused Layer-1 kernel (the FPGA datapath twin).
* ``"core"`` — the shared butterfly implementation in :mod:`kernels.fft_core`
  (used to cross-check the other two).

The decoupling optimizations are structural in all three: weight spectra are
precomputed once, input-block FFTs are computed once per block-column, and
the IFFT sits outside the accumulation (q rFFTs + p IFFTs per sample, not
p*q of each).
"""

from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from .kernels import fft_core
from .kernels.circulant_layer import circulant_layer_pallas


# ---------------------------------------------------------------------------
# quantization (the paper's 12-bit fixed-point datapath)
# ---------------------------------------------------------------------------

def fake_quant(x, bits: int = 12):
    """Symmetric uniform fake-quantization with a straight-through estimator.

    Models the FPGA's ``bits``-bit fixed-point datapath during training and
    evaluation; the forward value is quantized, the gradient passes through
    unchanged (STE).  Scale is per-tensor max-abs, matching the simple
    fixed-point calibration the paper's hardware uses.
    """
    if bits is None:
        return x
    levels = float(2 ** (bits - 1) - 1)
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-8) / levels
    q = jnp.round(x / scale) * scale
    return x + jax.lax.stop_gradient(q - x)


# ---------------------------------------------------------------------------
# block-circulant FC
# ---------------------------------------------------------------------------

def init_bc_dense(key, n: int, m: int, k: int):
    """Initialize a block-circulant FC layer: defining vectors + bias.

    Weight scale matches He-init of the *equivalent dense layer*: each output
    element is a sum of n products where the effective dense entry is some
    ``w_blocks`` element, so ``std = sqrt(2/n)`` applies to the defining
    vectors directly.
    """
    if n % k or m % k:
        raise ValueError(f"k={k} must divide n={n} and m={m}")
    p, q = m // k, n // k
    kw, _ = jax.random.split(key)
    w = jax.random.normal(kw, (p, q, k), dtype=jnp.float32) * np.sqrt(2.0 / n)
    b = jnp.zeros((m,), dtype=jnp.float32)
    return {"w": w, "b": b}


def bc_dense_spectra(w_blocks):
    """Precompute the half-spectra of the defining vectors (real/imag planes).

    This is the paper's offline ``FFT(w_ij)`` precomputation: at inference
    time only the spectra exist — in the HLO artifacts they are baked
    constants, in the FPGA they sit in BRAM.
    """
    wf = jnp.fft.rfft(w_blocks, axis=-1)
    return jnp.real(wf).astype(jnp.float32), jnp.imag(wf).astype(jnp.float32)


def bc_dense_apply(params, x, *, k: int, activation: str = "relu",
                   backend: str = "jnp", quant_bits=None):
    """Apply a block-circulant FC layer to ``x`` of shape ``(batch, n)``."""
    w, b = params["w"], params["b"]
    if quant_bits is not None:
        w = fake_quant(w, quant_bits)
        x = fake_quant(x, quant_bits)
    p, q, _ = w.shape
    batch = x.shape[0]
    if backend == "pallas":
        wfr, wfi = bc_dense_spectra(w)
        y = circulant_layer_pallas(x, wfr, wfi, b, k=k, relu=(activation == "relu"))
        return y
    if backend == "jnp":
        xf = jnp.fft.rfft(x.reshape(batch, q, k), axis=-1)
        wf = jnp.fft.rfft(w, axis=-1)
        acc = jnp.einsum("pqk,bqk->bpk", wf, xf)
        y = jnp.fft.irfft(acc, n=k, axis=-1).reshape(batch, p * k)
    elif backend == "core":
        xfr, xfi = fft_core.rfft_halfspec(x.reshape(batch, q, k))
        wfr, wfi = fft_core.rfft_halfspec(w)
        ar = jnp.einsum("pqk,bqk->bpk", wfr, xfr) - jnp.einsum("pqk,bqk->bpk", wfi, xfi)
        ai = jnp.einsum("pqk,bqk->bpk", wfr, xfi) + jnp.einsum("pqk,bqk->bpk", wfi, xfr)
        y = fft_core.irfft_halfspec(ar, ai, k).reshape(batch, p * k)
    else:
        raise ValueError(f"unknown backend {backend!r}")
    y = y + b[None, :]
    if activation == "relu":
        y = jnp.maximum(y, 0.0)
    elif activation != "none":
        raise ValueError(f"unknown activation {activation!r}")
    return y


# ---------------------------------------------------------------------------
# dense twins (uncompressed baselines)
# ---------------------------------------------------------------------------

def init_dense(key, n: int, m: int):
    kw, _ = jax.random.split(key)
    w = jax.random.normal(kw, (n, m), dtype=jnp.float32) * np.sqrt(2.0 / n)
    return {"w": w, "b": jnp.zeros((m,), dtype=jnp.float32)}


def dense_apply(params, x, *, activation: str = "relu", quant_bits=None):
    w, b = params["w"], params["b"]
    if quant_bits is not None:
        w = fake_quant(w, quant_bits)
        x = fake_quant(x, quant_bits)
    y = x @ w + b[None, :]
    if activation == "relu":
        y = jnp.maximum(y, 0.0)
    elif activation != "none":
        raise ValueError(f"unknown activation {activation!r}")
    return y


# ---------------------------------------------------------------------------
# im2col and CONV layers
# ---------------------------------------------------------------------------

def im2col(x, r: int, k: int):
    """Vectorized im2col with the block-contiguous channel ordering.

    ``x``: ``(batch, H, W, C)``, ``C`` divisible by ``k``; VALID patches.
    Returns ``(batch, oh, ow, (C//k)*r*r, k)`` — j enumerates
    ``(c_block, di, dj)`` with the k channel lanes contiguous, exactly the
    ``x_j`` block layout Eqn. (1) needs.
    """
    b, h, w, c = x.shape
    qc = c // k
    oh, ow = h - r + 1, w - r + 1
    taps = []
    for di in range(r):
        for dj in range(r):
            taps.append(x[:, di : di + oh, dj : dj + ow, :])
    # (b, oh, ow, r*r, qc, k) -> (b, oh, ow, qc, r*r, k)
    stacked = jnp.stack(taps, axis=3).reshape(b, oh, ow, r * r, qc, k)
    ordered = jnp.transpose(stacked, (0, 1, 2, 4, 3, 5))
    return ordered.reshape(b, oh, ow, qc * r * r, k)


def init_bc_conv(key, c: int, p_out: int, r: int, k: int):
    """Block-circulant CONV layer (CirCNN convention over the C/P dims)."""
    if c % k or p_out % k:
        raise ValueError(f"k={k} must divide C={c} and P={p_out}")
    fan_in = c * r * r
    kw, _ = jax.random.split(key)
    w = jax.random.normal(kw, (p_out // k, (c // k) * r * r, k), dtype=jnp.float32)
    w = w * np.sqrt(2.0 / fan_in)
    return {"w": w, "b": jnp.zeros((p_out,), dtype=jnp.float32)}


def bc_conv_apply(params, x, *, r: int, k: int, activation: str = "relu",
                  padding: str = "valid", quant_bits=None):
    """Block-circulant CONV via im2col + the spectral FC machinery.

    The paper's CONV generalization: after im2col the weight matrix
    ``F (Cr^2 x P)`` is block-circulant, so the same FFT -> elementwise ->
    IFFT procedure applies with q' = (C/k) r^2 column blocks.
    """
    w, b = params["w"], params["b"]
    if quant_bits is not None:
        w = fake_quant(w, quant_bits)
        x = fake_quant(x, quant_bits)
    if padding == "same":
        pad = (r - 1) // 2
        x = jnp.pad(x, ((0, 0), (pad, r - 1 - pad), (pad, r - 1 - pad), (0, 0)))
    elif padding != "valid":
        raise ValueError(f"unknown padding {padding!r}")
    bsz = x.shape[0]
    cols = im2col(x, r, k)  # (b, oh, ow, q', k)
    oh, ow = cols.shape[1], cols.shape[2]
    xf = jnp.fft.rfft(cols, axis=-1)
    wf = jnp.fft.rfft(w, axis=-1)  # (p', q', kh)
    acc = jnp.einsum("pqk,bhwqk->bhwpk", wf, xf)
    y = jnp.fft.irfft(acc, n=k, axis=-1)  # (b, oh, ow, p', k)
    y = y.reshape(bsz, oh, ow, -1) + b[None, None, None, :]
    if activation == "relu":
        y = jnp.maximum(y, 0.0)
    elif activation != "none":
        raise ValueError(f"unknown activation {activation!r}")
    return y


def init_conv(key, c: int, p_out: int, r: int):
    fan_in = c * r * r
    kw, _ = jax.random.split(key)
    f = jax.random.normal(kw, (r, r, c, p_out), dtype=jnp.float32) * np.sqrt(2.0 / fan_in)
    return {"w": f, "b": jnp.zeros((p_out,), dtype=jnp.float32)}


def conv_apply(params, x, *, activation: str = "relu", padding: str = "valid",
               quant_bits=None):
    """Dense VALID/SAME convolution (uncompressed baseline / stem layers)."""
    f, b = params["w"], params["b"]
    if quant_bits is not None:
        f = fake_quant(f, quant_bits)
        x = fake_quant(x, quant_bits)
    y = jax.lax.conv_general_dilated(
        x, f, window_strides=(1, 1),
        padding="SAME" if padding == "same" else "VALID",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    y = y + b[None, None, None, :]
    if activation == "relu":
        y = jnp.maximum(y, 0.0)
    elif activation != "none":
        raise ValueError(f"unknown activation {activation!r}")
    return y


# ---------------------------------------------------------------------------
# pooling and the paper's "prior pooling" input reduction
# ---------------------------------------------------------------------------

def avg_pool2(x):
    """2x2 average pooling, stride 2 (NHWC)."""
    b, h, w, c = x.shape
    return x.reshape(b, h // 2, 2, w // 2, 2, c).mean(axis=(2, 4))


def max_pool2(x):
    b, h, w, c = x.shape
    return x.reshape(b, h // 2, 2, w // 2, 2, c).max(axis=(2, 4))


def prior_pool(x, out_dim: int):
    """The paper's input-size reduction for the MNIST MLPs.

    1-D average pooling of the flattened image down to ``out_dim`` values:
    window = ceil(dim/out_dim), zero-pad the tail so windows tile evenly.
    Deterministic and mirrored bit-for-bit by ``rust/src/data/prior_pool``.
    """
    b = x.shape[0]
    flat = x.reshape(b, -1)
    dim = flat.shape[1]
    win = -(-dim // out_dim)  # ceil
    padded = jnp.pad(flat, ((0, 0), (0, win * out_dim - dim)))
    return padded.reshape(b, out_dim, win).mean(axis=2)
