"""Experiment S2/S3 driver: block-size sweep + Bayesian-vs-point study.

Writes ``artifacts/sweep.json`` consumed by ``examples/codesign_sweep.rs``
(the co-optimization frontier) and EXPERIMENTS.md.

Usage: ``cd python && python -m compile.train_sweep --out ../artifacts/sweep.json``
"""

from __future__ import annotations

import argparse
import json
import time

from . import model as model_mod
from . import train as train_mod


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts/sweep.json")
    ap.add_argument("--steps", type=int, default=400)
    args = ap.parse_args()

    t0 = time.time()
    print("[sweep] block-size sweep (S2)", flush=True)
    sweep = train_mod.block_size_sweep(steps=args.steps)
    for row in sweep:
        print(f"  k={row['k']:3d} acc={row['accuracy']:.4f} "
              f"storage x{row['reduction']:.1f}", flush=True)

    print("[sweep] Bayesian VI vs point, small data (S3)", flush=True)
    spec = model_mod.REGISTRY["mnist_mlp_1"]
    bayes_rows = []
    for n in (128, 256, 512):
        point, _ = train_mod.train(spec, steps=300, train_size=n, seed=2)
        acc_point = train_mod.evaluate(point, spec, test_size=512)
        mean, _ = train_mod.train_bayes(spec, steps=300, train_size=n, seed=2)
        acc_bayes = train_mod.evaluate(mean, spec, test_size=512)
        bayes_rows.append(dict(train_size=n, point=acc_point, bayes=acc_bayes))
        print(f"  n={n:4d} point={acc_point:.4f} bayes={acc_bayes:.4f}", flush=True)

    with open(args.out, "w") as f:
        json.dump(dict(block_size_sweep=sweep, bayes_vs_point=bayes_rows,
                       steps=args.steps, elapsed_s=time.time() - t0), f, indent=1)
    print(f"[sweep] wrote {args.out} in {time.time()-t0:.0f}s", flush=True)


if __name__ == "__main__":
    main()
