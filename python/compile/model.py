"""Layer-2 model definitions: the six Table-1 networks + dense twins.

A small spec-driven composable model system: a model is a list of
``LayerSpec``s interpreted by :func:`init_params` / :func:`apply`.  Each
block-circulant model has a *dense twin* (same architecture, uncompressed
weights) used for the paper's baseline accounting and accuracy comparison.

The registry mirrors Table 1 of the paper:

  mnist_mlp_1   MLP, prior-pooled 256-d input   (paper row: 92.9%)
  mnist_mlp_2   MLP, prior-pooled 128-d input   (paper row: 95.6%)
  mnist_lenet   LeNet-5-like CNN                (paper row: 99.0%)
  svhn_cnn      small CNN                       (paper row: 96.2%)
  cifar_cnn     small CNN                       (paper row: 80.3%)
  cifar_wrn     wide-ResNet-lite with residual  (paper row: 94.75%)
                block-circulant CONV blocks

Block sizes follow the paper's co-optimization guidance: 64-128 for FC
layers, smaller (4-16) for CONV layers.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

import jax
import jax.numpy as jnp

from . import layers


@dataclass(frozen=True)
class LayerSpec:
    """One layer of a model.

    ``kind``: bc_dense | dense | bc_conv | conv | avg_pool2 | max_pool2 |
    flatten | prior_pool | residual_begin | residual_end.
    Residual markers bracket a sequence whose input is added back to its
    output (shapes must match; used by cifar_wrn).
    """
    kind: str
    n: int = 0            # fc in-dim
    m: int = 0            # fc out-dim
    c: int = 0            # conv in-channels
    p: int = 0            # conv out-channels
    r: int = 0            # conv kernel size
    k: int = 0            # circulant block size (0 = dense)
    activation: str = "relu"
    padding: str = "valid"
    out_dim: int = 0      # prior_pool target


@dataclass(frozen=True)
class ModelSpec:
    name: str
    dataset: str
    input_shape: tuple   # (H, W, C)
    specs: tuple         # tuple[LayerSpec, ...]
    batch: int = 64      # artifact batch size (paper: 50-100 interleaved)
    paper_accuracy: float = 0.0
    paper_kfps: float = 0.0
    paper_kfps_per_w: float = 0.0
    description: str = ""

    @property
    def num_classes(self) -> int:
        return 10


def _mlp(name, dataset, pooled, hidden, k_fc, paper):
    """Prior-pooled MLP: pool -> BC hidden layers -> small dense head."""
    sp = [LayerSpec("prior_pool", out_dim=pooled), LayerSpec("flatten")]
    n = pooled
    for h in hidden:
        sp.append(LayerSpec("bc_dense", n=n, m=h, k=k_fc))
        n = h
    sp.append(LayerSpec("dense", n=n, m=10, activation="none"))
    acc, kfps, eff = paper
    return ModelSpec(name, dataset, (28, 28, 1), tuple(sp), 64, acc, kfps, eff,
                     f"MLP {pooled}->{'->'.join(map(str, hidden))}->10, k={k_fc}")


def _registry():
    models = {}

    models["mnist_mlp_1"] = _mlp(
        "mnist_mlp_1", "mnist_s", 256, [256], 128, (92.9, 8.6e4, 1.57e5))
    models["mnist_mlp_2"] = _mlp(
        "mnist_mlp_2", "mnist_s", 128, [256, 256], 64, (95.6, 2.9e4, 5.2e4))

    # LeNet-5-like: 28x28x1 -> conv5(8) -> pool -> bc_conv5(16,k4) -> pool
    # -> fc 256->128 (k64) -> head
    models["mnist_lenet"] = ModelSpec(
        "mnist_lenet", "mnist_s", (28, 28, 1),
        (
            LayerSpec("conv", c=1, p=8, r=5),
            LayerSpec("avg_pool2"),
            LayerSpec("bc_conv", c=8, p=16, r=5, k=4),
            LayerSpec("avg_pool2"),
            LayerSpec("flatten"),
            LayerSpec("bc_dense", n=256, m=128, k=64),
            LayerSpec("dense", n=128, m=10, activation="none"),
        ),
        64, 99.0, 363.0, 659.5, "LeNet-5-like CNN, conv k=4 / fc k=64")

    # SVHN: 32x32x3 -> conv3(16) -> pool -> bc_conv3(32,k8) -> pool ->
    # bc_conv3(32,k8) -> pool -> fc 128->128(k64) -> head
    models["svhn_cnn"] = ModelSpec(
        "svhn_cnn", "svhn_s", (32, 32, 3),
        (
            LayerSpec("conv", c=3, p=16, r=3, padding="same"),
            LayerSpec("max_pool2"),
            LayerSpec("bc_conv", c=16, p=32, r=3, k=8, padding="same"),
            LayerSpec("max_pool2"),
            LayerSpec("bc_conv", c=32, p=32, r=3, k=8, padding="same"),
            LayerSpec("max_pool2"),
            LayerSpec("flatten"),
            LayerSpec("bc_dense", n=512, m=128, k=64),
            LayerSpec("dense", n=128, m=10, activation="none"),
        ),
        64, 96.2, 384.9, 699.7, "small CNN, conv k=8 / fc k=64")

    # CIFAR-10 simple CNN (the 80.3% row): same topology as svhn_cnn.
    models["cifar_cnn"] = ModelSpec(
        "cifar_cnn", "cifar_s", (32, 32, 3),
        (
            LayerSpec("conv", c=3, p=16, r=3, padding="same"),
            LayerSpec("max_pool2"),
            LayerSpec("bc_conv", c=16, p=32, r=3, k=8, padding="same"),
            LayerSpec("max_pool2"),
            LayerSpec("bc_conv", c=32, p=32, r=3, k=8, padding="same"),
            LayerSpec("max_pool2"),
            LayerSpec("flatten"),
            LayerSpec("bc_dense", n=512, m=128, k=64),
            LayerSpec("dense", n=128, m=10, activation="none"),
        ),
        64, 80.3, 1383.0, 2514.0, "small CNN, conv k=8 / fc k=64")

    # Wide-ResNet-lite (the 94.75% row): conv stem + two residual
    # block-circulant CONV blocks + BC fc.
    models["cifar_wrn"] = ModelSpec(
        "cifar_wrn", "cifar_s", (32, 32, 3),
        (
            LayerSpec("conv", c=3, p=32, r=3, padding="same"),
            LayerSpec("max_pool2"),
            LayerSpec("residual_begin"),
            LayerSpec("bc_conv", c=32, p=32, r=3, k=8, padding="same"),
            LayerSpec("bc_conv", c=32, p=32, r=3, k=8, padding="same", activation="none"),
            LayerSpec("residual_end"),
            LayerSpec("max_pool2"),
            LayerSpec("residual_begin"),
            LayerSpec("bc_conv", c=32, p=32, r=3, k=8, padding="same"),
            LayerSpec("bc_conv", c=32, p=32, r=3, k=8, padding="same", activation="none"),
            LayerSpec("residual_end"),
            LayerSpec("max_pool2"),
            LayerSpec("flatten"),
            LayerSpec("bc_dense", n=512, m=256, k=64),
            LayerSpec("dense", n=256, m=10, activation="none"),
        ),
        64, 94.75, 13.95, 25.4, "wide-ResNet-lite, residual BC conv blocks")

    return models


REGISTRY = _registry()
MODEL_NAMES = tuple(REGISTRY.keys())


# ---------------------------------------------------------------------------
# init / apply
# ---------------------------------------------------------------------------

def init_params(key, model: ModelSpec, *, dense_twin: bool = False):
    """Initialize the parameter list (one dict or None per LayerSpec)."""
    params = []
    for spec in model.specs:
        key, sub = jax.random.split(key)
        if spec.kind == "bc_dense":
            params.append(layers.init_dense(sub, spec.n, spec.m) if dense_twin
                          else layers.init_bc_dense(sub, spec.n, spec.m, spec.k))
        elif spec.kind == "dense":
            params.append(layers.init_dense(sub, spec.n, spec.m))
        elif spec.kind == "bc_conv":
            params.append(layers.init_conv(sub, spec.c, spec.p, spec.r) if dense_twin
                          else layers.init_bc_conv(sub, spec.c, spec.p, spec.r, spec.k))
        elif spec.kind == "conv":
            params.append(layers.init_conv(sub, spec.c, spec.p, spec.r))
        else:
            params.append(None)
    return params


def apply(params, x, model: ModelSpec, *, dense_twin: bool = False,
          backend: str = "jnp", quant_bits=None):
    """Forward pass.  ``x``: (batch, H, W, C) raw images; returns logits."""
    residual_stack = []
    for spec, p in zip(model.specs, params):
        if spec.kind == "bc_dense":
            if dense_twin:
                x = layers.dense_apply(p, x, activation=spec.activation,
                                       quant_bits=quant_bits)
            else:
                x = layers.bc_dense_apply(p, x, k=spec.k, activation=spec.activation,
                                          backend=backend, quant_bits=quant_bits)
        elif spec.kind == "dense":
            x = layers.dense_apply(p, x, activation=spec.activation,
                                   quant_bits=quant_bits)
        elif spec.kind == "bc_conv":
            if dense_twin:
                x = layers.conv_apply(p, x, activation=spec.activation,
                                      padding=spec.padding, quant_bits=quant_bits)
            else:
                x = layers.bc_conv_apply(p, x, r=spec.r, k=spec.k,
                                         activation=spec.activation,
                                         padding=spec.padding, quant_bits=quant_bits)
        elif spec.kind == "conv":
            x = layers.conv_apply(p, x, activation=spec.activation,
                                  padding=spec.padding, quant_bits=quant_bits)
        elif spec.kind == "avg_pool2":
            x = layers.avg_pool2(x)
        elif spec.kind == "max_pool2":
            x = layers.max_pool2(x)
        elif spec.kind == "flatten":
            x = x.reshape(x.shape[0], -1)
        elif spec.kind == "prior_pool":
            x = layers.prior_pool(x, spec.out_dim)
        elif spec.kind == "residual_begin":
            residual_stack.append(x)
        elif spec.kind == "residual_end":
            x = jnp.maximum(x + residual_stack.pop(), 0.0)
        else:
            raise ValueError(f"unknown layer kind {spec.kind!r}")
    return x


# ---------------------------------------------------------------------------
# accounting (shared with the manifest and the Rust model registry)
# ---------------------------------------------------------------------------

def _conv_out_hw(h, w, r, padding):
    if padding == "same":
        return h, w
    return h - r + 1, w - r + 1


def _fft_real_mults(k: int) -> int:
    """Real mults of one k-point real transform under the packed-rfft cost
    model (k/2-point complex FFT + untangle), kept in lockstep with the
    Rust side (``models::fft_real_mults`` / ``FftPlan::real_mults``)."""
    log2k = k.bit_length() - 1
    return k * max(0, log2k - 1) + 4 * (k // 2 + 1)


def accounting(model: ModelSpec):
    """Per-layer parameter / storage / op accounting.

    Returns a list of dicts with, per weight layer: dense params, circulant
    params, dense MACs and circulant real-mult count per image — the inputs
    to Fig. 3 (storage reduction) and the equivalent-GOPS normalization of
    Fig. 6.  Circulant op model (decoupled, half-spectrum):
      FC:   q rFFTs + p*q*kh complex mults + p IFFTs
      CONV: per output pixel, same with q' = (C/k) r^2.
    An n-point real transform takes the packed fast path (the Rust
    substrate's rfft_halfspec): an n/2-point complex FFT plus one complex
    twiddle multiply per half-spectrum bin — n*(log2(n)-1) + 4*(n/2+1)
    real mults (matches rust models::fft_real_mults / FftPlan::real_mults).
    """
    h, w, _ = model.input_shape
    rows = []
    for spec in model.specs:
        if spec.kind == "prior_pool":
            h, w = spec.out_dim, 1
        elif spec.kind in ("avg_pool2", "max_pool2"):
            h, w = h // 2, w // 2
        elif spec.kind in ("conv", "bc_conv"):
            oh, ow = _conv_out_hw(h, w, spec.r, spec.padding)
            dense_params = spec.r * spec.r * spec.c * spec.p
            dense_macs = oh * ow * dense_params
            if spec.kind == "bc_conv":
                k = spec.k
                kh = k // 2 + 1
                qb = (spec.c // k) * spec.r * spec.r
                pb = spec.p // k
                circ_params = pb * qb * k
                fft_mults = _fft_real_mults(k)
                circ_mults = oh * ow * (qb * fft_mults + pb * qb * kh * 4 + pb * fft_mults)
            else:
                circ_params, circ_mults = dense_params, dense_macs
            rows.append(dict(kind=spec.kind, shape=f"{spec.c}x{spec.p}x{spec.r}x{spec.r}",
                             k=spec.k, dense_params=dense_params, circ_params=circ_params,
                             dense_macs=dense_macs, circ_mults=circ_mults))
            h, w = oh, ow
        elif spec.kind in ("dense", "bc_dense"):
            dense_params = spec.n * spec.m
            dense_macs = dense_params
            if spec.kind == "bc_dense":
                k = spec.k
                kh = k // 2 + 1
                pb, qb = spec.m // k, spec.n // k
                circ_params = pb * qb * k
                fft_mults = _fft_real_mults(k)
                circ_mults = qb * fft_mults + pb * qb * kh * 4 + pb * fft_mults
            else:
                circ_params, circ_mults = dense_params, dense_macs
            rows.append(dict(kind=spec.kind, shape=f"{spec.n}x{spec.m}", k=spec.k,
                             dense_params=dense_params, circ_params=circ_params,
                             dense_macs=dense_macs, circ_mults=circ_mults))
    return rows


def storage_report(model: ModelSpec, *, bits: int = 12, dense_bits: int = 32):
    """Fig.-3-style storage reduction: dense f32 model vs circulant
    ``bits``-bit model (parameter reduction x quantization)."""
    acc = accounting(model)
    dense_bytes = sum(r["dense_params"] for r in acc) * dense_bits // 8
    circ_bytes = sum(r["circ_params"] for r in acc) * bits // 8
    return dict(dense_bytes=dense_bytes, circ_bytes=circ_bytes,
                reduction=dense_bytes / max(1, circ_bytes))


def equivalent_ops_per_image(model: ModelSpec) -> int:
    """Dense-equivalent (mult+add) op count per image — the paper's
    'equivalent GOPS' normalization basis."""
    return 2 * sum(r["dense_macs"] for r in accounting(model))
