"""Deterministic synthetic datasets standing in for MNIST / SVHN / CIFAR-10.

No network access is available in this environment, so per DESIGN.md §2 the
real datasets are substituted by synthetic class-conditional image
generators with matched shapes (28x28x1 and 32x32x3, 10 classes).  The
generator is built on the splitmix64 PRNG with *closed-form per-element
states*, so ``rust/src/data`` reproduces every float bit-for-bit: the same
u64 arithmetic, the same top-24-bit-to-f32 mapping, the same element order.
Integration tests compare checksums across the language boundary.

Task structure: each class has ``MODES`` prototype templates (coarse grids
upsampled nearest-neighbor), and each sample is ``clip(contrast * template
+ brightness + noise)``.  Multi-modal prototypes + jitter make accuracy
capacity-dependent, which is what the paper's block-size/accuracy trade-off
(Fig. 5 co-optimization loop) needs; absolute accuracies are reported next
to the paper's real-dataset numbers in EXPERIMENTS.md, never in place of
them.
"""

from __future__ import annotations

import numpy as np

GAMMA = np.uint64(0x9E3779B97F4A7C15)
MODES = 10
NOISE_AMP = np.float32(1.0)
TEST_INDEX_OFFSET = 1 << 20

DATASETS = {
    # name: (H, W, C, coarse_grid, upsample_factor)
    "mnist_s": (28, 28, 1, 7, 4),
    "svhn_s": (32, 32, 3, 8, 4),
    "cifar_s": (32, 32, 3, 8, 4),
}
NUM_CLASSES = 10

_DS_SEED = {"mnist_s": np.uint64(101), "svhn_s": np.uint64(202), "cifar_s": np.uint64(303)}


def mix(z):
    """splitmix64 finalizer (vectorized over uint64 arrays)."""
    z = np.uint64(z) if np.isscalar(z) else z.astype(np.uint64)
    with np.errstate(over="ignore"):
        z = (z ^ (z >> np.uint64(30))) * np.uint64(0xBF58476D1CE4E5B9)
        z = (z ^ (z >> np.uint64(27))) * np.uint64(0x94D049BB133111EB)
        return z ^ (z >> np.uint64(31))


def combine(*vals) -> np.uint64:
    """Hash a tuple of small integers into a stream seed (order-sensitive)."""
    h = np.uint64(0x243F6A8885A308D3)
    with np.errstate(over="ignore"):
        for v in vals:
            h = mix(h ^ (np.uint64(v) + GAMMA))
    return h


def u01_stream(seed: np.uint64, n: int) -> np.ndarray:
    """``n`` uniform f32 values in [0,1): closed-form splitmix64 stream.

    Element ``i`` depends only on ``seed`` and ``i`` (state = seed +
    (i+1)*GAMMA), so Rust can generate any slice independently and the two
    implementations agree bit-for-bit (24-bit mantissa path is exact).
    """
    with np.errstate(over="ignore"):
        states = np.uint64(seed) + GAMMA * np.arange(1, n + 1, dtype=np.uint64)
    z = mix(states)
    return ((z >> np.uint64(40)).astype(np.float32)) / np.float32(16777216.0)


def class_template(name: str, cls: int, mode: int) -> np.ndarray:
    """Prototype image for (class, mode): coarse grid, nearest-upsampled."""
    h, w, c, grid, factor = DATASETS[name]
    seed = combine(_DS_SEED[name], 1, cls, mode)
    coarse = u01_stream(seed, grid * grid * c).reshape(grid, grid, c)
    up = np.repeat(np.repeat(coarse, factor, axis=0), factor, axis=1)
    return up[:h, :w, :].astype(np.float32)


def sample(name: str, index: int) -> tuple[np.ndarray, int]:
    """Deterministic sample ``index`` of dataset ``name``: (image, label)."""
    h, w, c, _, _ = DATASETS[name]
    cls = index % NUM_CLASSES
    mode = (index // NUM_CLASSES) % MODES
    template = class_template(name, cls, mode)
    seed = combine(_DS_SEED[name], 2, cls, index)
    vals = u01_stream(seed, 2 + h * w * c)
    contrast = np.float32(0.7) + np.float32(0.6) * vals[0]
    brightness = np.float32(-0.15) + np.float32(0.3) * vals[1]
    noise = (vals[2:].reshape(h, w, c) - np.float32(0.5)) * NOISE_AMP
    img = np.clip(template * contrast + brightness + noise, 0.0, 1.0).astype(np.float32)
    return img, cls


def batch(name: str, start: int, count: int, *, test: bool = False):
    """Generate ``count`` consecutive samples starting at ``start``.

    Test-split indices live at ``TEST_INDEX_OFFSET`` so the splits are
    disjoint by construction.
    """
    base = start + (TEST_INDEX_OFFSET if test else 0)
    h, w, c, _, _ = DATASETS[name]
    xs = np.empty((count, h, w, c), dtype=np.float32)
    ys = np.empty((count,), dtype=np.int32)
    for i in range(count):
        xs[i], ys[i] = sample(name, base + i)
    return xs, ys


def checksum(name: str, count: int = 16) -> int:
    """Order-sensitive u64 checksum over the f32 bit patterns of the first
    ``count`` training images — compared against the Rust mirror in
    integration tests."""
    xs, ys = batch(name, 0, count)
    bits = xs.reshape(-1).view(np.uint32).astype(np.uint64)
    h = np.uint64(0)
    with np.errstate(over="ignore"):
        for b in bits:
            h = mix(h ^ (b + GAMMA))
        for y in ys:
            h = mix(h ^ (np.uint64(int(y)) + GAMMA))
    return int(h)
