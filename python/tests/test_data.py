"""Synthetic dataset generator: determinism, shape, split, PRNG contract.

The PRNG contract (splitmix64, closed-form per-element states, top-24-bit
f32 mapping) is what the Rust mirror reproduces bit-for-bit; these tests pin
it down so a refactor on either side trips an alarm.
"""

import numpy as np
import pytest

from compile import data


def test_known_splitmix64_vector():
    # Reference values for seed 1234567: classic splitmix64 outputs.
    s = np.uint64(1234567)
    with np.errstate(over="ignore"):
        z1 = data.mix(s + data.GAMMA)
    # recompute by hand with python ints to cross-check the numpy path
    def pymix(z):
        z &= (1 << 64) - 1
        z = (z ^ (z >> 30)) * 0xBF58476D1CE4E5B9 & ((1 << 64) - 1)
        z = (z ^ (z >> 27)) * 0x94D049BB133111EB & ((1 << 64) - 1)
        return (z ^ (z >> 31)) & ((1 << 64) - 1)
    assert int(z1) == pymix((1234567 + 0x9E3779B97F4A7C15) & ((1 << 64) - 1))


def test_u01_stream_range_and_determinism():
    v1 = data.u01_stream(np.uint64(42), 1000)
    v2 = data.u01_stream(np.uint64(42), 1000)
    np.testing.assert_array_equal(v1, v2)
    assert v1.dtype == np.float32
    assert float(v1.min()) >= 0.0 and float(v1.max()) < 1.0
    # mean of U[0,1) over 1000 samples
    assert abs(float(v1.mean()) - 0.5) < 0.05


def test_u01_stream_prefix_consistency():
    # closed-form states: a prefix of a longer stream equals the short stream
    a = data.u01_stream(np.uint64(7), 10)
    b = data.u01_stream(np.uint64(7), 100)[:10]
    np.testing.assert_array_equal(a, b)


def test_sample_deterministic_and_shaped():
    for name, (h, w, c, _, _) in data.DATASETS.items():
        img1, y1 = data.sample(name, 12345)
        img2, y2 = data.sample(name, 12345)
        np.testing.assert_array_equal(img1, img2)
        assert img1.shape == (h, w, c) and img1.dtype == np.float32
        assert y1 == y2 == 12345 % 10
        assert img1.min() >= 0.0 and img1.max() <= 1.0


def test_train_test_split_disjoint():
    xtr, _ = data.batch("mnist_s", 0, 4)
    xte, _ = data.batch("mnist_s", 0, 4, test=True)
    assert not np.array_equal(xtr, xte)


def test_labels_balanced():
    _, ys = data.batch("mnist_s", 0, 100)
    counts = np.bincount(ys, minlength=10)
    np.testing.assert_array_equal(counts, np.full(10, 10))


def test_class_templates_differ_between_classes_and_modes():
    t00 = data.class_template("mnist_s", 0, 0)
    t10 = data.class_template("mnist_s", 1, 0)
    t01 = data.class_template("mnist_s", 0, 1)
    assert not np.array_equal(t00, t10)
    assert not np.array_equal(t00, t01)


def test_checksum_stable():
    # Regression pin: if this changes, the Rust mirror must change too.
    c1 = data.checksum("mnist_s", count=4)
    c2 = data.checksum("mnist_s", count=4)
    assert c1 == c2
    assert isinstance(c1, int) and c1 > 0


def test_dataset_checksums_differ():
    sums = {name: data.checksum(name, count=2) for name in data.DATASETS}
    assert len(set(sums.values())) == len(sums)
