"""Training: gradient structure (Eqns. 2-3), convergence, VI, sweep shape."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import layers, model as M, train as T
from compile.kernels import ref


def test_gradient_matches_explicit_matrix():
    # Paper Eqns. (2)/(3): training learns the defining vectors directly;
    # autodiff through the FFT forward must equal the gradient obtained by
    # differentiating through the explicit block-circulant matrix.
    n, m, k = 8, 8, 4
    rng = np.random.default_rng(0)
    w = jnp.asarray(rng.normal(size=(m // k, n // k, k)).astype(np.float32))
    x = jnp.asarray(rng.normal(size=(3, n)).astype(np.float32))
    tgt = jnp.asarray(rng.normal(size=(3, m)).astype(np.float32))

    def loss_fft(w):
        params = {"w": w, "b": jnp.zeros((m,))}
        y = layers.bc_dense_apply(params, x, k=k, activation="none")
        return jnp.sum((y - tgt) ** 2)

    def loss_explicit(w):
        y = ref.block_circulant_matmul(w, x)
        return jnp.sum((y - tgt) ** 2)

    g_fft = jax.grad(loss_fft)(w)
    g_exp = jax.grad(loss_explicit)(w)
    np.testing.assert_allclose(g_fft, g_exp, rtol=1e-3, atol=1e-3)


def test_training_reduces_loss():
    spec = M.REGISTRY["mnist_mlp_1"]
    _, losses = T.train(spec, steps=150, train_size=512)
    assert losses[-1] < losses[0] * 0.5


def test_training_reaches_usable_accuracy():
    spec = M.REGISTRY["mnist_mlp_1"]
    params, _ = T.train(spec, steps=300)
    acc = T.evaluate(params, spec, test_size=512)
    assert acc > 0.8


def test_quant_aware_training_close_to_f32():
    spec = M.REGISTRY["mnist_mlp_1"]
    p32, _ = T.train(spec, steps=200, seed=1)
    p12, _ = T.train(spec, steps=200, seed=1, quant_bits=12)
    a32 = T.evaluate(p32, spec, test_size=512)
    a12 = T.evaluate(p12, spec, test_size=512, quant_bits=12)
    # paper: 12-bit costs ~1-2% accuracy at most
    assert a12 > a32 - 0.05


def test_adam_step_moves_params():
    spec = M.REGISTRY["mnist_mlp_1"]
    params = M.init_params(jax.random.PRNGKey(0), spec)
    opt = T.adam_init(params)
    step = T.make_train_step(spec)
    from compile import data
    xs, ys = data.batch(spec.dataset, 0, 64)
    new_params, _, loss = step(params, opt, jnp.asarray(xs), jnp.asarray(ys))
    assert float(loss) > 0
    before = params[2]["w"]
    after = new_params[2]["w"]
    assert not np.allclose(np.asarray(before), np.asarray(after))


def test_bayes_vi_trains_and_infers_with_mean():
    spec = M.REGISTRY["mnist_mlp_1"]
    mean_params, losses = T.train_bayes(spec, steps=150, train_size=256)
    assert losses[-1] < losses[0]
    acc = T.evaluate(mean_params, spec, test_size=256)
    assert acc > 0.3  # small-data regime; must beat chance comfortably


def test_bayes_comparable_to_point_on_small_data():
    # Paper: "Bayesian training is the most effective for small data
    # training and small-to-medium neural networks."  On our synthetic task
    # VI lands within a few points of point training (measured ~0.83 vs
    # ~0.86 at 256 samples; honest result recorded in EXPERIMENTS.md §S3) —
    # we assert comparability, not superiority.
    spec = M.REGISTRY["mnist_mlp_1"]
    small = 256
    point, _ = T.train(spec, steps=300, train_size=small, seed=2)
    acc_point = T.evaluate(point, spec, test_size=512)
    bayes, _ = T.train_bayes(spec, steps=300, train_size=small, seed=2)
    acc_bayes = T.evaluate(bayes, spec, test_size=512)
    assert acc_bayes >= acc_point - 0.06


def test_vi_kl_positive_and_decreasing_in_sigma_match():
    spec = M.REGISTRY["mnist_mlp_1"]
    params = M.init_params(jax.random.PRNGKey(0), spec)
    v = T.vi_init(params, rho0=-5.0)
    kl = float(T.vi_kl(v, prior_sigma=0.1))
    assert kl > 0
