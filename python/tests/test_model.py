"""Model registry: shapes, twins, accounting invariants (Fig. 3 inputs)."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import data, model as M


@pytest.mark.parametrize("name", M.MODEL_NAMES)
def test_forward_shapes(name):
    spec = M.REGISTRY[name]
    params = M.init_params(jax.random.PRNGKey(0), spec)
    x = jnp.asarray(data.batch(spec.dataset, 0, 2)[0])
    y = M.apply(params, x, spec)
    assert y.shape == (2, 10)
    assert bool(jnp.all(jnp.isfinite(y)))


@pytest.mark.parametrize("name", M.MODEL_NAMES)
def test_dense_twin_shapes(name):
    spec = M.REGISTRY[name]
    params = M.init_params(jax.random.PRNGKey(0), spec, dense_twin=True)
    x = jnp.asarray(data.batch(spec.dataset, 0, 2)[0])
    y = M.apply(params, x, spec, dense_twin=True)
    assert y.shape == (2, 10)


@pytest.mark.parametrize("name", M.MODEL_NAMES)
def test_quantized_forward_close_to_f32(name):
    spec = M.REGISTRY[name]
    params = M.init_params(jax.random.PRNGKey(0), spec)
    x = jnp.asarray(data.batch(spec.dataset, 0, 2)[0])
    y32 = M.apply(params, x, spec)
    y12 = M.apply(params, x, spec, quant_bits=12)
    # 12-bit fixed point: small relative error on logits
    assert float(jnp.max(jnp.abs(y32 - y12))) < 0.15 * float(jnp.max(jnp.abs(y32)) + 1.0)


@pytest.mark.parametrize("name", M.MODEL_NAMES)
def test_storage_reduction_positive(name):
    rep = M.storage_report(M.REGISTRY[name])
    # Fig. 3: significant model size compression on every benchmark —
    # parameter reduction x (32/12) quantization.
    assert rep["reduction"] > 10.0
    assert rep["circ_bytes"] < rep["dense_bytes"]


@pytest.mark.parametrize("name", M.MODEL_NAMES)
def test_circulant_params_match_storage_formula(name):
    # per-layer circ params == dense params / k for every compressed layer
    for row in M.accounting(M.REGISTRY[name]):
        if row["kind"] in ("bc_dense", "bc_conv"):
            assert row["circ_params"] == row["dense_params"] // row["k"]
        else:
            assert row["circ_params"] == row["dense_params"]


@pytest.mark.parametrize("name", M.MODEL_NAMES)
def test_complexity_reduction(name):
    # O(n log n) vs O(n^2): circulant mults strictly below dense MACs for
    # every compressed layer of every registry model.
    for row in M.accounting(M.REGISTRY[name]):
        if row["kind"] in ("bc_dense", "bc_conv") and row["k"] >= 8:
            assert row["circ_mults"] < row["dense_macs"], row


def test_equivalent_ops_match_paper_scale():
    # Sanity: MLP models are ~0.1-0.3 MOP/image, CNNs are MOP-scale.
    ops = {n: M.equivalent_ops_per_image(M.REGISTRY[n]) for n in M.MODEL_NAMES}
    assert 5e4 < ops["mnist_mlp_1"] < 1e6
    assert ops["cifar_wrn"] > ops["mnist_mlp_1"]


def test_whole_model_fits_on_chip():
    # The paper's headline design point: every Table-1 model (12-bit,
    # circulant) fits in the CyClone V's ~2 MB of on-chip block memory.
    for name in M.MODEL_NAMES:
        rep = M.storage_report(M.REGISTRY[name])
        assert rep["circ_bytes"] < 2 * 1024 * 1024, (name, rep)


def test_registry_matches_table1_rows():
    # Paper metadata baked into the registry (used by the Rust Table-1 bench).
    assert M.REGISTRY["mnist_mlp_1"].paper_accuracy == 92.9
    assert M.REGISTRY["cifar_wrn"].paper_accuracy == 94.75
    assert M.REGISTRY["svhn_cnn"].paper_kfps == 384.9
    assert len(M.MODEL_NAMES) == 6


def test_residual_model_runs_and_differs_from_plain():
    spec = M.REGISTRY["cifar_wrn"]
    kinds = [s.kind for s in spec.specs]
    assert kinds.count("residual_begin") == kinds.count("residual_end") == 2
