"""Pallas FFT/IFFT kernels vs the fft_core / naive-DFT oracles.

Hypothesis sweeps shapes (row counts that do and don't divide the tile,
all power-of-two k in the paper's range) — per DESIGN.md these kernels are
the software twin of the FPGA's single pipelined FFT unit.
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import fft_core, fft_kernel, ref


def _randn(rng, *shape):
    return jnp.asarray(rng.normal(size=shape).astype(np.float32))


@settings(max_examples=20, deadline=None)
@given(
    logk=st.integers(min_value=1, max_value=7),
    rows=st.integers(min_value=1, max_value=20),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_fft_pallas_matches_oracle(logk, rows, seed):
    k = 1 << logk
    rng = np.random.default_rng(seed)
    xr, xi = _randn(rng, rows, k), _randn(rng, rows, k)
    yr, yi = fft_kernel.fft_pallas(xr, xi)
    rr, ri = ref.naive_dft(xr, xi)
    np.testing.assert_allclose(yr, rr, rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(yi, ri, rtol=1e-3, atol=1e-3)


@settings(max_examples=20, deadline=None)
@given(
    logk=st.integers(min_value=1, max_value=7),
    rows=st.integers(min_value=1, max_value=20),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_rfft_irfft_pallas_roundtrip(logk, rows, seed):
    k = 1 << logk
    rng = np.random.default_rng(seed)
    x = _randn(rng, rows, k)
    hr, hi = fft_kernel.rfft_pallas(x)
    assert hr.shape == (rows, k // 2 + 1)
    back = fft_kernel.irfft_pallas(hr, hi, k)
    np.testing.assert_allclose(back, x, rtol=1e-3, atol=1e-3)


@pytest.mark.parametrize("k", [4, 32, 128])
def test_ifft_pallas_matches_core(k):
    rng = np.random.default_rng(k)
    xr, xi = _randn(rng, 6, k), _randn(rng, 6, k)
    yr, yi = fft_kernel.fft_pallas(xr, xi, inverse=True)
    cr, ci = fft_core.ifft(xr, xi)
    np.testing.assert_allclose(yr, cr, rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(yi, ci, rtol=1e-3, atol=1e-3)


def test_rfft_pallas_matches_jnp():
    rng = np.random.default_rng(1)
    x = _randn(rng, 4, 64)
    hr, hi = fft_kernel.rfft_pallas(x)
    expected = jnp.fft.rfft(x, axis=-1)
    np.testing.assert_allclose(hr, expected.real, rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(hi, expected.imag, rtol=1e-3, atol=1e-3)


def test_large_row_count_tiled():
    # More rows than the default tile: exercises the 1-D grid.
    rng = np.random.default_rng(2)
    x = _randn(rng, 3 * fft_kernel.DEFAULT_ROW_TILE, 16)
    hr, hi = fft_kernel.rfft_pallas(x)
    back = fft_kernel.irfft_pallas(hr, hi, 16)
    np.testing.assert_allclose(back, x, rtol=1e-3, atol=1e-3)


def test_irfft_width_mismatch_raises():
    with pytest.raises(ValueError):
        fft_kernel.irfft_pallas(jnp.zeros((2, 5)), jnp.zeros((2, 5)), 32)
