"""Fused block-circulant layer kernel vs the explicit-matrix oracle."""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import circulant_layer, fft_core, ref


def _randn(rng, *shape):
    return jnp.asarray(rng.normal(size=shape).astype(np.float32))


@settings(max_examples=20, deadline=None)
@given(
    p=st.integers(min_value=1, max_value=4),
    q=st.integers(min_value=1, max_value=4),
    logk=st.integers(min_value=1, max_value=6),
    batch=st.integers(min_value=1, max_value=8),
    relu=st.booleans(),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_fused_layer_matches_oracle(p, q, logk, batch, relu, seed):
    k = 1 << logk
    rng = np.random.default_rng(seed)
    wb = _randn(rng, p, q, k)
    xs = _randn(rng, batch, q * k)
    bias = _randn(rng, p * k)
    wfr, wfi = fft_core.rfft_halfspec(wb)
    y = circulant_layer.circulant_layer_pallas(xs, wfr, wfi, bias, k=k, relu=relu)
    expected = ref.circulant_layer_ref(wb, bias, xs, activation="relu" if relu else "none")
    np.testing.assert_allclose(y, expected, rtol=2e-3, atol=2e-3)


def test_relu_clamps_negative():
    k = 4
    wb = jnp.zeros((1, 1, k))
    wfr, wfi = fft_core.rfft_halfspec(wb)
    bias = jnp.asarray([-1.0, -2.0, 3.0, 0.0], dtype=jnp.float32)
    y = circulant_layer.circulant_layer_pallas(
        jnp.ones((2, k)), wfr, wfi, bias, k=k, relu=True
    )
    np.testing.assert_allclose(y, jnp.broadcast_to(jnp.maximum(bias, 0.0), (2, k)))


def test_input_width_mismatch_raises():
    wfr = jnp.zeros((1, 2, 3))
    with pytest.raises(ValueError):
        circulant_layer.circulant_layer_pallas(
            jnp.zeros((1, 5)), wfr, wfr, jnp.zeros((4,)), k=4
        )


def test_vmem_footprint_within_budget_for_paper_configs():
    # DESIGN.md §9: per-grid-step working set <= 2 MiB for every Table-1
    # FC configuration (k up to 128/256, q up to 32).
    for (n, m, k) in [(256, 256, 128), (1024, 1024, 128), (512, 256, 64), (4096, 1024, 256)]:
        p, q = m // k, n // k
        fp = circulant_layer.vmem_footprint_bytes(
            circulant_layer.DEFAULT_BATCH_TILE, n, m, p, q, k
        )
        assert fp <= 2 * 1024 * 1024, (n, m, k, fp)


def test_batch_tile_divides_batch():
    for batch in range(1, 40):
        t = circulant_layer._batch_tile(batch)
        assert batch % t == 0 and 1 <= t <= circulant_layer.DEFAULT_BATCH_TILE
