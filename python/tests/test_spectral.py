"""Pallas spectral multiply-accumulate kernel (phase 2) vs the einsum oracle."""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import fft_core, ref, spectral


def _randn(rng, *shape):
    return jnp.asarray(rng.normal(size=shape).astype(np.float32))


@settings(max_examples=20, deadline=None)
@given(
    p=st.integers(min_value=1, max_value=6),
    q=st.integers(min_value=1, max_value=6),
    logk=st.integers(min_value=1, max_value=6),
    batch=st.integers(min_value=1, max_value=10),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_spectral_matmul_matches_ref(p, q, logk, batch, seed):
    k = 1 << logk
    kh = k // 2 + 1
    rng = np.random.default_rng(seed)
    wfr, wfi = _randn(rng, p, q, kh), _randn(rng, p, q, kh)
    xfr, xfi = _randn(rng, batch, q, kh), _randn(rng, batch, q, kh)
    yr, yi = spectral.spectral_matmul_pallas(wfr, wfi, xfr, xfi)
    rr, ri = ref.spectral_matmul_ref(wfr, wfi, xfr, xfi)
    np.testing.assert_allclose(yr, rr, rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(yi, ri, rtol=1e-3, atol=1e-3)


def test_spectral_pipeline_equals_block_circulant_matvec():
    # End-to-end phase-1/2/3 composition equals the explicit-matrix oracle.
    p, q, k, batch = 2, 3, 16, 5
    rng = np.random.default_rng(0)
    wb = _randn(rng, p, q, k)
    xs = _randn(rng, batch, q * k)
    wfr, wfi = fft_core.rfft_halfspec(wb)
    xfr, xfi = fft_core.rfft_halfspec(xs.reshape(batch, q, k))
    yr, yi = spectral.spectral_matmul_pallas(wfr, wfi, xfr, xfi)
    y = fft_core.irfft_halfspec(yr, yi, k).reshape(batch, p * k)
    expected = ref.block_circulant_matmul(wb, xs)
    np.testing.assert_allclose(y, expected, rtol=1e-3, atol=1e-3)


def test_spectral_zero_weights_give_zero():
    yr, yi = spectral.spectral_matmul_pallas(
        jnp.zeros((2, 2, 5)), jnp.zeros((2, 2, 5)),
        jnp.ones((3, 2, 5)), jnp.ones((3, 2, 5)),
    )
    assert float(jnp.abs(yr).max()) == 0.0
    assert float(jnp.abs(yi).max()) == 0.0


def test_spectral_identity_weight_passthrough():
    # W = identity circulant (delta defining vector) => flat spectrum of ones
    # => output spectra equal summed input spectra.
    k, kh = 8, 5
    wfr = jnp.ones((1, 1, kh))
    wfi = jnp.zeros((1, 1, kh))
    rng = np.random.default_rng(4)
    xfr, xfi = _randn(rng, 2, 1, kh), _randn(rng, 2, 1, kh)
    yr, yi = spectral.spectral_matmul_pallas(wfr, wfi, xfr, xfi)
    np.testing.assert_allclose(yr[:, 0], xfr[:, 0], rtol=1e-5)
    np.testing.assert_allclose(yi[:, 0], xfi[:, 0], rtol=1e-5)
