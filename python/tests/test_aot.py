"""AOT export helpers + (when present) manifest schema validation."""

import json
import os
import pathlib

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import aot, model as M

ART_DIR = os.path.join(os.path.dirname(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__)))), "artifacts")


def test_to_hlo_text_emits_parseable_module():
    def fn(x):
        return (jnp.fft.irfft(jnp.fft.rfft(x, axis=-1) * 2.0, n=8, axis=-1),)
    spec = jax.ShapeDtypeStruct((2, 8), jnp.float32)
    text = aot.to_hlo_text(jax.jit(fn).lower(spec))
    assert text.startswith("HloModule")
    assert "fft" in text  # rfft lowers to the HLO fft op the runtime executes
    assert "ENTRY" in text


def test_flatten_unflatten_roundtrip():
    model = M.REGISTRY["mnist_mlp_1"]
    params = M.init_params(jax.random.PRNGKey(3), model)
    flat = aot._flatten_params(params)
    names = [n for n, _ in flat]
    assert names == sorted(names)  # stable order
    rebuilt = aot._unflatten_params(model, [v for _, v in flat])
    for p, r in zip(params, rebuilt):
        if p is None:
            assert r is None
        else:
            for k in p:
                np.testing.assert_array_equal(p[k], r[k])


def test_save_load_params_roundtrip(tmp_path):
    model = M.REGISTRY["mnist_mlp_1"]
    params = M.init_params(jax.random.PRNGKey(4), model)
    path = str(tmp_path / "p" / "m.npz")
    aot.save_params(path, params)
    loaded = aot.load_params(path, model)
    for p, l in zip(params, loaded):
        if p is not None:
            for k in p:
                np.testing.assert_array_equal(p[k], l[k])


needs_manifest = pytest.mark.skipif(
    not os.path.exists(os.path.join(ART_DIR, "manifest.json")),
    reason="artifacts not built (run `make artifacts`)")


@needs_manifest
def test_manifest_schema():
    with open(os.path.join(ART_DIR, "manifest.json")) as f:
        man = json.load(f)
    assert man["quant_bits"] == 12
    assert set(man["datasets"]) == {"mnist_s", "svhn_s", "cifar_s"}
    names = [m["name"] for m in man["models"]]
    assert names == list(M.MODEL_NAMES)
    for m in man["models"]:
        assert 0.5 < m["accuracy"]["circulant_12bit"] <= 1.0
        assert m["storage"]["reduction"] > 10
        for art in m["artifacts"]:
            assert os.path.exists(os.path.join(ART_DIR, art["file"]))


@needs_manifest
def test_manifest_accuracy_degradation_within_paper_band():
    # Paper: accuracy degradation constrained to ~1-2% (we allow a wider
    # band on the synthetic task, and record actuals in EXPERIMENTS.md).
    with open(os.path.join(ART_DIR, "manifest.json")) as f:
        man = json.load(f)
    for m in man["models"]:
        acc = m["accuracy"]
        assert acc["dense_f32"] - acc["circulant_12bit"] < 0.08, m["name"]
        # 12-bit quantization itself costs almost nothing
        assert acc["circulant_f32"] - acc["circulant_12bit"] < 0.02, m["name"]


@needs_manifest
def test_training_artifacts_exported():
    with open(os.path.join(ART_DIR, "manifest.json")) as f:
        man = json.load(f)
    entry = next(m for m in man["models"] if m["name"] == "mnist_mlp_1")
    tr = entry["training"]
    assert os.path.exists(os.path.join(ART_DIR, tr["init_file"]))
    assert os.path.exists(os.path.join(ART_DIR, tr["step_file"]))
    assert len(tr["param_names"]) == len(tr["param_shapes"])
    assert entry["artifacts_pallas"], "pallas-backed artifact missing"


def test_hlo_text_includes_large_constants():
    # Regression pin: without print_large_constants=True the HLO text elides
    # big literals as "{...}", which the Rust-side parser silently reads as
    # zeros — turning baked-weight models into zero functions.
    big = jnp.asarray(np.arange(4096, dtype=np.float32).reshape(64, 64))

    def fn(x):
        return (x @ big,)

    text = aot.to_hlo_text(jax.jit(fn).lower(jax.ShapeDtypeStruct((2, 64), jnp.float32)))
    assert "{...}" not in text
    assert "4095" in text  # the last constant value is actually present


def test_artifact_fft_ops_bounded_by_decoupling():
    """The lowered HLO must contain at most the decoupled FFT-op census:
    <= 2 RFFT ops per block-circulant layer (weight spectra + input blocks;
    both batched over p/q) and <= 1 IRFFT per layer — and never the p*q
    explosion the naive Eqn.-1 evaluation would emit.  This is the L2
    structural performance target of DESIGN.md §9 (XLA may CSE same-shape
    transforms below these bounds)."""
    import re
    art_dir = pathlib.Path(__file__).resolve().parents[2] / "artifacts"
    if not (art_dir / "manifest.json").exists():
        pytest.skip("artifacts not built")
    spec_registry = M.REGISTRY

    for name, spec in spec_registry.items():
        path = art_dir / f"{name}_b64.hlo.txt"
        if not path.exists():
            continue
        text = path.read_text()
        kinds = re.findall(r"fft_type=([A-Z]+)", text)
        n_bc = sum(1 for s in spec.specs if s.kind in ("bc_dense", "bc_conv"))
        pq_total = sum(
            (s.m // s.k) * (s.n // s.k) if s.kind == "bc_dense"
            else (s.p // s.k) * ((s.c // s.k) * s.r * s.r)
            for s in spec.specs if s.kind in ("bc_dense", "bc_conv")
        )
        rffts = kinds.count("RFFT")
        irffts = kinds.count("IRFFT")
        assert 1 <= rffts <= 2 * n_bc, f"{name}: {rffts} RFFT ops vs {n_bc} BC layers"
        assert 1 <= irffts <= n_bc, f"{name}: {irffts} IRFFT ops"
        # the decoupling claim: op census nowhere near the p*q explosion
        assert rffts + irffts < pq_total + n_bc, (
            f"{name}: FFT census {rffts + irffts} looks like the naive p*q schedule"
        )
