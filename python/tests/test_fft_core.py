"""fft_core vs the O(k^2) DFT oracle + structural FFT identities.

This is the base of the correctness pyramid: every other component (Pallas
kernels, circulant layers, HLO artifacts, the Rust substrate) is validated
directly or transitively against these identities.
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import fft_core, ref

POW2 = [2, 4, 8, 16, 32, 64, 128, 256]


def _randn(rng, *shape):
    return jnp.asarray(rng.normal(size=shape).astype(np.float32))


@pytest.mark.parametrize("k", POW2)
def test_fft_matches_naive_dft(k):
    rng = np.random.default_rng(k)
    xr, xi = _randn(rng, 3, k), _randn(rng, 3, k)
    yr, yi = fft_core.fft(xr, xi)
    rr, ri = ref.naive_dft(xr, xi)
    np.testing.assert_allclose(yr, rr, rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(yi, ri, rtol=1e-3, atol=1e-3)


@pytest.mark.parametrize("k", POW2)
def test_ifft_matches_naive_inverse_dft(k):
    rng = np.random.default_rng(k + 1)
    xr, xi = _randn(rng, 2, k), _randn(rng, 2, k)
    yr, yi = fft_core.ifft(xr, xi)
    rr, ri = ref.naive_dft(xr, xi, inverse=True)
    np.testing.assert_allclose(yr, rr / k, rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(yi, ri / k, rtol=1e-3, atol=1e-3)


@settings(max_examples=25, deadline=None)
@given(
    logk=st.integers(min_value=1, max_value=8),
    rows=st.integers(min_value=1, max_value=9),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_fft_ifft_roundtrip(logk, rows, seed):
    k = 1 << logk
    rng = np.random.default_rng(seed)
    xr, xi = _randn(rng, rows, k), _randn(rng, rows, k)
    yr, yi = fft_core.fft(xr, xi)
    br, bi = fft_core.ifft(yr, yi)
    np.testing.assert_allclose(br, xr, rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(bi, xi, rtol=1e-3, atol=1e-3)


@settings(max_examples=25, deadline=None)
@given(
    logk=st.integers(min_value=1, max_value=8),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_rfft_halfspec_roundtrip(logk, seed):
    k = 1 << logk
    rng = np.random.default_rng(seed)
    x = _randn(rng, 4, k)
    hr, hi = fft_core.rfft_halfspec(x)
    assert hr.shape == (4, k // 2 + 1)
    back = fft_core.irfft_halfspec(hr, hi, k)
    np.testing.assert_allclose(back, x, rtol=1e-3, atol=1e-3)


def test_rfft_matches_jnp_rfft():
    # Cross-check against jax's own FFT (the one L2 lowers into HLO).
    rng = np.random.default_rng(7)
    x = _randn(rng, 5, 64)
    hr, hi = fft_core.rfft_halfspec(x)
    expected = jnp.fft.rfft(x, axis=-1)
    np.testing.assert_allclose(hr, expected.real, rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(hi, expected.imag, rtol=1e-3, atol=1e-3)


def test_fft_linearity():
    rng = np.random.default_rng(3)
    a, b = _randn(rng, 2, 32), _randn(rng, 2, 32)
    z = jnp.zeros_like(a)
    ya, _ = fft_core.fft(a, z)
    yb, _ = fft_core.fft(b, z)
    ysum, _ = fft_core.fft(a + 2.0 * b, z)
    np.testing.assert_allclose(ysum, ya + 2.0 * yb, rtol=1e-3, atol=1e-3)


def test_parseval_energy_preserved():
    rng = np.random.default_rng(5)
    x = _randn(rng, 1, 128)
    hr, hi = fft_core.fft(x, jnp.zeros_like(x))
    time_energy = float(jnp.sum(x * x))
    freq_energy = float(jnp.sum(hr * hr + hi * hi)) / 128
    assert abs(time_energy - freq_energy) < 1e-2 * max(1.0, time_energy)


def test_fft_of_delta_is_flat():
    x = jnp.zeros((1, 16)).at[0, 0].set(1.0)
    yr, yi = fft_core.fft(x, jnp.zeros_like(x))
    np.testing.assert_allclose(yr, jnp.ones_like(yr), atol=1e-5)
    np.testing.assert_allclose(yi, jnp.zeros_like(yi), atol=1e-5)


def test_halfspec_is_conjugate_symmetric_info():
    # The dropped half must be reconstructible: spectrum of real input is
    # conjugate-symmetric (the paper's storage-halving argument).
    rng = np.random.default_rng(11)
    x = _randn(rng, 2, 32)
    fr, fi = fft_core.fft(x, jnp.zeros_like(x))
    np.testing.assert_allclose(fr[..., 1:], fr[..., 1:][..., ::-1], rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(fi[..., 1:], -fi[..., 1:][..., ::-1], rtol=1e-3, atol=1e-3)


def test_bit_reversal_is_involution():
    for k in POW2:
        perm = np.asarray(fft_core.bit_reversal_permutation(k))
        np.testing.assert_array_equal(perm[perm], np.arange(k))


def test_bad_k_raises():
    with pytest.raises(ValueError):
        fft_core.bit_reversal_permutation(12)
    with pytest.raises(ValueError):
        fft_core.irfft_halfspec(jnp.zeros((1, 4)), jnp.zeros((1, 4)), 16)


@pytest.mark.parametrize("k", [4, 16, 64])
def test_circulant_convolution_theorem(k):
    # C @ x == IFFT(FFT(w) o FFT(x)) — the identity the whole paper rests on.
    rng = np.random.default_rng(k)
    w, x = _randn(rng, k), _randn(rng, k)
    direct = ref.circulant(w) @ x
    wfr, wfi = fft_core.rfft_halfspec(w[None])
    xfr, xfi = fft_core.rfft_halfspec(x[None])
    pr, pi = fft_core.complex_mul(wfr, wfi, xfr, xfi)
    spec = fft_core.irfft_halfspec(pr, pi, k)[0]
    np.testing.assert_allclose(direct, spec, rtol=1e-3, atol=1e-3)
