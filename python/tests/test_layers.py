"""Layer-2 layers vs the explicit-matrix oracles, across all three backends."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile import layers
from compile.kernels import ref


def _randn(rng, *shape):
    return jnp.asarray(rng.normal(size=shape).astype(np.float32))


# --------------------------------------------------------------------- FC

@pytest.mark.parametrize("backend", ["jnp", "core", "pallas"])
def test_bc_dense_matches_oracle(backend):
    n, m, k, batch = 24, 16, 8, 5
    rng = np.random.default_rng(0)
    params = {"w": _randn(rng, m // k, n // k, k), "b": _randn(rng, m)}
    x = _randn(rng, batch, n)
    y = layers.bc_dense_apply(params, x, k=k, backend=backend)
    expected = ref.circulant_layer_ref(params["w"], params["b"], x)
    np.testing.assert_allclose(y, expected, rtol=2e-3, atol=2e-3)


@settings(max_examples=15, deadline=None)
@given(
    p=st.integers(min_value=1, max_value=4),
    q=st.integers(min_value=1, max_value=4),
    logk=st.integers(min_value=1, max_value=5),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_bc_dense_backends_agree(p, q, logk, seed):
    k = 1 << logk
    n, m = q * k, p * k
    rng = np.random.default_rng(seed)
    params = {"w": _randn(rng, p, q, k), "b": _randn(rng, m)}
    x = _randn(rng, 3, n)
    ys = [layers.bc_dense_apply(params, x, k=k, backend=b)
          for b in ("jnp", "core", "pallas")]
    np.testing.assert_allclose(ys[0], ys[1], rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(ys[0], ys[2], rtol=2e-3, atol=2e-3)


def test_bc_dense_init_shape_checks():
    with pytest.raises(ValueError):
        layers.init_bc_dense(jax.random.PRNGKey(0), 10, 16, 4)  # 4 !| 10


def test_bc_dense_storage_is_linear_in_n():
    # O(n) storage: the param count of a bc layer is p*q*k = n*m/k.
    p = layers.init_bc_dense(jax.random.PRNGKey(0), 64, 64, 16)
    assert p["w"].size == 64 * 64 // 16


# --------------------------------------------------------------------- conv

def test_im2col_matches_ref():
    rng = np.random.default_rng(1)
    x = _randn(rng, 2, 6, 6, 4)
    got = layers.im2col(x, r=3, k=2)  # (b, oh, ow, q', k)
    b, oh, ow, qp, k = got.shape
    flat = got.reshape(b * oh * ow, qp * k)
    expected = ref.im2col_ref(x, r=3, k=2)
    np.testing.assert_allclose(flat, expected, rtol=1e-6)


def test_bc_conv_matches_oracle():
    rng = np.random.default_rng(2)
    c, p_out, r, k = 4, 4, 3, 2
    x = _randn(rng, 2, 7, 7, c)
    params = {"w": _randn(rng, p_out // k, (c // k) * r * r, k),
              "b": jnp.zeros((p_out,))}
    y = layers.bc_conv_apply(params, x, r=r, k=k, activation="none")
    expected = ref.block_circulant_conv2d_ref(x, params["w"], r, k)
    np.testing.assert_allclose(y, expected.reshape(y.shape), rtol=2e-3, atol=2e-3)


def test_bc_conv_same_padding_preserves_hw():
    rng = np.random.default_rng(3)
    x = _randn(rng, 1, 8, 8, 4)
    params = layers.init_bc_conv(jax.random.PRNGKey(0), 4, 8, 3, 2)
    y = layers.bc_conv_apply(params, x, r=3, k=2, padding="same")
    assert y.shape == (1, 8, 8, 8)


def test_dense_conv_matches_naive_ref():
    rng = np.random.default_rng(4)
    x = _randn(rng, 2, 6, 6, 3)
    params = layers.init_conv(jax.random.PRNGKey(1), 3, 5, 3)
    y = layers.conv_apply(params, x, activation="none")
    expected = ref.conv2d_ref(x, params["w"]) + params["b"]
    np.testing.assert_allclose(y, expected, rtol=1e-3, atol=1e-3)


# --------------------------------------------------------------------- quant

def test_fake_quant_identity_for_none():
    x = jnp.asarray([1.0, -2.0])
    assert layers.fake_quant(x, None) is x


def test_fake_quant_levels():
    # 12-bit symmetric: max-abs maps to 2047 levels; error <= scale/2.
    rng = np.random.default_rng(5)
    x = _randn(rng, 1000)
    q = layers.fake_quant(x, 12)
    scale = float(jnp.max(jnp.abs(x))) / 2047
    assert float(jnp.max(jnp.abs(q - x))) <= scale / 2 + 1e-7


def test_fake_quant_gradient_is_straight_through():
    g = jax.grad(lambda x: jnp.sum(layers.fake_quant(x, 8) ** 2))(jnp.asarray([0.3, -0.7]))
    # d/dx of q(x)^2 with STE is 2*q(x)
    q = layers.fake_quant(jnp.asarray([0.3, -0.7]), 8)
    np.testing.assert_allclose(g, 2 * q, rtol=1e-5)


@pytest.mark.parametrize("bits,tol", [(4, 0.1), (8, 6e-3), (12, 4e-4)])
def test_quant_error_shrinks_with_bits(bits, tol):
    rng = np.random.default_rng(6)
    x = _randn(rng, 4096)
    err = float(jnp.max(jnp.abs(layers.fake_quant(x, bits) - x)))
    assert err < tol * float(jnp.max(jnp.abs(x)))


# --------------------------------------------------------------------- pooling

def test_avg_pool2():
    x = jnp.arange(16, dtype=jnp.float32).reshape(1, 4, 4, 1)
    y = layers.avg_pool2(x)
    np.testing.assert_allclose(y[0, :, :, 0], [[2.5, 4.5], [10.5, 12.5]])


def test_max_pool2():
    x = jnp.arange(16, dtype=jnp.float32).reshape(1, 4, 4, 1)
    y = layers.max_pool2(x)
    np.testing.assert_allclose(y[0, :, :, 0], [[5, 7], [13, 15]])


def test_prior_pool_shape_and_mean():
    x = jnp.ones((2, 28, 28, 1))
    y = layers.prior_pool(x, 256)
    assert y.shape == (2, 256)
    # 784 -> window 4, padded to 1024: first 196 windows average 1.0,
    # remaining windows include zero padding.
    np.testing.assert_allclose(y[:, :190], 1.0, rtol=1e-6)
