"""Pytest path setup: make the ``compile`` package importable whether pytest
is invoked from ``python/`` (the Makefile default) or the repo root."""

import os
import sys

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
