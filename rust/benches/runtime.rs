//! Bench: the runtime hot paths.  With the `pjrt` feature: compile cost
//! (paid once per model variant, the "bitstream load"), per-batch execute
//! latency, and the derived images/s for batch-1 vs batch-64 and plain vs
//! Pallas-kernel artifacts — the L3 perf baseline the coordinator overhead
//! is measured against (DESIGN.md §9).  Always: the native pure-Rust engine
//! (whose FC layers ride the batch-major parallel `matmul`), so the two
//! execution substrates of the same trained models stay comparable.

use circnn::data;
use circnn::runtime::Manifest;
use circnn::util::benchkit::Bench;

#[cfg(feature = "pjrt")]
fn pjrt_benches(man: &Manifest, bench: &Bench) -> anyhow::Result<()> {
    use circnn::runtime::engine::{literal_f32, Engine};

    let engine = Engine::cpu()?;
    println!("PJRT platform: {}\n", engine.platform());

    // compile cost: load each mnist artifact fresh (cache defeated by a
    // fresh engine per iteration would be too slow; report one-shot times)
    println!("== compile (one-shot, per artifact) ==");
    for e in &man.models {
        for a in &e.artifacts {
            let fresh = Engine::cpu()?;
            let t0 = std::time::Instant::now();
            fresh.load(man.path_of(&a.file))?;
            println!("compile {:40} {:>10.1}ms", a.file, t0.elapsed().as_secs_f64() * 1e3);
        }
    }

    println!("\n== execute (steady-state, cached executable) ==");
    for e in &man.models {
        let ds = data::dataset(&e.dataset).unwrap();
        for (arts, tag) in [(&e.artifacts, "plain"), (&e.artifacts_pallas, "pallas")] {
            for a in arts {
                let exe = engine.load(man.path_of(&a.file))?;
                let (xs, _) = data::batch(&ds, 0, a.batch, true);
                let lit = literal_f32(&xs, &a.input_shape)?;
                bench.run(
                    &format!("execute/{}/{}/b{}", e.name, tag, a.batch),
                    a.batch as u64,
                    || exe.run1(std::slice::from_ref(&lit)).unwrap(),
                );
            }
        }
    }

    // literal construction (hot-path allocation cost the batcher pays)
    println!("\n== literal construction ==");
    let e = man.model("mnist_mlp_1")?;
    let a = e.artifacts.iter().max_by_key(|a| a.batch).unwrap();
    let ds = data::dataset(&e.dataset).unwrap();
    let (xs, _) = data::batch(&ds, 0, a.batch, true);
    bench.run("literal_f32/b64_mnist", a.batch as u64, || {
        literal_f32(&xs, &a.input_shape).unwrap()
    });
    Ok(())
}

fn main() -> anyhow::Result<()> {
    let man = match Manifest::load(Manifest::default_dir()) {
        Ok(m) => m,
        Err(_) => {
            eprintln!("SKIP: artifacts missing (run `make artifacts`)");
            return Ok(());
        }
    };
    let bench = Bench::default();

    #[cfg(feature = "pjrt")]
    pjrt_benches(&man, &bench)?;
    #[cfg(not(feature = "pjrt"))]
    println!("(built without the pjrt feature: compile/execute benches skipped)\n");

    // native pure-Rust engine vs PJRT — the two execution substrates of the
    // same trained models (parity pinned in rust/tests/native_parity.rs).
    // FC layers execute through the batch-major parallel matmul.
    println!("== native engine (pure Rust, no PJRT) ==");
    for e in &man.models {
        let Some(m) = circnn::models::by_name(&e.name) else { continue };
        let path = man.dir.join("params").join(format!("{}.npz", e.name));
        let Ok(native) = circnn::native::NativeModel::load(&m, &path, Some(12)) else {
            continue;
        };
        let ds = data::dataset(&e.dataset).unwrap();
        let (h, w, c) = m.input;
        for batch in [1usize, 64] {
            let (xs, _) = data::batch(&ds, 0, batch, true);
            bench.run(&format!("native/{}/b{}", e.name, batch), batch as u64, || {
                native.forward(&xs, batch, h, w, c)
            });
        }
    }

    Ok(())
}
