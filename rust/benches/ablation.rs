//! Bench AB1-AB3: the design-choice ablations of DESIGN.md §6 — decoupled
//! FFT/IFFT placement, real-input half-spectrum symmetry, and batch
//! interleaving — swept over every registry model and over batch sizes
//! (the bubble-amortization curve behind Fig. 4).

use circnn::experiments::ablations;
use circnn::fpga::device::CYCLONE_V;
use circnn::fpga::schedule::{simulate, ScheduleConfig};
use circnn::models;

fn main() {
    println!("{}", ablations::render());

    // batch-size amortization (AB3's underlying curve): ns/image vs batch
    println!("== batch interleaving: ns/image vs batch (mnist_mlp_1) ==");
    let m = models::by_name("mnist_mlp_1").unwrap();
    println!("{:>7} {:>14} {:>14}", "batch", "interleaved", "per-image");
    for b in [1u64, 2, 4, 8, 16, 32, 64] {
        let on = simulate(&m, &CYCLONE_V, &ScheduleConfig { batch: b, ..Default::default() });
        let off = simulate(
            &m,
            &CYCLONE_V,
            &ScheduleConfig { batch: b, interleave: false, ..Default::default() },
        );
        println!(
            "{:>7} {:>12.1}ns {:>12.1}ns",
            b,
            on.ns_per_image(),
            off.ns_per_image()
        );
    }

    // ablations must all point the right way — guard the shape in bench too
    for m in models::registry() {
        for row in ablations::ablate(&m) {
            assert!(
                row.retained <= 1.0 + 1e-9,
                "{} / {}: ablation helped?!",
                row.model,
                row.ablation
            );
        }
    }
    println!("\nall ablations degrade throughput when disabled (shape holds)");
}
