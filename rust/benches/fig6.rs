//! Bench F6: regenerate the Fig. 6 scatter (equivalent GOPS vs GOPS/W for
//! the proposed designs on both devices against the reference-FPGA corpus)
//! and time the design-space evaluation — one `DesignReport` per
//! (model, device) pair is the unit the co-optimization loop of Fig. 5
//! sweeps, so its cost bounds how fine a design sweep can afford to be.

use circnn::experiments::fig6;
use circnn::fpga::device::{CYCLONE_V, KINTEX_7};
use circnn::fpga::report::DesignReport;
use circnn::fpga::schedule::ScheduleConfig;
use circnn::models;
use circnn::util::benchkit::Bench;

fn main() {
    println!("{}", fig6::render());

    let bench = Bench::default();
    println!("== generation cost ==");
    for dev in [&CYCLONE_V, &KINTEX_7] {
        for m in models::registry() {
            let cfg = ScheduleConfig::auto_for(&m, dev);
            bench.run(&format!("design_report/{}/{}", dev.name, m.name), 1, || {
                DesignReport::build(&m, dev, &cfg)
            });
        }
    }
    bench.run("fig6_points/full", 1, fig6::points);

    let gain = fig6::min_efficiency_gain();
    println!("\nmin efficiency gain of proposed (CyClone V) over reference corpus: {gain:.1}x");
    assert!(gain >= 5.0, "Fig. 6 shape collapsed");
}
