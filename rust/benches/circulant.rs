//! Bench S1: the O(n log n) vs O(n^2) crossover (the paper's core
//! algorithmic claim), measured on the pure-Rust substrate — plus the
//! before/after comparisons for the packed real-FFT fast path and the
//! batch-major parallel `matmul`.
//!
//! Prints dense vs block-circulant matvec times over a grid of matrix
//! sizes and block sizes, the FFT-plan primitives the simulator's cycle
//! model is built from, and writes the whole suite as machine-readable
//! JSON to `BENCH_circulant.json` at the repo root (perf trajectory
//! tracking across PRs).  `harness = false`: uses `util::benchkit`.
//!
//! ## How CI consumes the JSON
//!
//! The workflow's `bench` job runs this target, uploads
//! `BENCH_circulant.json` as an artifact, and **fails the build if any key
//! in the `derived` map whose name contains `_speedup_` is below 1.0** —
//! so every ratio emitted under a `*_speedup_*` name is a regression gate
//! (serial vs parallel, old vs new ordering), while `*_ratio_*` names are
//! informational trajectory points that may legitimately dip below 1.0 on
//! small runners (per-case resident-vs-pixel-outer, SIMD-vs-scalar MAC,
//! int16-engine-vs-f32-engine).  The executed int16 BFP path gates its own
//! `fixed_mac_speedup_*` / `fixed_conv_speedup_*` serial-vs-sharded keys.
//!
//! The multi-batch serving case follows the same contract: it gates
//! `pipeline_speedup_<model>_b<batch>x<waves>` (deep-pipelined layer
//! stages vs the serial one-batch-at-a-time executor) whenever the host
//! plans ≥ 2 stages; on a single-core host the pipeline degenerates to one
//! stage with nothing to overlap, and the same measurement is emitted
//! informationally as `pipeline_ratio_…` instead.  The telemetry layer
//! pins its overhead-neutrality claim as
//! `telemetry_overhead_ratio_serve_…` (traced/untraced serving medians,
//! ~1.0 expected) — informational by construction, never a gate.

use std::sync::Arc;
use std::time::Duration;

use circnn::circulant::fft;
use circnn::circulant::{dense, BlockCirculant, FftPlan};
use circnn::coordinator::{BatchPolicy, EngineKind, Server, ServerConfig};
use circnn::native::conv::{self, ConvShape};
use circnn::native::NativeModel;
use circnn::pipeline::{Pipeline, PipelinePlan};
use circnn::runtime::Manifest;
use circnn::train::Trainer;
use circnn::util::benchkit::{self, Bench, Measurement};
use circnn::util::rng::SplitMix;
use circnn::{data, models};

fn main() {
    let bench = Bench::default();
    let mut rng = SplitMix::new(0xBEEF);
    let mut results: Vec<Measurement> = Vec::new();
    let mut derived: Vec<(String, f64)> = Vec::new();

    println!("== FFT plan primitives (packed real path vs full-complex pre-PR path) ==");
    for k in [64usize, 128, 256, 512] {
        let plan = FftPlan::shared(k);
        let mut re = rng.normal_vec(k);
        let mut im = rng.normal_vec(k);
        results.push(bench.run(&format!("fft/k{k}"), 1, || plan.fft(&mut re, &mut im)));
        let kh = plan.half_bins();
        let x = rng.normal_vec(k);
        let (mut hr, mut hi) = (vec![0.0; kh], vec![0.0; kh]);
        let mut scratch = vec![0.0; 2 * k];
        let new = bench.run(&format!("rfft_halfspec/k{k}"), 1, || {
            plan.rfft_halfspec(&x, &mut hr, &mut hi, &mut scratch)
        });
        let old = bench.run(&format!("rfft_fullcomplex/k{k}"), 1, || {
            plan.rfft_halfspec_via_full(&x, &mut hr, &mut hi, &mut scratch)
        });
        let mut out = vec![0.0; k];
        let inew = bench.run(&format!("irfft_halfspec/k{k}"), 1, || {
            plan.irfft_halfspec(&hr, &hi, &mut out, &mut scratch)
        });
        let iold = bench.run(&format!("irfft_fullcomplex/k{k}"), 1, || {
            plan.irfft_halfspec_via_full(&hr, &hi, &mut out, &mut scratch)
        });
        let fwd = old.median_ns() / new.median_ns();
        let inv = iold.median_ns() / inew.median_ns();
        println!("   k={k:<4} rfft speedup {fwd:.2}x  irfft speedup {inv:.2}x");
        derived.push((format!("rfft_speedup_k{k}"), fwd));
        derived.push((format!("irfft_speedup_k{k}"), inv));
        results.extend([new, old, inew, iold]);
    }

    println!(
        "\n== spectral MAC kernel: dispatched engine ({}) vs scalar oracle ==",
        fft::mac_backend()
    );
    // the phase-2 inner kernel in isolation; informational ratio (the
    // autovectorized oracle can tie the explicit engine on some hosts)
    for k in [64usize, 256] {
        let kh = k / 2 + 1;
        let (ar, ai) = (rng.normal_vec(kh), rng.normal_vec(kh));
        let (br, bi) = (rng.normal_vec(kh), rng.normal_vec(kh));
        let (mut cr, mut ci) = (vec![0.0f32; kh], vec![0.0f32; kh]);
        let d = bench.run(&format!("mac_dispatch/k{k}"), 1, || {
            fft::complex_mul_acc(&ar, &ai, &br, &bi, &mut cr, &mut ci)
        });
        let s = bench.run(&format!("mac_scalar/k{k}"), 1, || {
            fft::complex_mul_acc_scalar(&ar, &ai, &br, &bi, &mut cr, &mut ci)
        });
        let ratio = s.median_ns() / d.median_ns();
        println!("   k={k:<4} {} vs scalar {ratio:.2}x", fft::mac_backend());
        derived.push((format!("mac_simd_ratio_k{k}"), ratio));
        results.extend([d, s]);
    }

    println!("\n== dense vs block-circulant matvec (k = 64) ==");
    println!(
        "{:>6} {:>6} | {:>12} {:>12} {:>9}",
        "n", "k", "dense", "circulant", "speedup"
    );
    for n in [256usize, 512, 1024, 2048, 4096] {
        let k = 64;
        let pq = n / k;
        let mut bc = BlockCirculant::new(pq, pq, k, rng.normal_vec(pq * pq * k));
        bc.precompute();
        let w = bc.to_dense();
        let x = rng.normal_vec(n);
        let mut y = vec![0.0f32; n];
        let d = bench.run(&format!("dense_matvec/n{n}"), 1, || {
            dense::matvec(&w, n, n, &x, &mut y)
        });
        let c = bench.run(&format!("circ_matvec/n{n}_k{k}"), 1, || {
            bc.matvec(&x, &mut y)
        });
        println!(
            "{:>6} {:>6} | {:>10.1}us {:>10.1}us {:>8.2}x",
            n,
            k,
            d.median_ns() / 1e3,
            c.median_ns() / 1e3,
            d.median_ns() / c.median_ns()
        );
        results.extend([d, c]);
    }

    println!("\n== batched matmul: serial per-row (pre-PR) vs batch-major parallel ==");
    let threads = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    println!("   (available parallelism: {threads}; override with CIRCNN_THREADS)");
    for (n, k, batch) in [(1024usize, 64usize, 64usize), (2048, 64, 64), (1024, 128, 64)] {
        let pq = n / k;
        let mut bc = BlockCirculant::new(pq, pq, k, rng.normal_vec(pq * pq * k));
        bc.precompute();
        let xs = rng.normal_vec(batch * n);
        let mut ys = vec![0.0f32; batch * n];
        let ser = bench.run(&format!("matmul_serial/b{batch}_n{n}_k{k}"), batch as u64, || {
            bc.matmul_serial(&xs, batch, &mut ys)
        });
        let par = bench.run(&format!("matmul/b{batch}_n{n}_k{k}"), batch as u64, || {
            bc.matmul(&xs, batch, &mut ys)
        });
        let speedup = ser.median_ns() / par.median_ns();
        println!("   n={n:<5} k={k:<4} batch={batch:<3} parallel speedup {speedup:.2}x");
        derived.push((format!("matmul_speedup_b{batch}_n{n}_k{k}"), speedup));
        results.extend([ser, par]);
    }

    println!("\n== int16 BFP matmul: serial vs batch-major parallel (executed fixed path) ==");
    // the `--precision fixed16` datapath on the same shapes as the gated
    // f32 matmul cases.  Gated key: serial vs parallel (same sharding win
    // the f32 gate proves); the fixed-vs-f32 comparison is informational —
    // the i16 engine adds per-spectrum quantize/rescale work, so parity,
    // not speedup, is the expectation on wide-SIMD hosts.
    for (n, k, batch) in [(1024usize, 64usize, 64usize), (2048, 64, 64), (1024, 128, 64)] {
        let pq = n / k;
        let mut bc = BlockCirculant::new(pq, pq, k, rng.normal_vec(pq * pq * k));
        bc.precompute_fixed(12);
        let xs = rng.normal_vec(batch * n);
        let mut ys = vec![0.0f32; batch * n];
        let f32_par = bench.run(&format!("matmul_f32_ref/b{batch}_n{n}_k{k}"), batch as u64, || {
            bc.matmul(&xs, batch, &mut ys)
        });
        let ser = bench.run(&format!("matmul_fixed_serial/b{batch}_n{n}_k{k}"), batch as u64, || {
            bc.matmul_fixed_serial(&xs, batch, &mut ys)
        });
        let par = bench.run(&format!("matmul_fixed/b{batch}_n{n}_k{k}"), batch as u64, || {
            bc.matmul_fixed(&xs, batch, &mut ys)
        });
        let speedup = ser.median_ns() / par.median_ns();
        let vs_f32 = f32_par.median_ns() / par.median_ns();
        println!(
            "   n={n:<5} k={k:<4} batch={batch:<3} parallel speedup {speedup:.2}x  vs f32 {vs_f32:.2}x"
        );
        derived.push((format!("fixed_mac_speedup_b{batch}_n{n}_k{k}"), speedup));
        derived.push((format!("fixed_vs_f32_ratio_b{batch}_n{n}_k{k}"), vs_f32));
        results.extend([f32_par, ser, par]);
    }

    println!("\n== BcConv pixel pipeline: serial (pre-PR) vs pixel-outer vs resident ==");
    // the registry's CNN hot path: svhn/cifar-shaped SAME conv layers.
    // Three orderings of the same (bitwise-identical) computation: the
    // pre-PR serial walk, the parallel pixel-outer walk (weight spectra
    // re-fetched per output pixel) and the parallel weight-block-outer
    // resident sweep (each spectrum loaded once per shard — the BRAM-reuse
    // ordering).  The best per-case resident gain is gated >= 1.0 in CI:
    // the resident ordering must beat the pixel-outer walk on at least one
    // registry CONV layer.
    let conv_cases =
        [(16usize, 32usize, 3usize, 8usize, 16usize, 32usize), (32, 32, 3, 8, 16, 32)];
    let mut resident_best = f64::MIN;
    for (c, p, r, k, hw, batch) in conv_cases {
        let (pb, qb) = (p / k, (c / k) * r * r);
        let mut bc = BlockCirculant::new(pb, qb, k, rng.normal_vec(pb * qb * k));
        bc.precompute();
        let shape = ConvShape { h: hw, w: hw, c, r, same: true };
        let xs = rng.normal_vec(batch * hw * hw * c);
        let bias = rng.normal_vec(p);
        let ser_name = format!("bc_conv_serial/c{c}_p{p}_{hw}x{hw}_b{batch}");
        let ser = bench.run(&ser_name, batch as u64, || {
            conv::forward_serial(&bc, &xs, batch, shape, &bias, true)
        });
        let po_name = format!("bc_conv_pixel_outer/c{c}_p{p}_{hw}x{hw}_b{batch}");
        let po = bench.run(&po_name, batch as u64, || {
            conv::forward_pixel_outer(&bc, &xs, batch, shape, &bias, true)
        });
        let par_name = format!("bc_conv/c{c}_p{p}_{hw}x{hw}_b{batch}");
        let par = bench.run(&par_name, batch as u64, || {
            conv::forward(&bc, &xs, batch, shape, &bias, true)
        });
        let speedup = ser.median_ns() / par.median_ns();
        let resident = po.median_ns() / par.median_ns();
        resident_best = resident_best.max(resident);
        println!(
            "   c={c:<3} p={p:<3} r={r} k={k} {hw}x{hw} batch={batch:<3} vs serial {speedup:.2}x  vs pixel-outer {resident:.2}x"
        );
        derived.push((format!("bc_conv_speedup_c{c}_p{p}_{hw}x{hw}_b{batch}"), speedup));
        derived.push((
            format!("bc_conv_resident_ratio_c{c}_p{p}_{hw}x{hw}_b{batch}"),
            resident,
        ));
        results.extend([ser, po, par]);
    }
    // gated: the resident ordering must win somewhere in the registry
    derived.push(("bc_conv_resident_speedup_best".into(), resident_best));

    println!("\n== int16 BFP conv: serial vs sharded (executed fixed path) ==");
    // the fixed twin of the gated conv cases, same contract as the fixed
    // matmul section: the gate is serial-vs-sharded; fixed-vs-f32 is the
    // informational trajectory point.
    for (c, p, r, k, hw, batch) in conv_cases {
        let (pb, qb) = (p / k, (c / k) * r * r);
        let mut bc = BlockCirculant::new(pb, qb, k, rng.normal_vec(pb * qb * k));
        bc.precompute_fixed(12);
        let shape = ConvShape { h: hw, w: hw, c, r, same: true };
        let xs = rng.normal_vec(batch * hw * hw * c);
        let bias = rng.normal_vec(p);
        let ref_name = format!("bc_conv_f32_ref/c{c}_p{p}_{hw}x{hw}_b{batch}");
        let f32_par = bench.run(&ref_name, batch as u64, || {
            conv::forward(&bc, &xs, batch, shape, &bias, true)
        });
        let ser_name = format!("bc_conv_fixed_serial/c{c}_p{p}_{hw}x{hw}_b{batch}");
        let ser = bench.run(&ser_name, batch as u64, || {
            conv::forward_fixed_serial(&bc, &xs, batch, shape, &bias, true)
        });
        let par_name = format!("bc_conv_fixed/c{c}_p{p}_{hw}x{hw}_b{batch}");
        let par = bench.run(&par_name, batch as u64, || {
            conv::forward_fixed(&bc, &xs, batch, shape, &bias, true)
        });
        let speedup = ser.median_ns() / par.median_ns();
        let vs_f32 = f32_par.median_ns() / par.median_ns();
        println!(
            "   c={c:<3} p={p:<3} r={r} k={k} {hw}x{hw} batch={batch:<3} vs serial {speedup:.2}x  vs f32 {vs_f32:.2}x"
        );
        derived.push((format!("fixed_conv_speedup_c{c}_p{p}_{hw}x{hw}_b{batch}"), speedup));
        derived.push((
            format!("fixed_conv_vs_f32_ratio_c{c}_p{p}_{hw}x{hw}_b{batch}"),
            vs_f32,
        ));
        results.extend([f32_par, ser, par]);
    }

    println!("\n== native train step: serial vs parallel (spectral backprop) ==");
    // the new training workload: forward + conjugate-spectrum backward +
    // frequency-accumulated weight grads + SGD, one full step per iteration
    // (an MLP, so the serial flag covers every FFT stage of the step)
    {
        let model = models::by_name("mnist_mlp_2").unwrap();
        let ds = data::dataset(model.dataset).unwrap();
        let batch = 64;
        let (xs, ys) = data::batch(&ds, 0, batch, false);
        let mut ser_tr = Trainer::new(&model, 1).expect("trainer");
        ser_tr.set_serial(true);
        let mut par_tr = Trainer::new(&model, 1).expect("trainer");
        let ser = bench.run(&format!("train_step_serial/mnist_mlp_2_b{batch}"), batch as u64, || {
            ser_tr.step(&xs, &ys)
        });
        let par = bench.run(&format!("train_step/mnist_mlp_2_b{batch}"), batch as u64, || {
            par_tr.step(&xs, &ys)
        });
        let speedup = ser.median_ns() / par.median_ns();
        println!("   mnist_mlp_2 batch={batch} train_step parallel speedup {speedup:.2}x");
        derived.push((format!("train_step_speedup_mnist_mlp_2_b{batch}"), speedup));
        results.extend([ser, par]);
    }

    println!("\n== deep-pipelined serving: serial executor vs multi-batch layer pipeline ==");
    // the serving hot path under multi-batch load: N released batches, run
    // one-at-a-time end to end (the pre-PR executor) vs streamed through
    // the per-layer stage pipeline with one batch per stage in flight.
    // mnist_mlp_2 at batch 64 keeps every layer below the matmul sharding
    // threshold, so the serial walk is single-core and the overlap the
    // pipeline buys is real parallelism, not shard reshuffling.
    {
        let model = models::by_name("mnist_mlp_2").unwrap();
        let native = Arc::new(NativeModel::init_random(&model, 0xA11CE));
        let (h, w, c) = model.input;
        let ds = data::dataset(model.dataset).unwrap();
        let (batch, waves) = (64usize, 12usize);
        let per = h * w * c;
        let (xs, _) = data::batch(&ds, 0, batch * waves, false);
        let ser = bench.run(
            &format!("serve_serial/mnist_mlp_2_b{batch}x{waves}"),
            (batch * waves) as u64,
            || {
                for i in 0..waves {
                    native.forward(&xs[i * batch * per..(i + 1) * batch * per], batch, h, w, c);
                }
            },
        );
        let plan = PipelinePlan::auto(&native);
        let stages = plan.stage_count();
        let (done_tx, done_rx) = std::sync::mpsc::channel();
        let pipe = Pipeline::start(native.clone(), plan, None, move |_t, _p: usize| {
            let _ = done_tx.send(());
        });
        let par = bench.run(
            &format!("serve_pipeline/mnist_mlp_2_b{batch}x{waves}"),
            (batch * waves) as u64,
            || {
                for i in 0..waves {
                    pipe.submit(&xs[i * batch * per..(i + 1) * batch * per], batch, h, w, c, i)
                        .expect("pipeline running");
                }
                for _ in 0..waves {
                    done_rx.recv().expect("pipeline sink hung up");
                }
            },
        );
        pipe.shutdown();
        let speedup = ser.median_ns() / par.median_ns();
        println!(
            "   mnist_mlp_2 batch={batch} waves={waves} stages={stages} pipeline speedup {speedup:.2}x"
        );
        // gate only when the host can actually overlap stages (naming
        // contract in the header doc: single-stage hosts report info-only)
        let key = if stages >= 2 {
            format!("pipeline_speedup_mnist_mlp_2_b{batch}x{waves}")
        } else {
            format!("pipeline_ratio_mnist_mlp_2_b{batch}x{waves}")
        };
        derived.push((key, speedup));
        results.extend([ser, par]);
    }

    println!("\n== telemetry overhead: traced vs untraced serving (informational) ==");
    // the telemetry layer's overhead-neutrality trajectory point: the same
    // synthetic request stream through the full coordinator path with span
    // tracing off vs on.  Tracing adds two `Instant` stamps and one ring
    // insert per request, so ~1.0 is the expectation; the key is a
    // `_ratio_` (never CI-gated, header contract) because sub-percent
    // effects drown in scheduler noise on small runners.  Value is
    // traced/untraced median — above 1.0 reads as tracing overhead.
    {
        let model = "mnist_mlp_1";
        let mut man = Manifest::synthetic();
        man.models.retain(|m| m.name == model);
        let (batch, waves) = (16usize, 4usize);
        let imgs: Vec<_> =
            (0..(batch * waves) as u64).map(|i| data::sample(&data::MNIST_S, i).0).collect();
        let mut serve = |trace: bool, label: &str| {
            let server = Server::start_with_manifest(
                man.clone(),
                ServerConfig {
                    policy: BatchPolicy {
                        max_batch: batch,
                        max_delay: Duration::from_secs(5),
                        max_queue: 8192,
                    },
                    engine: EngineKind::Native,
                    init_random_fallback: true,
                    trace,
                    ..ServerConfig::default()
                },
            )
            .expect("bench server");
            let m = bench.run(label, (batch * waves) as u64, || {
                let pending: Vec<_> = imgs
                    .iter()
                    .map(|img| server.infer_async(model, img).expect("admitted"))
                    .collect();
                for rx in pending {
                    rx.recv().expect("server alive").expect("response");
                }
            });
            server.shutdown();
            m
        };
        let off = serve(false, "serve_untraced/mnist_mlp_1_b16x4");
        let on = serve(true, "serve_traced/mnist_mlp_1_b16x4");
        let overhead = on.median_ns() / off.median_ns();
        println!("   mnist_mlp_1 batch={batch} waves={waves} traced/untraced {overhead:.3}x");
        derived.push(("telemetry_overhead_ratio_serve_mnist_mlp_1_b16x4".into(), overhead));
        results.extend([off, on]);
    }

    println!("\n== block-size sweep at n = 2048 (compression/speed frontier) ==");
    for k in [16usize, 32, 64, 128, 256] {
        let n = 2048;
        let pq = n / k;
        let mut bc = BlockCirculant::new(pq, pq, k, rng.normal_vec(pq * pq * k));
        bc.precompute();
        let x = rng.normal_vec(n);
        let mut y = vec![0.0f32; n];
        let m = bench.run(&format!("circ_matvec_sweep/n{n}_k{k}"), 1, || {
            bc.matvec(&x, &mut y)
        });
        println!(
            "   k={k:<4} params {:>8} ({:>5.1}x fewer)  median {:.1}us",
            bc.param_count(),
            (n * n) as f64 / bc.param_count() as f64,
            m.median_ns() / 1e3
        );
        results.push(m);
    }

    println!("\n== precompute (offline FFT(w) step) ==");
    for k in [64usize, 128] {
        let n = 1024;
        let pq = n / k;
        let w = rng.normal_vec(pq * pq * k);
        results.push(bench.run(&format!("precompute/n{n}_k{k}"), 1, || {
            let mut bc = BlockCirculant::new(pq, pq, k, w.clone());
            bc.precompute();
            bc
        }));
    }

    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_circulant.json");
    match benchkit::write_json(path, "circulant", &results, &derived) {
        Ok(()) => println!("\nwrote {path}"),
        Err(e) => eprintln!("\ncould not write {path}: {e}"),
    }
}
