//! Bench S1: the O(n log n) vs O(n^2) crossover (the paper's core
//! algorithmic claim), measured on the pure-Rust substrate.
//!
//! Prints dense vs block-circulant matvec times over a grid of matrix
//! sizes and block sizes, plus the FFT-plan primitives the simulator's
//! cycle model is built from.  `harness = false`: uses `util::benchkit`.

use circnn::circulant::{dense, BlockCirculant, FftPlan};
use circnn::util::benchkit::Bench;
use circnn::util::rng::SplitMix;

fn main() {
    let bench = Bench::default();
    let mut rng = SplitMix::new(0xBEEF);

    println!("== FFT plan primitives ==");
    for k in [64usize, 128, 256, 512] {
        let plan = FftPlan::new(k);
        let mut re = rng.normal_vec(k);
        let mut im = rng.normal_vec(k);
        bench.run(&format!("fft/k{k}"), 1, || plan.fft(&mut re, &mut im));
        let kh = plan.half_bins();
        let x = rng.normal_vec(k);
        let (mut hr, mut hi) = (vec![0.0; kh], vec![0.0; kh]);
        let mut scratch = vec![0.0; 2 * k];
        bench.run(&format!("rfft_halfspec/k{k}"), 1, || {
            plan.rfft_halfspec(&x, &mut hr, &mut hi, &mut scratch)
        });
    }

    println!("\n== dense vs block-circulant matvec (k = 64) ==");
    println!(
        "{:>6} {:>6} | {:>12} {:>12} {:>9}",
        "n", "k", "dense", "circulant", "speedup"
    );
    for n in [256usize, 512, 1024, 2048, 4096] {
        let k = 64;
        let pq = n / k;
        let mut bc = BlockCirculant::new(pq, pq, k, rng.normal_vec(pq * pq * k));
        bc.precompute();
        let w = bc.to_dense();
        let x = rng.normal_vec(n);
        let mut y = vec![0.0f32; n];
        let d = bench.run(&format!("dense_matvec/n{n}"), 1, || {
            dense::matvec(&w, n, n, &x, &mut y)
        });
        let c = bench.run(&format!("circ_matvec/n{n}_k{k}"), 1, || {
            bc.matvec(&x, &mut y)
        });
        println!(
            "{:>6} {:>6} | {:>10.1}us {:>10.1}us {:>8.2}x",
            n,
            k,
            d.median_ns() / 1e3,
            c.median_ns() / 1e3,
            d.median_ns() / c.median_ns()
        );
    }

    println!("\n== block-size sweep at n = 2048 (compression/speed frontier) ==");
    for k in [16usize, 32, 64, 128, 256] {
        let n = 2048;
        let pq = n / k;
        let mut bc = BlockCirculant::new(pq, pq, k, rng.normal_vec(pq * pq * k));
        bc.precompute();
        let x = rng.normal_vec(n);
        let mut y = vec![0.0f32; n];
        let m = bench.run(&format!("circ_matvec/n{n}_k{k}"), 1, || {
            bc.matvec(&x, &mut y)
        });
        println!(
            "   k={k:<4} params {:>8} ({:>5.1}x fewer)  median {:.1}us",
            bc.param_count(),
            (n * n) as f64 / bc.param_count() as f64,
            m.median_ns() / 1e3
        );
    }

    println!("\n== precompute (offline FFT(w) step) ==");
    for k in [64usize, 128] {
        let n = 1024;
        let pq = n / k;
        let w = rng.normal_vec(pq * pq * k);
        bench.run(&format!("precompute/n{n}_k{k}"), 1, || {
            let mut bc = BlockCirculant::new(pq, pq, k, w.clone());
            bc.precompute();
            bc
        });
    }
}
