//! Bench T1: regenerate Table 1 end-to-end and time its moving parts.
//!
//! Prints the full regenerated table (simulator + baseline models + trained
//! accuracies from the manifest when present), the paper's headline ratios,
//! and benchmark timings of the table generation itself — the "experiment
//! harness must be cheap enough to sweep" requirement.

use circnn::experiments::{table1, try_manifest};
use circnn::fpga::device::CYCLONE_V;
use circnn::fpga::schedule::{simulate, ScheduleConfig};
use circnn::models;
use circnn::util::benchkit::Bench;

fn main() {
    let man = try_manifest();
    if man.is_none() {
        eprintln!("note: artifacts/manifest.json missing — paper accuracies used instead");
    }

    // the regenerated table itself
    println!("{}", table1::render(man.as_ref()));

    let bench = Bench::default();
    println!("== generation cost ==");
    for m in models::registry() {
        let cfg = ScheduleConfig::auto_for(&m, &CYCLONE_V);
        bench.run(&format!("simulate/{}", m.name), cfg.batch, || {
            simulate(&m, &CYCLONE_V, &cfg)
        });
    }
    bench.run("table1_rows/full", 1, || table1::rows(man.as_ref()));

    // headline invariants, asserted so `cargo bench` also guards the shape
    let rows = table1::rows(man.as_ref());
    let h = table1::headline(&rows);
    println!(
        "\nheadline: {:.0}x speedup vs TrueNorth (paper >=152x), \
         {:.0}x energy vs TrueNorth (paper >=71x), \
         {:.0}x energy vs reference FPGA (paper >=31x)",
        h.speedup_vs_truenorth, h.energy_gain_vs_truenorth, h.energy_gain_vs_reference_fpga
    );
    assert!(h.speedup_vs_truenorth >= 152.0);
    assert!(h.energy_gain_vs_truenorth >= 71.0);
    assert!(h.energy_gain_vs_reference_fpga >= 31.0);
}
