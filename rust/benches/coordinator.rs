//! Bench: coordinator overhead — serving throughput across batching
//! policies vs the raw-engine roofline measured in `benches/runtime.rs`,
//! plus the pure-logic hot paths (batcher push/drain, router lookup) that
//! must stay allocation-light (DESIGN.md §9: coordinator adds <10%
//! overhead over raw execute at batch 64).
//!
//! Without the `pjrt` feature the serving section still runs: the server
//! falls back to the native block-circulant backend (parallel batch-major
//! matmul), and the roofline comparison is skipped.

use std::time::{Duration, Instant};

use circnn::coordinator::{BatchPolicy, BatchQueue, Router, Server, ServerConfig};
use circnn::data;
use circnn::runtime::Manifest;
use circnn::util::benchkit::Bench;

fn serve_throughput(policy: BatchPolicy, clients: usize, requests: usize) -> anyhow::Result<f64> {
    let server = Server::start(ServerConfig { policy, ..ServerConfig::default() })?;
    let (img, _) = data::sample(&data::MNIST_S, 0);
    // warmup (compile)
    server.infer("mnist_mlp_1", &img).unwrap();
    let t0 = Instant::now();
    std::thread::scope(|scope| {
        for _ in 0..clients {
            let server = &server;
            let img = &img;
            scope.spawn(move || {
                for _ in 0..requests / clients {
                    let _ = server.infer("mnist_mlp_1", img);
                }
            });
        }
    });
    let rps = requests as f64 / t0.elapsed().as_secs_f64();
    let m = server.metrics();
    println!(
        "policy max_batch={:<3} delay={:>5}us clients={clients:<3} -> {:>9.0} img/s  {}",
        policy.max_batch,
        policy.max_delay.as_micros(),
        rps,
        m.summary()
    );
    server.shutdown();
    Ok(rps)
}

/// Raw PJRT execute throughput (img/s) for the overhead comparison.
#[cfg(feature = "pjrt")]
fn raw_roofline(man: &Manifest, bench: &Bench) -> anyhow::Result<f64> {
    use circnn::runtime::engine::{literal_f32, Engine};
    let engine = Engine::cpu()?;
    let e = man.model("mnist_mlp_1")?;
    let a = e.artifacts.iter().max_by_key(|a| a.batch).unwrap();
    let exe = engine.load(man.path_of(&a.file))?;
    let ds = data::dataset(&e.dataset).unwrap();
    let (xs, _) = data::batch(&ds, 0, a.batch, true);
    let lit = literal_f32(&xs, &a.input_shape)?;
    let raw = bench.run("raw_execute/b64", a.batch as u64, || {
        exe.run1(std::slice::from_ref(&lit)).unwrap()
    });
    Ok(raw.throughput())
}

#[cfg(not(feature = "pjrt"))]
fn raw_roofline(_man: &Manifest, _bench: &Bench) -> anyhow::Result<f64> {
    println!("(no pjrt feature: native backend, roofline comparison skipped)");
    Ok(f64::NAN)
}

fn main() -> anyhow::Result<()> {
    let bench = Bench::default();

    println!("== pure-logic hot paths ==");
    let policy = BatchPolicy::default();
    bench.run("batcher/push_drain_64", 64, || {
        let mut q = BatchQueue::new(policy);
        let now = Instant::now();
        for i in 0..64u32 {
            let _ = q.push(i, now);
        }
        q.drain_batch()
    });

    if let Ok(man) = Manifest::load(Manifest::default_dir()) {
        let router = Router::from_manifest(&man);
        let (img, _) = data::sample(&data::MNIST_S, 0);
        bench.run("router/validate", 1, || {
            router.validate("mnist_mlp_1", &img).unwrap()
        });

        let roofline = raw_roofline(&man, &bench)?;

        println!("\n== end-to-end serving (coordinator) ==");
        let mut best = 0.0f64;
        for (max_batch, delay_us, clients) in
            [(1usize, 200u64, 8usize), (8, 500, 8), (64, 2000, 32), (64, 2000, 64)]
        {
            let rps = serve_throughput(
                BatchPolicy {
                    max_batch,
                    max_delay: Duration::from_micros(delay_us),
                    max_queue: 16384,
                },
                clients,
                8192,
            )?;
            best = best.max(rps);
        }
        if roofline.is_finite() {
            println!(
                "\nbest coordinator throughput = {:.1}% of raw roofline {roofline:.0} img/s",
                100.0 * best / roofline
            );
        }
    } else {
        eprintln!("artifacts missing: serving benches skipped (run `make artifacts`)");
    }
    Ok(())
}
