//! Native-engine parity: the pure-Rust block-circulant substrate
//! (`circnn::native`, no PJRT/XLA/Python) must compute the same function as
//! the AOT HLO artifacts for every registry model — the claim that the
//! FPGA simulator's cycle accounting walks a datapath that produces the
//! right numbers.
//!
//! Comparing the two substrates requires both, so this target only exists
//! under the `pjrt` feature (`cargo test --features pjrt`).

#![cfg(feature = "pjrt")]

use std::sync::Mutex;

use circnn::data;
use circnn::models;
use circnn::native::NativeModel;
use circnn::runtime::engine::{argmax_rows, literal_f32, Engine};
use circnn::runtime::Manifest;

static PJRT_LOCK: Mutex<()> = Mutex::new(());

fn manifest() -> Option<Manifest> {
    match Manifest::load(Manifest::default_dir()) {
        Ok(m) => Some(m),
        Err(_) => {
            eprintln!("SKIP: artifacts missing (run `make artifacts`)");
            None
        }
    }
}

fn params_path(man: &Manifest, name: &str) -> std::path::PathBuf {
    man.dir.join("params").join(format!("{name}.npz"))
}

#[test]
fn native_matches_pjrt_on_every_model() {
    let _g = PJRT_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let Some(man) = manifest() else { return };
    let engine = Engine::cpu().expect("PJRT");
    for m in models::registry() {
        let e = man.model(m.name).unwrap();
        let a = e.artifact_for_batch(1).expect("b1 artifact");
        let ds = data::dataset(&e.dataset).unwrap();
        let native = NativeModel::load(&m, params_path(&man, m.name), Some(12))
            .unwrap_or_else(|err| panic!("{}: native load failed: {err:#}", m.name));
        let exe = engine.load(man.path_of(&a.file)).unwrap();
        let (h, w, c) = m.input;
        let mut label_matches = 0;
        const N: u64 = 16;
        for i in 0..N {
            let (img, _) = data::sample(&ds, i);
            let pjrt = exe
                .run1(&[literal_f32(&img, &a.input_shape).unwrap()])
                .unwrap()
                .to_vec::<f32>()
                .unwrap();
            let nat = native.forward(&img, 1, h, w, c);
            assert_eq!(nat.len(), pjrt.len(), "{}: logit arity", m.name);
            for (t, (x, y)) in nat.iter().zip(&pjrt).enumerate() {
                assert!(
                    (x - y).abs() <= 2e-2 + 2e-2 * y.abs().max(x.abs()),
                    "{}: image {i} logit {t}: native {x} vs pjrt {y}",
                    m.name
                );
            }
            if argmax_rows(&nat, nat.len())[0] == argmax_rows(&pjrt, pjrt.len())[0] {
                label_matches += 1;
            }
        }
        assert_eq!(label_matches, N, "{}: native/pjrt labels must agree", m.name);
        println!("{}: native == pjrt on {N} images", m.name);
    }
}

#[test]
fn native_batch_equals_per_image() {
    let Some(man) = manifest() else { return };
    let m = models::by_name("mnist_mlp_1").unwrap();
    let native = NativeModel::load(&m, params_path(&man, m.name), Some(12)).unwrap();
    let ds = data::dataset(m.dataset).unwrap();
    let (h, w, c) = m.input;
    let (xs, _) = data::batch(&ds, 0, 8, true);
    let batched = native.forward(&xs, 8, h, w, c);
    let classes = batched.len() / 8;
    for i in 0..8usize {
        let (img, _) = data::sample(&ds, (data::TEST_INDEX_OFFSET as usize + i) as u64);
        let single = native.forward(&img, 1, h, w, c);
        // per-tensor activation quantization sees a different max over a
        // batch than over one image, so allow grid-step noise but demand
        // identical labels
        for (t, (x, y)) in single.iter().zip(&batched[i * classes..]).enumerate() {
            assert!(
                (x - y).abs() <= 3e-2 + 3e-2 * y.abs().max(x.abs()),
                "image {i} logit {t}: single {x} vs batched {y}"
            );
        }
        assert_eq!(
            argmax_rows(&single, classes)[0],
            argmax_rows(&batched[i * classes..(i + 1) * classes], classes)[0]
        );
    }
}

#[test]
fn native_accuracy_matches_manifest() {
    let Some(man) = manifest() else { return };
    for name in ["mnist_mlp_1", "svhn_cnn"] {
        let m = models::by_name(name).unwrap();
        let e = man.model(name).unwrap();
        let native = NativeModel::load(&m, params_path(&man, name), Some(12)).unwrap();
        let ds = data::dataset(m.dataset).unwrap();
        let (h, w, c) = m.input;
        let (xs, ys) = data::batch(&ds, 0, 256, true);
        let labels = native.classify(&xs, 256, h, w, c);
        let acc = labels.iter().zip(&ys).filter(|(a, b)| a == b).count() as f64 / 256.0;
        let recorded = e.accuracy.circulant_12bit;
        assert!(
            (acc - recorded).abs() < 0.08,
            "{name}: native accuracy {acc:.3} vs manifest 12-bit {recorded:.3}"
        );
        println!("{name}: native accuracy {acc:.3} (manifest {recorded:.3})");
    }
}

#[test]
fn native_f32_vs_quantized_degradation_is_small() {
    let Some(man) = manifest() else { return };
    let m = models::by_name("mnist_mlp_1").unwrap();
    let path = params_path(&man, m.name);
    let q12 = NativeModel::load(&m, &path, Some(12)).unwrap();
    let f32_ = NativeModel::load(&m, &path, None).unwrap();
    let ds = data::dataset(m.dataset).unwrap();
    let (h, w, c) = m.input;
    let (xs, ys) = data::batch(&ds, 0, 256, true);
    let acc = |labels: Vec<u32>| labels.iter().zip(&ys).filter(|(a, b)| a == b).count();
    let a12 = acc(q12.classify(&xs, 256, h, w, c));
    let af = acc(f32_.classify(&xs, 256, h, w, c));
    assert!(
        (af as i64 - a12 as i64).abs() <= 256 * 5 / 100,
        "12-bit quantization cost more than 5% accuracy ({af} vs {a12} / 256)"
    );
}

#[test]
fn native_load_failure_modes() {
    let Some(man) = manifest() else { return };
    let m = models::by_name("mnist_mlp_1").unwrap();
    // missing archive
    assert!(NativeModel::load(&m, man.dir.join("params/nope.npz"), Some(12)).is_err());
    // wrong model's parameters (shape mismatch caught at load, not at run)
    let lenet = models::by_name("mnist_lenet").unwrap();
    let err = NativeModel::load(&lenet, params_path(&man, "mnist_mlp_1"), Some(12));
    assert!(err.is_err(), "mismatched archive must be rejected at load time");
}
