//! PJRT runtime round-trip tests: every AOT artifact must load, compile and
//! execute from Rust with numerics consistent across batch sizes and across
//! the Pallas-kernel / plain-JAX lowering variants, and the exported
//! train-step must actually learn (the FFT-domain backward pass of
//! Eqns. 2-3, run with Python completely out of the loop).
//!
//! Tests share one engine behind a mutex — PJRT CPU clients are heavy and
//! the default test parallelism would otherwise compile the same HLO
//! modules several times over.
//!
//! The whole target needs the PJRT runtime, so it only exists under the
//! `pjrt` feature (`cargo test --features pjrt`).

#![cfg(feature = "pjrt")]

use std::sync::Mutex;

use circnn::data;
use circnn::runtime::engine::{argmax_rows, literal_f32, literal_i32, Engine};
use circnn::runtime::Manifest;

static PJRT_LOCK: Mutex<()> = Mutex::new(());

fn setup() -> Option<(Manifest, Engine)> {
    let man = match Manifest::load(Manifest::default_dir()) {
        Ok(m) => m,
        Err(_) => {
            eprintln!("SKIP: artifacts missing (run `make artifacts`)");
            return None;
        }
    };
    let engine = Engine::cpu().expect("PJRT CPU client");
    Some((man, engine))
}

/// Run a `(batch, h, w, c) -> (batch, classes)` artifact on `count` test
/// images; returns (logits, labels).
fn run_batch(
    engine: &Engine,
    man: &Manifest,
    model: &str,
    file: &str,
    input_shape: &[usize],
    start: u64,
) -> (Vec<f32>, Vec<u32>) {
    let entry = man.model(model).unwrap();
    let ds = data::dataset(&entry.dataset).unwrap();
    let batch = input_shape[0];
    let (xs, ys) = data::batch(&ds, start, batch, true);
    let exe = engine.load(man.path_of(file)).expect("load+compile");
    let lit = literal_f32(&xs, input_shape).unwrap();
    let out = exe.run1(&[lit]).expect("execute");
    (out.to_vec::<f32>().unwrap(), ys)
}

#[test]
fn every_artifact_loads_and_runs() {
    let _g = PJRT_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let Some((man, engine)) = setup() else { return };
    for e in &man.models {
        for a in &e.artifacts {
            let (logits, _) =
                run_batch(&engine, &man, &e.name, &a.file, &a.input_shape, 0);
            let want: usize = a.output_shape.iter().product();
            assert_eq!(logits.len(), want, "{}: output size", a.file);
            assert!(
                logits.iter().all(|v| v.is_finite()),
                "{}: non-finite logits",
                a.file
            );
        }
    }
}

#[test]
fn batch1_and_batch64_agree() {
    let _g = PJRT_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let Some((man, engine)) = setup() else { return };
    for e in &man.models {
        let Some(a1) = e.artifact_for_batch(1) else { continue };
        let Some(a64) = e.artifacts.iter().find(|a| a.batch > 1) else { continue };
        let (l64, _) = run_batch(&engine, &man, &e.name, &a64.file, &a64.input_shape, 0);
        let classes = *a64.output_shape.last().unwrap();
        // row 0 of the big batch == the batch-1 run of image 0
        let (l1, _) = run_batch(&engine, &man, &e.name, &a1.file, &a1.input_shape, 0);
        // different batch variants compile to different fusions; the deep
        // WRN accumulates visible f32 reassociation noise, so require close
        // logits *and* an identical predicted label
        for c in 0..classes {
            let (a, b) = (l1[c], l64[c]);
            assert!(
                (a - b).abs() <= 5e-2 + 5e-2 * b.abs().max(a.abs()),
                "{}: batch-1 vs batch-{} logit {c}: {a} vs {b}",
                e.name,
                a64.batch
            );
        }
        assert_eq!(
            argmax_rows(&l1, classes)[0],
            argmax_rows(&l64[..classes], classes)[0],
            "{}: batch variants predict different labels",
            e.name
        );
    }
}

#[test]
fn pallas_variant_matches_plain_lowering() {
    // Layer-1 check at the system level: the Pallas-kernel-backed artifact
    // (interpret=True lowering) and the plain jnp lowering of the same
    // trained model must produce the same labels and close logits.
    let _g = PJRT_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let Some((man, engine)) = setup() else { return };
    let mut checked = 0;
    for e in &man.models {
        for (a, ap) in e.artifacts.iter().zip(&e.artifacts_pallas) {
            assert_eq!(a.batch, ap.batch);
            let (plain, _) = run_batch(&engine, &man, &e.name, &a.file, &a.input_shape, 7);
            let (pallas, _) = run_batch(&engine, &man, &e.name, &ap.file, &ap.input_shape, 7);
            assert_eq!(plain.len(), pallas.len());
            for (i, (x, y)) in plain.iter().zip(&pallas).enumerate() {
                assert!(
                    (x - y).abs() <= 1e-2 + 1e-2 * y.abs().max(x.abs()),
                    "{}: pallas/plain logit {i} diverged: {x} vs {y}",
                    e.name
                );
            }
            checked += 1;
        }
    }
    assert!(checked > 0, "no pallas artifact pairs found");
}

#[test]
fn execution_is_deterministic() {
    let _g = PJRT_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let Some((man, engine)) = setup() else { return };
    let e = man.model("mnist_mlp_1").unwrap();
    let a = e.artifacts.iter().max_by_key(|a| a.batch).unwrap();
    let (l1, _) = run_batch(&engine, &man, &e.name, &a.file, &a.input_shape, 3);
    let (l2, _) = run_batch(&engine, &man, &e.name, &a.file, &a.input_shape, 3);
    assert_eq!(l1, l2, "same input must give bit-identical logits");
}

#[test]
fn engine_caches_compiled_executables() {
    let _g = PJRT_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let Some((man, engine)) = setup() else { return };
    let e = man.model("mnist_mlp_1").unwrap();
    let path = man.path_of(&e.artifacts[0].file);
    assert_eq!(engine.cached(), 0);
    let m1 = engine.load(&path).unwrap();
    assert_eq!(engine.cached(), 1);
    let m2 = engine.load(&path).unwrap();
    assert_eq!(engine.cached(), 1, "second load must hit the cache");
    assert!(std::rc::Rc::ptr_eq(&m1, &m2));
    assert!(engine.load("artifacts/definitely_missing.hlo.txt").is_err());
}

#[test]
fn artifact_accuracy_matches_manifest() {
    // the compiled artifact must reproduce (within sampling noise of a
    // 256-image slice) the test accuracy the Python side recorded for the
    // same deterministic test split
    let _g = PJRT_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let Some((man, engine)) = setup() else { return };
    let e = man.model("mnist_mlp_1").unwrap();
    let a = e.artifacts.iter().max_by_key(|x| x.batch).unwrap();
    let classes = *a.output_shape.last().unwrap();
    let (mut correct, mut total) = (0usize, 0usize);
    for chunk in 0..(256 / a.batch).max(1) {
        let (logits, ys) = run_batch(
            &engine,
            &man,
            &e.name,
            &a.file,
            &a.input_shape,
            (chunk * a.batch) as u64,
        );
        for (row, &y) in argmax_rows(&logits, classes).iter().zip(&ys) {
            total += 1;
            if *row == y {
                correct += 1;
            }
        }
    }
    let acc = correct as f64 / total as f64;
    let recorded = e.accuracy.circulant_f32;
    assert!(
        (acc - recorded).abs() < 0.08,
        "measured accuracy {acc:.3} vs manifest {recorded:.3} — artifact and \
         training disagree beyond sampling noise"
    );
}

#[test]
fn train_step_reduces_loss_from_rust() {
    // E2E (training half), abbreviated: 64 steps must visibly reduce loss.
    // examples/train_loop.rs runs the full 300-step curve (loss halves).
    let _g = PJRT_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let Some((man, engine)) = setup() else { return };
    let e = man.model("mnist_mlp_1").unwrap();
    let tr = e.training.as_ref().expect("training artifacts");
    let ds = data::dataset(&e.dataset).unwrap();
    let init = engine.load(man.path_of(&tr.init_file)).unwrap();
    let step = engine.load(man.path_of(&tr.step_file)).unwrap();

    let mut state = init.run(&[]).unwrap();
    let n_params = state.len();
    let (mut first, mut last) = (f32::NAN, f32::NAN);
    for s in 0..64u64 {
        let (xs, ys) = data::batch(&ds, s * tr.batch as u64, tr.batch, false);
        let x = literal_f32(&xs, &[tr.batch, 28, 28, 1]).unwrap();
        let y = literal_i32(&ys.iter().map(|&v| v as i32).collect::<Vec<_>>(), &[tr.batch])
            .unwrap();
        let mut args = std::mem::take(&mut state);
        args.push(x);
        args.push(y);
        let mut out = step.run(&args).unwrap();
        let loss = out[tr.loss_index].to_vec::<f32>().unwrap()[0];
        assert!(loss.is_finite(), "loss diverged at step {s}");
        out.truncate(tr.loss_index);
        assert_eq!(out.len(), n_params, "state arity must be stable");
        state = out;
        if s == 0 {
            first = loss;
        }
        last = loss;
    }
    assert!(
        last < first * 0.88,
        "64 train steps: loss {first:.4} -> {last:.4} did not drop 12%"
    );
}
