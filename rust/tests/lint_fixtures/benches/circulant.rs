//! Fixture bench: seeded `bench-key` contract violations next to one
//! well-formed pair.  The fixture workflow (`../ci.yml`) gates `_ratio_`
//! keys and has no `_speedup_` gate, so the gated key below is flagged.

fn main() {
    let rows: Vec<(String, f64)> = vec![
        ("conv_speedup_k8".to_string(), 1.5), // LINT-EXPECT: bench-key
        ("mac_ratio_k8".to_string(), 0.8),
        ("fast_speedup8".to_string(), 2.0), // LINT-EXPECT: bench-key
        ("mixed_speedup_ratio_k4".to_string(), 1.0), // LINT-EXPECT: bench-key
    ];
    for (k, v) in rows {
        println!("{k} {v}");
    }
}
