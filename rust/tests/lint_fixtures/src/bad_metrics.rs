//! Seeded `metric-name` violations: a dynamic (non-literal) name, a
//! non-snake_case name, a second registering site for an existing name,
//! and a `_hits` counter without its `_misses` twin — next to negative
//! controls (clean literals, a wrapped call, an allowed re-read, and a
//! test-region registration) that must stay quiet.

pub fn dynamic_name(r: &Registry, suffix: &str) -> Counter {
    r.counter(&format!("requests_{suffix}")) // LINT-EXPECT: metric-name
}

pub fn shouting_name(r: &Registry) -> Gauge {
    r.gauge("QueueDepth") // LINT-EXPECT: metric-name
}

pub fn first_site(r: &Registry) -> Counter {
    r.counter("fixture_dup_total")
}

pub fn second_site(r: &Registry) -> Counter {
    r.counter("fixture_dup_total") // LINT-EXPECT: metric-name
}

pub fn lonely_hits(r: &Registry) -> Counter {
    r.counter("fixture_cache_hits") // LINT-EXPECT: metric-name
}

pub fn undocumented(r: &Registry) -> Counter {
    r.counter("fixture_undocumented_total") // LINT-EXPECT: docs-fresh
}

// --- negative controls ---------------------------------------------------

pub fn clean_sites(r: &Registry) {
    let _ = r.histogram("fixture_wait_us");
    let _ = r.gauge_with(
        "fixture_depth_permille",
        &[("model", "m".to_string())],
    );
    // lint:allow(metric-name): deliberate re-read of the first site's handle
    let _ = r.counter("fixture_dup_total");
}

#[cfg(test)]
mod tests {
    #[test]
    fn test_code_is_exempt() {
        let r = Registry::new();
        let _ = r.counter("AnythingGoesHere");
    }
}
