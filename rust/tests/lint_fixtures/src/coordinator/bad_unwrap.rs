//! Seeded `request-unwrap` violations on the fixture's request path, with
//! the two sanctioned escapes (lock-poisoning recovery, `lint:allow`) as
//! negative controls.

pub fn respond(rx: std::sync::mpsc::Receiver<u8>) -> u8 {
    rx.recv().unwrap() // LINT-EXPECT: request-unwrap
}

pub fn label(x: Option<u8>) -> u8 {
    x.expect("fixture label") // LINT-EXPECT: request-unwrap
}

pub fn poison_recovery(m: &std::sync::Mutex<u8>) -> u8 {
    *m.lock().unwrap()
}

pub fn start_invariant(x: Option<u8>) -> u8 {
    // lint:allow(unwrap): construction-time fixture invariant
    x.expect("fixture start")
}

#[cfg(test)]
mod tests {
    #[test]
    fn test_code_may_unwrap() {
        assert_eq!(Some(1u8).unwrap(), 1);
    }
}
