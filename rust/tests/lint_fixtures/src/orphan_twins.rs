//! Seeded `dead-oracle` violation: `walk_serial` twins `walk` but no test
//! references it.  `probe_via_full` is the live negative control, and
//! `set_serial` shows the setter exemption (no `fn set` exists).

pub fn walk(xs: &[f32]) -> f32 {
    xs.iter().sum()
}

pub fn walk_serial(xs: &[f32]) -> f32 { // LINT-EXPECT: dead-oracle
    xs.iter().sum()
}

pub fn probe(xs: &[f32]) -> f32 {
    xs.iter().sum()
}

pub fn probe_via_full(xs: &[f32]) -> f32 {
    xs.iter().sum()
}

pub fn set_serial(_on: bool) {}

#[cfg(test)]
mod tests {
    #[test]
    fn probe_via_full_stays_pinned() {
        assert_eq!(super::probe(&[1.0, 2.0]), super::probe_via_full(&[1.0, 2.0]));
    }
}
