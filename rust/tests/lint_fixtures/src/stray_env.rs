//! Seeded `env-knob` violations: a raw `env::var` read outside the
//! registry file, and a `CIRCNN_*` literal the registry never lists.

pub fn raw_read() -> bool {
    std::env::var("CIRCNN_FIXTURE_OK").is_ok() // LINT-EXPECT: env-knob
}

pub fn rogue_name() -> &'static str {
    "CIRCNN_FIXTURE_ROGUE" // LINT-EXPECT: env-knob
}

pub fn registered_read() -> bool {
    crate::circulant::sched::env_flag("CIRCNN_FIXTURE_OK")
}

pub fn allowed_raw() -> bool {
    // lint:allow(env): fixture-pinned escape hatch
    std::env::var("CIRCNN_FIXTURE_OK").is_ok()
}
