//! Seeded `unbounded-channel` + `request-unwrap` violations in the
//! fixture pipeline, with bounded and annotated channels staying quiet.

pub fn leak() {
    let (tx, rx) = std::sync::mpsc::channel::<u8>(); // LINT-EXPECT: unbounded-channel
    tx.send(1).expect("send"); // LINT-EXPECT: request-unwrap
    let _ = rx;
}

pub fn bounded() {
    let (tx, rx) = std::sync::mpsc::sync_channel::<u8>(4);
    let _ = (tx, rx);
    // lint:allow(channel): fixture-pinned escape hatch
    let (_tx2, _rx2) = std::sync::mpsc::channel::<u8>();
}
