//! Seeded `safety-comment` violation, with a justified site as the
//! negative control.

pub fn naked(p: *const u8) -> u8 {
    unsafe { p.read() } // LINT-EXPECT: safety-comment
}

pub fn justified(p: *const u8) -> u8 {
    // SAFETY: fixture caller passes a valid, aligned pointer
    unsafe { p.read() }
}

pub fn justified_through_attributes(p: *const u8) -> u8 {
    // SAFETY: the justification may sit above attribute lines
    #[allow(clippy::let_and_return)]
    let v = unsafe { p.read() };
    v
}
