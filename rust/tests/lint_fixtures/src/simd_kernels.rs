//! Seeded `simd-oracle` violations: a kernel with no oracle at all, a
//! kernel whose oracle exists but is never exercised by a test, and (as
//! the negative control) a fully pinned kernel/oracle/dispatcher trio.

// SAFETY: fixture kernel; the dispatcher checks avx2 at runtime
#[target_feature(enable = "avx2")]
pub unsafe fn mac_avx2(xs: &mut [f32]) {} // LINT-EXPECT: simd-oracle

// SAFETY: fixture kernel; the dispatcher checks neon at runtime
#[target_feature(enable = "neon")]
pub unsafe fn frob_neon(xs: &mut [f32]) {} // LINT-EXPECT: simd-oracle

pub fn frob_scalar(_xs: &mut [f32]) {}

// SAFETY: fixture kernel; the dispatcher checks avx2 at runtime
#[target_feature(enable = "avx2")]
pub unsafe fn dot_avx2(_xs: &[f32]) -> f32 {
    0.0
}

pub fn dot_scalar(xs: &[f32]) -> f32 {
    xs.iter().sum()
}

pub fn dot(xs: &[f32]) -> f32 {
    dot_scalar(xs)
}

#[cfg(test)]
mod tests {
    #[test]
    fn dot_simd_matches_scalar_oracle() {
        // the dispatcher `dot` and the oracle `dot_scalar` co-occur here,
        // which is what keeps `dot_avx2` pinned
        let xs = [1.0f32, 2.0];
        assert_eq!(super::dot(&xs), super::dot_scalar(&xs));
    }
}
