//! Seeded violations on the observability plane: the snapshot-ticker
//! knob read raw instead of through the sched helpers, and a watermark
//! gauge the operator's guide never mentions — next to negative controls
//! (the helper-routed knob read and documented snapshot metrics) that
//! must stay quiet.

pub fn snap_period_raw() -> u64 {
    std::env::var("CIRCNN_SNAP_MS") // LINT-EXPECT: env-knob
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(100)
}

pub fn undocumented_watermark(r: &Registry) -> Gauge {
    r.gauge("fixture_queue_depth_watermark") // LINT-EXPECT: docs-fresh
}

// --- negative controls ---------------------------------------------------

pub fn snap_period_registered() -> bool {
    crate::circulant::sched::env_flag("CIRCNN_SNAP_MS")
}

pub fn documented_snapshot_metrics(r: &Registry) {
    let _ = r.counter("fixture_snap_samples_total");
    let _ = r.gauge("fixture_inflight_watermark");
}
