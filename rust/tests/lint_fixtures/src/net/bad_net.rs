//! Seeded `request-unwrap` + `unbounded-channel` violations inside the
//! TCP front-end scope (`src/net/`), pinning that the request-path
//! hygiene rules extend beyond `coordinator`/`pipeline` — next to
//! negative controls (a poisoning-aware lock, an annotated
//! construction-time invariant, and a bounded channel) that must stay
//! quiet.

pub fn reader_loop(rx: Receiver<Frame>) {
    let frame = rx.recv().unwrap(); // LINT-EXPECT: request-unwrap
    handle(frame);
}

pub fn writer_queue() {
    let (tx, rx) = mpsc::channel(); // LINT-EXPECT: unbounded-channel
    drop((tx, rx));
}

// --- negative controls ---------------------------------------------------

pub fn open_connections(conns: &Mutex<usize>) -> usize {
    *conns.lock().unwrap()
}

pub fn listener(l: &Option<Listener>) -> &Listener {
    // lint:allow(unwrap): the listener exists until shutdown consumes it
    l.as_ref().unwrap()
}

pub fn reply_queue() {
    let (tx, rx) = mpsc::sync_channel::<u8>(8);
    drop((tx, rx));
}
