//! Fixture knob registry — the one file where raw `env::var` is legal.

pub struct Knob {
    pub name: &'static str,
    pub role: &'static str,
}

pub const KNOBS: &[Knob] = &[
    Knob { name: "CIRCNN_FIXTURE_OK", role: "fixture knob" },
    Knob { name: "CIRCNN_FIXTURE_UNDOC", role: "absent from the guide" }, // LINT-EXPECT: docs-fresh
    Knob { name: "CIRCNN_SNAP_MS", role: "snapshot-ticker period" },
];

pub fn env_flag(name: &str) -> bool {
    std::env::var(name).map(|v| !v.is_empty() && v != "0").unwrap_or(false)
}
