//! Cross-module integration tests: the experiment generators (T1, F3, F6,
//! A1, S1, AB1-3) must reproduce the paper's *shape* — who wins, by roughly
//! what factor — and the Python↔Rust contracts (manifest accounting, dataset
//! checksums) must hold bit-for-bit.
//!
//! Tests that need `artifacts/manifest.json` skip with a notice when it is
//! absent (run `make artifacts`); everything else runs standalone.

use circnn::baselines::{analog as analog_corpus, dense_fpga, reference_fpga, truenorth};
use circnn::data;
use circnn::experiments::{ablations, analog, complexity, fig3, fig6, table1};
use circnn::fpga::device::{self, CYCLONE_V, KINTEX_7};
use circnn::fpga::memory::memory_report;
use circnn::fpga::report::DesignReport;
use circnn::fpga::schedule::{simulate, ScheduleConfig};
use circnn::models;
use circnn::runtime::Manifest;

fn manifest() -> Option<Manifest> {
    match Manifest::load(Manifest::default_dir()) {
        Ok(m) => Some(m),
        Err(_) => {
            eprintln!("SKIP: artifacts/manifest.json missing (run `make artifacts`)");
            None
        }
    }
}

// ---------------------------------------------------------------------------
// T1 — Table 1
// ---------------------------------------------------------------------------

#[test]
fn table1_has_all_rows_of_the_paper() {
    let rows = table1::rows(None);
    assert_eq!(rows.iter().filter(|r| r.proposed).count(), 6, "6 proposed designs");
    assert_eq!(
        rows.iter().filter(|r| r.platform.contains("truenorth")).count(),
        4,
        "4 TrueNorth baseline rows"
    );
    assert_eq!(
        rows.iter().filter(|r| r.platform.contains("ref fpga")).count(),
        4,
        "3 FINN rows + Alemdar"
    );
    for r in &rows {
        assert!(r.kfps > 0.0 && r.kfps_per_w > 0.0, "{}: non-positive metric", r.name);
        assert!((0.0..=1.0).contains(&r.accuracy), "{}: accuracy {}", r.name, r.accuracy);
    }
}

#[test]
fn table1_headline_ratios_hold() {
    // the paper's abstract: >=152x speedup and >=71x energy efficiency vs
    // TrueNorth, >=31x energy efficiency vs the best reference FPGA — all
    // at matched accuracy.  The regenerated table must preserve the shape.
    let rows = table1::rows(manifest().as_ref());
    let h = table1::headline(&rows);
    assert!(
        h.speedup_vs_truenorth >= 152.0,
        "speedup vs TrueNorth {:.0}x < paper's 152x",
        h.speedup_vs_truenorth
    );
    assert!(
        h.energy_gain_vs_truenorth >= 71.0,
        "energy gain vs TrueNorth {:.0}x < paper's 71x",
        h.energy_gain_vs_truenorth
    );
    assert!(
        h.energy_gain_vs_reference_fpga >= 31.0,
        "energy gain vs reference FPGA {:.0}x < paper's 31x",
        h.energy_gain_vs_reference_fpga
    );
}

#[test]
fn truenorth_model_reproduces_published_rows() {
    // Table 1's baseline rows are regenerated from the tick/core model, not
    // transcribed; they must land on the published numbers.
    let rows = truenorth::table1_rows();
    let mnist_high = rows.iter().find(|r| r.dataset == "mnist_s" && r.accuracy > 0.98).unwrap();
    assert!((mnist_high.kfps() - 1.0).abs() < 0.1, "MNIST 99% row is ~1.0 kFPS");
    let svhn = rows.iter().find(|r| r.dataset == "svhn_s").unwrap();
    assert!((svhn.kfps() - 2.53).abs() < 0.6, "SVHN row is ~2.53 kFPS, got {}", svhn.kfps());
    let cifar = rows.iter().find(|r| r.dataset == "cifar_s").unwrap();
    assert!((cifar.kfps() - 1.25).abs() < 0.3, "CIFAR row is ~1.25 kFPS");
    // efficiency comes out of the first-principles power model; within 2x
    // of the published 6.11 kFPS/W (same tolerance as the module's tests)
    let eff = cifar.kfps_per_w();
    assert!(
        eff > 6.11 / 2.0 && eff < 6.11 * 2.0,
        "CIFAR efficiency ~6.11 kFPS/W, got {eff:.2}"
    );
}

#[test]
fn reference_fpga_model_reproduces_finn_rows() {
    let rows = reference_fpga::table1_rows();
    let finn_mnist = rows.iter().find(|r| r.name.contains("finn") && r.dataset == "mnist_s");
    let finn_mnist = finn_mnist.expect("FINN MNIST row present");
    assert!(
        (finn_mnist.kfps() - 12_300.0).abs() / 12_300.0 < 0.3,
        "FINN MNIST ~1.23e4 kFPS, got {:.0}",
        finn_mnist.kfps()
    );
    assert!(
        (finn_mnist.kfps_per_w() - 1693.0).abs() / 1693.0 < 0.3,
        "FINN MNIST ~1693 kFPS/W, got {:.0}",
        finn_mnist.kfps_per_w()
    );
}

#[test]
fn table1_proposed_beats_dense_fpga_baseline() {
    // the compression is the point: the same model without block-circulant
    // structure must be slower and less efficient on the same device
    for m in models::registry() {
        let cfg = ScheduleConfig::auto_for(&m, &CYCLONE_V);
        let circ = DesignReport::build(&m, &CYCLONE_V, &cfg);
        let dense = dense_fpga::dense_design(&m, &CYCLONE_V, &cfg);
        assert!(
            circ.kfps > dense.kfps,
            "{}: circulant {:.1} kFPS not faster than dense {:.1}",
            m.name,
            circ.kfps,
            dense.kfps
        );
        assert!(
            circ.kfps_per_w > dense.kfps_per_w,
            "{}: circulant must be more energy-efficient than dense",
            m.name
        );
    }
}

// ---------------------------------------------------------------------------
// F3 — Fig. 3 storage reduction
// ---------------------------------------------------------------------------

#[test]
fn fig3_reductions_are_significant_and_consistent() {
    let bars = fig3::bars();
    assert_eq!(bars.len(), 6);
    for b in &bars {
        assert!(b.circ_bytes < b.dense_bytes, "{}: no compression", b.model);
        assert!(
            b.reduction > 10.0,
            "{}: total reduction {:.1}x too small for Fig. 3's shape",
            b.model,
            b.reduction
        );
        // total = params x quantization; quantization is 32/12
        let quant_factor = b.reduction / b.param_reduction;
        assert!(
            (quant_factor - 32.0 / 12.0).abs() < 0.01,
            "{}: quantization factor {:.3} != 32/12",
            b.model,
            quant_factor
        );
    }
}

#[test]
fn fig3_matches_manifest_storage_accounting() {
    let Some(man) = manifest() else { return };
    for b in fig3::bars() {
        let e = man.model(&b.model).expect("manifest entry");
        assert!(
            (e.storage_reduction - b.reduction).abs() / b.reduction < 1e-6,
            "{}: Rust reduction {:.3} != Python manifest {:.3}",
            b.model,
            b.reduction,
            e.storage_reduction
        );
    }
}

// ---------------------------------------------------------------------------
// F6 — Fig. 6 GOPS vs GOPS/W
// ---------------------------------------------------------------------------

#[test]
fn fig6_proposed_dominates_reference_corpus() {
    let pts = fig6::points();
    assert!(pts.iter().filter(|p| p.proposed).count() >= 12, "6 models x 2 devices");
    assert!(pts.iter().filter(|p| !p.proposed).count() >= 6, "reference corpus");
    // every low-power (CyClone V) proposed point must sit above every
    // reference point in efficiency — Fig. 6's visual shape
    let best_ref = pts
        .iter()
        .filter(|p| !p.proposed)
        .map(|p| p.gops_per_w)
        .fold(0.0f64, f64::max);
    for p in pts.iter().filter(|p| p.proposed && p.name.contains("cyclone")) {
        assert!(
            p.gops_per_w > best_ref,
            "{}: {:.0} GOPS/W <= best reference {:.0}",
            p.name,
            p.gops_per_w,
            best_ref
        );
    }
    let gain = fig6::min_efficiency_gain();
    assert!(
        gain >= 5.0,
        "minimum efficiency gain over the reference corpus collapsed: {gain:.1}x \
         (the paper's >=31x-vs-FINN headline is asserted at matched accuracy in \
         table1_headline_ratios_hold)"
    );
    // the flagship MLP design must reach the paper's TOPS/W class
    let flagship = pts
        .iter()
        .find(|p| p.name == "proposed_mnist_mlp_1_cyclone_v_5cea9")
        .unwrap();
    assert!(
        flagship.gops_per_w > 5140.0,
        "flagship efficiency {:.0} GOPS/W below the paper's 5.14 TOPS/W claim",
        flagship.gops_per_w
    );
}

#[test]
fn fig6_reference_corpus_in_published_envelope() {
    // "typical (equivalent) energy efficiency range is from 7 GOPS/W to
    // less than 1 TOPS/W" (related-work section; the corpus also carries
    // the early CNP'09 point well below that band)
    for p in fig6::points().iter().filter(|p| !p.proposed) {
        assert!(
            p.gops_per_w > 0.0 && p.gops_per_w < 1000.0,
            "{}: {} GOPS/W outside the published <1 TOPS/W envelope",
            p.name,
            p.gops_per_w
        );
    }
}

// ---------------------------------------------------------------------------
// A1 — analog / emerging-device comparison
// ---------------------------------------------------------------------------

#[test]
fn analog_comparison_shape_holds() {
    let c = analog::compare();
    // paper: ~5.14 TOPS/W; beats ISAAC (380.7), PipeLayer (142.9),
    // Lu et al. (1040 GOPS/W)
    assert!(
        c.proposed_gops_per_w_cyclone > 1040.0,
        "proposed {:.0} GOPS/W must beat the best analog point (1.04 TOPS/W)",
        c.proposed_gops_per_w_cyclone
    );
    assert!(c.min_efficiency_gain > 1.0);
    // paper: 11.6 ns/image CyClone V vs ~1 us analog -> ~2 orders
    assert!(
        c.min_latency_gain > 10.0,
        "latency gain vs ~1us analog inference should be >10x, got {:.1}",
        c.min_latency_gain
    );
    assert!(
        c.proposed_ns_per_image_kintex < c.proposed_ns_per_image_cyclone,
        "Kintex-7 must be faster than CyClone V"
    );
}

#[test]
fn analog_corpus_latency_model() {
    for p in analog_corpus::ANALOG_CORPUS {
        let lat = p.inference_latency_s();
        assert!(
            (1e-8..=1e-4).contains(&lat),
            "{}: latency {lat}s outside the paper's ~100ns..1us ballpark",
            p.name
        );
    }
}

// ---------------------------------------------------------------------------
// S1 — O(n log n) vs O(n^2)
// ---------------------------------------------------------------------------

#[test]
fn complexity_sweep_crossover() {
    // the measured speedup must grow with n and exceed 1 at large n — the
    // asymptotic claim of the paper, measured, not assumed
    let points = complexity::sweep(&[256, 1024, 4096], 64, 9);
    assert_eq!(points.len(), 3);
    let last = points.last().unwrap();
    assert!(
        last.speedup > 1.0,
        "n=4096 k=64: circulant should beat dense, got {:.2}x",
        last.speedup
    );
    assert!(
        last.speedup > points[0].speedup,
        "speedup must grow with n ({:.2} -> {:.2})",
        points[0].speedup,
        last.speedup
    );
    // op-count accounting: circ mults grow ~n log n, dense ~n^2
    for p in &points {
        assert!(p.circ_mults < p.dense_macs, "n={}: op accounting inverted", p.n);
    }
}

// ---------------------------------------------------------------------------
// AB1-3 — ablations point the right way
// ---------------------------------------------------------------------------

#[test]
fn ablations_all_optimizations_help() {
    for m in models::registry() {
        for row in ablations::ablate(&m) {
            assert!(
                row.retained <= 1.0 + 1e-9,
                "{} / {}: disabling the optimization must not help (retained {:.3})",
                row.model,
                row.ablation,
                row.retained
            );
        }
    }
    // decoupling is the big lever on FC-heavy models: MLP-1 must lose
    // meaningful throughput without it
    let mlp = models::by_name("mnist_mlp_1").unwrap();
    let dec = ablations::ablate(&mlp)
        .into_iter()
        .find(|r| r.ablation.contains("decoupling"))
        .unwrap();
    assert!(
        dec.retained < 0.9,
        "AB1 on mnist_mlp_1: decoupling should matter, retained {:.3}",
        dec.retained
    );
}

// ---------------------------------------------------------------------------
// FPGA memory / device claims
// ---------------------------------------------------------------------------

#[test]
fn whole_model_fits_on_chip_at_design_point() {
    // "the proposed FPGA-based implementation can accommodate the whole DNN
    // model using on-chip block memory"
    for m in models::registry() {
        let cfg = ScheduleConfig::auto_for(&m, &CYCLONE_V);
        let rep = memory_report(&m, CYCLONE_V.bram_bytes, cfg.bits, cfg.batch, true, true);
        assert!(
            rep.fits,
            "{}: {}B > {}B BRAM at batch {}",
            m.name,
            rep.total_bytes,
            CYCLONE_V.bram_bytes,
            cfg.batch
        );
        assert!(cfg.batch >= 1, "auto batch must be positive");
    }
}

#[test]
fn ab2_full_spectrum_costs_memory() {
    for m in models::registry() {
        let half = memory_report(&m, CYCLONE_V.bram_bytes, 12, 64, true, true);
        let full = memory_report(&m, CYCLONE_V.bram_bytes, 12, 64, false, true);
        assert!(
            full.weight_bytes > half.weight_bytes,
            "{}: full spectra must cost more weight memory",
            m.name
        );
    }
}

#[test]
fn device_registry() {
    assert_eq!(device::by_name("cyclone_v").unwrap().name, CYCLONE_V.name);
    assert_eq!(device::by_name("kintex7").unwrap().name, KINTEX_7.name);
    assert!(device::by_name("virtex_9000").is_none());
    assert!(KINTEX_7.peak_mults_per_s() > CYCLONE_V.peak_mults_per_s());
    // 5CEA9 M10K ≈ 0.5 MiB, Kintex-7 16 Mb = 2 MiB (the paper's "more than
    // 2MB" refers to the class; the devices' datasheet numbers are modeled)
    assert!(CYCLONE_V.bram_bytes > 400 * 1024);
    assert!(KINTEX_7.bram_bytes >= 2 * 1024 * 1024);
}

// ---------------------------------------------------------------------------
// Python <-> Rust contracts (manifest-backed)
// ---------------------------------------------------------------------------

#[test]
fn manifest_covers_registry_and_files_exist() {
    let Some(man) = manifest() else { return };
    assert_eq!(man.quant_bits, 12);
    for m in models::registry() {
        let e = man.model(m.name).expect("registry model present in manifest");
        assert_eq!(e.dataset, m.dataset, "{}: dataset mismatch", m.name);
        assert_eq!(e.serve_batch, m.serve_batch, "{}: serve batch", m.name);
        assert!(!e.artifacts.is_empty(), "{}: no artifacts", m.name);
        for a in &e.artifacts {
            let path = man.path_of(&a.file);
            assert!(path.exists(), "{}: missing artifact {}", m.name, path.display());
            assert_eq!(a.input_shape[0], a.batch, "{}: batch dim mismatch", m.name);
        }
        // accounting agreement across the language boundary
        assert_eq!(
            e.equivalent_ops_per_image,
            m.equivalent_ops_per_image(),
            "{}: equivalent-ops accounting drifted between Python and Rust",
            m.name
        );
        let rep = m.storage_report(man.quant_bits);
        assert!(
            (e.storage_reduction - rep.reduction).abs() / rep.reduction < 1e-6,
            "{}: storage reduction {:.4} (py) vs {:.4} (rs)",
            m.name,
            e.storage_reduction,
            rep.reduction
        );
    }
}

#[test]
fn dataset_checksums_match_python() {
    let Some(man) = manifest() else { return };
    for (name, &py_sum) in &man.dataset_checksums {
        let ds = data::dataset(name).expect("known dataset");
        let rs_sum = data::checksum(&ds, 16);
        assert_eq!(
            rs_sum, py_sum,
            "{name}: Rust generator diverged from Python (bit-exactness contract)"
        );
    }
}

#[test]
fn manifest_accuracies_are_sane() {
    let Some(man) = manifest() else { return };
    for e in &man.models {
        assert!(
            e.accuracy.circulant_f32 > 0.5,
            "{}: circulant f32 accuracy {:.3} — model did not train",
            e.name,
            e.accuracy.circulant_f32
        );
        assert!(
            e.accuracy.circulant_12bit > e.accuracy.circulant_f32 - 0.05,
            "{}: 12-bit quantization cost more than 5% accuracy",
            e.name
        );
        // the paper's constraint: degradation vs dense within ~1-2%
        assert!(
            e.accuracy.dense_f32 - e.accuracy.circulant_f32 < 0.06,
            "{}: circulant degradation vs dense too large ({:.3} vs {:.3})",
            e.name,
            e.accuracy.circulant_f32,
            e.accuracy.dense_f32
        );
    }
}

#[test]
fn simulate_reports_are_internally_consistent() {
    for m in models::registry() {
        for dev in [&CYCLONE_V, &KINTEX_7] {
            let cfg = ScheduleConfig::auto_for(&m, dev);
            let r = simulate(&m, dev, &cfg);
            assert_eq!(
                r.cycles_per_batch,
                r.phase.total(),
                "{}: phase breakdown must sum to total",
                m.name
            );
            let rep = DesignReport::build(&m, dev, &cfg);
            assert!((rep.kfps - r.kfps()).abs() / r.kfps() < 1e-9);
            // equivalent GOPS uses the dense-op normalization
            let expect_gops = m.equivalent_ops_per_image() as f64 * r.fps() / 1e9;
            assert!(
                (rep.equivalent_gops - expect_gops).abs() / expect_gops < 1e-9,
                "{}: equivalent GOPS normalization drifted",
                m.name
            );
        }
    }
}
