//! Coordinator-under-load tests: the full serving path (router → dynamic
//! batcher → PJRT executor thread) driven by concurrent clients, plus the
//! failure-injection cases (unknown model, bad shapes, backpressure,
//! shutdown drain).
//!
//! Each test starts its own [`Server`] (its own PJRT client on a dedicated
//! executor thread); a mutex serializes them so the process never compiles
//! the same artifacts concurrently.

use std::sync::Mutex;
use std::time::Duration;

use circnn::coordinator::{BatchPolicy, InferError, Server, ServerConfig};
use circnn::data;
use circnn::runtime::Manifest;

static SERVER_LOCK: Mutex<()> = Mutex::new(());

fn have_artifacts() -> bool {
    if Manifest::load(Manifest::default_dir()).is_ok() {
        true
    } else {
        eprintln!("SKIP: artifacts missing (run `make artifacts`)");
        false
    }
}

fn start(policy: BatchPolicy) -> Server {
    Server::start(ServerConfig { policy, ..ServerConfig::default() })
        .expect("server start")
}

const MODEL: &str = "mnist_mlp_1";

#[test]
fn single_request_roundtrip() {
    let _g = SERVER_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    if !have_artifacts() {
        return;
    }
    let server = start(BatchPolicy {
        max_batch: 64,
        max_delay: Duration::from_millis(1),
        max_queue: 1024,
    });
    let (img, _label) = data::sample(&data::MNIST_S, 0);
    let resp = server.infer(MODEL, &img).expect("infer");
    assert_eq!(resp.logits.len(), 10);
    assert!(resp.logits.iter().all(|v| v.is_finite()));
    assert_eq!(resp.label as usize, argmax(&resp.logits));
    assert!(resp.batch_occupancy >= 1);
    server.shutdown();
}

fn argmax(v: &[f32]) -> usize {
    let mut best = 0;
    for (i, &x) in v.iter().enumerate() {
        if x > v[best] {
            best = i;
        }
    }
    best
}

#[test]
fn concurrent_clients_all_get_consistent_answers() {
    let _g = SERVER_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    if !have_artifacts() {
        return;
    }
    let server = start(BatchPolicy {
        max_batch: 64,
        max_delay: Duration::from_millis(2),
        max_queue: 8192,
    });
    const CLIENTS: usize = 8;
    const PER: usize = 64;

    // reference labels: one warmup pass through the same server
    let mut want = Vec::new();
    for i in 0..PER as u64 {
        let (img, _) = data::sample(&data::MNIST_S, i);
        want.push(server.infer(MODEL, &img).unwrap().label);
    }

    let mut got_all = vec![Vec::new(); CLIENTS];
    std::thread::scope(|scope| {
        let mut handles = Vec::new();
        for c in 0..CLIENTS {
            let server = &server;
            handles.push(scope.spawn(move || {
                let mut got = Vec::with_capacity(PER);
                for i in 0..PER as u64 {
                    let (img, _) = data::sample(&data::MNIST_S, i);
                    got.push(server.infer(MODEL, &img).expect("infer").label);
                }
                got
            }));
        }
        for (c, h) in handles.into_iter().enumerate() {
            got_all[c] = h.join().unwrap();
        }
    });
    for (c, got) in got_all.iter().enumerate() {
        assert_eq!(got, &want, "client {c} saw different labels — batching must not mix rows");
    }

    // metrics bookkeeping: every request accounted for
    let m = server.metrics();
    let responses = m.responses.load(std::sync::atomic::Ordering::Relaxed);
    assert_eq!(responses as usize, PER + CLIENTS * PER);
    let batches = m.batches.load(std::sync::atomic::Ordering::Relaxed);
    assert!(batches > 0);
    assert!(m.mean_batch_size() >= 1.0);
    assert!(m.mean_latency_us() > 0.0);
    assert!(m.latency_percentile_us(99.0) >= m.latency_percentile_us(50.0));
    server.shutdown();
}

#[test]
fn full_batches_form_under_concurrency() {
    let _g = SERVER_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    if !have_artifacts() {
        return;
    }
    let server = start(BatchPolicy {
        max_batch: 64,
        max_delay: Duration::from_millis(50),
        max_queue: 8192,
    });
    // fire 256 async requests, then collect — the long deadline forces
    // size-triggered batches
    let (img, _) = data::sample(&data::MNIST_S, 0);
    let mut pending = Vec::new();
    for _ in 0..256 {
        pending.push(server.infer_async(MODEL, &img).unwrap());
    }
    let mut max_occ = 0;
    for rx in pending {
        let resp = rx.recv().unwrap().unwrap();
        max_occ = max_occ.max(resp.batch_occupancy);
    }
    assert_eq!(max_occ, 64, "paper's batch regime: full 64-image batches must form");
    assert!(server.metrics().padding_fraction() < 0.5);
    server.shutdown();
}

#[test]
fn unknown_model_and_bad_shape_are_rejected_at_the_router() {
    let _g = SERVER_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    if !have_artifacts() {
        return;
    }
    let server = start(BatchPolicy::default());
    let (img, _) = data::sample(&data::MNIST_S, 0);
    match server.infer("resnet_152", &img) {
        Err(InferError::Route(_)) => {}
        other => panic!("unknown model must fail at routing, got {other:?}"),
    }
    match server.infer(MODEL, &img[..100]) {
        Err(InferError::Route(_)) => {}
        other => panic!("wrong image size must fail at routing, got {other:?}"),
    }
    // routing failures must not poison the server
    assert!(server.infer(MODEL, &img).is_ok());
    server.shutdown();
}

#[test]
fn backpressure_rejects_when_queue_is_full() {
    let _g = SERVER_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    if !have_artifacts() {
        return;
    }
    // tiny admission queue + long deadline: flood with async pushes faster
    // than one executor can drain
    let server = start(BatchPolicy {
        max_batch: 64,
        max_delay: Duration::from_millis(200),
        max_queue: 4,
    });
    let (img, _) = data::sample(&data::MNIST_S, 0);
    let mut rejected = 0;
    let mut accepted = Vec::new();
    for _ in 0..512 {
        match server.infer_async(MODEL, &img) {
            Ok(rx) => accepted.push(rx),
            Err(InferError::Rejected) => rejected += 1,
            Err(e) => panic!("unexpected error {e}"),
        }
    }
    assert!(rejected > 0, "flooding a max_queue=4 server must shed load");
    // every accepted request still completes (bounded, not dropped)
    for rx in accepted {
        match rx.recv().unwrap() {
            Ok(_) | Err(InferError::Rejected) => {}
            Err(e) => panic!("accepted request failed: {e}"),
        }
    }
    server.shutdown();
}

#[test]
fn shutdown_drains_inflight_requests() {
    let _g = SERVER_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    if !have_artifacts() {
        return;
    }
    let server = start(BatchPolicy {
        max_batch: 64,
        max_delay: Duration::from_secs(5), // deadline won't fire; drain must
        max_queue: 1024,
    });
    let (img, _) = data::sample(&data::MNIST_S, 0);
    let pending: Vec<_> = (0..10)
        .map(|_| server.infer_async(MODEL, &img).unwrap())
        .collect();
    server.shutdown(); // closes the channel; executor drains queued work
    for (i, rx) in pending.into_iter().enumerate() {
        let resp = rx.recv().expect("response channel must not be dropped");
        assert!(resp.is_ok(), "queued request {i} lost during shutdown");
    }
}

#[test]
fn pallas_backed_serving_agrees_with_plain() {
    let _g = SERVER_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    if !have_artifacts() {
        return;
    }
    let plain = start(BatchPolicy::default());
    let mut labels_plain = Vec::new();
    for i in 0..32u64 {
        let (img, _) = data::sample(&data::MNIST_S, i);
        labels_plain.push(plain.infer(MODEL, &img).unwrap().label);
    }
    plain.shutdown();

    let pallas = Server::start(ServerConfig {
        use_pallas: true,
        ..ServerConfig::default()
    })
    .unwrap();
    for (i, &want) in labels_plain.iter().enumerate() {
        let (img, _) = data::sample(&data::MNIST_S, i as u64);
        let got = pallas.infer(MODEL, &img).unwrap().label;
        assert_eq!(got, want, "image {i}: pallas-served label diverged");
    }
    pallas.shutdown();
}

#[test]
fn deadline_releases_partial_batch_under_light_load() {
    let _g = SERVER_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    if !have_artifacts() {
        return;
    }
    let server = start(BatchPolicy {
        max_batch: 64,
        max_delay: Duration::from_millis(5),
        max_queue: 1024,
    });
    let (img, _) = data::sample(&data::MNIST_S, 0);
    let t0 = std::time::Instant::now();
    let resp = server.infer(MODEL, &img).expect("single request");
    assert!(resp.batch_occupancy < 64, "lone request must ride a partial batch");
    assert!(
        t0.elapsed() < Duration::from_secs(2),
        "deadline-triggered release took {:?}",
        t0.elapsed()
    );
    assert!(server.metrics().padding_fraction() > 0.9, "63/64 slots were padding");
    server.shutdown();
}
