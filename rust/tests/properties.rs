//! Property-based tests on the paper's core invariants (DESIGN.md §6 S1 +
//! coordinator invariants), run through the from-scratch harness in
//! `circnn::util::prop` (the offline closure has no proptest).
//!
//! Everything here is pure logic — no PJRT, no artifacts — so this target
//! runs in milliseconds and catches algebra regressions before the heavier
//! integration targets even compile their HLO.

use std::time::{Duration, Instant};

use circnn::circulant::fft::{complex_mul_acc, FftPlan};
use circnn::circulant::{dense, im2col, quant, BlockCirculant};
use circnn::coordinator::batcher::{BatchPolicy, BatchQueue, PushOutcome};
use circnn::data;
use circnn::fpga::device::CYCLONE_V;
use circnn::fpga::schedule::{simulate, ScheduleConfig};
use circnn::models;
use circnn::util::json::Json;
use circnn::util::prop::{assert_all_close, close, forall};
use circnn::util::rng::SplitMix;

// ---------------------------------------------------------------------------
// block-circulant algebra (Eqn. 1)
// ---------------------------------------------------------------------------

fn random_bc(rng: &mut SplitMix) -> BlockCirculant {
    let p = 1 + rng.below(4) as usize;
    let q = 1 + rng.below(4) as usize;
    let k = 1usize << (1 + rng.below(6)); // 2..64
    let w = rng.normal_vec(p * q * k);
    let mut bc = BlockCirculant::new(p, q, k, w);
    bc.precompute();
    bc
}

#[test]
fn prop_fft_matvec_matches_naive() {
    forall(
        "decoupled FFT matvec == explicit circulant matvec",
        |r| {
            let bc = random_bc(r);
            let x = r.normal_vec(bc.cols());
            (bc, x)
        },
        |(bc, x)| {
            let mut fast = vec![0.0; bc.rows()];
            let mut slow = vec![0.0; bc.rows()];
            bc.matvec(x, &mut fast);
            bc.matvec_naive(x, &mut slow);
            assert_all_close(&fast, &slow, 1e-3, 1e-3)
        },
    );
}

#[test]
fn prop_matvec_matches_dense_reconstruction() {
    forall(
        "W x through to_dense() == FFT path",
        |r| {
            let bc = random_bc(r);
            let x = r.normal_vec(bc.cols());
            (bc, x)
        },
        |(bc, x)| {
            let w = bc.to_dense();
            let (m, n) = (bc.rows(), bc.cols());
            let mut via_dense = vec![0.0; m];
            dense::matvec(&w, m, n, x, &mut via_dense);
            let mut fast = vec![0.0; m];
            bc.matvec(x, &mut fast);
            assert_all_close(&fast, &via_dense, 1e-3, 1e-3)
        },
    );
}

#[test]
fn prop_matvec_linearity() {
    forall(
        "W(ax + by) == a Wx + b Wy",
        |r| {
            let bc = random_bc(r);
            let x = r.normal_vec(bc.cols());
            let y = r.normal_vec(bc.cols());
            let (a, b) = (r.next_f32() * 4.0 - 2.0, r.next_f32() * 4.0 - 2.0);
            (bc, x, y, a, b)
        },
        |(bc, x, y, a, b)| {
            let m = bc.rows();
            let mixed: Vec<f32> = x.iter().zip(y).map(|(u, v)| a * u + b * v).collect();
            let mut lhs = vec![0.0; m];
            bc.matvec(&mixed, &mut lhs);
            let (mut wx, mut wy) = (vec![0.0; m], vec![0.0; m]);
            bc.matvec(x, &mut wx);
            bc.matvec(y, &mut wy);
            let rhs: Vec<f32> = wx.iter().zip(&wy).map(|(u, v)| a * u + b * v).collect();
            assert_all_close(&lhs, &rhs, 2e-3, 2e-3)
        },
    );
}

#[test]
fn prop_single_block_is_cyclic_convolution() {
    // the circulant convolution theorem the whole paper rests on:
    // C(w) x == cyclic_conv(w, x) for first-COLUMN-generated C
    forall(
        "1x1 block == cyclic convolution",
        |r| {
            let k = 1usize << (1 + r.below(7));
            (k, r.normal_vec(k), r.normal_vec(k))
        },
        |(k, w, x)| {
            let k = *k;
            let mut bc = BlockCirculant::new(1, 1, k, w.clone());
            bc.precompute();
            let mut got = vec![0.0; k];
            bc.matvec(x, &mut got);
            // direct cyclic convolution sum_c w[(r - c) mod k] * x[c]
            let mut want = vec![0.0f32; k];
            for (r_i, slot) in want.iter_mut().enumerate() {
                for c in 0..k {
                    *slot += w[(r_i + k - c) % k] * x[c];
                }
            }
            assert_all_close(&got, &want, 1e-3, 1e-3)
        },
    );
}

#[test]
fn prop_param_count_is_o_n() {
    forall(
        "storage O(n): pqk floats vs pk*qk dense",
        |r| random_bc(r),
        |bc| {
            if bc.param_count() != bc.p * bc.q * bc.k {
                return Err(format!("param_count {} != pqk", bc.param_count()));
            }
            if bc.param_count() * bc.k != bc.rows() * bc.cols() {
                return Err("dense/circ ratio must be exactly k".into());
            }
            Ok(())
        },
    );
}

#[test]
fn prop_batched_matmul_matches_per_row_matvec() {
    forall(
        "matmul == stacked matvec",
        |r| {
            let bc = random_bc(r);
            let batch = 1 + r.below(5) as usize;
            let xs = r.normal_vec(batch * bc.cols());
            (bc, batch, xs)
        },
        |(bc, batch, xs)| {
            let (n, m) = (bc.cols(), bc.rows());
            let mut all = vec![0.0; batch * m];
            bc.matmul(xs, *batch, &mut all);
            for b in 0..*batch {
                let mut one = vec![0.0; m];
                bc.matvec(&xs[b * n..(b + 1) * n], &mut one);
                assert_all_close(&all[b * m..(b + 1) * m], &one, 1e-6, 1e-6)?;
            }
            Ok(())
        },
    );
}

// (the bitwise matmul == matvec_ws property and the packed-vs-full rfft
// parity checks live with the code in circulant::block / circulant::fft —
// one copy per property, not re-run here)

// ---------------------------------------------------------------------------
// FFT plan details used by the decoupling argument
// ---------------------------------------------------------------------------

#[test]
fn prop_rfft_equals_full_fft_prefix() {
    forall(
        "rfft half-spectrum == full FFT bins 0..k/2",
        |r| {
            let k = 1usize << (1 + r.below(7));
            (k, r.normal_vec(k))
        },
        |(k, x)| {
            let plan = FftPlan::new(*k);
            let kh = plan.half_bins();
            let mut scratch = vec![0.0; 2 * k];
            let (mut hr, mut hi) = (vec![0.0; kh], vec![0.0; kh]);
            plan.rfft_halfspec(x, &mut hr, &mut hi, &mut scratch);
            let (mut fr, mut fi) = (x.clone(), vec![0.0; *k]);
            plan.fft(&mut fr, &mut fi);
            assert_all_close(&hr, &fr[..kh], 1e-4, 1e-4)?;
            assert_all_close(&hi, &fi[..kh], 1e-4, 1e-4)
        },
    );
}

#[test]
fn prop_real_spectrum_hermitian_symmetry() {
    // the paper's §hardware-optimization: FFT of a real vector is conjugate
    // symmetric, so bins k/2+1.. are redundant
    forall(
        "FFT(real x) conjugate-symmetric",
        |r| {
            let k = 1usize << (2 + r.below(6));
            (k, r.normal_vec(k))
        },
        |(k, x)| {
            let plan = FftPlan::new(*k);
            let (mut re, mut im) = (x.clone(), vec![0.0; *k]);
            plan.fft(&mut re, &mut im);
            for t in 1..*k / 2 {
                if !close(re[t], re[k - t], 1e-3, 1e-3) || !close(im[t], -im[k - t], 1e-3, 1e-3) {
                    return Err(format!("bin {t} not conjugate of bin {}", k - t));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_complex_mul_acc_is_complex_product() {
    forall(
        "complex_mul_acc == (a+bi)(c+di) accumulation",
        |r| {
            let n = 1 + r.below(32) as usize;
            (
                r.normal_vec(n),
                r.normal_vec(n),
                r.normal_vec(n),
                r.normal_vec(n),
                r.normal_vec(n),
                r.normal_vec(n),
            )
        },
        |(ar, ai, br, bi, r0, i0)| {
            let (mut acc_r, mut acc_i) = (r0.clone(), i0.clone());
            complex_mul_acc(ar, ai, br, bi, &mut acc_r, &mut acc_i);
            for t in 0..ar.len() {
                let er = r0[t] + ar[t] * br[t] - ai[t] * bi[t];
                let ei = i0[t] + ar[t] * bi[t] + ai[t] * br[t];
                if !close(acc_r[t], er, 1e-4, 1e-4) || !close(acc_i[t], ei, 1e-4, 1e-4) {
                    return Err(format!("lane {t} wrong"));
                }
            }
            Ok(())
        },
    );
}

// ---------------------------------------------------------------------------
// quantization (the 12-bit precision column of Table 1)
// ---------------------------------------------------------------------------

#[test]
fn prop_quant_roundtrip_error_bounded() {
    forall(
        "12-bit quant error <= half step",
        |r| {
            let n = 1 + r.below(256) as usize;
            let bits = 4 + r.below(12) as u32;
            (r.normal_vec(n), bits)
        },
        |(x, bits)| {
            let q = quant::Quantized::encode(x, *bits);
            let back = q.decode();
            // symmetric signed grid: step = max|x| / (2^(bits-1) - 1)
            let amax = x.iter().fold(0.0f32, |m, v| m.max(v.abs()));
            let step = amax / ((1u64 << (*bits - 1)) - 1) as f32;
            for (i, (&a, &b)) in x.iter().zip(&back).enumerate() {
                if (a - b).abs() > 0.5001 * step {
                    return Err(format!("index {i}: |{a}-{b}| > step/2 {}", step / 2.0));
                }
            }
            if q.max_error() > 0.5001 * step {
                return Err("max_error() exceeds half step".into());
            }
            Ok(())
        },
    );
}

#[test]
fn prop_fake_quant_idempotent() {
    forall(
        "fake_quant(fake_quant(x)) == fake_quant(x)",
        |r| {
            let n = 1 + r.below(128) as usize;
            let bits = 4 + r.below(12) as u32;
            (r.normal_vec(n), bits)
        },
        |(x, bits)| {
            let mut once = x.clone();
            quant::fake_quant(&mut once, *bits);
            let mut twice = once.clone();
            quant::fake_quant(&mut twice, *bits);
            assert_all_close(&once, &twice, 0.0, 1e-6)
        },
    );
}

#[test]
fn quant_packed_bytes_accounting() {
    let q = quant::Quantized::encode(&[0.5; 100], 12);
    assert_eq!(q.packed_bytes(), (100usize * 12).div_ceil(8));
}

// ---------------------------------------------------------------------------
// im2col (the CONV reformulation of Fig. 2)
// ---------------------------------------------------------------------------

/// Direct valid-convolution oracle in HWC layout.
fn direct_conv(x: &[f32], h: usize, w: usize, c: usize, f: &[f32], r: usize, p: usize) -> Vec<f32> {
    let (oh, ow) = (h - r + 1, w - r + 1);
    let mut y = vec![0.0f32; oh * ow * p];
    for oy in 0..oh {
        for ox in 0..ow {
            for op in 0..p {
                let mut acc = 0.0f32;
                for i in 0..r {
                    for j in 0..r {
                        for ch in 0..c {
                            let xi = x[((oy + i) * w + (ox + j)) * c + ch];
                            // F layout (i, j, c, p) to match Fig. 2
                            let fi = f[((i * r + j) * c + ch) * p + op];
                            acc += xi * fi;
                        }
                    }
                }
                y[(oy * ow + ox) * p + op] = acc;
            }
        }
    }
    y
}

#[test]
fn prop_im2col_matmul_equals_direct_conv() {
    forall(
        "Y = im2col(X) F == direct convolution (Eqn. 4)",
        |rng| {
            let h = 4 + rng.below(6) as usize;
            let w = 4 + rng.below(6) as usize;
            let c = 1 + rng.below(3) as usize;
            let r = 1 + rng.below(3.min(h as u64 - 1)) as usize;
            let p = 1 + rng.below(4) as usize;
            let x = rng.normal_vec(h * w * c);
            let f = rng.normal_vec(r * r * c * p);
            (h, w, c, r, p, x, f)
        },
        |(h, w, c, r, p, x, f)| {
            let (h, w, c, r, p) = (*h, *w, *c, *r, *p);
            // k=1: column ordering is (c_block=c, di, dj, 1)
            let cols = im2col::im2col(x, h, w, c, r, 1);
            let (oh, ow) = (h - r + 1, w - r + 1);
            let mut y = vec![0.0f32; oh * ow * p];
            for pos in 0..oh * ow {
                for op in 0..p {
                    let mut acc = 0.0;
                    for ch in 0..c {
                        for i in 0..r {
                            for j in 0..r {
                                let col = (ch * r + i) * r + j; // im2col order
                                let fi = ((i * r + j) * c + ch) * p + op; // F (i,j,c,p)
                                acc += cols[pos * r * r * c + col] * f[fi];
                            }
                        }
                    }
                    y[pos * p + op] = acc;
                }
            }
            let want = direct_conv(x, h, w, c, f, r, p);
            assert_all_close(&y, &want, 1e-3, 1e-3)
        },
    );
}

#[test]
fn pad_same_preserves_interior() {
    let mut rng = SplitMix::new(7);
    let (h, w, c, r) = (5, 6, 2, 3);
    let x = rng.normal_vec(h * w * c);
    let (px, ph, pw) = im2col::pad_same(&x, h, w, c, r);
    assert_eq!((ph, pw), (h + r - 1, w + r - 1));
    let off = (r - 1) / 2;
    for y in 0..h {
        for xx in 0..w {
            for ch in 0..c {
                let a = x[(y * w + xx) * c + ch];
                let b = px[((y + off) * pw + (xx + off)) * c + ch];
                assert_eq!(a, b, "interior moved at ({y},{xx},{ch})");
            }
        }
    }
}

// ---------------------------------------------------------------------------
// dynamic batcher invariants (coordinator, DESIGN.md §5)
// ---------------------------------------------------------------------------

#[test]
fn prop_batcher_never_exceeds_max_batch_and_preserves_fifo() {
    forall(
        "batches <= max_batch, FIFO order, nothing lost",
        |r| {
            let max_batch = 1 + r.below(16) as usize;
            let pushes = 1 + r.below(200) as usize;
            (max_batch, pushes)
        },
        |&(max_batch, pushes)| {
            let policy = BatchPolicy {
                max_batch,
                max_delay: Duration::from_secs(3600), // never trigger by time
                max_queue: usize::MAX,
            };
            let mut q = BatchQueue::new(policy);
            let now = Instant::now();
            let mut drained: Vec<u32> = Vec::new();
            for i in 0..pushes as u32 {
                match q.push(i, now) {
                    PushOutcome::BatchReady => {
                        let batch = q.drain_batch();
                        if batch.len() != max_batch {
                            return Err(format!("ready batch len {} != {max_batch}", batch.len()));
                        }
                        drained.extend(batch.iter().map(|p| p.item));
                    }
                    PushOutcome::Queued => {}
                    PushOutcome::Rejected(_) => return Err("unexpected rejection".into()),
                }
            }
            // tail flush
            while !q.is_empty() {
                let batch = q.drain_batch();
                if batch.len() > max_batch {
                    return Err("tail batch exceeds max_batch".into());
                }
                drained.extend(batch.iter().map(|p| p.item));
            }
            let want: Vec<u32> = (0..pushes as u32).collect();
            if drained != want {
                return Err(format!("order/loss violation: got {} items", drained.len()));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_batcher_backpressure_rejects_exactly_past_max_queue() {
    forall(
        "push rejected iff queue full",
        |r| (1 + r.below(8) as usize, 1 + r.below(64) as usize),
        |&(max_queue, pushes)| {
            let policy = BatchPolicy {
                max_batch: usize::MAX, // never release
                max_delay: Duration::from_secs(3600),
                max_queue,
            };
            let mut q = BatchQueue::new(policy);
            let now = Instant::now();
            for i in 0..pushes {
                let outcome = q.push(i, now);
                let expect_reject = i >= max_queue;
                match (outcome, expect_reject) {
                    (PushOutcome::Rejected(v), true) if v == i => {}
                    (PushOutcome::Queued, false) => {}
                    (o, _) => return Err(format!("push {i}: wrong outcome {o:?}")),
                }
            }
            Ok(())
        },
    );
}

#[test]
fn batcher_deadline_releases_partial_batch() {
    let policy = BatchPolicy {
        max_batch: 100,
        max_delay: Duration::from_millis(1),
        max_queue: 100,
    };
    let mut q = BatchQueue::new(policy);
    let t0 = Instant::now();
    assert!(matches!(q.push(1u32, t0), PushOutcome::Queued));
    assert!(!q.ready(t0));
    assert!(q.ready(t0 + Duration::from_millis(2)), "deadline must trigger");
    assert_eq!(q.drain_batch().len(), 1);
}

// ---------------------------------------------------------------------------
// FPGA schedule monotonicity (the ablations must point the right way for
// every registry model, not just the ones the bench prints)
// ---------------------------------------------------------------------------

#[test]
fn schedule_every_optimization_helps_every_model() {
    for m in models::registry() {
        let base = ScheduleConfig::auto_for(&m, &CYCLONE_V);
        let on = simulate(&m, &CYCLONE_V, &base).kfps();
        for (name, cfg) in [
            ("decouple", ScheduleConfig { decouple: false, ..base }),
            ("half_spectrum", ScheduleConfig { half_spectrum: false, ..base }),
            ("interleave", ScheduleConfig { interleave: false, ..base }),
        ] {
            let off = simulate(&m, &CYCLONE_V, &cfg).kfps();
            assert!(
                on >= off,
                "{}: disabling {name} should not speed things up ({on} < {off})",
                m.name
            );
        }
    }
}

#[test]
fn prop_schedule_batch_amortizes_fills() {
    forall(
        "per-image ns is non-increasing in batch size",
        |r| {
            let reg = models::registry();
            let m = reg[r.below(reg.len() as u64) as usize].clone();
            let b = 1u64 << r.below(6);
            (m, b)
        },
        |(m, b)| {
            let small = simulate(m, &CYCLONE_V, &ScheduleConfig { batch: *b, ..Default::default() });
            let large =
                simulate(m, &CYCLONE_V, &ScheduleConfig { batch: b * 2, ..Default::default() });
            if large.ns_per_image() <= small.ns_per_image() * 1.0001 {
                Ok(())
            } else {
                Err(format!(
                    "{}: batch {} -> {} raised ns/img {} -> {}",
                    m.name,
                    b,
                    b * 2,
                    small.ns_per_image(),
                    large.ns_per_image()
                ))
            }
        },
    );
}

#[test]
fn schedule_utilization_is_a_fraction() {
    for m in models::registry() {
        let cfg = ScheduleConfig::auto_for(&m, &CYCLONE_V);
        let r = simulate(&m, &CYCLONE_V, &cfg);
        assert!(
            r.utilization > 0.0 && r.utilization <= 1.0,
            "{}: utilization {} out of (0,1]",
            m.name,
            r.utilization
        );
        assert!(r.power_w() > CYCLONE_V.static_w, "dynamic power must add");
    }
}

// ---------------------------------------------------------------------------
// synthetic data contract
// ---------------------------------------------------------------------------

#[test]
fn prop_data_deterministic_and_in_range() {
    forall(
        "samples are deterministic, clamped, label == index mod 10",
        |r| (r.below(3), r.below(100_000)),
        |&(ds_i, idx)| {
            let ds = [data::MNIST_S, data::SVHN_S, data::CIFAR_S][ds_i as usize];
            let (img1, y1) = data::sample(&ds, idx);
            let (img2, y2) = data::sample(&ds, idx);
            if img1 != img2 || y1 != y2 {
                return Err("non-deterministic sample".into());
            }
            if y1 as u64 != idx % 10 {
                return Err(format!("label {y1} != {} mod 10", idx));
            }
            if img1.iter().any(|&v| !(0.0..=1.0).contains(&v)) {
                return Err("pixel out of [0,1]".into());
            }
            if img1.len() != ds.pixels() {
                return Err("pixel count mismatch".into());
            }
            Ok(())
        },
    );
}

#[test]
fn data_test_split_disjoint_from_train() {
    let (train, _) = data::batch(&data::MNIST_S, 0, 8, false);
    let (test, _) = data::batch(&data::MNIST_S, 0, 8, true);
    assert_ne!(train, test, "test split must differ from train split");
}

#[test]
fn prop_prior_pool_averages() {
    forall(
        "prior_pool output bounded by input range",
        |r| {
            let n = 16 + r.below(768) as usize;
            let out = 1 + r.below(64) as usize;
            (r.normal_vec(n).iter().map(|v| v.abs().min(1.0)).collect::<Vec<_>>(), out)
        },
        |(img, out_dim)| {
            let pooled = data::prior_pool(img, *out_dim);
            if pooled.len() != *out_dim {
                return Err("wrong output dim".into());
            }
            let max = img.iter().cloned().fold(0.0f32, f32::max);
            if pooled.iter().any(|&v| v < -1e-6 || v > max + 1e-6) {
                return Err("pooled value outside input range".into());
            }
            Ok(())
        },
    );
}

// ---------------------------------------------------------------------------
// json substrate (manifest parser)
// ---------------------------------------------------------------------------

#[test]
fn prop_json_number_roundtrip() {
    forall(
        "parse(to_string(n)) == n",
        |r| (r.next_f64() * 2e6 - 1e6, r.next_u64() % 1_000_000),
        |&(f, u)| {
            let text = format!("{{\"f\": {f}, \"u\": {u}, \"s\": \"x\\\"y\", \"a\": [1, 2.5], \"b\": true, \"n\": null}}");
            let parsed = Json::parse(&text).map_err(|e| e.0)?;
            let f2 = parsed.require("f").map_err(|e| e.0)?.as_f64().unwrap();
            let u2 = parsed.require("u").map_err(|e| e.0)?.as_u64().unwrap();
            if !close(f as f32, f2 as f32, 1e-5, 1e-5) {
                return Err(format!("f {f} != {f2}"));
            }
            if u != u2 {
                return Err(format!("u {u} != {u2}"));
            }
            if parsed.get("s").and_then(|s| s.as_str()) != Some("x\"y") {
                return Err("escaped string mangled".into());
            }
            // reserialize -> reparse stability
            let again = Json::parse(&parsed.to_string()).map_err(|e| e.0)?;
            if again.require("u").map_err(|e| e.0)?.as_u64() != Some(u) {
                return Err("to_string not reparseable".into());
            }
            Ok(())
        },
    );
}
