//! Acceptance pins for `circnn lint`: every violation seeded in
//! `tests/lint_fixtures/` is caught at its exact `file:line` (and nothing
//! else fires in the fixture tree), every rule family fires at least
//! once, and the merged repo itself lints clean.

use std::collections::BTreeSet;
use std::path::{Path, PathBuf};

const MARKER: &str = "LINT-EXPECT:";

fn fixture_root() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("tests/lint_fixtures")
}

/// The `(file, line, rule)` triples declared by marker comments in the
/// fixture tree — the ground truth the lint output must equal.
fn expected(root: &Path) -> BTreeSet<(String, usize, String)> {
    let mut out = BTreeSet::new();
    collect_markers(root, root, &mut out);
    out
}

fn collect_markers(root: &Path, dir: &Path, out: &mut BTreeSet<(String, usize, String)>) {
    for entry in std::fs::read_dir(dir).expect("fixture dir") {
        let p = entry.expect("fixture entry").path();
        if p.is_dir() {
            collect_markers(root, &p, out);
            continue;
        }
        let Ok(text) = std::fs::read_to_string(&p) else { continue };
        let rel = p
            .strip_prefix(root)
            .expect("fixture path under root")
            .to_string_lossy()
            .replace('\\', "/");
        for (i, line) in text.lines().enumerate() {
            if let Some(idx) = line.find(MARKER) {
                let rule = line[idx + MARKER.len()..].trim().to_string();
                out.insert((rel.clone(), i + 1, rule));
            }
        }
    }
}

#[test]
fn every_seeded_fixture_violation_is_caught_at_its_line() {
    let root = fixture_root();
    let want = expected(&root);
    assert!(!want.is_empty(), "no markers found under {}", root.display());

    let report = circnn::lint::run(&root).expect("lint over the fixture tree");
    let got: BTreeSet<(String, usize, String)> = report
        .diagnostics
        .iter()
        .map(|d| (d.file.clone(), d.line, d.rule.to_string()))
        .collect();
    assert_eq!(
        got,
        want,
        "fixture diagnostics diverged from the seeded markers; lint said:\n{}",
        render(&report.diagnostics)
    );

    // every rule family is pinned live — it fires somewhere in the tree
    let fired: BTreeSet<&str> = report.diagnostics.iter().map(|d| d.rule).collect();
    for rule in [
        "safety-comment",
        "simd-oracle",
        "dead-oracle",
        "env-knob",
        "bench-key",
        "request-unwrap",
        "unbounded-channel",
        "metric-name",
        "docs-fresh",
    ] {
        assert!(fired.contains(rule), "no fixture pins rule `{rule}`");
    }
}

#[test]
fn diagnostics_render_as_file_line_rule_message() {
    let root = fixture_root();
    let report = circnn::lint::run(&root).expect("lint over the fixture tree");
    let naked = report
        .diagnostics
        .iter()
        .find(|d| d.rule == "safety-comment")
        .expect("the seeded safety violation");
    let line = naked.to_string();
    assert!(
        line.starts_with("src/bad_unsafe.rs:5: [safety-comment]"),
        "diagnostic format drifted: {line}"
    );
}

#[test]
fn the_repo_itself_lints_clean() {
    // CARGO_MANIFEST_DIR is <repo>/rust; lint from the repo root so the
    // workflow under .github/ is in scope for the bench-key rule
    let repo = Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .expect("crate lives under the repo root");
    let report = circnn::lint::run(repo).expect("lint over the repo");
    assert!(
        report.is_clean(),
        "the merged tree must satisfy its own lint:\n{}",
        render(&report.diagnostics)
    );
    assert!(
        report.files_scanned > 30,
        "suspiciously few files scanned ({})",
        report.files_scanned
    );
}

fn render(diags: &[circnn::lint::Diagnostic]) -> String {
    diags.iter().map(|d| format!("  {d}\n")).collect()
}
