//! Serving-level pipeline equivalence: the full coordinator path (router →
//! dynamic batcher → engine) driven over the pipelined backend must produce
//! **bitwise identical** responses to the serial native executor for
//! identical request streams — including partial final batches and
//! `max_delay`-released batches.
//!
//! These tests need no artifacts: they serve [`Manifest::synthetic`]
//! registry entries with the server's deterministic random-init fallback,
//! so both servers hold bit-identical weights.  Batch *composition* must
//! match between the two servers for bitwise equality (the 12-bit
//! activation quantization scales per batch tensor), so streams are
//! submitted from one thread and sized so every release is size-triggered
//! — except where a test deliberately exercises the deadline path.

use std::time::Duration;

use circnn::coordinator::{BatchPolicy, EngineKind, Server, ServerConfig};
use circnn::data;
use circnn::runtime::Manifest;
use circnn::util::prop::forall;

const MODEL: &str = "mnist_mlp_1";

/// A synthetic manifest trimmed to one model, so each server builds (and,
/// on the pipeline engine, spawns stage workers for) only what the test
/// uses.
fn manifest_for(model: &str) -> Manifest {
    let mut man = Manifest::synthetic();
    man.models.retain(|m| m.name == model);
    assert_eq!(man.models.len(), 1, "{model} missing from the registry");
    man
}

fn start(engine: EngineKind, policy: BatchPolicy, depth: Option<usize>) -> Server {
    start_cfg(engine, policy, depth, false)
}

fn start_cfg(engine: EngineKind, policy: BatchPolicy, depth: Option<usize>, trace: bool) -> Server {
    Server::start_with_manifest(
        manifest_for(MODEL),
        ServerConfig {
            policy,
            engine,
            depth,
            init_random_fallback: true,
            trace,
            ..ServerConfig::default()
        },
    )
    .expect("server start")
}

/// Submit `stream` (sample indices) from one thread, collect responses in
/// order: (logits, label, batch_occupancy) per request.
fn serve_stream(server: &Server, stream: &[u64]) -> Vec<(Vec<f32>, u32, usize)> {
    let pending: Vec<_> = stream
        .iter()
        .map(|&i| {
            let (img, _) = data::sample(&data::MNIST_S, i);
            server.infer_async(MODEL, &img).expect("admitted")
        })
        .collect();
    pending
        .into_iter()
        .map(|rx| {
            let r = rx.recv().expect("channel alive").expect("response");
            (r.logits, r.label, r.batch_occupancy)
        })
        .collect()
}

#[test]
fn prop_pipelined_serving_bitwise_equals_serial_executor() {
    // forall over policy/depth/stream shapes (full size-triggered batches:
    // composition is then deterministic, so bitwise equality must hold
    // request by request)
    forall(
        "pipeline server == serial server (bitwise)",
        |r| {
            let max_batch = 1 + r.below(6) as usize;
            let depth = (r.below(4) != 0).then(|| 1 + r.below(3) as usize);
            let waves = 1 + r.below(3) as usize;
            (max_batch, depth, waves)
        },
        |&(max_batch, depth, waves)| {
            let policy = BatchPolicy {
                max_batch,
                max_delay: Duration::from_secs(10), // size-triggered only
                max_queue: 4096,
            };
            let stream: Vec<u64> = (0..(max_batch * waves) as u64).collect();
            let serial = start(EngineKind::Native, policy, None);
            let want = serve_stream(&serial, &stream);
            serial.shutdown();
            let pipelined = start(EngineKind::Pipeline, policy, depth);
            let got = serve_stream(&pipelined, &stream);
            pipelined.shutdown();
            for (i, (w, g)) in want.iter().zip(&got).enumerate() {
                if w.2 != g.2 {
                    return Err(format!(
                        "request {i}: batch occupancy diverged ({} vs {})",
                        w.2, g.2
                    ));
                }
                if w.0 != g.0 || w.1 != g.1 {
                    return Err(format!(
                        "request {i}: pipelined logits diverged from serial \
                         (max_batch {max_batch}, depth {depth:?})"
                    ));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn partial_final_batch_and_max_delay_release_agree() {
    // 8 + 8 + 5: two size-triggered releases and a deadline-released tail —
    // the ragged path must stay bitwise equal too
    let policy = BatchPolicy {
        max_batch: 8,
        max_delay: Duration::from_millis(300),
        max_queue: 4096,
    };
    let stream: Vec<u64> = (0..21).collect();
    let serial = start(EngineKind::Native, policy, None);
    let want = serve_stream(&serial, &stream);
    serial.shutdown();
    let pipelined = start(EngineKind::Pipeline, policy, None);
    let got = serve_stream(&pipelined, &stream);
    pipelined.shutdown();
    assert_eq!(want.len(), got.len());
    for (i, (w, g)) in want.iter().zip(&got).enumerate() {
        assert_eq!(w.2, g.2, "request {i}: batch occupancy diverged");
        assert_eq!(w.1, g.1, "request {i}: label diverged");
        assert_eq!(w.0, g.0, "request {i}: logits diverged (bitwise)");
    }
    // the tail really was a partial, deadline-released batch
    assert_eq!(got[20].2, 5, "tail batch occupancy");
}

#[test]
fn pipelined_server_reports_stage_occupancy() {
    let policy = BatchPolicy {
        max_batch: 4,
        max_delay: Duration::from_millis(5),
        max_queue: 4096,
    };
    let server = start(EngineKind::Pipeline, policy, None);
    let stream: Vec<u64> = (0..32).collect();
    let _ = serve_stream(&server, &stream);
    let pipes = server.metrics().pipelines();
    assert_eq!(pipes.len(), 1, "one pipelined model attached");
    let (name, stats) = &pipes[0];
    assert_eq!(name, MODEL);
    let executed: u64 = stats.stages[0]
        .batches
        .load(std::sync::atomic::Ordering::Relaxed);
    assert!(executed > 0, "stage 0 saw no batches");
    assert!(
        server.metrics().summary().contains("pipeline[mnist_mlp_1]: s0="),
        "summary must carry stage occupancy: {}",
        server.metrics().summary()
    );
    // the serving-side timeline renders from the recorded events
    let text = circnn::pipeline::timeline::render(stats, 48);
    assert!(text.contains("S0 |"), "{text}");
    server.shutdown();
}

#[test]
fn prop_tracing_does_not_change_served_bits() {
    // the telemetry tentpole's overhead-neutrality pin: span tracing is
    // pure observation, so a traced server must serve bitwise identical
    // logits/labels/occupancies to an untraced one — on both engines
    forall(
        "serve --trace == serve (bitwise)",
        |r| {
            let pipelined = r.below(2) == 1;
            let max_batch = 1 + r.below(5) as usize;
            let waves = 1 + r.below(3) as usize;
            (pipelined, max_batch, waves)
        },
        |&(pipelined, max_batch, waves)| {
            let policy = BatchPolicy {
                max_batch,
                max_delay: Duration::from_secs(10), // size-triggered only
                max_queue: 4096,
            };
            let engine = if pipelined { EngineKind::Pipeline } else { EngineKind::Native };
            let stream: Vec<u64> = (0..(max_batch * waves) as u64).collect();
            let plain = start_cfg(engine, policy, None, false);
            let want = serve_stream(&plain, &stream);
            plain.shutdown();
            let traced = start_cfg(engine, policy, None, true);
            let got = serve_stream(&traced, &stream);
            let spans = traced.trace_spans();
            traced.shutdown();
            if spans.len() != stream.len() {
                return Err(format!(
                    "traced server recorded {} spans for {} requests",
                    spans.len(),
                    stream.len()
                ));
            }
            for (i, (w, g)) in want.iter().zip(&got).enumerate() {
                if w != g {
                    return Err(format!(
                        "request {i}: traced serving diverged from untraced \
                         (engine {engine:?}, max_batch {max_batch})"
                    ));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_scraping_does_not_change_served_bits() {
    // the observability-plane pin: a server being scraped concurrently —
    // text + JSON expositions and the trace document, as fast as a thread
    // can pull them — must serve bitwise identical results to an unscraped
    // one, on both engines; and the counters a scraper reads must be
    // monotone across scrapes (a scrape never perturbs the books)
    use std::sync::atomic::{AtomicBool, Ordering};
    use std::sync::Arc;

    use circnn::util::json::Json;

    forall(
        "serve under concurrent scrape == serve (bitwise)",
        |r| {
            let pipelined = r.below(2) == 1;
            let max_batch = 1 + r.below(5) as usize;
            let waves = 1 + r.below(3) as usize;
            (pipelined, max_batch, waves)
        },
        |&(pipelined, max_batch, waves)| {
            let policy = BatchPolicy {
                max_batch,
                max_delay: Duration::from_secs(10), // size-triggered only
                max_queue: 4096,
            };
            let engine = if pipelined { EngineKind::Pipeline } else { EngineKind::Native };
            let stream: Vec<u64> = (0..(max_batch * waves) as u64).collect();
            let plain = start_cfg(engine, policy, None, false);
            let want = serve_stream(&plain, &stream);
            plain.shutdown();

            let scraped = start_cfg(engine, policy, None, false);
            let frontend = scraped.frontend().expect("serving server has a frontend");
            let stop = Arc::new(AtomicBool::new(false));
            let stop_flag = stop.clone();
            let scraper = std::thread::spawn(move || {
                let mut last_requests = 0u64;
                let mut scrapes = 0u64;
                // at least one full scrape even if serving wins the race
                loop {
                    let text = frontend.metrics().export_text();
                    if !text.contains("requests_total") {
                        return Err("text exposition lost requests_total".to_string());
                    }
                    let doc = Json::parse(&frontend.metrics().export_json())
                        .map_err(|e| format!("json exposition unparseable mid-run: {e}"))?;
                    let requests = doc
                        .get("counters")
                        .and_then(|c| c.get("requests_total"))
                        .and_then(Json::as_u64)
                        .ok_or("requests_total missing from json exposition")?;
                    if requests < last_requests {
                        return Err(format!(
                            "requests_total went backwards across scrapes: \
                             {last_requests} -> {requests}"
                        ));
                    }
                    last_requests = requests;
                    Json::parse(&frontend.trace_json())
                        .map_err(|e| format!("trace document unparseable mid-run: {e}"))?;
                    scrapes += 1;
                    if stop_flag.load(Ordering::SeqCst) {
                        return Ok(scrapes);
                    }
                }
            });
            let got = serve_stream(&scraped, &stream);
            stop.store(true, Ordering::SeqCst);
            // join before shutdown: the scraper's Frontend must drop for
            // the executor to drain
            let scrapes = scraper
                .join()
                .map_err(|_| "scraper thread panicked".to_string())??;
            scraped.shutdown();
            if scrapes == 0 {
                return Err("scraper never completed a scrape".to_string());
            }
            for (i, (w, g)) in want.iter().zip(&got).enumerate() {
                if w != g {
                    return Err(format!(
                        "request {i}: serving under scrape diverged from unscraped \
                         (engine {engine:?}, max_batch {max_batch}, {scrapes} scrapes)"
                    ));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn traced_server_renders_waterfall_and_telemetry_json() {
    let policy = BatchPolicy {
        max_batch: 4,
        max_delay: Duration::from_millis(5),
        max_queue: 4096,
    };
    let server = start_cfg(EngineKind::Pipeline, policy, None, true);
    let stream: Vec<u64> = (0..16).collect();
    let _ = serve_stream(&server, &stream);
    assert!(server.tracing());

    let waterfall = server.trace_waterfall(80).expect("tracing server renders a waterfall");
    assert!(waterfall.contains("span waterfall"), "{waterfall}");
    assert!(waterfall.contains("16 spans"), "{waterfall}");

    // the --trace-dump payload: metrics exposition + span records, one doc
    let dump = server.telemetry_json();
    let json = circnn::util::json::Json::parse(&dump).expect("telemetry dump parses");
    let requests = json
        .get("metrics")
        .and_then(|m| m.get("counters"))
        .and_then(|c| c.get("requests_total"))
        .and_then(|v| v.as_u64())
        .expect("requests_total in the dump");
    assert_eq!(requests, 16);
    assert!(
        json.get("metrics")
            .and_then(|m| m.get("histograms"))
            .and_then(|h| h.get("queue_wait_us"))
            .is_some(),
        "queue_wait_us histogram in the dump"
    );
    let spans = json.get("spans").and_then(|s| s.as_arr()).expect("spans array");
    assert_eq!(spans.len(), 16, "one span per request");
    server.shutdown();

    // an untraced server exposes metrics but no waterfall
    let plain = start(EngineKind::Native, policy, None);
    assert!(!plain.tracing());
    assert!(plain.trace_waterfall(80).is_none());
    plain.shutdown();
}

#[test]
fn dropping_server_with_inflight_batches_joins_and_answers() {
    // implicit teardown (Drop, not shutdown()) while batches are still in
    // flight: the executor and every stage worker must join, and every
    // admitted request must still get an answer — no worker leaks, no
    // dropped response channels
    let policy = BatchPolicy {
        max_batch: 4,
        max_delay: Duration::from_secs(5),
        max_queue: 4096,
    };
    let server = start(EngineKind::Pipeline, policy, Some(2));
    let (img, _) = data::sample(&data::MNIST_S, 0);
    let pending: Vec<_> = (0..12)
        .map(|_| server.infer_async(MODEL, &img).unwrap())
        .collect();
    drop(server);
    for (i, rx) in pending.into_iter().enumerate() {
        let resp = rx.recv().expect("response channel must not be dropped");
        assert!(resp.is_ok(), "request {i} lost when the server was dropped mid-flight");
    }
}

#[test]
fn shutdown_drains_pipelined_inflight_requests() {
    // queued + in-flight work must reach clients before shutdown returns,
    // exactly as on the serial executor
    let policy = BatchPolicy {
        max_batch: 4,
        max_delay: Duration::from_secs(5), // deadline won't fire; drain must
        max_queue: 4096,
    };
    let server = start(EngineKind::Pipeline, policy, Some(2));
    let (img, _) = data::sample(&data::MNIST_S, 0);
    let pending: Vec<_> = (0..10)
        .map(|_| server.infer_async(MODEL, &img).unwrap())
        .collect();
    server.shutdown();
    for (i, rx) in pending.into_iter().enumerate() {
        let resp = rx.recv().expect("response channel must not be dropped");
        assert!(resp.is_ok(), "queued request {i} lost during pipelined shutdown");
    }
}
