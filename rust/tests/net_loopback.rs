//! Loopback integration tests for the TCP serving front-end: a real
//! `TcpServer` on an ephemeral port, driven by the blocking [`Client`]
//! and the open-loop load generator, must serve **bitwise identical**
//! replies to the in-process [`Server`] API for identical request
//! streams — on both the native and the pipelined engine, including a
//! partial deadline-released final batch, a deterministic forced
//! `Overloaded` shed, and a drain-on-shutdown.
//!
//! The first test also pins `docs/PROTOCOL.md`: every ```` ```frame ````
//! hex block in the document is re-parsed and checked byte-for-byte
//! against the encoder, so the documented wire format cannot drift from
//! the implementation.

use std::time::Duration;

use circnn::coordinator::{BatchPolicy, EngineKind, Server, ServerConfig};
use circnn::data;
use circnn::net::protocol::{
    decode_frame, encode_admin, encode_admin_reply, encode_reply, encode_request, Frame,
};
use circnn::net::{
    AdminFrame, AdminKind, AdminReplyFrame, Arrival, Client, LoadConfig, NetConfig, ReplyFrame,
    RequestFrame, Status, TcpServer,
};
use circnn::runtime::Manifest;
use circnn::util::json::Json;

const MODEL: &str = "mnist_mlp_1";
const INPUT: u32 = 784;

fn manifest_for(model: &str) -> Manifest {
    let mut man = Manifest::synthetic();
    man.models.retain(|m| m.name == model);
    assert_eq!(man.models.len(), 1, "{model} missing from the registry");
    man
}

fn start(engine: EngineKind, policy: BatchPolicy) -> Server {
    Server::start_with_manifest(
        manifest_for(MODEL),
        ServerConfig {
            policy,
            engine,
            depth: None,
            init_random_fallback: true,
            ..ServerConfig::default()
        },
    )
    .expect("server start")
}

/// (logit bit patterns, label, occupancy) — the bitwise comparison key.
type Served = (Vec<u32>, u32, u32);

fn bits(logits: &[f32]) -> Vec<u32> {
    logits.iter().map(|v| v.to_bits()).collect()
}

/// In-process twin: submit `stream` (sample indices) from one thread,
/// collect responses in order.
fn serve_inprocess(server: &Server, stream: &[u64]) -> Vec<Served> {
    let pending: Vec<_> = stream
        .iter()
        .map(|&i| {
            let (img, _) = data::sample(&data::MNIST_S, i);
            server.infer_async(MODEL, &img).expect("admitted")
        })
        .collect();
    pending
        .into_iter()
        .map(|rx| {
            let r = rx.recv().expect("channel alive").expect("response");
            (bits(&r.logits), r.label, r.batch_occupancy as u32)
        })
        .collect()
}

/// TCP path: pipeline the whole stream down one warm connection, then
/// read the replies back in order.
fn serve_tcp(addr: std::net::SocketAddr, stream: &[u64]) -> Vec<Served> {
    let mut client = Client::connect(addr).expect("connect");
    for &i in stream {
        let (img, _) = data::sample(&data::MNIST_S, i);
        client.send(MODEL, &[INPUT], img).expect("send");
    }
    stream
        .iter()
        .enumerate()
        .map(|(i, _)| {
            let rep = client.recv().expect("reply");
            assert_eq!(rep.id, i as u64, "replies must come back in request order");
            assert_eq!(rep.status, Status::Ok, "request {i}: {}", rep.message);
            (bits(&rep.logits), rep.label, rep.occupancy)
        })
        .collect()
}

/// Parse every ```frame block of `docs/PROTOCOL.md` into raw bytes
/// (lines are `offset  hex bytes  | annotation`).
fn documented_frames() -> Vec<Vec<u8>> {
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../docs/PROTOCOL.md");
    let text = std::fs::read_to_string(path).expect("docs/PROTOCOL.md exists");
    let mut frames = Vec::new();
    let mut current: Option<Vec<u8>> = None;
    for line in text.lines() {
        if line.trim_start().starts_with("```frame") {
            current = Some(Vec::new());
            continue;
        }
        match (&mut current, line.trim_start().starts_with("```")) {
            (Some(bytes), true) => {
                frames.push(std::mem::take(bytes));
                current = None;
            }
            (Some(bytes), false) => {
                let hex = line.split('|').next().unwrap_or("");
                for tok in hex.split_whitespace().skip(1) {
                    bytes.push(
                        u8::from_str_radix(tok, 16)
                            .unwrap_or_else(|_| panic!("bad hex token {tok:?} in PROTOCOL.md")),
                    );
                }
            }
            (None, _) => {}
        }
    }
    frames
}

#[test]
fn documented_example_frames_decode_byte_exactly() {
    let frames = documented_frames();
    assert_eq!(frames.len(), 5, "PROTOCOL.md documents five example frames");

    let request = RequestFrame {
        id: 1,
        model: "demo".into(),
        dims: vec![2, 2],
        payload: vec![0.0, 0.5, -1.0, 2.0],
    };
    assert_eq!(encode_request(&request), frames[0], "request example bytes drifted");
    assert_eq!(decode_frame(&frames[0]).unwrap(), Frame::Request(request));

    let ok = ReplyFrame {
        id: 1,
        status: Status::Ok,
        label: 3,
        occupancy: 8,
        logits: vec![0.25, -0.75],
        message: String::new(),
    };
    assert_eq!(encode_reply(&ok), frames[1], "Ok-reply example bytes drifted");
    assert_eq!(decode_frame(&frames[1]).unwrap(), Frame::Reply(ok));

    let shed = ReplyFrame::error(2, Status::Overloaded, "shed");
    assert_eq!(encode_reply(&shed), frames[2], "Overloaded example bytes drifted");
    assert_eq!(decode_frame(&frames[2]).unwrap(), Frame::Reply(shed));

    let admin = AdminFrame { id: 7, kind: AdminKind::Health };
    assert_eq!(encode_admin(&admin), frames[3], "admin example bytes drifted");
    assert_eq!(decode_frame(&frames[3]).unwrap(), Frame::Admin(admin));

    let admin_reply = AdminReplyFrame {
        id: 7,
        kind: AdminKind::Health,
        body: "{\"status\":\"ok\",\"draining\":false}".into(),
    };
    assert_eq!(encode_admin_reply(&admin_reply), frames[4], "admin-reply example bytes drifted");
    assert_eq!(decode_frame(&frames[4]).unwrap(), Frame::AdminReply(admin_reply));
}

#[test]
fn admin_frames_scrape_the_wire_without_a_second_socket() {
    // Four inference round trips interleaved with admin scrapes on the
    // *same* connection: the scrape documents must reflect the served
    // work, ride the FIFO reply order, and count only in net_admin_total.
    let policy = BatchPolicy {
        max_batch: 4,
        max_delay: Duration::from_millis(2),
        max_queue: 4096,
    };
    let tcp = TcpServer::start(start(EngineKind::Native, policy), NetConfig::default())
        .expect("tcp start");
    let mut client = Client::connect(tcp.local_addr()).expect("connect");
    for i in 0..4u64 {
        let (img, _) = data::sample(&data::MNIST_S, i);
        let rep = client.infer(MODEL, &[INPUT], img).expect("round trip");
        assert_eq!(rep.status, Status::Ok, "request {i}: {}", rep.message);
    }

    let text = client.admin(AdminKind::MetricsText).expect("metrics text");
    assert_eq!(text.kind, AdminKind::MetricsText);
    assert!(text.body.contains("requests_total"), "prometheus text names the counters");

    let json = client.admin(AdminKind::MetricsJson).expect("metrics json");
    let doc = Json::parse(&json.body).expect("metrics json parses");
    let served = doc
        .get("counters")
        .and_then(|c| c.get("requests_total"))
        .and_then(|v| v.as_u64())
        .expect("requests_total present");
    assert_eq!(served, 4, "scrape sees the four served requests");

    let trace = client.admin(AdminKind::TraceJson).expect("trace json");
    let tdoc = Json::parse(&trace.body).expect("trace json parses");
    assert_eq!(tdoc.get("truncated").and_then(|v| v.as_u64()), Some(0));
    assert_eq!(
        tdoc.get("spans").and_then(|v| v.as_arr()).map(|a| a.len()),
        Some(0),
        "tracing is off, so the span array is empty"
    );

    let health = client.admin(AdminKind::Health).expect("health");
    assert!(health.body.contains("\"draining\":false"), "live server reports not draining");

    let net = &tcp.server().metrics().net;
    assert_eq!(net.admin.get(), 4, "four admin replies written");
    assert_eq!(net.frames_rx.get(), 8, "four inference + four admin frames read");
    tcp.shutdown().shutdown();
}

#[test]
fn tcp_serving_is_bitwise_identical_to_inprocess_on_both_engines() {
    // 8 + 8 + 5: two size-triggered releases and a deadline-released
    // partial tail, exactly the pipeline_serve.rs ragged stream
    let policy = BatchPolicy {
        max_batch: 8,
        max_delay: Duration::from_millis(300),
        max_queue: 4096,
    };
    let stream: Vec<u64> = (0..21).collect();
    for engine in [EngineKind::Native, EngineKind::Pipeline] {
        let twin = start(engine, policy);
        let want = serve_inprocess(&twin, &stream);
        twin.shutdown();

        let tcp = TcpServer::start(start(engine, policy), NetConfig::default()).expect("tcp start");
        let got = serve_tcp(tcp.local_addr(), &stream);

        let net = &tcp.server().metrics().net;
        assert_eq!(net.connections.get(), 1, "one client connection");
        assert_eq!(net.frames_rx.get(), stream.len() as u64);
        assert_eq!(net.frames_tx.get(), stream.len() as u64);
        assert!(net.bytes_rx.get() > 0 && net.bytes_tx.get() > 0);
        assert_eq!(net.overloaded.get(), 0);
        tcp.shutdown().shutdown();

        assert_eq!(want.len(), got.len());
        for (i, (w, g)) in want.iter().zip(&got).enumerate() {
            assert_eq!(w, g, "request {i} ({engine:?}): TCP reply diverged from in-process");
        }
        assert_eq!(got[20].2, 5, "tail batch occupancy ({engine:?})");
    }
}

#[test]
fn inflight_cap_sheds_deterministically_and_admitted_bits_match_twin() {
    // One connection, in-flight cap 4, six back-to-back requests against
    // a deadline that cannot fire before the frames land: requests 0-3
    // are admitted (and ride one deadline batch of 4), requests 4-5 see
    // inflight == cap while the writer is still parked on reply 0, so
    // both shed with an explicit Overloaded reply.
    let policy = BatchPolicy {
        max_batch: 64,
        max_delay: Duration::from_millis(1200),
        max_queue: 4096,
    };
    let twin = start(EngineKind::Native, policy);
    let want = serve_inprocess(&twin, &[0, 1, 2, 3]);
    twin.shutdown();

    let net_cfg = NetConfig { max_inflight: 4, ..NetConfig::default() };
    let tcp = TcpServer::start(start(EngineKind::Native, policy), net_cfg).expect("tcp start");
    let mut client = Client::connect(tcp.local_addr()).expect("connect");
    for i in 0..6u64 {
        let (img, _) = data::sample(&data::MNIST_S, i);
        client.send(MODEL, &[INPUT], img).expect("send");
    }
    let replies: Vec<_> = (0..6).map(|_| client.recv().expect("reply")).collect();

    for (i, rep) in replies[..4].iter().enumerate() {
        assert_eq!(rep.id, i as u64);
        assert_eq!(rep.status, Status::Ok, "admitted request {i}: {}", rep.message);
        let got = (bits(&rep.logits), rep.label, rep.occupancy);
        assert_eq!(got, want[i], "admitted request {i} diverged from the in-process twin");
        assert_eq!(rep.occupancy, 4, "admitted requests ride one deadline batch");
    }
    for (i, rep) in replies[4..].iter().enumerate() {
        assert_eq!(rep.status, Status::Overloaded, "request {} must shed", i + 4);
        assert!(rep.logits.is_empty() && rep.label == 0);
    }
    assert_eq!(tcp.server().metrics().net.overloaded.get(), 2);
    tcp.shutdown().shutdown();
}

#[test]
fn shutdown_drains_admitted_requests() {
    // five requests sit queued behind a deadline that will never fire;
    // shutdown must execute and answer all of them before sockets close
    let policy = BatchPolicy {
        max_batch: 8,
        max_delay: Duration::from_secs(10),
        max_queue: 4096,
    };
    let tcp = TcpServer::start(start(EngineKind::Native, policy), NetConfig::default())
        .expect("tcp start");
    let mut client = Client::connect(tcp.local_addr()).expect("connect");
    for i in 0..5u64 {
        let (img, _) = data::sample(&data::MNIST_S, i);
        client.send(MODEL, &[INPUT], img).expect("send");
    }
    // let the reader decode and admit all five frames
    std::thread::sleep(Duration::from_millis(400));
    let server = tcp.shutdown();
    assert_eq!(server.metrics().net.frames_rx.get(), 5);
    assert_eq!(server.metrics().net.frames_tx.get(), 5, "drain must answer every frame");
    assert_eq!(server.metrics().net.connections_open.get(), 0, "writers closed out");
    server.shutdown();

    // the replies were flushed before the socket closed
    for i in 0..5 {
        let rep = client.recv().expect("drained reply");
        assert_eq!(rep.id, i as u64);
        assert_eq!(rep.status, Status::Ok, "drained request {i}: {}", rep.message);
        assert_eq!(rep.occupancy, 5, "all five drained as one partial batch");
    }
    assert!(client.recv().is_err(), "connection closes after the drain");
}

#[test]
fn loadgen_drives_tcp_server_open_loop() {
    let policy = BatchPolicy {
        max_batch: 8,
        max_delay: Duration::from_millis(2),
        max_queue: 4096,
    };
    let tcp = TcpServer::start(start(EngineKind::Native, policy), NetConfig::default())
        .expect("tcp start");
    let cfg = LoadConfig {
        model: MODEL.into(),
        dims: vec![INPUT],
        requests: 64,
        rate: 2000.0,
        arrival: Arrival::Poisson,
        warm: 2,
        cold: 1,
        seed: 0xC1C1,
    };
    let sample = |i: u64| data::sample(&data::MNIST_S, i).0;
    let report = circnn::net::loadgen::run_tcp(tcp.local_addr(), &cfg, &sample);
    assert_eq!(report.sent, 64, "open loop sends every scheduled request");
    assert_eq!(report.ok + report.overloaded + report.errors, 64);
    assert_eq!(report.errors, 0, "no transport/protocol errors on loopback");
    assert_eq!(report.ok, 64, "uncontended server answers everything");
    assert!(report.p50_us > 0 && report.p50_us <= report.p95_us && report.p95_us <= report.p99_us);

    // two warm connections plus one fresh connection per cold-slot request
    let net = &tcp.server().metrics().net;
    assert!(net.connections.get() > 2, "cold slot must open per-request connections");
    assert_eq!(net.frames_rx.get(), 64);
    tcp.shutdown().shutdown();
}
