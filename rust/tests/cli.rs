//! Launcher tests: drive the `circnn` binary as a subprocess the way a
//! user would — every experiment subcommand, the simulator flags, and the
//! error paths (unknown command/model, missing flags).

use std::process::{Command, Output};

fn circnn(args: &[&str]) -> Output {
    Command::new(env!("CARGO_BIN_EXE_circnn"))
        .args(args)
        .current_dir(env!("CARGO_MANIFEST_DIR"))
        .output()
        .expect("spawn circnn")
}

fn stdout(out: &Output) -> String {
    String::from_utf8_lossy(&out.stdout).into_owned()
}

#[test]
fn table1_prints_all_rows_and_headline() {
    let out = circnn(&["table1"]);
    assert!(out.status.success());
    let text = stdout(&out);
    for needle in [
        "proposed_mnist_mlp_1",
        "proposed_cifar_wrn",
        "truenorth_mnist_99",
        "finn_mnist",
        "alemdar_mnist",
        "headline ratios",
    ] {
        assert!(text.contains(needle), "table1 output missing {needle:?}");
    }
}

#[test]
fn fig3_fig6_analog_ablations_precision_render() {
    for (cmd, needle) in [
        ("fig3", "Dense(B)"),
        ("fig6", "eq GOPS/W"),
        ("analog", "isaac_isca16"),
        ("ablations", "AB1_decoupling"),
        ("precision", "matvec SNR"),
    ] {
        let out = circnn(&[cmd]);
        assert!(out.status.success(), "{cmd} failed: {}", String::from_utf8_lossy(&out.stderr));
        assert!(stdout(&out).contains(needle), "{cmd} output missing {needle:?}");
    }
}

#[test]
fn simulate_flags_change_the_design_point() {
    let base = stdout(&circnn(&["simulate", "--model", "mnist_mlp_1"]));
    assert!(base.contains("kFPS"));
    let no_dec = stdout(&circnn(&["simulate", "--model", "mnist_mlp_1", "--no-decouple"]));
    let kfps = |s: &str| -> f64 {
        s.lines()
            .find(|l| l.starts_with("kFPS "))
            .and_then(|l| l.split_whitespace().nth(1))
            .and_then(|v| v.parse().ok())
            .expect("kFPS line")
    };
    assert!(kfps(&base) > kfps(&no_dec), "AB1 must cost throughput via the CLI too");
}

#[test]
fn simulate_timeline_renders_fig4() {
    let out = circnn(&["simulate", "--model", "mnist_lenet", "--timeline"]);
    assert!(out.status.success());
    let text = stdout(&out);
    assert!(text.contains("cycles/batch"));
    assert!(text.contains("M"), "multiply phase missing from timeline");
}

#[test]
fn codesign_selects_a_feasible_point() {
    let out = circnn(&["codesign", "--model", "mnist_mlp_1", "--min-accuracy", "0.95"]);
    assert!(out.status.success());
    let text = stdout(&out);
    assert!(text.contains("<- selected"));
    assert!(text.contains("accuracy >= 95.0%"));
}

#[test]
fn models_lists_registry() {
    let text = stdout(&circnn(&["models"]));
    for name in ["mnist_mlp_1", "mnist_mlp_2", "mnist_lenet", "svhn_cnn", "cifar_cnn", "cifar_wrn"]
    {
        assert!(text.contains(name), "models output missing {name}");
    }
}

#[test]
fn error_paths_exit_nonzero_with_messages() {
    let out = circnn(&["frobnicate"]);
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("unknown command"));

    let out = circnn(&["simulate", "--model", "resnet_9000"]);
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("unknown model"));

    let out = circnn(&["help"]);
    assert!(out.status.success());
    assert!(stdout(&out).contains("circnn"));
}

#[test]
fn train_demo_native_runs_on_default_features() {
    // the native spectral-domain trainer needs no artifacts and no PJRT;
    // --engine native also pins the path when built with --features pjrt
    let out = circnn(&[
        "train-demo", "--engine", "native", "--model", "mnist_mlp_1", "--steps", "3", "--batch",
        "8",
    ]);
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = stdout(&out);
    assert!(text.contains("training mnist_mlp_1 for 3 steps (batch 8)"), "{text}");
    assert!(text.contains("loss"), "loss curve missing: {text}");
    assert!(text.contains("test accuracy"), "eval line missing: {text}");
}

#[test]
fn infer_native_runs_without_pjrt_server_path() {
    // needs artifacts; skip quietly when absent
    if !std::path::Path::new(concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts/manifest.json"))
        .exists()
    {
        eprintln!("SKIP: artifacts missing");
        return;
    }
    let out = circnn(&[
        "infer", "--model", "mnist_mlp_1", "--engine", "native", "--count", "64",
    ]);
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = stdout(&out);
    assert!(text.contains("native block-circulant engine"));
    assert!(text.contains("accuracy"));
}
