//! Registry of the six Table-1 networks: architecture, block sizes, and the
//! parameter / storage / operation accounting shared with the Python
//! manifest (`python/compile/model.py` — the two sides must agree; pinned by
//! `rust/tests/integration.rs` against `artifacts/manifest.json`).
//!
//! The accounting feeds everything downstream: Fig. 3 (storage reduction),
//! Fig. 6 (equivalent GOPS normalization), and the FPGA simulator's workload
//! description (FFT / multiply / IFFT counts per layer, exp T1/AB*).

/// One layer of a registry model.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Layer {
    /// Block-circulant FC: n -> m with block size k.
    BcDense { n: usize, m: usize, k: usize },
    /// Uncompressed FC (classifier heads).
    Dense { n: usize, m: usize },
    /// Block-circulant CONV: c -> p channels, r x r kernel, block size k.
    BcConv { c: usize, p: usize, r: usize, k: usize, same_pad: bool },
    /// Uncompressed CONV (stem layers).
    Conv { c: usize, p: usize, r: usize, same_pad: bool },
    AvgPool2,
    MaxPool2,
    Flatten,
    /// The paper's input-size reduction for the MNIST MLPs.
    PriorPool { out_dim: usize },
    ResidualBegin,
    ResidualEnd,
}

/// A Table-1 model with its paper row.
#[derive(Debug, Clone)]
pub struct Model {
    pub name: &'static str,
    pub dataset: &'static str,
    pub input: (usize, usize, usize),
    pub layers: Vec<Layer>,
    /// serving batch (the paper's 50-100 interleaved pictures)
    pub serve_batch: usize,
    pub paper_accuracy: f64,
    pub paper_kfps: f64,
    pub paper_kfps_per_w: f64,
}

/// Per-layer accounting row (mirrors `model.accounting`).
#[derive(Debug, Clone)]
pub struct LayerAccount {
    pub kind: &'static str,
    pub k: usize,
    pub dense_params: u64,
    pub circ_params: u64,
    pub dense_macs: u64,
    pub circ_mults: u64,
    /// FFT workload for the simulator: (q rFFTs, p*q*kh complex mults,
    /// p IFFTs) per image under decoupling, times spatial positions for conv
    pub fft_work: FftWork,
}

/// The decoupled FFT workload of one layer *per image* — the quantity the
/// FPGA schedule simulates (exp T1, AB1, AB2).
///
/// Decoupling (the paper's pre-calculation of `FFT(x_j)` for re-use) means:
/// * FC: q input FFTs + p output IFFTs (not p*q of each);
/// * CONV: one FFT per input channel-block per *input pixel* — every pixel's
///   spectrum is shared by all r^2 patch taps that touch it — plus one IFFT
///   per output channel-block per output pixel.  For `same_pad` layers the
///   substrate walks the padded `(h+r-1) x (w+r-1)` grid but *skips* the
///   all-zero border spectra (they are identically zero, so the skip is
///   bitwise invisible): only the `h*w` interior pixels are charged here,
///   and `native::staged`'s conv parity test pins these counts against the
///   transforms `native::conv` actually executes.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct FftWork {
    pub k: usize,
    /// input-block FFT transforms per image under decoupling
    pub ffts_total: u64,
    /// output-block IFFT transforms per image under decoupling
    pub iffts_total: u64,
    /// complex multiply-accumulate groups per image (each k/2+1 lanes under
    /// the real-symmetry optimization, k lanes without)
    pub mult_groups_total: u64,
    /// transforms per image for the naive (non-decoupled) evaluation:
    /// p*q per position for both FFT and IFFT
    pub naive_transforms: u64,
    /// circulant blocks in the weight grid (`p·q` for FC,
    /// `(p/k)·(c/k)·r·r` for CONV) — the unit of the per-*step* training
    /// transforms (weight-grad IFFTs, weight-spectrum refresh FFTs)
    pub weight_blocks: u64,
}

impl FftWork {
    /// The per-**step** training transform charge for a minibatch of
    /// `batch` images (zero for non-FFT layers), stated in the same three
    /// executed-work quantities the substrate counts.
    ///
    /// Convention (pinned against the trainer's executed counters by the
    /// train parity test):
    ///
    /// * **FFTs** — per image, the forward transforms the input blocks
    ///   (`ffts_total`) and the backward transforms the upstream-gradient
    ///   blocks once (`iffts_total`, shared by both Eqn.-2/3 products);
    ///   per step, the weight spectra are re-transformed once after the
    ///   update (`weight_blocks`, the paper's "offline" FFT(w) step gone
    ///   per-step).  Input spectra are *not* charged twice: the forward's
    ///   planes stay resident and the weight gradient reuses them.
    /// * **IFFTs** — per image, the forward output blocks (`iffts_total`)
    ///   and the input-gradient blocks (`ffts_total`); per step, one IFFT
    ///   per weight block for `dL/dw` — the weight gradient accumulates in
    ///   the frequency domain across the whole batch, so its transforms
    ///   amortize over the batch instead of scaling with it.
    /// * **multiply groups** — 3x the forward count: forward `W∘X`,
    ///   input-gradient `conj(W)∘G`, weight-gradient `conj(X)∘G`.
    pub fn train_charge(&self, batch: u64) -> crate::circulant::sched::PhaseCounters {
        let per_image = self.ffts_total + self.iffts_total;
        crate::circulant::sched::PhaseCounters {
            ffts: batch * per_image + self.weight_blocks,
            iffts: batch * per_image + self.weight_blocks,
            mult_groups: 3 * batch * self.mult_groups_total,
        }
    }
}

fn log2(k: usize) -> u64 {
    (usize::BITS - 1 - k.leading_zeros()) as u64
}

/// Real mults of one k-point *real* transform under the paper's cost model
/// (matches `FftPlan::real_mults`): the packed real-input fast path runs a
/// k/2-point complex FFT (4 real mults per butterfly, k/4 butterflies per
/// stage, `log2(k) - 1` stages) plus one complex twiddle multiply per
/// half-spectrum bin in the untangle sweep.
pub fn fft_real_mults(k: usize) -> u64 {
    let k64 = k as u64;
    k64 * log2(k).saturating_sub(1) + 4 * (k64 / 2 + 1)
}

impl Model {
    /// Per-layer accounting (weight layers only).
    pub fn accounting(&self) -> Vec<LayerAccount> {
        let (mut h, mut w, _) = self.input;
        let mut rows = Vec::new();
        for layer in &self.layers {
            match *layer {
                Layer::PriorPool { out_dim } => {
                    h = out_dim;
                    w = 1;
                }
                Layer::AvgPool2 | Layer::MaxPool2 => {
                    h /= 2;
                    w /= 2;
                }
                Layer::Conv { c, p, r, same_pad } => {
                    let (oh, ow) = if same_pad { (h, w) } else { (h - r + 1, w - r + 1) };
                    let dp = (r * r * c * p) as u64;
                    rows.push(LayerAccount {
                        kind: "conv",
                        k: 0,
                        dense_params: dp,
                        circ_params: dp,
                        dense_macs: (oh * ow) as u64 * dp,
                        circ_mults: (oh * ow) as u64 * dp,
                        fft_work: FftWork::default(),
                    });
                    h = oh;
                    w = ow;
                }
                Layer::BcConv { c, p, r, k, same_pad } => {
                    let (oh, ow) = if same_pad { (h, w) } else { (h - r + 1, w - r + 1) };
                    let kh = (k / 2 + 1) as u64;
                    let qb = ((c / k) * r * r) as u64;
                    let pb = (p / k) as u64;
                    let cb = (c / k) as u64;
                    let dp = (r * r * c * p) as u64;
                    let fm = fft_real_mults(k);
                    // decoupled: each input pixel's channel-block spectrum is
                    // computed once and re-used by every patch tap touching
                    // it.  h*w is the count for both pad modes: under
                    // same_pad the substrate skips the padded grid's
                    // all-zero border spectra, leaving exactly the h*w
                    // interior pixels it transforms (conv parity test).
                    let ffts_total = cb * (h * w) as u64;
                    let iffts_total = pb * (oh * ow) as u64;
                    let mult_groups_total = pb * qb * (oh * ow) as u64;
                    rows.push(LayerAccount {
                        kind: "bc_conv",
                        k,
                        dense_params: dp,
                        circ_params: pb * qb * k as u64,
                        dense_macs: (oh * ow) as u64 * dp,
                        circ_mults: ffts_total * fm
                            + mult_groups_total * kh * 4
                            + iffts_total * fm,
                        fft_work: FftWork {
                            k,
                            ffts_total,
                            iffts_total,
                            mult_groups_total,
                            naive_transforms: pb * qb * (oh * ow) as u64,
                            weight_blocks: pb * qb,
                        },
                    });
                    h = oh;
                    w = ow;
                }
                Layer::Dense { n, m } => {
                    let dp = (n * m) as u64;
                    rows.push(LayerAccount {
                        kind: "dense",
                        k: 0,
                        dense_params: dp,
                        circ_params: dp,
                        dense_macs: dp,
                        circ_mults: dp,
                        fft_work: FftWork::default(),
                    });
                }
                Layer::BcDense { n, m, k } => {
                    let kh = (k / 2 + 1) as u64;
                    let (pb, qb) = ((m / k) as u64, (n / k) as u64);
                    let dp = (n * m) as u64;
                    let fm = fft_real_mults(k);
                    rows.push(LayerAccount {
                        kind: "bc_dense",
                        k,
                        dense_params: dp,
                        circ_params: pb * qb * k as u64,
                        dense_macs: dp,
                        circ_mults: qb * fm + pb * qb * kh * 4 + pb * fm,
                        fft_work: FftWork {
                            k,
                            ffts_total: qb,
                            iffts_total: pb,
                            mult_groups_total: pb * qb,
                            naive_transforms: pb * qb,
                            weight_blocks: pb * qb,
                        },
                    });
                }
                Layer::Flatten | Layer::ResidualBegin | Layer::ResidualEnd => {}
            }
        }
        rows
    }

    /// Fig.-3 storage reduction: dense f32 vs circulant `bits`-bit.
    pub fn storage_report(&self, bits: u64) -> StorageReport {
        let acc = self.accounting();
        let dense_bytes: u64 = acc.iter().map(|r| r.dense_params).sum::<u64>() * 4;
        let circ_bytes: u64 =
            acc.iter().map(|r| r.circ_params).sum::<u64>() * bits / 8;
        StorageReport {
            dense_bytes,
            circ_bytes,
            reduction: dense_bytes as f64 / circ_bytes.max(1) as f64,
        }
    }

    /// Dense-equivalent (mult+add) ops per image — the paper's
    /// "equivalent GOPS" normalization basis.
    pub fn equivalent_ops_per_image(&self) -> u64 {
        2 * self.accounting().iter().map(|r| r.dense_macs).sum::<u64>()
    }

    /// Actual circulant real-mults per image (the simulated workload size).
    pub fn circ_mults_per_image(&self) -> u64 {
        self.accounting().iter().map(|r| r.circ_mults).sum()
    }

    /// Activation footprint per image in bytes (largest intermediate, f32) —
    /// input to the batch-memory model.
    pub fn peak_activation_bytes(&self) -> u64 {
        let (mut h, mut w, mut c) = self.input;
        let mut peak = h * w * c;
        for layer in &self.layers {
            match *layer {
                Layer::PriorPool { out_dim } => {
                    h = out_dim;
                    w = 1;
                    c = 1;
                }
                Layer::AvgPool2 | Layer::MaxPool2 => {
                    h /= 2;
                    w /= 2;
                }
                Layer::Conv { p, r, same_pad, .. } | Layer::BcConv { p, r, same_pad, .. } => {
                    if !same_pad {
                        h -= r - 1;
                        w -= r - 1;
                    }
                    c = p;
                }
                Layer::Dense { m, .. } | Layer::BcDense { m, .. } => {
                    h = m;
                    w = 1;
                    c = 1;
                }
                Layer::Flatten => {
                    h *= w * c;
                    w = 1;
                    c = 1;
                }
                Layer::ResidualBegin | Layer::ResidualEnd => {}
            }
            peak = peak.max(h * w * c);
        }
        (peak * 4) as u64
    }
}

/// Output of [`Model::storage_report`].
#[derive(Debug, Clone, Copy)]
pub struct StorageReport {
    pub dense_bytes: u64,
    pub circ_bytes: u64,
    pub reduction: f64,
}

/// Build the registry (mirrors `model.REGISTRY`, same order).
pub fn registry() -> Vec<Model> {
    use Layer::*;
    vec![
        Model {
            name: "mnist_mlp_1",
            dataset: "mnist_s",
            input: (28, 28, 1),
            layers: vec![
                PriorPool { out_dim: 256 },
                Flatten,
                BcDense { n: 256, m: 256, k: 128 },
                Dense { n: 256, m: 10 },
            ],
            serve_batch: 64,
            paper_accuracy: 92.9,
            paper_kfps: 8.6e4,
            paper_kfps_per_w: 1.57e5,
        },
        Model {
            name: "mnist_mlp_2",
            dataset: "mnist_s",
            input: (28, 28, 1),
            layers: vec![
                PriorPool { out_dim: 128 },
                Flatten,
                BcDense { n: 128, m: 256, k: 64 },
                BcDense { n: 256, m: 256, k: 64 },
                Dense { n: 256, m: 10 },
            ],
            serve_batch: 64,
            paper_accuracy: 95.6,
            paper_kfps: 2.9e4,
            paper_kfps_per_w: 5.2e4,
        },
        Model {
            name: "mnist_lenet",
            dataset: "mnist_s",
            input: (28, 28, 1),
            layers: vec![
                Conv { c: 1, p: 8, r: 5, same_pad: false },
                AvgPool2,
                BcConv { c: 8, p: 16, r: 5, k: 4, same_pad: false },
                AvgPool2,
                Flatten,
                BcDense { n: 256, m: 128, k: 64 },
                Dense { n: 128, m: 10 },
            ],
            serve_batch: 64,
            paper_accuracy: 99.0,
            paper_kfps: 363.0,
            paper_kfps_per_w: 659.5,
        },
        Model {
            name: "svhn_cnn",
            dataset: "svhn_s",
            input: (32, 32, 3),
            layers: vec![
                Conv { c: 3, p: 16, r: 3, same_pad: true },
                MaxPool2,
                BcConv { c: 16, p: 32, r: 3, k: 8, same_pad: true },
                MaxPool2,
                BcConv { c: 32, p: 32, r: 3, k: 8, same_pad: true },
                MaxPool2,
                Flatten,
                BcDense { n: 512, m: 128, k: 64 },
                Dense { n: 128, m: 10 },
            ],
            serve_batch: 64,
            paper_accuracy: 96.2,
            paper_kfps: 384.9,
            paper_kfps_per_w: 699.7,
        },
        Model {
            name: "cifar_cnn",
            dataset: "cifar_s",
            input: (32, 32, 3),
            layers: vec![
                Conv { c: 3, p: 16, r: 3, same_pad: true },
                MaxPool2,
                BcConv { c: 16, p: 32, r: 3, k: 8, same_pad: true },
                MaxPool2,
                BcConv { c: 32, p: 32, r: 3, k: 8, same_pad: true },
                MaxPool2,
                Flatten,
                BcDense { n: 512, m: 128, k: 64 },
                Dense { n: 128, m: 10 },
            ],
            serve_batch: 64,
            paper_accuracy: 80.3,
            paper_kfps: 1383.0,
            paper_kfps_per_w: 2514.0,
        },
        Model {
            name: "cifar_wrn",
            dataset: "cifar_s",
            input: (32, 32, 3),
            layers: vec![
                Conv { c: 3, p: 32, r: 3, same_pad: true },
                MaxPool2,
                ResidualBegin,
                BcConv { c: 32, p: 32, r: 3, k: 8, same_pad: true },
                BcConv { c: 32, p: 32, r: 3, k: 8, same_pad: true },
                ResidualEnd,
                MaxPool2,
                ResidualBegin,
                BcConv { c: 32, p: 32, r: 3, k: 8, same_pad: true },
                BcConv { c: 32, p: 32, r: 3, k: 8, same_pad: true },
                ResidualEnd,
                MaxPool2,
                Flatten,
                BcDense { n: 512, m: 256, k: 64 },
                Dense { n: 256, m: 10 },
            ],
            serve_batch: 64,
            paper_accuracy: 94.75,
            paper_kfps: 13.95,
            paper_kfps_per_w: 25.4,
        },
    ]
}

/// Look up a registry model by name.
pub fn by_name(name: &str) -> Option<Model> {
    registry().into_iter().find(|m| m.name == name)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_has_six_table1_models() {
        let reg = registry();
        assert_eq!(reg.len(), 6);
        assert_eq!(reg[0].name, "mnist_mlp_1");
        assert_eq!(reg[5].paper_accuracy, 94.75);
    }

    #[test]
    fn storage_reduction_matches_python_values() {
        // Pinned against the values `make artifacts` produced (manifest.json).
        let expect = [
            ("mnist_mlp_1", 59.07),
            ("mnist_mlp_2", 65.72),
            ("mnist_lenet", 35.84),
            ("svhn_cnn", 48.38),
            ("cifar_cnn", 48.38),
            ("cifar_wrn", 45.28),
        ];
        for (name, red) in expect {
            let got = by_name(name).unwrap().storage_report(12).reduction;
            assert!(
                (got - red).abs() / red < 0.01,
                "{name}: reduction {got:.2} != {red}"
            );
        }
    }

    #[test]
    fn fft_cost_model_matches_the_substrate() {
        // the cycles the simulator charges and the arithmetic the Rust
        // substrate performs must be the same model
        for k in [2usize, 8, 64, 128, 256, 512] {
            assert_eq!(
                fft_real_mults(k),
                crate::circulant::FftPlan::shared(k).real_mults(),
                "k={k}"
            );
        }
    }

    #[test]
    fn circ_params_are_dense_over_k() {
        for m in registry() {
            for row in m.accounting() {
                if row.k > 0 {
                    assert_eq!(row.circ_params, row.dense_params / row.k as u64);
                }
            }
        }
    }

    #[test]
    fn complexity_reduced_for_compressed_layers() {
        for m in registry() {
            for row in m.accounting() {
                if row.k >= 8 {
                    assert!(row.circ_mults < row.dense_macs, "{} {:?}", m.name, row);
                }
            }
        }
    }

    #[test]
    fn decoupling_counts_fc() {
        // mnist_mlp_1 bc layer: 256x256 k=128 -> p=q=2: 2 FFTs, 2 IFFTs,
        // 4 mult groups (vs 4+4 FFT/IFFT without decoupling).
        let m = by_name("mnist_mlp_1").unwrap();
        let acc = m.accounting();
        let fw = acc[0].fft_work;
        assert_eq!(
            (fw.ffts_total, fw.iffts_total, fw.mult_groups_total, fw.naive_transforms),
            (2, 2, 4, 4)
        );
    }

    #[test]
    fn decoupling_counts_conv_reuse_input_ffts() {
        // svhn_cnn layer "bc_conv 16->32 r3 k8 same" at 16x16: decoupled
        // input FFTs = (C/k) * pixels = 2*256, far below the naive
        // (P/k)*(C/k)*r^2 per output position = 72*256.
        let m = by_name("svhn_cnn").unwrap();
        let acc = m.accounting();
        let fw = acc[1].fft_work; // first bc_conv (after the dense stem)
        assert_eq!(fw.k, 8);
        assert_eq!(fw.ffts_total, 2 * 256);
        assert_eq!(fw.iffts_total, 4 * 256);
        assert_eq!(fw.mult_groups_total, 72 * 256);
        assert_eq!(fw.naive_transforms, 72 * 256);
        assert!(fw.ffts_total < fw.naive_transforms / 10);
    }

    #[test]
    fn train_charge_convention() {
        // mnist_mlp_1 bc layer (p=q=2, 4 weight blocks), batch 8:
        // ffts = 8*(2+2) + 4 (weight-spectrum refresh), iffts = 8*(2+2) + 4
        // (amortized weight-grad irffts), mults = 3 * 8 * 4
        let m = by_name("mnist_mlp_1").unwrap();
        let fw = m.accounting()[0].fft_work;
        assert_eq!(fw.weight_blocks, 4);
        let c = fw.train_charge(8);
        assert_eq!((c.ffts, c.iffts, c.mult_groups), (36, 36, 96));
        // non-FFT layers (dense heads, conv stems) charge nothing
        let head = m.accounting()[1].fft_work;
        assert_eq!(head.train_charge(8), crate::circulant::sched::PhaseCounters::default());
        // weight-grad transforms amortize: the per-step charge at batch B
        // grows by exactly (ffts+iffts) per extra image, not by weight_blocks
        assert_eq!(fw.train_charge(9).iffts - c.iffts, fw.ffts_total + fw.iffts_total);
    }

    #[test]
    fn whole_model_fits_on_chip() {
        // Every Table-1 model at 12 bits fits the CyClone V's ~2MB BRAM.
        for m in registry() {
            let rep = m.storage_report(12);
            assert!(rep.circ_bytes < 2 * 1024 * 1024, "{}", m.name);
        }
    }

    #[test]
    fn peak_activation_small_enough_for_batching() {
        // Paper: intermediate results take several KB per picture, so a
        // batch of 50-100 fits beside the model in BRAM.
        for m in registry() {
            let act = m.peak_activation_bytes();
            assert!(act <= 128 * 1024, "{}: {act}", m.name);
        }
    }

    #[test]
    fn equivalent_ops_positive_and_ordered() {
        let mlp = by_name("mnist_mlp_1").unwrap().equivalent_ops_per_image();
        let wrn = by_name("cifar_wrn").unwrap().equivalent_ops_per_image();
        assert!(mlp > 0 && wrn > mlp);
    }
}
