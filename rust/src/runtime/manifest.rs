//! Artifact manifest: the contract between the Python AOT path and the
//! Rust request path.

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, Context};

use crate::util::json::Json;

/// One exported inference artifact (a batch-size variant of a model).
#[derive(Debug, Clone)]
pub struct ArtifactEntry {
    pub batch: usize,
    pub file: String,
    pub input_shape: Vec<usize>,
    pub output_shape: Vec<usize>,
}

/// The exported training pipeline of a model (init + step HLO).
#[derive(Debug, Clone)]
pub struct TrainingEntry {
    pub init_file: String,
    pub step_file: String,
    pub batch: usize,
    pub param_names: Vec<String>,
    /// index of the scalar loss in the train-step output tuple
    pub loss_index: usize,
}

/// Measured accuracies for one model.
#[derive(Debug, Clone, Copy)]
pub struct Accuracy {
    pub circulant_12bit: f64,
    pub circulant_f32: f64,
    pub dense_f32: f64,
}

/// Per-model manifest entry.
#[derive(Debug, Clone)]
pub struct ModelEntry {
    pub name: String,
    pub dataset: String,
    pub input_shape: Vec<usize>,
    pub serve_batch: usize,
    pub accuracy: Accuracy,
    pub paper_accuracy: f64,
    pub paper_kfps: f64,
    pub paper_kfps_per_w: f64,
    pub storage_reduction: f64,
    pub equivalent_ops_per_image: u64,
    pub artifacts: Vec<ArtifactEntry>,
    pub artifacts_pallas: Vec<ArtifactEntry>,
    pub training: Option<TrainingEntry>,
}

impl ModelEntry {
    /// The artifact for a given batch size (exact match).
    pub fn artifact_for_batch(&self, batch: usize) -> Option<&ArtifactEntry> {
        self.artifacts.iter().find(|a| a.batch == batch)
    }
}

/// Parsed `artifacts/manifest.json`.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub quant_bits: u64,
    /// mantissa width of the executed int16 fixed-point engine
    /// (`--precision fixed16`); defaults to `quant_bits` when the manifest
    /// doesn't name one
    pub fixed_bits: u64,
    pub models: Vec<ModelEntry>,
    /// dataset name -> python-side checksum (bit-exactness contract)
    pub dataset_checksums: HashMap<String, u64>,
}

fn parse_artifacts(v: &Json) -> anyhow::Result<Vec<ArtifactEntry>> {
    let mut out = Vec::new();
    for a in v.as_arr().ok_or_else(|| anyhow!("artifacts not an array"))? {
        out.push(ArtifactEntry {
            batch: a.require("batch")?.as_usize().ok_or_else(|| anyhow!("bad batch"))?,
            file: a.require("file")?.as_str().unwrap_or_default().to_string(),
            input_shape: a
                .require("input_shape")?
                .as_arr()
                .unwrap_or(&[])
                .iter()
                .filter_map(|x| x.as_usize())
                .collect(),
            output_shape: a
                .require("output_shape")?
                .as_arr()
                .unwrap_or(&[])
                .iter()
                .filter_map(|x| x.as_usize())
                .collect(),
        });
    }
    Ok(out)
}

impl Manifest {
    /// Load and validate `<dir>/manifest.json`.
    pub fn load(dir: impl AsRef<Path>) -> anyhow::Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {} (run `make artifacts`)", path.display()))?;
        let root = Json::parse(&text).map_err(|e| anyhow!("{e}"))?;

        let mut dataset_checksums = HashMap::new();
        if let Some(Json::Obj(fields)) = root.get("datasets").cloned() {
            for (name, ds) in fields {
                if let Some(cs) = ds.get("checksum").and_then(|c| c.as_str()) {
                    dataset_checksums.insert(name, cs.parse::<u64>()?);
                }
            }
        }

        let mut models = Vec::new();
        for m in root.require("models").map_err(|e| anyhow!("{e}"))?.as_arr().unwrap_or(&[]) {
            let acc = m.require("accuracy").map_err(|e| anyhow!("{e}"))?;
            let paper = m.require("paper").map_err(|e| anyhow!("{e}"))?;
            let storage = m.require("storage").map_err(|e| anyhow!("{e}"))?;
            let training = m.get("training").map(|t| -> anyhow::Result<TrainingEntry> {
                Ok(TrainingEntry {
                    init_file: t.require("init_file")?.as_str().unwrap_or_default().into(),
                    step_file: t.require("step_file")?.as_str().unwrap_or_default().into(),
                    batch: t.require("batch")?.as_usize().unwrap_or(64),
                    param_names: t
                        .require("param_names")?
                        .as_arr()
                        .unwrap_or(&[])
                        .iter()
                        .filter_map(|x| x.as_str().map(String::from))
                        .collect(),
                    loss_index: t.require("loss_index")?.as_usize().unwrap_or(0),
                })
            });
            models.push(ModelEntry {
                name: m.require("name").map_err(|e| anyhow!("{e}"))?.as_str().unwrap_or_default().into(),
                dataset: m.require("dataset").map_err(|e| anyhow!("{e}"))?.as_str().unwrap_or_default().into(),
                input_shape: m
                    .require("input_shape")
                    .map_err(|e| anyhow!("{e}"))?
                    .as_arr()
                    .unwrap_or(&[])
                    .iter()
                    .filter_map(|x| x.as_usize())
                    .collect(),
                serve_batch: m.get("serve_batch").and_then(|x| x.as_usize()).unwrap_or(64),
                accuracy: Accuracy {
                    circulant_12bit: acc.get("circulant_12bit").and_then(|x| x.as_f64()).unwrap_or(0.0),
                    circulant_f32: acc.get("circulant_f32").and_then(|x| x.as_f64()).unwrap_or(0.0),
                    dense_f32: acc.get("dense_f32").and_then(|x| x.as_f64()).unwrap_or(0.0),
                },
                paper_accuracy: paper.get("accuracy").and_then(|x| x.as_f64()).unwrap_or(0.0),
                paper_kfps: paper.get("kfps").and_then(|x| x.as_f64()).unwrap_or(0.0),
                paper_kfps_per_w: paper.get("kfps_per_w").and_then(|x| x.as_f64()).unwrap_or(0.0),
                storage_reduction: storage.get("reduction").and_then(|x| x.as_f64()).unwrap_or(0.0),
                equivalent_ops_per_image: m
                    .get("equivalent_ops_per_image")
                    .and_then(|x| x.as_u64())
                    .unwrap_or(0),
                artifacts: parse_artifacts(m.require("artifacts").map_err(|e| anyhow!("{e}"))?)?,
                artifacts_pallas: m
                    .get("artifacts_pallas")
                    .map(parse_artifacts)
                    .transpose()?
                    .unwrap_or_default(),
                training: training.transpose()?,
            });
        }

        let quant_bits = root.get("quant_bits").and_then(|x| x.as_u64()).unwrap_or(12);
        Ok(Manifest {
            dir,
            quant_bits,
            fixed_bits: root.get("fixed_bits").and_then(|x| x.as_u64()).unwrap_or(quant_bits),
            models,
            dataset_checksums,
        })
    }

    /// Model entry by name.
    pub fn model(&self, name: &str) -> anyhow::Result<&ModelEntry> {
        self.models
            .iter()
            .find(|m| m.name == name)
            .ok_or_else(|| anyhow!("model {name:?} not in manifest"))
    }

    /// Absolute path of an artifact file.
    pub fn path_of(&self, file: &str) -> PathBuf {
        self.dir.join(file)
    }

    /// Default artifacts directory: `$CIRCNN_ARTIFACTS` or `./artifacts`
    /// (read through the central knob registry in `circulant::sched`).
    pub fn default_dir() -> PathBuf {
        crate::circulant::sched::env_path("CIRCNN_ARTIFACTS", "artifacts")
    }

    /// An in-memory manifest covering the native registry — no files on
    /// disk, no compiled artifacts.  Serving from it takes the native (or
    /// pipelined-native) backend with either a params archive under
    /// `<default_dir>/params/` or the server's `init_random_fallback`;
    /// the PJRT path has nothing to execute.  This is the demo/CI serving
    /// mode (`circnn serve --synthetic`) and the test hook for
    /// `Server::start_with_manifest`.
    pub fn synthetic() -> Self {
        let models = crate::models::registry()
            .iter()
            .map(|m| ModelEntry {
                name: m.name.to_string(),
                dataset: m.dataset.to_string(),
                input_shape: vec![m.input.0, m.input.1, m.input.2],
                serve_batch: m.serve_batch,
                accuracy: Accuracy {
                    circulant_12bit: 0.0,
                    circulant_f32: 0.0,
                    dense_f32: 0.0,
                },
                paper_accuracy: m.paper_accuracy,
                paper_kfps: m.paper_kfps,
                paper_kfps_per_w: m.paper_kfps_per_w,
                storage_reduction: 0.0,
                equivalent_ops_per_image: 0,
                artifacts: Vec::new(),
                artifacts_pallas: Vec::new(),
                training: None,
            })
            .collect();
        Manifest {
            dir: Self::default_dir(),
            quant_bits: 12,
            fixed_bits: 12,
            models,
            dataset_checksums: HashMap::new(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;

    fn write_manifest(dir: &Path, body: &str) {
        let mut f = std::fs::File::create(dir.join("manifest.json")).unwrap();
        f.write_all(body.as_bytes()).unwrap();
    }

    const MINIMAL: &str = r#"{
      "version": 1, "quant_bits": 12,
      "datasets": {"mnist_s": {"checksum": "12345"}},
      "models": [{
        "name": "m", "dataset": "mnist_s", "input_shape": [28, 28, 1],
        "serve_batch": 64,
        "accuracy": {"circulant_12bit": 0.9, "circulant_f32": 0.91, "dense_f32": 0.95},
        "paper": {"accuracy": 92.9, "kfps": 86000.0, "kfps_per_w": 157000.0},
        "storage": {"dense_bytes": 100, "circ_bytes": 2, "reduction": 50.0},
        "equivalent_ops_per_image": 1000,
        "artifacts": [{"batch": 1, "file": "m_b1.hlo.txt",
                       "input_shape": [1,28,28,1], "output_shape": [1,10]}]
      }]
    }"#;

    #[test]
    fn parses_minimal_manifest() {
        let dir = std::env::temp_dir().join(format!("circnn_man_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        write_manifest(&dir, MINIMAL);
        let man = Manifest::load(&dir).unwrap();
        assert_eq!(man.quant_bits, 12);
        assert_eq!(man.fixed_bits, 12, "fixed_bits defaults to quant_bits");
        assert_eq!(man.dataset_checksums["mnist_s"], 12345);
        let m = man.model("m").unwrap();
        assert_eq!(m.serve_batch, 64);
        assert_eq!(m.artifact_for_batch(1).unwrap().file, "m_b1.hlo.txt");
        assert!(m.artifact_for_batch(2).is_none());
        assert!(m.training.is_none());
        assert!((m.accuracy.dense_f32 - 0.95).abs() < 1e-12);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn synthetic_manifest_covers_the_registry() {
        let man = Manifest::synthetic();
        assert_eq!(man.models.len(), crate::models::registry().len());
        let m = man.model("mnist_mlp_1").unwrap();
        assert_eq!(m.input_shape, vec![28, 28, 1]);
        assert_eq!(m.input_shape.iter().product::<usize>(), 784);
        assert!(m.artifacts.is_empty(), "synthetic entries have no artifacts");
        assert_eq!(man.quant_bits, 12);
        assert_eq!(man.fixed_bits, 12);
    }

    #[test]
    fn missing_file_is_contextual_error() {
        let err = Manifest::load("/definitely/not/here").unwrap_err();
        assert!(err.to_string().contains("make artifacts"));
    }

    #[test]
    fn unknown_model_lookup_fails() {
        let dir = std::env::temp_dir().join(format!("circnn_man2_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        write_manifest(&dir, MINIMAL);
        let man = Manifest::load(&dir).unwrap();
        assert!(man.model("nope").is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
