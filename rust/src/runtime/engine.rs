//! PJRT execution engine: compile-once, execute-many.
//!
//! One compiled executable per model variant plays the role of one
//! bitstream in the paper's reconfiguration story; the cache makes
//! switching variants (the router's job) free after first use.
//!
//! `PjRtClient` is `Rc`-based (not `Send`), so an [`Engine`] is owned by a
//! single thread; the coordinator gives it a dedicated executor thread and
//! feeds it batches over a channel — which also mirrors the hardware: one
//! FPGA, strictly serialized datapath.

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::rc::Rc;

use anyhow::{anyhow, Context};

/// A compiled HLO module ready to execute.
pub struct LoadedModel {
    pub path: PathBuf,
    exe: xla::PjRtLoadedExecutable,
}

impl LoadedModel {
    /// Execute with literal inputs; returns the *untupled* outputs.
    ///
    /// All our artifacts are lowered with `return_tuple=True`, so the raw
    /// result is a single tuple literal that we decompose.
    pub fn run(&self, args: &[xla::Literal]) -> anyhow::Result<Vec<xla::Literal>> {
        let result = self.exe.execute::<xla::Literal>(args)?;
        let first = result
            .first()
            .and_then(|d| d.first())
            .ok_or_else(|| anyhow!("no output buffer"))?;
        let lit = first.to_literal_sync()?;
        Ok(lit.to_tuple()?)
    }

    /// Execute and return the single (first) output, untupled.
    pub fn run1(&self, args: &[xla::Literal]) -> anyhow::Result<xla::Literal> {
        let mut outs = self.run(args)?;
        if outs.is_empty() {
            return Err(anyhow!("empty output tuple"));
        }
        Ok(outs.swap_remove(0))
    }
}

/// PJRT CPU client + executable cache.
pub struct Engine {
    client: xla::PjRtClient,
    cache: RefCell<HashMap<PathBuf, Rc<LoadedModel>>>,
}

impl Engine {
    /// Create a CPU engine (the "FPGA" of the serving stack).
    pub fn cpu() -> anyhow::Result<Self> {
        Ok(Self {
            client: xla::PjRtClient::cpu()?,
            cache: RefCell::new(HashMap::new()),
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile an HLO-text artifact (cached by path).
    pub fn load(&self, path: impl AsRef<Path>) -> anyhow::Result<Rc<LoadedModel>> {
        let path = path.as_ref().to_path_buf();
        if let Some(hit) = self.cache.borrow().get(&path) {
            return Ok(hit.clone());
        }
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )
        .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {}", path.display()))?;
        let model = Rc::new(LoadedModel {
            path: path.clone(),
            exe,
        });
        self.cache.borrow_mut().insert(path, model.clone());
        Ok(model)
    }

    /// Number of cached executables.
    pub fn cached(&self) -> usize {
        self.cache.borrow().len()
    }
}

/// Build an f32 literal of the given shape from a flat buffer.
pub fn literal_f32(data: &[f32], shape: &[usize]) -> anyhow::Result<xla::Literal> {
    let n: usize = shape.iter().product();
    if n != data.len() {
        return Err(anyhow!("shape {shape:?} wants {n} values, got {}", data.len()));
    }
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    Ok(xla::Literal::vec1(data).reshape(&dims)?)
}

/// Build an i32 literal of the given shape.
pub fn literal_i32(data: &[i32], shape: &[usize]) -> anyhow::Result<xla::Literal> {
    let n: usize = shape.iter().product();
    if n != data.len() {
        return Err(anyhow!("shape {shape:?} wants {n} values, got {}", data.len()));
    }
    if shape.is_empty() {
        return Ok(xla::Literal::scalar(data[0]));
    }
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    Ok(xla::Literal::vec1(data).reshape(&dims)?)
}

/// Extract a literal's f32 payload.
pub fn to_vec_f32(lit: &xla::Literal) -> anyhow::Result<Vec<f32>> {
    Ok(lit.to_vec::<f32>()?)
}

/// Row-wise argmax — re-exported from [`crate::util`] (its home since the
/// native engine needs it without the `pjrt` feature).
pub use crate::util::argmax_rows;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_shape_validation() {
        assert!(literal_f32(&[1.0, 2.0], &[3]).is_err());
        assert!(literal_f32(&[1.0, 2.0, 3.0, 4.0], &[2, 2]).is_ok());
        assert!(literal_i32(&[7], &[]).is_ok());
    }

    // argmax_rows tests live with the function in crate::util.
    // Engine-level tests that need the PJRT runtime + artifacts live in
    // rust/tests/runtime_roundtrip.rs.
}
