//! The PJRT runtime: load AOT HLO-text artifacts and execute them from the
//! Rust request path.
//!
//! * [`manifest`] — parse `artifacts/manifest.json` (model metadata,
//!   accuracies, accounting, artifact index, dataset checksums).  Always
//!   available: it is pure JSON over the std filesystem.
//! * [`engine`] — the `xla` crate wrapper: `PjRtClient::cpu()` →
//!   `HloModuleProto::from_text_file` → compile → execute, with an
//!   executable cache (one compiled executable per model variant ≈ one
//!   bitstream in the paper's reconfiguration story).  Gated behind the
//!   off-by-default `pjrt` cargo feature so the crate builds and serves
//!   (through [`crate::native`]) on machines without the XLA runtime.
//!
//! HLO *text* is the interchange format: the image's xla_extension 0.5.1
//! rejects jax≥0.5's 64-bit-id serialized protos, while the text parser
//! reassigns ids (see /opt/xla-example/README.md).

#[cfg(feature = "pjrt")]
pub mod engine;
pub mod manifest;

#[cfg(feature = "pjrt")]
pub use engine::Engine;
pub use manifest::Manifest;
