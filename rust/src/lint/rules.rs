//! The invariant families `circnn lint` enforces, as passes over the
//! scanned tree ([`super::source`]).  Every rule reports `file:line`
//! [`Diagnostic`]s; the fixture tree under `rust/tests/lint_fixtures/`
//! seeds one violation per rule and pins that it fires.
//!
//! | rule | invariant |
//! |---|---|
//! | `safety-comment` | every `unsafe` token carries a `// SAFETY:` (or `# Safety` doc) justification on the line or the comment block above |
//! | `simd-oracle` | every `#[target_feature]` kernel has a `*_scalar` oracle, and a test exercises the oracle against the kernel (or its dispatcher) |
//! | `dead-oracle` | every kept ordering twin (`*_serial`, `*_pixel_outer`, `*_sample_major`, `*_via_full`) is referenced by at least one test |
//! | `env-knob` | `CIRCNN_*` knobs are read through `circulant::sched` helpers and listed in the `KNOBS` registry; raw `env::var` elsewhere fails |
//! | `bench-key` | bench keys use the `_speedup_` (CI-gated) or `_ratio_` (informational) infix; the workflow gates `_speedup_` and never `_ratio_` |
//! | `request-unwrap` | no `.unwrap()`/`.expect()` in non-test `coordinator`/`pipeline`/`net` code (lock-poisoning recovery and `lint:allow(unwrap)` excepted) |
//! | `unbounded-channel` | no unbounded `mpsc::channel` in `pipeline` or `net` (backpressure must stay token/queue-bounded) |
//! | `metric-name` | telemetry registrations use literal `snake_case` names, unique crate-wide (one registering site per name — labels carry dynamic dimensions), and `*_hits`/`*_misses` pairs both exist |
//! | `docs-fresh` | every registered metric name and every `CIRCNN_*` knob in the `KNOBS` registry appears in `docs/OPERATIONS.md` (silent when the doc is absent) |

use std::collections::{BTreeSet, HashSet};
use std::fmt;

use super::source::{has_ident, FileKind, Line, LintTree, SourceFile};

/// One lint violation, rendered `file:line: [rule] message`.
#[derive(Debug, Clone, PartialEq, Eq, PartialOrd, Ord)]
pub struct Diagnostic {
    pub file: String,
    /// 1-indexed
    pub line: usize,
    pub rule: &'static str,
    pub message: String,
}

impl fmt::Display for Diagnostic {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}:{}: [{}] {}", self.file, self.line, self.rule, self.message)
    }
}

/// The kept-twin suffixes of rule `dead-oracle` — a fn named
/// `<base><suffix>` where `<base>` is also a fn in non-test code is an
/// oracle twin and must stay referenced by a test.
const TWIN_SUFFIXES: [&str; 4] = ["_serial", "_pixel_outer", "_sample_major", "_via_full"];

/// Run every rule over the tree; diagnostics come back sorted and deduped.
pub fn check(tree: &LintTree) -> Vec<Diagnostic> {
    let mut out = Vec::new();
    safety_comments(&tree.files, &mut out);
    simd_oracles(&tree.files, &mut out);
    dead_oracles(&tree.files, &mut out);
    env_knobs(&tree.files, &mut out);
    bench_keys(tree, &mut out);
    request_path(&tree.files, &mut out);
    metric_names(&tree.files, &mut out);
    docs_fresh(tree, &mut out);
    out.sort();
    out.dedup();
    out
}

fn diag(out: &mut Vec<Diagnostic>, file: &str, line: usize, rule: &'static str, message: String) {
    out.push(Diagnostic { file: file.to_string(), line: line + 1, rule, message });
}

/// `// lint:allow(<what>): reason` on the flagged line or anywhere in the
/// contiguous comment/attribute block above it suppresses the rule — the
/// audited escape hatch for construction-time invariants.
fn allowed(lines: &[Line], i: usize, marker: &str) -> bool {
    if lines[i].raw.contains(marker) {
        return true;
    }
    let mut j = i;
    while j > 0 {
        j -= 1;
        let code = lines[j].code.trim();
        let is_annotation = code.is_empty() || code.starts_with("#[") || code.starts_with("#!");
        if !is_annotation {
            return false;
        }
        if lines[j].raw.contains(marker) {
            return true;
        }
    }
    false
}

/// Names of `fn` definitions on one stripped-code line.
fn fn_defs(code: &str) -> Vec<&str> {
    let mut out = Vec::new();
    let bytes = code.as_bytes();
    let mut from = 0;
    while let Some(pos) = code[from..].find("fn") {
        let start = from + pos;
        let end = start + 2;
        from = start + 1;
        let left_ok =
            start == 0 || (!bytes[start - 1].is_ascii_alphanumeric() && bytes[start - 1] != b'_');
        let right_ok = end < bytes.len() && bytes[end] == b' ';
        if !(left_ok && right_ok) {
            continue;
        }
        let rest = code[end..].trim_start();
        let name_len = rest
            .bytes()
            .take_while(|b| b.is_ascii_alphanumeric() || *b == b'_')
            .count();
        if name_len > 0 {
            out.push(&rest[..name_len]);
        }
    }
    out
}

/// Rule `safety-comment`: every `unsafe` token in non-test code needs a
/// `SAFETY:` (or `# Safety` doc-section) justification on the same line or
/// in the contiguous comment/attribute block directly above.
fn safety_comments(files: &[SourceFile], out: &mut Vec<Diagnostic>) {
    for f in files.iter().filter(|f| f.kind != FileKind::Test) {
        for (i, line) in f.lines.iter().enumerate() {
            if line.in_test || !has_ident(&line.code, "unsafe") {
                continue;
            }
            if justified(&f.lines, i) {
                continue;
            }
            diag(
                out,
                &f.rel,
                i,
                "safety-comment",
                "`unsafe` without a `// SAFETY:` justification on the line or the \
                 comment block above"
                    .into(),
            );
        }
    }
}

fn justified(lines: &[Line], i: usize) -> bool {
    let carries = |l: &Line| l.raw.contains("SAFETY:") || l.raw.contains("# Safety");
    if carries(&lines[i]) {
        return true;
    }
    // walk up through the contiguous comment / attribute / blank block
    let mut j = i;
    while j > 0 {
        j -= 1;
        let code = lines[j].code.trim();
        let is_annotation = code.is_empty() || code.starts_with("#[") || code.starts_with("#!");
        if !is_annotation {
            return false;
        }
        if carries(&lines[j]) {
            return true;
        }
    }
    false
}

/// Rule `simd-oracle`: a `#[target_feature]` kernel `foo_avx2`/`foo_neon`
/// must have a `foo_scalar` oracle defined, and some test must exercise
/// the oracle together with the kernel or its dispatcher `foo`.
fn simd_oracles(files: &[SourceFile], out: &mut Vec<Diagnostic>) {
    let defs = non_test_fn_defs(files);
    let test_texts: Vec<String> = files.iter().map(|f| f.test_text()).collect();

    for f in files.iter().filter(|f| f.kind == FileKind::Src) {
        let mut pending_target_feature = false;
        for (i, line) in f.lines.iter().enumerate() {
            if line.in_test {
                continue;
            }
            if line.code.contains("#[target_feature") {
                pending_target_feature = true;
                continue;
            }
            let names = fn_defs(&line.code);
            if names.is_empty() || !pending_target_feature {
                continue;
            }
            pending_target_feature = false;
            let kernel = names[0];
            let base = kernel
                .strip_suffix("_avx2")
                .or_else(|| kernel.strip_suffix("_neon"))
                .unwrap_or(kernel);
            let oracle = format!("{base}_scalar");
            if !defs.contains(oracle.as_str()) {
                diag(
                    out,
                    &f.rel,
                    i,
                    "simd-oracle",
                    format!("SIMD kernel `{kernel}` has no scalar oracle `{oracle}`"),
                );
                continue;
            }
            let pinned = test_texts.iter().any(|t| {
                has_ident(t, &oracle) && (has_ident(t, kernel) || has_ident(t, base))
            });
            if !pinned {
                diag(
                    out,
                    &f.rel,
                    i,
                    "simd-oracle",
                    format!(
                        "scalar oracle `{oracle}` is never exercised against `{kernel}` \
                         (or its dispatcher `{base}`) in any test"
                    ),
                );
            }
        }
    }
}

/// Rule `dead-oracle`: a kept ordering twin must be referenced by a test.
fn dead_oracles(files: &[SourceFile], out: &mut Vec<Diagnostic>) {
    let defs = non_test_fn_defs(files);
    let test_texts: Vec<String> = files.iter().map(|f| f.test_text()).collect();

    for f in files.iter().filter(|f| f.kind == FileKind::Src) {
        for (i, line) in f.lines.iter().enumerate() {
            if line.in_test {
                continue;
            }
            for name in fn_defs(&line.code) {
                let Some(base) = TWIN_SUFFIXES
                    .iter()
                    .find_map(|s| name.strip_suffix(s))
                else {
                    continue;
                };
                // `set_serial` is a setter, not a twin: only names whose
                // base is itself a kept fn count as oracle twins
                if base.is_empty() || !defs.contains(base) {
                    continue;
                }
                if !test_texts.iter().any(|t| has_ident(t, name)) {
                    diag(
                        out,
                        &f.rel,
                        i,
                        "dead-oracle",
                        format!(
                            "oracle twin `{name}` (twin of `{base}`) is not referenced \
                             by any test — dead pin"
                        ),
                    );
                }
            }
        }
    }
}

fn non_test_fn_defs(files: &[SourceFile]) -> HashSet<String> {
    let mut defs = HashSet::new();
    for f in files.iter().filter(|f| f.kind == FileKind::Src) {
        for line in f.lines.iter().filter(|l| !l.in_test) {
            for name in fn_defs(&line.code) {
                defs.insert(name.to_string());
            }
        }
    }
    defs
}

/// Rule `env-knob`: the file defining `const KNOBS` is the only place raw
/// `env::var` may appear outside test code, and every `CIRCNN_*` string
/// literal in non-test code must be a registered knob name.
fn env_knobs(files: &[SourceFile], out: &mut Vec<Diagnostic>) {
    let registry_file = files.iter().find(|f| {
        f.kind == FileKind::Src
            && f.lines.iter().any(|l| {
                !l.in_test && has_ident(&l.code, "const") && has_ident(&l.code, "KNOBS")
            })
    });
    let registry: BTreeSet<&str> = registry_file
        .map(|f| {
            f.lines
                .iter()
                .filter(|l| !l.in_test)
                .flat_map(|l| l.strings.iter())
                .filter(|s| s.starts_with("CIRCNN_"))
                .map(String::as_str)
                .collect()
        })
        .unwrap_or_default();
    let registry_rel = registry_file.map(|f| f.rel.as_str());

    for f in files.iter().filter(|f| f.kind == FileKind::Src) {
        for (i, line) in f.lines.iter().enumerate() {
            if line.in_test {
                continue;
            }
            if line.code.contains("env::var")
                && Some(f.rel.as_str()) != registry_rel
                && !allowed(&f.lines, i, "lint:allow(env)")
            {
                diag(
                    out,
                    &f.rel,
                    i,
                    "env-knob",
                    "raw `env::var` read: route knobs through the \
                     `circulant::sched` env helpers (env_flag/env_parse/env_path)"
                        .into(),
                );
            }
            for s in line.strings.iter().filter(|s| s.starts_with("CIRCNN_")) {
                // knob names are SHOUTY literals; skip prose that merely
                // mentions a knob inside a longer message, and the bare
                // `"CIRCNN_"` prefix that prefix-matching code uses
                let name_len = s
                    .bytes()
                    .take_while(|b| b.is_ascii_uppercase() || b.is_ascii_digit() || *b == b'_')
                    .count();
                if name_len != s.len() || s.len() == "CIRCNN_".len() {
                    continue;
                }
                if !registry.contains(s.as_str()) {
                    diag(
                        out,
                        &f.rel,
                        i,
                        "env-knob",
                        format!(
                            "env knob \"{s}\" is not listed in the central KNOBS registry"
                        ),
                    );
                }
            }
        }
    }
}

/// Rule `bench-key`: derived bench keys carry exactly one of the
/// `_speedup_` / `_ratio_` infixes; `_speedup_` keys require the CI
/// workflow's `< 1.0` perf gate, and `_ratio_` keys must never be gated.
fn bench_keys(tree: &LintTree, out: &mut Vec<Diagnostic>) {
    let mut speedup_keys: Vec<(&str, usize, &str)> = Vec::new();
    for f in tree.files.iter().filter(|f| f.kind == FileKind::Bench) {
        for (i, line) in f.lines.iter().enumerate() {
            for s in &line.strings {
                if !is_key_candidate(s) {
                    continue;
                }
                let (sp, ra) = (s.contains("_speedup_"), s.contains("_ratio_"));
                match (sp, ra) {
                    (true, true) => diag(
                        out,
                        &f.rel,
                        i,
                        "bench-key",
                        format!(
                            "bench key \"{s}\" mixes the `_speedup_` (gated) and \
                             `_ratio_` (informational) markers"
                        ),
                    ),
                    (true, false) => speedup_keys.push((&f.rel, i, s)),
                    (false, true) => {}
                    (false, false) => diag(
                        out,
                        &f.rel,
                        i,
                        "bench-key",
                        format!(
                            "bench key \"{s}\" must use the `_speedup_` (CI-gated) or \
                             `_ratio_` (informational) infix"
                        ),
                    ),
                }
            }
        }
    }
    if speedup_keys.is_empty() {
        return;
    }
    match &tree.workflow {
        None => {
            for (rel, i, s) in speedup_keys {
                diag(
                    out,
                    rel,
                    i,
                    "bench-key",
                    format!("gated bench key \"{s}\": no CI workflow found to enforce the gate"),
                );
            }
        }
        Some((wf_rel, wf_lines)) => {
            let gate_ok = wf_lines
                .iter()
                .any(|l| l.contains("_speedup_") && l.contains("< 1.0"));
            if !gate_ok {
                for (rel, i, s) in speedup_keys {
                    diag(
                        out,
                        rel,
                        i,
                        "bench-key",
                        format!(
                            "gated bench key \"{s}\": {wf_rel} has no \
                             `*_speedup_* < 1.0` perf gate"
                        ),
                    );
                }
            }
            for (i, l) in wf_lines.iter().enumerate() {
                if l.contains("_ratio_") && l.contains("< 1.0") {
                    diag(
                        out,
                        wf_rel,
                        i,
                        "bench-key",
                        "informational `*_ratio_*` bench keys must not be CI-gated".into(),
                    );
                }
            }
        }
    }
}

/// A string literal is a derived-key candidate when `speedup` or `ratio`
/// appears with an underscore directly on either side — prose like
/// `"parallel speedup {x:.2}x"` is not a key.
fn is_key_candidate(s: &str) -> bool {
    for word in ["speedup", "ratio"] {
        let bytes = s.as_bytes();
        let mut from = 0;
        while let Some(pos) = s[from..].find(word) {
            let start = from + pos;
            let end = start + word.len();
            from = start + 1;
            if (start > 0 && bytes[start - 1] == b'_')
                || (end < bytes.len() && bytes[end] == b'_')
            {
                return true;
            }
        }
    }
    false
}

/// Rules `request-unwrap` + `unbounded-channel`: serving request-path
/// hygiene in `src/coordinator/`, `src/pipeline/` and `src/net/`.
fn request_path(files: &[SourceFile], out: &mut Vec<Diagnostic>) {
    for f in files.iter().filter(|f| f.kind == FileKind::Src) {
        let in_coord = f.rel.contains("src/coordinator/");
        let in_pipe = f.rel.contains("src/pipeline/");
        let in_net = f.rel.contains("src/net/");
        if !in_coord && !in_pipe && !in_net {
            continue;
        }
        for (i, line) in f.lines.iter().enumerate() {
            if line.in_test {
                continue;
            }
            let panicky = line.code.contains(".unwrap()") || line.code.contains(".expect(");
            if panicky
                && !line.code.contains(".lock()")
                && !allowed(&f.lines, i, "lint:allow(unwrap)")
            {
                diag(
                    out,
                    &f.rel,
                    i,
                    "request-unwrap",
                    "`.unwrap()`/`.expect()` on the serving request path: return a \
                     typed error, or annotate a construction-time invariant with \
                     `// lint:allow(unwrap): why`"
                        .into(),
                );
            }
            if (in_pipe || in_net)
                && has_path_token(&line.code, "mpsc::channel")
                && !allowed(&f.lines, i, "lint:allow(channel)")
            {
                diag(
                    out,
                    &f.rel,
                    i,
                    "unbounded-channel",
                    "unbounded `mpsc::channel` on the serving path: use a bounded \
                     `mpsc::sync_channel` (backpressure, never unbounded buffering)"
                        .into(),
                );
            }
        }
    }
}

/// The registration methods of `telemetry::Registry` whose first argument
/// is a metric name.  The registry's private `register_*` internals are
/// deliberately absent: the public wrappers forward non-literal arguments
/// to them, and only *call sites* of the public surface are in scope.
const METRIC_TOKENS: [&str; 7] = [
    ".counter(",
    ".counter_with(",
    ".gauge(",
    ".gauge_with(",
    ".histogram(",
    ".histogram_with(",
    ".histogram_edges(",
];

/// Rule `metric-name`: every metric registration in non-test crate code
/// uses a **literal** `snake_case` name (so the full metric namespace is
/// greppable and stable), each name has exactly one registering site
/// (dynamic dimensions belong in labels, not name suffixes), and
/// `*_hits` / `*_misses` counters come in pairs.  Deliberate re-reads of
/// an already-registered handle carry `lint:allow(metric-name)`.
fn metric_names(files: &[SourceFile], out: &mut Vec<Diagnostic>) {
    // (name, file, 0-indexed line) of every literal registration site
    let mut seen: Vec<(String, String, usize)> = Vec::new();
    for f in files.iter().filter(|f| f.kind == FileKind::Src) {
        for (i, line) in f.lines.iter().enumerate() {
            if line.in_test {
                continue;
            }
            for tok in METRIC_TOKENS {
                let mut from = 0;
                while let Some(pos) = line.code[from..].find(tok) {
                    let after = from + pos + tok.len();
                    from = after;
                    if allowed(&f.lines, i, "lint:allow(metric-name)") {
                        continue;
                    }
                    match literal_name(f, i, after) {
                        None => diag(
                            out,
                            &f.rel,
                            i,
                            "metric-name",
                            format!(
                                "metric name passed to `{}...)` must be a string literal \
                                 (dynamic dimensions belong in labels); deliberate handle \
                                 re-reads carry `lint:allow(metric-name)`",
                                tok
                            ),
                        ),
                        Some(name) => {
                            check_metric_name(&name, &f.rel, i, &mut seen, out);
                        }
                    }
                }
            }
        }
    }
    // pairing pass: a cache-style `_hits` counter without its `_misses`
    // twin (or vice versa) hides half the story
    for (name, rel, i) in &seen {
        let twin = if let Some(stem) = name.strip_suffix("_hits") {
            format!("{stem}_misses")
        } else if let Some(stem) = name.strip_suffix("_misses") {
            format!("{stem}_hits")
        } else {
            continue;
        };
        if !seen.iter().any(|(n, _, _)| n == &twin) {
            diag(
                out,
                rel,
                *i,
                "metric-name",
                format!("metric \"{name}\" has no \"{twin}\" twin — hits/misses come in pairs"),
            );
        }
    }
}

/// Validate one literal metric name and record it for the uniqueness and
/// pairing passes.
fn check_metric_name(
    name: &str,
    rel: &str,
    i: usize,
    seen: &mut Vec<(String, String, usize)>,
    out: &mut Vec<Diagnostic>,
) {
    if !is_snake_case(name) {
        diag(
            out,
            rel,
            i,
            "metric-name",
            format!("metric name \"{name}\" is not snake_case ([a-z][a-z0-9_]*, no __ runs)"),
        );
        return;
    }
    // a site is checked before it is recorded, so any hit is a prior site
    if let Some((_, prev_rel, prev_i)) = seen.iter().find(|(n, _, _)| n == name) {
        diag(
            out,
            rel,
            i,
            "metric-name",
            format!(
                "metric \"{name}\" is already registered at {prev_rel}:{} — one \
                 registering site per name (labels carry dynamic dimensions; \
                 re-reads carry `lint:allow(metric-name)`)",
                prev_i + 1
            ),
        );
        return;
    }
    seen.push((name.to_string(), rel.to_string(), i));
}

fn is_snake_case(name: &str) -> bool {
    name.starts_with(|c: char| c.is_ascii_lowercase())
        && !name.ends_with('_')
        && !name.contains("__")
        && name
            .chars()
            .all(|c| c.is_ascii_lowercase() || c.is_ascii_digit() || c == '_')
}

/// Rule `docs-fresh`: the operator's guide (`docs/OPERATIONS.md`) must
/// mention every metric name registered with the telemetry registry and
/// every `CIRCNN_*` knob listed in the `KNOBS` registry — code-level
/// observability surface cannot silently outrun its documentation.  The
/// rule is silent when the tree ships no `docs/OPERATIONS.md` (plain
/// fixture crates don't opt in); malformed or non-literal metric names
/// are `metric-name`'s concern and are skipped here.  The audited escape
/// hatch is `// lint:allow(docs-fresh): why`.
fn docs_fresh(tree: &LintTree, out: &mut Vec<Diagnostic>) {
    let Some(doc) = &tree.ops_doc else { return };

    // every literal metric registration (first site wins — duplicates are
    // metric-name's concern)
    let mut seen: BTreeSet<String> = BTreeSet::new();
    for f in tree.files.iter().filter(|f| f.kind == FileKind::Src) {
        for (i, line) in f.lines.iter().enumerate() {
            if line.in_test {
                continue;
            }
            for tok in METRIC_TOKENS {
                let mut from = 0;
                while let Some(pos) = line.code[from..].find(tok) {
                    let after = from + pos + tok.len();
                    from = after;
                    let Some(name) = literal_name(f, i, after) else { continue };
                    if !is_snake_case(&name) || !seen.insert(name.clone()) {
                        continue;
                    }
                    if allowed(&f.lines, i, "lint:allow(docs-fresh)") {
                        continue;
                    }
                    if !doc.contains(name.as_str()) {
                        diag(
                            out,
                            &f.rel,
                            i,
                            "docs-fresh",
                            format!(
                                "metric \"{name}\" is not documented in docs/OPERATIONS.md — \
                                 every registered metric belongs in the operator's guide"
                            ),
                        );
                    }
                }
            }
        }
    }

    // every knob in the KNOBS registry file (same literal filter as the
    // `env-knob` rule: full SHOUTY names only, not the bare prefix)
    let registry_file = tree.files.iter().find(|f| {
        f.kind == FileKind::Src
            && f.lines.iter().any(|l| {
                !l.in_test && has_ident(&l.code, "const") && has_ident(&l.code, "KNOBS")
            })
    });
    if let Some(f) = registry_file {
        let mut seen_knobs: BTreeSet<&str> = BTreeSet::new();
        for (i, line) in f.lines.iter().enumerate() {
            if line.in_test {
                continue;
            }
            for s in line.strings.iter().filter(|s| s.starts_with("CIRCNN_")) {
                let name_len = s
                    .bytes()
                    .take_while(|b| b.is_ascii_uppercase() || b.is_ascii_digit() || *b == b'_')
                    .count();
                if name_len != s.len() || s.len() == "CIRCNN_".len() {
                    continue;
                }
                if !seen_knobs.insert(s.as_str())
                    || allowed(&f.lines, i, "lint:allow(docs-fresh)")
                {
                    continue;
                }
                if !doc.contains(s.as_str()) {
                    diag(
                        out,
                        &f.rel,
                        i,
                        "docs-fresh",
                        format!(
                            "env knob \"{s}\" is not documented in docs/OPERATIONS.md — \
                             every registered knob belongs in the operator's guide"
                        ),
                    );
                }
            }
        }
    }
}

/// Recover the literal first argument of a registration call: the next
/// non-space character after the open paren (same line, or the first
/// following line when the call wraps) must open a string literal; its
/// contents come from the lexer's per-line string table (`line.code` keeps
/// the quotes but blanks the contents, so the n-th opening quote on a line
/// maps to `strings[n]`).  `None` = not a literal.
fn literal_name(f: &SourceFile, i: usize, after: usize) -> Option<String> {
    let mut j = i;
    let mut at = after;
    loop {
        let code = &f.lines[j].code;
        let rest = &code[at.min(code.len())..];
        let offset = rest.len() - rest.trim_start().len();
        if let Some(c) = rest.trim_start().chars().next() {
            if c != '"' {
                return None;
            }
            let quote_pos = at + offset;
            let quotes_before = code[..quote_pos].matches('"').count();
            return f.lines[j].strings.get(quotes_before / 2).cloned();
        }
        // the call wraps: the name must open the very next line
        j += 1;
        at = 0;
        if j >= f.lines.len() {
            return None;
        }
    }
}

/// `needle` (a `::`-qualified path) occurs and is not a prefix of a longer
/// identifier (`mpsc::channel` must not match `mpsc::channel_like`).
fn has_path_token(haystack: &str, needle: &str) -> bool {
    let bytes = haystack.as_bytes();
    let mut from = 0;
    while let Some(pos) = haystack[from..].find(needle) {
        let start = from + pos;
        let end = start + needle.len();
        from = start + 1;
        if end == bytes.len() || !(bytes[end].is_ascii_alphanumeric() || bytes[end] == b'_') {
            return true;
        }
    }
    false
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lint::source::scan;

    fn file(rel: &str, kind: FileKind, text: &str) -> SourceFile {
        SourceFile { rel: rel.to_string(), kind, lines: scan(text, kind) }
    }

    fn tree(files: Vec<SourceFile>) -> LintTree {
        LintTree { files, workflow: None, ops_doc: None }
    }

    fn rules_of(d: &[Diagnostic]) -> Vec<&str> {
        d.iter().map(|d| d.rule).collect()
    }

    #[test]
    fn unsafe_needs_safety_comment() {
        let bad = tree(vec![file(
            "src/a.rs",
            FileKind::Src,
            "fn f(p: *const u8) { unsafe { p.read() }; }",
        )]);
        assert_eq!(rules_of(&check(&bad)), ["safety-comment"]);
        let good = tree(vec![file(
            "src/a.rs",
            FileKind::Src,
            "fn f(p: *const u8) {\n    // SAFETY: caller guarantees p is valid\n    unsafe { p.read() };\n}",
        )]);
        assert!(check(&good).is_empty(), "{:?}", check(&good));
    }

    #[test]
    fn deny_attr_is_not_an_unsafe_token() {
        let t = tree(vec![file("src/lib.rs", FileKind::Src, "#![deny(unsafe_op_in_unsafe_fn)]")]);
        assert!(check(&t).is_empty());
    }

    #[test]
    fn unsafe_in_tests_is_exempt() {
        let t = tree(vec![file(
            "src/a.rs",
            FileKind::Src,
            "#[cfg(test)]\nmod tests {\n    fn f(p: *const u8) { unsafe { p.read() }; }\n}",
        )]);
        assert!(check(&t).is_empty());
    }

    #[test]
    fn kernel_without_oracle_or_pin_flagged() {
        let no_oracle =
            "// SAFETY: n/a\n#[target_feature(enable = \"avx2\")]\nunsafe fn frob_avx2() {}";
        let t = tree(vec![file("src/k.rs", FileKind::Src, no_oracle)]);
        let d = check(&t);
        assert_eq!(rules_of(&d), ["simd-oracle"], "{d:?}");
        assert!(d[0].message.contains("frob_scalar"));

        let unpinned = format!("{no_oracle}\nfn frob_scalar() {{}}");
        let t = tree(vec![file("src/k.rs", FileKind::Src, &unpinned)]);
        let d = check(&t);
        assert_eq!(rules_of(&d), ["simd-oracle"], "{d:?}");
        assert!(d[0].message.contains("never exercised"));

        let pinned = format!(
            "{unpinned}\nfn frob() {{}}\n#[cfg(test)]\nmod tests {{\n    fn t() {{ frob(); frob_scalar(); }}\n}}"
        );
        let t = tree(vec![file("src/k.rs", FileKind::Src, &pinned)]);
        assert!(check(&t).is_empty(), "{:?}", check(&t));
    }

    #[test]
    fn orphaned_twin_flagged_but_setters_are_not_twins() {
        let orphan = "fn walk() {}\nfn walk_serial() {}";
        let d = check(&tree(vec![file("src/t.rs", FileKind::Src, orphan)]));
        assert_eq!(rules_of(&d), ["dead-oracle"], "{d:?}");

        // no `fn set` exists, so `set_serial` is a setter, not a twin
        let setter = "fn set_serial(&mut self, on: bool) {}";
        assert!(check(&tree(vec![file("src/t.rs", FileKind::Src, setter)])).is_empty());

        // a reference from an integration test keeps the twin alive
        let lib = file("src/t.rs", FileKind::Src, orphan);
        let it = file("tests/t.rs", FileKind::Test, "fn pin() { walk_serial(); }");
        assert!(check(&tree(vec![lib, it])).is_empty());
    }

    #[test]
    fn raw_env_reads_and_unregistered_knobs_flagged() {
        let sched = file(
            "src/circulant/sched.rs",
            FileKind::Src,
            "pub const KNOBS: &[Knob] = &[Knob { name: \"CIRCNN_GOOD\", role: \"x\" }];\n\
             pub fn env_flag(n: &str) -> bool { std::env::var(n).is_ok() }",
        );
        let stray = file(
            "src/other.rs",
            FileKind::Src,
            "fn f() { let _ = std::env::var(\"CIRCNN_GOOD\"); }\n\
             fn g() -> &'static str { \"CIRCNN_ROGUE\" }",
        );
        let d = check(&tree(vec![stray, sched]));
        assert_eq!(rules_of(&d), ["env-knob", "env-knob"], "{d:?}");
        assert!(d.iter().any(|d| d.message.contains("raw `env::var`")));
        assert!(d.iter().any(|d| d.message.contains("CIRCNN_ROGUE")));
    }

    #[test]
    fn bench_key_contract() {
        let b = file(
            "benches/circulant.rs",
            FileKind::Bench,
            "fn main() {\n    let k = \"matmul_speedup_b8\";\n    let bad = \"fast_speedup8\";\n    let info = \"mac_ratio_k4\";\n}",
        );
        // gate present, ratio never gated => only the malformed key fires
        let wf = (
            "ci.yml".to_string(),
            vec!["bad = [k for k in d if \"_speedup_\" in k and v < 1.0]".to_string()],
        );
        let t = LintTree { files: vec![b], workflow: Some(wf), ops_doc: None };
        let d = check(&t);
        assert_eq!(rules_of(&d), ["bench-key"], "{d:?}");
        assert!(d[0].message.contains("fast_speedup8"));
    }

    #[test]
    fn speedup_keys_require_the_gate_and_ratio_must_stay_ungated() {
        let b = file(
            "benches/circulant.rs",
            FileKind::Bench,
            "fn main() { let k = \"x_speedup_k2\"; }",
        );
        let wf = (
            "ci.yml".to_string(),
            vec!["gate = [k for k in d if \"_ratio_\" in k and v < 1.0]".to_string()],
        );
        let t = LintTree { files: vec![b], workflow: Some(wf), ops_doc: None };
        let d = check(&t);
        assert_eq!(rules_of(&d), ["bench-key", "bench-key"], "{d:?}");
        assert!(d.iter().any(|x| x.message.contains("no `*_speedup_* < 1.0` perf gate")));
        assert!(d.iter().any(|x| x.message.contains("must not be CI-gated")));
    }

    #[test]
    fn request_path_hygiene() {
        let text = "fn serve(rx: Receiver<u8>) {\n\
                    \x20   let v = rx.recv().unwrap();\n\
                    \x20   let g = self.m.lock().unwrap();\n\
                    \x20   // lint:allow(unwrap): start-time invariant\n\
                    \x20   let h = spawn().expect(\"spawn\");\n\
                    \x20   let (tx2, rx2) = mpsc::channel();\n\
                    }";
        let d = check(&tree(vec![file("src/pipeline/engine.rs", FileKind::Src, text)]));
        assert_eq!(rules_of(&d), ["request-unwrap", "unbounded-channel"], "{d:?}");
        assert_eq!(d[0].line, 2, "the lock + annotated lines are exempt");
        // the same unwrap outside coordinator/pipeline is out of scope
        let elsewhere = check(&tree(vec![file("src/util/x.rs", FileKind::Src, text)]));
        assert!(elsewhere.is_empty(), "{elsewhere:?}");
    }

    #[test]
    fn sync_channel_is_not_unbounded() {
        let t = tree(vec![file(
            "src/pipeline/engine.rs",
            FileKind::Src,
            "fn f() { let (tx, rx) = mpsc::sync_channel::<u8>(4); }",
        )]);
        assert!(check(&t).is_empty());
    }

    #[test]
    fn net_front_end_is_on_the_request_path() {
        let text = "fn accept(rx: Receiver<u8>) {\n\
                    \x20   let v = rx.recv().unwrap();\n\
                    \x20   let (tx2, rx2) = mpsc::channel();\n\
                    }";
        let d = check(&tree(vec![file("src/net/server.rs", FileKind::Src, text)]));
        assert_eq!(rules_of(&d), ["request-unwrap", "unbounded-channel"], "{d:?}");
        // coordinator stays out of unbounded-channel scope (its response
        // channels are rendezvous by design)
        let d = check(&tree(vec![file("src/coordinator/server.rs", FileKind::Src, text)]));
        assert_eq!(rules_of(&d), ["request-unwrap"], "{d:?}");
    }

    #[test]
    fn metric_names_must_be_literal_and_snake_case() {
        let dynamic = "fn f(r: &Registry, n: &'static str) { r.counter(n); }";
        let d = check(&tree(vec![file("src/m.rs", FileKind::Src, dynamic)]));
        assert_eq!(rules_of(&d), ["metric-name"], "{d:?}");
        assert!(d[0].message.contains("string literal"));

        let camel = "fn f(r: &Registry) { r.counter(\"RequestsTotal\"); }";
        let d = check(&tree(vec![file("src/m.rs", FileKind::Src, camel)]));
        assert_eq!(rules_of(&d), ["metric-name"], "{d:?}");
        assert!(d[0].message.contains("snake_case"));

        let fine = "fn f(r: &Registry) { r.histogram_edges(\"wait_us\", &[10, 100]); }";
        assert!(check(&tree(vec![file("src/m.rs", FileKind::Src, fine)])).is_empty());
    }

    #[test]
    fn metric_names_are_unique_crate_wide_unless_allowed() {
        let first = "fn f(r: &Registry) { r.counter(\"dup_total\"); }";
        let b = file(
            "src/b.rs",
            FileKind::Src,
            "fn g(r: &Registry) { r.counter(\"dup_total\"); }",
        );
        let d = check(&tree(vec![file("src/a.rs", FileKind::Src, first), b]));
        assert_eq!(rules_of(&d), ["metric-name"], "{d:?}");
        assert!(d[0].message.contains("already registered at src/a.rs:1"), "{d:?}");

        // the audited escape hatch for deliberate handle re-reads
        let allowed = file(
            "src/b.rs",
            FileKind::Src,
            "fn g(r: &Registry) {\n    // lint:allow(metric-name): re-reading a's handle\n    r.counter(\"dup_total\");\n}",
        );
        assert!(check(&tree(vec![file("src/a.rs", FileKind::Src, first), allowed])).is_empty());
    }

    #[test]
    fn hits_require_misses_and_wrapped_calls_resolve() {
        let lonely = "fn f(r: &Registry) { r.counter(\"cache_hits\"); }";
        let d = check(&tree(vec![file("src/m.rs", FileKind::Src, lonely)]));
        assert_eq!(rules_of(&d), ["metric-name"], "{d:?}");
        assert!(d[0].message.contains("cache_misses"), "{d:?}");

        let paired =
            "fn f(r: &Registry) { r.counter(\"cache_hits\"); r.counter(\"cache_misses\"); }";
        assert!(check(&tree(vec![file("src/m.rs", FileKind::Src, paired)])).is_empty());

        // a call wrapped across lines still resolves its literal name (and
        // a second string on the same line doesn't confuse the mapping)
        let wrapped = "fn f(r: &Registry) {\n\
                       \x20   let a = r.gauge_with(\n\
                       \x20       \"wrapped_permille\",\n\
                       \x20       &[(\"model\", m.to_string())],\n\
                       \x20   );\n\
                       \x20   let b = r.counter(\"plain_total\"); let s = \"prose\";\n\
                       }";
        assert!(check(&tree(vec![file("src/m.rs", FileKind::Src, wrapped)])).is_empty());
    }

    #[test]
    fn docs_fresh_flags_undocumented_metrics_and_knobs() {
        let src = file(
            "src/m.rs",
            FileKind::Src,
            "fn f(r: &Registry) { r.counter(\"documented_total\"); r.counter(\"missing_total\"); }",
        );
        let sched = file(
            "src/circulant/sched.rs",
            FileKind::Src,
            "pub const KNOBS: &[Knob] = &[\n\
             \x20   Knob { name: \"CIRCNN_DOCUMENTED\", role: \"x\" },\n\
             \x20   Knob { name: \"CIRCNN_MISSING\", role: \"y\" },\n\
             ];",
        );
        let doc = "`documented_total` counts requests; `CIRCNN_DOCUMENTED` is a knob.";
        let t = LintTree {
            files: vec![src, sched],
            workflow: None,
            ops_doc: Some(doc.to_string()),
        };
        let d = check(&t);
        assert_eq!(rules_of(&d), ["docs-fresh", "docs-fresh"], "{d:?}");
        assert!(d.iter().any(|x| x.message.contains("missing_total")), "{d:?}");
        assert!(d.iter().any(|x| x.message.contains("CIRCNN_MISSING")), "{d:?}");
    }

    #[test]
    fn docs_fresh_is_silent_without_the_doc_and_honors_allow() {
        let reg = "fn f(r: &Registry) { r.counter(\"undoc_total\"); }";
        // no docs/OPERATIONS.md in the tree: the rule does not opt in
        assert!(check(&tree(vec![file("src/m.rs", FileKind::Src, reg)])).is_empty());

        // the audited escape hatch for internal-only metrics
        let escaped = file(
            "src/m.rs",
            FileKind::Src,
            "fn f(r: &Registry) {\n\
             \x20   // lint:allow(docs-fresh): internal-only metric\n\
             \x20   r.counter(\"undoc_total\");\n\
             }",
        );
        let t = LintTree {
            files: vec![escaped],
            workflow: None,
            ops_doc: Some("the guide".to_string()),
        };
        assert!(check(&t).is_empty(), "{:?}", check(&t));
    }
}
