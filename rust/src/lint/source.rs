//! Line-level source model for the lint pass: a from-scratch lexer that
//! classifies every line of a Rust file into code, string literals and
//! comment text, and marks `#[cfg(test)]` regions — the substrate the
//! rules in [`super::rules`] match against.
//!
//! This is deliberately *not* a Rust parser.  The invariants `circnn lint`
//! enforces are lexical (a `// SAFETY:` comment near an `unsafe` token, a
//! `CIRCNN_*` string literal, a `fn name_serial(` definition), so a
//! comment/string-aware line scanner is exactly enough — and it keeps the
//! pass dependency-free, matching the crate's from-scratch `util` ethos.
//! The scanner handles line and block comments (nested), plain and raw
//! string literals, and disambiguates char literals from lifetimes.

use std::fs;
use std::io;
use std::path::{Path, PathBuf};

/// What part of the tree a file came from — rules scope themselves by kind.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FileKind {
    /// `src/**` — library/binary code; `#[cfg(test)]` regions are tracked.
    Src,
    /// top-level `tests/*.rs` integration tests — every line is test code.
    Test,
    /// `benches/*.rs` — the bench-key contract applies here.
    Bench,
}

/// One scanned line.
#[derive(Debug)]
pub struct Line {
    /// the original text (markers, SAFETY comments and `lint:allow`
    /// annotations are matched against this)
    pub raw: String,
    /// comment-stripped text with string-literal *contents* blanked to
    /// spaces (quotes kept, so tokens never merge across a literal)
    pub code: String,
    /// contents of every string literal that starts on this line
    pub strings: Vec<String>,
    /// inside a `#[cfg(test)]` module (or a [`FileKind::Test`] file)
    pub in_test: bool,
}

/// One scanned file.
#[derive(Debug)]
pub struct SourceFile {
    /// path relative to the lint root, `/`-separated (diagnostic display)
    pub rel: String,
    pub kind: FileKind,
    pub lines: Vec<Line>,
}

impl SourceFile {
    /// Concatenated `code` of every test-region line — the unit the
    /// oracle-pinning rules search for co-occurring identifiers.
    pub fn test_text(&self) -> String {
        let mut s = String::new();
        for l in &self.lines {
            if l.in_test {
                s.push_str(&l.code);
                s.push('\n');
            }
        }
        s
    }
}

/// `needle` occurs in `haystack` as a whole identifier (neighbors are not
/// `[A-Za-z0-9_]`).  The matcher every rule uses, so `unsafe` never matches
/// `unsafe_op_in_unsafe_fn` and `complex_mul_acc` never matches
/// `complex_mul_acc_scalar`.
pub fn has_ident(haystack: &str, needle: &str) -> bool {
    if needle.is_empty() {
        return false;
    }
    let bytes = haystack.as_bytes();
    let mut from = 0;
    while let Some(pos) = haystack[from..].find(needle) {
        let start = from + pos;
        let end = start + needle.len();
        let left_ok = start == 0 || !is_ident_byte(bytes[start - 1]);
        let right_ok = end == bytes.len() || !is_ident_byte(bytes[end]);
        if left_ok && right_ok {
            return true;
        }
        from = start + 1;
    }
    false
}

fn is_ident_byte(b: u8) -> bool {
    b.is_ascii_alphanumeric() || b == b'_'
}

/// Lexer state that survives across lines.
#[derive(Default)]
struct ScanState {
    /// nesting depth of `/* */` block comments
    block_comment: usize,
    /// inside a plain `"` string
    in_str: bool,
    /// inside a raw string, with this many `#`s in the closing delimiter
    in_raw_str: Option<usize>,
}

/// Scan one file's text into classified lines with test regions marked.
pub fn scan(text: &str, kind: FileKind) -> Vec<Line> {
    let mut state = ScanState::default();
    let mut out: Vec<Line> = Vec::new();
    // test-region tracking: brace depth over stripped code, plus the depth
    // at which the innermost `#[cfg(test)] mod` opened
    let mut depth: i64 = 0;
    let mut test_region_depth: Option<i64> = None;
    // a `#[cfg(test)]` attribute waiting for its item
    let mut pending_cfg_test = false;

    for raw_line in text.lines() {
        let (code, strings) = strip_line(raw_line, &mut state);
        let depth_before = depth;
        for b in code.bytes() {
            match b {
                b'{' => depth += 1,
                b'}' => depth -= 1,
                _ => {}
            }
        }
        let trimmed = code.trim();
        let mut in_test = kind == FileKind::Test;
        if let Some(open) = test_region_depth {
            // inside an open region: every line up to and including the
            // closing brace is test code
            in_test = true;
            if depth <= open {
                test_region_depth = None;
            }
        } else {
            if trimmed.contains("#[cfg(test)]") {
                pending_cfg_test = true;
            }
            if pending_cfg_test && has_ident(&code, "mod") {
                test_region_depth = Some(depth_before);
                pending_cfg_test = false;
                in_test = true;
                if depth <= depth_before {
                    // one-line `#[cfg(test)] mod m {}`
                    test_region_depth = None;
                }
            } else if pending_cfg_test && !trimmed.is_empty() && !trimmed.starts_with("#[") {
                // the attribute attached to a non-mod item (a lone gated
                // fn); treat it conservatively as non-test and move on
                pending_cfg_test = false;
            }
        }
        out.push(Line { raw: raw_line.to_string(), code, strings, in_test });
    }
    out
}

/// Strip comments from one line (updating cross-line state), returning the
/// code text (string contents blanked, quotes kept) and the string-literal
/// contents that started on this line.
fn strip_line(line: &str, state: &mut ScanState) -> (String, Vec<String>) {
    let mut code = String::with_capacity(line.len());
    let mut strings: Vec<String> = Vec::new();
    let mut cur_str = String::new();
    let chars: Vec<char> = line.chars().collect();
    let n = chars.len();
    let mut i = 0;

    while i < n {
        let c = chars[i];
        // --- inside a block comment ---
        if state.block_comment > 0 {
            if c == '*' && i + 1 < n && chars[i + 1] == '/' {
                state.block_comment -= 1;
                i += 2;
            } else if c == '/' && i + 1 < n && chars[i + 1] == '*' {
                state.block_comment += 1;
                i += 2;
            } else {
                i += 1;
            }
            continue;
        }
        // --- inside a raw string ---
        if let Some(hashes) = state.in_raw_str {
            if c == '"'
                && chars[i + 1..].iter().take(hashes).filter(|&&h| h == '#').count() == hashes
            {
                state.in_raw_str = None;
                strings.push(std::mem::take(&mut cur_str));
                code.push('"');
                for _ in 0..hashes {
                    code.push(' ');
                }
                i += 1 + hashes;
            } else {
                cur_str.push(c);
                code.push(' ');
                i += 1;
            }
            continue;
        }
        // --- inside a plain string ---
        if state.in_str {
            if c == '\\' && i + 1 < n {
                cur_str.push(c);
                cur_str.push(chars[i + 1]);
                code.push(' ');
                code.push(' ');
                i += 2;
            } else if c == '"' {
                state.in_str = false;
                strings.push(std::mem::take(&mut cur_str));
                code.push('"');
                i += 1;
            } else {
                cur_str.push(c);
                code.push(' ');
                i += 1;
            }
            continue;
        }
        // --- normal code ---
        match c {
            '/' if i + 1 < n && chars[i + 1] == '/' => break, // line comment
            '/' if i + 1 < n && chars[i + 1] == '*' => {
                state.block_comment += 1;
                i += 2;
            }
            '"' => {
                state.in_str = true;
                code.push('"');
                i += 1;
            }
            'r' if i + 1 < n && (chars[i + 1] == '"' || chars[i + 1] == '#') => {
                // raw string candidate: r"..." or r#"..."#
                let mut j = i + 1;
                let mut hashes = 0;
                while j < n && chars[j] == '#' {
                    hashes += 1;
                    j += 1;
                }
                if j < n && chars[j] == '"' {
                    state.in_raw_str = Some(hashes);
                    code.push('r');
                    for _ in 0..hashes {
                        code.push('#');
                    }
                    code.push('"');
                    i = j + 1;
                } else {
                    code.push(c);
                    i += 1;
                }
            }
            '\'' => {
                // char literal vs lifetime: a literal is '\x', or 'c'
                // (any single char followed by a closing quote)
                if i + 1 < n && chars[i + 1] == '\\' {
                    // escaped char literal: skip to the closing quote
                    code.push('\'');
                    let mut j = i + 2;
                    while j < n && chars[j] != '\'' {
                        code.push(' ');
                        j += 1;
                    }
                    code.push('\'');
                    i = (j + 1).min(n);
                } else if i + 2 < n && chars[i + 2] == '\'' {
                    code.push('\'');
                    code.push(' ');
                    code.push('\'');
                    i += 3;
                } else {
                    // a lifetime — keep the tick, the identifier follows
                    code.push('\'');
                    i += 1;
                }
            }
            _ => {
                code.push(c);
                i += 1;
            }
        }
    }
    // a string still open at end of line continues on the next one
    if (state.in_str || state.in_raw_str.is_some()) && !cur_str.is_empty() {
        strings.push(std::mem::take(&mut cur_str));
    }
    (code, strings)
}

/// The tree layout the lint walks, resolved from a root directory.  The
/// real repo keeps the crate under `rust/`; the negative-fixture tree (and
/// any plain crate) keeps `src`/`benches` at the root — both are accepted.
pub struct LintTree {
    pub files: Vec<SourceFile>,
    /// the CI workflow, when present: (relative path, raw lines)
    pub workflow: Option<(String, Vec<String>)>,
    /// `docs/OPERATIONS.md`, when present — the docs-fresh rule checks
    /// every registered metric name and `CIRCNN_*` knob appears in it
    pub ops_doc: Option<String>,
}

/// Walk `root` and scan every relevant file.  Scanned: `src/**/*.rs`
/// (recursive), top-level `tests/*.rs` (the fixture subtrees under
/// `tests/` are *not* cargo targets and are not scanned), `benches/*.rs`,
/// and the CI workflow (`.github/workflows/ci.yml`, or `ci.yml` at the
/// root for fixture trees).
pub fn collect(root: &Path) -> io::Result<LintTree> {
    let crate_dir = if root.join("rust/src").is_dir() {
        root.join("rust")
    } else {
        root.to_path_buf()
    };
    let mut files = Vec::new();
    let src = crate_dir.join("src");
    if src.is_dir() {
        let mut paths = Vec::new();
        walk_rs(&src, &mut paths)?;
        for p in paths {
            files.push(read_one(root, &p, FileKind::Src)?);
        }
    }
    for (dir, kind) in [("tests", FileKind::Test), ("benches", FileKind::Bench)] {
        let d = crate_dir.join(dir);
        if d.is_dir() {
            for p in top_level_rs(&d)? {
                files.push(read_one(root, &p, kind)?);
            }
        }
    }
    files.sort_by(|a, b| a.rel.cmp(&b.rel));

    let workflow = [root.join(".github/workflows/ci.yml"), root.join("ci.yml")]
        .into_iter()
        .find(|p| p.is_file())
        .map(|p| -> io::Result<_> {
            let text = fs::read_to_string(&p)?;
            Ok((rel_display(root, &p), text.lines().map(str::to_string).collect()))
        })
        .transpose()?;

    let ops_doc = fs::read_to_string(root.join("docs/OPERATIONS.md")).ok();

    Ok(LintTree { files, workflow, ops_doc })
}

fn read_one(root: &Path, path: &Path, kind: FileKind) -> io::Result<SourceFile> {
    let text = fs::read_to_string(path)?;
    Ok(SourceFile { rel: rel_display(root, path), kind, lines: scan(&text, kind) })
}

fn rel_display(root: &Path, path: &Path) -> String {
    let rel = path.strip_prefix(root).unwrap_or(path);
    rel.to_string_lossy().replace('\\', "/")
}

/// All `.rs` files under `dir`, recursively, sorted for determinism.
fn walk_rs(dir: &Path, out: &mut Vec<PathBuf>) -> io::Result<()> {
    let mut entries: Vec<_> = fs::read_dir(dir)?.collect::<io::Result<_>>()?;
    entries.sort_by_key(|e| e.file_name());
    for e in entries {
        let p = e.path();
        if p.is_dir() {
            walk_rs(&p, out)?;
        } else if p.extension().is_some_and(|x| x == "rs") {
            out.push(p);
        }
    }
    Ok(())
}

/// `.rs` files directly in `dir` (non-recursive), sorted.
fn top_level_rs(dir: &Path) -> io::Result<Vec<PathBuf>> {
    let mut out: Vec<_> = fs::read_dir(dir)?
        .collect::<io::Result<Vec<_>>>()?
        .into_iter()
        .map(|e| e.path())
        .filter(|p| p.is_file() && p.extension().is_some_and(|x| x == "rs"))
        .collect();
    out.sort();
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn comments_and_strings_are_separated() {
        let lines = scan(
            "let x = \"unsafe in a string\"; // unsafe in a comment\nunsafe { x }",
            FileKind::Src,
        );
        assert!(!has_ident(&lines[0].code, "unsafe"), "{}", lines[0].code);
        assert_eq!(lines[0].strings, vec!["unsafe in a string".to_string()]);
        assert!(has_ident(&lines[1].code, "unsafe"));
    }

    #[test]
    fn ident_boundaries_respected() {
        assert!(!has_ident("deny(unsafe_op_in_unsafe_fn)", "unsafe"));
        assert!(!has_ident("complex_mul_acc_scalar(a)", "complex_mul_acc"));
        assert!(has_ident("complex_mul_acc(a)", "complex_mul_acc"));
        assert!(has_ident("unsafe { }", "unsafe"));
    }

    #[test]
    fn char_literals_do_not_open_strings() {
        let lines = scan("let c = '\"'; let d = 'x'; let r = &'a str;", FileKind::Src);
        assert!(lines[0].strings.is_empty(), "{:?}", lines[0].strings);
        assert!(lines[0].code.contains("str"));
    }

    #[test]
    fn block_comments_span_lines() {
        let lines = scan("/* start\n unsafe here\n*/ let a = 1;", FileKind::Src);
        assert!(!has_ident(&lines[1].code, "unsafe"));
        assert!(lines[2].code.contains("let a = 1;"));
    }

    #[test]
    fn cfg_test_regions_are_marked() {
        let text =
            "fn live() {}\n#[cfg(test)]\nmod tests {\n    fn t() { live(); }\n}\nfn after() {}";
        let lines = scan(text, FileKind::Src);
        assert!(!lines[0].in_test);
        assert!(lines[2].in_test && lines[3].in_test && lines[4].in_test);
        assert!(!lines[5].in_test, "region must close at its brace");
    }

    #[test]
    fn raw_strings_are_blanked() {
        let lines = scan("let p = r\"unsafe \\ path\";", FileKind::Src);
        assert!(!has_ident(&lines[0].code, "unsafe"));
        assert_eq!(lines[0].strings, vec!["unsafe \\ path".to_string()]);
    }
}
