//! `circnn lint` — a repo-invariant static-analysis pass over the crate's
//! own sources, dependency-free by construction.
//!
//! Six PRs of SIMD-kernel and pipelined-concurrency work rest on
//! conventions that nothing used to check: `unsafe` blocks justified by
//! `// SAFETY:` comments, `#[target_feature]` kernels pinned bitwise to
//! `*_scalar` oracles, ordering twins kept alive by tests, `CIRCNN_*`
//! knobs routed through the [`crate::circulant::sched`] registry, the
//! bench-JSON `_speedup_`/`_ratio_` key contract matched against the CI
//! gate, no panicking calls or unbounded channels on the serving
//! request path (coordinator, pipeline, and the TCP front-end alike),
//! metric names that are literal snake_case strings registered at
//! exactly one site each (see [`crate::telemetry`] for the naming
//! contract the `metric-name` rule enforces), and — since the serving
//! front-end — documentation freshness: every registered metric and
//! every `CIRCNN_*` knob must appear in `docs/OPERATIONS.md` (the
//! `docs-fresh` rule). This module turns each convention into a
//! machine-checked
//! rule (see [`rules`] for the full table) built on a line-level
//! lexer/scanner ([`source`]) that strips comments, blanks string-literal
//! contents, and tracks `#[cfg(test)]` regions — no syn, no regex, no
//! external dependencies.
//!
//! Diagnostics render as `file:line: [rule] message` and any violation
//! makes `circnn lint` exit non-zero, so the pass runs as a blocking CI
//! job. The negative fixtures under `rust/tests/lint_fixtures/` seed one
//! violation per rule and `tests/lint_rules.rs` pins that each is caught
//! at the expected `file:line` — and that the merged tree itself lints
//! clean.

pub mod rules;
pub mod source;

use std::io;
use std::path::Path;

pub use rules::Diagnostic;
pub use source::{FileKind, LintTree, SourceFile};

/// Result of one lint pass.
#[derive(Debug)]
pub struct LintReport {
    /// sorted by (file, line, rule), deduplicated
    pub diagnostics: Vec<Diagnostic>,
    /// how many `.rs` files were scanned
    pub files_scanned: usize,
}

impl LintReport {
    pub fn is_clean(&self) -> bool {
        self.diagnostics.is_empty()
    }
}

/// Scan the tree rooted at `root` (the repo root, or the crate directory —
/// [`source::collect`] finds `rust/` underneath either) and run every rule.
pub fn run(root: &Path) -> io::Result<LintReport> {
    let tree = source::collect(root)?;
    let diagnostics = rules::check(&tree);
    Ok(LintReport { diagnostics, files_scanned: tree.files.len() })
}
