//! Native inference engine: the trained models executed by the pure-Rust
//! block-circulant substrate — no PJRT, no XLA, no Python.
//!
//! This is the *functional twin* of the FPGA datapath the simulator
//! (`crate::fpga`) costs: the same decoupled three-phase procedure
//! (q rFFTs → half-spectrum multiply-accumulate → p IFFTs, spectra
//! precomputed offline), the same 12-bit fake-quantized arithmetic, walking
//! the same layer program. It loads the parameters the Python training
//! pipeline wrote (`artifacts/params/*.npz` via [`crate::util::npz`]) and
//! must agree with the AOT HLO artifacts executed through PJRT
//! (`rust/tests/native_parity.rs`) — which pins that the simulator's cycle
//! accounting walks a datapath that computes the right numbers.
//!
//! It also serves as a deployment path of its own: inference on targets
//! where the 40 MB xla_extension runtime is unavailable (the paper's
//! embedded setting), at O(n log n) cost and O(n) weight memory.

pub mod conv;
pub mod staged;

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{anyhow, bail, Context};

use crate::circulant::{dense, im2col, BlockCirculant};
use crate::data;
use crate::models::{Layer, Model};
use crate::util::npz::{self, Array};

/// The paper's datapath precision.
pub const QUANT_BITS: u32 = 12;

/// Activation tensor flowing through the program: `(batch, h, w, c)` when
/// spatial, `(batch, d)` after flatten/FC (h=d, w=c=1 then).
#[derive(Debug, Clone)]
pub struct Tensor {
    pub batch: usize,
    pub h: usize,
    pub w: usize,
    pub c: usize,
    pub data: Vec<f32>,
}

impl Tensor {
    fn per_image(&self) -> usize {
        self.h * self.w * self.c
    }
}

/// One executable layer with its (quantized) parameters baked in.
enum Op {
    /// spectra precomputed — the paper's offline FFT(w) step
    BcDense { bc: BlockCirculant, bias: Vec<f32>, relu: bool },
    Dense { w: Vec<f32>, n: usize, m: usize, bias: Vec<f32>, relu: bool },
    BcConv { bc: BlockCirculant, bias: Vec<f32>, r: usize, same: bool, relu: bool },
    Conv { f: Vec<f32>, bias: Vec<f32>, c: usize, p: usize, r: usize, same: bool, relu: bool },
    AvgPool2,
    MaxPool2,
    Flatten,
    PriorPool { out_dim: usize },
    ResidualBegin,
    ResidualEnd,
}

/// A model compiled to the native substrate.
pub struct NativeModel {
    pub name: String,
    ops: Vec<Op>,
    quant_bits: Option<u32>,
}

/// Quantize a whole tensor in place (per-tensor max-abs symmetric grid),
/// mirroring `layers.fake_quant` — a no-op when `bits` is `None`.
fn maybe_quant(x: &mut [f32], bits: Option<u32>) {
    if let Some(b) = bits {
        crate::circulant::quant::fake_quant(x, b);
    }
}

fn take<'a>(
    params: &'a BTreeMap<String, Array>,
    idx: usize,
    field: &str,
) -> anyhow::Result<&'a Array> {
    let key = format!("L{idx:02}_{field}");
    params
        .get(&key)
        .ok_or_else(|| anyhow!("parameter {key} missing from archive"))
}

impl NativeModel {
    /// Compile `model` against a parameter archive (the `.npz` the Python
    /// training pipeline wrote). `quant_bits = Some(12)` reproduces the
    /// AOT artifacts' arithmetic; `None` runs float32.
    pub fn load(
        model: &Model,
        params_path: impl AsRef<Path>,
        quant_bits: Option<u32>,
    ) -> anyhow::Result<Self> {
        let params = npz::load_npz(&params_path)
            .map_err(|e| anyhow!("{e}"))
            .with_context(|| format!("loading {}", params_path.as_ref().display()))?;
        Self::from_params(model, &params, quant_bits)
    }

    /// Compile from already-loaded arrays (testing hook).
    pub fn from_params(
        model: &Model,
        params: &BTreeMap<String, Array>,
        quant_bits: Option<u32>,
    ) -> anyhow::Result<Self> {
        let mut ops = Vec::with_capacity(model.layers.len());
        for (i, layer) in model.layers.iter().enumerate() {
            // activation convention of the registry (python model.py): every
            // weight layer is relu except the classifier head (`Dense`) and
            // a BC-conv feeding straight into a residual join.
            let next_is_join = matches!(model.layers.get(i + 1), Some(Layer::ResidualEnd));
            let op = match *layer {
                Layer::BcDense { n, m, k } => {
                    let w = take(params, i, "w")?;
                    if w.shape != [m / k, n / k, k] {
                        bail!("L{i:02}_w: shape {:?} != ({},{},{})", w.shape, m / k, n / k, k);
                    }
                    let mut wv = w.data.clone();
                    maybe_quant(&mut wv, quant_bits);
                    let mut bc = BlockCirculant::new(m / k, n / k, k, wv);
                    bc.precompute();
                    Op::BcDense { bc, bias: take(params, i, "b")?.data.clone(), relu: true }
                }
                Layer::Dense { n, m } => {
                    let w = take(params, i, "w")?;
                    if w.shape != [n, m] {
                        bail!("L{i:02}_w: shape {:?} != ({n},{m})", w.shape);
                    }
                    let mut wv = w.data.clone();
                    maybe_quant(&mut wv, quant_bits);
                    // classifier heads carry no activation in the registry
                    Op::Dense { w: wv, n, m, bias: take(params, i, "b")?.data.clone(), relu: false }
                }
                Layer::BcConv { c, p, r, k, same_pad } => {
                    let w = take(params, i, "w")?;
                    let (pb, qb) = (p / k, (c / k) * r * r);
                    if w.shape != [pb, qb, k] {
                        bail!("L{i:02}_w: shape {:?} != ({pb},{qb},{k})", w.shape);
                    }
                    let mut wv = w.data.clone();
                    maybe_quant(&mut wv, quant_bits);
                    let mut bc = BlockCirculant::new(pb, qb, k, wv);
                    bc.precompute();
                    Op::BcConv {
                        bc,
                        bias: take(params, i, "b")?.data.clone(),
                        r,
                        same: same_pad,
                        relu: !next_is_join,
                    }
                }
                Layer::Conv { c, p, r, same_pad } => {
                    let w = take(params, i, "w")?;
                    if w.shape != [r, r, c, p] {
                        bail!("L{i:02}_w: shape {:?} != ({r},{r},{c},{p})", w.shape);
                    }
                    let mut f = w.data.clone();
                    maybe_quant(&mut f, quant_bits);
                    Op::Conv {
                        f,
                        bias: take(params, i, "b")?.data.clone(),
                        c,
                        p,
                        r,
                        same: same_pad,
                        relu: !next_is_join,
                    }
                }
                Layer::AvgPool2 => Op::AvgPool2,
                Layer::MaxPool2 => Op::MaxPool2,
                Layer::Flatten => Op::Flatten,
                Layer::PriorPool { out_dim } => Op::PriorPool { out_dim },
                Layer::ResidualBegin => Op::ResidualBegin,
                Layer::ResidualEnd => Op::ResidualEnd,
            };
            ops.push(op);
        }
        Ok(Self { name: model.name.to_string(), ops, quant_bits })
    }

    /// Forward a batch of raw images `(batch, h, w, c)` to logits
    /// `(batch, 10)`.
    pub fn forward(&self, images: &[f32], batch: usize, h: usize, w: usize, c: usize) -> Vec<f32> {
        assert_eq!(images.len(), batch * h * w * c, "image buffer size");
        let mut x = Tensor { batch, h, w, c, data: images.to_vec() };
        let mut residuals: Vec<Tensor> = Vec::new();
        for op in &self.ops {
            x = self.step(op, x, &mut residuals);
        }
        debug_assert!(residuals.is_empty(), "unbalanced residual markers");
        x.data
    }

    fn step(&self, op: &Op, mut x: Tensor, residuals: &mut Vec<Tensor>) -> Tensor {
        match op {
            Op::PriorPool { out_dim } => {
                let per = x.per_image();
                let mut out = Vec::with_capacity(x.batch * out_dim);
                for b in 0..x.batch {
                    out.extend(data::prior_pool(&x.data[b * per..(b + 1) * per], *out_dim));
                }
                Tensor { batch: x.batch, h: *out_dim, w: 1, c: 1, data: out }
            }
            Op::Flatten => {
                let d = x.per_image();
                Tensor { batch: x.batch, h: d, w: 1, c: 1, data: x.data }
            }
            Op::AvgPool2 | Op::MaxPool2 => {
                let avg = matches!(op, Op::AvgPool2);
                let (oh, ow) = (x.h / 2, x.w / 2);
                let mut out = vec![0.0f32; x.batch * oh * ow * x.c];
                for b in 0..x.batch {
                    for oy in 0..oh {
                        for ox in 0..ow {
                            for ch in 0..x.c {
                                let at = |dy: usize, dx: usize| {
                                    x.data[((b * x.h + 2 * oy + dy) * x.w + 2 * ox + dx) * x.c + ch]
                                };
                                let (a, bb, cc, d) = (at(0, 0), at(0, 1), at(1, 0), at(1, 1));
                                out[((b * oh + oy) * ow + ox) * x.c + ch] = if avg {
                                    0.25 * (a + bb + cc + d)
                                } else {
                                    a.max(bb).max(cc).max(d)
                                };
                            }
                        }
                    }
                }
                Tensor { batch: x.batch, h: oh, w: ow, c: x.c, data: out }
            }
            Op::ResidualBegin => {
                residuals.push(x.clone());
                x
            }
            Op::ResidualEnd => {
                let saved = residuals.pop().expect("residual_begin missing");
                debug_assert_eq!(saved.data.len(), x.data.len());
                for (v, s) in x.data.iter_mut().zip(&saved.data) {
                    *v = (*v + s).max(0.0); // join + relu, as in model.apply
                }
                x
            }
            Op::BcDense { bc, bias, relu } => {
                maybe_quant(&mut x.data, self.quant_bits);
                let (n, m) = (bc.cols(), bc.rows());
                debug_assert_eq!(x.per_image(), n);
                let mut out = vec![0.0f32; x.batch * m];
                bc.matmul(&x.data, x.batch, &mut out);
                finish_rows(&mut out, bias, m, *relu);
                Tensor { batch: x.batch, h: m, w: 1, c: 1, data: out }
            }
            Op::Dense { w, n, m, bias, relu } => {
                maybe_quant(&mut x.data, self.quant_bits);
                debug_assert_eq!(x.per_image(), *n);
                let mut out = vec![0.0f32; x.batch * m];
                // python convention: y = x @ W with W (n, m)
                for b in 0..x.batch {
                    let xi = &x.data[b * n..(b + 1) * n];
                    let yo = &mut out[b * m..(b + 1) * m];
                    for (i, &xv) in xi.iter().enumerate() {
                        if xv == 0.0 {
                            continue; // post-relu activations are sparse
                        }
                        let wr = &w[i * m..(i + 1) * m];
                        for (y, &wv) in yo.iter_mut().zip(wr) {
                            *y += xv * wv;
                        }
                    }
                }
                finish_rows(&mut out, bias, *m, *relu);
                Tensor { batch: x.batch, h: *m, w: 1, c: 1, data: out }
            }
            Op::BcConv { bc, bias, r, same, relu } => {
                maybe_quant(&mut x.data, self.quant_bits);
                // the decoupled three-phase CONV schedule, batch- and
                // pixel-parallel — see native::conv for the full story
                let shape =
                    conv::ConvShape { h: x.h, w: x.w, c: x.c, r: *r, same: *same };
                let o = conv::forward(bc, &x.data, x.batch, shape, bias, *relu);
                Tensor { batch: x.batch, h: o.oh, w: o.ow, c: bc.rows(), data: o.data }
            }
            Op::Conv { f, bias, c, p, r, same, relu } => {
                maybe_quant(&mut x.data, self.quant_bits);
                let per = x.per_image();
                let mut out = Vec::new();
                let (mut oh, mut ow) = (0, 0);
                for b in 0..x.batch {
                    let img = &x.data[b * per..(b + 1) * per];
                    let (padded, ih, iw);
                    let src: &[f32] = if *same {
                        (padded, ih, iw) = im2col::pad_same(img, x.h, x.w, x.c, *r);
                        &padded
                    } else {
                        (ih, iw) = (x.h, x.w);
                        img
                    };
                    (oh, ow) = (ih - r + 1, iw - r + 1);
                    if out.is_empty() {
                        out = vec![0.0f32; x.batch * oh * ow * p];
                    }
                    for oy in 0..oh {
                        for ox in 0..ow {
                            let dst = ((b * oh + oy) * ow + ox) * p;
                            for i in 0..*r {
                                for j in 0..*r {
                                    for ch in 0..*c {
                                        let xv = src[((oy + i) * iw + ox + j) * c + ch];
                                        if xv == 0.0 {
                                            continue;
                                        }
                                        let fr = &f[((i * r + j) * c + ch) * p..][..*p];
                                        for (y, &w) in out[dst..dst + p].iter_mut().zip(fr) {
                                            *y += xv * w;
                                        }
                                    }
                                }
                            }
                        }
                    }
                }
                finish_rows(&mut out, bias, *p, *relu);
                Tensor { batch: x.batch, h: oh, w: ow, c: *p, data: out }
            }
        }
    }

    /// Classify a batch: forward + row-wise argmax.
    pub fn classify(&self, images: &[f32], batch: usize, h: usize, w: usize, c: usize) -> Vec<u32> {
        let logits = self.forward(images, batch, h, w, c);
        let classes = logits.len() / batch;
        crate::util::argmax_rows(&logits, classes)
    }
}

/// Add bias + optional relu over `(rows, m)`-shaped data.
fn finish_rows(data: &mut [f32], bias: &[f32], m: usize, relu: bool) {
    if !bias.is_empty() {
        for row in data.chunks_mut(m) {
            dense::add_bias(row, bias);
        }
    }
    if relu {
        dense::relu(data);
    }
}
