//! Native inference engine: the trained models executed by the pure-Rust
//! block-circulant substrate — no PJRT, no XLA, no Python.
//!
//! This is the *functional twin* of the FPGA datapath the simulator
//! (`crate::fpga`) costs: the same decoupled three-phase procedure
//! (q rFFTs → half-spectrum multiply-accumulate → p IFFTs, spectra
//! precomputed offline), the same 12-bit fake-quantized arithmetic, walking
//! the same layer program. It loads the parameters the Python training
//! pipeline wrote (`artifacts/params/*.npz` via [`crate::util::npz`]) and
//! must agree with the AOT HLO artifacts executed through PJRT
//! (`rust/tests/native_parity.rs`) — which pins that the simulator's cycle
//! accounting walks a datapath that computes the right numbers.
//!
//! It also serves as a deployment path of its own: inference on targets
//! where the 40 MB xla_extension runtime is unavailable (the paper's
//! embedded setting), at O(n log n) cost and O(n) weight memory.

pub mod conv;
pub mod staged;

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{anyhow, bail, Context};

use crate::circulant::{dense, im2col, quant, BlockCirculant, Precision};
use crate::data;
use crate::models::{Layer, Model};
use crate::util::npz::{self, Array};

/// The paper's datapath precision.
pub const QUANT_BITS: u32 = 12;

/// Activation tensor flowing through the program: `(batch, h, w, c)` when
/// spatial, `(batch, d)` after flatten/FC (h=d, w=c=1 then).
#[derive(Debug, Clone)]
pub struct Tensor {
    pub batch: usize,
    pub h: usize,
    pub w: usize,
    pub c: usize,
    pub data: Vec<f32>,
}

impl Tensor {
    /// Elements per image (`h * w * c`).
    pub fn per_image(&self) -> usize {
        self.h * self.w * self.c
    }
}

/// One executable layer with its (quantized) parameters baked in.
/// `pub(crate)` so the training subsystem (`crate::train`) can walk and
/// update the same program the inference path executes.
pub(crate) enum Op {
    /// spectra precomputed — the paper's offline FFT(w) step
    BcDense { bc: BlockCirculant, bias: Vec<f32>, relu: bool },
    Dense { w: Vec<f32>, n: usize, m: usize, bias: Vec<f32>, relu: bool },
    BcConv { bc: BlockCirculant, bias: Vec<f32>, r: usize, same: bool, relu: bool },
    Conv { f: Vec<f32>, bias: Vec<f32>, c: usize, p: usize, r: usize, same: bool, relu: bool },
    AvgPool2,
    MaxPool2,
    Flatten,
    PriorPool { out_dim: usize },
    ResidualBegin,
    ResidualEnd,
}

impl Op {
    /// Weight-bearing (FFT/MAC-heavy) ops — these anchor the stages of the
    /// serving-side layer pipeline (`crate::pipeline::PipelinePlan`).
    pub(crate) fn is_weight(&self) -> bool {
        matches!(
            self,
            Op::BcDense { .. } | Op::Dense { .. } | Op::BcConv { .. } | Op::Conv { .. }
        )
    }

    /// Stable short name (accounting/stage-label vocabulary).
    pub(crate) fn kind_name(&self) -> &'static str {
        match self {
            Op::BcDense { .. } => "bc_dense",
            Op::Dense { .. } => "dense",
            Op::BcConv { .. } => "bc_conv",
            Op::Conv { .. } => "conv",
            Op::AvgPool2 => "avg_pool",
            Op::MaxPool2 => "max_pool",
            Op::Flatten => "flatten",
            Op::PriorPool { .. } => "prior_pool",
            Op::ResidualBegin => "residual_begin",
            Op::ResidualEnd => "residual_end",
        }
    }
}

/// A model compiled to the native substrate.
pub struct NativeModel {
    pub name: String,
    pub(crate) ops: Vec<Op>,
    pub(crate) quant_bits: Option<u32>,
    /// executed MAC datapath for the block-circulant layers; dense heads
    /// and unstructured conv stems always run f32 (they are not the
    /// spectral engine the paper's fixed-point claim is about)
    pub(crate) precision: Precision,
}

/// Quantize a whole tensor in place (per-tensor max-abs symmetric grid),
/// mirroring `layers.fake_quant` — a no-op when `bits` is `None`.
fn maybe_quant(x: &mut [f32], bits: Option<u32>) {
    if let Some(b) = bits {
        crate::circulant::quant::fake_quant(x, b);
    }
}

fn take<'a>(
    params: &'a BTreeMap<String, Array>,
    idx: usize,
    field: &str,
) -> anyhow::Result<&'a Array> {
    let key = format!("L{idx:02}_{field}");
    params
        .get(&key)
        .ok_or_else(|| anyhow!("parameter {key} missing from archive"))
}

impl NativeModel {
    /// Compile `model` against a parameter archive (the `.npz` the Python
    /// training pipeline wrote). `quant_bits = Some(12)` reproduces the
    /// AOT artifacts' arithmetic; `None` runs float32.
    pub fn load(
        model: &Model,
        params_path: impl AsRef<Path>,
        quant_bits: Option<u32>,
    ) -> anyhow::Result<Self> {
        let params = npz::load_npz(&params_path)
            .map_err(|e| anyhow!("{e}"))
            .with_context(|| format!("loading {}", params_path.as_ref().display()))?;
        Self::from_params(model, &params, quant_bits)
    }

    /// Compile from already-loaded arrays (testing hook).
    pub fn from_params(
        model: &Model,
        params: &BTreeMap<String, Array>,
        quant_bits: Option<u32>,
    ) -> anyhow::Result<Self> {
        let mut ops = Vec::with_capacity(model.layers.len());
        for (i, layer) in model.layers.iter().enumerate() {
            // activation convention of the registry (python model.py): every
            // weight layer is relu except the classifier head (`Dense`) and
            // a BC-conv feeding straight into a residual join.
            let next_is_join = matches!(model.layers.get(i + 1), Some(Layer::ResidualEnd));
            let op = match *layer {
                Layer::BcDense { n, m, k } => {
                    let w = take(params, i, "w")?;
                    if w.shape != [m / k, n / k, k] {
                        bail!("L{i:02}_w: shape {:?} != ({},{},{})", w.shape, m / k, n / k, k);
                    }
                    let mut wv = w.data.clone();
                    maybe_quant(&mut wv, quant_bits);
                    let mut bc = BlockCirculant::new(m / k, n / k, k, wv);
                    bc.precompute();
                    Op::BcDense { bc, bias: take(params, i, "b")?.data.clone(), relu: true }
                }
                Layer::Dense { n, m } => {
                    let w = take(params, i, "w")?;
                    if w.shape != [n, m] {
                        bail!("L{i:02}_w: shape {:?} != ({n},{m})", w.shape);
                    }
                    let mut wv = w.data.clone();
                    maybe_quant(&mut wv, quant_bits);
                    // classifier heads carry no activation in the registry
                    Op::Dense { w: wv, n, m, bias: take(params, i, "b")?.data.clone(), relu: false }
                }
                Layer::BcConv { c, p, r, k, same_pad } => {
                    let w = take(params, i, "w")?;
                    let (pb, qb) = (p / k, (c / k) * r * r);
                    if w.shape != [pb, qb, k] {
                        bail!("L{i:02}_w: shape {:?} != ({pb},{qb},{k})", w.shape);
                    }
                    let mut wv = w.data.clone();
                    maybe_quant(&mut wv, quant_bits);
                    let mut bc = BlockCirculant::new(pb, qb, k, wv);
                    bc.precompute();
                    Op::BcConv {
                        bc,
                        bias: take(params, i, "b")?.data.clone(),
                        r,
                        same: same_pad,
                        relu: !next_is_join,
                    }
                }
                Layer::Conv { c, p, r, same_pad } => {
                    let w = take(params, i, "w")?;
                    if w.shape != [r, r, c, p] {
                        bail!("L{i:02}_w: shape {:?} != ({r},{r},{c},{p})", w.shape);
                    }
                    let mut f = w.data.clone();
                    maybe_quant(&mut f, quant_bits);
                    Op::Conv {
                        f,
                        bias: take(params, i, "b")?.data.clone(),
                        c,
                        p,
                        r,
                        same: same_pad,
                        relu: !next_is_join,
                    }
                }
                Layer::AvgPool2 => Op::AvgPool2,
                Layer::MaxPool2 => Op::MaxPool2,
                Layer::Flatten => Op::Flatten,
                Layer::PriorPool { out_dim } => Op::PriorPool { out_dim },
                Layer::ResidualBegin => Op::ResidualBegin,
                Layer::ResidualEnd => Op::ResidualEnd,
            };
            ops.push(op);
        }
        Ok(Self { name: model.name.to_string(), ops, quant_bits, precision: Precision::F32 })
    }

    /// Initialize a model with He-init random parameters, float32 (no
    /// quantization) — the native trainer's from-scratch starting point.
    /// Mirrors `python/compile/layers.init_*`: defining vectors and dense
    /// weights at `std = sqrt(2 / fan_in)`, zero biases (same scales, not
    /// bit-identical to the JAX PRNG).
    pub fn init_random(model: &Model, seed: u64) -> Self {
        use crate::util::rng::{combine, SplitMix};
        let he = |rng: &mut SplitMix, len: usize, fan_in: usize| -> Vec<f32> {
            let scale = (2.0 / fan_in as f64).sqrt() as f32;
            let mut v = rng.normal_vec(len);
            for w in &mut v {
                *w *= scale;
            }
            v
        };
        let mut ops = Vec::with_capacity(model.layers.len());
        for (i, layer) in model.layers.iter().enumerate() {
            let next_is_join = matches!(model.layers.get(i + 1), Some(Layer::ResidualEnd));
            let mut rng = SplitMix::new(combine(&[seed, i as u64]));
            let op = match *layer {
                Layer::BcDense { n, m, k } => {
                    let mut bc =
                        BlockCirculant::new(m / k, n / k, k, he(&mut rng, m / k * (n / k) * k, n));
                    bc.precompute();
                    Op::BcDense { bc, bias: vec![0.0; m], relu: true }
                }
                Layer::Dense { n, m } => {
                    Op::Dense { w: he(&mut rng, n * m, n), n, m, bias: vec![0.0; m], relu: false }
                }
                Layer::BcConv { c, p, r, k, same_pad } => {
                    let (pb, qb) = (p / k, (c / k) * r * r);
                    let mut bc =
                        BlockCirculant::new(pb, qb, k, he(&mut rng, pb * qb * k, c * r * r));
                    bc.precompute();
                    Op::BcConv {
                        bc,
                        bias: vec![0.0; p],
                        r,
                        same: same_pad,
                        relu: !next_is_join,
                    }
                }
                Layer::Conv { c, p, r, same_pad } => Op::Conv {
                    f: he(&mut rng, r * r * c * p, c * r * r),
                    bias: vec![0.0; p],
                    c,
                    p,
                    r,
                    same: same_pad,
                    relu: !next_is_join,
                },
                Layer::AvgPool2 => Op::AvgPool2,
                Layer::MaxPool2 => Op::MaxPool2,
                Layer::Flatten => Op::Flatten,
                Layer::PriorPool { out_dim } => Op::PriorPool { out_dim },
                Layer::ResidualBegin => Op::ResidualBegin,
                Layer::ResidualEnd => Op::ResidualEnd,
            };
            ops.push(op);
        }
        Self { name: model.name.to_string(), ops, quant_bits: None, precision: Precision::F32 }
    }

    /// Switch the executed MAC datapath.  For [`Precision::Fixed16`] every
    /// block-circulant weight spectrum is (re)quantized to int16
    /// block-floating-point planes at `bits` mantissa width (`None`: the
    /// model's fake-quant width, else the paper's 12-bit default), clamped
    /// to the encoder's supported range — the Fixed16 analogue of the
    /// offline `FFT(w)` precompute.  Back to `F32` is free: the f32
    /// spectra are always kept.
    pub fn set_precision(&mut self, precision: Precision, bits: Option<u32>) {
        self.precision = precision;
        if precision == Precision::Fixed16 {
            let bits =
                bits.or(self.quant_bits).unwrap_or(QUANT_BITS).clamp(quant::MIN_BITS, 16);
            for op in &mut self.ops {
                if let Op::BcDense { bc, .. } | Op::BcConv { bc, .. } = op {
                    if bc.fixed_bits() != bits {
                        bc.precompute_fixed(bits);
                    }
                }
            }
        }
    }

    /// The executed MAC datapath.
    pub fn precision(&self) -> Precision {
        self.precision
    }

    /// Number of ops in the compiled program (the pipeline planner's
    /// index space: a [`crate::pipeline::PipelinePlan`] covers `0..op_count`).
    pub fn op_count(&self) -> usize {
        self.ops.len()
    }

    /// The compiled op program (crate-internal: the pipeline planner walks
    /// it to find weight anchors and residual regions).
    pub(crate) fn ops_slice(&self) -> &[Op] {
        &self.ops
    }

    /// Forward a batch of raw images `(batch, h, w, c)` to logits
    /// `(batch, 10)`.
    pub fn forward(&self, images: &[f32], batch: usize, h: usize, w: usize, c: usize) -> Vec<f32> {
        assert_eq!(images.len(), batch * h * w * c, "image buffer size");
        let x = Tensor { batch, h, w, c, data: images.to_vec() };
        let mut residuals: Vec<Tensor> = Vec::new();
        let out = self.run_ops(0..self.ops.len(), x, &mut residuals);
        debug_assert!(residuals.is_empty(), "unbalanced residual markers");
        out.data
    }

    /// Walk the contiguous op segment `range` over activation `x` through
    /// the owned-input fast path ([`step`](Self::step)) — the exact code
    /// path [`forward`](Self::forward) runs, exposed as a segment so the
    /// serving pipeline (`crate::pipeline`) can split the same walk across
    /// stage workers.  Per-batch results are therefore bitwise identical
    /// to `forward` by construction (and property-pinned in
    /// `pipeline::engine`).  `residuals` must be empty whenever `range`
    /// starts or ends at residual nesting depth zero — the pipeline
    /// planner only cuts at such boundaries.
    pub(crate) fn run_ops(
        &self,
        range: std::ops::Range<usize>,
        mut x: Tensor,
        residuals: &mut Vec<Tensor>,
    ) -> Tensor {
        for op in &self.ops[range] {
            x = self.step(op, x, residuals);
        }
        x
    }

    /// Forward keeping every intermediate activation: returns the chain
    /// `acts[0] = input, acts[i+1] = output of op i` (the last entry is the
    /// logits).  Each activation is *moved* into the chain and the next op
    /// borrows it through [`step_ref`](Self::step_ref) — the weight layers
    /// and pools read their input in place instead of consuming a copy
    /// (only ops that inherently rewrite the buffer, like the flatten
    /// reshape and the residual join, still allocate).
    ///
    /// This is the reference walk over the borrowed-step plumbing and the
    /// surface the bit-identity property test pins.  The trainer drives
    /// [`step_ref`](Self::step_ref) through its own copy of this loop so
    /// it can additionally cache BC input spectra on the two spectral
    /// arms (`train::Trainer::step`); a semantic change here must be
    /// mirrored there — the shared per-op compute itself lives in
    /// `step_ref`/`weight_op`, so only the loop shell is duplicated.
    /// Bit-identical to [`forward`](Self::forward) (property-pinned): the
    /// owned path only adds in-place shortcuts.
    pub fn forward_traced(
        &self,
        images: &[f32],
        batch: usize,
        h: usize,
        w: usize,
        c: usize,
    ) -> Vec<Tensor> {
        assert_eq!(images.len(), batch * h * w * c, "image buffer size");
        let mut acts = Vec::with_capacity(self.ops.len() + 1);
        acts.push(Tensor { batch, h, w, c, data: images.to_vec() });
        let mut residuals: Vec<Tensor> = Vec::new();
        for op in &self.ops {
            let next = self.step_ref(op, acts.last().unwrap(), &mut residuals);
            acts.push(next);
        }
        debug_assert!(residuals.is_empty(), "unbalanced residual markers");
        acts
    }

    /// Owned-input step: keeps the inference path's zero-copy moves
    /// (`Flatten` reuses the buffer, `ResidualEnd` joins in place, the
    /// 12-bit path quantizes in place) and delegates every read-only op to
    /// [`step_ref`](Self::step_ref).
    fn step(&self, op: &Op, mut x: Tensor, residuals: &mut Vec<Tensor>) -> Tensor {
        match op {
            Op::Flatten => {
                let d = x.per_image();
                Tensor { batch: x.batch, h: d, w: 1, c: 1, data: x.data }
            }
            Op::ResidualBegin => {
                residuals.push(x.clone());
                x
            }
            Op::ResidualEnd => {
                let saved = residuals.pop().expect("residual_begin missing");
                debug_assert_eq!(saved.data.len(), x.data.len());
                for (v, s) in x.data.iter_mut().zip(&saved.data) {
                    *v = (*v + s).max(0.0); // join + relu, as in model.apply
                }
                x
            }
            Op::BcDense { .. } | Op::Dense { .. } | Op::BcConv { .. } | Op::Conv { .. }
                if self.quant_bits.is_some() =>
            {
                maybe_quant(&mut x.data, self.quant_bits);
                self.weight_op(op, &x, &x.data)
            }
            _ => self.step_ref(op, &x, residuals),
        }
    }

    /// Borrowed-input step: computes op `op` from `&x` without consuming
    /// it, so a caller can keep the activation chain alive (the trainer,
    /// [`forward_traced`](Self::forward_traced)).  In float mode nothing is
    /// copied; the 12-bit path quantizes a copy of the one input tensor
    /// (same values as the in-place fast path).
    pub(crate) fn step_ref(&self, op: &Op, x: &Tensor, residuals: &mut Vec<Tensor>) -> Tensor {
        match op {
            Op::BcDense { .. } | Op::Dense { .. } | Op::BcConv { .. } | Op::Conv { .. } => {
                if self.quant_bits.is_some() {
                    let mut xq = x.data.clone();
                    maybe_quant(&mut xq, self.quant_bits);
                    self.weight_op(op, x, &xq)
                } else {
                    self.weight_op(op, x, &x.data)
                }
            }
            Op::Flatten => {
                let d = x.per_image();
                Tensor { batch: x.batch, h: d, w: 1, c: 1, data: x.data.clone() }
            }
            Op::ResidualBegin => {
                residuals.push(x.clone());
                x.clone()
            }
            Op::ResidualEnd => {
                let saved = residuals.pop().expect("residual_begin missing");
                debug_assert_eq!(saved.data.len(), x.data.len());
                let mut data = x.data.clone();
                for (v, s) in data.iter_mut().zip(&saved.data) {
                    *v = (*v + s).max(0.0); // join + relu, as in model.apply
                }
                Tensor { batch: x.batch, h: x.h, w: x.w, c: x.c, data }
            }
            Op::PriorPool { out_dim } => {
                let per = x.per_image();
                let mut out = Vec::with_capacity(x.batch * out_dim);
                for b in 0..x.batch {
                    out.extend(data::prior_pool(&x.data[b * per..(b + 1) * per], *out_dim));
                }
                Tensor { batch: x.batch, h: *out_dim, w: 1, c: 1, data: out }
            }
            Op::AvgPool2 | Op::MaxPool2 => {
                let avg = matches!(op, Op::AvgPool2);
                let (oh, ow) = (x.h / 2, x.w / 2);
                let mut out = vec![0.0f32; x.batch * oh * ow * x.c];
                for b in 0..x.batch {
                    for oy in 0..oh {
                        for ox in 0..ow {
                            for ch in 0..x.c {
                                let at = |dy: usize, dx: usize| {
                                    x.data[((b * x.h + 2 * oy + dy) * x.w + 2 * ox + dx) * x.c + ch]
                                };
                                let (a, bb, cc, d) = (at(0, 0), at(0, 1), at(1, 0), at(1, 1));
                                out[((b * oh + oy) * ow + ox) * x.c + ch] = if avg {
                                    0.25 * (a + bb + cc + d)
                                } else {
                                    a.max(bb).max(cc).max(d)
                                };
                            }
                        }
                    }
                }
                Tensor { batch: x.batch, h: oh, w: ow, c: x.c, data: out }
            }
        }
    }

    /// Weight-layer compute on already-quantized input data `xd` (the
    /// tensor `x` supplies geometry only) — shared by the owned and
    /// borrowed step paths.  Calling it with a non-weight op is a bug.
    fn weight_op(&self, op: &Op, x: &Tensor, xd: &[f32]) -> Tensor {
        match op {
            Op::BcDense { bc, bias, relu } => {
                let (n, m) = (bc.cols(), bc.rows());
                debug_assert_eq!(x.per_image(), n);
                let mut out = vec![0.0f32; x.batch * m];
                match self.precision {
                    Precision::F32 => bc.matmul(xd, x.batch, &mut out),
                    Precision::Fixed16 => bc.matmul_fixed(xd, x.batch, &mut out),
                }
                finish_rows(&mut out, bias, m, *relu);
                Tensor { batch: x.batch, h: m, w: 1, c: 1, data: out }
            }
            Op::Dense { w, n, m, bias, relu } => {
                debug_assert_eq!(x.per_image(), *n);
                let mut out = vec![0.0f32; x.batch * m];
                // python convention: y = x @ W with W (n, m)
                for b in 0..x.batch {
                    let xi = &xd[b * n..(b + 1) * n];
                    let yo = &mut out[b * m..(b + 1) * m];
                    for (i, &xv) in xi.iter().enumerate() {
                        if xv == 0.0 {
                            continue; // post-relu activations are sparse
                        }
                        let wr = &w[i * m..(i + 1) * m];
                        for (y, &wv) in yo.iter_mut().zip(wr) {
                            *y += xv * wv;
                        }
                    }
                }
                finish_rows(&mut out, bias, *m, *relu);
                Tensor { batch: x.batch, h: *m, w: 1, c: 1, data: out }
            }
            Op::BcConv { bc, bias, r, same, relu } => {
                // the decoupled three-phase CONV schedule, batch- and
                // pixel-parallel — see native::conv for the full story
                let shape =
                    conv::ConvShape { h: x.h, w: x.w, c: x.c, r: *r, same: *same };
                let o = match self.precision {
                    Precision::F32 => conv::forward(bc, xd, x.batch, shape, bias, *relu),
                    Precision::Fixed16 => {
                        conv::forward_fixed(bc, xd, x.batch, shape, bias, *relu)
                    }
                };
                Tensor { batch: x.batch, h: o.oh, w: o.ow, c: bc.rows(), data: o.data }
            }
            Op::Conv { f, bias, c, p, r, same, relu } => {
                let per = x.per_image();
                let mut out = Vec::new();
                let (mut oh, mut ow) = (0, 0);
                for b in 0..x.batch {
                    let img = &xd[b * per..(b + 1) * per];
                    let (padded, ih, iw);
                    let src: &[f32] = if *same {
                        (padded, ih, iw) = im2col::pad_same(img, x.h, x.w, x.c, *r);
                        &padded
                    } else {
                        (ih, iw) = (x.h, x.w);
                        img
                    };
                    (oh, ow) = (ih - r + 1, iw - r + 1);
                    if out.is_empty() {
                        out = vec![0.0f32; x.batch * oh * ow * p];
                    }
                    for oy in 0..oh {
                        for ox in 0..ow {
                            let dst = ((b * oh + oy) * ow + ox) * p;
                            for i in 0..*r {
                                for j in 0..*r {
                                    for ch in 0..*c {
                                        let xv = src[((oy + i) * iw + ox + j) * c + ch];
                                        if xv == 0.0 {
                                            continue;
                                        }
                                        let fr = &f[((i * r + j) * c + ch) * p..][..*p];
                                        for (y, &w) in out[dst..dst + p].iter_mut().zip(fr) {
                                            *y += xv * w;
                                        }
                                    }
                                }
                            }
                        }
                    }
                }
                finish_rows(&mut out, bias, *p, *relu);
                Tensor { batch: x.batch, h: oh, w: ow, c: *p, data: out }
            }
            _ => unreachable!("weight_op called on a non-weight op"),
        }
    }

    /// Classify a batch: forward + row-wise argmax.
    pub fn classify(&self, images: &[f32], batch: usize, h: usize, w: usize, c: usize) -> Vec<u32> {
        let logits = self.forward(images, batch, h, w, c);
        let classes = logits.len() / batch;
        crate::util::argmax_rows(&logits, classes)
    }
}

/// Add bias + optional relu over `(rows, m)`-shaped data.
pub(crate) fn finish_rows(data: &mut [f32], bias: &[f32], m: usize, relu: bool) {
    if !bias.is_empty() {
        for row in data.chunks_mut(m) {
            dense::add_bias(row, bias);
        }
    }
    if relu {
        dense::relu(data);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models;

    #[test]
    fn forward_traced_bit_identical_to_forward() {
        // the satellite pin for the borrowed-activation plumbing: tracing
        // must not change the inference path, quantized or float, across
        // every op kind in the registry (conv stems, pools, residual pairs,
        // prior-pool, BC layers, dense heads)
        for name in ["mnist_mlp_1", "mnist_lenet", "svhn_cnn", "cifar_wrn"] {
            let model = models::by_name(name).unwrap();
            let mut native = NativeModel::init_random(&model, 7);
            let (h, w, c) = model.input;
            let ds = data::dataset(model.dataset).unwrap();
            let batch = 2;
            let (xs, _) = data::batch(&ds, 0, batch, false);
            for quant in [None, Some(QUANT_BITS)] {
                native.quant_bits = quant;
                let plain = native.forward(&xs, batch, h, w, c);
                let acts = native.forward_traced(&xs, batch, h, w, c);
                assert_eq!(acts.len(), model.layers.len() + 1);
                let logits = &acts.last().unwrap().data;
                assert!(
                    &plain == logits,
                    "{name} quant={quant:?}: traced forward diverged from forward"
                );
            }
        }
    }

    #[test]
    fn fixed16_forward_is_deterministic_and_tracks_f32() {
        // the Fixed16 engine mode end to end: deterministic, close to the
        // f32 logits, and reversible — switching back to F32 restores the
        // default path byte for byte (the fixed planes are additive state)
        for name in ["mnist_mlp_1", "svhn_cnn"] {
            let model = models::by_name(name).unwrap();
            let mut native = NativeModel::init_random(&model, 7);
            let (h, w, c) = model.input;
            let ds = data::dataset(model.dataset).unwrap();
            let batch = 4;
            let (xs, _) = data::batch(&ds, 0, batch, false);
            let f32_logits = native.forward(&xs, batch, h, w, c);
            native.set_precision(Precision::Fixed16, Some(12));
            assert_eq!(native.precision(), Precision::Fixed16);
            let a = native.forward(&xs, batch, h, w, c);
            let b = native.forward(&xs, batch, h, w, c);
            assert!(a == b, "{name}: fixed16 forward must be deterministic");
            let snr = crate::circulant::fixed::snr_db(&f32_logits, &a);
            assert!(snr > 20.0, "{name}: fixed16 logits SNR vs f32 too low: {snr} dB");
            native.set_precision(Precision::F32, None);
            let back = native.forward(&xs, batch, h, w, c);
            assert!(back == f32_logits, "{name}: f32 path changed after precision round-trip");
        }
    }

    #[test]
    fn init_random_scales_follow_he_init() {
        let model = models::by_name("mnist_mlp_1").unwrap();
        let native = NativeModel::init_random(&model, 3);
        let Op::BcDense { bc, bias, .. } = &native.ops[2] else {
            panic!("op 2 of mnist_mlp_1 should be the BC dense layer");
        };
        assert!(bias.iter().all(|&b| b == 0.0));
        let n = bc.w.len() as f32;
        let var = bc.w.iter().map(|v| v * v).sum::<f32>() / n;
        let expect = 2.0 / bc.cols() as f32;
        assert!(
            (var - expect).abs() < 0.5 * expect,
            "defining-vector variance {var} far from He target {expect}"
        );
    }
}
