//! Parallel three-phase executor for block-circulant CONV layers — the
//! paper's CONV reformulation (Fig. 2 / Eqn. 1) on the native substrate,
//! sharded across cores the way [`BlockCirculant::matmul`] shards the FC
//! path.
//!
//! The decoupled schedule (§Perf: 2.3x on the CNN models): every *input
//! pixel's* channel-block spectrum is computed once and shared by all r^2
//! filter taps that touch it, instead of re-FFT-ing the im2col replicas —
//! exactly the FFT count the simulator's `models::FftWork` charges.
//! [`forward`] runs it in two parallel sweeps over `crate::circulant::sched`
//! shards with per-thread workspaces:
//!
//! * **phase 1**: one rFFT per (image, input pixel, channel block), the
//!   whole batch's spectra sharded by pixel.  For `same`-padded layers the
//!   all-zero border pixels of the padded grid are *skipped*: their spectrum
//!   is identically zero — already the buffer's state — so every
//!   `complex_mul_acc` against them contributes exact `±0.0` terms that
//!   leave the accumulators bitwise unchanged.  The skip is therefore
//!   invisible in the output and makes the executed transform count equal
//!   the `ffts_total` the cost model charges (pinned by the conv parity
//!   test in [`super::staged`]).
//! * **phases 2+3**: **weight-block-outer, spectrum-resident** — each
//!   `(output block, tap)` weight spectrum is loaded once per shard and
//!   swept across every output pixel of the shard before the next spectrum
//!   is touched (the BRAM-reuse ordering the paper's FPGA streams its MACs
//!   through, and the FC matmul already uses), then one IFFT per (output
//!   pixel, output block); output pixels sharded across the batch.  The
//!   pre-resident pixel-outer walk — every weight spectrum re-fetched per
//!   output pixel — is kept as [`forward_pixel_outer`], the ordering twin
//!   the benches measure the resident sweep against.  (An earlier row-major
//!   tap-outer variant without the resident accumulator planes was tried
//!   and reverted: neutral on SVHN, -19% on the WRN — §Perf iteration log.)
//!
//! All sweeps only reorder *independent* per-pixel work — per (pixel,
//! output block) accumulator the taps still arrive in `(cb, di, dj)` order
//! — so the result is bit-identical to both the pixel-outer walk and the
//! pre-PR serial walk (kept as [`forward_serial`], pinned by
//! `prop_parallel_conv_bit_identical_to_serial`).
//!
//! The same pipeline has an int16 fixed-point twin ([`forward_fixed`], the
//! CONV arm of `Precision::Fixed16`): identical schedule and resident
//! ordering, with the phase-1 spectra block-floating-point-quantized to
//! i16 mantissas and phase 2 running the integer MAC kernels — the
//! paper's 12–16-bit FPGA datapath, executed.

use crate::circulant::fft::{complex_conj_mul_acc, complex_mul_acc, complex_mul_acc_i16};
use crate::circulant::quant;
use crate::circulant::sched::{self, FixedShardWorkspace, PhaseCounters, ShardWorkspace};
use crate::circulant::{im2col, BlockCirculant};

/// Result of one BC-conv layer over a batch.
pub struct ConvOutput {
    /// `(batch, oh, ow, p)` row-major activations (bias/relu applied)
    pub data: Vec<f32>,
    pub oh: usize,
    pub ow: usize,
    /// transforms / multiply groups actually executed, whole batch
    pub counters: PhaseCounters,
}

/// Shape of one BC-conv application: `(h, w, c)` input, `r x r` kernel,
/// SAME or VALID padding.
#[derive(Debug, Clone, Copy)]
pub struct ConvShape {
    pub h: usize,
    pub w: usize,
    pub c: usize,
    pub r: usize,
    pub same: bool,
}

/// Derived layer geometry shared by the phases.
struct Geom {
    h: usize,
    w: usize,
    c: usize,
    r: usize,
    /// padded input grid (equal to `h`/`w` for VALID)
    ih: usize,
    iw: usize,
    oh: usize,
    ow: usize,
    /// low-side SAME pad — `(r-1)/2`, the asymmetric-split convention of
    /// `im2col::pad_same` (0 for VALID)
    lo: usize,
}

impl Geom {
    fn new(s: ConvShape) -> Self {
        let ConvShape { h, w, c, r, same } = s;
        let (ih, iw, lo) = if same { (h + r - 1, w + r - 1, (r - 1) / 2) } else { (h, w, 0) };
        assert!(ih >= r && iw >= r, "kernel {r} larger than {ih}x{iw} input");
        Self { h, w, c, r, ih, iw, oh: ih - r + 1, ow: iw - r + 1, lo }
    }
}

/// Phase-1 spectra retained across a training step: the padded-grid
/// input-pixel half-spectra of the whole batch, layout
/// `[(b*ihw + pix) * (c/k) + cb][kh]` (border pixels all-zero for SAME).
///
/// [`forward_cached`] fills it, [`backward`] reuses it for the weight
/// gradient (`dL/dw = IFFT(Σ conj(X) o G)`) so the backward pass never
/// re-transforms the activations.  The buffers are caller-owned and resized
/// in place, so one cache serves every step allocation-free after the first
/// (the `Workspace` reuse story of the FC path).
#[derive(Debug, Default)]
pub struct ConvFwdCache {
    pub xfr: Vec<f32>,
    pub xfi: Vec<f32>,
}

impl ConvFwdCache {
    pub fn new() -> Self {
        Self::default()
    }
}

/// Batch- and pixel-parallel BC-conv: `xs` is `(batch, h, w, c)` row-major,
/// `bc` holds the `(p/k) x ((c/k)·r·r)` weight-spectrum grid (precomputed).
/// Returns activations plus the executed phase counters.
pub fn forward(
    bc: &BlockCirculant,
    xs: &[f32],
    batch: usize,
    shape: ConvShape,
    bias: &[f32],
    relu: bool,
) -> ConvOutput {
    let mut cache = ConvFwdCache::new();
    forward_cached(bc, xs, batch, shape, bias, relu, &mut cache)
}

/// [`forward`] with the phase-1 spectra kept in a caller-owned
/// [`ConvFwdCache`] for reuse by [`backward`] — identical output (it *is*
/// the same code; `forward` passes a throwaway cache).
pub fn forward_cached(
    bc: &BlockCirculant,
    xs: &[f32],
    batch: usize,
    shape: ConvShape,
    bias: &[f32],
    relu: bool,
    cache: &mut ConvFwdCache,
) -> ConvOutput {
    forward_impl(bc, xs, batch, shape, bias, relu, cache, true)
}

/// The pre-resident parallel pipeline: identical phase-1 sweep, pixel-outer
/// phases 2+3 (every weight-block spectrum re-fetched per output pixel).
/// Kept as the ordering twin the resident sweep is pinned against bitwise
/// (tests) and measured against (`bc_conv_resident_*` in the benches).
pub fn forward_pixel_outer(
    bc: &BlockCirculant,
    xs: &[f32],
    batch: usize,
    shape: ConvShape,
    bias: &[f32],
    relu: bool,
) -> ConvOutput {
    let mut cache = ConvFwdCache::new();
    forward_impl(bc, xs, batch, shape, bias, relu, &mut cache, false)
}

#[allow(clippy::too_many_arguments)]
fn forward_impl(
    bc: &BlockCirculant,
    xs: &[f32],
    batch: usize,
    shape: ConvShape,
    bias: &[f32],
    relu: bool,
    cache: &mut ConvFwdCache,
    resident: bool,
) -> ConvOutput {
    let k = bc.k;
    assert_eq!(xs.len(), batch * shape.h * shape.w * shape.c, "input buffer size");
    assert_eq!(shape.c % k, 0, "k must divide the channel count");
    let qc = shape.c / k;
    assert_eq!(bc.q, qc * shape.r * shape.r, "weight grid != (c/k)*r*r input blocks");
    let p_out = bc.rows();
    let pb = bc.p;
    let plan = bc.plan_arc();
    let kh = plan.half_bins();
    let g = Geom::new(shape);
    let (ihw, ohw) = (g.ih * g.iw, g.oh * g.ow);

    let mut counters = PhaseCounters::default();
    let mut out = vec![0.0f32; batch * ohw * p_out];
    if batch == 0 {
        return ConvOutput { data: out, oh: g.oh, ow: g.ow, counters };
    }

    // ---- phase 1: the whole batch's input-pixel spectra, sharded by pixel.
    // Layout `[(b*ihw + pix) * qc + cb][kh]`; border pixels stay zero.  The
    // planes are moved out of the caller's cache (and back at the end) so
    // the body keeps the seed's owned-Vec borrow structure while a reused
    // cache makes the resize a no-op after the first step.
    let spec_stride = qc * kh;
    let mut xfr = std::mem::take(&mut cache.xfr);
    let mut xfi = std::mem::take(&mut cache.xfi);
    xfr.clear();
    xfr.resize(batch * ihw * spec_stride, 0.0);
    xfi.clear();
    xfi.resize(batch * ihw * spec_stride, 0.0);
    let fft_shard = |unit0: usize, xr: &mut [f32], xi: &mut [f32]| -> u64 {
        let mut ws = ShardWorkspace::new(k, 0, 0);
        let mut ffts = 0u64;
        for u in 0..xr.len() / spec_stride {
            let pix = (unit0 + u) % ihw;
            let (y, x) = (pix / g.iw, pix % g.iw);
            if y < g.lo || y >= g.lo + g.h || x < g.lo || x >= g.lo + g.w {
                continue; // all-zero padded border: spectrum is already zero
            }
            let b = (unit0 + u) / ihw;
            let src = ((b * g.h + (y - g.lo)) * g.w + (x - g.lo)) * g.c;
            for cb in 0..qc {
                let off = u * spec_stride + cb * kh;
                plan.rfft_halfspec(
                    &xs[src + cb * k..src + (cb + 1) * k],
                    &mut xr[off..off + kh],
                    &mut xi[off..off + kh],
                    &mut ws.scratch,
                );
                ffts += 1;
            }
        }
        ffts
    };
    let units1 = batch * ihw;
    let shards1 = sched::shard_count(units1, qc * plan.real_mults() as usize);
    if shards1 <= 1 {
        counters.ffts = fft_shard(0, &mut xfr, &mut xfi);
    } else {
        let chunk = units1.div_ceil(shards1) * spec_stride;
        std::thread::scope(|scope| {
            let mut handles = Vec::with_capacity(shards1);
            let mut unit0 = 0;
            for (xr, xi) in xfr.chunks_mut(chunk).zip(xfi.chunks_mut(chunk)) {
                let units_here = xr.len() / spec_stride;
                let (u0, f) = (unit0, &fft_shard);
                handles.push(scope.spawn(move || f(u0, xr, xi)));
                unit0 += units_here;
            }
            for hdl in handles {
                counters.ffts += hdl.join().expect("phase-1 shard panicked");
            }
        });
    }

    // ---- phases 2+3: spectral MAC + one IFFT per (output pixel, output
    // block), output pixels sharded across the batch.  Resident ordering
    // (the default): weight-block-outer — spectrum (i, j) is loaded once
    // per shard and swept across every output pixel through per-pixel
    // accumulator planes, so one BRAM-resident spectrum serves all its
    // dependent MACs before the next is fetched.  Per (pixel, i)
    // accumulator the taps still arrive in (cb, di, dj) order, so the
    // result is bitwise identical to the pixel-outer walk.
    let mac_shard = |unit0: usize, out: &mut [f32]| -> (u64, u64) {
        let units_here = out.len() / p_out;
        let (mut mult_groups, mut iffts) = (0u64, 0u64);
        if resident {
            let mut ws = ShardWorkspace::new(k, 0, units_here * kh);
            // per-unit spectral offset of the pixel under tap (0, 0) —
            // hoists the div/mod unit decode out of the resident sweep so
            // the inner loop is adds + the MAC kernel only
            let base: Vec<usize> = (0..units_here)
                .map(|u| {
                    let (b, opix) = ((unit0 + u) / ohw, (unit0 + u) % ohw);
                    let (oy, ox) = (opix / g.ow, opix % g.ow);
                    (b * ihw + oy * g.iw + ox) * spec_stride
                })
                .collect();
            for i in 0..pb {
                ws.acc_r.fill(0.0);
                ws.acc_i.fill(0.0);
                for cb in 0..qc {
                    for di in 0..g.r {
                        for dj in 0..g.r {
                            let j = (cb * g.r + di) * g.r + dj;
                            let (wr, wi) = bc.spectrum(i, j);
                            let tap = (di * g.iw + dj) * spec_stride + cb * kh;
                            for (u, &b0) in base.iter().enumerate() {
                                let xo = b0 + tap;
                                complex_mul_acc(
                                    wr,
                                    wi,
                                    &xfr[xo..xo + kh],
                                    &xfi[xo..xo + kh],
                                    &mut ws.acc_r[u * kh..(u + 1) * kh],
                                    &mut ws.acc_i[u * kh..(u + 1) * kh],
                                );
                                mult_groups += 1;
                            }
                        }
                    }
                }
                for u in 0..units_here {
                    let dst = u * p_out;
                    plan.irfft_halfspec(
                        &ws.acc_r[u * kh..(u + 1) * kh],
                        &ws.acc_i[u * kh..(u + 1) * kh],
                        &mut out[dst + i * k..dst + (i + 1) * k],
                        &mut ws.scratch,
                    );
                    iffts += 1;
                }
            }
        } else {
            // pixel-outer: the pre-resident walk, kept verbatim
            let mut ws = ShardWorkspace::new(k, 0, kh);
            for u in 0..units_here {
                let (b, opix) = ((unit0 + u) / ohw, (unit0 + u) % ohw);
                let (oy, ox) = (opix / g.ow, opix % g.ow);
                let dst = u * p_out;
                for i in 0..pb {
                    ws.acc_r.fill(0.0);
                    ws.acc_i.fill(0.0);
                    for cb in 0..qc {
                        for di in 0..g.r {
                            for dj in 0..g.r {
                                let j = (cb * g.r + di) * g.r + dj;
                                let (wr, wi) = bc.spectrum(i, j);
                                let pix = (oy + di) * g.iw + ox + dj;
                                let xo = (b * ihw + pix) * spec_stride + cb * kh;
                                complex_mul_acc(
                                    wr,
                                    wi,
                                    &xfr[xo..xo + kh],
                                    &xfi[xo..xo + kh],
                                    &mut ws.acc_r,
                                    &mut ws.acc_i,
                                );
                                mult_groups += 1;
                            }
                        }
                    }
                    plan.irfft_halfspec(
                        &ws.acc_r,
                        &ws.acc_i,
                        &mut out[dst + i * k..dst + (i + 1) * k],
                        &mut ws.scratch,
                    );
                    iffts += 1;
                }
            }
        }
        (mult_groups, iffts)
    };
    let units2 = batch * ohw;
    let shards2 = sched::shard_count(units2, pb * bc.q * kh);
    if shards2 <= 1 {
        (counters.mult_groups, counters.iffts) = mac_shard(0, &mut out);
    } else {
        let chunk = units2.div_ceil(shards2) * p_out;
        std::thread::scope(|scope| {
            let mut handles = Vec::with_capacity(shards2);
            let mut unit0 = 0;
            for out_chunk in out.chunks_mut(chunk) {
                let units_here = out_chunk.len() / p_out;
                let (u0, f) = (unit0, &mac_shard);
                handles.push(scope.spawn(move || f(u0, out_chunk)));
                unit0 += units_here;
            }
            for hdl in handles {
                let (mg, iff) = hdl.join().expect("phase-2/3 shard panicked");
                counters.mult_groups += mg;
                counters.iffts += iff;
            }
        });
    }

    super::finish_rows(&mut out, bias, p_out, relu);
    cache.xfr = xfr;
    cache.xfi = xfi;
    ConvOutput { data: out, oh: g.oh, ow: g.ow, counters }
}

/// [`forward`] through the int16 fixed-point datapath
/// (`Precision::Fixed16`): the same decoupled schedule and
/// weight-block-outer resident ordering, with phase 1 BFP-quantizing every
/// interior pixel spectrum to i16 mantissas + one power-of-two exponent
/// (border spectra keep zero mantissas and the [`quant::ZERO_EXP`]
/// sentinel, so they never inflate an output spectrum's accumulator
/// scale), phase 2 running [`complex_mul_acc_i16`] into i32 accumulators,
/// and one exact power-of-two rescale per output spectrum before the f32
/// IFFT.  Per-pixel work is independent, so the output is bit-identical to
/// [`forward_fixed_serial`] (pinned in tests).  Requires
/// [`BlockCirculant::precompute_fixed`].
pub fn forward_fixed(
    bc: &BlockCirculant,
    xs: &[f32],
    batch: usize,
    shape: ConvShape,
    bias: &[f32],
    relu: bool,
) -> ConvOutput {
    forward_fixed_impl(bc, xs, batch, shape, bias, relu, false)
}

/// [`forward_fixed`] pinned to one shard — the serial baseline the benches
/// measure the sharded fixed conv against (bitwise-identical: sharding
/// splits independent pixel work only).
pub fn forward_fixed_serial(
    bc: &BlockCirculant,
    xs: &[f32],
    batch: usize,
    shape: ConvShape,
    bias: &[f32],
    relu: bool,
) -> ConvOutput {
    forward_fixed_impl(bc, xs, batch, shape, bias, relu, true)
}

fn forward_fixed_impl(
    bc: &BlockCirculant,
    xs: &[f32],
    batch: usize,
    shape: ConvShape,
    bias: &[f32],
    relu: bool,
    serial: bool,
) -> ConvOutput {
    let k = bc.k;
    let bits = bc.fixed_bits();
    assert!(bits != 0, "call precompute_fixed() first");
    assert_eq!(xs.len(), batch * shape.h * shape.w * shape.c, "input buffer size");
    assert_eq!(shape.c % k, 0, "k must divide the channel count");
    let qc = shape.c / k;
    assert_eq!(bc.q, qc * shape.r * shape.r, "weight grid != (c/k)*r*r input blocks");
    let p_out = bc.rows();
    let pb = bc.p;
    let plan = bc.plan_arc();
    let kh = plan.half_bins();
    let g = Geom::new(shape);
    let (ihw, ohw) = (g.ih * g.iw, g.oh * g.ow);

    let mut counters = PhaseCounters::default();
    let mut out = vec![0.0f32; batch * ohw * p_out];
    if batch == 0 {
        return ConvOutput { data: out, oh: g.oh, ow: g.ow, counters };
    }

    // ---- phase 1: rFFT + BFP-quantize the batch's input-pixel spectra,
    // sharded by pixel.  Mantissa layout `[(b*ihw + pix) * qc + cb][kh]`,
    // one exponent per (pixel, channel block); border pixels keep zero
    // mantissas and the ZERO_EXP sentinel.
    let spec_stride = qc * kh;
    let mut qxr = vec![0i16; batch * ihw * spec_stride];
    let mut qxi = vec![0i16; batch * ihw * spec_stride];
    let mut xexp = vec![quant::ZERO_EXP; batch * ihw * qc];
    let fft_shard = |unit0: usize, xr: &mut [i16], xi: &mut [i16], xe: &mut [i32]| -> u64 {
        let mut ws = FixedShardWorkspace::new(k, 0, 0);
        let mut ffts = 0u64;
        for u in 0..xe.len() / qc {
            let pix = (unit0 + u) % ihw;
            let (y, x) = (pix / g.iw, pix % g.iw);
            if y < g.lo || y >= g.lo + g.h || x < g.lo || x >= g.lo + g.w {
                continue; // all-zero padded border: sentinel already in place
            }
            let b = (unit0 + u) / ihw;
            let src = ((b * g.h + (y - g.lo)) * g.w + (x - g.lo)) * g.c;
            for cb in 0..qc {
                plan.rfft_halfspec(
                    &xs[src + cb * k..src + (cb + 1) * k],
                    &mut ws.fr,
                    &mut ws.fi,
                    &mut ws.scratch,
                );
                let off = u * spec_stride + cb * kh;
                xe[u * qc + cb] = quant::encode_spectrum_i16(
                    &ws.fr,
                    &ws.fi,
                    bits,
                    &mut xr[off..off + kh],
                    &mut xi[off..off + kh],
                );
                ffts += 1;
            }
        }
        ffts
    };
    let units1 = batch * ihw;
    let shards1 =
        if serial { 1 } else { sched::shard_count(units1, qc * plan.real_mults() as usize) };
    if shards1 <= 1 {
        counters.ffts = fft_shard(0, &mut qxr, &mut qxi, &mut xexp);
    } else {
        let chunk_units = units1.div_ceil(shards1);
        std::thread::scope(|scope| {
            let mut handles = Vec::with_capacity(shards1);
            let mut unit0 = 0;
            for ((xr, xi), xe) in qxr
                .chunks_mut(chunk_units * spec_stride)
                .zip(qxi.chunks_mut(chunk_units * spec_stride))
                .zip(xexp.chunks_mut(chunk_units * qc))
            {
                let units_here = xe.len() / qc;
                let (u0, f) = (unit0, &fft_shard);
                handles.push(scope.spawn(move || f(u0, xr, xi, xe)));
                unit0 += units_here;
            }
            for hdl in handles {
                counters.ffts += hdl.join().expect("fixed phase-1 shard panicked");
            }
        });
    }

    // ---- phases 2+3: resident int16 MAC + one rescale + IFFT per (output
    // pixel, output block).  Scale handling as in the FC fixed path: each
    // output spectrum picks `P = max over taps (e_w + e_x)` plus the
    // overflow headroom, every tap product is pre-shifted to that common
    // scale, and the accumulator is worth `acc * 2^(P+h)` at the end.
    let h_sh = quant::acc_headroom(bits, bc.q) as i32;
    let mac_shard = |unit0: usize, out: &mut [f32]| -> (u64, u64) {
        let units_here = out.len() / p_out;
        let (mut mult_groups, mut iffts) = (0u64, 0u64);
        let mut ws = FixedShardWorkspace::new(k, 0, units_here * kh);
        // per-unit mantissa/exponent offsets of the pixel under tap (0, 0)
        let base: Vec<(usize, usize)> = (0..units_here)
            .map(|u| {
                let (b, opix) = ((unit0 + u) / ohw, (unit0 + u) % ohw);
                let (oy, ox) = (opix / g.ow, opix % g.ow);
                let pix0 = b * ihw + oy * g.iw + ox;
                (pix0 * spec_stride, pix0 * qc)
            })
            .collect();
        let mut pmax = vec![0i32; units_here];
        for i in 0..pb {
            for pm in pmax.iter_mut() {
                *pm = i32::MIN;
            }
            for cb in 0..qc {
                for di in 0..g.r {
                    for dj in 0..g.r {
                        let j = (cb * g.r + di) * g.r + dj;
                        let (_, _, we) = bc.fixed_spectrum(i, j);
                        let te = (di * g.iw + dj) * qc + cb;
                        for (u, pm) in pmax.iter_mut().enumerate() {
                            *pm = (*pm).max(we + xexp[base[u].1 + te]);
                        }
                    }
                }
            }
            ws.acc_r.fill(0);
            ws.acc_i.fill(0);
            for cb in 0..qc {
                for di in 0..g.r {
                    for dj in 0..g.r {
                        let j = (cb * g.r + di) * g.r + dj;
                        let (wr, wi, we) = bc.fixed_spectrum(i, j);
                        let tap = (di * g.iw + dj) * spec_stride + cb * kh;
                        let te = (di * g.iw + dj) * qc + cb;
                        for (u, &(b0, e0)) in base.iter().enumerate() {
                            let xo = b0 + tap;
                            let shift =
                                ((pmax[u] + h_sh - we - xexp[e0 + te]) as u32).min(31);
                            complex_mul_acc_i16(
                                wr,
                                wi,
                                &qxr[xo..xo + kh],
                                &qxi[xo..xo + kh],
                                shift,
                                &mut ws.acc_r[u * kh..(u + 1) * kh],
                                &mut ws.acc_i[u * kh..(u + 1) * kh],
                            );
                            mult_groups += 1;
                        }
                    }
                }
            }
            for u in 0..units_here {
                let scale = f64::from(pmax[u] + h_sh).exp2() as f32;
                for t in 0..kh {
                    ws.fr[t] = ws.acc_r[u * kh + t] as f32 * scale;
                    ws.fi[t] = ws.acc_i[u * kh + t] as f32 * scale;
                }
                let dst = u * p_out;
                plan.irfft_halfspec(
                    &ws.fr,
                    &ws.fi,
                    &mut out[dst + i * k..dst + (i + 1) * k],
                    &mut ws.scratch,
                );
                iffts += 1;
            }
        }
        (mult_groups, iffts)
    };
    let units2 = batch * ohw;
    let shards2 = if serial { 1 } else { sched::shard_count(units2, pb * bc.q * kh) };
    if shards2 <= 1 {
        (counters.mult_groups, counters.iffts) = mac_shard(0, &mut out);
    } else {
        let chunk = units2.div_ceil(shards2) * p_out;
        std::thread::scope(|scope| {
            let mut handles = Vec::with_capacity(shards2);
            let mut unit0 = 0;
            for out_chunk in out.chunks_mut(chunk) {
                let units_here = out_chunk.len() / p_out;
                let (u0, f) = (unit0, &mac_shard);
                handles.push(scope.spawn(move || f(u0, out_chunk)));
                unit0 += units_here;
            }
            for hdl in handles {
                let (mg, iff) = hdl.join().expect("fixed phase-2/3 shard panicked");
                counters.mult_groups += mg;
                counters.iffts += iff;
            }
        });
    }

    super::finish_rows(&mut out, bias, p_out, relu);
    ConvOutput { data: out, oh: g.oh, ow: g.ow, counters }
}

/// Spectral backward of one BC-conv layer (the CONV instance of CirCNN
/// Eqns. 2/3), sharded sample-parallel over [`sched`]:
///
/// * every (output pixel, output block) gradient is FFT'd **once** per
///   sample and shared by both products;
/// * the tap sweep is **weight-block-outer, spectrum-resident** (the same
///   inversion as the forward): each `conj(W_ij)` spectrum and each
///   `gw_ij` frequency-domain accumulator is loaded once per sample and
///   swept across all output pixels.  `dL/dw`'s per-accumulator op order
///   is unchanged (output pixels ascending), so it stays **bitwise** equal
///   to the pre-resident tap walk (kept as [`backward_pixel_outer`]);
///   `dL/dx`'s padded-grid accumulators gather their taps in a different
///   order under the inversion, so that product is pinned against the twin
///   with tolerance (and against `to_dense()` finite differences);
/// * `dL/dx` accumulates `conj(W_ij) o G` into a padded-grid spectral
///   buffer, then runs one irfft per *interior* (input pixel, channel
///   block) — the padded border's gradients are discarded untransformed,
///   mirroring the forward's border-FFT skip;
/// * `dL/dw` accumulates `conj(X) o G` in the frequency domain across the
///   whole batch with one irfft per weight block at the end (the per-step
///   amortized transforms the training cost model charges).
///
/// `cache` is the forward's [`ConvFwdCache`] (input spectra reused, not
/// recomputed); `gys` is `(batch, oh*ow, p)` with any activation mask
/// already applied; `gx` is `(batch, h, w, c)`; `gw` (`(p/k)·q·k`) is
/// overwritten with the batch-summed defining-vector gradient.  Weight-grad
/// partials reduce in shard order: deterministic for a fixed thread count.
pub fn backward(
    bc: &BlockCirculant,
    cache: &ConvFwdCache,
    gys: &[f32],
    batch: usize,
    shape: ConvShape,
    gx: &mut [f32],
    gw: &mut [f32],
) -> PhaseCounters {
    let threads = sched::shard_count(batch, 2 * bc.p * bc.q * (bc.k / 2 + 1) * shape.h * shape.w);
    backward_threads(bc, cache, gys, batch, shape, gx, gw, threads, true)
}

/// [`backward`] pinned to one shard — the serial baseline for benches and
/// the `CIRCNN_THREADS=1` fallback tests.
pub fn backward_serial(
    bc: &BlockCirculant,
    cache: &ConvFwdCache,
    gys: &[f32],
    batch: usize,
    shape: ConvShape,
    gx: &mut [f32],
    gw: &mut [f32],
) -> PhaseCounters {
    backward_threads(bc, cache, gys, batch, shape, gx, gw, 1, true)
}

/// The pre-resident tap ordering (output pixel outer, weight spectra
/// re-fetched per pixel), kept as the twin the resident backward is pinned
/// against: `dL/dw` bitwise, `dL/dx` with tolerance (its padded-grid
/// accumulators gather taps in a different order under the inversion).
/// Strictly serial (one shard).
pub fn backward_pixel_outer(
    bc: &BlockCirculant,
    cache: &ConvFwdCache,
    gys: &[f32],
    batch: usize,
    shape: ConvShape,
    gx: &mut [f32],
    gw: &mut [f32],
) -> PhaseCounters {
    backward_threads(bc, cache, gys, batch, shape, gx, gw, 1, false)
}

#[allow(clippy::too_many_arguments)]
fn backward_threads(
    bc: &BlockCirculant,
    cache: &ConvFwdCache,
    gys: &[f32],
    batch: usize,
    shape: ConvShape,
    gx: &mut [f32],
    gw: &mut [f32],
    threads: usize,
    resident: bool,
) -> PhaseCounters {
    let k = bc.k;
    assert_eq!(shape.c % k, 0, "k must divide the channel count");
    let qc = shape.c / k;
    assert_eq!(bc.q, qc * shape.r * shape.r, "weight grid != (c/k)*r*r input blocks");
    let (pb, p_out) = (bc.p, bc.rows());
    let plan = bc.plan_arc();
    let kh = plan.half_bins();
    let g = Geom::new(shape);
    let (ihw, ohw) = (g.ih * g.iw, g.oh * g.ow);
    let spec_stride = qc * kh;
    assert_eq!(gys.len(), batch * ohw * p_out, "upstream gradient size");
    assert_eq!(gx.len(), batch * shape.h * shape.w * shape.c, "input gradient size");
    assert_eq!(gw.len(), bc.p * bc.q * k, "weight gradient size");
    let mut counters = PhaseCounters::default();
    if batch == 0 {
        gw.fill(0.0);
        return counters;
    }
    assert_eq!(cache.xfr.len(), batch * ihw * spec_stride, "stale forward cache");

    let bwd_shard = |b0: usize,
                     gy_c: &[f32],
                     gx_c: &mut [f32]|
     -> (PhaseCounters, Vec<f32>, Vec<f32>) {
        let b_here = gy_c.len() / (ohw * p_out);
        let mut ws = ShardWorkspace::new(k, 0, 0);
        // one sample's grad spectra `[opix][i][kh]` and input-grad spectra
        // `[pix][cb][kh]` (padded grid), reused across the shard's samples
        let mut gsr = vec![0.0f32; ohw * pb * kh];
        let mut gsi = vec![0.0f32; ohw * pb * kh];
        let mut gxr = vec![0.0f32; ihw * spec_stride];
        let mut gxi = vec![0.0f32; ihw * spec_stride];
        let mut gwr = vec![0.0f32; pb * bc.q * kh];
        let mut gwi = vec![0.0f32; pb * bc.q * kh];
        let mut c = PhaseCounters::default();
        for b in 0..b_here {
            let gb = b0 + b; // global sample index into the forward cache
            for opix in 0..ohw {
                for i in 0..pb {
                    let src = (b * ohw + opix) * p_out + i * k;
                    let off = (opix * pb + i) * kh;
                    plan.rfft_halfspec(
                        &gy_c[src..src + k],
                        &mut gsr[off..off + kh],
                        &mut gsi[off..off + kh],
                        &mut ws.scratch,
                    );
                    c.ffts += 1;
                }
            }
            gxr.fill(0.0);
            gxi.fill(0.0);
            if resident {
                // weight-block-outer: conj(W_ij) and the gw_ij accumulator
                // row stay hot while every output pixel streams through
                // them (the forward's resident inversion).  Per gw_ij lane
                // the pixels still arrive in ascending order — bitwise
                // equal to the pixel-outer twin; the gx padded-grid lanes
                // gather their taps in a different order (tolerance-pinned).
                for i in 0..pb {
                    for cb in 0..qc {
                        for di in 0..g.r {
                            for dj in 0..g.r {
                                let j = (cb * g.r + di) * g.r + dj;
                                let (wr, wi) = bc.spectrum(i, j);
                                let woff = (i * bc.q + j) * kh;
                                for opix in 0..ohw {
                                    let (oy, ox) = (opix / g.ow, opix % g.ow);
                                    let goff = (opix * pb + i) * kh;
                                    let pix = (oy + di) * g.iw + ox + dj;
                                    let xg = pix * spec_stride + cb * kh;
                                    complex_conj_mul_acc(
                                        wr,
                                        wi,
                                        &gsr[goff..goff + kh],
                                        &gsi[goff..goff + kh],
                                        &mut gxr[xg..xg + kh],
                                        &mut gxi[xg..xg + kh],
                                    );
                                    c.mult_groups += 1;
                                    let xo = (gb * ihw + pix) * spec_stride + cb * kh;
                                    complex_conj_mul_acc(
                                        &cache.xfr[xo..xo + kh],
                                        &cache.xfi[xo..xo + kh],
                                        &gsr[goff..goff + kh],
                                        &gsi[goff..goff + kh],
                                        &mut gwr[woff..woff + kh],
                                        &mut gwi[woff..woff + kh],
                                    );
                                    c.mult_groups += 1;
                                }
                            }
                        }
                    }
                }
            } else {
                // pixel-outer: the pre-resident tap walk, kept verbatim
                for opix in 0..ohw {
                    let (oy, ox) = (opix / g.ow, opix % g.ow);
                    for i in 0..pb {
                        let goff = (opix * pb + i) * kh;
                        for cb in 0..qc {
                            for di in 0..g.r {
                                for dj in 0..g.r {
                                    let j = (cb * g.r + di) * g.r + dj;
                                    let pix = (oy + di) * g.iw + ox + dj;
                                    let (wr, wi) = bc.spectrum(i, j);
                                    let xg = pix * spec_stride + cb * kh;
                                    complex_conj_mul_acc(
                                        wr,
                                        wi,
                                        &gsr[goff..goff + kh],
                                        &gsi[goff..goff + kh],
                                        &mut gxr[xg..xg + kh],
                                        &mut gxi[xg..xg + kh],
                                    );
                                    c.mult_groups += 1;
                                    let xo = (gb * ihw + pix) * spec_stride + cb * kh;
                                    let woff = (i * bc.q + j) * kh;
                                    complex_conj_mul_acc(
                                        &cache.xfr[xo..xo + kh],
                                        &cache.xfi[xo..xo + kh],
                                        &gsr[goff..goff + kh],
                                        &gsi[goff..goff + kh],
                                        &mut gwr[woff..woff + kh],
                                        &mut gwi[woff..woff + kh],
                                    );
                                    c.mult_groups += 1;
                                }
                            }
                        }
                    }
                }
            }
            for y in 0..g.h {
                for x in 0..g.w {
                    let pix = (y + g.lo) * g.iw + x + g.lo;
                    for cb in 0..qc {
                        let xg = pix * spec_stride + cb * kh;
                        let dst = ((b * g.h + y) * g.w + x) * g.c + cb * k;
                        plan.irfft_halfspec(
                            &gxr[xg..xg + kh],
                            &gxi[xg..xg + kh],
                            &mut gx_c[dst..dst + k],
                            &mut ws.scratch,
                        );
                        c.iffts += 1;
                    }
                }
            }
        }
        (c, gwr, gwi)
    };

    let per_gy = ohw * p_out;
    let per_gx = shape.h * shape.w * shape.c;
    let partials: Vec<(PhaseCounters, Vec<f32>, Vec<f32>)> = if threads <= 1 {
        vec![bwd_shard(0, gys, gx)]
    } else {
        let shard = batch.div_ceil(threads);
        std::thread::scope(|scope| {
            let mut handles = Vec::with_capacity(threads);
            let mut b0 = 0;
            for (gy_c, gx_c) in gys.chunks(shard * per_gy).zip(gx.chunks_mut(shard * per_gx)) {
                let here = gy_c.len() / per_gy;
                let (start, f) = (b0, &bwd_shard);
                handles.push(scope.spawn(move || f(start, gy_c, gx_c)));
                b0 += here;
            }
            handles
                .into_iter()
                .map(|h| h.join().expect("conv backward shard panicked"))
                .collect()
        })
    };
    let mut gwr = vec![0.0f32; pb * bc.q * kh];
    let mut gwi = vec![0.0f32; pb * bc.q * kh];
    for (c, pr, pi) in partials {
        counters.add(c);
        for (a, v) in gwr.iter_mut().zip(&pr) {
            *a += v;
        }
        for (a, v) in gwi.iter_mut().zip(&pi) {
            *a += v;
        }
    }
    let mut scratch = vec![0.0f32; 2 * k];
    for t in 0..pb * bc.q {
        plan.irfft_halfspec(
            &gwr[t * kh..(t + 1) * kh],
            &gwi[t * kh..(t + 1) * kh],
            &mut gw[t * k..(t + 1) * k],
            &mut scratch,
        );
        counters.iffts += 1;
    }
    counters
}

/// The pre-PR serial walk: one core, one image at a time, padded grid
/// materialized and FFT'd border included.  Kept verbatim as the baseline
/// [`forward`] must match bit-for-bit (property-tested) and the benches
/// measure it against; its counters show the border FFTs the parallel path
/// skips.
pub fn forward_serial(
    bc: &BlockCirculant,
    xs: &[f32],
    batch: usize,
    shape: ConvShape,
    bias: &[f32],
    relu: bool,
) -> ConvOutput {
    let ConvShape { h, w, c, r, same } = shape;
    let k = bc.k;
    assert_eq!(xs.len(), batch * h * w * c, "input buffer size");
    let p_out = bc.rows();
    let per = h * w * c;
    let plan = bc.plan_arc();
    let kh = plan.half_bins();
    let (qc, pb) = (c / k, p_out / k);
    let mut counters = PhaseCounters::default();
    let mut out = Vec::new();
    let (mut oh, mut ow) = (0, 0);
    let mut scratch = vec![0.0f32; 2 * k];
    let mut xfr: Vec<f32> = Vec::new();
    let mut xfi: Vec<f32> = Vec::new();
    let (mut acc_r, mut acc_i) = (vec![0.0f32; kh], vec![0.0f32; kh]);
    for b in 0..batch {
        let img = &xs[b * per..(b + 1) * per];
        let padded;
        let (src, ih, iw): (&[f32], usize, usize) = if same {
            let (p_, ph, pw) = im2col::pad_same(img, h, w, c, r);
            padded = p_;
            (&padded, ph, pw)
        } else {
            (img, h, w)
        };
        (oh, ow) = (ih - r + 1, iw - r + 1);
        if out.is_empty() {
            out = vec![0.0f32; batch * oh * ow * p_out];
        }
        // phase 1: one rFFT per (input pixel, channel block)
        xfr.resize(ih * iw * qc * kh, 0.0);
        xfi.resize(ih * iw * qc * kh, 0.0);
        for pix in 0..ih * iw {
            for cb in 0..qc {
                let off = (pix * qc + cb) * kh;
                plan.rfft_halfspec(
                    &src[pix * c + cb * k..pix * c + (cb + 1) * k],
                    &mut xfr[off..off + kh],
                    &mut xfi[off..off + kh],
                    &mut scratch,
                );
                counters.ffts += 1;
            }
        }
        // phases 2+3: per-pixel spectral MAC + one IFFT per
        // (output pixel, output block)
        for oy in 0..oh {
            for ox in 0..ow {
                let dst = ((b * oh + oy) * ow + ox) * p_out;
                for i in 0..pb {
                    acc_r.fill(0.0);
                    acc_i.fill(0.0);
                    for cb in 0..qc {
                        for di in 0..r {
                            for dj in 0..r {
                                let j = (cb * r + di) * r + dj;
                                let (wr, wi) = bc.spectrum(i, j);
                                let pix = (oy + di) * iw + ox + dj;
                                let xo = (pix * qc + cb) * kh;
                                complex_mul_acc(
                                    wr,
                                    wi,
                                    &xfr[xo..xo + kh],
                                    &xfi[xo..xo + kh],
                                    &mut acc_r,
                                    &mut acc_i,
                                );
                                counters.mult_groups += 1;
                            }
                        }
                    }
                    plan.irfft_halfspec(
                        &acc_r,
                        &acc_i,
                        &mut out[dst + i * k..dst + (i + 1) * k],
                        &mut scratch,
                    );
                    counters.iffts += 1;
                }
            }
        }
    }
    super::finish_rows(&mut out, bias, p_out, relu);
    ConvOutput { data: out, oh, ow, counters }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{assert_all_close, forall};
    use crate::util::rng::SplitMix;

    fn random_conv_bc(
        rng: &mut SplitMix,
        pb: usize,
        qc: usize,
        r: usize,
        k: usize,
    ) -> BlockCirculant {
        let qb = qc * r * r;
        let mut bc = BlockCirculant::new(pb, qb, k, rng.normal_vec(pb * qb * k));
        bc.precompute();
        bc
    }

    #[test]
    fn prop_parallel_conv_bit_identical_to_serial() {
        // the resident pipeline only reorders independent per-pixel work
        // (per (pixel, output block) accumulator the taps still arrive in
        // (cb, di, dj) order), and the skipped border spectra are
        // identically zero, so resident, pixel-outer and the pre-PR serial
        // walk must all agree bit for bit — no tolerance
        forall(
            "resident bc-conv == pixel-outer == serial pre-PR path, bitwise",
            |rng| {
                let k = 1usize << (1 + rng.below(4)); // 2..16
                let qc = 1 + rng.below(3) as usize;
                let pb = 1 + rng.below(3) as usize;
                let r = 1 + rng.below(3) as usize;
                let same = rng.below(2) == 1;
                let (h, w) = (r + rng.below(5) as usize, r + rng.below(5) as usize);
                let batch = 1 + rng.below(6) as usize;
                let c = qc * k;
                let bc = random_conv_bc(rng, pb, qc, r, k);
                let xs = rng.normal_vec(batch * h * w * c);
                let bias = rng.normal_vec(pb * k);
                (bc, xs, batch, ConvShape { h, w, c, r, same }, bias)
            },
            |(bc, xs, batch, shape, bias)| {
                let par = forward(bc, xs, *batch, *shape, bias, true);
                let ser = forward_serial(bc, xs, *batch, *shape, bias, true);
                if (par.oh, par.ow) != (ser.oh, ser.ow) {
                    return Err(format!(
                        "output dims ({}, {}) != serial ({}, {})",
                        par.oh, par.ow, ser.oh, ser.ow
                    ));
                }
                if par.data != ser.data {
                    let i = par
                        .data
                        .iter()
                        .zip(&ser.data)
                        .position(|(a, b)| a.to_bits() != b.to_bits())
                        .unwrap();
                    return Err(format!(
                        "output differs at {i}: {} vs {}",
                        par.data[i], ser.data[i]
                    ));
                }
                let po = forward_pixel_outer(bc, xs, *batch, *shape, bias, true);
                if po.data != par.data {
                    return Err("pixel-outer twin differs from resident (bitwise)".into());
                }
                if po.counters != par.counters {
                    return Err(format!(
                        "ordering must not change executed counters: {:?} vs {:?}",
                        po.counters, par.counters
                    ));
                }
                Ok(())
            },
        );
    }

    #[test]
    fn prop_conv_matches_per_patch_matvec_oracle() {
        // Eqn. 1 ground truth: each output pixel is the block-circulant
        // matvec of its (c_block, di, dj, c_in_block)-ordered patch
        forall(
            "bc-conv == per-patch naive matvec",
            |rng| {
                let k = 1usize << (1 + rng.below(3)); // 2..8
                let qc = 1 + rng.below(2) as usize;
                let pb = 1 + rng.below(2) as usize;
                let r = 1 + rng.below(2) as usize;
                let same = rng.below(2) == 1;
                let (h, w) = (r + rng.below(3) as usize, r + rng.below(3) as usize);
                let c = qc * k;
                let bc = random_conv_bc(rng, pb, qc, r, k);
                let xs = rng.normal_vec(h * w * c);
                (bc, xs, ConvShape { h, w, c, r, same })
            },
            |(bc, xs, shape)| {
                let got = forward(bc, xs, 1, *shape, &[], false);
                let (src, ih, iw) = if shape.same {
                    im2col::pad_same(xs, shape.h, shape.w, shape.c, shape.r)
                } else {
                    (xs.clone(), shape.h, shape.w)
                };
                let cols = im2col::im2col(&src, ih, iw, shape.c, shape.r, bc.k);
                let patch = bc.cols();
                let p_out = bc.rows();
                let mut want = vec![0.0f32; got.oh * got.ow * p_out];
                for (pix, col) in cols.chunks(patch).enumerate() {
                    bc.matvec_naive(col, &mut want[pix * p_out..(pix + 1) * p_out]);
                }
                assert_all_close(&got.data, &want, 2e-3, 2e-3)
            },
        );
    }

    #[test]
    fn conv_multi_shard_case_bit_identical_and_skips_border_ffts() {
        // big enough that shard_count() actually splits both sweeps on any
        // multi-core host (the property tests' small cases stay serial
        // under the min-work heuristic)
        let mut rng = SplitMix::new(0xC0DE);
        let (k, qc, pb, r, h, w, batch) = (8, 4, 4, 3, 16, 16, 8);
        let c = qc * k;
        let shape = ConvShape { h, w, c, r, same: true };
        let bc = random_conv_bc(&mut rng, pb, qc, r, k);
        let xs = rng.normal_vec(batch * h * w * c);
        let bias = rng.normal_vec(pb * k);
        let par = forward(&bc, &xs, batch, shape, &bias, true);
        let ser = forward_serial(&bc, &xs, batch, shape, &bias, true);
        assert!(par.data == ser.data, "sharded conv must be bitwise equal to serial");
        // same numbers, fewer transforms: the serial walk FFTs the padded
        // border, the parallel path charges only the h*w interior pixels
        assert_eq!(par.counters.ffts, (batch * qc * h * w) as u64);
        assert_eq!(
            ser.counters.ffts,
            (batch * qc * (h + r - 1) * (w + r - 1)) as u64
        );
        assert!(par.counters.ffts < ser.counters.ffts);
        // phases 2+3 execute identical work on both paths
        assert_eq!(par.counters.mult_groups, ser.counters.mult_groups);
        assert_eq!(par.counters.iffts, ser.counters.iffts);
    }

    #[test]
    fn valid_conv_counters_match_decoupled_minimum() {
        let mut rng = SplitMix::new(42);
        let (k, qc, pb, r, h, w) = (4, 2, 2, 3, 6, 5);
        let c = qc * k;
        let bc = random_conv_bc(&mut rng, pb, qc, r, k);
        let xs = rng.normal_vec(h * w * c);
        let o = forward(&bc, &xs, 1, ConvShape { h, w, c, r, same: false }, &[], false);
        let (oh, ow) = (h - r + 1, w - r + 1);
        assert_eq!((o.oh, o.ow), (oh, ow));
        assert_eq!(o.counters.ffts, (qc * h * w) as u64);
        assert_eq!(o.counters.iffts, (pb * oh * ow) as u64);
        assert_eq!(o.counters.mult_groups, (pb * qc * r * r * oh * ow) as u64);
    }

    #[test]
    fn prop_fixed_conv_sharded_bitwise_equal_serial() {
        // the fixed conv's per-pixel work (quantize, int MAC, rescale,
        // IFFT) is independent, so sharding either sweep must not change a
        // single bit of the output
        forall(
            "forward_fixed (sharded) == forward_fixed_serial, bitwise",
            |rng| {
                let k = 1usize << (1 + rng.below(4)); // 2..16
                let qc = 1 + rng.below(3) as usize;
                let pb = 1 + rng.below(3) as usize;
                let r = 1 + rng.below(3) as usize;
                let same = rng.below(2) == 1;
                let (h, w) = (r + rng.below(5) as usize, r + rng.below(5) as usize);
                let batch = 1 + rng.below(6) as usize;
                let bits = 8 + rng.below(9) as u32; // 8..=16
                let c = qc * k;
                let mut bc = random_conv_bc(rng, pb, qc, r, k);
                bc.precompute_fixed(bits);
                let xs = rng.normal_vec(batch * h * w * c);
                let bias = rng.normal_vec(pb * k);
                (bc, xs, batch, ConvShape { h, w, c, r, same }, bias)
            },
            |(bc, xs, batch, shape, bias)| {
                let par = forward_fixed(bc, xs, *batch, *shape, bias, true);
                let ser = forward_fixed_serial(bc, xs, *batch, *shape, bias, true);
                if par.data != ser.data {
                    return Err("fixed conv sharded differs from serial (bitwise)".into());
                }
                if par.counters != ser.counters {
                    return Err(format!(
                        "sharding must not change executed counters: {:?} vs {:?}",
                        par.counters, ser.counters
                    ));
                }
                Ok(())
            },
        );
    }

    #[test]
    fn fixed_conv_multi_shard_case_bit_identical_and_tracks_f32() {
        // large enough that shard_count() splits both sweeps on a
        // multi-core host; 16 bits exercises nonzero accumulator headroom
        let mut rng = SplitMix::new(0xF1C0);
        let (k, qc, pb, r, h, w, batch) = (8, 4, 4, 3, 16, 16, 8);
        let c = qc * k;
        let shape = ConvShape { h, w, c, r, same: true };
        let mut bc = random_conv_bc(&mut rng, pb, qc, r, k);
        let xs = rng.normal_vec(batch * h * w * c);
        let bias = rng.normal_vec(pb * k);
        let want = forward(&bc, &xs, batch, shape, &bias, false);
        for bits in [12u32, 16] {
            bc.precompute_fixed(bits);
            let par = forward_fixed(&bc, &xs, batch, shape, &bias, false);
            let ser = forward_fixed_serial(&bc, &xs, batch, shape, &bias, false);
            assert!(par.data == ser.data, "fixed conv must be bitwise equal at {bits} bits");
            // same executed transform counts as the f32 path (border FFTs
            // skipped on both)
            assert_eq!(par.counters, want.counters);
            let snr = crate::circulant::fixed::snr_db(&want.data, &par.data);
            assert!(snr > 35.0, "{bits}-bit conv SNR too low: {snr} dB");
        }
    }

    #[test]
    #[should_panic(expected = "precompute_fixed")]
    fn fixed_conv_without_precompute_fixed_panics() {
        let mut rng = SplitMix::new(7);
        let bc = random_conv_bc(&mut rng, 1, 1, 3, 4);
        let shape = ConvShape { h: 5, w: 5, c: 4, r: 3, same: true };
        forward_fixed(&bc, &rng.normal_vec(5 * 5 * 4), 1, shape, &[], false);
    }

    /// `L = Σ_pix u_pix · (to_dense(bc) @ patch_pix)` in f64 via the im2col
    /// oracle — the dense-expansion loss the conv backward is checked
    /// against (one sample).
    fn conv_dense_loss(dense: &[f32], p_out: usize, k: usize, xs: &[f32], shape: ConvShape, us: &[f32]) -> f64 {
        let (src, ih, iw) = if shape.same {
            im2col::pad_same(xs, shape.h, shape.w, shape.c, shape.r)
        } else {
            (xs.to_vec(), shape.h, shape.w)
        };
        let cols = im2col::im2col(&src, ih, iw, shape.c, shape.r, k);
        let patch = (shape.c / k) * shape.r * shape.r * k;
        let (oh, ow) = (ih - shape.r + 1, iw - shape.r + 1);
        let mut total = 0.0f64;
        for pix in 0..oh * ow {
            for i in 0..p_out {
                let mut acc = 0.0f64;
                for t in 0..patch {
                    acc += dense[i * patch + t] as f64 * cols[pix * patch + t] as f64;
                }
                total += acc * us[pix * p_out + i] as f64;
            }
        }
        total
    }

    #[test]
    fn conv_backward_matches_dense_numeric_gradients() {
        // dL/dw and dL/dx from the conjugate-spectrum conv backward vs
        // central finite differences of the dense-expansion loss, at the
        // 1e-3 rtol / 1e-3 atol acceptance bar — swept over even and odd
        // kernel sizes, SAME and VALID padding, and the k=2 edge
        let cases = [
            (2usize, 1usize, 1usize, 1usize, true),
            (2, 2, 1, 2, true),
            (2, 1, 2, 2, false),
            (4, 2, 2, 3, true),
            (4, 1, 1, 3, false),
            (4, 2, 1, 2, false),
        ];
        for (case, &(k, qc, pb, r, same)) in cases.iter().enumerate() {
            let mut rng = SplitMix::new(0xFD00 + case as u64);
            let (h, w) = (r + 2, r + 1);
            let c = qc * k;
            let shape = ConvShape { h, w, c, r, same };
            let w0 = rng.normal_vec(pb * qc * r * r * k);
            let mut bc = BlockCirculant::new(pb, qc * r * r, k, w0.clone());
            bc.precompute();
            let p_out = bc.rows();
            let (oh, ow) = if same { (h, w) } else { (h - r + 1, w - r + 1) };
            let xs = rng.normal_vec(h * w * c);
            let us = rng.normal_vec(oh * ow * p_out);
            // analytic gradients
            let mut cache = ConvFwdCache::new();
            forward_cached(&bc, &xs, 1, shape, &[], false, &mut cache);
            let mut gx = vec![0.0; h * w * c];
            let mut gw = vec![0.0; bc.param_count()];
            backward(&bc, &cache, &us, 1, shape, &mut gx, &mut gw);
            // numeric central differences
            let eps = 1e-2f32;
            let check = |got: f32, want: f64, what: String| {
                assert!(
                    (got as f64 - want).abs() <= 1e-3 + 1e-3 * want.abs(),
                    "case {case}: {what}: analytic {got} vs numeric {want}"
                );
            };
            for t in 0..w0.len() {
                let mut wp = w0.clone();
                let (hi_w, lo_w) = (w0[t] + eps, w0[t] - eps);
                wp[t] = hi_w;
                let hi = conv_dense_loss(
                    &BlockCirculant::new(pb, qc * r * r, k, wp.clone()).to_dense(),
                    p_out,
                    k,
                    &xs,
                    shape,
                    &us,
                );
                wp[t] = lo_w;
                let lo = conv_dense_loss(
                    &BlockCirculant::new(pb, qc * r * r, k, wp).to_dense(),
                    p_out,
                    k,
                    &xs,
                    shape,
                    &us,
                );
                check(gw[t], (hi - lo) / (hi_w - lo_w) as f64, format!("dL/dw[{t}]"));
            }
            let dense = bc.to_dense();
            for t in 0..xs.len() {
                let mut xp = xs.clone();
                let (hi_x, lo_x) = (xs[t] + eps, xs[t] - eps);
                xp[t] = hi_x;
                let hi = conv_dense_loss(&dense, p_out, k, &xp, shape, &us);
                xp[t] = lo_x;
                let lo = conv_dense_loss(&dense, p_out, k, &xp, shape, &us);
                check(gx[t], (hi - lo) / (hi_x - lo_x) as f64, format!("dL/dx[{t}]"));
            }
        }
    }

    #[test]
    fn conv_backward_serial_close_to_parallel_with_equal_counters() {
        let mut rng = SplitMix::new(0xBAD2);
        let (k, qc, pb, r, h, w, batch) = (8, 2, 2, 3, 10, 10, 8);
        let c = qc * k;
        let shape = ConvShape { h, w, c, r, same: true };
        let bc = random_conv_bc(&mut rng, pb, qc, r, k);
        let xs = rng.normal_vec(batch * h * w * c);
        let gys = rng.normal_vec(batch * h * w * pb * k);
        let mut cache = ConvFwdCache::new();
        forward_cached(&bc, &xs, batch, shape, &[], false, &mut cache);
        let mut gx_p = vec![0.0; xs.len()];
        let mut gw_p = vec![0.0; bc.param_count()];
        let cp = backward(&bc, &cache, &gys, batch, shape, &mut gx_p, &mut gw_p);
        let mut gx_s = vec![0.0; xs.len()];
        let mut gw_s = vec![0.0; bc.param_count()];
        let cs = backward_serial(&bc, &cache, &gys, batch, shape, &mut gx_s, &mut gw_s);
        assert_eq!(cp, cs, "executed counters must not depend on sharding");
        // per-sample gx work is reordered only; gw regroups a sum
        assert!(gx_p == gx_s, "gx must be bitwise identical across shardings");
        assert_all_close(&gw_p, &gw_s, 1e-4, 1e-4).unwrap();
        // the per-step transform counts the training cost model charges:
        // B*iffts_total grad FFTs, B*ffts_total input-grad IFFTs (interior
        // pixels only) + one IFFT per weight block, 2*B*mult_groups MACs
        let b = batch as u64;
        let (ffts_total, iffts_total) = ((qc * h * w) as u64, (pb * h * w) as u64);
        let mult_total = (pb * qc * r * r * h * w) as u64;
        assert_eq!(cs.ffts, b * iffts_total);
        assert_eq!(cs.iffts, b * ffts_total + (pb * qc * r * r) as u64);
        assert_eq!(cs.mult_groups, 2 * b * mult_total);
    }

    #[test]
    fn conv_backward_resident_pinned_against_pixel_outer_twin() {
        // the resident inversion keeps dL/dw's per-accumulator op order
        // (output pixels ascending) — bitwise equal to the pixel-outer tap
        // walk — while dL/dx's padded-grid lanes gather their taps in a
        // different order: same math, reassociated sum, tolerance pin (the
        // finite-difference oracle test pins correctness independently)
        let mut rng = SplitMix::new(0x0DE2);
        for &(k, qc, pb, r, h, w, same) in
            &[(4usize, 2usize, 2usize, 3usize, 6usize, 5usize, true), (2, 1, 2, 2, 5, 4, false)]
        {
            let c = qc * k;
            let shape = ConvShape { h, w, c, r, same };
            let bc = random_conv_bc(&mut rng, pb, qc, r, k);
            let batch = 3;
            let (oh, ow) = if same { (h, w) } else { (h - r + 1, w - r + 1) };
            let xs = rng.normal_vec(batch * h * w * c);
            let gys = rng.normal_vec(batch * oh * ow * pb * k);
            let mut cache = ConvFwdCache::new();
            forward_cached(&bc, &xs, batch, shape, &[], false, &mut cache);
            let mut gx_r = vec![0.0; xs.len()];
            let mut gw_r = vec![0.0; bc.param_count()];
            let cr = backward_serial(&bc, &cache, &gys, batch, shape, &mut gx_r, &mut gw_r);
            let mut gx_p = vec![0.0; xs.len()];
            let mut gw_p = vec![0.0; bc.param_count()];
            let cp = backward_pixel_outer(&bc, &cache, &gys, batch, shape, &mut gx_p, &mut gw_p);
            assert_eq!(cr, cp, "ordering must not change executed counters");
            assert!(gw_r == gw_p, "dL/dw must be bitwise identical across orderings");
            assert_all_close(&gx_r, &gx_p, 1e-4, 1e-4).unwrap();
        }
    }

    #[test]
    fn forward_cached_reuses_buffers_and_matches_forward() {
        let mut rng = SplitMix::new(0xCACE);
        let (k, qc, pb, r, h, w, batch) = (4, 2, 2, 3, 6, 5, 3);
        let c = qc * k;
        let shape = ConvShape { h, w, c, r, same: true };
        let bc = random_conv_bc(&mut rng, pb, qc, r, k);
        let bias = rng.normal_vec(pb * k);
        let xs1 = rng.normal_vec(batch * h * w * c);
        let xs2 = rng.normal_vec(batch * h * w * c);
        let mut cache = ConvFwdCache::new();
        let a1 = forward_cached(&bc, &xs1, batch, shape, &bias, true, &mut cache);
        let cap = (cache.xfr.capacity(), cache.xfi.capacity());
        // second step through the same cache: no regrowth, same output as a
        // fresh forward (stale spectra fully overwritten / re-zeroed)
        let a2 = forward_cached(&bc, &xs2, batch, shape, &bias, true, &mut cache);
        assert_eq!((cache.xfr.capacity(), cache.xfi.capacity()), cap);
        let fresh = forward(&bc, &xs2, batch, shape, &bias, true);
        assert!(a2.data == fresh.data, "cached forward must equal fresh forward bitwise");
        assert_eq!(a1.counters, a2.counters);
    }

    #[test]
    fn empty_batch_returns_geometry_and_zero_counters() {
        let mut rng = SplitMix::new(7);
        let bc = random_conv_bc(&mut rng, 1, 1, 3, 4);
        let shape = ConvShape { h: 5, w: 5, c: 4, r: 3, same: true };
        let o = forward(&bc, &[], 0, shape, &[], true);
        assert_eq!((o.oh, o.ow), (5, 5));
        assert!(o.data.is_empty());
        assert_eq!(o.counters, PhaseCounters::default());
    }
}
