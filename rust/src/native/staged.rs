//! Three-phase staged execution of a block-circulant FC layer — the
//! *functional* realization of the Fig.-4 schedule the cycle simulator
//! (`crate::fpga::schedule`) costs.
//!
//! Where [`BlockCirculant::matvec`](crate::circulant::BlockCirculant::matvec)
//! interleaves the phases per sample, this executor runs them the way the
//! FPGA does — phase 1 (all input FFTs, whole batch), then phase 2 (all
//! spectral multiply-accumulates), then phase 3 (all IFFTs + bias +
//! activation) — and *counts* the transforms and multiply groups it
//! performs.  The counters must equal the workload description the
//! simulator charges cycles for ([`crate::models::FftWork`]): that equality
//! (pinned in `rust/tests/native_parity.rs`) is the evidence that the
//! regenerated Table-1 numbers cost exactly the work the datapath executes,
//! no more, no less.

use crate::circulant::fft::{complex_mul_acc, FftPlan};
use crate::circulant::{dense, BlockCirculant};

/// Re-exported from the substrate's shared scheduler: the counters are now
/// produced by every counted schedule (staged FC, CONV pipeline, training
/// backward), so the type lives in [`crate::circulant::sched`].
pub use crate::circulant::sched::PhaseCounters;

/// Staged (three-phase) batched `Y = X W^T + b` for a block-circulant
/// layer.  Output is identical to `bc.matmul` + bias/activation; the
/// difference is the schedule (and the returned counters).
///
/// `xs`: `(batch, q*k)` row-major; `out`: `(batch, p*k)`.
pub fn bc_dense_staged(
    bc: &BlockCirculant,
    xs: &[f32],
    batch: usize,
    bias: &[f32],
    relu: bool,
    out: &mut [f32],
) -> PhaseCounters {
    let (p, q, k) = (bc.p, bc.q, bc.k);
    let plan = FftPlan::shared(k);
    let kh = plan.half_bins();
    assert_eq!(xs.len(), batch * q * k);
    assert_eq!(out.len(), batch * p * k);
    let mut counters = PhaseCounters::default();
    let mut scratch = vec![0.0f32; 2 * k];

    // ---- phase 1: FFT of every input block of every picture (q per image,
    // the decoupled minimum — each spectrum is reused by all p block-rows)
    let mut xr = vec![0.0f32; batch * q * kh];
    let mut xi = vec![0.0f32; batch * q * kh];
    for b in 0..batch {
        for j in 0..q {
            let src = &xs[(b * q + j) * k..(b * q + j + 1) * k];
            let off = (b * q + j) * kh;
            plan.rfft_halfspec(src, &mut xr[off..off + kh], &mut xi[off..off + kh], &mut scratch);
            counters.ffts += 1;
        }
    }

    // ---- phase 2: spectral multiply-accumulate, p*q groups per image
    let mut acc_r = vec![0.0f32; batch * p * kh];
    let mut acc_i = vec![0.0f32; batch * p * kh];
    for b in 0..batch {
        for i in 0..p {
            let dst = (b * p + i) * kh;
            for j in 0..q {
                let (wr, wi) = spec_of(bc, i, j, kh);
                let src = (b * q + j) * kh;
                complex_mul_acc(
                    &wr,
                    &wi,
                    &xr[src..src + kh],
                    &xi[src..src + kh],
                    &mut acc_r[dst..dst + kh],
                    &mut acc_i[dst..dst + kh],
                );
                counters.mult_groups += 1;
            }
        }
    }

    // ---- phase 3: one IFFT per output block per image + bias + activation
    for b in 0..batch {
        for i in 0..p {
            let src = (b * p + i) * kh;
            let dst = (b * p + i) * k;
            plan.irfft_halfspec(
                &acc_r[src..src + kh],
                &acc_i[src..src + kh],
                &mut out[dst..dst + k],
                &mut scratch,
            );
            counters.iffts += 1;
        }
        let row = &mut out[b * p * k..(b + 1) * p * k];
        if !bias.is_empty() {
            dense::add_bias(row, bias);
        }
        if relu {
            dense::relu(row);
        }
    }
    counters
}

/// The naive (non-decoupled) schedule of ablation AB1: FFT(x_j) is
/// recomputed for every block-row and the IFFT sits inside the Σ_j loop —
/// p·q forward and p·q inverse transforms.  Same output, more work; the
/// counter difference *is* experiment AB1's workload claim.
pub fn bc_dense_naive_schedule(
    bc: &BlockCirculant,
    xs: &[f32],
    batch: usize,
    bias: &[f32],
    relu: bool,
    out: &mut [f32],
) -> PhaseCounters {
    let (p, q, k) = (bc.p, bc.q, bc.k);
    let plan = FftPlan::shared(k);
    let kh = plan.half_bins();
    let mut counters = PhaseCounters::default();
    let mut scratch = vec![0.0f32; 2 * k];
    let (mut fr, mut fi) = (vec![0.0f32; kh], vec![0.0f32; kh]);
    let (mut mr, mut mi) = (vec![0.0f32; kh], vec![0.0f32; kh]);
    let mut term = vec![0.0f32; k];
    for b in 0..batch {
        for i in 0..p {
            let dst = (b * p + i) * k;
            out[dst..dst + k].fill(0.0);
            for j in 0..q {
                // recompute FFT(x_j) — the waste decoupling removes
                let src = &xs[(b * q + j) * k..(b * q + j + 1) * k];
                plan.rfft_halfspec(src, &mut fr, &mut fi, &mut scratch);
                counters.ffts += 1;
                let (wr, wi) = spec_of(bc, i, j, kh);
                mr.fill(0.0);
                mi.fill(0.0);
                complex_mul_acc(&wr, &wi, &fr, &fi, &mut mr, &mut mi);
                counters.mult_groups += 1;
                // IFFT inside the accumulation — q IFFTs per output block
                plan.irfft_halfspec(&mr, &mi, &mut term, &mut scratch);
                counters.iffts += 1;
                for (o, t) in out[dst..dst + k].iter_mut().zip(&term) {
                    *o += t;
                }
            }
        }
        let row = &mut out[b * p * k..(b + 1) * p * k];
        if !bias.is_empty() {
            dense::add_bias(row, bias);
        }
        if relu {
            dense::relu(row);
        }
    }
    counters
}

fn spec_of(bc: &BlockCirculant, i: usize, j: usize, kh: usize) -> (Vec<f32>, Vec<f32>) {
    // recompute from the defining vector: the staged executor owns its own
    // FFT plan and never borrows BlockCirculant's internal cache (which is
    // private); cost is irrelevant here — the counters track the *datapath*
    // work (phases 1-3), weight spectra are the paper's offline step
    let plan = FftPlan::shared(bc.k);
    let mut scratch = vec![0.0f32; 2 * bc.k];
    let (mut re, mut im) = (vec![0.0f32; kh], vec![0.0f32; kh]);
    plan.rfft_halfspec(bc.block(i, j), &mut re, &mut im, &mut scratch);
    (re, im)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{assert_all_close, forall};
    use crate::util::rng::SplitMix;

    fn random_case(r: &mut SplitMix) -> (BlockCirculant, usize, Vec<f32>, Vec<f32>) {
        let p = 1 + r.below(3) as usize;
        let q = 1 + r.below(3) as usize;
        let k = 1usize << (1 + r.below(5));
        let batch = 1 + r.below(4) as usize;
        let mut bc = BlockCirculant::new(p, q, k, r.normal_vec(p * q * k));
        bc.precompute();
        let xs = r.normal_vec(batch * q * k);
        let bias = r.normal_vec(p * k);
        (bc, batch, xs, bias)
    }

    #[test]
    fn prop_staged_matches_interleaved() {
        forall(
            "three-phase staged == per-sample interleaved",
            |r| random_case(r),
            |(bc, batch, xs, bias)| {
                let m = bc.rows();
                let mut staged = vec![0.0; batch * m];
                bc_dense_staged(bc, xs, *batch, bias, true, &mut staged);
                let mut plain = vec![0.0; batch * m];
                bc.matmul(xs, *batch, &mut plain);
                for row in 0..*batch {
                    let r = &mut plain[row * m..(row + 1) * m];
                    crate::circulant::dense::add_bias(r, bias);
                    crate::circulant::dense::relu(r);
                }
                assert_all_close(&staged, &plain, 1e-3, 1e-3)
            },
        );
    }

    #[test]
    fn prop_naive_schedule_same_numbers_more_work() {
        forall(
            "AB1: naive schedule computes the same layer with p*q transforms",
            |r| random_case(r),
            |(bc, batch, xs, bias)| {
                let (p, q) = (bc.p as u64, bc.q as u64);
                let m = bc.rows();
                let mut a = vec![0.0; batch * m];
                let ca = bc_dense_staged(bc, xs, *batch, bias, false, &mut a);
                let mut b = vec![0.0; batch * m];
                let cb = bc_dense_naive_schedule(bc, xs, *batch, bias, false, &mut b);
                assert_all_close(&a, &b, 2e-3, 2e-3)?;
                let ca1 = ca.per_image(*batch);
                let cb1 = cb.per_image(*batch);
                if ca1.ffts != q || ca1.iffts != p || ca1.mult_groups != p * q {
                    return Err(format!("decoupled counters wrong: {ca1:?}"));
                }
                if cb1.ffts != p * q || cb1.iffts != p * q {
                    return Err(format!("naive counters wrong: {cb1:?}"));
                }
                Ok(())
            },
        );
    }

    #[test]
    fn per_image_of_an_empty_batch_is_zero() {
        // batch == 0 used to divide by zero; an empty batch did no work
        let c = PhaseCounters { ffts: 7, mult_groups: 9, iffts: 3 };
        assert_eq!(c.per_image(0), PhaseCounters::default());
        assert_eq!(c.per_image(1), c);
    }

    #[test]
    fn counters_match_simulator_workload_for_fc_layers() {
        // the cross-check that makes Table 1 trustworthy: the transforms
        // the staged executor actually performs equal the per-layer FFT
        // workload the cycle simulator charges (models::FftWork)
        use crate::models::{self, Layer};
        for model in models::registry() {
            let accounting = model.accounting();
            let mut acc_iter = accounting.iter();
            for layer in &model.layers {
                let Layer::BcDense { n, m, k } = *layer else { continue };
                let row = acc_iter
                    .by_ref()
                    .find(|r| r.kind == "bc_dense")
                    .expect("accounting row");
                let mut rng = SplitMix::new(n as u64);
                let mut bc = BlockCirculant::new(m / k, n / k, k, rng.normal_vec(m / k * (n / k) * k));
                bc.precompute();
                let xs = rng.normal_vec(n);
                let mut out = vec![0.0; m];
                let c = bc_dense_staged(&bc, &xs, 1, &[], false, &mut out);
                assert_eq!(
                    c.ffts, row.fft_work.ffts_total,
                    "{}: executed FFTs != simulated FFTs",
                    model.name
                );
                assert_eq!(c.iffts, row.fft_work.iffts_total, "{}: IFFTs", model.name);
                assert_eq!(
                    c.mult_groups, row.fft_work.mult_groups_total,
                    "{}: multiply groups",
                    model.name
                );
            }
        }
    }

    #[test]
    fn counters_match_simulator_workload_for_conv_layers() {
        // the CONV half of the Table-1 cross-check: the transforms the
        // parallel pixel pipeline actually executes (including the padded
        // layers, whose all-zero border spectra it skips) equal the
        // per-image FFT workload the cycle simulator charges
        use crate::models::{self, Layer};
        use crate::native::conv::{self, ConvShape};
        for model in models::registry() {
            let accounting = model.accounting();
            let mut acc_iter = accounting.iter();
            let (mut h, mut w, mut c) = model.input;
            for layer in &model.layers {
                match *layer {
                    Layer::PriorPool { out_dim } => (h, w, c) = (out_dim, 1, 1),
                    Layer::AvgPool2 | Layer::MaxPool2 => (h, w) = (h / 2, w / 2),
                    Layer::Conv { p, r, same_pad, .. } => {
                        if !same_pad {
                            (h, w) = (h - r + 1, w - r + 1);
                        }
                        c = p;
                    }
                    Layer::BcConv { c: ci, p, r, k, same_pad } => {
                        assert_eq!(ci, c, "{}: registry shape walk diverged", model.name);
                        let row = acc_iter
                            .by_ref()
                            .find(|a| a.kind == "bc_conv")
                            .expect("accounting row");
                        let (pb, qb) = (p / k, (c / k) * r * r);
                        let mut rng = SplitMix::new((h * w * c) as u64);
                        let mut bc =
                            BlockCirculant::new(pb, qb, k, rng.normal_vec(pb * qb * k));
                        bc.precompute();
                        let batch = 2;
                        let xs = rng.normal_vec(batch * h * w * c);
                        let shape = ConvShape { h, w, c, r, same: same_pad };
                        let o = conv::forward(&bc, &xs, batch, shape, &[], false);
                        let per = o.counters.per_image(batch);
                        assert_eq!(
                            per.ffts, row.fft_work.ffts_total,
                            "{}: executed conv FFTs != simulated FFTs",
                            model.name
                        );
                        assert_eq!(
                            per.iffts, row.fft_work.iffts_total,
                            "{}: conv IFFTs",
                            model.name
                        );
                        assert_eq!(
                            per.mult_groups, row.fft_work.mult_groups_total,
                            "{}: conv multiply groups",
                            model.name
                        );
                        if !same_pad {
                            (h, w) = (h - r + 1, w - r + 1);
                        }
                        c = p;
                    }
                    Layer::Dense { .. }
                    | Layer::BcDense { .. }
                    | Layer::Flatten
                    | Layer::ResidualBegin
                    | Layer::ResidualEnd => {}
                }
            }
        }
    }
}
