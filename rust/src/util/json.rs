//! Minimal JSON parser and writer for the artifact manifest.
//!
//! Supports the full JSON grammar (objects, arrays, strings with escapes,
//! numbers, booleans, null).  Object key order is preserved.  This is a
//! substrate module: `serde_json` is not in the offline dependency closure.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    /// Parse a JSON document from text.
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters after document"));
        }
        Ok(v)
    }

    /// Object field lookup (None for non-objects / missing keys).
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(fields) => fields.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    /// Like [`get`](Self::get) but returns an error naming the key.
    pub fn require(&self, key: &str) -> Result<&Json, JsonError> {
        self.get(key)
            .ok_or_else(|| JsonError(format!("missing key {key:?}")))
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().filter(|n| *n >= 0.0 && n.fract() == 0.0).map(|n| n as u64)
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_u64().map(|n| n as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    /// Serialize back to compact JSON text.
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{n}"));
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(items) => {
                out.push('[');
                for (i, v) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(fields) => {
                out.push('{');
                for (i, (k, v)) in fields.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    /// Convert an object into a map for bulk access.
    pub fn to_map(&self) -> Option<BTreeMap<&str, &Json>> {
        match self {
            Json::Obj(fields) => Some(fields.iter().map(|(k, v)| (k.as_str(), v)).collect()),
            _ => None,
        }
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

/// JSON parse error with byte position context.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct JsonError(pub String);

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error: {}", self.0)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError(format!("{msg} at byte {}", self.pos))
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected {:?}", b as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(c) => Err(self.err(&format!("unexpected character {:?}", c as char))),
            None => Err(self.err("unexpected end of input")),
        }
    }

    fn literal(&mut self, lit: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(self.err(&format!("invalid literal, expected {lit}")))
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut fields = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(fields));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            fields.push((key, val));
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(fields)),
                _ => return Err(self.err("expected ',' or '}' in object")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(items)),
                _ => return Err(self.err("expected ',' or ']' in array")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'b') => out.push('\u{0008}'),
                    Some(b'f') => out.push('\u{000C}'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'u') => {
                        let cp = self.hex4()?;
                        // surrogate pair handling
                        let c = if (0xD800..0xDC00).contains(&cp) {
                            if self.bump() != Some(b'\\') || self.bump() != Some(b'u') {
                                return Err(self.err("missing low surrogate"));
                            }
                            let lo = self.hex4()?;
                            if !(0xDC00..0xE000).contains(&lo) {
                                return Err(self.err("invalid low surrogate"));
                            }
                            let combined =
                                0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                            char::from_u32(combined)
                        } else {
                            char::from_u32(cp)
                        };
                        out.push(c.ok_or_else(|| self.err("invalid unicode escape"))?);
                    }
                    _ => return Err(self.err("invalid escape")),
                },
                Some(c) if c < 0x20 => return Err(self.err("control character in string")),
                Some(c) => {
                    // re-assemble UTF-8 multibyte sequences
                    if c < 0x80 {
                        out.push(c as char);
                    } else {
                        let start = self.pos - 1;
                        let len = utf8_len(c);
                        let end = start + len;
                        if end > self.bytes.len() {
                            return Err(self.err("truncated utf-8"));
                        }
                        let s = std::str::from_utf8(&self.bytes[start..end])
                            .map_err(|_| self.err("invalid utf-8"))?;
                        out.push_str(s);
                        self.pos = end;
                    }
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, JsonError> {
        let mut v = 0u32;
        for _ in 0..4 {
            let c = self.bump().ok_or_else(|| self.err("truncated \\u escape"))?;
            v = v * 16
                + (c as char)
                    .to_digit(16)
                    .ok_or_else(|| self.err("invalid hex digit"))?;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("invalid number"))
    }
}

fn utf8_len(first: u8) -> usize {
    if first >= 0xF0 {
        4
    } else if first >= 0xE0 {
        3
    } else {
        2
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-1.5e3").unwrap(), Json::Num(-1500.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parses_nested_structures() {
        let v = Json::parse(r#"{"a": [1, {"b": null}], "c": "x"}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_str(), Some("x"));
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr[0].as_f64(), Some(1.0));
        assert_eq!(arr[1].get("b"), Some(&Json::Null));
    }

    #[test]
    fn parses_escapes_and_unicode() {
        let v = Json::parse(r#""a\n\t\"\\Aé""#).unwrap();
        assert_eq!(v.as_str(), Some("a\n\t\"\\Aé"));
        let v = Json::parse(r#""😀""#).unwrap();
        assert_eq!(v.as_str(), Some("😀"));
    }

    #[test]
    fn parses_raw_utf8() {
        let v = Json::parse("\"héllo → ∞\"").unwrap();
        assert_eq!(v.as_str(), Some("héllo → ∞"));
    }

    #[test]
    fn rejects_malformed() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("{\"a\" 1}").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("\"unterminated").is_err());
        assert!(Json::parse("nul").is_err());
    }

    #[test]
    fn roundtrips() {
        let src = r#"{"name":"x","vals":[1,2.5,true,null],"nested":{"k":"v"}}"#;
        let v = Json::parse(src).unwrap();
        let out = v.to_string();
        assert_eq!(Json::parse(&out).unwrap(), v);
    }

    #[test]
    fn integer_accessors() {
        let v = Json::parse("[3, 3.5, -2]").unwrap();
        let a = v.as_arr().unwrap();
        assert_eq!(a[0].as_u64(), Some(3));
        assert_eq!(a[1].as_u64(), None);
        assert_eq!(a[2].as_u64(), None);
    }

    #[test]
    fn empty_containers() {
        assert_eq!(Json::parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(Json::parse("{}").unwrap(), Json::Obj(vec![]));
        assert_eq!(Json::parse(" [ ] ").unwrap(), Json::Arr(vec![]));
    }

    #[test]
    fn key_order_preserved() {
        let v = Json::parse(r#"{"z":1,"a":2}"#).unwrap();
        if let Json::Obj(fields) = &v {
            assert_eq!(fields[0].0, "z");
            assert_eq!(fields[1].0, "a");
        } else {
            panic!()
        }
    }
}
