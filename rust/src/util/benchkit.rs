//! Tiny benchmark harness for the `harness = false` bench targets.
//!
//! criterion is not in the offline dependency closure, so this provides the
//! minimum viable equivalent: warmup, repeated timed runs, and a stats line
//! (median / mean / p95 / std-dev) in a stable parseable format.  All
//! `rust/benches/*.rs` targets use it.

use std::time::{Duration, Instant};

/// One benchmark measurement.
#[derive(Debug, Clone)]
pub struct Measurement {
    pub name: String,
    pub samples_ns: Vec<f64>,
    /// work items per iteration, for derived throughput (0 = no throughput)
    pub items_per_iter: u64,
}

impl Measurement {
    pub fn median_ns(&self) -> f64 {
        percentile(&self.samples_ns, 50.0)
    }

    pub fn mean_ns(&self) -> f64 {
        self.samples_ns.iter().sum::<f64>() / self.samples_ns.len() as f64
    }

    pub fn p95_ns(&self) -> f64 {
        percentile(&self.samples_ns, 95.0)
    }

    pub fn stddev_ns(&self) -> f64 {
        let mean = self.mean_ns();
        let var = self
            .samples_ns
            .iter()
            .map(|s| (s - mean) * (s - mean))
            .sum::<f64>()
            / self.samples_ns.len() as f64;
        var.sqrt()
    }

    /// Items per second at the median sample.
    pub fn throughput(&self) -> f64 {
        if self.items_per_iter == 0 {
            return 0.0;
        }
        self.items_per_iter as f64 / (self.median_ns() / 1e9)
    }

    /// Render the standard one-line report.
    pub fn report(&self) -> String {
        let mut line = format!(
            "bench {:44} median {:>12}  mean {:>12}  p95 {:>12}  sd {:>10}",
            self.name,
            fmt_ns(self.median_ns()),
            fmt_ns(self.mean_ns()),
            fmt_ns(self.p95_ns()),
            fmt_ns(self.stddev_ns()),
        );
        if self.items_per_iter > 0 {
            line.push_str(&format!("  thrpt {:>12.1}/s", self.throughput()));
        }
        line
    }
}

fn percentile(samples: &[f64], p: f64) -> f64 {
    let mut sorted = samples.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let idx = ((p / 100.0) * (sorted.len() - 1) as f64).round() as usize;
    sorted[idx.min(sorted.len() - 1)]
}

fn fmt_ns(ns: f64) -> String {
    if ns < 1_000.0 {
        format!("{ns:.1}ns")
    } else if ns < 1_000_000.0 {
        format!("{:.2}us", ns / 1_000.0)
    } else if ns < 1_000_000_000.0 {
        format!("{:.2}ms", ns / 1_000_000.0)
    } else {
        format!("{:.3}s", ns / 1_000_000_000.0)
    }
}

/// Benchmark runner with warmup + fixed sample count.
pub struct Bench {
    warmup: Duration,
    samples: usize,
    min_iters_per_sample: u64,
}

impl Default for Bench {
    fn default() -> Self {
        Self {
            warmup: Duration::from_millis(300),
            samples: 20,
            min_iters_per_sample: 1,
        }
    }
}

impl Bench {
    pub fn quick() -> Self {
        Self {
            warmup: Duration::from_millis(50),
            samples: 10,
            min_iters_per_sample: 1,
        }
    }

    pub fn with_samples(mut self, n: usize) -> Self {
        self.samples = n;
        self
    }

    /// Time `f`, printing and returning the measurement.  `items` scales the
    /// derived throughput (e.g. images per iteration).
    pub fn run<R>(&self, name: &str, items: u64, mut f: impl FnMut() -> R) -> Measurement {
        // warmup & calibration: find iters/sample so each sample >= ~1ms
        let warm_start = Instant::now();
        let mut calib_iters = 0u64;
        while warm_start.elapsed() < self.warmup {
            std::hint::black_box(f());
            calib_iters += 1;
        }
        let per_iter = self.warmup.as_nanos() as f64 / calib_iters.max(1) as f64;
        let iters = ((1_000_000.0 / per_iter).ceil() as u64)
            .clamp(self.min_iters_per_sample, 1_000_000);

        let mut samples_ns = Vec::with_capacity(self.samples);
        for _ in 0..self.samples {
            let t0 = Instant::now();
            for _ in 0..iters {
                std::hint::black_box(f());
            }
            samples_ns.push(t0.elapsed().as_nanos() as f64 / iters as f64);
        }
        let m = Measurement {
            name: name.to_string(),
            samples_ns,
            items_per_iter: items,
        };
        println!("{}", m.report());
        m
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentile_and_stats() {
        let m = Measurement {
            name: "t".into(),
            samples_ns: vec![10.0, 20.0, 30.0, 40.0, 50.0],
            items_per_iter: 2,
        };
        assert_eq!(m.median_ns(), 30.0);
        assert_eq!(m.mean_ns(), 30.0);
        assert!(m.stddev_ns() > 0.0);
        assert!((m.throughput() - 2.0 / 30e-9).abs() / m.throughput() < 1e-9);
    }

    #[test]
    fn bench_measures_something() {
        let b = Bench::quick().with_samples(3);
        let m = b.run("noop-sum", 1, || (0..100u64).sum::<u64>());
        assert!(m.median_ns() > 0.0);
        assert_eq!(m.samples_ns.len(), 3);
    }

    #[test]
    fn fmt_ns_units() {
        assert!(fmt_ns(12.0).ends_with("ns"));
        assert!(fmt_ns(12_000.0).ends_with("us"));
        assert!(fmt_ns(12_000_000.0).ends_with("ms"));
        assert!(fmt_ns(2_000_000_000.0).ends_with('s'));
    }
}
