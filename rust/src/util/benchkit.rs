//! Tiny benchmark harness for the `harness = false` bench targets.
//!
//! criterion is not in the offline dependency closure, so this provides the
//! minimum viable equivalent: warmup, repeated timed runs, and a stats line
//! (median / mean / p95 / std-dev) in a stable parseable format.  All
//! `rust/benches/*.rs` targets use it.
//!
//! Besides the human report, [`write_json`] emits the same measurements as
//! a machine-readable JSON document (via the from-scratch `util::json`
//! writer) so the perf trajectory is trackable across PRs — `benches/
//! circulant.rs` writes `BENCH_circulant.json` at the repo root.

use std::path::Path;
use std::time::{Duration, Instant};

use crate::util::json::Json;

/// One benchmark measurement.
#[derive(Debug, Clone)]
pub struct Measurement {
    pub name: String,
    pub samples_ns: Vec<f64>,
    /// work items per iteration, for derived throughput (0 = no throughput)
    pub items_per_iter: u64,
}

impl Measurement {
    pub fn median_ns(&self) -> f64 {
        percentile(&self.samples_ns, 50.0)
    }

    pub fn mean_ns(&self) -> f64 {
        self.samples_ns.iter().sum::<f64>() / self.samples_ns.len() as f64
    }

    pub fn p95_ns(&self) -> f64 {
        percentile(&self.samples_ns, 95.0)
    }

    pub fn stddev_ns(&self) -> f64 {
        let mean = self.mean_ns();
        let var = self
            .samples_ns
            .iter()
            .map(|s| (s - mean) * (s - mean))
            .sum::<f64>()
            / self.samples_ns.len() as f64;
        var.sqrt()
    }

    /// Items per second at the median sample.
    pub fn throughput(&self) -> f64 {
        if self.items_per_iter == 0 {
            return 0.0;
        }
        self.items_per_iter as f64 / (self.median_ns() / 1e9)
    }

    /// Render the standard one-line report.
    pub fn report(&self) -> String {
        let mut line = format!(
            "bench {:44} median {:>12}  mean {:>12}  p95 {:>12}  sd {:>10}",
            self.name,
            fmt_ns(self.median_ns()),
            fmt_ns(self.mean_ns()),
            fmt_ns(self.p95_ns()),
            fmt_ns(self.stddev_ns()),
        );
        if self.items_per_iter > 0 {
            line.push_str(&format!("  thrpt {:>12.1}/s", self.throughput()));
        }
        line
    }
}

impl Measurement {
    /// The measurement as a JSON object (stats only, not raw samples).
    pub fn to_json(&self) -> Json {
        Json::Obj(vec![
            ("name".into(), Json::Str(self.name.clone())),
            ("median_ns".into(), Json::Num(self.median_ns())),
            ("mean_ns".into(), Json::Num(self.mean_ns())),
            ("p95_ns".into(), Json::Num(self.p95_ns())),
            ("stddev_ns".into(), Json::Num(self.stddev_ns())),
            ("samples".into(), Json::Num(self.samples_ns.len() as f64)),
            ("items_per_iter".into(), Json::Num(self.items_per_iter as f64)),
            ("throughput_per_s".into(), Json::Num(self.throughput())),
        ])
    }
}

/// Write a bench suite as machine-readable JSON: the per-measurement stats
/// plus a `derived` map of named summary ratios (speedups etc.).  The
/// format is stable so cross-PR tooling can diff perf trajectories.
pub fn write_json(
    path: impl AsRef<Path>,
    suite: &str,
    results: &[Measurement],
    derived: &[(String, f64)],
) -> std::io::Result<()> {
    let epoch_s = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_secs())
        .unwrap_or(0);
    let doc = Json::Obj(vec![
        ("suite".into(), Json::Str(suite.to_string())),
        ("unix_time_s".into(), Json::Num(epoch_s as f64)),
        (
            "parallelism".into(),
            Json::Num(
                std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1) as f64,
            ),
        ),
        (
            "results".into(),
            Json::Arr(results.iter().map(Measurement::to_json).collect()),
        ),
        (
            "derived".into(),
            Json::Obj(
                derived
                    .iter()
                    .map(|(k, v)| (k.clone(), Json::Num(*v)))
                    .collect(),
            ),
        ),
    ]);
    std::fs::write(path, doc.to_string() + "\n")
}

/// Merge derived keys into an existing bench-JSON document's `derived`
/// map in place (updating keys that exist, appending ones that don't), so
/// serving-side measurements ride the same perf-trajectory file as the
/// kernel benches.  A missing or unparseable file gets a fresh doc via
/// [`write_json`].
pub fn merge_derived(
    path: impl AsRef<Path>,
    suite: &str,
    extra: &[(String, f64)],
) -> std::io::Result<()> {
    let path = path.as_ref();
    let merged = std::fs::read_to_string(path)
        .ok()
        .and_then(|t| Json::parse(&t).ok())
        .and_then(|doc| match doc {
            Json::Obj(mut fields) => {
                let slot = fields.iter_mut().find(|(k, _)| k == "derived")?;
                let Json::Obj(entries) = &mut slot.1 else { return None };
                for (k, v) in extra {
                    match entries.iter_mut().find(|(n, _)| n == k) {
                        Some(e) => e.1 = Json::Num(*v),
                        None => entries.push((k.clone(), Json::Num(*v))),
                    }
                }
                Some(Json::Obj(fields))
            }
            _ => None,
        });
    match merged {
        Some(doc) => std::fs::write(path, doc.to_string() + "\n"),
        None => write_json(path, suite, &[], extra),
    }
}

fn percentile(samples: &[f64], p: f64) -> f64 {
    let mut sorted = samples.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let idx = ((p / 100.0) * (sorted.len() - 1) as f64).round() as usize;
    sorted[idx.min(sorted.len() - 1)]
}

fn fmt_ns(ns: f64) -> String {
    if ns < 1_000.0 {
        format!("{ns:.1}ns")
    } else if ns < 1_000_000.0 {
        format!("{:.2}us", ns / 1_000.0)
    } else if ns < 1_000_000_000.0 {
        format!("{:.2}ms", ns / 1_000_000.0)
    } else {
        format!("{:.3}s", ns / 1_000_000_000.0)
    }
}

/// Benchmark runner with warmup + fixed sample count.
pub struct Bench {
    warmup: Duration,
    samples: usize,
    min_iters_per_sample: u64,
}

impl Default for Bench {
    fn default() -> Self {
        Self {
            warmup: Duration::from_millis(300),
            samples: 20,
            min_iters_per_sample: 1,
        }
    }
}

impl Bench {
    pub fn quick() -> Self {
        Self {
            warmup: Duration::from_millis(50),
            samples: 10,
            min_iters_per_sample: 1,
        }
    }

    pub fn with_samples(mut self, n: usize) -> Self {
        self.samples = n;
        self
    }

    /// Time `f`, printing and returning the measurement.  `items` scales the
    /// derived throughput (e.g. images per iteration).
    pub fn run<R>(&self, name: &str, items: u64, mut f: impl FnMut() -> R) -> Measurement {
        // warmup & calibration: find iters/sample so each sample >= ~1ms
        let warm_start = Instant::now();
        let mut calib_iters = 0u64;
        while warm_start.elapsed() < self.warmup {
            std::hint::black_box(f());
            calib_iters += 1;
        }
        let per_iter = self.warmup.as_nanos() as f64 / calib_iters.max(1) as f64;
        let iters = ((1_000_000.0 / per_iter).ceil() as u64)
            .clamp(self.min_iters_per_sample, 1_000_000);

        let mut samples_ns = Vec::with_capacity(self.samples);
        for _ in 0..self.samples {
            let t0 = Instant::now();
            for _ in 0..iters {
                std::hint::black_box(f());
            }
            samples_ns.push(t0.elapsed().as_nanos() as f64 / iters as f64);
        }
        let m = Measurement {
            name: name.to_string(),
            samples_ns,
            items_per_iter: items,
        };
        println!("{}", m.report());
        m
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentile_and_stats() {
        let m = Measurement {
            name: "t".into(),
            samples_ns: vec![10.0, 20.0, 30.0, 40.0, 50.0],
            items_per_iter: 2,
        };
        assert_eq!(m.median_ns(), 30.0);
        assert_eq!(m.mean_ns(), 30.0);
        assert!(m.stddev_ns() > 0.0);
        assert!((m.throughput() - 2.0 / 30e-9).abs() / m.throughput() < 1e-9);
    }

    #[test]
    fn bench_measures_something() {
        let b = Bench::quick().with_samples(3);
        let m = b.run("noop-sum", 1, || (0..100u64).sum::<u64>());
        assert!(m.median_ns() > 0.0);
        assert_eq!(m.samples_ns.len(), 3);
    }

    #[test]
    fn write_json_roundtrips_through_the_parser() {
        let m = Measurement {
            name: "rfft_halfspec/k256".into(),
            samples_ns: vec![100.0, 110.0, 120.0],
            items_per_iter: 1,
        };
        let path = std::env::temp_dir().join(format!("circnn_bench_{}.json", std::process::id()));
        write_json(&path, "circulant", &[m], &[("rfft_speedup_k256".into(), 1.7)]).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let doc = Json::parse(&text).unwrap();
        assert_eq!(doc.get("suite").and_then(|s| s.as_str()), Some("circulant"));
        let results = doc.get("results").and_then(|r| r.as_arr()).unwrap();
        assert_eq!(results.len(), 1);
        assert_eq!(results[0].get("median_ns").and_then(|v| v.as_f64()), Some(110.0));
        let derived = doc.get("derived").unwrap();
        assert_eq!(derived.get("rfft_speedup_k256").and_then(|v| v.as_f64()), Some(1.7));
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn merge_derived_updates_and_appends() {
        let path = std::env::temp_dir().join(format!("circnn_merge_{}.json", std::process::id()));
        write_json(&path, "circulant", &[], &[("a_ratio_x".into(), 1.0)]).unwrap();
        merge_derived(&path, "circulant", &[("a_ratio_x".into(), 2.0), ("b_ratio_y".into(), 3.0)])
            .unwrap();
        let doc = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        let derived = doc.get("derived").unwrap();
        assert_eq!(derived.get("a_ratio_x").and_then(|v| v.as_f64()), Some(2.0));
        assert_eq!(derived.get("b_ratio_y").and_then(|v| v.as_f64()), Some(3.0));
        std::fs::remove_file(&path).ok();

        // a missing file gets a fresh document
        let fresh = std::env::temp_dir().join(format!("circnn_merge2_{}.json", std::process::id()));
        std::fs::remove_file(&fresh).ok();
        merge_derived(&fresh, "circulant", &[("c_ratio_z".into(), 4.0)]).unwrap();
        let doc = Json::parse(&std::fs::read_to_string(&fresh).unwrap()).unwrap();
        assert_eq!(
            doc.get("derived").and_then(|d| d.get("c_ratio_z")).and_then(|v| v.as_f64()),
            Some(4.0)
        );
        std::fs::remove_file(&fresh).ok();
    }

    #[test]
    fn fmt_ns_units() {
        assert!(fmt_ns(12.0).ends_with("ns"));
        assert!(fmt_ns(12_000.0).ends_with("us"));
        assert!(fmt_ns(12_000_000.0).ends_with("ms"));
        assert!(fmt_ns(2_000_000_000.0).ends_with('s'));
    }
}
