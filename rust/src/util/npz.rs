//! Minimal NPY/NPZ reader — just enough to load the trained parameter
//! archives (`artifacts/params/*.npz`) into the native inference engine.
//!
//! Scope (matching what `numpy.savez` of f32 arrays produces): ZIP archives
//! with *stored* (method 0) entries, each an NPY v1.x file of
//! little-endian `<f4` data in C order.  Built from scratch because the
//! offline dependency closure has no zip/ndarray crates (same rationale as
//! `util::json`).

use std::collections::BTreeMap;
use std::path::Path;

/// One loaded array: shape + row-major f32 data.
#[derive(Debug, Clone)]
pub struct Array {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl Array {
    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }
}

/// Error type for archive parsing.
#[derive(Debug, thiserror::Error)]
#[error("npz: {0}")]
pub struct NpzError(pub String);

fn err(msg: impl Into<String>) -> NpzError {
    NpzError(msg.into())
}

fn rd_u16(b: &[u8], off: usize) -> u64 {
    u16::from_le_bytes([b[off], b[off + 1]]) as u64
}

fn rd_u32(b: &[u8], off: usize) -> u64 {
    u32::from_le_bytes([b[off], b[off + 1], b[off + 2], b[off + 3]]) as u64
}

/// Parse a ZIP archive (stored entries only) into `name -> bytes`.
pub fn unzip_stored(bytes: &[u8]) -> Result<BTreeMap<String, Vec<u8>>, NpzError> {
    // find End Of Central Directory (EOCD): signature 0x06054b50, scanned
    // backwards over the trailing comment space
    if bytes.len() < 22 {
        return Err(err("file too small for a zip archive"));
    }
    let mut eocd = None;
    let lo = bytes.len().saturating_sub(22 + 65536);
    for off in (lo..=bytes.len() - 22).rev() {
        if bytes[off..off + 4] == [0x50, 0x4b, 0x05, 0x06] {
            eocd = Some(off);
            break;
        }
    }
    let eocd = eocd.ok_or_else(|| err("no end-of-central-directory record"))?;
    let entries = rd_u16(bytes, eocd + 10) as usize;
    let mut cd = rd_u32(bytes, eocd + 16) as usize;

    let mut out = BTreeMap::new();
    for _ in 0..entries {
        if bytes.len() < cd + 46 || bytes[cd..cd + 4] != [0x50, 0x4b, 0x01, 0x02] {
            return Err(err("bad central-directory entry"));
        }
        let method = rd_u16(bytes, cd + 10);
        let csize = rd_u32(bytes, cd + 20) as usize;
        let usize_ = rd_u32(bytes, cd + 24) as usize;
        let nlen = rd_u16(bytes, cd + 28) as usize;
        let xlen = rd_u16(bytes, cd + 30) as usize;
        let clen = rd_u16(bytes, cd + 32) as usize;
        let lho = rd_u32(bytes, cd + 42) as usize;
        let name = String::from_utf8_lossy(&bytes[cd + 46..cd + 46 + nlen]).into_owned();
        if method != 0 {
            return Err(err(format!(
                "entry {name:?} uses compression method {method}; only stored (0) is supported \
                 (numpy.savez writes stored entries)"
            )));
        }
        if csize != usize_ {
            return Err(err(format!("entry {name:?}: stored sizes disagree")));
        }
        // local header: skip its (possibly different) name/extra lengths
        if bytes.len() < lho + 30 || bytes[lho..lho + 4] != [0x50, 0x4b, 0x03, 0x04] {
            return Err(err(format!("entry {name:?}: bad local header")));
        }
        let lnlen = rd_u16(bytes, lho + 26) as usize;
        let lxlen = rd_u16(bytes, lho + 28) as usize;
        let start = lho + 30 + lnlen + lxlen;
        if bytes.len() < start + csize {
            return Err(err(format!("entry {name:?}: truncated data")));
        }
        out.insert(name, bytes[start..start + csize].to_vec());
        cd += 46 + nlen + xlen + clen;
    }
    Ok(out)
}

/// Parse one NPY v1.x/2.x buffer of little-endian f32, C order.
pub fn parse_npy(bytes: &[u8]) -> Result<Array, NpzError> {
    if bytes.len() < 10 || &bytes[..6] != b"\x93NUMPY" {
        return Err(err("bad npy magic"));
    }
    let major = bytes[6];
    let (hlen, hstart) = match major {
        1 => (rd_u16(bytes, 8) as usize, 10),
        2 | 3 => (rd_u32(bytes, 8) as usize, 12),
        v => return Err(err(format!("unsupported npy version {v}"))),
    };
    let header = std::str::from_utf8(&bytes[hstart..hstart + hlen])
        .map_err(|_| err("non-utf8 npy header"))?;
    if !header.contains("'descr': '<f4'") && !header.contains("'descr': \"<f4\"") {
        return Err(err(format!("only <f4 supported, header: {}", header.trim())));
    }
    if header.contains("'fortran_order': True") {
        return Err(err("fortran order not supported"));
    }
    // shape tuple: "'shape': (a, b, c)," — also handles "()" (scalar) and
    // trailing comma in 1-tuples
    let shape_src = header
        .split("'shape':")
        .nth(1)
        .and_then(|s| s.split('(').nth(1))
        .and_then(|s| s.split(')').next())
        .ok_or_else(|| err("no shape in npy header"))?;
    let shape: Vec<usize> = shape_src
        .split(',')
        .map(str::trim)
        .filter(|t| !t.is_empty())
        .map(|t| t.parse().map_err(|_| err(format!("bad dim {t:?}"))))
        .collect::<Result<_, _>>()?;
    let count: usize = shape.iter().product();
    let dstart = hstart + hlen;
    if bytes.len() < dstart + 4 * count {
        return Err(err(format!(
            "npy payload truncated: want {} f32, have {} bytes",
            count,
            bytes.len() - dstart
        )));
    }
    let data = bytes[dstart..dstart + 4 * count]
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect();
    Ok(Array { shape, data })
}

/// Load a full `.npz` parameter archive: `entry name (sans .npy) -> Array`.
pub fn load_npz(path: impl AsRef<Path>) -> Result<BTreeMap<String, Array>, NpzError> {
    let bytes = std::fs::read(path.as_ref())
        .map_err(|e| err(format!("reading {}: {e}", path.as_ref().display())))?;
    let entries = unzip_stored(&bytes)?;
    let mut out = BTreeMap::new();
    for (name, data) in entries {
        let key = name.strip_suffix(".npy").unwrap_or(&name).to_string();
        out.insert(key, parse_npy(&data)?);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Hand-build a stored zip with one npy member.
    fn tiny_npz(name: &str, npy: &[u8]) -> Vec<u8> {
        let mut out = Vec::new();
        let crc = 0u32; // we never verify crc
        // local header
        out.extend_from_slice(&[0x50, 0x4b, 0x03, 0x04]);
        out.extend_from_slice(&[20, 0, 0, 0, 0, 0, 0, 0, 0, 0]); // ver,flags,method,time,date
        out.extend_from_slice(&crc.to_le_bytes());
        out.extend_from_slice(&(npy.len() as u32).to_le_bytes());
        out.extend_from_slice(&(npy.len() as u32).to_le_bytes());
        out.extend_from_slice(&(name.len() as u16).to_le_bytes());
        out.extend_from_slice(&0u16.to_le_bytes());
        out.extend_from_slice(name.as_bytes());
        out.extend_from_slice(npy);
        let cd_start = out.len();
        // central directory
        out.extend_from_slice(&[0x50, 0x4b, 0x01, 0x02]);
        out.extend_from_slice(&[20, 0, 20, 0, 0, 0, 0, 0, 0, 0, 0, 0]);
        out.extend_from_slice(&crc.to_le_bytes());
        out.extend_from_slice(&(npy.len() as u32).to_le_bytes());
        out.extend_from_slice(&(npy.len() as u32).to_le_bytes());
        out.extend_from_slice(&(name.len() as u16).to_le_bytes());
        out.extend_from_slice(&[0u8; 8]); // extra,comment,disk,int attrs
        out.extend_from_slice(&0u32.to_le_bytes()); // ext attrs
        out.extend_from_slice(&0u32.to_le_bytes()); // local header offset
        out.extend_from_slice(name.as_bytes());
        let cd_len = out.len() - cd_start;
        // EOCD
        out.extend_from_slice(&[0x50, 0x4b, 0x05, 0x06]);
        out.extend_from_slice(&[0u8; 4]);
        out.extend_from_slice(&1u16.to_le_bytes());
        out.extend_from_slice(&1u16.to_le_bytes());
        out.extend_from_slice(&(cd_len as u32).to_le_bytes());
        out.extend_from_slice(&(cd_start as u32).to_le_bytes());
        out.extend_from_slice(&0u16.to_le_bytes());
        out
    }

    fn tiny_npy(shape: &str, vals: &[f32]) -> Vec<u8> {
        let header = format!(
            "{{'descr': '<f4', 'fortran_order': False, 'shape': {shape}, }}"
        );
        let mut h = header.into_bytes();
        while (10 + h.len()) % 64 != 0 {
            h.push(b' ');
        }
        let mut out = b"\x93NUMPY\x01\x00".to_vec();
        out.extend_from_slice(&(h.len() as u16).to_le_bytes());
        out.extend_from_slice(&h);
        for v in vals {
            out.extend_from_slice(&v.to_le_bytes());
        }
        out
    }

    #[test]
    fn roundtrip_tiny_archive() {
        let npy = tiny_npy("(2, 3)", &[1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let zip = tiny_npz("w.npy", &npy);
        let arrs = {
            let entries = unzip_stored(&zip).unwrap();
            let mut m = BTreeMap::new();
            for (n, d) in entries {
                m.insert(n, parse_npy(&d).unwrap());
            }
            m
        };
        let a = &arrs["w.npy"];
        assert_eq!(a.shape, vec![2, 3]);
        assert_eq!(a.data, vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
    }

    #[test]
    fn scalar_and_1d_shapes() {
        let a = parse_npy(&tiny_npy("()", &[7.5])).unwrap();
        assert!(a.shape.is_empty());
        assert_eq!(a.data, vec![7.5]);
        let b = parse_npy(&tiny_npy("(3,)", &[1.0, 2.0, 3.0])).unwrap();
        assert_eq!(b.shape, vec![3]);
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse_npy(b"not an npy").is_err());
        assert!(unzip_stored(b"definitely not a zip archive, far too short to have an EOCD record anywhere inside it").is_err());
        // truncated payload
        let mut npy = tiny_npy("(4,)", &[1.0, 2.0]);
        npy.truncate(npy.len());
        assert!(parse_npy(&npy).is_err());
    }

    #[test]
    fn real_artifacts_load_if_present() {
        let path = crate::runtime::Manifest::default_dir().join("params/mnist_mlp_1.npz");
        if !path.exists() {
            eprintln!("SKIP: {} missing", path.display());
            return;
        }
        let arrs = load_npz(&path).unwrap();
        // L02 = bc_dense 256->256 k=128: w (2, 2, 128), b (256,)
        let w = &arrs["L02_w"];
        assert_eq!(w.shape, vec![2, 2, 128]);
        assert_eq!(arrs["L02_b"].shape, vec![256]);
        assert!(w.data.iter().all(|v| v.is_finite()));
    }
}
