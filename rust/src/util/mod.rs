//! Utility substrates: JSON parsing, the shared PRNG, property-test and
//! benchmark harness kits.
//!
//! These exist because the offline environment pins the dependency set to
//! the `xla` crate's closure — no `serde_json`, `proptest` or `criterion` —
//! so the substrates the rest of the crate needs are built from scratch
//! here (per the reproduction brief: build every substrate you depend on).

pub mod benchkit;
pub mod json;
pub mod npz;
pub mod prop;
pub mod rng;

/// Row-wise argmax over a `(batch, classes)` logit buffer.  Lives here (not
/// in the PJRT engine) because every execution substrate — native, PJRT,
/// coordinator — shares it, and only the PJRT one is feature-gated.
pub fn argmax_rows(logits: &[f32], classes: usize) -> Vec<u32> {
    logits
        .chunks(classes)
        .map(|row| {
            let mut best = 0usize;
            for (i, &v) in row.iter().enumerate() {
                if v > row[best] {
                    best = i;
                }
            }
            best as u32
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn argmax_basic() {
        let logits = [0.1, 0.9, 0.0, 1.0, 0.2, 0.3];
        assert_eq!(argmax_rows(&logits, 3), vec![1, 0]);
    }

    #[test]
    fn argmax_ties_take_first() {
        assert_eq!(argmax_rows(&[0.5, 0.5], 2), vec![0]);
    }
}
