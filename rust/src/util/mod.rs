//! Utility substrates: JSON parsing, the shared PRNG, property-test and
//! benchmark harness kits.
//!
//! These exist because the offline environment pins the dependency set to
//! the `xla` crate's closure — no `serde_json`, `proptest` or `criterion` —
//! so the substrates the rest of the crate needs are built from scratch
//! here (per the reproduction brief: build every substrate you depend on).

pub mod benchkit;
pub mod json;
pub mod npz;
pub mod prop;
pub mod rng;
