//! splitmix64 PRNG — the *same* generator as `python/compile/data.py`.
//!
//! The Python side generates all synthetic data with closed-form per-element
//! splitmix64 states; this module reproduces every value bit-for-bit (same
//! u64 arithmetic, same top-24-bit→f32 mapping, same element order).  The
//! cross-language contract is pinned by checksums in the artifact manifest
//! and checked by `rust/tests/integration.rs`.

/// The splitmix64 additive constant (golden-ratio increment).
pub const GAMMA: u64 = 0x9E37_79B9_7F4A_7C15;

/// splitmix64 finalizer.
#[inline]
pub fn mix(mut z: u64) -> u64 {
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Hash a tuple of small integers into a stream seed (order-sensitive);
/// mirrors `data.combine`.
pub fn combine(vals: &[u64]) -> u64 {
    let mut h: u64 = 0x243F_6A88_85A3_08D3;
    for &v in vals {
        h = mix(h ^ v.wrapping_add(GAMMA));
    }
    h
}

/// Element `i` (0-based) of the u01 stream for `seed`; mirrors
/// `data.u01_stream`.  The 24-bit mantissa path is exact in f32, so the
/// Python and Rust values are identical bits.
#[inline]
pub fn u01_at(seed: u64, i: u64) -> f32 {
    let state = seed.wrapping_add(GAMMA.wrapping_mul(i + 1));
    ((mix(state) >> 40) as f32) / 16_777_216.0
}

/// Generate `n` u01 values for `seed` (the whole stream).
pub fn u01_stream(seed: u64, n: usize) -> Vec<f32> {
    (0..n as u64).map(|i| u01_at(seed, i)).collect()
}

/// A convenient sequential PRNG over the same core, for property tests and
/// workload generators (NOT used for dataset generation, which must stay
/// closed-form to match Python).
#[derive(Debug, Clone)]
pub struct SplitMix {
    state: u64,
}

impl SplitMix {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(GAMMA);
        mix(self.state)
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Uniform f32 in [0, 1).
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        ((self.next_u64() >> 40) as f32) / 16_777_216.0
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        // multiply-shift bounded sampling; bias is negligible for test use
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.next_f64().max(1e-12);
        let u2 = self.next_f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Vector of standard-normal f32s.
    pub fn normal_vec(&mut self, n: usize) -> Vec<f32> {
        (0..n).map(|_| self.normal() as f32).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mix_reference_values() {
        // Cross-checked against the Python implementation (test_data.py uses
        // the same hand-rolled big-int reference).
        let z = mix(1234567u64.wrapping_add(GAMMA));
        let py = {
            let m = (1u128 << 64) - 1;
            let mut zz: u128 = (1234567u128 + 0x9E37_79B9_7F4A_7C15u128) & m;
            zz = ((zz ^ (zz >> 30)) * 0xBF58_476D_1CE4_E5B9) & m;
            zz = ((zz ^ (zz >> 27)) * 0x94D0_49BB_1331_11EB) & m;
            ((zz ^ (zz >> 31)) & m) as u64
        };
        assert_eq!(z, py);
    }

    #[test]
    fn u01_in_range_and_deterministic() {
        let v1 = u01_stream(42, 1000);
        let v2 = u01_stream(42, 1000);
        assert_eq!(v1, v2);
        assert!(v1.iter().all(|&x| (0.0..1.0).contains(&x)));
        let mean: f32 = v1.iter().sum::<f32>() / 1000.0;
        assert!((mean - 0.5).abs() < 0.05, "mean {mean}");
    }

    #[test]
    fn stream_prefix_consistency() {
        let a = u01_stream(7, 10);
        let b = u01_stream(7, 100);
        assert_eq!(a[..], b[..10]);
    }

    #[test]
    fn combine_is_order_sensitive() {
        assert_ne!(combine(&[1, 2]), combine(&[2, 1]));
        assert_ne!(combine(&[1]), combine(&[1, 0]));
    }

    #[test]
    fn sequential_distinct_from_closed_form_contract() {
        // sequential SplitMix must agree with the closed form (same core)
        let mut r = SplitMix::new(99);
        for i in 0..5u64 {
            let direct = mix(99u64.wrapping_add(GAMMA.wrapping_mul(i + 1)));
            assert_eq!(r.next_u64(), direct);
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = SplitMix::new(1);
        let n = 20_000;
        let samples: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = samples.iter().sum::<f64>() / n as f64;
        let var = samples.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.03, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn below_respects_bound() {
        let mut r = SplitMix::new(3);
        for _ in 0..1000 {
            assert!(r.below(17) < 17);
        }
    }
}
