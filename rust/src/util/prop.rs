//! Mini property-testing harness (the offline environment has no proptest).
//!
//! Deterministic, seed-reported, shrinking-free: each property runs `cases`
//! random inputs drawn through a [`SplitMix`](super::rng::SplitMix) PRNG; on
//! failure the panic message carries the case index and seed so the exact
//! input can be replayed by construction.

use super::rng::SplitMix;

/// Configuration for a property run.
#[derive(Debug, Clone, Copy)]
pub struct Config {
    pub cases: usize,
    pub seed: u64,
}

impl Default for Config {
    fn default() -> Self {
        // CIRCNN_PROP_CASES / CIRCNN_PROP_SEED override for deeper sweeps,
        // read through the central knob registry in `circulant::sched`
        Self {
            cases: crate::circulant::sched::env_parse("CIRCNN_PROP_CASES", 64),
            seed: crate::circulant::sched::env_parse("CIRCNN_PROP_SEED", 0xC1CC_0DE5),
        }
    }
}

/// Run `prop` on `cases` inputs drawn by `gen`.  Panics (test failure) with
/// the case number, seed and the property's message on the first violation.
pub fn forall<T: std::fmt::Debug>(
    name: &str,
    gen: impl Fn(&mut SplitMix) -> T,
    prop: impl Fn(&T) -> Result<(), String>,
) {
    let cfg = Config::default();
    for case in 0..cfg.cases {
        let mut rng = SplitMix::new(cfg.seed.wrapping_add(case as u64));
        let input = gen(&mut rng);
        if let Err(msg) = prop(&input) {
            panic!(
                "property {name} failed on case {case}/{} (seed {}): {msg}\ninput: {input:?}",
                cfg.cases, cfg.seed
            );
        }
    }
}

/// Convenience: approximate float comparison for property bodies.
pub fn close(a: f32, b: f32, rtol: f32, atol: f32) -> bool {
    (a - b).abs() <= atol + rtol * b.abs().max(a.abs())
}

/// Compare slices with tolerance; returns a useful message on mismatch.
pub fn assert_all_close(a: &[f32], b: &[f32], rtol: f32, atol: f32) -> Result<(), String> {
    if a.len() != b.len() {
        return Err(format!("length mismatch {} vs {}", a.len(), b.len()));
    }
    for (i, (&x, &y)) in a.iter().zip(b.iter()).enumerate() {
        if !close(x, y, rtol, atol) {
            return Err(format!("index {i}: {x} vs {y} (|d|={})", (x - y).abs()));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forall_passes_trivial_property() {
        forall("u01 in range", |r| r.next_f32(), |&x| {
            if (0.0..1.0).contains(&x) {
                Ok(())
            } else {
                Err(format!("{x} out of range"))
            }
        });
    }

    #[test]
    #[should_panic(expected = "property always-fails failed")]
    fn forall_reports_failures() {
        forall("always-fails", |r| r.next_u64(), |_| Err("nope".into()));
    }

    #[test]
    fn close_tolerances() {
        assert!(close(1.0, 1.0 + 1e-7, 1e-5, 0.0));
        assert!(!close(1.0, 1.1, 1e-5, 1e-5));
        assert!(close(0.0, 1e-9, 0.0, 1e-8));
    }

    #[test]
    fn assert_all_close_messages() {
        assert!(assert_all_close(&[1.0], &[1.0, 2.0], 0.0, 0.0).is_err());
        let e = assert_all_close(&[1.0, 2.0], &[1.0, 3.0], 1e-5, 1e-5).unwrap_err();
        assert!(e.contains("index 1"));
    }
}
