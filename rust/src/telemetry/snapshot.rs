//! Time-series snapshots of the serving plane: a background sampler
//! captures queue depth, in-flight count, per-stage busy permille, and
//! open-connection gauges every `CIRCNN_SNAP_MS` into a bounded ring,
//! tracking the **high watermark** of each series in `*_watermark`
//! gauges.
//!
//! Averaged metrics hide transient saturation: a queue that spikes to its
//! cap for 50ms and drains again leaves no trace in a per-run mean, but
//! it is exactly the signal the paper's deep-pipelining story depends on
//! (sustained occupancy, not one-shot benchmarks).  The ring keeps the
//! last [`SnapshotRing::cap`] samples for `/metrics.json` consumers and
//! the ASCII sparkline in the `circnn serve` status output; the watermark
//! gauges survive ring wrap-around, so "how bad did it ever get" is
//! always one scrape away.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use super::registry::{Counter, Gauge, Registry};

/// Default ring capacity: at the default 100ms period this is ~25s of
/// history — enough to catch a burst, small enough to scrape cheaply.
pub const DEFAULT_SNAP_CAP: usize = 256;

/// Default sampling period when `CIRCNN_SNAP_MS` is unset.
pub const DEFAULT_SNAP_MS: u64 = 100;

/// One sampled observation of the serving plane.  `at_ms` is milliseconds
/// since the ring was created (plain integers — deterministic to
/// serialize, trivial to diff).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct SnapSample {
    pub at_ms: u64,
    /// requests queued in the dynamic batcher(s), summed across models
    pub queue_depth: u64,
    /// requests admitted but not yet answered
    pub inflight: u64,
    /// open TCP connections (`net_connections_open`)
    pub net_open: u64,
    /// busiest pipeline stage, integer thousandths (0 on the serial engine)
    pub stage_busy_permille: u64,
}

/// The bounded time-series ring plus its watermark gauges.  Pushing is a
/// short lock; scraping clones the window.  All five snapshot metrics are
/// registered here and nowhere else (the `metric-name` single-site rule).
pub struct SnapshotRing {
    cap: usize,
    period_ms: u64,
    epoch: Instant,
    inner: Mutex<VecDeque<SnapSample>>,
    samples_total: Counter,
    wm_queue_depth: Gauge,
    wm_inflight: Gauge,
    wm_net_open: Gauge,
    wm_stage_busy: Gauge,
}

impl SnapshotRing {
    pub fn new(reg: &Registry, cap: usize, period_ms: u64) -> Arc<Self> {
        Arc::new(SnapshotRing {
            cap: cap.max(1),
            period_ms: period_ms.max(1),
            epoch: Instant::now(),
            inner: Mutex::new(VecDeque::new()),
            samples_total: reg.counter("snap_samples_total"),
            wm_queue_depth: reg.gauge("queue_depth_watermark"),
            wm_inflight: reg.gauge("inflight_requests_watermark"),
            wm_net_open: reg.gauge("net_connections_open_watermark"),
            wm_stage_busy: reg.gauge("stage_busy_permille_watermark"),
        })
    }

    /// Ring capacity (samples retained).
    pub fn cap(&self) -> usize {
        self.cap
    }

    /// Configured sampling period in ms (the `CIRCNN_SNAP_MS` value).
    pub fn period_ms(&self) -> u64 {
        self.period_ms
    }

    /// ms since the ring was created — the `at_ms` stamp for a sample
    /// taken now.
    pub fn now_ms(&self) -> u64 {
        self.epoch.elapsed().as_millis() as u64
    }

    /// Append one sample: evicts the oldest at capacity and raises the
    /// watermark gauges (watermarks are process-lifetime maxima — they
    /// never decay with the ring).
    pub fn push(&self, sample: SnapSample) {
        raise(&self.wm_queue_depth, sample.queue_depth);
        raise(&self.wm_inflight, sample.inflight);
        raise(&self.wm_net_open, sample.net_open);
        raise(&self.wm_stage_busy, sample.stage_busy_permille);
        let mut inner = self.inner.lock().unwrap_or_else(|e| e.into_inner());
        if inner.len() >= self.cap {
            inner.pop_front();
        }
        inner.push_back(sample);
        self.samples_total.inc();
    }

    /// Snapshot of the retained window, oldest first.
    pub fn samples(&self) -> Vec<SnapSample> {
        let inner = self.inner.lock().unwrap_or_else(|e| e.into_inner());
        inner.iter().copied().collect()
    }

    /// JSON for the `/metrics.json` `"snapshots"` key:
    /// `{"period_ms":…,"cap":…,"samples":[{"at_ms":…,"queue_depth":…,
    /// "inflight":…,"net_open":…,"stage_busy_permille":…},…]}` —
    /// integers only, parseable by [`crate::util::json`].
    pub fn render_json(&self) -> String {
        let rows: Vec<String> = self
            .samples()
            .iter()
            .map(|s| {
                format!(
                    "{{\"at_ms\":{},\"queue_depth\":{},\"inflight\":{},\"net_open\":{},\
                     \"stage_busy_permille\":{}}}",
                    s.at_ms, s.queue_depth, s.inflight, s.net_open, s.stage_busy_permille
                )
            })
            .collect();
        format!(
            "{{\"period_ms\":{},\"cap\":{},\"samples\":[{}]}}",
            self.period_ms,
            self.cap,
            rows.join(",")
        )
    }

    /// Multi-line ASCII status block: one sparkline per series over the
    /// retained window, annotated with the watermark (printed by
    /// `circnn serve` at shutdown).
    pub fn render_status(&self, width: usize) -> String {
        let samples = self.samples();
        if samples.is_empty() {
            return "(no snapshots — sampler never ticked)\n".to_string();
        }
        let span_ms = samples.last().map(|s| s.at_ms).unwrap_or(0)
            - samples.first().map(|s| s.at_ms).unwrap_or(0);
        let mut out = format!(
            "== snapshot ring ({} samples, {}ms window, period {}ms) ==\n",
            samples.len(),
            span_ms,
            self.period_ms
        );
        let series: [(&str, Vec<u64>, u64); 4] = [
            (
                "queue_depth",
                samples.iter().map(|s| s.queue_depth).collect(),
                self.wm_queue_depth.get(),
            ),
            ("inflight", samples.iter().map(|s| s.inflight).collect(), self.wm_inflight.get()),
            ("net_open", samples.iter().map(|s| s.net_open).collect(), self.wm_net_open.get()),
            (
                "stage_busy_pm",
                samples.iter().map(|s| s.stage_busy_permille).collect(),
                self.wm_stage_busy.get(),
            ),
        ];
        for (name, vals, watermark) in series {
            out.push_str(&format!(
                "{:>14} [wm {:>6}] |{}|\n",
                name,
                watermark,
                sparkline(&vals, width)
            ));
        }
        out
    }
}

/// Raise `gauge` to `v` if `v` is higher (last-write-wins is fine: the
/// sampler is the only writer).
fn raise(gauge: &Gauge, v: u64) {
    if v > gauge.get() {
        gauge.set(v);
    }
}

/// ASCII sparkline: downsample `vals` to `width` columns (bucket max, so
/// a one-sample spike survives downsampling) and paint each column on a
/// 9-level ramp scaled to the series max.
pub fn sparkline(vals: &[u64], width: usize) -> String {
    const RAMP: [char; 9] = [' ', '.', ':', '-', '=', '+', '*', '#', '@'];
    let width = width.max(8);
    if vals.is_empty() {
        return " ".repeat(width);
    }
    let cols = width.min(vals.len());
    let mut maxes = vec![0u64; cols];
    for (i, &v) in vals.iter().enumerate() {
        let col = i * cols / vals.len();
        if v > maxes[col] {
            maxes[col] = v;
        }
    }
    let peak = maxes.iter().copied().max().unwrap_or(0).max(1);
    maxes
        .iter()
        .map(|&v| {
            if v == 0 {
                RAMP[0]
            } else {
                // non-zero paints at least level 1; the column holding the
                // series max always paints the top ramp level
                let lvl = 1 + (v as u128 * (RAMP.len() - 2) as u128 / peak as u128) as usize;
                RAMP[lvl.min(RAMP.len() - 1)]
            }
        })
        .collect()
}

/// The background snapshot ticker: every `period` it runs `probe` and
/// pushes the stamped sample into `ring`.  Stop with [`Sampler::stop`]
/// (also run on drop); the thread wakes every few ms so shutdown never
/// waits a full period.
pub struct Sampler {
    stop: Arc<AtomicBool>,
    handle: Option<JoinHandle<()>>,
}

impl Sampler {
    pub fn start(
        ring: Arc<SnapshotRing>,
        probe: Box<dyn Fn() -> SnapSample + Send>,
        period: Duration,
    ) -> Sampler {
        let stop = Arc::new(AtomicBool::new(false));
        let stop_flag = Arc::clone(&stop);
        let period = period.max(Duration::from_millis(1));
        let handle = std::thread::Builder::new()
            .name("circnn-snap".into())
            .spawn(move || {
                let mut next = Instant::now() + period;
                loop {
                    while Instant::now() < next {
                        if stop_flag.load(Ordering::Relaxed) {
                            return;
                        }
                        std::thread::sleep(Duration::from_millis(2).min(period));
                    }
                    next += period;
                    let mut sample = probe();
                    sample.at_ms = ring.now_ms();
                    ring.push(sample);
                }
            })
            .ok();
        Sampler { stop, handle }
    }

    /// Signal the ticker and join it.
    pub fn stop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for Sampler {
    fn drop(&mut self) {
        self.stop();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::Json;

    #[test]
    fn ring_is_bounded_and_watermarks_survive_eviction() {
        let reg = Registry::new();
        let ring = SnapshotRing::new(&reg, 4, 10);
        for i in 0..10u64 {
            // the peak (depth 100) lands mid-run and is evicted by the end
            let depth = if i == 3 { 100 } else { i };
            ring.push(SnapSample {
                at_ms: i * 10,
                queue_depth: depth,
                inflight: i * 2,
                net_open: 1,
                stage_busy_permille: 500 + i,
            });
        }
        let samples = ring.samples();
        assert_eq!(samples.len(), 4, "ring holds exactly `cap` samples");
        assert_eq!(samples[0].at_ms, 60, "oldest samples were evicted");
        // the evicted spike still shows in the watermark gauge
        assert_eq!(reg.gauge("queue_depth_watermark").get(), 100);
        assert_eq!(reg.gauge("inflight_requests_watermark").get(), 18);
        assert_eq!(reg.gauge("net_connections_open_watermark").get(), 1);
        assert_eq!(reg.gauge("stage_busy_permille_watermark").get(), 509);
        assert_eq!(reg.counter("snap_samples_total").get(), 10);
    }

    #[test]
    fn snapshot_json_parses_with_integer_series() {
        let reg = Registry::new();
        let ring = SnapshotRing::new(&reg, 8, 50);
        ring.push(SnapSample {
            at_ms: 1,
            queue_depth: 2,
            inflight: 3,
            net_open: 4,
            stage_busy_permille: 5,
        });
        let doc = Json::parse(&ring.render_json()).expect("snapshot json parses");
        assert_eq!(doc.get("period_ms").and_then(Json::as_u64), Some(50));
        assert_eq!(doc.get("cap").and_then(Json::as_u64), Some(8));
        let rows = doc.get("samples").and_then(Json::as_arr).expect("samples");
        assert_eq!(rows.len(), 1);
        assert_eq!(rows[0].get("queue_depth").and_then(Json::as_u64), Some(2));
        assert_eq!(rows[0].get("stage_busy_permille").and_then(Json::as_u64), Some(5));
    }

    #[test]
    fn sparkline_preserves_spikes_and_scales() {
        // bucket-max downsampling: a single spike in 256 samples must
        // survive a 16-column render
        let mut vals = vec![1u64; 256];
        vals[100] = 1000;
        let line = sparkline(&vals, 16);
        assert_eq!(line.chars().count(), 16);
        assert!(line.contains('@'), "spike must paint the top ramp level: {line}");
        assert!(line.contains('.'), "baseline must stay visible: {line}");
        assert!(!sparkline(&[0, 0, 0], 8).contains('@'), "all-zero paints blank");
        assert_eq!(sparkline(&[], 8), "        ");
    }

    #[test]
    fn sampler_ticks_and_stops() {
        let reg = Registry::new();
        let ring = SnapshotRing::new(&reg, 32, 2);
        let mut sampler = Sampler::start(
            Arc::clone(&ring),
            Box::new(|| SnapSample { queue_depth: 7, ..SnapSample::default() }),
            Duration::from_millis(2),
        );
        let deadline = Instant::now() + Duration::from_secs(5);
        while ring.samples().len() < 3 && Instant::now() < deadline {
            std::thread::sleep(Duration::from_millis(2));
        }
        sampler.stop();
        let n = ring.samples().len();
        assert!(n >= 3, "sampler must have ticked: {n} samples");
        assert_eq!(reg.gauge("queue_depth_watermark").get(), 7);
        // stopped: no further ticks
        std::thread::sleep(Duration::from_millis(10));
        assert_eq!(ring.samples().len(), n, "no ticks after stop");
    }
}
