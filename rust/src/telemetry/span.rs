//! Per-request span tracing: a span ID minted at server admission,
//! stamped at batch release and reply scatter, collected into a bounded
//! ring buffer of completed [`SpanRecord`]s.
//!
//! The tracer records *offsets in microseconds from its own epoch* (the
//! `Instant` it was created at), so records are plain integers — cheap to
//! store, deterministic to serialize ([`spans_to_json`]) and trivial to
//! join against `pipeline::PipelineStats` stage events (the server
//! converts the stats' epoch into tracer offsets and appends one segment
//! per stage hop before rendering).  See the module docs of
//! [`crate::telemetry`] for the span lifecycle diagram.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use super::registry::{Counter, Registry};

/// Completed spans kept for rendering/dumping; oldest dropped first.
const SPAN_RING_CAP: usize = 4096;

/// One labelled wall-clock segment of a span, offsets in µs from the
/// tracer epoch.
#[derive(Debug, Clone)]
pub struct Seg {
    pub label: String,
    pub start_us: u64,
    pub end_us: u64,
}

/// One completed request span.
#[derive(Debug, Clone)]
pub struct SpanRecord {
    pub id: u64,
    pub model: String,
    /// pipeline batch sequence number (None on the serial executor) — the
    /// join key against `PipelineStats` stage events
    pub seq: Option<u64>,
    pub segs: Vec<Seg>,
}

impl SpanRecord {
    pub fn start_us(&self) -> u64 {
        self.segs.iter().map(|s| s.start_us).min().unwrap_or(0)
    }

    pub fn end_us(&self) -> u64 {
        self.segs.iter().map(|s| s.end_us).max().unwrap_or(0)
    }

    fn seg(&self, label: &str) -> Option<&Seg> {
        self.segs.iter().find(|s| s.label == label)
    }
}

struct PendingSpan {
    id: u64,
    model: String,
    admitted_us: u64,
    released_us: Option<u64>,
    seq: Option<u64>,
}

struct Inner {
    pending: Vec<PendingSpan>, // id-sorted (ids are minted monotonically)
    done: VecDeque<SpanRecord>,
}

/// The span tracer.  All methods are cheap and lock only a small state
/// mutex; when tracing is disabled the server holds no tracer at all, so
/// the disabled-path overhead is exactly zero.
pub struct Tracer {
    epoch: Instant,
    next_id: AtomicU64,
    inner: Mutex<Inner>,
    spans_total: Counter,
    spans_dropped: Counter,
}

impl Tracer {
    pub fn new(reg: &Registry) -> Arc<Self> {
        Arc::new(Tracer {
            epoch: Instant::now(),
            next_id: AtomicU64::new(1),
            inner: Mutex::new(Inner { pending: Vec::new(), done: VecDeque::new() }),
            spans_total: reg.counter("trace_spans_total"),
            spans_dropped: reg.counter("trace_spans_dropped_total"),
        })
    }

    pub fn epoch(&self) -> Instant {
        self.epoch
    }

    /// µs offset of `at` from the tracer epoch (0 for pre-epoch instants).
    pub fn offset_us(&self, at: Instant) -> u64 {
        at.checked_duration_since(self.epoch).map(|d| d.as_micros() as u64).unwrap_or(0)
    }

    /// Mint a span for a request admitted at `at`; returns its ID (> 0).
    pub fn admitted(&self, model: &str, at: Instant) -> u64 {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let admitted_us = self.offset_us(at);
        let mut inner = self.inner.lock().unwrap_or_else(|e| e.into_inner());
        inner.pending.push(PendingSpan {
            id,
            model: model.to_string(),
            admitted_us,
            released_us: None,
            seq: None,
        });
        self.spans_total.inc();
        id
    }

    /// The request's batch was released from the queue at `at` (with the
    /// pipeline sequence number when the pipelined engine runs it).
    pub fn released(&self, id: u64, at: Instant, seq: Option<u64>) {
        let released_us = self.offset_us(at);
        let mut inner = self.inner.lock().unwrap_or_else(|e| e.into_inner());
        if let Ok(i) = inner.pending.binary_search_by_key(&id, |p| p.id) {
            inner.pending[i].released_us = Some(released_us);
            inner.pending[i].seq = seq;
        }
    }

    /// The reply was scattered at `at`: the span completes into the ring.
    pub fn finished(&self, id: u64, at: Instant) {
        let done_us = self.offset_us(at);
        let mut inner = self.inner.lock().unwrap_or_else(|e| e.into_inner());
        let Ok(i) = inner.pending.binary_search_by_key(&id, |p| p.id) else { return };
        let p = inner.pending.remove(i);
        let released = p.released_us.unwrap_or(done_us);
        let record = SpanRecord {
            id: p.id,
            model: p.model,
            seq: p.seq,
            segs: vec![
                Seg { label: "queue".into(), start_us: p.admitted_us, end_us: released },
                Seg { label: "exec".into(), start_us: released, end_us: done_us },
            ],
        };
        if inner.done.len() >= SPAN_RING_CAP {
            inner.done.pop_front();
            self.spans_dropped.inc();
        }
        inner.done.push_back(record);
    }

    /// Drop a span that will never complete (admission rejected after
    /// minting).
    pub fn abandon(&self, id: u64) {
        let mut inner = self.inner.lock().unwrap_or_else(|e| e.into_inner());
        if let Ok(i) = inner.pending.binary_search_by_key(&id, |p| p.id) {
            inner.pending.remove(i);
        }
    }

    /// Snapshot of the completed-span ring, oldest first.
    pub fn spans(&self) -> Vec<SpanRecord> {
        let inner = self.inner.lock().unwrap_or_else(|e| e.into_inner());
        inner.done.iter().cloned().collect()
    }

    /// Spans dropped at the ring cap so far (`trace_spans_dropped_total`).
    /// Non-zero means every snapshot from [`Tracer::spans`] is a *window*,
    /// not the full history — renderers must say so (see
    /// [`render_waterfall`] / [`trace_document`]).
    pub fn dropped_count(&self) -> u64 {
        self.spans_dropped.get()
    }
}

/// Paint character for a segment: `queue` → `q`, `exec` → `x`, stage hops
/// (`s0`, `s1`, …) → their stage digit.
fn paint(label: &str) -> char {
    match label {
        "queue" => 'q',
        "exec" => 'x',
        other => other.chars().last().unwrap_or('?'),
    }
}

/// ASCII waterfall over completed spans: one row per request, segments
/// painted over a shared time axis (the per-request analogue of
/// [`crate::pipeline::timeline::render`]).
///
/// `dropped` is the tracer's ring-drop count
/// ([`Tracer::dropped_count`]): when non-zero the waterfall leads with an
/// explicit `truncated: N` banner, so a partial window is never presented
/// as the complete history.
pub fn render_waterfall(spans: &[SpanRecord], width: usize, dropped: u64) -> String {
    let width = width.max(8);
    let banner = if dropped > 0 {
        format!(
            "!! truncated: {dropped} older span(s) dropped at the ring cap \
             (trace_spans_dropped_total) !!\n"
        )
    } else {
        String::new()
    };
    if spans.is_empty() {
        return format!("{banner}(no completed spans — run with --trace / CIRCNN_TRACE=1)\n");
    }
    let t0 = spans.iter().map(SpanRecord::start_us).min().unwrap_or(0);
    let t1 = spans.iter().map(SpanRecord::end_us).max().unwrap_or(t0).max(t0 + 1);
    let per_col = ((t1 - t0) as f64 / width as f64).max(1.0);
    let mut out = format!(
        "{banner}== per-request span waterfall ({} spans, {}us, 1 col = {:.0}us) ==\n",
        spans.len(),
        t1 - t0,
        per_col
    );
    out.push_str(&format!(
        "{:>6} {:<14} {:>5} {:>9} {:>8}  timeline (q=queue x=exec digits=stage)\n",
        "id", "model", "seq", "queue_us", "exec_us"
    ));
    for span in spans {
        let mut row = vec!['.'; width];
        for seg in &span.segs {
            let a = (seg.start_us.saturating_sub(t0) as f64 / per_col) as usize;
            let end = seg.end_us.max(seg.start_us + 1);
            let b = (end.saturating_sub(t0) as f64 / per_col).ceil() as usize;
            let ch = paint(&seg.label);
            for cell in row.iter_mut().take(b.min(width)).skip(a.min(width)) {
                *cell = ch;
            }
        }
        let queue_us = span.seg("queue").map(|s| s.end_us - s.start_us).unwrap_or(0);
        let exec_us = span.seg("exec").map(|s| s.end_us - s.start_us).unwrap_or(0);
        let seq = span.seq.map(|s| s.to_string()).unwrap_or_else(|| "-".into());
        out.push_str(&format!(
            "{:>6} {:<14} {:>5} {:>9} {:>8}  |{}|\n",
            span.id,
            span.model,
            seq,
            queue_us,
            exec_us,
            row.into_iter().collect::<String>()
        ));
    }
    out
}

/// JSON array of spans: `[{"id":…,"model":…,"seq":…|null,"segs":[{"label":
/// …,"start_us":…,"end_us":…},…]},…]` — integers and plain strings only.
pub fn spans_to_json(spans: &[SpanRecord]) -> String {
    let rows: Vec<String> = spans
        .iter()
        .map(|s| {
            let segs: Vec<String> = s
                .segs
                .iter()
                .map(|g| {
                    format!(
                        "{{\"label\":\"{}\",\"start_us\":{},\"end_us\":{}}}",
                        g.label, g.start_us, g.end_us
                    )
                })
                .collect();
            let seq = s.seq.map(|v| v.to_string()).unwrap_or_else(|| "null".into());
            format!(
                "{{\"id\":{},\"model\":\"{}\",\"seq\":{},\"segs\":[{}]}}",
                s.id,
                s.model,
                seq,
                segs.join(",")
            )
        })
        .collect();
    format!("[{}]", rows.join(","))
}

/// The `/trace.json` document: `{"truncated":N,"spans":[…]}`.  `truncated`
/// is the ring-drop count ([`Tracer::dropped_count`]) — `0` means the
/// `spans` array is the complete history, `N > 0` means the `N` oldest
/// spans were dropped at the ring cap and only a window remains.
pub fn trace_document(spans: &[SpanRecord], dropped: u64) -> String {
    format!("{{\"truncated\":{dropped},\"spans\":{}}}", spans_to_json(spans))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::Json;
    use std::time::Duration;

    fn at(tracer: &Tracer, us: u64) -> Instant {
        tracer.epoch() + Duration::from_micros(us)
    }

    #[test]
    fn span_lifecycle_records_queue_and_exec_segments() {
        let reg = Registry::new();
        let tr = Tracer::new(&reg);
        let id = tr.admitted("mnist_mlp_1", at(&tr, 100));
        tr.released(id, at(&tr, 250), Some(7));
        tr.finished(id, at(&tr, 900));
        let spans = tr.spans();
        assert_eq!(spans.len(), 1);
        let s = &spans[0];
        assert_eq!((s.id, s.seq), (id, Some(7)));
        assert_eq!(s.model, "mnist_mlp_1");
        assert_eq!(s.segs.len(), 2);
        assert_eq!((s.segs[0].start_us, s.segs[0].end_us), (100, 250), "queue");
        assert_eq!((s.segs[1].start_us, s.segs[1].end_us), (250, 900), "exec");
        assert_eq!(reg.counter("trace_spans_total").get(), 1);
    }

    #[test]
    fn abandoned_spans_never_complete() {
        let reg = Registry::new();
        let tr = Tracer::new(&reg);
        let id = tr.admitted("m", at(&tr, 1));
        tr.abandon(id);
        tr.finished(id, at(&tr, 2)); // must be a no-op
        assert!(tr.spans().is_empty());
    }

    #[test]
    fn ring_is_bounded_and_counts_drops() {
        let reg = Registry::new();
        let tr = Tracer::new(&reg);
        let n = SPAN_RING_CAP + 10;
        for i in 0..n {
            let id = tr.admitted("m", at(&tr, i as u64));
            tr.finished(id, at(&tr, i as u64 + 1));
        }
        let spans = tr.spans();
        assert_eq!(spans.len(), SPAN_RING_CAP);
        assert_eq!(reg.counter("trace_spans_dropped_total").get(), 10);
        assert_eq!(tr.dropped_count(), 10);
        // oldest were dropped: the first surviving span is id 11
        assert_eq!(spans[0].id, 11);
    }

    #[test]
    fn truncated_ring_is_bannered_never_silent() {
        // the regression pin: at exactly ring-capacity + 1 spans the
        // waterfall and the trace document must both announce the single
        // dropped span instead of presenting the window as complete.
        let reg = Registry::new();
        let tr = Tracer::new(&reg);
        for i in 0..(SPAN_RING_CAP + 1) {
            let id = tr.admitted("m", at(&tr, i as u64));
            tr.finished(id, at(&tr, i as u64 + 1));
        }
        assert_eq!(tr.dropped_count(), 1);
        let spans = tr.spans();
        assert_eq!(spans.len(), SPAN_RING_CAP);

        let text = render_waterfall(&spans, 32, tr.dropped_count());
        assert!(
            text.contains("truncated: 1 older span(s) dropped at the ring cap"),
            "waterfall must banner the drop: {}",
            text.lines().next().unwrap_or("")
        );

        let doc = Json::parse(&trace_document(&spans, tr.dropped_count())).expect("doc parses");
        assert_eq!(doc.get("truncated").and_then(Json::as_u64), Some(1));
        let arr = doc.get("spans").and_then(Json::as_arr).expect("spans array");
        assert_eq!(arr.len(), SPAN_RING_CAP);

        // one span under the cap: no banner, truncated: 0
        let reg2 = Registry::new();
        let tr2 = Tracer::new(&reg2);
        let id = tr2.admitted("m", at(&tr2, 1));
        tr2.finished(id, at(&tr2, 2));
        let text2 = render_waterfall(&tr2.spans(), 32, tr2.dropped_count());
        assert!(!text2.contains("truncated"), "no banner without drops: {text2}");
        let doc2 = Json::parse(&trace_document(&tr2.spans(), tr2.dropped_count())).expect("parses");
        assert_eq!(doc2.get("truncated").and_then(Json::as_u64), Some(0));
    }

    #[test]
    fn waterfall_and_json_render() {
        let reg = Registry::new();
        let tr = Tracer::new(&reg);
        for i in 0..3u64 {
            let id = tr.admitted("svhn_cnn", at(&tr, i * 10));
            tr.released(id, at(&tr, i * 10 + 40), Some(i));
            tr.finished(id, at(&tr, i * 10 + 100));
        }
        let mut spans = tr.spans();
        // a stage hop appended by the server-side join paints its digit
        spans[0].segs.push(Seg { label: "s1".into(), start_us: 50, end_us: 70 });
        let text = render_waterfall(&spans, 48, 0);
        assert!(text.contains("3 spans"), "{text}");
        assert!(text.contains('q') && text.contains('x'), "{text}");
        assert!(text.contains('1'), "stage digit missing: {text}");

        let doc = Json::parse(&spans_to_json(&spans)).expect("span json parses");
        let arr = doc.as_arr().expect("array");
        assert_eq!(arr.len(), 3);
        assert_eq!(arr[0].get("model").and_then(Json::as_str), Some("svhn_cnn"));
        assert_eq!(arr[0].get("seq").and_then(Json::as_u64), Some(0));
        let segs = arr[0].get("segs").and_then(Json::as_arr).expect("segs");
        assert_eq!(segs[0].get("label").and_then(Json::as_str), Some("queue"));
        assert_eq!(segs[1].get("end_us").and_then(Json::as_u64), Some(100));
    }

    #[test]
    fn empty_waterfall_is_a_hint_not_a_panic() {
        let text = render_waterfall(&[], 32, 0);
        assert!(text.contains("no completed spans"), "{text}");
    }
}
