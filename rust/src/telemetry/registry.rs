//! The process-wide metrics registry: atomic counters, gauges, and
//! fixed-boundary histograms with deterministic bucket edges, plus the
//! Prometheus-style text and JSON expositions.
//!
//! Handles ([`Counter`], [`Gauge`], [`Histogram`]) are cheap `Arc`-backed
//! clones: registration takes the registry lock once, the hot path is a
//! single relaxed atomic op, and re-registering the same `(name, labels)`
//! pair returns the existing handle (idempotent — a second `Metrics` or a
//! reattached trainer sees the same cell).  Metric *names* must be literal
//! `snake_case` strings (enforced by the `metric-name` lint rule); dynamic
//! dimensions ride in labels.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// A monotonically increasing counter.
#[derive(Clone, Debug)]
pub struct Counter(Arc<AtomicU64>);

impl Counter {
    pub fn inc(&self) {
        self.0.fetch_add(1, Ordering::Relaxed);
    }

    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// A last-write-wins gauge.
#[derive(Clone, Debug)]
pub struct Gauge(Arc<AtomicU64>);

impl Gauge {
    pub fn set(&self, v: u64) {
        self.0.store(v, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// A fixed-boundary histogram: `edges.len() + 1` atomic buckets (the last
/// is the overflow bucket), upper-inclusive (`v <= edge`), with a
/// saturating sum.  Edges are fixed at registration, so bucket boundaries
/// are deterministic across runs — the property the golden exposition
/// test pins.
#[derive(Clone, Debug)]
pub struct Histogram(Arc<HistCore>);

#[derive(Debug)]
struct HistCore {
    edges: Vec<u64>,
    buckets: Vec<AtomicU64>,
    sum: AtomicU64,
    count: AtomicU64,
}

impl Histogram {
    fn new(edges: &[u64]) -> Self {
        debug_assert!(edges.windows(2).all(|w| w[0] < w[1]), "edges must increase");
        debug_assert!(!edges.is_empty(), "a histogram needs at least one edge");
        let buckets = (0..=edges.len()).map(|_| AtomicU64::new(0)).collect();
        Histogram(Arc::new(HistCore {
            edges: edges.to_vec(),
            buckets,
            sum: AtomicU64::new(0),
            count: AtomicU64::new(0),
        }))
    }

    /// Record one observation.  Values beyond the last edge land in the
    /// overflow bucket; the running sum saturates instead of wrapping, so
    /// a `u64::MAX` observation cannot corrupt the mean.
    pub fn observe(&self, v: u64) {
        let c = &*self.0;
        let idx = c.edges.iter().position(|&e| v <= e).unwrap_or(c.edges.len());
        c.buckets[idx].fetch_add(1, Ordering::Relaxed);
        c.count.fetch_add(1, Ordering::Relaxed);
        let mut cur = c.sum.load(Ordering::Relaxed);
        loop {
            let next = cur.saturating_add(v);
            match c.sum.compare_exchange_weak(cur, next, Ordering::Relaxed, Ordering::Relaxed) {
                Ok(_) => break,
                Err(seen) => cur = seen,
            }
        }
    }

    pub fn count(&self) -> u64 {
        self.0.count.load(Ordering::Relaxed)
    }

    pub fn sum(&self) -> u64 {
        self.0.sum.load(Ordering::Relaxed)
    }

    pub fn edges(&self) -> &[u64] {
        &self.0.edges
    }

    /// Per-bucket counts, overflow bucket last (`edges().len() + 1` long).
    pub fn counts(&self) -> Vec<u64> {
        self.0.buckets.iter().map(|b| b.load(Ordering::Relaxed)).collect()
    }

    /// Index of the bucket holding quantile `q` (0.0..=1.0): `None` when
    /// empty; `Some(edges().len())` means the overflow bucket.
    pub fn quantile_bucket(&self, q: f64) -> Option<usize> {
        let counts = self.counts();
        let total: u64 = counts.iter().sum();
        if total == 0 {
            return None;
        }
        let target = ((q * total as f64).ceil() as u64).clamp(1, total);
        let mut seen = 0u64;
        for (i, &c) in counts.iter().enumerate() {
            seen += c;
            if seen >= target {
                return Some(i);
            }
        }
        Some(counts.len() - 1)
    }

    /// Upper edge of the quantile-`q` bucket.  Overflow saturates into the
    /// last finite edge (the `p95>edge` floor convention: the true value is
    /// at least this large); an empty histogram reports 0.
    pub fn quantile_edge(&self, q: f64) -> u64 {
        match self.quantile_bucket(q) {
            None => 0,
            Some(i) => self.0.edges[i.min(self.0.edges.len() - 1)],
        }
    }
}

/// The default duration edges: powers of two from 1µs to ~537s — the
/// "fixed-boundary log2 histogram" of the module contract.
pub fn log2_edges() -> Vec<u64> {
    (0..30).map(|i| 1u64 << i).collect()
}

enum Kind {
    Counter(Counter),
    Gauge(Gauge),
    Histogram(Histogram),
}

struct Entry {
    name: &'static str,
    labels: Vec<(String, String)>,
    kind: Kind,
}

impl Entry {
    /// `name` or `name{k="v",…}` — the exposition key.
    fn key(&self) -> String {
        if self.labels.is_empty() {
            return self.name.to_string();
        }
        let body: Vec<String> =
            self.labels.iter().map(|(k, v)| format!("{k}=\"{}\"", esc(v))).collect();
        format!("{}{{{}}}", self.name, body.join(","))
    }
}

/// The registry itself: an ordered set of named metrics behind one mutex
/// (locked only at registration and exposition — never on the hot path).
#[derive(Default)]
pub struct Registry {
    inner: Mutex<Vec<Entry>>,
}

impl std::fmt::Debug for Registry {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let n = self.inner.lock().map(|v| v.len()).unwrap_or(0);
        write!(f, "Registry({n} metrics)")
    }
}

impl Registry {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn counter(&self, name: &'static str) -> Counter {
        self.register_counter(name, &[])
    }

    pub fn counter_with(&self, name: &'static str, labels: &[(&str, String)]) -> Counter {
        self.register_counter(name, labels)
    }

    pub fn gauge(&self, name: &'static str) -> Gauge {
        self.register_gauge(name, &[])
    }

    pub fn gauge_with(&self, name: &'static str, labels: &[(&str, String)]) -> Gauge {
        self.register_gauge(name, labels)
    }

    /// A histogram over the default [`log2_edges`].
    pub fn histogram(&self, name: &'static str) -> Histogram {
        self.register_histogram(name, &[], &log2_edges())
    }

    pub fn histogram_with(&self, name: &'static str, labels: &[(&str, String)]) -> Histogram {
        self.register_histogram(name, labels, &log2_edges())
    }

    /// A histogram with explicit finite edges (strictly increasing; the
    /// overflow bucket is implicit).
    pub fn histogram_edges(&self, name: &'static str, edges: &[u64]) -> Histogram {
        self.register_histogram(name, &[], edges)
    }

    fn register_counter(&self, name: &'static str, labels: &[(&str, String)]) -> Counter {
        let mut inner = self.inner.lock().unwrap_or_else(|e| e.into_inner());
        if let Some(e) = find(&inner, name, labels) {
            if let Kind::Counter(c) = &e.kind {
                return c.clone();
            }
            debug_assert!(false, "metric `{name}` re-registered as a different kind");
        }
        let c = Counter(Arc::new(AtomicU64::new(0)));
        inner.push(Entry { name, labels: own(labels), kind: Kind::Counter(c.clone()) });
        c
    }

    fn register_gauge(&self, name: &'static str, labels: &[(&str, String)]) -> Gauge {
        let mut inner = self.inner.lock().unwrap_or_else(|e| e.into_inner());
        if let Some(e) = find(&inner, name, labels) {
            if let Kind::Gauge(g) = &e.kind {
                return g.clone();
            }
            debug_assert!(false, "metric `{name}` re-registered as a different kind");
        }
        let g = Gauge(Arc::new(AtomicU64::new(0)));
        inner.push(Entry { name, labels: own(labels), kind: Kind::Gauge(g.clone()) });
        g
    }

    fn register_histogram(
        &self,
        name: &'static str,
        labels: &[(&str, String)],
        edges: &[u64],
    ) -> Histogram {
        let mut inner = self.inner.lock().unwrap_or_else(|e| e.into_inner());
        if let Some(e) = find(&inner, name, labels) {
            if let Kind::Histogram(h) = &e.kind {
                debug_assert_eq!(h.edges(), edges, "metric `{name}` re-registered with new edges");
                return h.clone();
            }
            debug_assert!(false, "metric `{name}` re-registered as a different kind");
        }
        let h = Histogram::new(edges);
        inner.push(Entry { name, labels: own(labels), kind: Kind::Histogram(h.clone()) });
        h
    }

    /// Prometheus-style text exposition (see the module docs for a
    /// sample).  Deterministic for deterministic registration order and
    /// values — the golden test compares it byte for byte.
    pub fn render_text(&self) -> String {
        let inner = self.inner.lock().unwrap_or_else(|e| e.into_inner());
        let mut out = String::new();
        let mut typed: Vec<&str> = Vec::new();
        for e in inner.iter() {
            let kind = match &e.kind {
                Kind::Counter(_) => "counter",
                Kind::Gauge(_) => "gauge",
                Kind::Histogram(_) => "histogram",
            };
            if !typed.contains(&e.name) {
                typed.push(e.name);
                out.push_str(&format!("# TYPE {} {kind}\n", e.name));
            }
            match &e.kind {
                Kind::Counter(c) => out.push_str(&format!("{} {}\n", e.key(), c.get())),
                Kind::Gauge(g) => out.push_str(&format!("{} {}\n", e.key(), g.get())),
                Kind::Histogram(h) => {
                    let counts = h.counts();
                    let mut cum = 0u64;
                    for (i, &c) in counts.iter().enumerate() {
                        cum += c;
                        let le = match h.edges().get(i) {
                            Some(edge) => edge.to_string(),
                            None => "+Inf".to_string(),
                        };
                        out.push_str(&format!("{}_bucket{{le=\"{le}\"}} {cum}\n", e.name));
                    }
                    out.push_str(&format!("{}_sum {}\n", e.name, h.sum()));
                    out.push_str(&format!("{}_count {}\n", e.name, h.count()));
                }
            }
        }
        out
    }

    /// JSON exposition: `{"counters":{…},"gauges":{…},"histograms":{…}}`,
    /// all values integers, parseable by [`crate::util::json`].
    pub fn render_json(&self) -> String {
        let inner = self.inner.lock().unwrap_or_else(|e| e.into_inner());
        let mut counters = Vec::new();
        let mut gauges = Vec::new();
        let mut hists = Vec::new();
        for e in inner.iter() {
            match &e.kind {
                Kind::Counter(c) => counters.push(format!("\"{}\":{}", esc(&e.key()), c.get())),
                Kind::Gauge(g) => gauges.push(format!("\"{}\":{}", esc(&e.key()), g.get())),
                Kind::Histogram(h) => {
                    let edges: Vec<String> = h.edges().iter().map(u64::to_string).collect();
                    let counts: Vec<String> = h.counts().iter().map(u64::to_string).collect();
                    hists.push(format!(
                        "\"{}\":{{\"edges\":[{}],\"counts\":[{}],\"sum\":{},\"count\":{},\
                         \"p50\":{},\"p95\":{},\"p99\":{}}}",
                        esc(&e.key()),
                        edges.join(","),
                        counts.join(","),
                        h.sum(),
                        h.count(),
                        h.quantile_edge(0.50),
                        h.quantile_edge(0.95),
                        h.quantile_edge(0.99),
                    ));
                }
            }
        }
        format!(
            "{{\"counters\":{{{}}},\"gauges\":{{{}}},\"histograms\":{{{}}}}}",
            counters.join(","),
            gauges.join(","),
            hists.join(",")
        )
    }
}

fn find<'a>(entries: &'a [Entry], name: &str, labels: &[(&str, String)]) -> Option<&'a Entry> {
    entries.iter().find(|e| {
        e.name == name
            && e.labels.len() == labels.len()
            && e.labels.iter().zip(labels).all(|(a, b)| a.0 == b.0 && a.1 == b.1)
    })
}

fn own(labels: &[(&str, String)]) -> Vec<(String, String)> {
    labels.iter().map(|(k, v)| (k.to_string(), v.clone())).collect()
}

/// Minimal JSON/label string escape (backslash, quote, control chars).
fn esc(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for ch in s.chars() {
        match ch {
            '\\' => out.push_str("\\\\"),
            '"' => out.push_str("\\\""),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::Json;
    use std::sync::Arc;
    use std::thread;

    #[test]
    fn golden_text_and_json_exposition() {
        // the acceptance golden: stable names, deterministic bucket edges,
        // byte-exact text exposition
        let reg = Registry::new();
        let c = reg.counter("demo_requests_total");
        let g = reg.gauge_with("demo_stage_busy_permille", &[("stage", "0".into())]);
        let h = reg.histogram_edges("demo_wait_us", &[10, 100, 1000]);
        c.add(3);
        g.set(417);
        h.observe(0);
        h.observe(10); // exactly on an edge: upper-inclusive
        h.observe(11);
        h.observe(5000); // overflow
        let want = "\
# TYPE demo_requests_total counter
demo_requests_total 3
# TYPE demo_stage_busy_permille gauge
demo_stage_busy_permille{stage=\"0\"} 417
# TYPE demo_wait_us histogram
demo_wait_us_bucket{le=\"10\"} 2
demo_wait_us_bucket{le=\"100\"} 3
demo_wait_us_bucket{le=\"1000\"} 3
demo_wait_us_bucket{le=\"+Inf\"} 4
demo_wait_us_sum 5021
demo_wait_us_count 4
";
        assert_eq!(reg.render_text(), want);

        let doc = Json::parse(&reg.render_json()).expect("exposition parses");
        let counters = doc.get("counters").expect("counters");
        assert_eq!(counters.get("demo_requests_total").and_then(Json::as_f64), Some(3.0));
        let hist = doc.get("histograms").and_then(|h| h.get("demo_wait_us")).expect("hist");
        assert_eq!(hist.get("count").and_then(Json::as_f64), Some(4.0));
        assert_eq!(hist.get("p50").and_then(Json::as_f64), Some(10.0));
        // overflow saturates the p99 into the last finite edge
        assert_eq!(hist.get("p99").and_then(Json::as_f64), Some(1000.0));
    }

    #[test]
    fn histogram_bucket_boundaries() {
        // values exactly on an edge, zero, and u64::MAX saturation
        let reg = Registry::new();
        let h = reg.histogram_edges("edge_cases_us", &[10, 30, 100]);
        h.observe(0);
        assert_eq!(h.counts(), vec![1, 0, 0, 0], "zero lands in the first bucket");
        h.observe(10);
        h.observe(30);
        h.observe(100);
        assert_eq!(h.counts(), vec![2, 1, 1, 0], "edge values are upper-inclusive");
        h.observe(101);
        h.observe(u64::MAX);
        assert_eq!(h.counts(), vec![2, 1, 1, 2], "past-the-end lands in overflow");
        assert_eq!(h.sum(), u64::MAX, "sum saturates instead of wrapping");
        // the overflow quantile saturates into the last finite edge — the
        // `p95>100us` floor convention
        assert_eq!(h.quantile_bucket(0.99), Some(3));
        assert_eq!(h.quantile_edge(0.99), 100);
    }

    #[test]
    fn empty_histogram_reports_zero() {
        let reg = Registry::new();
        let h = reg.histogram("empty_us");
        assert_eq!(h.quantile_bucket(0.5), None);
        assert_eq!(h.quantile_edge(0.5), 0);
        assert_eq!(h.count(), 0);
        assert_eq!(h.edges(), log2_edges().as_slice());
    }

    #[test]
    fn quantile_edge_saturates_never_interpolates() {
        // the satellite audit: `quantile_edge` on degenerate mass
        // distributions must return the documented saturation values —
        // never a value interpolated past the last finite edge.
        let reg = Registry::new();

        // empty histogram: no mass, no bucket — the documented answer is 0
        let empty = reg.histogram_edges("audit_empty_us", &[10, 100, 1000]);
        for q in [0.0, 0.5, 0.99, 1.0] {
            assert_eq!(empty.quantile_bucket(q), None);
            assert_eq!(empty.quantile_edge(q), 0, "empty histogram reports 0 at q={q}");
        }

        // overflow-only: every observation past the last finite edge — every
        // quantile (even p1) saturates into the last finite edge, the
        // coordinator's `p95>1000us` floor convention
        let over = reg.histogram_edges("audit_overflow_us", &[10, 100, 1000]);
        over.observe(5000);
        over.observe(u64::MAX);
        for q in [0.01, 0.5, 0.99, 1.0] {
            assert_eq!(over.quantile_bucket(q), Some(3), "all mass in overflow at q={q}");
            assert_eq!(over.quantile_edge(q), 1000, "saturates to last finite edge at q={q}");
        }

        // all mass in the first bucket: even p99/p100 stay on the first
        // edge — no drift toward later empty buckets
        let first = reg.histogram_edges("audit_first_bucket_us", &[10, 100, 1000]);
        for _ in 0..32 {
            first.observe(3);
        }
        for q in [0.01, 0.5, 0.99, 1.0] {
            assert_eq!(first.quantile_bucket(q), Some(0));
            assert_eq!(first.quantile_edge(q), 10, "first-bucket mass pins to first edge");
        }
    }

    #[test]
    fn reregistration_is_idempotent() {
        let reg = Registry::new();
        let a = reg.counter("idem_total");
        let b = reg.counter("idem_total");
        a.inc();
        b.inc();
        assert_eq!(a.get(), 2, "same name must resolve to the same cell");
        // distinct labels are distinct cells under one name
        let l0 = reg.counter_with("idem_labeled_total", &[("m", "a".into())]);
        let l1 = reg.counter_with("idem_labeled_total", &[("m", "b".into())]);
        l0.add(5);
        assert_eq!(l1.get(), 0);
        assert!(reg.render_text().contains("idem_labeled_total{m=\"a\"} 5"));
    }

    #[test]
    fn concurrent_hammer_from_many_threads() {
        // the TSAN-tier test: many threads, one registry — registration
        // races, hot-path increments, and concurrent exposition
        let reg = Arc::new(Registry::new());
        let threads = 8;
        let per = 500u64;
        thread::scope(|s| {
            for t in 0..threads {
                let reg = Arc::clone(&reg);
                s.spawn(move || {
                    let c = reg.counter("hammer_total");
                    let h = reg.histogram_edges("hammer_us", &[4, 64, 1024]);
                    for i in 0..per {
                        c.inc();
                        h.observe(i * (t + 1));
                        if i % 128 == 0 {
                            // re-register mid-hammer and render concurrently
                            let again = reg.counter("hammer_total");
                            let _ = again.get();
                            let _ = reg.render_json();
                        }
                    }
                });
            }
        });
        let c = reg.counter("hammer_total");
        let h = reg.histogram_edges("hammer_us", &[4, 64, 1024]);
        assert_eq!(c.get(), threads * per);
        assert_eq!(h.count(), threads * per);
        assert_eq!(h.counts().iter().sum::<u64>(), threads * per);
    }
}
