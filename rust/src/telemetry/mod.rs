//! Unified observability layer: metrics registry, per-request span
//! tracing, and phase-level profiling — dependency-free by construction
//! (std only, no serde/prometheus/tracing crates).
//!
//! Until this module the stack's observability was three disjoint,
//! string-summary-only silos: `coordinator::Metrics` (atomic fields +
//! a hand-rolled latency histogram), `pipeline::PipelineStats` (per-stage
//! busy/idle events) and `circulant::sched::PhaseCounters` (executed
//! FFT/MAC counts, visible only to tests).  The paper's headline claims
//! rest on *measured, attributable* per-layer and per-phase costs, and the
//! ROADMAP's next items (network front-end with SLO-gated p50/p99,
//! spectrum cache with `*_hits/_misses` telemetry, global scheduler
//! occupancy) all report through a substrate like this one.
//!
//! # Observability
//!
//! ## Metric naming contract
//!
//! Every metric is registered through [`Registry`] under a **literal**
//! `snake_case` name, unique crate-wide — machine-checked by the
//! `metric-name` lint rule (`crate::lint::rules`):
//!
//! * names are `[a-z0-9_]`, start with a letter, no `__` runs, no
//!   trailing `_`;
//! * counters end in `_total`; histograms of durations end in `_us`;
//!   gauges carry their unit as a suffix (`_permille`, `_bits`,
//!   `_per_image`);
//! * cache-style pairs follow the `*_hits`/`*_misses`(/`*_evictions`)
//!   convention — registering one of the pair without the other is a lint
//!   error, so a cache can never ship half its telemetry;
//! * dynamic dimensions (model, layer, stage, precision) go in **labels**
//!   (`counter_with`/`gauge_with`/`histogram_with`), never in the name.
//!
//! ## Span lifecycle
//!
//! One span per admitted request, minted at `coordinator::server`
//! admission and finished at reply scatter:
//!
//! ```text
//!   infer_async          batcher            executor / pipeline     scatter
//!       │                   │                       │                  │
//!   admitted(model) ──► queued (enqueued) ──► released(seq) ──► … ──► finished
//!       │  span id minted   │   queue-wait seg     │  exec seg        │
//!       ▼                   ▼                      ▼                  ▼
//!     [admit t0]········[queue t0..t1]·········[exec t1..t2]······[ring buffer]
//! ```
//!
//! Completed spans land in a bounded ring buffer (oldest dropped first,
//! drops counted in `trace_spans_dropped_total`), renderable as an ASCII
//! waterfall ([`render_waterfall`] — the per-request analogue of
//! `pipeline::timeline::render`) and dumpable as JSON (`circnn serve
//! --trace [--trace-dump PATH]`, gated by the registered `CIRCNN_TRACE`
//! knob).  For pipelined engines the server joins each span's `seq`
//! against `PipelineStats` stage events, so the waterfall shows every
//! stage hop inside the exec segment.  Tracing is overhead-neutral when
//! disabled (no span is minted, no lock is touched) and never perturbs
//! results: serving output is property-pinned bitwise identical with
//! tracing on and off.
//!
//! ## Exposition formats
//!
//! [`Registry::render_text`] emits Prometheus-style text:
//!
//! ```text
//! # TYPE requests_total counter
//! requests_total 512
//! # TYPE queue_wait_us histogram
//! queue_wait_us_bucket{le="1"} 0
//! queue_wait_us_bucket{le="+Inf"} 512
//! queue_wait_us_sum 92816
//! queue_wait_us_count 512
//! ```
//!
//! [`Registry::render_json`] emits the machine-readable twin consumed by
//! CI's telemetry-dump smoke and `util::benchkit`-style tooling:
//! `{"counters":{...},"gauges":{...},"histograms":{name:{"edges":[...],
//! "counts":[...],"sum":n,"count":n,"p50":e,"p95":e,"p99":e}}}` — all
//! integers, so the output is deterministic for deterministic inputs
//! (golden-tested).  Histogram quantiles saturate into the last finite
//! edge on overflow, matching `coordinator::Metrics`' `p95>…` floor
//! convention.
//!
//! ## Live scrape and snapshots
//!
//! Both expositions are also served **on-line**: `circnn serve
//! --metrics-addr HOST:PORT` starts the HTTP/1.0 responder of
//! `crate::net::scrape` (GET `/metrics`, `/metrics.json`, `/trace.json`,
//! `/healthz`) against the same registry/tracer, and the CIRC wire
//! protocol's `Admin` frame scrapes the same documents without a second
//! socket.  The [`snapshot`] module adds the time dimension: a background
//! [`snapshot::Sampler`] captures queue depth, in-flight, stage busy
//! permille, and open connections every `CIRCNN_SNAP_MS` into a bounded
//! [`snapshot::SnapshotRing`] with `*_watermark` high-water gauges, so
//! transient saturation is visible instead of averaged away.

pub mod registry;
pub mod snapshot;
pub mod span;

pub use registry::{log2_edges, Counter, Gauge, Histogram, Registry};
pub use snapshot::{sparkline, Sampler, SnapSample, SnapshotRing};
pub use span::{render_waterfall, spans_to_json, trace_document, Seg, SpanRecord, Tracer};
