//! Experiment T1: regenerate Table 1.
//!
//! Proposed rows come from the FPGA simulator on the CyClone V model at
//! 12-bit; baseline rows come from the TrueNorth and binary-FPGA analytical
//! models.  The paper's headline ratios are computed at matched accuracy
//! rows: >=152x speedup and >=71x energy efficiency vs TrueNorth, >=31x
//! energy efficiency vs the best reference FPGA (FINN).

use crate::baselines::{reference_fpga, truenorth};
use crate::fpga::device::CYCLONE_V;
use crate::fpga::report::DesignReport;
use crate::fpga::schedule::ScheduleConfig;
use crate::models;
use crate::runtime::manifest::Manifest;

/// One row of the regenerated table.
#[derive(Debug, Clone)]
pub struct Row {
    pub name: String,
    pub dataset: String,
    pub platform: String,
    pub precision_bits: u64,
    /// measured accuracy on the synthetic substitute (None for baselines,
    /// which report their published accuracy)
    pub accuracy: f64,
    pub paper_accuracy: f64,
    pub kfps: f64,
    pub kfps_per_w: f64,
    pub proposed: bool,
}

/// The paper's headline ratios, computed from the regenerated rows.
#[derive(Debug, Clone, Copy)]
pub struct Headline {
    /// min over matched-accuracy pairs of proposed_kfps / truenorth_kfps
    pub speedup_vs_truenorth: f64,
    /// min over matched pairs of proposed_eff / truenorth_eff
    pub energy_gain_vs_truenorth: f64,
    /// min proposed_eff / best reference-FPGA eff on the same dataset
    pub energy_gain_vs_reference_fpga: f64,
}

/// Generate all rows.
pub fn rows(manifest: Option<&Manifest>) -> Vec<Row> {
    let mut out = Vec::new();
    for m in models::registry() {
        let cfg = ScheduleConfig::auto_for(&m, &CYCLONE_V);
        let rep = DesignReport::build(&m, &CYCLONE_V, &cfg);
        let accuracy = manifest
            .and_then(|man| man.model(m.name).ok())
            .map(|e| e.accuracy.circulant_12bit)
            .unwrap_or(m.paper_accuracy / 100.0);
        out.push(Row {
            name: format!("proposed_{}", m.name),
            dataset: m.dataset.to_string(),
            platform: "cyclone_v (sim)".into(),
            precision_bits: 12,
            accuracy,
            paper_accuracy: m.paper_accuracy / 100.0,
            kfps: rep.kfps,
            kfps_per_w: rep.kfps_per_w,
            proposed: true,
        });
    }
    for t in truenorth::table1_rows() {
        out.push(Row {
            name: t.name.into(),
            dataset: t.dataset.into(),
            platform: "truenorth (model)".into(),
            precision_bits: 2,
            accuracy: t.accuracy,
            paper_accuracy: t.accuracy,
            kfps: t.kfps(),
            kfps_per_w: t.kfps_per_w(),
            proposed: false,
        });
    }
    for r in reference_fpga::table1_rows() {
        out.push(Row {
            name: r.name.into(),
            dataset: r.dataset.into(),
            platform: "ref fpga (model)".into(),
            precision_bits: r.precision_bits,
            accuracy: r.accuracy,
            paper_accuracy: r.accuracy,
            kfps: r.kfps(),
            kfps_per_w: r.kfps_per_w(),
            proposed: false,
        });
    }
    out
}

/// Compute the headline ratios from the regenerated rows.
///
/// Matching follows the paper's "under the same test accuracy": each
/// proposed design is compared against same-dataset baselines in the same
/// accuracy class (|Δ accuracy| <= 2.5%, paper-accuracy basis since the
/// baselines' accuracies are published values on the real datasets).  With
/// the paper's own numbers this rule reproduces exactly its >=152x / >=71x
/// / >=31x minima (the SVHN pair for TrueNorth, the MLP-2/FINN pair for the
/// reference FPGA).
pub fn headline(rows: &[Row]) -> Headline {
    let mut speedup = f64::INFINITY;
    let mut energy_tn = f64::INFINITY;
    let mut energy_ref = f64::INFINITY;
    for p in rows.iter().filter(|r| r.proposed) {
        for b in rows.iter().filter(|r| !r.proposed && r.dataset == p.dataset) {
            // same accuracy class only
            if (p.paper_accuracy - b.paper_accuracy).abs() > 0.025 {
                continue;
            }
            let su = p.kfps / b.kfps;
            let eg = p.kfps_per_w / b.kfps_per_w;
            if b.platform.contains("truenorth") {
                speedup = speedup.min(su);
                energy_tn = energy_tn.min(eg);
            } else {
                energy_ref = energy_ref.min(eg);
            }
        }
    }
    Headline {
        speedup_vs_truenorth: speedup,
        energy_gain_vs_truenorth: energy_tn,
        energy_gain_vs_reference_fpga: energy_ref,
    }
}

/// Render the table + headline as text.
pub fn render(manifest: Option<&Manifest>) -> String {
    let rows = rows(manifest);
    let mut out = String::new();
    out.push_str(&format!(
        "{:<28} {:<9} {:<19} {:>4} {:>9} {:>9} {:>14} {:>14}\n",
        "Name", "Dataset", "Platform", "Prec", "Acc", "PaperAcc", "kFPS", "kFPS/W"
    ));
    out.push_str(&"-".repeat(112));
    out.push('\n');
    for r in &rows {
        out.push_str(&format!(
            "{:<28} {:<9} {:<19} {:>4} {:>8.2}% {:>8.2}% {:>14.3} {:>14.3}\n",
            r.name,
            r.dataset,
            r.platform,
            r.precision_bits,
            r.accuracy * 100.0,
            r.paper_accuracy * 100.0,
            r.kfps,
            r.kfps_per_w,
        ));
    }
    let h = headline(&rows);
    out.push_str(&format!(
        "\nheadline ratios (regenerated / paper):\n\
           speedup vs TrueNorth      {:>10.1}x   (paper: >=152x)\n\
           energy eff vs TrueNorth   {:>10.1}x   (paper: >=71x)\n\
           energy eff vs ref FPGA    {:>10.1}x   (paper: >=31x)\n",
        h.speedup_vs_truenorth, h.energy_gain_vs_truenorth, h.energy_gain_vs_reference_fpga
    ));
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_has_all_row_groups() {
        let rows = rows(None);
        assert_eq!(rows.iter().filter(|r| r.proposed).count(), 6);
        assert_eq!(
            rows.iter().filter(|r| r.platform.contains("truenorth")).count(),
            4
        );
        assert_eq!(
            rows.iter().filter(|r| r.platform.contains("ref fpga")).count(),
            4
        );
    }

    #[test]
    fn headline_shapes_hold() {
        // The paper's qualitative claims must come out of the regenerated
        // numbers: large speedup and energy gains vs TrueNorth, a
        // significant efficiency gain vs the best reference FPGA.
        let h = headline(&rows(None));
        assert!(
            h.speedup_vs_truenorth >= 100.0,
            "speedup {} too small",
            h.speedup_vs_truenorth
        );
        assert!(
            h.energy_gain_vs_truenorth >= 50.0,
            "energy gain {} too small",
            h.energy_gain_vs_truenorth
        );
        assert!(
            h.energy_gain_vs_reference_fpga >= 10.0,
            "ref-fpga gain {} too small",
            h.energy_gain_vs_reference_fpga
        );
    }

    #[test]
    fn render_contains_paper_anchors() {
        let text = render(None);
        assert!(text.contains(">=152x"));
        assert!(text.contains("proposed_mnist_mlp_1"));
        assert!(text.contains("truenorth_mnist_95"));
    }
}
