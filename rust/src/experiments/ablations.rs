//! Experiments AB1-AB3: ablations of the paper's hardware design choices.
//!
//! * **AB1 decoupling** — FFT/IFFT placement: q FFTs + p IFFTs (decoupled)
//!   vs p*q of each (naive Eqn.-1 evaluation).
//! * **AB2 real-FFT symmetry** — half-spectrum storage/multiplication vs
//!   full spectrum.
//! * **AB3 batch interleaving** — Fig.-4 batch pipelining vs per-image
//!   pipeline fills.

use crate::fpga::device::CYCLONE_V;
use crate::fpga::schedule::{simulate, ScheduleConfig, ScheduleResult};
use crate::models::{self, Model};

/// One ablation row: design point on/off and the cost of turning it off.
#[derive(Debug, Clone)]
pub struct AblationRow {
    pub model: String,
    pub ablation: &'static str,
    pub kfps_on: f64,
    pub kfps_off: f64,
    /// throughput retained when the optimization is disabled
    pub retained: f64,
}

fn run(model: &Model, cfg: &ScheduleConfig) -> ScheduleResult {
    simulate(model, &CYCLONE_V, cfg)
}

/// All ablations for one model.
pub fn ablate(model: &Model) -> Vec<AblationRow> {
    let base = ScheduleConfig::auto_for(model, &CYCLONE_V);
    let on = run(model, &base);
    let variants: [(&'static str, ScheduleConfig); 3] = [
        ("AB1_decoupling", ScheduleConfig { decouple: false, ..base }),
        ("AB2_half_spectrum", ScheduleConfig { half_spectrum: false, ..base }),
        ("AB3_batch_interleave", ScheduleConfig { interleave: false, ..base }),
    ];
    variants
        .into_iter()
        .map(|(name, cfg)| {
            let off = run(model, &cfg);
            AblationRow {
                model: model.name.to_string(),
                ablation: name,
                kfps_on: on.kfps(),
                kfps_off: off.kfps(),
                retained: off.kfps() / on.kfps(),
            }
        })
        .collect()
}

pub fn render() -> String {
    let mut out = String::new();
    out.push_str(&format!(
        "{:<14} {:<22} {:>14} {:>14} {:>10}\n",
        "Model", "Ablation (disabled)", "kFPS on", "kFPS off", "retained"
    ));
    out.push_str(&"-".repeat(80));
    out.push('\n');
    for m in models::registry() {
        for row in ablate(&m) {
            out.push_str(&format!(
                "{:<14} {:<22} {:>14.2} {:>14.2} {:>9.1}%\n",
                row.model,
                row.ablation,
                row.kfps_on,
                row.kfps_off,
                row.retained * 100.0
            ));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_optimization_helps_every_model() {
        for m in models::registry() {
            for row in ablate(&m) {
                assert!(
                    row.retained < 1.0,
                    "{} {}: retained {}",
                    row.model,
                    row.ablation,
                    row.retained
                );
            }
        }
    }

    #[test]
    fn interleaving_matters_most_for_small_models() {
        // pipeline fills dominate small workloads: the MLP should lose more
        // from disabling interleaving than the big CNN does
        let mlp = ablate(&models::by_name("mnist_mlp_1").unwrap());
        let wrn = ablate(&models::by_name("cifar_wrn").unwrap());
        let mlp_ab3 = mlp.iter().find(|r| r.ablation == "AB3_batch_interleave").unwrap();
        let wrn_ab3 = wrn.iter().find(|r| r.ablation == "AB3_batch_interleave").unwrap();
        assert!(mlp_ab3.retained < wrn_ab3.retained);
    }
}
