//! Experiment S1: the O(n log n) vs O(n^2) claim, measured.
//!
//! Sweeps matrix size n and block size k, comparing the wall-clock of the
//! from-scratch circulant matvec against the dense matvec, plus the
//! analytic op counts.  The crossover (where FFT-based wins) and the
//! asymptotic slope are the paper's algorithmic claim; `rust/benches/
//! circulant.rs` runs the same sweep under the bench harness.

use std::time::Instant;

use crate::circulant::{dense, BlockCirculant};
use crate::util::rng::SplitMix;

/// One sweep point.
#[derive(Debug, Clone)]
pub struct SweepPoint {
    pub n: usize,
    pub k: usize,
    pub dense_ns: f64,
    pub circ_ns: f64,
    pub speedup: f64,
    pub dense_macs: u64,
    pub circ_mults: u64,
}

/// Time one closure (median of `reps`).
fn time_ns<R>(reps: usize, mut f: impl FnMut() -> R) -> f64 {
    let mut samples = Vec::with_capacity(reps);
    for _ in 0..reps {
        let t0 = Instant::now();
        std::hint::black_box(f());
        samples.push(t0.elapsed().as_nanos() as f64);
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    samples[reps / 2]
}

/// Run the sweep over square n x n matrices.
pub fn sweep(ns: &[usize], k: usize, reps: usize) -> Vec<SweepPoint> {
    let mut rng = SplitMix::new(42);
    let mut out = Vec::new();
    for &n in ns {
        if n % k != 0 {
            continue;
        }
        let pq = n / k;
        let mut bc = BlockCirculant::new(pq, pq, k, rng.normal_vec(pq * pq * k));
        bc.precompute();
        let dense_w = bc.to_dense();
        let x = rng.normal_vec(n);
        let mut y = vec![0.0f32; n];

        let dense_ns = time_ns(reps, || dense::matvec(&dense_w, n, n, &x, &mut y));
        let circ_ns = time_ns(reps, || bc.matvec(&x, &mut y));

        let kh = (k / 2 + 1) as u64;
        let fm = crate::models::fft_real_mults(k);
        let circ_mults = pq as u64 * fm * 2 + (pq * pq) as u64 * kh * 4;
        out.push(SweepPoint {
            n,
            k,
            dense_ns,
            circ_ns,
            speedup: dense_ns / circ_ns,
            dense_macs: (n * n) as u64,
            circ_mults,
        });
    }
    out
}

pub fn render(points: &[SweepPoint]) -> String {
    let mut out = String::new();
    out.push_str(&format!(
        "{:>6} {:>5} {:>12} {:>12} {:>9} {:>12} {:>12}\n",
        "n", "k", "dense ns", "circ ns", "speedup", "dense MACs", "circ mults"
    ));
    out.push_str(&"-".repeat(74));
    out.push('\n');
    for p in points {
        out.push_str(&format!(
            "{:>6} {:>5} {:>12.0} {:>12.0} {:>8.2}x {:>12} {:>12}\n",
            p.n, p.k, p.dense_ns, p.circ_ns, p.speedup, p.dense_macs, p.circ_mults
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn op_counts_grow_asymptotically_slower() {
        let pts = sweep(&[256, 512, 1024, 2048], 64, 3);
        assert!(pts.len() >= 3);
        // op-count ratio dense/circ grows with n: O(n^2) vs O(n log n)
        let first = pts.first().unwrap();
        let last = pts.last().unwrap();
        let r0 = first.dense_macs as f64 / first.circ_mults as f64;
        let r1 = last.dense_macs as f64 / last.circ_mults as f64;
        assert!(r1 > r0 * 1.5, "ratios {r0} -> {r1}");
    }

    #[test]
    fn measured_speedup_at_large_n() {
        // at n=2048, k=64 the FFT path must clearly win on wall clock
        let pts = sweep(&[2048], 64, 5);
        assert!(pts[0].speedup > 2.0, "speedup {}", pts[0].speedup);
    }

    #[test]
    fn skips_non_dividing_sizes() {
        assert!(sweep(&[100], 64, 1).is_empty());
    }
}
