//! Experiment A1: the analog / emerging-device comparison paragraph.
//!
//! Regenerates: (i) the ~TOPS/W equivalent-efficiency comparison against
//! ISAAC / PipeLayer / Lu et al., and (ii) the latency comparison — the
//! paper's 11.6 ns/image (CyClone V) and ~4 ns/image (Kintex-7) for the
//! MNIST MLP vs the ~1 us/inference regime of analog classifiers.

use crate::baselines::analog::ANALOG_CORPUS;
use crate::fpga::device::{CYCLONE_V, KINTEX_7};
use crate::fpga::report::DesignReport;
use crate::fpga::schedule::ScheduleConfig;
use crate::models;

/// The regenerated comparison.
#[derive(Debug, Clone)]
pub struct AnalogComparison {
    pub proposed_gops_per_w_cyclone: f64,
    pub proposed_ns_per_image_cyclone: f64,
    pub proposed_ns_per_image_kintex: f64,
    /// min gain over the analog corpus in GOPS/W
    pub min_efficiency_gain: f64,
    /// min latency advantage vs the ~1 us analog inference
    pub min_latency_gain: f64,
}

pub fn compare() -> AnalogComparison {
    let m = models::by_name("mnist_mlp_1").unwrap();
    let cv = DesignReport::build(&m, &CYCLONE_V, &ScheduleConfig::auto_for(&m, &CYCLONE_V));
    let k7 = DesignReport::build(&m, &KINTEX_7, &ScheduleConfig::auto_for(&m, &KINTEX_7));
    let min_eff_gain = ANALOG_CORPUS
        .iter()
        .map(|p| cv.equivalent_gops_per_w / p.gops_per_w)
        .fold(f64::INFINITY, f64::min);
    let min_lat_gain = ANALOG_CORPUS
        .iter()
        .map(|p| p.inference_latency_s() * 1e9 / cv.ns_per_image)
        .fold(f64::INFINITY, f64::min);
    AnalogComparison {
        proposed_gops_per_w_cyclone: cv.equivalent_gops_per_w,
        proposed_ns_per_image_cyclone: cv.ns_per_image,
        proposed_ns_per_image_kintex: k7.ns_per_image,
        min_efficiency_gain: min_eff_gain,
        min_latency_gain: min_lat_gain,
    }
}

pub fn render() -> String {
    let c = compare();
    let mut out = String::new();
    out.push_str("analog / emerging-device comparison (MNIST MLP-1)\n");
    out.push_str(&"-".repeat(64));
    out.push('\n');
    out.push_str(&format!(
        "proposed (cyclone_v sim):  {:>10.1} GOPS/W   {:>8.1} ns/image (paper: 5140 GOPS/W, 11.6 ns)\n",
        c.proposed_gops_per_w_cyclone, c.proposed_ns_per_image_cyclone
    ));
    out.push_str(&format!(
        "proposed (kintex7 sim):                      {:>8.1} ns/image (paper: ~4 ns)\n",
        c.proposed_ns_per_image_kintex
    ));
    for p in ANALOG_CORPUS {
        out.push_str(&format!(
            "{:<24}   {:>10.1} GOPS/W   {:>8.1} ns/inference\n",
            p.name,
            p.gops_per_w,
            p.inference_latency_s() * 1e9
        ));
    }
    out.push_str(&format!(
        "\nmin efficiency gain vs analog corpus: {:.1}x; min latency gain: {:.0}x\n",
        c.min_efficiency_gain, c.min_latency_gain
    ));
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn proposed_latency_out_of_reach_of_analog() {
        // paper: ns-scale per image "is difficult to achieve even using
        // emerging devices" (which sit at ~1 us)
        let c = compare();
        assert!(c.proposed_ns_per_image_cyclone < 100.0, "{}", c.proposed_ns_per_image_cyclone);
        assert!(c.min_latency_gain > 10.0, "{}", c.min_latency_gain);
    }

    #[test]
    fn efficiency_competitive_with_analog() {
        // paper: 5.14 TOPS/W beats ISAAC (380.7) and PipeLayer (142.9) and
        // Lu (1040).  Our simulated point must beat the corpus too.
        let c = compare();
        assert!(c.min_efficiency_gain > 1.0, "{}", c.min_efficiency_gain);
    }

    #[test]
    fn kintex_faster_than_cyclone() {
        let c = compare();
        assert!(c.proposed_ns_per_image_kintex < c.proposed_ns_per_image_cyclone);
    }
}
