//! Experiment F3: Fig. 3 — weight storage reduction per benchmark.
//!
//! The overall compression is parameter reduction (x k per compressed
//! layer) times bit quantization (32-bit float -> 12-bit fixed).

use crate::models;
use crate::runtime::manifest::Manifest;

/// One bar of Fig. 3.
#[derive(Debug, Clone)]
pub struct Bar {
    pub model: String,
    pub dataset: String,
    pub dense_bytes: u64,
    pub circ_bytes: u64,
    pub reduction: f64,
    /// parameter-count reduction alone (no quantization)
    pub param_reduction: f64,
}

pub fn bars() -> Vec<Bar> {
    models::registry()
        .iter()
        .map(|m| {
            let rep12 = m.storage_report(12);
            let rep32 = m.storage_report(32);
            Bar {
                model: m.name.to_string(),
                dataset: m.dataset.to_string(),
                dense_bytes: rep12.dense_bytes,
                circ_bytes: rep12.circ_bytes,
                reduction: rep12.reduction,
                param_reduction: rep32.reduction,
            }
        })
        .collect()
}

/// Render as an ASCII bar chart + table.
pub fn render(manifest: Option<&Manifest>) -> String {
    let mut out = String::new();
    out.push_str(&format!(
        "{:<14} {:<9} {:>12} {:>12} {:>9} {:>9} {:>10}\n",
        "Model", "Dataset", "Dense(B)", "Circ12(B)", "Params x", "Total x", "Manifest x"
    ));
    out.push_str(&"-".repeat(80));
    out.push('\n');
    for b in bars() {
        let man_red = manifest
            .and_then(|m| m.model(&b.model).ok())
            .map(|e| format!("{:9.1}", e.storage_reduction))
            .unwrap_or_else(|| "        -".into());
        out.push_str(&format!(
            "{:<14} {:<9} {:>12} {:>12} {:>8.1}x {:>8.1}x {:>10}\n",
            b.model, b.dataset, b.dense_bytes, b.circ_bytes, b.param_reduction, b.reduction,
            man_red
        ));
    }
    out.push('\n');
    for b in bars() {
        let width = (b.reduction / 2.0).round() as usize;
        out.push_str(&format!(
            "{:<14} |{} {:.1}x\n",
            b.model,
            "#".repeat(width.min(60)),
            b.reduction
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn six_bars_all_compressed() {
        let bs = bars();
        assert_eq!(bs.len(), 6);
        for b in &bs {
            // Fig. 3's claim: significant compression on every benchmark
            assert!(b.reduction > 10.0, "{}: {}", b.model, b.reduction);
            // total = params x quantization (32/12)
            let expected = b.param_reduction * 32.0 / 12.0;
            assert!((b.reduction - expected).abs() / expected < 0.01);
        }
    }

    #[test]
    fn render_shows_bars() {
        let text = render(None);
        assert!(text.contains("mnist_mlp_1"));
        assert!(text.contains('#'));
    }
}
