//! Experiment F6: Fig. 6 — equivalent performance (GOPS) vs energy
//! efficiency (GOPS/W) of the proposed designs against the reference FPGA
//! corpus.
//!
//! "Equivalent" normalizes to the dense matrix-vector op count (the paper's
//! fair-comparison device for cross-architecture numbers).  The paper's
//! claim: a minimum of >84x energy-efficiency gain over every reference
//! point.

use crate::baselines::reference_fpga::{Fig6Point, FIG6_CORPUS};
use crate::fpga::device::{CYCLONE_V, KINTEX_7};
use crate::fpga::report::DesignReport;
use crate::fpga::schedule::ScheduleConfig;
use crate::models;

/// A point of the regenerated scatter.
#[derive(Debug, Clone)]
pub struct Point {
    pub name: String,
    pub gops: f64,
    pub gops_per_w: f64,
    pub proposed: bool,
}

pub fn points() -> Vec<Point> {
    let mut out = Vec::new();
    for m in models::registry() {
        for dev in [&CYCLONE_V, &KINTEX_7] {
            let cfg = ScheduleConfig::auto_for(&m, dev);
            let rep = DesignReport::build(&m, dev, &cfg);
            out.push(Point {
                name: format!("proposed_{}_{}", m.name, dev.name),
                gops: rep.equivalent_gops,
                gops_per_w: rep.equivalent_gops_per_w,
                proposed: true,
            });
        }
    }
    for Fig6Point { name, gops, gops_per_w } in FIG6_CORPUS {
        out.push(Point {
            name: (*name).to_string(),
            gops: *gops,
            gops_per_w: *gops_per_w,
            proposed: false,
        });
    }
    out
}

/// Minimum efficiency gain of any proposed *CyClone V* design over the best
/// reference point (the paper's efficiency claim targets its low-power
/// device; the Kintex-7 points trade efficiency for raw speed).
pub fn min_efficiency_gain() -> f64 {
    let pts = points();
    let best_ref = pts
        .iter()
        .filter(|p| !p.proposed)
        .map(|p| p.gops_per_w)
        .fold(0.0f64, f64::max);
    pts.iter()
        .filter(|p| p.proposed && p.name.contains("cyclone"))
        .map(|p| p.gops_per_w / best_ref)
        .fold(f64::INFINITY, f64::min)
}

pub fn render() -> String {
    let mut out = String::new();
    out.push_str(&format!(
        "{:<44} {:>14} {:>14}\n",
        "Design", "eq GOPS", "eq GOPS/W"
    ));
    out.push_str(&"-".repeat(76));
    out.push('\n');
    let mut pts = points();
    pts.sort_by(|a, b| b.gops_per_w.partial_cmp(&a.gops_per_w).unwrap());
    for p in &pts {
        out.push_str(&format!(
            "{:<44} {:>14.1} {:>14.1}{}\n",
            p.name,
            p.gops,
            p.gops_per_w,
            if p.proposed { "  *" } else { "" }
        ));
    }
    out.push_str(&format!(
        "\nmin proposed/best-reference efficiency gain: {:.1}x (paper: >=84x over references,\n\
         >=31x over the best, FINN)\n",
        min_efficiency_gain()
    ));
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn proposed_dominates_reference_corpus() {
        // The Fig-6 shape: every proposed point sits above every reference
        // point in efficiency.
        let pts = points();
        let best_ref = pts
            .iter()
            .filter(|p| !p.proposed)
            .map(|p| p.gops_per_w)
            .fold(0.0f64, f64::max);
        for p in pts.iter().filter(|p| p.proposed && p.name.contains("cyclone")) {
            assert!(
                p.gops_per_w > best_ref,
                "{} at {} <= best ref {}",
                p.name,
                p.gops_per_w,
                best_ref
            );
        }
    }

    #[test]
    fn substantial_minimum_gain() {
        // paper: >=31x vs FINN (the best reference).  Our simulated designs
        // must show a substantial (>=5x) minimum gain for the shape to hold.
        let gain = min_efficiency_gain();
        assert!(gain >= 5.0, "min gain {gain}");
    }

    #[test]
    fn corpus_present_in_render() {
        let text = render();
        assert!(text.contains("umuroglu_finn_fpga17"));
        assert!(text.contains("proposed_mnist_mlp_1_cyclone_v_5cea9"));
    }
}
