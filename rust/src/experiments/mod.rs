//! Experiment generators: every table and figure of the paper's evaluation.
//!
//! | id | paper artifact | generator |
//! |----|----------------|-----------|
//! | T1 | Table 1 (accuracy / kFPS / kFPS/W vs TrueNorth, FINN, Alemdar) | [`table1`] |
//! | F3 | Fig. 3 (weight storage reduction) | [`fig3`] |
//! | F6 | Fig. 6 (GOPS vs GOPS/W scatter) | [`fig6`] |
//! | A1 | analog / emerging-device comparison (~TOPS/W, ns/image) | [`analog`] |
//! | S1 | O(n log n) vs O(n^2) crossover | [`complexity`] |
//! | AB1-3 | decoupling / symmetry / batching ablations | [`ablations`] |
//!
//! Accuracies come from the manifest when available (measured on the
//! synthetic substitute datasets) and are always printed next to the
//! paper's published values — never in place of them.

pub mod ablations;
pub mod analog;
pub mod complexity;
pub mod fig3;
pub mod fig6;
pub mod precision;
pub mod table1;

use crate::runtime::manifest::Manifest;

/// Load the manifest if it exists (experiments degrade gracefully to
/// paper-row accuracies when artifacts have not been built).
pub fn try_manifest() -> Option<Manifest> {
    Manifest::load(Manifest::default_dir()).ok()
}
