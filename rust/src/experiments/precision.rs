//! Experiment P1 (supplementary): why 12 bits — SNR of the true
//! fixed-point FFT→∘→IFFT datapath (`circulant::fixed`) and end-to-end
//! accuracy of the native engine vs datapath width.
//!
//! The paper fixes the datapath at 12-bit without showing the sensitivity;
//! this experiment regenerates the design rationale: SNR grows ~6 dB/bit,
//! and classification accuracy saturates at the width where arithmetic
//! noise drops below the task's decision margins — at or before 12 bits
//! for every Table-1 model, which is the paper's choice.

use crate::circulant::fixed::{float_circulant_matvec, snr_db, FixedFft};
use crate::util::rng::SplitMix;

/// One row of the precision sweep.
#[derive(Debug, Clone)]
pub struct PrecisionRow {
    pub frac_bits: u32,
    /// SNR of one k=128 circulant matvec through the fixed datapath
    pub matvec_snr_db: f64,
    /// native-engine accuracy at this fake-quant width (None when the
    /// parameter artifacts are unavailable)
    pub accuracy: Option<f64>,
}

/// Sweep datapath widths; `samples` test images per accuracy point.
pub fn sweep(widths: &[u32], samples: usize) -> Vec<PrecisionRow> {
    let mut rng = SplitMix::new(0xF1CED);
    let k = 128;
    let w: Vec<f32> = rng.normal_vec(k).iter().map(|v| v / k as f32).collect();
    let x = rng.normal_vec(k);
    let want = float_circulant_matvec(&w, &x);

    // accuracy leg: native engine on mnist_mlp_1 at each width
    let man = crate::runtime::Manifest::load(crate::runtime::Manifest::default_dir()).ok();
    let model = crate::models::by_name("mnist_mlp_1").unwrap();
    let ds = crate::data::dataset(model.dataset).unwrap();
    let (h, wd, c) = model.input;
    let (xs, ys) = crate::data::batch(&ds, 0, samples, true);

    widths
        .iter()
        .map(|&frac| {
            let got = FixedFft::new(k, frac).circulant_matvec(&w, &x);
            let accuracy = man.as_ref().and_then(|m| {
                let path = m.dir.join("params/mnist_mlp_1.npz");
                let native =
                    crate::native::NativeModel::load(&model, &path, Some(frac)).ok()?;
                let labels = native.classify(&xs, samples, h, wd, c);
                Some(
                    labels.iter().zip(&ys).filter(|(a, b)| a == b).count() as f64
                        / samples as f64,
                )
            });
            PrecisionRow { frac_bits: frac, matvec_snr_db: snr_db(&want, &got), accuracy }
        })
        .collect()
}

pub fn render() -> String {
    let rows = sweep(&[6, 8, 10, 12, 14, 16], 256);
    let mut out = String::new();
    out.push_str("precision sweep: fixed-point datapath SNR and end-to-end accuracy\n");
    out.push_str(&format!(
        "{:>6} {:>14} {:>16}\n",
        "bits", "matvec SNR", "accuracy (MLP-1)"
    ));
    out.push_str(&"-".repeat(40));
    out.push('\n');
    for r in &rows {
        out.push_str(&format!(
            "{:>6} {:>11.1} dB {:>16}\n",
            r.frac_bits,
            r.matvec_snr_db,
            r.accuracy
                .map(|a| format!("{:.2}%", 100.0 * a))
                .unwrap_or_else(|| "-".into()),
        ));
    }
    out.push_str(
        "\nshape: ~6 dB/bit; accuracy saturates by 12 bits — the paper's datapath choice.\n",
    );
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn snr_grows_and_accuracy_saturates() {
        let rows = sweep(&[6, 10, 12, 16], 128);
        assert!(rows[0].matvec_snr_db < rows.last().unwrap().matvec_snr_db);
        if let (Some(a12), Some(a16)) = (rows[2].accuracy, rows[3].accuracy) {
            assert!(
                (a16 - a12).abs() < 0.04,
                "accuracy must have saturated by 12 bits ({a12:.3} vs {a16:.3})"
            );
        }
        if let (Some(a6), Some(a12)) = (rows[0].accuracy, rows[2].accuracy) {
            assert!(a12 >= a6 - 0.02, "more bits must not hurt");
        }
    }
}
