//! Experiment P1 (supplementary): why 12 bits — SNR of the true
//! fixed-point FFT→∘→IFFT datapath (`circulant::fixed`) and end-to-end
//! behaviour of the native engine vs datapath width.
//!
//! The paper fixes the datapath at 12-bit without showing the sensitivity;
//! this experiment regenerates the design rationale from two directions:
//!
//! * the **simulated** leg (`sweep`): SNR of one circulant matvec through
//!   the software-modelled fixed-point FFT (`circulant::fixed::FixedFft`),
//!   plus trained-artifact accuracy under fake-quantized weights when
//!   `make artifacts` has run — SNR grows ~6 dB/bit and accuracy saturates
//!   at or before 12 bits;
//! * the **executed** leg (`executed_sweep`): registry models run through
//!   the real int16 block-floating-point MAC engine
//!   ([`crate::native::NativeModel::set_precision`], the same kernels
//!   `--precision fixed16` serves with), reporting the compression ×
//!   bit-width × fidelity surface — storage reduction, logits SNR against
//!   the f32 engine, and argmax agreement.

use crate::circulant::fixed::{float_circulant_matvec, snr_db, FixedFft};
use crate::circulant::Precision;
use crate::telemetry::Registry;
use crate::util::argmax_rows;
use crate::util::rng::SplitMix;

/// One row of the simulated precision sweep.
#[derive(Debug, Clone)]
pub struct PrecisionRow {
    pub frac_bits: u32,
    /// SNR of one k=128 circulant matvec through the fixed datapath
    pub matvec_snr_db: f64,
    /// native-engine accuracy at this fake-quant width (None when the
    /// parameter artifacts are unavailable)
    pub accuracy: Option<f64>,
}

/// One row of the executed-engine sweep: one registry model at one
/// datapath width, run through the int16 BFP MAC engine.
#[derive(Debug, Clone)]
pub struct ExecutedRow {
    pub model: &'static str,
    pub bits: u32,
    /// weight-storage reduction vs the dense f32 layer set at this width
    pub storage_reduction: f64,
    /// SNR of the fixed-engine logits against the f32 engine's
    pub logits_snr_db: f64,
    /// fraction of samples whose argmax matches the f32 engine
    pub agreement: f64,
}

/// Sweep datapath widths; `samples` test images per accuracy point.
pub fn sweep(widths: &[u32], samples: usize) -> Vec<PrecisionRow> {
    let mut rng = SplitMix::new(0xF1CED);
    let k = 128;
    let w: Vec<f32> = rng.normal_vec(k).iter().map(|v| v / k as f32).collect();
    let x = rng.normal_vec(k);
    let want = float_circulant_matvec(&w, &x);

    // accuracy leg: native engine on mnist_mlp_1 at each width
    let man = crate::runtime::Manifest::load(crate::runtime::Manifest::default_dir()).ok();
    let model = crate::models::by_name("mnist_mlp_1").unwrap();
    let ds = crate::data::dataset(model.dataset).unwrap();
    let (h, wd, c) = model.input;
    let (xs, ys) = crate::data::batch(&ds, 0, samples, true);

    widths
        .iter()
        .map(|&frac| {
            let got = FixedFft::new(k, frac).circulant_matvec(&w, &x);
            let accuracy = man.as_ref().and_then(|m| {
                let path = m.dir.join("params/mnist_mlp_1.npz");
                let native =
                    crate::native::NativeModel::load(&model, &path, Some(frac)).ok()?;
                let labels = native.classify(&xs, samples, h, wd, c);
                Some(
                    labels.iter().zip(&ys).filter(|(a, b)| a == b).count() as f64
                        / samples as f64,
                )
            });
            PrecisionRow { frac_bits: frac, matvec_snr_db: snr_db(&want, &got), accuracy }
        })
        .collect()
}

/// Deterministic seed for the executed sweep's random-init parameters (no
/// artifacts required — the same demo/CI mode `serve --synthetic` uses).
const EXEC_SWEEP_SEED: u64 = 0x16BF;

/// Run registry models through the **executed** int16 BFP engine at each
/// width: for every (model, bits) pair, forward `samples` dataset images
/// on the f32 engine and on the fixed engine and compare the logits.
pub fn executed_sweep(model_names: &[&str], bits_list: &[u32], samples: usize) -> Vec<ExecutedRow> {
    let mut rows = Vec::new();
    for name in model_names {
        let model = crate::models::by_name(name).expect("registry model");
        let ds = crate::data::dataset(model.dataset).unwrap();
        let (h, w, c) = model.input;
        let (xs, _) = crate::data::batch(&ds, 0, samples, true);
        let mut native = crate::native::NativeModel::init_random(&model, EXEC_SWEEP_SEED);
        let f32_logits = native.forward(&xs, samples, h, w, c);
        let classes = f32_logits.len() / samples;
        let f32_labels = argmax_rows(&f32_logits, classes);
        for &bits in bits_list {
            native.set_precision(Precision::Fixed16, Some(bits));
            let fixed = native.forward(&xs, samples, h, w, c);
            let labels = argmax_rows(&fixed, classes);
            let agreement = labels.iter().zip(&f32_labels).filter(|(a, b)| a == b).count()
                as f64
                / samples as f64;
            rows.push(ExecutedRow {
                model: model.name,
                bits,
                storage_reduction: model.storage_report(bits as u64).reduction,
                logits_snr_db: snr_db(&f32_logits, &fixed),
                agreement,
            });
        }
    }
    rows
}

/// Widths and models of the standard executed table (`circnn precision`).
pub const EXEC_WIDTHS: [u32; 5] = [8, 10, 12, 14, 16];
pub const EXEC_MODELS: [&str; 3] = ["mnist_mlp_1", "mnist_mlp_2", "svhn_cnn"];

/// Publish an executed sweep into a metrics registry as labelled gauges —
/// the experiments' accounting in the same exposition the server serves
/// (`circnn precision --metrics`).  Fractional quantities ride as
/// fixed-point integers: permille for agreement, ×10 for the dB / ratio
/// columns (the registry is integer-valued by design).
pub fn publish(rows: &[ExecutedRow], registry: &Registry) {
    for r in rows {
        let labels = [("model", r.model.to_string()), ("bits", r.bits.to_string())];
        registry
            .gauge_with("precision_agreement_permille", &labels)
            .set((1000.0 * r.agreement).round() as u64);
        registry
            .gauge_with("precision_logits_snr_db_x10", &labels)
            .set((10.0 * r.logits_snr_db).max(0.0).round() as u64);
        registry
            .gauge_with("precision_storage_reduction_x10", &labels)
            .set((10.0 * r.storage_reduction).round() as u64);
    }
}

pub fn render() -> String {
    let rows = sweep(&[6, 8, 10, 12, 14, 16], 256);
    let mut out = String::new();
    out.push_str("precision sweep: fixed-point datapath SNR and end-to-end accuracy\n");
    out.push_str(&format!(
        "{:>6} {:>14} {:>16}\n",
        "bits", "matvec SNR", "accuracy (MLP-1)"
    ));
    out.push_str(&"-".repeat(40));
    out.push('\n');
    for r in &rows {
        out.push_str(&format!(
            "{:>6} {:>11.1} dB {:>16}\n",
            r.frac_bits,
            r.matvec_snr_db,
            r.accuracy
                .map(|a| format!("{:.2}%", 100.0 * a))
                .unwrap_or_else(|| "-".into()),
        ));
    }
    out.push_str(
        "\nshape: ~6 dB/bit; accuracy saturates by 12 bits — the paper's datapath choice.\n",
    );

    out.push_str("\nexecuted int16 BFP engine: compression x bits x fidelity (vs f32 engine)\n");
    out.push_str(&format!(
        "{:>14} {:>5} {:>9} {:>12} {:>10}\n",
        "model", "bits", "storage", "logits SNR", "agreement"
    ));
    out.push_str(&"-".repeat(54));
    out.push('\n');
    for r in &executed_sweep(&EXEC_MODELS, &EXEC_WIDTHS, 64) {
        out.push_str(&format!(
            "{:>14} {:>5} {:>8.1}x {:>9.1} dB {:>9.1}%\n",
            r.model,
            r.bits,
            r.storage_reduction,
            r.logits_snr_db,
            100.0 * r.agreement,
        ));
    }
    out.push_str(
        "\nexecuted path: every block-circulant layer runs the i16 MAC kernels \
         (`--precision fixed16`); 12-16 bits keep argmax agreement at ~100%.\n",
    );
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn snr_grows_and_accuracy_saturates() {
        let rows = sweep(&[6, 10, 12, 16], 128);
        assert!(rows[0].matvec_snr_db < rows.last().unwrap().matvec_snr_db);
        if let (Some(a12), Some(a16)) = (rows[2].accuracy, rows[3].accuracy) {
            assert!(
                (a16 - a12).abs() < 0.04,
                "accuracy must have saturated by 12 bits ({a12:.3} vs {a16:.3})"
            );
        }
        if let (Some(a6), Some(a12)) = (rows[0].accuracy, rows[2].accuracy) {
            assert!(a12 >= a6 - 0.02, "more bits must not hurt");
        }
    }

    #[test]
    fn publish_exposes_the_sweep_as_labelled_gauges() {
        let rows = vec![
            ExecutedRow {
                model: "mnist_mlp_1",
                bits: 12,
                storage_reduction: 21.3,
                logits_snr_db: 47.8,
                agreement: 0.997,
            },
            ExecutedRow {
                model: "mnist_mlp_1",
                bits: 8,
                storage_reduction: 32.0,
                logits_snr_db: 18.2,
                agreement: 0.62,
            },
        ];
        let reg = Registry::new();
        publish(&rows, &reg);
        let labels = [("model", "mnist_mlp_1".to_string()), ("bits", "12".to_string())];
        assert_eq!(reg.gauge_with("precision_agreement_permille", &labels).get(), 997);
        assert_eq!(reg.gauge_with("precision_logits_snr_db_x10", &labels).get(), 478);
        assert_eq!(reg.gauge_with("precision_storage_reduction_x10", &labels).get(), 213);
        let text = reg.render_text();
        assert!(
            text.contains("precision_agreement_permille{model=\"mnist_mlp_1\",bits=\"8\"} 620"),
            "{text}"
        );
    }

    /// Golden pin of the executed table: shape (models x widths, width-major
    /// within each model), SNR non-decreasing in datapath width, storage
    /// reduction decreasing in width, and near-perfect argmax agreement at
    /// the top width.
    #[test]
    fn executed_sweep_shape_snr_monotone_and_agreement() {
        let bits = [8, 12, 16];
        let models = ["mnist_mlp_1", "svhn_cnn"];
        let rows = executed_sweep(&models, &bits, 32);
        assert_eq!(rows.len(), models.len() * bits.len());
        for (m, chunk) in models.iter().zip(rows.chunks(bits.len())) {
            for (r, &b) in chunk.iter().zip(bits.iter()) {
                assert_eq!(r.model, *m);
                assert_eq!(r.bits, b);
                assert!((0.0..=1.0).contains(&r.agreement));
            }
            for w in chunk.windows(2) {
                assert!(
                    w[1].logits_snr_db >= w[0].logits_snr_db - 3.0,
                    "{m}: SNR must grow with width ({} dB @ {} bits vs {} dB @ {} bits)",
                    w[0].logits_snr_db,
                    w[0].bits,
                    w[1].logits_snr_db,
                    w[1].bits
                );
                assert!(
                    w[1].storage_reduction < w[0].storage_reduction,
                    "{m}: wider mantissas must store more"
                );
            }
            let (lo, hi) = (chunk.first().unwrap(), chunk.last().unwrap());
            assert!(
                hi.logits_snr_db > lo.logits_snr_db + 10.0,
                "{m}: 8->16 bits must buy substantial SNR ({} -> {} dB)",
                lo.logits_snr_db,
                hi.logits_snr_db
            );
            assert!(
                hi.logits_snr_db > 35.0,
                "{m}: 16-bit executed path too noisy ({} dB)",
                hi.logits_snr_db
            );
            assert!(
                hi.agreement >= 0.9,
                "{m}: 16-bit argmax agreement {} too low",
                hi.agreement
            );
        }
    }
}
