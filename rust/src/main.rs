//! `circnn` — the CirCNN-Flow command-line launcher.
//!
//! Subcommands map one-to-one onto the paper's evaluation artifacts
//! (DESIGN.md §6) plus the serving/training drivers:
//!
//! ```text
//! circnn table1                 regenerate Table 1 (+ headline ratios)
//! circnn fig3                   regenerate Fig. 3 (storage reduction)
//! circnn fig6                   regenerate Fig. 6 (GOPS vs GOPS/W)
//! circnn analog                 analog / emerging-device comparison (A1)
//! circnn ablations              AB1-AB3 design-choice ablations
//! circnn sweep                  O(n log n) vs O(n^2) crossover (S1)
//! circnn simulate [flags]       one FPGA-sim design point
//! circnn infer [flags]          run images through a compiled artifact
//! circnn serve [flags]          serving demo: batched requests + metrics
//!                               (--tcp serves the framed protocol of
//!                               docs/PROTOCOL.md over a TCP listener)
//! circnn loadgen [flags]        open-loop TCP load harness: Poisson or
//!                               bursty arrivals, warm/cold connections,
//!                               registry-derived latency percentiles
//! circnn train-demo [flags]     train natively in the spectral domain
//!                               (loss curve; PJRT artifact driver with
//!                               --features pjrt)
//! circnn models                 list registry models + accounting
//! circnn lint [--root DIR]      repo-invariant static analysis (CI-blocking)
//! ```
//!
//! Arguments are parsed by hand (`clap` is outside the offline dependency
//! closure); every flag has the form `--key value` or `--flag`.

use std::collections::HashMap;
use std::time::Instant;

use circnn::baselines::dense_fpga;
use circnn::coordinator::{BatchPolicy, EngineKind, Server, ServerConfig};
use circnn::data;
use circnn::experiments::{ablations, analog, complexity, fig3, fig6, table1, try_manifest};
use circnn::fpga::device;
use circnn::fpga::report::DesignReport;
use circnn::fpga::schedule::ScheduleConfig;
use circnn::models;
#[cfg(feature = "pjrt")]
use circnn::runtime::engine::{argmax_rows, literal_f32, literal_i32, Engine};
use circnn::runtime::manifest::Manifest;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let cmd = args.first().map(String::as_str).unwrap_or("help");
    let flags = parse_flags(&args[args.len().min(1)..]);
    let result = match cmd {
        "table1" => cmd_table1(),
        "fig3" => cmd_fig3(),
        "fig6" => cmd_fig6(),
        "analog" => cmd_analog(),
        "ablations" => cmd_ablations(),
        "sweep" => cmd_sweep(&flags),
        "codesign" => cmd_codesign(&flags),
        "precision" => cmd_precision(&flags),
        "simulate" => cmd_simulate(&flags),
        "infer" => cmd_infer(&flags),
        "serve" => cmd_serve(&flags),
        "loadgen" => cmd_loadgen(&flags),
        "train-demo" => cmd_train_demo(&flags),
        "models" => cmd_models(),
        "lint" => cmd_lint(&flags),
        "help" | "--help" | "-h" => {
            print!("{}", HELP);
            Ok(())
        }
        other => {
            eprintln!("unknown command {other:?}\n{HELP}");
            std::process::exit(2);
        }
    };
    if let Err(err) = result {
        eprintln!("error: {err:#}");
        std::process::exit(1);
    }
}

const HELP: &str = "\
circnn — CirCNN-Flow: block-circulant DNN co-design framework (AAAI'18 repro)

experiments:
  table1 | fig3 | fig6 | analog | ablations | sweep | precision
  precision [--metrics]   --metrics also prints the executed sweep as a
                          metrics-registry text exposition (labelled gauges)

co-optimization (Fig. 5):
  codesign  --model NAME [--device cyclone_v|kintex7] [--min-accuracy 0.95]

simulator:
  simulate --model NAME [--device cyclone_v|kintex7] [--batch N]
           [--no-decouple] [--full-spectrum] [--no-interleave] [--dense]
           [--timeline]   (hierarchical-controller event trace, Fig. 4)

runtime (infer/serve need `make artifacts`; PJRT paths need `--features pjrt`):
  infer      --model NAME [--count N] [--batch 1|64] [--pallas]
             [--engine native]   (pure-Rust, no PJRT)
  serve      [--model NAME] [--requests N] [--clients N] [--max-batch N]
             [--engine native|pipeline] [--depth N] [--synthetic]
             [--precision f32|fixed16] [--trace] [--trace-dump PATH]
             [--tcp] [--tcp-addr HOST:PORT] [--max-conns N]
             [--max-inflight N] [--metrics-addr HOST:PORT]
             --engine native:   serve on the pure-Rust substrate
             --engine pipeline: deep-pipelined serving — per-layer stage
                                workers, multiple batches in flight
                                (--depth bounds them), prints the measured
                                stage-occupancy timeline
             --synthetic:       no artifacts needed — registry models with
                                deterministic random-init params (demo/CI)
             --precision fixed16: run block-circulant layers through the
                                executed int16 BFP MAC engine at the
                                manifest's fixed_bits width (native/
                                pipeline engines; see `circnn precision`)
             --trace:           per-request span tracing (admission ->
                                queue wait -> batch release -> stage hops
                                -> reply); prints the span waterfall after
                                the run (CIRCNN_TRACE=1 does the same)
             --trace-dump PATH: write the full telemetry document
                                ({\"metrics\": ..., \"spans\": ...}) as JSON
             --tcp:             also serve the framed wire protocol
                                (docs/PROTOCOL.md) on --tcp-addr (default
                                127.0.0.1:0 = ephemeral port); the demo
                                clients then connect over TCP.  With
                                --requests 0 no demo clients run: the
                                server serves external traffic until
                                stdin closes (EOF), then drains.
                                --max-conns
                                caps concurrent connections, --max-inflight
                                caps unanswered requests per connection;
                                both shed with explicit Overloaded replies
                                (see docs/OPERATIONS.md)
             --metrics-addr:    live scrape endpoint (HTTP/1.0, std::net
                                only): GET /metrics (Prometheus text),
                                /metrics.json (registry JSON + the
                                snapshot time series), /trace.json (span
                                ring incl. truncation count), /healthz
                                (503 once draining); port 0 = ephemeral.
                                The same documents ride the wire
                                protocol's admin frames, so `--tcp` alone
                                is scrapable too.  A background ticker
                                samples queue depth / in-flight / open
                                connections / stage busy permille every
                                CIRCNN_SNAP_MS ms (default 100; 0 turns
                                the ticker off) into a bounded ring with
                                *_watermark gauges, and the run report
                                ends with one sparkline per series
  loadgen    [--addr HOST:PORT | --synthetic] [--model NAME] [--requests N]
             [--rate R] [--process poisson|bursty] [--burst N]
             [--connections N] [--cold N] [--seed N]
             [--engine native|pipeline] [--max-batch N] [--bench-json PATH]
             [--record PATH] [--replay PATH]
             [--slo-p99-us N] [--slo-key latency|sched_lag]
             open-loop load harness for the TCP front-end (arrivals follow
             a fixed-seed schedule, never the server's reply rate).
             --addr drives an already-running `serve --tcp`; --synthetic
             (default) starts its own synthetic server, also replays the
             identical schedule in-process, and derives
             tcp_vs_inproc_ratio_* alongside serve_tcp_latency_p*_us_*;
             --bench-json merges those keys into BENCH_circulant.json
             (informational keys, never CI-gated), plus
             scrape_overhead_ratio_* from one extra schedule run under a
             hammering scraper.
             --record writes the realized schedule (integer-us offsets,
             sample + slot assignment) as JSON; --replay re-drives a
             recorded schedule verbatim — same payloads, same slots —
             instead of deriving one from the flags.
             --slo-p99-us exits non-zero when the measured p99 (of
             --slo-key, default "latency"; also "sched_lag") exceeds the
             budget — the CI latency gate.
             full walkthrough: docs/OPERATIONS.md
  train-demo [--model NAME] [--steps N] [--batch N] [--lr F] [--seed N]
             default build: native spectral-domain trainer (O(n log n)
             backprop, no artifacts needed); with `--features pjrt` it
             drives the AOT train-step artifacts instead, unless
             --engine native is passed

misc:
  models     list the registry with accounting
  lint       [--root DIR] repo-invariant static analysis over the crate's
             own sources: SAFETY comments + pinned SIMD oracles, dead
             oracle twins, the CIRCNN_* knob registry, the bench-key
             gating contract, request-path unwrap/channel hygiene
             (coordinator/pipeline/net), the metric naming contract
             (literal snake_case names), and docs freshness (every
             metric + knob documented in docs/OPERATIONS.md);
             prints `file:line: [rule] message` and exits non-zero on
             any violation (the CI lint job runs exactly this)
";

fn parse_flags(args: &[String]) -> HashMap<String, String> {
    let mut flags = HashMap::new();
    let mut i = 0;
    while i < args.len() {
        if let Some(key) = args[i].strip_prefix("--") {
            let val = args
                .get(i + 1)
                .filter(|v| !v.starts_with("--"))
                .cloned();
            match val {
                Some(v) => {
                    flags.insert(key.to_string(), v);
                    i += 2;
                }
                None => {
                    flags.insert(key.to_string(), "true".to_string());
                    i += 1;
                }
            }
        } else {
            i += 1;
        }
    }
    flags
}

fn flag_usize(flags: &HashMap<String, String>, key: &str, default: usize) -> usize {
    flags
        .get(key)
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

fn flag_bool(flags: &HashMap<String, String>, key: &str) -> bool {
    flags.get(key).map(|v| v == "true").unwrap_or(false)
}

// ---------------------------------------------------------------- commands

fn cmd_table1() -> anyhow::Result<()> {
    let man = try_manifest();
    if man.is_none() {
        eprintln!("note: no artifacts/manifest.json — using paper accuracies");
    }
    print!("{}", table1::render(man.as_ref()));
    Ok(())
}

fn cmd_fig3() -> anyhow::Result<()> {
    print!("{}", fig3::render(try_manifest().as_ref()));
    Ok(())
}

fn cmd_fig6() -> anyhow::Result<()> {
    print!("{}", fig6::render());
    Ok(())
}

fn cmd_analog() -> anyhow::Result<()> {
    print!("{}", analog::render());
    Ok(())
}

fn cmd_ablations() -> anyhow::Result<()> {
    print!("{}", ablations::render());
    Ok(())
}

/// The precision experiment (P1); `--metrics` additionally re-publishes
/// the executed sweep into a metrics registry and prints the text
/// exposition — the experiments' accounting in the same format the server
/// serves.
fn cmd_precision(flags: &HashMap<String, String>) -> anyhow::Result<()> {
    use circnn::experiments::precision;
    print!("{}", precision::render());
    if flag_bool(flags, "metrics") {
        let rows = precision::executed_sweep(&precision::EXEC_MODELS, &precision::EXEC_WIDTHS, 64);
        let registry = circnn::telemetry::Registry::new();
        precision::publish(&rows, &registry);
        println!("\n# executed sweep as a registry exposition");
        print!("{}", registry.render_text());
    }
    Ok(())
}

fn cmd_sweep(flags: &HashMap<String, String>) -> anyhow::Result<()> {
    let k = flag_usize(flags, "k", 64);
    let reps = flag_usize(flags, "reps", 9);
    let ns = [256, 512, 1024, 2048, 4096];
    let pts = complexity::sweep(&ns, k, reps);
    print!("{}", complexity::render(&pts));
    Ok(())
}

fn cmd_codesign(flags: &HashMap<String, String>) -> anyhow::Result<()> {
    let model_name = flags.get("model").map(String::as_str).unwrap_or("mnist_mlp_1");
    let model = models::by_name(model_name)
        .ok_or_else(|| anyhow::anyhow!("unknown model {model_name:?}"))?;
    let dev_name = flags.get("device").map(String::as_str).unwrap_or("cyclone_v");
    let dev = device::by_name(dev_name)
        .ok_or_else(|| anyhow::anyhow!("unknown device {dev_name:?}"))?;
    let min_acc: f64 = flags
        .get("min-accuracy")
        .and_then(|v| v.parse().ok())
        .unwrap_or(0.95);
    let am = circnn::codesign::AccuracyModel::from_artifacts(&Manifest::default_dir());
    let res = circnn::codesign::optimize(
        &model,
        &dev,
        &circnn::codesign::SearchSpace::default(),
        &am,
        min_acc,
    );
    print!("{}", circnn::codesign::render(&model, &dev, &res));
    Ok(())
}

fn cmd_simulate(flags: &HashMap<String, String>) -> anyhow::Result<()> {
    let model_name = flags.get("model").map(String::as_str).unwrap_or("mnist_mlp_1");
    let model = models::by_name(model_name)
        .ok_or_else(|| anyhow::anyhow!("unknown model {model_name:?} (see `circnn models`)"))?;
    let dev_name = flags.get("device").map(String::as_str).unwrap_or("cyclone_v");
    let dev = device::by_name(dev_name)
        .ok_or_else(|| anyhow::anyhow!("unknown device {dev_name:?}"))?;
    let cfg = ScheduleConfig {
        batch: flag_usize(flags, "batch", 64) as u64,
        decouple: !flag_bool(flags, "no-decouple"),
        half_spectrum: !flag_bool(flags, "full-spectrum"),
        interleave: !flag_bool(flags, "no-interleave"),
        in_place: true,
        bits: flag_usize(flags, "bits", 12) as u64,
    };
    let rep = DesignReport::build(&model, &dev, &cfg);
    if flag_bool(flags, "timeline") {
        print!("{}", circnn::fpga::controller::render_timeline(&model, &dev, &cfg, 96));
        return Ok(());
    }
    println!("model        {model_name}");
    println!("device       {} @ {:.0} MHz", dev.name, dev.fmax_hz / 1e6);
    println!("config       {cfg:?}");
    println!("cycles/batch {}", rep.sched.cycles_per_batch);
    println!("phases       {:?}", rep.sched.phase);
    println!("kFPS         {:.3}", rep.kfps);
    println!("kFPS/W       {:.3}", rep.kfps_per_w);
    println!("ns/image     {:.2}", rep.ns_per_image);
    println!("utilization  {:.1}%", rep.utilization * 100.0);
    println!("eq GOPS      {:.1}", rep.equivalent_gops);
    println!("eq GOPS/W    {:.1}", rep.equivalent_gops_per_w);
    println!(
        "BRAM         {} / {} bytes ({})",
        rep.bram_used,
        rep.bram_capacity,
        if rep.sched.memory.fits { "fits" } else { "OVERFLOW" }
    );
    if flag_bool(flags, "dense") {
        let d = dense_fpga::dense_design(&model, &dev, &cfg);
        println!(
            "dense twin   {:.3} kFPS, {:.3} kFPS/W, on-chip: {}",
            d.kfps, d.kfps_per_w, d.fits_on_chip
        );
        println!("circ/dense   {:.1}x throughput", rep.kfps / d.kfps);
    }
    Ok(())
}

fn cmd_models() -> anyhow::Result<()> {
    println!(
        "{:<14} {:<9} {:>12} {:>12} {:>9} {:>14} {:>12}",
        "Model", "Dataset", "DenseParams", "CircParams", "Storage", "eqOps/img", "PaperAcc"
    );
    println!("{}", "-".repeat(88));
    for m in models::registry() {
        let acc = m.accounting();
        let dp: u64 = acc.iter().map(|r| r.dense_params).sum();
        let cp: u64 = acc.iter().map(|r| r.circ_params).sum();
        println!(
            "{:<14} {:<9} {:>12} {:>12} {:>8.1}x {:>14} {:>11.2}%",
            m.name,
            m.dataset,
            dp,
            cp,
            m.storage_report(12).reduction,
            m.equivalent_ops_per_image(),
            m.paper_accuracy
        );
    }
    Ok(())
}

/// Repo-invariant static analysis over the crate's own sources
/// ([`circnn::lint`]). Non-zero exit on any violation; CI runs this as a
/// blocking job.
fn cmd_lint(flags: &HashMap<String, String>) -> anyhow::Result<()> {
    let root = match flags.get("root") {
        Some(dir) => std::path::PathBuf::from(dir),
        None => lint_root()?,
    };
    let report = circnn::lint::run(&root)?;
    for d in &report.diagnostics {
        eprintln!("{d}");
    }
    if !report.is_clean() {
        anyhow::bail!("{} lint violation(s)", report.diagnostics.len());
    }
    println!(
        "lint: clean ({} files scanned under {})",
        report.files_scanned,
        root.display()
    );
    Ok(())
}

/// Walk up from the current directory, preferring an ancestor that holds
/// `rust/src/lib.rs` (the repo root — keeps `.github/workflows/` in scope
/// when invoked from `rust/`) over one that only holds `src/lib.rs`.
fn lint_root() -> anyhow::Result<std::path::PathBuf> {
    let cwd = std::env::current_dir()?;
    let mut crate_root = None;
    for dir in cwd.ancestors() {
        if dir.join("rust").join("src").join("lib.rs").is_file() {
            return Ok(dir.to_path_buf());
        }
        if crate_root.is_none() && dir.join("src").join("lib.rs").is_file() {
            crate_root = Some(dir.to_path_buf());
        }
    }
    crate_root
        .ok_or_else(|| anyhow::anyhow!("no src/lib.rs above {} (pass --root)", cwd.display()))
}

fn cmd_infer(flags: &HashMap<String, String>) -> anyhow::Result<()> {
    let model_name = flags.get("model").map(String::as_str).unwrap_or("mnist_mlp_1");
    let count = flag_usize(flags, "count", 256);
    let batch = flag_usize(flags, "batch", 64);
    if flags.get("engine").map(String::as_str) == Some("native") {
        return cmd_infer_native(model_name, count, batch);
    }
    cmd_infer_pjrt(flags, model_name, count, batch)
}

/// Binary built without PJRT: only the native substrate can execute.
#[cfg(not(feature = "pjrt"))]
fn cmd_infer_pjrt(
    _flags: &HashMap<String, String>,
    model_name: &str,
    count: usize,
    batch: usize,
) -> anyhow::Result<()> {
    eprintln!("note: built without the `pjrt` feature; using --engine native");
    cmd_infer_native(model_name, count, batch)
}

#[cfg(feature = "pjrt")]
fn cmd_infer_pjrt(
    flags: &HashMap<String, String>,
    model_name: &str,
    count: usize,
    batch: usize,
) -> anyhow::Result<()> {
    let man = Manifest::load(Manifest::default_dir())?;
    let entry = man.model(model_name)?;
    let arts = if flag_bool(flags, "pallas") {
        &entry.artifacts_pallas
    } else {
        &entry.artifacts
    };
    let art = arts
        .iter()
        .find(|a| a.batch == batch)
        .ok_or_else(|| anyhow::anyhow!("no batch-{batch} artifact for {model_name}"))?;
    let ds = data::dataset(&entry.dataset)
        .ok_or_else(|| anyhow::anyhow!("unknown dataset {}", entry.dataset))?;

    let engine = Engine::cpu()?;
    let exe = engine.load(man.path_of(&art.file))?;
    println!("loaded {} on {}", art.file, engine.platform());

    let mut correct = 0usize;
    let mut done = 0usize;
    let t0 = Instant::now();
    while done < count {
        let n = batch.min(count - done);
        let (mut xs, ys) = data::batch(&ds, done as u64, n, true);
        xs.resize(batch * ds.pixels(), 0.0); // pad the tail batch
        let lit = literal_f32(&xs, &art.input_shape)?;
        let out = exe.run1(&[lit])?;
        let logits = out.to_vec::<f32>()?;
        let preds = argmax_rows(&logits, 10);
        correct += preds
            .iter()
            .zip(&ys)
            .filter(|(p, y)| *p == *y)
            .count();
        done += n;
    }
    let dt = t0.elapsed();
    println!(
        "{done} images in {:.3}s -> {:.1} img/s, accuracy {:.2}% \
         (manifest: {:.2}%, paper on real data: {:.2}%)",
        dt.as_secs_f64(),
        done as f64 / dt.as_secs_f64(),
        100.0 * correct as f64 / done as f64,
        100.0 * entry.accuracy.circulant_12bit,
        entry.paper_accuracy
    );
    Ok(())
}

/// Pure-Rust inference: no PJRT, no artifacts beyond the parameter archive
/// — the native block-circulant substrate (`circnn::native`).
fn cmd_infer_native(model_name: &str, count: usize, batch: usize) -> anyhow::Result<()> {
    let model = models::by_name(model_name)
        .ok_or_else(|| anyhow::anyhow!("unknown model {model_name:?}"))?;
    let man = Manifest::load(Manifest::default_dir())?;
    let entry = man.model(model_name)?;
    let path = man.dir.join("params").join(format!("{model_name}.npz"));
    let native = circnn::native::NativeModel::load(&model, &path, Some(12))?;
    let ds = data::dataset(model.dataset).unwrap();
    let (h, w, c) = model.input;
    println!("loaded {} (native block-circulant engine, 12-bit)", path.display());

    let mut correct = 0usize;
    let mut done = 0usize;
    let t0 = Instant::now();
    while done < count {
        let n = batch.min(count - done);
        let (xs, ys) = data::batch(&ds, done as u64, n, true);
        let preds = native.classify(&xs, n, h, w, c);
        correct += preds.iter().zip(&ys).filter(|(p, y)| *p == *y).count();
        done += n;
    }
    let dt = t0.elapsed();
    println!(
        "{done} images in {:.3}s -> {:.1} img/s, accuracy {:.2}% (manifest 12-bit: {:.2}%)",
        dt.as_secs_f64(),
        done as f64 / dt.as_secs_f64(),
        100.0 * correct as f64 / done as f64,
        100.0 * entry.accuracy.circulant_12bit
    );
    Ok(())
}

fn cmd_serve(flags: &HashMap<String, String>) -> anyhow::Result<()> {
    let model = flags
        .get("model")
        .cloned()
        .unwrap_or_else(|| "mnist_mlp_1".to_string());
    let requests = flag_usize(flags, "requests", 2048);
    let clients = flag_usize(flags, "clients", 8);
    let policy = BatchPolicy {
        max_batch: flag_usize(flags, "max-batch", 64),
        ..BatchPolicy::default()
    };
    let engine = match flags.get("engine").map(String::as_str) {
        Some("native") => EngineKind::Native,
        Some("pipeline") => EngineKind::Pipeline,
        _ => EngineKind::Auto,
    };
    let precision = match flags.get("precision") {
        Some(s) => circnn::circulant::Precision::parse(s)
            .ok_or_else(|| anyhow::anyhow!("unknown precision {s:?} (f32|fixed16)"))?,
        None => circnn::circulant::Precision::F32,
    };
    // --synthetic: registry-only serving, no artifacts on disk (demo/CI
    // mode — deterministic random-init parameters stand in for missing
    // archives); the multi-batch pipeline demo runs on exactly this
    let synthetic = flag_bool(flags, "synthetic");
    let man = if synthetic {
        // serve only the requested model: the full registry would build
        // execution state (and, on the pipeline engine, stage-worker
        // pools) for five models this demo never queries
        let mut man = Manifest::synthetic();
        man.models.retain(|m| m.name == model);
        man
    } else {
        Manifest::load(Manifest::default_dir())?
    };
    let ds = data::dataset(&man.model(&model)?.dataset).unwrap();
    let server = Server::start_with_manifest(
        man,
        ServerConfig {
            policy,
            use_pallas: flag_bool(flags, "pallas"),
            engine,
            depth: flags.get("depth").and_then(|v| v.parse().ok()),
            init_random_fallback: synthetic,
            precision,
            trace: flag_bool(flags, "trace") || flags.contains_key("trace-dump"),
            ..ServerConfig::default()
        },
    )?;
    if precision != circnn::circulant::Precision::F32 {
        println!("precision: {} (int16 BFP spectral MAC engine)", precision.name());
    }

    // the live observability plane: a background snapshot ticker
    // (CIRCNN_SNAP_MS; 0 disables) and, with --metrics-addr, the HTTP
    // scrape responder.  Both hold Frontend clones, which keep the
    // executor's intake open — all of it is torn down explicitly before
    // the final drain below.
    let frontend = server
        .frontend()
        .ok_or_else(|| anyhow::anyhow!("server is already draining"))?;
    let draining = std::sync::Arc::new(std::sync::atomic::AtomicBool::new(false));
    let snap_ms: u64 = circnn::circulant::sched::env_parse(
        "CIRCNN_SNAP_MS",
        circnn::telemetry::snapshot::DEFAULT_SNAP_MS,
    );
    let snap = if snap_ms > 0 {
        let ring = circnn::telemetry::SnapshotRing::new(
            frontend.metrics().registry(),
            circnn::telemetry::snapshot::DEFAULT_SNAP_CAP,
            snap_ms,
        );
        let probe = frontend.metrics().clone();
        let sampler = circnn::telemetry::Sampler::start(
            ring.clone(),
            Box::new(move || probe.snapshot_sample()),
            std::time::Duration::from_millis(snap_ms),
        );
        Some((ring, sampler))
    } else {
        None
    };
    let scrape = match flags.get("metrics-addr") {
        Some(addr) => {
            let sources = circnn::net::ScrapeSources::from_frontend(
                &frontend,
                snap.as_ref().map(|(ring, _)| ring.clone()),
                draining.clone(),
            );
            let http = circnn::net::MetricsHttp::start(addr, sources)?;
            println!(
                "metrics scrape on http://{}  (/metrics /metrics.json /trace.json /healthz)",
                http.local_addr()
            );
            Some(http)
        }
        None => None,
    };

    let t0 = Instant::now();
    // --tcp: wrap the coordinator in the TCP front-end and run the demo
    // clients over the wire protocol instead of in-process calls
    let server = if flag_bool(flags, "tcp") {
        let net_cfg = circnn::net::NetConfig {
            addr: flags
                .get("tcp-addr")
                .cloned()
                .unwrap_or_else(|| "127.0.0.1:0".to_string()),
            max_connections: flag_usize(flags, "max-conns", 256),
            max_inflight: flag_usize(flags, "max-inflight", 1024),
            ..circnn::net::NetConfig::default()
        };
        let tcp = circnn::net::TcpServer::start(server, net_cfg)?;
        let addr = tcp.local_addr();
        println!("tcp front-end listening on {addr} (protocol: docs/PROTOCOL.md)");
        if requests == 0 {
            // no demo clients: serve external traffic (`circnn loadgen
            // --addr`, scrapers) until stdin closes, then drain — a
            // pipe-friendly lifetime for backgrounded/CI runs
            println!("serving external traffic until stdin closes (EOF)");
            let mut sink = Vec::new();
            let _ = std::io::Read::read_to_end(&mut std::io::stdin().lock(), &mut sink);
        } else {
            std::thread::scope(|scope| {
                for c in 0..clients {
                    let model = &model;
                    let ds = &ds;
                    scope.spawn(move || {
                        let mut client = match circnn::net::Client::connect(addr) {
                            Ok(cl) => cl,
                            Err(e) => {
                                eprintln!("client {c}: connect: {e}");
                                return;
                            }
                        };
                        let per = requests / clients;
                        for i in 0..per {
                            let (img, _) = data::sample(ds, (c * per + i) as u64);
                            let dims = [img.len() as u32];
                            match client.infer(model, &dims, img) {
                                Ok(_) => {}
                                Err(e) => {
                                    eprintln!("client {c}: {e}");
                                    return;
                                }
                            }
                        }
                    });
                }
            });
        }
        // graceful drain: stop accepting, answer everything admitted,
        // then hand the coordinator back for the report below
        tcp.shutdown()
    } else {
        std::thread::scope(|scope| {
            for c in 0..clients {
                let server = &server;
                let model = &model;
                scope.spawn(move || {
                    let per = requests / clients;
                    for i in 0..per {
                        let (img, _) = data::sample(&ds, (c * per + i) as u64);
                        match server.infer(model, &img) {
                            Ok(_) | Err(circnn::coordinator::InferError::Rejected) => {}
                            Err(e) => eprintln!("client {c}: {e}"),
                        }
                    }
                });
            }
        });
        server
    };
    let dt = t0.elapsed();
    // the run is over: flip health to draining, stop the ticker, and tear
    // the scrape plane down so its Frontend clones release the intake
    // (the executor cannot drain while they live)
    draining.store(true, std::sync::atomic::Ordering::SeqCst);
    let snap_status = snap.map(|(ring, sampler)| {
        drop(sampler); // join the ticker before the final render
        ring.render_status(96)
    });
    drop(scrape);
    drop(frontend);
    println!("served {requests} requests from {clients} clients in {:.3}s", dt.as_secs_f64());
    println!("throughput: {:.1} req/s", requests as f64 / dt.as_secs_f64());
    println!("{}", server.metrics().summary());
    if let Some(status) = &snap_status {
        print!("{status}");
    }
    // the multi-batch demo payoff: the measured stage-occupancy timeline
    // of the served model — the serving-side Fig. 4 (cf. `simulate
    // --timeline`, which predicts the same picture from the cycle model)
    for (name, stats) in server.metrics().pipelines() {
        if name == model {
            print!("{}", circnn::pipeline::timeline::render(&stats, 96));
        }
    }
    // the per-request twin of the stage timeline: the span waterfall
    // (queue wait / execution / stage hops per request)
    if let Some(waterfall) = server.trace_waterfall(96) {
        print!("{waterfall}");
    }
    if let Some(path) = flags.get("trace-dump") {
        std::fs::write(path, server.telemetry_json())?;
        println!("telemetry dump written to {path}");
    }
    server.shutdown();
    Ok(())
}

/// `circnn loadgen` — drive a TCP front-end with the open-loop harness
/// ([`circnn::net::loadgen`]).  With `--addr` it targets an external
/// `serve --tcp`; by default (`--synthetic`) it starts its own synthetic
/// server, replays the identical fixed-seed schedule in-process, and
/// derives the `tcp_vs_inproc_ratio_*` / `serve_tcp_latency_p*_us_*`
/// bench keys (informational; never CI-gated).
fn cmd_loadgen(flags: &HashMap<String, String>) -> anyhow::Result<()> {
    use circnn::net::{loadgen, Arrival, LoadConfig, NetConfig, TcpServer};

    // --replay: the record file defines the whole run (config + realized
    // schedule); otherwise the schedule derives from the flags' seed
    let (cfg, sends) = match flags.get("replay") {
        Some(path) => {
            let text = std::fs::read_to_string(path)?;
            let (cfg, sends) = loadgen::parse_record(&text).map_err(|e| anyhow::anyhow!(e))?;
            println!("replaying {} recorded sends from {path}", sends.len());
            (cfg, sends)
        }
        None => {
            let model = flags
                .get("model")
                .cloned()
                .unwrap_or_else(|| "mnist_mlp_1".to_string());
            let entry = models::by_name(&model)
                .ok_or_else(|| anyhow::anyhow!("unknown model {model:?} (see `circnn models`)"))?;
            let (h, w, c) = entry.input;
            let arrival = match flags.get("process").map(String::as_str) {
                Some("bursty") => Arrival::Bursty { burst: flag_usize(flags, "burst", 8) },
                Some("poisson") | None => Arrival::Poisson,
                Some(other) => anyhow::bail!("unknown arrival process {other:?} (poisson|bursty)"),
            };
            let cfg = LoadConfig {
                model,
                dims: vec![(h * w * c) as u32],
                requests: flag_usize(flags, "requests", 512),
                rate: flags.get("rate").and_then(|v| v.parse().ok()).unwrap_or(500.0),
                arrival,
                warm: flag_usize(flags, "connections", 4),
                cold: flag_usize(flags, "cold", 0),
                seed: flag_usize(flags, "seed", 0x10AD) as u64,
            };
            let sends = loadgen::schedule(&cfg);
            (cfg, sends)
        }
    };
    let model = cfg.model.clone();
    let entry = models::by_name(&model)
        .ok_or_else(|| anyhow::anyhow!("unknown model {model:?} (see `circnn models`)"))?;
    let ds = data::dataset(entry.dataset)
        .ok_or_else(|| anyhow::anyhow!("unknown dataset {}", entry.dataset))?;
    let sample = |i: u64| data::sample(&ds, i).0;
    if let Some(path) = flags.get("record") {
        // integer-µs offsets: a replay of this file is bit-for-bit the
        // same offered stream, payloads included
        std::fs::write(path, loadgen::record_json(&cfg, &sends))?;
        println!("recorded {} sends to {path}", sends.len());
    }
    println!(
        "loadgen: {} requests at {:.0} req/s ({:?}), {} warm + {} cold connections, seed {}",
        cfg.requests, cfg.rate, cfg.arrival, cfg.warm, cfg.cold, cfg.seed
    );

    // --addr: external server; no in-process twin is reachable, so only
    // the TCP-side percentiles are reported
    if let Some(addr) = flags.get("addr") {
        use std::net::ToSocketAddrs;
        let addr = addr
            .to_socket_addrs()?
            .next()
            .ok_or_else(|| anyhow::anyhow!("{addr:?} resolved to no address"))?;
        let report = loadgen::run_tcp_schedule(addr, &cfg, &sends, &sample);
        println!("tcp     {}", report.summary());
        return apply_slo_gate(flags, &report);
    }

    // --synthetic (default): own server, registry weights, deterministic
    // random-init — the CI/bench mode, no artifacts needed
    let policy = BatchPolicy {
        max_batch: flag_usize(flags, "max-batch", 64),
        ..BatchPolicy::default()
    };
    let engine = match flags.get("engine").map(String::as_str) {
        Some("pipeline") => EngineKind::Pipeline,
        _ => EngineKind::Native,
    };
    let mut man = Manifest::synthetic();
    man.models.retain(|m| m.name == model);
    let server = Server::start_with_manifest(
        man,
        ServerConfig {
            policy,
            engine,
            init_random_fallback: true,
            ..ServerConfig::default()
        },
    )?;
    let tcp = TcpServer::start(server, NetConfig::default())?;
    let addr = tcp.local_addr();
    println!("synthetic server on {addr} (engine {engine:?}, max_batch {})", policy.max_batch);

    let tcp_report = loadgen::run_tcp_schedule(addr, &cfg, &sends, &sample);
    println!("tcp     {}", tcp_report.summary());
    // the no-network twin: identical schedule, identical server, replies
    // through the in-process seam — isolates the wire + framing cost
    let inproc_report = loadgen::run_inprocess(tcp.server(), &cfg, &sample);
    println!("inproc  {}", inproc_report.summary());
    let ratio = tcp_report.p50_us as f64 / inproc_report.p50_us.max(1) as f64;
    println!("tcp/inproc p50 ratio: {ratio:.2}x");

    // scrape-overhead leg (bench mode only): the identical schedule once
    // more with a scraper hammering the HTTP plane throughout — an honest
    // measurement of what observability costs the serving path
    // (informational `_ratio_` key, never CI-gated)
    let scrape_ratio = if flags.contains_key("bench-json") {
        use std::sync::atomic::{AtomicBool, Ordering};
        use std::sync::Arc;
        let frontend = tcp
            .server()
            .frontend()
            .ok_or_else(|| anyhow::anyhow!("server is already draining"))?;
        let sources = circnn::net::ScrapeSources::from_frontend(
            &frontend,
            None,
            Arc::new(AtomicBool::new(false)),
        );
        let http = circnn::net::MetricsHttp::start("127.0.0.1:0", sources)?;
        let scrape_addr = http.local_addr();
        let stop = Arc::new(AtomicBool::new(false));
        let stop_flag = stop.clone();
        let scraper = std::thread::spawn(move || {
            let mut scrapes = 0u64;
            while !stop_flag.load(Ordering::SeqCst) {
                if scrape_get(scrape_addr, "/metrics").is_ok() {
                    scrapes += 1;
                }
            }
            scrapes
        });
        let scraped_report = loadgen::run_tcp_schedule(addr, &cfg, &sends, &sample);
        stop.store(true, Ordering::SeqCst);
        let scrapes = scraper.join().unwrap_or(0);
        drop(http);
        drop(frontend);
        println!("scraped {}  ({scrapes} concurrent scrapes)", scraped_report.summary());
        let r = scraped_report.p50_us as f64 / tcp_report.p50_us.max(1) as f64;
        println!("scrape-overhead p50 ratio: {r:.2}x (informational, never gated)");
        Some(r)
    } else {
        None
    };

    let server = tcp.shutdown();
    println!("server  {}", server.metrics().summary());
    server.shutdown();

    if let Some(path) = flags.get("bench-json") {
        let tag = format!("b{}_c{}", policy.max_batch, cfg.warm + cfg.cold);
        let mut derived = vec![
            (format!("serve_tcp_latency_p50_us_{tag}"), tcp_report.p50_us as f64),
            (format!("serve_tcp_latency_p95_us_{tag}"), tcp_report.p95_us as f64),
            (format!("serve_tcp_latency_p99_us_{tag}"), tcp_report.p99_us as f64),
            (format!("tcp_vs_inproc_ratio_{tag}"), ratio),
        ];
        if let Some(r) = scrape_ratio {
            derived.push((format!("scrape_overhead_ratio_{tag}"), r));
        }
        circnn::util::benchkit::merge_derived(path, "circulant", &derived)?;
        println!("merged {} loadgen keys into {path}", derived.len());
    }
    apply_slo_gate(flags, &tcp_report)
}

/// `--slo-p99-us N [--slo-key K]`: compare the measured p99 of the gated
/// series against the budget; over budget is an error (non-zero exit) —
/// the CI latency gate.
fn apply_slo_gate(
    flags: &HashMap<String, String>,
    report: &circnn::net::LoadReport,
) -> anyhow::Result<()> {
    let Some(budget) = flags.get("slo-p99-us") else {
        return Ok(());
    };
    let budget: u64 = budget
        .parse()
        .map_err(|_| anyhow::anyhow!("--slo-p99-us wants an integer µs budget, got {budget:?}"))?;
    let key = flags.get("slo-key").map(String::as_str).unwrap_or("latency");
    let measured = report.slo_p99_us(key).map_err(|e| anyhow::anyhow!(e))?;
    if measured > budget {
        anyhow::bail!("SLO violated: {key} p99 <= {measured}us exceeds the {budget}us budget");
    }
    println!("SLO ok: {key} p99 <= {measured}us within the {budget}us budget");
    Ok(())
}

/// One blocking HTTP GET against the scrape plane (bench + smoke helper).
fn scrape_get(addr: std::net::SocketAddr, path: &str) -> std::io::Result<String> {
    use std::io::{Read, Write};
    let mut stream = std::net::TcpStream::connect(addr)?;
    stream.write_all(format!("GET {path} HTTP/1.0\r\n\r\n").as_bytes())?;
    let mut out = String::new();
    stream.read_to_string(&mut out)?;
    Ok(out)
}

fn cmd_train_demo(flags: &HashMap<String, String>) -> anyhow::Result<()> {
    #[cfg(feature = "pjrt")]
    if flags.get("engine").map(String::as_str) != Some("native") {
        return cmd_train_demo_pjrt(flags);
    }
    cmd_train_demo_native(flags)
}

/// Native FFT-domain training: O(n log n) spectral backprop on the
/// pure-Rust substrate — no artifacts, no XLA (`circnn::train`).
fn cmd_train_demo_native(flags: &HashMap<String, String>) -> anyhow::Result<()> {
    let model_name = flags.get("model").map(String::as_str).unwrap_or("mnist_mlp_1");
    let model = models::by_name(model_name)
        .ok_or_else(|| anyhow::anyhow!("unknown model {model_name:?}"))?;
    let cfg = circnn::train::TrainConfig {
        steps: flag_usize(flags, "steps", 50),
        batch: flag_usize(flags, "batch", 64),
        lr: flags
            .get("lr")
            .and_then(|v| v.parse().ok())
            .unwrap_or(circnn::train::TrainConfig::default().lr),
        ..Default::default()
    };
    if cfg.batch == 0 {
        anyhow::bail!("--batch must be >= 1");
    }
    let ds = data::dataset(model.dataset)
        .ok_or_else(|| anyhow::anyhow!("unknown dataset {}", model.dataset))?;
    let mut trainer =
        circnn::train::Trainer::new(&model, flag_usize(flags, "seed", 0) as u64)?;
    let registry = std::sync::Arc::new(circnn::telemetry::Registry::new());
    trainer.attach_telemetry(&registry, model_name);
    println!(
        "training {} for {} steps (batch {})",
        model.name, cfg.steps, cfg.batch
    );
    let t0 = Instant::now();
    trainer.train(&ds, &cfg);
    println!("done in {:.2}s", t0.elapsed().as_secs_f64());
    // lint:allow(metric-name): re-reading handles the trainer registered
    let step_us = registry.histogram("train_step_us");
    println!(
        "steps: {} | step time p50<={}us p95<={}us (log2 buckets) | executed FFTs {}",
        registry.counter("train_steps_total").get(), // lint:allow(metric-name): re-read
        step_us.quantile_edge(0.50),
        step_us.quantile_edge(0.95),
        trainer.layer_counters().iter().map(|c| c.ffts).sum::<u64>(),
    );
    let acc = trainer.eval_accuracy(&ds, 512, 128);
    println!("test accuracy {:.1}% (512 held-out samples, float32 native)", 100.0 * acc);
    Ok(())
}

#[cfg(feature = "pjrt")]
fn cmd_train_demo_pjrt(flags: &HashMap<String, String>) -> anyhow::Result<()> {
    let steps = flag_usize(flags, "steps", 50);
    let man = Manifest::load(Manifest::default_dir())?;
    let entry = man.model("mnist_mlp_1")?;
    let tr = entry
        .training
        .as_ref()
        .ok_or_else(|| anyhow::anyhow!("no training artifacts in manifest"))?;
    let ds = data::dataset(&entry.dataset).unwrap();

    let engine = Engine::cpu()?;
    let init = engine.load(man.path_of(&tr.init_file))?;
    let step = engine.load(man.path_of(&tr.step_file))?;
    println!("training {} for {steps} steps (batch {})", entry.name, tr.batch);

    let mut state = init.run(&[])?;
    let t0 = Instant::now();
    for s in 0..steps {
        let (xs, ys) = data::batch(&ds, (s * tr.batch) as u64, tr.batch, false);
        let x = literal_f32(&xs, &[tr.batch, 28, 28, 1])?;
        let y = literal_i32(
            &ys.iter().map(|&v| v as i32).collect::<Vec<_>>(),
            &[tr.batch],
        )?;
        let mut args = std::mem::take(&mut state);
        args.push(x);
        args.push(y);
        let mut out = step.run(&args)?;
        let loss = out
            .get(tr.loss_index)
            .ok_or_else(|| anyhow::anyhow!("loss index out of range"))?
            .to_vec::<f32>()?[0];
        out.truncate(tr.loss_index); // keep params + opt state + t
        state = out;
        if s % 10 == 0 || s + 1 == steps {
            println!("  step {s:4}  loss {loss:.4}");
        }
    }
    println!("done in {:.2}s", t0.elapsed().as_secs_f64());
    Ok(())
}
