//! Serving metrics, rendered *from* the unified telemetry registry
//! ([`crate::telemetry::Registry`]): lock-free counters, the fixed-bucket
//! request-latency histogram, a log2 queue-wait histogram, and (for the
//! pipelined engine) per-stage occupancy gauges refreshed from the
//! attached [`PipelineStats`].
//!
//! `summary()` keeps its historical one-line format byte for byte — it is
//! now a *view* over the registry, so the same numbers are available as
//! Prometheus-style text ([`Metrics::export_text`]) and machine-readable
//! JSON ([`Metrics::export_json`], what `serve --trace-dump` writes and
//! CI's telemetry smoke asserts on).

use std::sync::{Arc, Mutex};
use std::time::Duration;

use crate::pipeline::PipelineStats;
use crate::telemetry::{Counter, Gauge, Histogram, Registry, SnapSample};

/// Log-spaced latency buckets (finite upper bounds, microseconds); the
/// registry histogram adds the open-ended overflow bucket.
const BUCKETS_US: [u64; 11] =
    [10, 30, 100, 300, 1_000, 3_000, 10_000, 30_000, 100_000, 300_000, 1_000_000];

/// Largest finite bucket bound — percentiles landing in the open-ended
/// overflow bucket saturate here instead of reporting `u64::MAX`.
const MAX_FINITE_US: u64 = BUCKETS_US[BUCKETS_US.len() - 1];

/// Shared serving metrics (cheap to clone via Arc).  The handle fields are
/// registry-backed atomics: `requests.inc()` both feeds `summary()` and
/// shows up as `requests_total` in the exposition.
#[derive(Debug)]
pub struct Metrics {
    registry: Arc<Registry>,
    pub requests: Counter,
    pub responses: Counter,
    pub rejected: Counter,
    pub batches: Counter,
    /// total occupied slots over all executed batches
    pub batched_items: Counter,
    /// total padded (wasted) slots
    pub padded_slots: Counter,
    latency: Histogram,
    queue_wait: Histogram,
    /// requests currently queued in the dynamic batcher(s), summed across
    /// models — maintained by the executor loop each poll iteration
    pub queue_depth: Gauge,
    /// requests admitted but not yet answered or rejected — refreshed by
    /// the executor loop and by [`Metrics::snapshot_sample`]
    pub inflight: Gauge,
    /// TCP front-end counters (`rust/src/net`).  Registered eagerly here —
    /// not lazily by the listener — so a server started *without* the TCP
    /// front-end still exposes every `net_*` name at zero and the bench
    /// JSON schema is identical across configs.
    pub net: NetMetrics,
    /// per-model pipeline stage occupancy (pipeline engine only; empty on
    /// the serial executors) plus the registry gauges mirroring it
    pipelines: Mutex<Vec<(String, Arc<PipelineStats>, Vec<Gauge>)>>,
}

/// Registry handles for the TCP front-end (`net::TcpServer` increments
/// them; everything else only reads).  All live in the same registry as
/// the serving counters, under stable `net_*` names.
#[derive(Debug)]
pub struct NetMetrics {
    /// connections ever accepted
    pub connections: Counter,
    /// currently open connections (maintained by the accept/reader threads)
    pub connections_open: Gauge,
    /// request frames decoded off the wire
    pub frames_rx: Counter,
    /// reply frames written to the wire
    pub frames_tx: Counter,
    /// raw bytes read from all connections
    pub bytes_rx: Counter,
    /// raw bytes written to all connections
    pub bytes_tx: Counter,
    /// requests answered `Overloaded` (connection in-flight cap, connection
    /// cap, or the batcher's `max_queue` admission limit)
    pub overloaded: Counter,
    /// connections dropped on a malformed/oversized/unsupported frame
    pub decode_errors: Counter,
    /// admin (scrape) frames answered on the wire; admin traffic also
    /// counts in the frame/byte totals, so subtracting this recovers the
    /// serving-only throughput picture
    pub admin: Counter,
}

impl Default for Metrics {
    fn default() -> Self {
        let registry = Arc::new(Registry::new());
        Self {
            requests: registry.counter("requests_total"),
            responses: registry.counter("responses_total"),
            rejected: registry.counter("rejected_total"),
            batches: registry.counter("batches_total"),
            batched_items: registry.counter("batched_items_total"),
            padded_slots: registry.counter("padded_slots_total"),
            latency: registry.histogram_edges("request_latency_us", &BUCKETS_US),
            queue_wait: registry.histogram("queue_wait_us"),
            queue_depth: registry.gauge("queue_depth"),
            inflight: registry.gauge("inflight_requests"),
            net: NetMetrics {
                connections: registry.counter("net_connections_total"),
                connections_open: registry.gauge("net_connections_open"),
                frames_rx: registry.counter("net_frames_rx_total"),
                frames_tx: registry.counter("net_frames_tx_total"),
                bytes_rx: registry.counter("net_bytes_rx_total"),
                bytes_tx: registry.counter("net_bytes_tx_total"),
                overloaded: registry.counter("net_overloaded_total"),
                decode_errors: registry.counter("net_decode_errors_total"),
                admin: registry.counter("net_admin_total"),
            },
            pipelines: Mutex::new(Vec::new()),
            registry,
        }
    }
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    /// The registry every serving metric lives in — the attachment point
    /// for phase-profiling hooks (model accounting gauges, trainer step
    /// timing) and the span tracer's own counters.
    pub fn registry(&self) -> &Arc<Registry> {
        &self.registry
    }

    pub fn record_latency(&self, latency: Duration) {
        self.latency.observe(latency.as_micros() as u64);
    }

    /// Time a request spent queued in the batcher before its batch was
    /// released (recorded at drain for every request, tracing or not).
    pub fn record_queue_wait(&self, wait: Duration) {
        self.queue_wait.observe(wait.as_micros() as u64);
    }

    pub fn mean_latency_us(&self) -> f64 {
        let n = self.responses.get();
        if n == 0 {
            return 0.0;
        }
        self.latency.sum() as f64 / n as f64
    }

    /// Approximate latency percentile from the histogram (the bucket's
    /// upper bound).  A percentile landing in the open-ended last bucket
    /// saturates to [`MAX_FINITE_US`] — a *lower* bound in that case, never
    /// `u64::MAX`; `summary()` reports it as `>1000000us`.
    pub fn latency_percentile_us(&self, p: f64) -> u64 {
        self.latency.quantile_edge(p / 100.0)
    }

    /// Mean occupied batch size.
    pub fn mean_batch_size(&self) -> f64 {
        let batches = self.batches.get();
        if batches == 0 {
            return 0.0;
        }
        self.batched_items.get() as f64 / batches as f64
    }

    /// Fraction of executed slots wasted on padding (0.0 with no samples —
    /// never NaN).
    pub fn padding_fraction(&self) -> f64 {
        let items = self.batched_items.get();
        let padded = self.padded_slots.get();
        if items + padded == 0 {
            return 0.0;
        }
        padded as f64 / (items + padded) as f64
    }

    /// Attach a running pipeline's stage stats under `model` so
    /// [`summary`](Self::summary) reports its occupancy (one entry per
    /// pipelined model; the executor calls this at startup).  Each stage
    /// also gets a `pipeline_stage_busy_permille{model,stage}` gauge,
    /// refreshed from the measured busy fraction at exposition time.
    pub fn attach_pipeline(&self, model: &str, stats: Arc<PipelineStats>) {
        let gauges: Vec<Gauge> = (0..stats.stage_count())
            .map(|s| {
                self.registry.gauge_with(
                    "pipeline_stage_busy_permille",
                    &[("model", model.to_string()), ("stage", s.to_string())],
                )
            })
            .collect();
        self.pipelines
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .push((model.to_string(), stats, gauges));
    }

    /// Snapshot of the attached pipelines (model name, stage stats).
    pub fn pipelines(&self) -> Vec<(String, Arc<PipelineStats>)> {
        self.pipelines
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .iter()
            .map(|(name, stats, _)| (name.clone(), stats.clone()))
            .collect()
    }

    /// Fold the measured per-stage busy fractions into their registry
    /// gauges (permille, so the exposition stays integer-valued).
    fn refresh_stage_gauges(&self) {
        let pipes = self.pipelines.lock().unwrap_or_else(|e| e.into_inner());
        for (_, stats, gauges) in pipes.iter() {
            for (s, gauge) in gauges.iter().enumerate() {
                gauge.set(stats.busy_permille(s));
            }
        }
    }

    /// Recompute the `inflight_requests` gauge from the admission
    /// counters (admitted − answered − rejected; saturating, so a scrape
    /// racing the counters can momentarily read 0 but never wraps).
    pub fn refresh_inflight(&self) {
        let answered = self.responses.get().saturating_add(self.rejected.get());
        self.inflight.set(self.requests.get().saturating_sub(answered));
    }

    /// One observation of the serving plane for the snapshot ticker
    /// (`at_ms` is stamped by the sampler): live queue depth and in-flight
    /// gauges, open connections, and the busiest pipeline stage's permille.
    pub fn snapshot_sample(&self) -> SnapSample {
        self.refresh_inflight();
        let stage_busy_permille = self
            .pipelines()
            .iter()
            .map(|(_, stats)| stats.max_busy_permille())
            .max()
            .unwrap_or(0);
        SnapSample {
            at_ms: 0,
            queue_depth: self.queue_depth.get(),
            inflight: self.inflight.get(),
            net_open: self.net.connections_open.get(),
            stage_busy_permille,
        }
    }

    /// Prometheus-style text exposition of every serving metric.
    pub fn export_text(&self) -> String {
        self.refresh_stage_gauges();
        self.refresh_inflight();
        self.registry.render_text()
    }

    /// JSON exposition (`{"counters":…,"gauges":…,"histograms":…}`).
    pub fn export_json(&self) -> String {
        self.refresh_stage_gauges();
        self.refresh_inflight();
        self.registry.render_json()
    }

    /// Render one latency percentile with the saturation convention: a
    /// percentile landing in the open-ended overflow bucket prints as a
    /// floor (`p95>…us`), never as `u64::MAX`.
    fn percentile_summary(&self, p: f64) -> String {
        match self.latency.quantile_bucket(p / 100.0) {
            // overflow bucket: the bound is a floor, not a ceiling
            Some(i) if i >= BUCKETS_US.len() => format!("p{p:.0}>{MAX_FINITE_US}us"),
            Some(i) => format!("p{p:.0}<={}us", BUCKETS_US[i]),
            None => format!("p{p:.0}<=0us"),
        }
    }

    /// One-line summary for logs / examples: counters, p50/p95/p99, and —
    /// when a pipeline is attached — per-stage busy fractions.  Rendered
    /// entirely from the registry handles.
    pub fn summary(&self) -> String {
        let mut s = format!(
            "requests={} responses={} rejected={} batches={} mean_batch={:.1} \
             padding={:.1}% mean_latency={:.0}us {} {} {}",
            self.requests.get(),
            self.responses.get(),
            self.rejected.get(),
            self.batches.get(),
            self.mean_batch_size(),
            self.padding_fraction() * 100.0,
            self.mean_latency_us(),
            self.percentile_summary(50.0),
            self.percentile_summary(95.0),
            self.percentile_summary(99.0),
        );
        // always rendered — zero-valued without a TCP listener — so the
        // summary's shape matches the exposition's stable net_* schema
        s.push_str(&format!(
            " net[conns={} frames_rx={} frames_tx={} shed={}]",
            self.net.connections.get(),
            self.net.frames_rx.get(),
            self.net.frames_tx.get(),
            self.net.overloaded.get(),
        ));
        for (name, stats) in self.pipelines().iter() {
            // only stages that saw traffic say anything useful
            use std::sync::atomic::Ordering;
            if stats.stages.iter().any(|st| st.batches.load(Ordering::Relaxed) > 0) {
                s.push_str(&format!(" pipeline[{name}]: {}", stats.occupancy_summary()));
            }
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::Json;

    #[test]
    fn latency_histogram_percentiles() {
        let m = Metrics::new();
        for _ in 0..99 {
            m.record_latency(Duration::from_micros(50));
        }
        m.record_latency(Duration::from_millis(50));
        assert_eq!(m.latency_percentile_us(50.0), 100);
        assert_eq!(m.latency_percentile_us(99.9), 100_000);
    }

    #[test]
    fn overflow_bucket_saturates_to_finite_bound() {
        // a >1s latency lands in the open-ended last bucket; the reported
        // percentile must saturate (it printed u64::MAX before) and the
        // summary must flag it as a floor
        let m = Metrics::new();
        m.record_latency(Duration::from_secs(2));
        assert_eq!(m.latency_percentile_us(50.0), 1_000_000);
        assert_eq!(m.latency_percentile_us(99.9), 1_000_000);
        assert!(
            m.summary().contains("p95>1000000us"),
            "summary must report the overflow bucket as a lower bound: {}",
            m.summary()
        );
    }

    #[test]
    fn batch_stats() {
        let m = Metrics::new();
        m.batches.add(2);
        m.batched_items.add(96);
        m.padded_slots.add(32);
        assert!((m.mean_batch_size() - 48.0).abs() < 1e-9);
        assert!((m.padding_fraction() - 0.25).abs() < 1e-9);
    }

    #[test]
    fn summary_reports_p50_p95_p99() {
        let m = Metrics::new();
        for _ in 0..98 {
            m.record_latency(Duration::from_micros(50));
        }
        m.record_latency(Duration::from_millis(50));
        m.responses.add(99);
        m.record_latency(Duration::from_secs(2)); // overflow bucket
        let s = m.summary();
        assert!(s.contains("p50<=100us"), "{s}");
        assert!(s.contains("p95<=100us"), "{s}");
        assert!(s.contains("p99<=100000us"), "{s}");
        // all three percentiles keep the saturation convention
        let m2 = Metrics::new();
        m2.record_latency(Duration::from_secs(2));
        let s2 = m2.summary();
        for needle in ["p50>1000000us", "p95>1000000us", "p99>1000000us"] {
            assert!(s2.contains(needle), "{s2}");
        }
    }

    #[test]
    fn summary_appends_attached_pipeline_occupancy() {
        use crate::pipeline::PipelineStats;
        use std::sync::Arc;
        use std::time::Instant;

        let m = Metrics::new();
        assert!(!m.summary().contains("pipeline["), "no pipeline attached yet");
        let stats = Arc::new(PipelineStats::new(vec!["L00 bc_dense".into()]));
        m.attach_pipeline("mnist_mlp_1", stats.clone());
        // a stage with no traffic stays silent
        assert!(!m.summary().contains("pipeline["), "{}", m.summary());
        let t = Instant::now();
        stats.record(0, 0, t, t + Duration::from_micros(10), 1);
        let s = m.summary();
        assert!(s.contains("pipeline[mnist_mlp_1]: s0="), "{s}");
        assert_eq!(m.pipelines().len(), 1);
        // the occupancy gauge rides the exposition under the stable name
        let text = m.export_text();
        assert!(
            text.contains("pipeline_stage_busy_permille{model=\"mnist_mlp_1\",stage=\"0\"}"),
            "{text}"
        );
    }

    #[test]
    fn empty_metrics_are_zero() {
        // the zero-sample edges: all three means/fractions report 0.0,
        // never NaN or a divide-by-zero panic
        let m = Metrics::new();
        assert_eq!(m.mean_latency_us(), 0.0);
        assert_eq!(m.latency_percentile_us(95.0), 0);
        assert_eq!(m.mean_batch_size(), 0.0);
        assert_eq!(m.padding_fraction(), 0.0);
        assert!(m.summary().contains("requests=0"));
        assert!(m.summary().contains("p50<=0us"));
    }

    #[test]
    fn net_metrics_present_at_zero_without_a_listener() {
        // the stable-schema contract: a server that never started the TCP
        // front-end still reports every net_* name (zero-valued), so bench
        // tooling sees one JSON shape across configs
        let m = Metrics::new();
        let doc = Json::parse(&m.export_json()).expect("exposition parses");
        let counters = doc.get("counters").expect("counters");
        for name in [
            "net_connections_total",
            "net_frames_rx_total",
            "net_frames_tx_total",
            "net_bytes_rx_total",
            "net_bytes_tx_total",
            "net_overloaded_total",
            "net_decode_errors_total",
            "net_admin_total",
        ] {
            assert_eq!(counters.get(name).and_then(Json::as_u64), Some(0), "{name}");
        }
        let gauges = doc.get("gauges").expect("gauges");
        assert_eq!(gauges.get("net_connections_open").and_then(Json::as_u64), Some(0));
        assert!(
            m.summary().contains("net[conns=0 frames_rx=0 frames_tx=0 shed=0]"),
            "{}",
            m.summary()
        );
    }

    #[test]
    fn inflight_and_queue_depth_gauges_ride_the_exposition() {
        let m = Metrics::new();
        m.requests.add(10);
        m.responses.add(4);
        m.rejected.add(1);
        m.queue_depth.set(3);
        let doc = Json::parse(&m.export_json()).expect("exposition parses");
        let gauges = doc.get("gauges").expect("gauges");
        assert_eq!(gauges.get("queue_depth").and_then(Json::as_u64), Some(3));
        // export refreshed it: 10 admitted − 4 answered − 1 rejected
        assert_eq!(gauges.get("inflight_requests").and_then(Json::as_u64), Some(5));

        let sample = m.snapshot_sample();
        assert_eq!(sample.queue_depth, 3);
        assert_eq!(sample.inflight, 5);
        assert_eq!(sample.stage_busy_permille, 0, "no pipeline attached");
        // counters racing a scrape can momentarily exceed admissions:
        // the gauge saturates at zero instead of wrapping
        m.responses.add(100);
        m.refresh_inflight();
        assert_eq!(m.inflight.get(), 0);
    }

    #[test]
    fn exposition_carries_the_serving_metrics() {
        let m = Metrics::new();
        m.requests.inc();
        m.responses.inc();
        m.record_latency(Duration::from_micros(70));
        m.record_queue_wait(Duration::from_micros(12));
        let doc = Json::parse(&m.export_json()).expect("exposition parses");
        let counters = doc.get("counters").expect("counters");
        assert_eq!(counters.get("requests_total").and_then(Json::as_u64), Some(1));
        let hists = doc.get("histograms").expect("histograms");
        let lat = hists.get("request_latency_us").expect("latency histogram");
        assert_eq!(lat.get("count").and_then(Json::as_u64), Some(1));
        assert_eq!(
            lat.get("edges").and_then(Json::as_arr).map(|a| a.len()),
            Some(BUCKETS_US.len()),
            "deterministic bucket edges"
        );
        let qw = hists.get("queue_wait_us").expect("queue-wait histogram");
        assert_eq!(qw.get("count").and_then(Json::as_u64), Some(1));
        assert_eq!(qw.get("p50").and_then(Json::as_u64), Some(16), "log2 edge");
    }
}
