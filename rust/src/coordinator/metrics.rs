//! Serving metrics: lock-free counters + a fixed-bucket latency histogram.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

/// Log-spaced latency buckets (upper bounds, microseconds).
const BUCKETS_US: [u64; 12] = [
    10, 30, 100, 300, 1_000, 3_000, 10_000, 30_000, 100_000, 300_000, 1_000_000, u64::MAX,
];

/// Largest finite bucket bound — percentiles landing in the open-ended
/// overflow bucket saturate here instead of reporting `u64::MAX`.
const MAX_FINITE_US: u64 = BUCKETS_US[BUCKETS_US.len() - 2];

/// Shared serving metrics (all atomic; cheap to clone via Arc).
#[derive(Debug, Default)]
pub struct Metrics {
    pub requests: AtomicU64,
    pub responses: AtomicU64,
    pub rejected: AtomicU64,
    pub batches: AtomicU64,
    /// total occupied slots over all executed batches
    pub batched_items: AtomicU64,
    /// total padded (wasted) slots
    pub padded_slots: AtomicU64,
    latency_buckets: [AtomicU64; 12],
    latency_sum_us: AtomicU64,
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record_latency(&self, latency: Duration) {
        let us = latency.as_micros() as u64;
        self.latency_sum_us.fetch_add(us, Ordering::Relaxed);
        let idx = BUCKETS_US.iter().position(|&b| us <= b).unwrap_or(11);
        self.latency_buckets[idx].fetch_add(1, Ordering::Relaxed);
    }

    pub fn mean_latency_us(&self) -> f64 {
        let n = self.responses.load(Ordering::Relaxed);
        if n == 0 {
            return 0.0;
        }
        self.latency_sum_us.load(Ordering::Relaxed) as f64 / n as f64
    }

    /// Index into `BUCKETS_US` of the bucket holding percentile `p`
    /// (`None` with no samples).
    fn percentile_bucket(&self, p: f64) -> Option<usize> {
        let total: u64 = self
            .latency_buckets
            .iter()
            .map(|b| b.load(Ordering::Relaxed))
            .sum();
        if total == 0 {
            return None;
        }
        let target = (total as f64 * p / 100.0).ceil() as u64;
        let mut seen = 0;
        for (i, b) in self.latency_buckets.iter().enumerate() {
            seen += b.load(Ordering::Relaxed);
            if seen >= target {
                return Some(i);
            }
        }
        Some(BUCKETS_US.len() - 1)
    }

    /// Approximate latency percentile from the histogram (the bucket's
    /// upper bound).  A percentile landing in the open-ended last bucket
    /// saturates to [`MAX_FINITE_US`] — a *lower* bound in that case, never
    /// `u64::MAX`; `summary()` reports it as `>1000000us`.
    pub fn latency_percentile_us(&self, p: f64) -> u64 {
        match self.percentile_bucket(p) {
            None => 0,
            Some(i) => BUCKETS_US[i].min(MAX_FINITE_US),
        }
    }

    /// Mean occupied batch size.
    pub fn mean_batch_size(&self) -> f64 {
        let batches = self.batches.load(Ordering::Relaxed);
        if batches == 0 {
            return 0.0;
        }
        self.batched_items.load(Ordering::Relaxed) as f64 / batches as f64
    }

    /// Fraction of executed slots wasted on padding.
    pub fn padding_fraction(&self) -> f64 {
        let items = self.batched_items.load(Ordering::Relaxed);
        let padded = self.padded_slots.load(Ordering::Relaxed);
        if items + padded == 0 {
            return 0.0;
        }
        padded as f64 / (items + padded) as f64
    }

    /// One-line summary for logs / examples.
    pub fn summary(&self) -> String {
        let p95 = match self.percentile_bucket(95.0) {
            // overflow bucket: the bound is a floor, not a ceiling
            Some(i) if BUCKETS_US[i] == u64::MAX => format!("p95>{MAX_FINITE_US}us"),
            Some(i) => format!("p95<={}us", BUCKETS_US[i]),
            None => "p95<=0us".to_string(),
        };
        format!(
            "requests={} responses={} rejected={} batches={} mean_batch={:.1} \
             padding={:.1}% mean_latency={:.0}us {p95}",
            self.requests.load(Ordering::Relaxed),
            self.responses.load(Ordering::Relaxed),
            self.rejected.load(Ordering::Relaxed),
            self.batches.load(Ordering::Relaxed),
            self.mean_batch_size(),
            self.padding_fraction() * 100.0,
            self.mean_latency_us(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn latency_histogram_percentiles() {
        let m = Metrics::new();
        for _ in 0..99 {
            m.record_latency(Duration::from_micros(50));
        }
        m.record_latency(Duration::from_millis(50));
        assert_eq!(m.latency_percentile_us(50.0), 100);
        assert_eq!(m.latency_percentile_us(99.9), 100_000);
    }

    #[test]
    fn overflow_bucket_saturates_to_finite_bound() {
        // a >1s latency lands in the open-ended last bucket; the reported
        // percentile must saturate (it printed u64::MAX before) and the
        // summary must flag it as a floor
        let m = Metrics::new();
        m.record_latency(Duration::from_secs(2));
        assert_eq!(m.latency_percentile_us(50.0), 1_000_000);
        assert_eq!(m.latency_percentile_us(99.9), 1_000_000);
        assert!(
            m.summary().contains("p95>1000000us"),
            "summary must report the overflow bucket as a lower bound: {}",
            m.summary()
        );
    }

    #[test]
    fn batch_stats() {
        let m = Metrics::new();
        m.batches.fetch_add(2, Ordering::Relaxed);
        m.batched_items.fetch_add(96, Ordering::Relaxed);
        m.padded_slots.fetch_add(32, Ordering::Relaxed);
        assert!((m.mean_batch_size() - 48.0).abs() < 1e-9);
        assert!((m.padding_fraction() - 0.25).abs() < 1e-9);
    }

    #[test]
    fn empty_metrics_are_zero() {
        let m = Metrics::new();
        assert_eq!(m.mean_latency_us(), 0.0);
        assert_eq!(m.latency_percentile_us(95.0), 0);
        assert_eq!(m.mean_batch_size(), 0.0);
        assert!(m.summary().contains("requests=0"));
    }
}
