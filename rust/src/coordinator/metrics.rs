//! Serving metrics: lock-free counters, a fixed-bucket latency histogram,
//! and (for the pipelined engine) per-stage occupancy attached by the
//! executor so `summary()` can report busy/fill fractions next to the
//! latency percentiles.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use crate::pipeline::PipelineStats;

/// Log-spaced latency buckets (upper bounds, microseconds).
const BUCKETS_US: [u64; 12] = [
    10, 30, 100, 300, 1_000, 3_000, 10_000, 30_000, 100_000, 300_000, 1_000_000, u64::MAX,
];

/// Largest finite bucket bound — percentiles landing in the open-ended
/// overflow bucket saturate here instead of reporting `u64::MAX`.
const MAX_FINITE_US: u64 = BUCKETS_US[BUCKETS_US.len() - 2];

/// Shared serving metrics (all atomic; cheap to clone via Arc).
#[derive(Debug, Default)]
pub struct Metrics {
    pub requests: AtomicU64,
    pub responses: AtomicU64,
    pub rejected: AtomicU64,
    pub batches: AtomicU64,
    /// total occupied slots over all executed batches
    pub batched_items: AtomicU64,
    /// total padded (wasted) slots
    pub padded_slots: AtomicU64,
    latency_buckets: [AtomicU64; 12],
    latency_sum_us: AtomicU64,
    /// per-model pipeline stage occupancy (pipeline engine only; empty on
    /// the serial executors)
    pipelines: Mutex<Vec<(String, Arc<PipelineStats>)>>,
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record_latency(&self, latency: Duration) {
        let us = latency.as_micros() as u64;
        self.latency_sum_us.fetch_add(us, Ordering::Relaxed);
        let idx = BUCKETS_US.iter().position(|&b| us <= b).unwrap_or(11);
        self.latency_buckets[idx].fetch_add(1, Ordering::Relaxed);
    }

    pub fn mean_latency_us(&self) -> f64 {
        let n = self.responses.load(Ordering::Relaxed);
        if n == 0 {
            return 0.0;
        }
        self.latency_sum_us.load(Ordering::Relaxed) as f64 / n as f64
    }

    /// Index into `BUCKETS_US` of the bucket holding percentile `p`
    /// (`None` with no samples).
    fn percentile_bucket(&self, p: f64) -> Option<usize> {
        let total: u64 = self
            .latency_buckets
            .iter()
            .map(|b| b.load(Ordering::Relaxed))
            .sum();
        if total == 0 {
            return None;
        }
        let target = (total as f64 * p / 100.0).ceil() as u64;
        let mut seen = 0;
        for (i, b) in self.latency_buckets.iter().enumerate() {
            seen += b.load(Ordering::Relaxed);
            if seen >= target {
                return Some(i);
            }
        }
        Some(BUCKETS_US.len() - 1)
    }

    /// Approximate latency percentile from the histogram (the bucket's
    /// upper bound).  A percentile landing in the open-ended last bucket
    /// saturates to [`MAX_FINITE_US`] — a *lower* bound in that case, never
    /// `u64::MAX`; `summary()` reports it as `>1000000us`.
    pub fn latency_percentile_us(&self, p: f64) -> u64 {
        match self.percentile_bucket(p) {
            None => 0,
            Some(i) => BUCKETS_US[i].min(MAX_FINITE_US),
        }
    }

    /// Mean occupied batch size.
    pub fn mean_batch_size(&self) -> f64 {
        let batches = self.batches.load(Ordering::Relaxed);
        if batches == 0 {
            return 0.0;
        }
        self.batched_items.load(Ordering::Relaxed) as f64 / batches as f64
    }

    /// Fraction of executed slots wasted on padding.
    pub fn padding_fraction(&self) -> f64 {
        let items = self.batched_items.load(Ordering::Relaxed);
        let padded = self.padded_slots.load(Ordering::Relaxed);
        if items + padded == 0 {
            return 0.0;
        }
        padded as f64 / (items + padded) as f64
    }

    /// Attach a running pipeline's stage stats under `model` so
    /// [`summary`](Self::summary) reports its occupancy (one entry per
    /// pipelined model; the executor calls this at startup).
    pub fn attach_pipeline(&self, model: &str, stats: Arc<PipelineStats>) {
        self.pipelines
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .push((model.to_string(), stats));
    }

    /// Snapshot of the attached pipelines (model name, stage stats).
    pub fn pipelines(&self) -> Vec<(String, Arc<PipelineStats>)> {
        self.pipelines
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .clone()
    }

    /// Render one latency percentile with the saturation convention: a
    /// percentile landing in the open-ended overflow bucket prints as a
    /// floor (`p95>…us`), never as `u64::MAX`.
    fn percentile_summary(&self, p: f64) -> String {
        match self.percentile_bucket(p) {
            // overflow bucket: the bound is a floor, not a ceiling
            Some(i) if BUCKETS_US[i] == u64::MAX => format!("p{p:.0}>{MAX_FINITE_US}us"),
            Some(i) => format!("p{p:.0}<={}us", BUCKETS_US[i]),
            None => format!("p{p:.0}<=0us"),
        }
    }

    /// One-line summary for logs / examples: counters, p50/p95/p99, and —
    /// when a pipeline is attached — per-stage busy fractions.
    pub fn summary(&self) -> String {
        let mut s = format!(
            "requests={} responses={} rejected={} batches={} mean_batch={:.1} \
             padding={:.1}% mean_latency={:.0}us {} {} {}",
            self.requests.load(Ordering::Relaxed),
            self.responses.load(Ordering::Relaxed),
            self.rejected.load(Ordering::Relaxed),
            self.batches.load(Ordering::Relaxed),
            self.mean_batch_size(),
            self.padding_fraction() * 100.0,
            self.mean_latency_us(),
            self.percentile_summary(50.0),
            self.percentile_summary(95.0),
            self.percentile_summary(99.0),
        );
        for (name, stats) in self.pipelines().iter() {
            // only stages that saw traffic say anything useful
            if stats.stages.iter().any(|st| st.batches.load(Ordering::Relaxed) > 0) {
                s.push_str(&format!(" pipeline[{name}]: {}", stats.occupancy_summary()));
            }
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn latency_histogram_percentiles() {
        let m = Metrics::new();
        for _ in 0..99 {
            m.record_latency(Duration::from_micros(50));
        }
        m.record_latency(Duration::from_millis(50));
        assert_eq!(m.latency_percentile_us(50.0), 100);
        assert_eq!(m.latency_percentile_us(99.9), 100_000);
    }

    #[test]
    fn overflow_bucket_saturates_to_finite_bound() {
        // a >1s latency lands in the open-ended last bucket; the reported
        // percentile must saturate (it printed u64::MAX before) and the
        // summary must flag it as a floor
        let m = Metrics::new();
        m.record_latency(Duration::from_secs(2));
        assert_eq!(m.latency_percentile_us(50.0), 1_000_000);
        assert_eq!(m.latency_percentile_us(99.9), 1_000_000);
        assert!(
            m.summary().contains("p95>1000000us"),
            "summary must report the overflow bucket as a lower bound: {}",
            m.summary()
        );
    }

    #[test]
    fn batch_stats() {
        let m = Metrics::new();
        m.batches.fetch_add(2, Ordering::Relaxed);
        m.batched_items.fetch_add(96, Ordering::Relaxed);
        m.padded_slots.fetch_add(32, Ordering::Relaxed);
        assert!((m.mean_batch_size() - 48.0).abs() < 1e-9);
        assert!((m.padding_fraction() - 0.25).abs() < 1e-9);
    }

    #[test]
    fn summary_reports_p50_p95_p99() {
        let m = Metrics::new();
        for _ in 0..98 {
            m.record_latency(Duration::from_micros(50));
        }
        m.record_latency(Duration::from_millis(50));
        m.record_latency(Duration::from_secs(2)); // overflow bucket
        let s = m.summary();
        assert!(s.contains("p50<=100us"), "{s}");
        assert!(s.contains("p95<=100us"), "{s}");
        assert!(s.contains("p99<=100000us"), "{s}");
        // all three percentiles keep the saturation convention
        let m2 = Metrics::new();
        m2.record_latency(Duration::from_secs(2));
        let s2 = m2.summary();
        for needle in ["p50>1000000us", "p95>1000000us", "p99>1000000us"] {
            assert!(s2.contains(needle), "{s2}");
        }
    }

    #[test]
    fn summary_appends_attached_pipeline_occupancy() {
        use crate::pipeline::PipelineStats;
        use std::sync::Arc;
        use std::time::Instant;

        let m = Metrics::new();
        assert!(!m.summary().contains("pipeline["), "no pipeline attached yet");
        let stats = Arc::new(PipelineStats::new(vec!["L00 bc_dense".into()]));
        m.attach_pipeline("mnist_mlp_1", stats.clone());
        // a stage with no traffic stays silent
        assert!(!m.summary().contains("pipeline["), "{}", m.summary());
        let t = Instant::now();
        stats.record(0, 0, t, t + Duration::from_micros(10), 1);
        let s = m.summary();
        assert!(s.contains("pipeline[mnist_mlp_1]: s0="), "{s}");
        assert_eq!(m.pipelines().len(), 1);
    }

    #[test]
    fn empty_metrics_are_zero() {
        let m = Metrics::new();
        assert_eq!(m.mean_latency_us(), 0.0);
        assert_eq!(m.latency_percentile_us(95.0), 0);
        assert_eq!(m.mean_batch_size(), 0.0);
        assert!(m.summary().contains("requests=0"));
    }
}
