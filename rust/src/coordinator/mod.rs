//! Layer-3 coordinator: the serving half of the co-design stack.
//!
//! Architecture (vLLM-router-like, scaled to this paper's serving story):
//!
//! ```text
//!  clients ──► Router ──► per-model BatchQueue ──► executor thread
//!              (validate,  (dynamic batching:       (assembles released
//!               dispatch,   size + deadline          batches, dispatches to
//!               admission)  policy, paper's          the engine backend)
//!                           50-100 batch)                  │
//!          serial backends (native / PJRT):                │
//!            execute end to end, scatter replies  ◄────────┤
//!          pipeline backend (crate::pipeline):             │
//!            [stage 0] ─► [stage 1] ─► … ─► sink  ◄────────┘
//!            (multiple batches in flight, one per layer stage;
//!             replies scatter from the last stage's worker)
//! ```
//!
//! The serial executor is the software twin of the paper's single
//! time-multiplexed FPGA: one thread walks every layer of a batch end to
//! end (`PjRtClient` is not `Send`, so on the PJRT backend this is
//! structural), and batching is what buys throughput.  The **pipeline**
//! backend ([`server::EngineKind::Pipeline`]) is the twin of the paper's
//! *deeply pipelined* datapath (Fig. 4): the native model's layer program
//! is split into stage workers chained by bounded channels, so batch N
//! streams through layer ℓ+1 while batch N+1 occupies layer ℓ — bitwise
//! identical per-batch results, per-stage occupancy in [`Metrics`].
//! The batcher implements the paper's batch-processing design point
//! (default max batch 64, bounded queueing with explicit backpressure;
//! degenerate policies are clamped, see `BatchPolicy::clamped`).
//!
//! The executor drives one of three backends (see [`server::EngineKind`]):
//! PJRT artifacts (`pjrt` feature), the always-available pure-Rust
//! substrate — whose batch-major parallel `matmul` shards each released
//! batch across cores — or that same substrate behind the layer pipeline.
//!
//! Clients reach the coordinator two ways: in-process ([`Server::infer`] /
//! [`Server::infer_async`]) or over TCP through [`crate::net::TcpServer`],
//! which feeds the same executor through the transport-agnostic
//! [`Frontend`] seam — the wire protocol and framing live in `crate::net`,
//! documented in `docs/PROTOCOL.md`.

pub mod batcher;
pub mod metrics;
pub mod router;
pub mod server;

pub use batcher::{BatchPolicy, BatchQueue};
pub use metrics::{Metrics, NetMetrics};
pub use router::Router;
pub use server::{EngineKind, Frontend, InferError, Response, Server, ServerConfig};
