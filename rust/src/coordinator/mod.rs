//! Layer-3 coordinator: the serving half of the co-design stack.
//!
//! Architecture (vLLM-router-like, scaled to this paper's serving story):
//!
//! ```text
//!  clients ──► Router ──► per-model BatchQueue ──► executor thread
//!              (validate,  (dynamic batching:       (owns the PJRT Engine,
//!               dispatch,   size + deadline          pads to the artifact
//!               admission)  policy, paper's          batch, executes, scatters
//!                           50-100 batch)            replies)
//! ```
//!
//! The executor thread is the software twin of the paper's single FPGA:
//! `PjRtClient` is not `Send`, so exactly one thread owns it and the
//! datapath is strictly serialized — batching is what buys throughput,
//! precisely as in Fig. 4.  The batcher implements the paper's
//! batch-processing design point (default max batch 64, bounded queueing
//! with explicit backpressure).
//!
//! The executor drives one of two backends (see [`server::EngineKind`]):
//! PJRT artifacts (`pjrt` feature) or the always-available pure-Rust
//! substrate, whose batch-major parallel `matmul` shards each released
//! batch across cores.

pub mod batcher;
pub mod metrics;
pub mod router;
pub mod server;

pub use batcher::{BatchPolicy, BatchQueue};
pub use metrics::Metrics;
pub use router::Router;
pub use server::{EngineKind, InferError, Response, Server, ServerConfig};
