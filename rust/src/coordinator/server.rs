//! The serving front-end: threads + channels around router, batcher, engine.
//!
//! One executor thread owns the (non-`Send`) PJRT engine and all batch
//! queues; any number of client threads call [`Server::infer`].  The
//! bounded request channel plus the per-queue `max_queue` give two layers
//! of backpressure, and all hot-path buffers (the padded batch input) are
//! reused across batches.

use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::atomic::Ordering;
use std::sync::{mpsc, Arc};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::coordinator::batcher::{BatchPolicy, BatchQueue, PushOutcome};
use crate::coordinator::metrics::Metrics;
use crate::coordinator::router::{RouteError, Router};
use crate::runtime::engine::{argmax_rows, literal_f32, Engine};
use crate::runtime::manifest::Manifest;

/// Inference result for one image.
#[derive(Debug, Clone)]
pub struct Response {
    pub label: u32,
    pub logits: Vec<f32>,
    /// end-to-end latency (enqueue -> response)
    pub latency: Duration,
    /// occupied size of the batch this request rode in
    pub batch_occupancy: usize,
}

/// Serving error taxonomy.
#[derive(Debug, thiserror::Error)]
pub enum InferError {
    #[error("routing: {0}")]
    Route(#[from] RouteError),
    #[error("rejected: server overloaded")]
    Rejected,
    #[error("server shut down")]
    Shutdown,
    #[error("execution failed: {0}")]
    Engine(String),
}

/// Server construction knobs.
#[derive(Debug, Clone)]
pub struct ServerConfig {
    pub artifacts_dir: PathBuf,
    pub policy: BatchPolicy,
    /// serve the Pallas-kernel-backed artifact variant where available
    pub use_pallas: bool,
}

impl Default for ServerConfig {
    fn default() -> Self {
        Self {
            artifacts_dir: Manifest::default_dir(),
            policy: BatchPolicy::default(),
            use_pallas: false,
        }
    }
}

struct Request {
    model: String,
    image: Vec<f32>,
    /// client-side submit time — the end-to-end latency origin (includes
    /// channel wait, unlike the batcher's queue-entry stamp)
    submitted: Instant,
    resp: mpsc::Sender<Result<Response, InferError>>,
}

/// A running coordinator.
pub struct Server {
    router: Arc<Router>,
    tx: Option<mpsc::SyncSender<Request>>,
    metrics: Arc<Metrics>,
    executor: Option<JoinHandle<()>>,
}

impl Server {
    /// Load the manifest, spawn the executor thread, return the handle.
    pub fn start(config: ServerConfig) -> anyhow::Result<Self> {
        let manifest = Manifest::load(&config.artifacts_dir)?;
        let router = Arc::new(Router::from_manifest(&manifest));
        let metrics = Arc::new(Metrics::new());
        let (tx, rx) = mpsc::sync_channel::<Request>(config.policy.max_queue);
        let exec_metrics = metrics.clone();
        let executor = std::thread::Builder::new()
            .name("circnn-executor".into())
            .spawn(move || executor_loop(manifest, config, rx, exec_metrics))?;
        Ok(Self {
            router,
            tx: Some(tx),
            metrics,
            executor: Some(executor),
        })
    }

    pub fn metrics(&self) -> &Metrics {
        &self.metrics
    }

    pub fn router(&self) -> &Router {
        &self.router
    }

    /// Blocking inference of one image.
    pub fn infer(&self, model: &str, image: &[f32]) -> Result<Response, InferError> {
        let rx = self.infer_async(model, image)?;
        rx.recv().map_err(|_| InferError::Shutdown)?
    }

    /// Enqueue one image; returns the response channel immediately.
    pub fn infer_async(
        &self,
        model: &str,
        image: &[f32],
    ) -> Result<mpsc::Receiver<Result<Response, InferError>>, InferError> {
        self.router.validate(model, image)?;
        self.metrics.requests.fetch_add(1, Ordering::Relaxed);
        let (resp_tx, resp_rx) = mpsc::channel();
        let req = Request {
            model: model.to_string(),
            image: image.to_vec(),
            submitted: Instant::now(),
            resp: resp_tx,
        };
        match self
            .tx
            .as_ref()
            .ok_or(InferError::Shutdown)?
            .try_send(req)
        {
            Ok(()) => Ok(resp_rx),
            Err(mpsc::TrySendError::Full(_)) => {
                self.metrics.rejected.fetch_add(1, Ordering::Relaxed);
                Err(InferError::Rejected)
            }
            Err(mpsc::TrySendError::Disconnected(_)) => Err(InferError::Shutdown),
        }
    }

    /// Graceful shutdown: drain in-flight work and join the executor.
    pub fn shutdown(mut self) {
        self.tx.take(); // close the channel; executor drains and exits
        if let Some(h) = self.executor.take() {
            let _ = h.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.tx.take();
        if let Some(h) = self.executor.take() {
            let _ = h.join();
        }
    }
}

/// State the executor keeps per model.
struct ModelState {
    queue: BatchQueue<Request>,
    artifact_path: PathBuf,
    input_shape: Vec<usize>,
    exec_batch: usize,
    image_elems: usize,
    classes: usize,
    /// reused padded input buffer (hot-path allocation avoidance)
    scratch: Vec<f32>,
}

fn executor_loop(
    manifest: Manifest,
    config: ServerConfig,
    rx: mpsc::Receiver<Request>,
    metrics: Arc<Metrics>,
) {
    let engine = match Engine::cpu() {
        Ok(e) => e,
        Err(err) => {
            // fail every request with a clear message
            drain_with_error(rx, &format!("PJRT init failed: {err}"));
            return;
        }
    };

    let mut states: HashMap<String, ModelState> = HashMap::new();
    for m in &manifest.models {
        let arts = if config.use_pallas && !m.artifacts_pallas.is_empty() {
            &m.artifacts_pallas
        } else {
            &m.artifacts
        };
        let Some(art) = arts.iter().max_by_key(|a| a.batch) else {
            continue;
        };
        let image_elems: usize = m.input_shape.iter().product();
        states.insert(
            m.name.clone(),
            ModelState {
                queue: BatchQueue::new(config.policy),
                artifact_path: manifest.path_of(&art.file),
                input_shape: art.input_shape.clone(),
                exec_batch: art.batch,
                image_elems,
                classes: *art.output_shape.last().unwrap_or(&10),
                scratch: vec![0.0; art.batch * image_elems],
            },
        );
    }

    loop {
        // poll timeout: earliest queue deadline, else a coarse tick
        let now = Instant::now();
        let timeout = states
            .values()
            .filter_map(|s| s.queue.next_deadline(now))
            .min()
            .unwrap_or(Duration::from_millis(50));

        match rx.recv_timeout(timeout) {
            Ok(req) => {
                let Some(state) = states.get_mut(&req.model) else {
                    let _ = req
                        .resp
                        .send(Err(InferError::Route(RouteError::UnknownModel(
                            req.model.clone(),
                        ))));
                    continue;
                };
                match state.queue.push(req, Instant::now()) {
                    PushOutcome::Rejected(req) => {
                        metrics.rejected.fetch_add(1, Ordering::Relaxed);
                        let _ = req.resp.send(Err(InferError::Rejected));
                    }
                    PushOutcome::BatchReady => {
                        execute_batch(&engine, state, &metrics);
                    }
                    PushOutcome::Queued => {}
                }
            }
            Err(mpsc::RecvTimeoutError::Timeout) => {}
            Err(mpsc::RecvTimeoutError::Disconnected) => {
                // drain remaining queued work, then exit
                for state in states.values_mut() {
                    while !state.queue.is_empty() {
                        execute_batch(&engine, state, &metrics);
                    }
                }
                return;
            }
        }

        // deadline-triggered partial batches
        let now = Instant::now();
        for state in states.values_mut() {
            if state.queue.ready(now) {
                execute_batch(&engine, state, &metrics);
            }
        }
    }
}

fn drain_with_error(rx: mpsc::Receiver<Request>, msg: &str) {
    while let Ok(req) = rx.recv() {
        let _ = req.resp.send(Err(InferError::Engine(msg.to_string())));
    }
}

fn execute_batch(engine: &Engine, state: &mut ModelState, metrics: &Metrics) {
    let pending = state.queue.drain_batch();
    if pending.is_empty() {
        return;
    }
    let occupied = pending.len();
    let padded = state.exec_batch - occupied;
    metrics.batches.fetch_add(1, Ordering::Relaxed);
    metrics
        .batched_items
        .fetch_add(occupied as u64, Ordering::Relaxed);
    metrics
        .padded_slots
        .fetch_add(padded as u64, Ordering::Relaxed);

    // assemble the padded batch into the reused scratch buffer
    state.scratch.fill(0.0);
    for (slot, p) in pending.iter().enumerate() {
        let dst = slot * state.image_elems;
        state.scratch[dst..dst + state.image_elems].copy_from_slice(&p.item.image);
    }

    let result = engine
        .load(&state.artifact_path)
        .and_then(|model| {
            let lit = literal_f32(&state.scratch, &state.input_shape)?;
            model.run1(&[lit])
        })
        .and_then(|out| Ok(out.to_vec::<f32>()?));

    match result {
        Ok(logits) => {
            let labels = argmax_rows(&logits, state.classes);
            for (slot, p) in pending.into_iter().enumerate() {
                let latency = p.item.submitted.elapsed();
                metrics.responses.fetch_add(1, Ordering::Relaxed);
                metrics.record_latency(latency);
                let row = &logits[slot * state.classes..(slot + 1) * state.classes];
                let _ = p.item.resp.send(Ok(Response {
                    label: labels[slot],
                    logits: row.to_vec(),
                    latency,
                    batch_occupancy: occupied,
                }));
            }
        }
        Err(err) => {
            let msg = err.to_string();
            for p in pending {
                let _ = p.item.resp.send(Err(InferError::Engine(msg.clone())));
            }
        }
    }
}

#[cfg(test)]
mod tests {
    // Server tests require compiled artifacts + the PJRT runtime; they live
    // in rust/tests/coordinator_load.rs.  The pure logic (batcher, router,
    // metrics) is tested in its own modules.
}
