//! The serving front-end: threads + channels around router, batcher, engine.
//!
//! One executor thread owns the execution backend and all batch queues; any
//! number of client threads call [`Server::infer`].  The bounded request
//! channel plus the per-queue `max_queue` give two layers of backpressure,
//! and all hot-path buffers (the padded batch input) are reused across
//! batches.
//!
//! Three backends implement the datapath behind the same batching policy:
//!
//! * **PJRT** (`pjrt` feature): compiled HLO artifacts through the `xla`
//!   crate — `PjRtClient` is not `Send`, so the single executor thread is
//!   structural, exactly the paper's one-FPGA story.
//! * **Native** (always available): the pure-Rust block-circulant substrate
//!   ([`crate::native`]).  Batches execute through the batch-major parallel
//!   [`BlockCirculant::matmul`](crate::circulant::BlockCirculant::matmul),
//!   so the datapath itself shards each released batch across cores.
//! * **Pipeline** (always available): the same native models behind the
//!   deep-pipelined engine ([`crate::pipeline`]) — released batches stream
//!   through per-layer stage workers with multiple batches in flight, and
//!   replies scatter from the last stage.  The executor thread only
//!   assembles and submits; `submit` blocking at the configured depth is
//!   the third backpressure layer.

use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::{mpsc, Arc};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::circulant::{sched, Precision};
use crate::coordinator::batcher::{BatchPolicy, BatchQueue, Pending, PushOutcome};
use crate::coordinator::metrics::Metrics;
use crate::coordinator::router::{RouteError, Router};
use crate::models;
use crate::native::{NativeModel, Tensor};
use crate::pipeline::{Pipeline, PipelinePlan};
use crate::telemetry::{self, Registry, Seg, SpanRecord, Tracer};
#[cfg(feature = "pjrt")]
use crate::runtime::engine::{literal_f32, Engine};
use crate::runtime::manifest::Manifest;
use crate::util::argmax_rows;

/// Inference result for one image.
#[derive(Debug, Clone)]
pub struct Response {
    pub label: u32,
    pub logits: Vec<f32>,
    /// end-to-end latency (enqueue -> response)
    pub latency: Duration,
    /// occupied size of the batch this request rode in
    pub batch_occupancy: usize,
}

/// Serving error taxonomy.
#[derive(Debug, thiserror::Error)]
pub enum InferError {
    #[error("routing: {0}")]
    Route(#[from] RouteError),
    #[error("rejected: server overloaded")]
    Rejected,
    #[error("server shut down")]
    Shutdown,
    #[error("execution failed: {0}")]
    Engine(String),
}

/// Which execution substrate the executor thread drives.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EngineKind {
    /// PJRT when the crate is built with the `pjrt` feature, else native.
    Auto,
    /// The pure-Rust block-circulant substrate (`crate::native`).
    Native,
    /// The native substrate behind the deep-pipelined serving engine
    /// (`crate::pipeline`): per-layer stage workers, multiple released
    /// batches in flight.  Replies scatter from the last stage's worker;
    /// per-batch results stay bitwise identical to [`EngineKind::Native`].
    Pipeline,
    /// Compiled HLO artifacts through PJRT.
    #[cfg(feature = "pjrt")]
    Pjrt,
}

/// Server construction knobs.
#[derive(Debug, Clone)]
pub struct ServerConfig {
    pub artifacts_dir: PathBuf,
    pub policy: BatchPolicy,
    /// serve the Pallas-kernel-backed artifact variant where available
    /// (PJRT backend only)
    pub use_pallas: bool,
    pub engine: EngineKind,
    /// [`EngineKind::Pipeline`] only: bound on concurrently in-flight
    /// batches per model (`None` = one per stage, the full pipeline)
    pub depth: Option<usize>,
    /// native/pipeline backends: when a model's params archive is missing,
    /// serve deterministic He-init random parameters
    /// ([`NativeModel::init_random`], fixed seed) instead of failing its
    /// requests — the demo/CI mode that needs no `make artifacts`
    pub init_random_fallback: bool,
    /// native/pipeline backends: executed datapath of the spectral MAC
    /// engine.  [`Precision::Fixed16`] runs every block-circulant layer
    /// through the int16 BFP engine at the manifest's `fixed_bits` width
    /// ([`NativeModel::set_precision`]); the PJRT backend ignores this.
    pub precision: Precision,
    /// enable per-request span tracing ([`crate::telemetry::Tracer`]):
    /// spans are minted at admission, stamped at batch release and reply
    /// scatter, and collected for [`Server::trace_waterfall`] /
    /// [`Server::telemetry_json`].  Also switched on by the registered
    /// `CIRCNN_TRACE` env knob; off (zero-overhead) by default, and
    /// results are bitwise identical either way (property-pinned).
    pub trace: bool,
}

impl Default for ServerConfig {
    fn default() -> Self {
        Self {
            artifacts_dir: Manifest::default_dir(),
            policy: BatchPolicy::default(),
            use_pallas: false,
            engine: EngineKind::Auto,
            depth: None,
            init_random_fallback: false,
            precision: Precision::F32,
            trace: false,
        }
    }
}

struct Request {
    model: String,
    image: Vec<f32>,
    /// client-side submit time — the end-to-end latency origin (includes
    /// channel wait, unlike the batcher's queue-entry stamp)
    submitted: Instant,
    /// span id minted at admission when tracing is on (0 = untraced)
    span_id: u64,
    resp: mpsc::Sender<Result<Response, InferError>>,
}

/// A running coordinator.
pub struct Server {
    router: Arc<Router>,
    tx: Option<mpsc::SyncSender<Request>>,
    metrics: Arc<Metrics>,
    tracer: Option<Arc<Tracer>>,
    executor: Option<JoinHandle<()>>,
}

/// Transport-agnostic submission seam: the validated enqueue half of the
/// server, cheap to clone into connection-handler threads (`rust/src/net`
/// holds one per TCP connection).  A `Frontend` does exactly what
/// [`Server::infer_async`] does — validate, count, mint a span, `try_send`
/// — but lets the caller stamp the admission instant, so the TCP path can
/// start the latency clock (and the span) at frame-decode time instead of
/// at submit time.
///
/// Holding a clone keeps the executor's request channel open: every
/// `Frontend` must drop before [`Server::begin_drain`]/`shutdown` can
/// drain, which is why the TCP server joins its readers first.
#[derive(Clone)]
pub struct Frontend {
    router: Arc<Router>,
    tx: mpsc::SyncSender<Request>,
    metrics: Arc<Metrics>,
    tracer: Option<Arc<Tracer>>,
}

impl Frontend {
    /// Validate + enqueue one image, stamped at `Instant::now()`.
    pub fn submit(
        &self,
        model: &str,
        image: Vec<f32>,
    ) -> Result<mpsc::Receiver<Result<Response, InferError>>, InferError> {
        self.submit_at(model, image, Instant::now())
    }

    /// Validate + enqueue with an explicit admission timestamp `at` — the
    /// end-to-end latency origin and (when tracing) the span's birth.  The
    /// TCP front-end passes the instant the request frame was decoded off
    /// the wire, so queueing inside the connection handler is charged to
    /// the request, not hidden.
    pub fn submit_at(
        &self,
        model: &str,
        image: Vec<f32>,
        at: Instant,
    ) -> Result<mpsc::Receiver<Result<Response, InferError>>, InferError> {
        self.router.validate(model, &image)?;
        self.metrics.requests.inc();
        let (resp_tx, resp_rx) = mpsc::channel();
        let span_id = match &self.tracer {
            Some(tracer) => tracer.admitted(model, at),
            None => 0,
        };
        let req = Request {
            model: model.to_string(),
            image,
            submitted: at,
            span_id,
            resp: resp_tx,
        };
        match self.tx.try_send(req) {
            Ok(()) => Ok(resp_rx),
            Err(mpsc::TrySendError::Full(_)) => {
                self.metrics.rejected.inc();
                if let Some(tracer) = &self.tracer {
                    tracer.abandon(span_id);
                }
                Err(InferError::Rejected)
            }
            Err(mpsc::TrySendError::Disconnected(_)) => Err(InferError::Shutdown),
        }
    }

    /// The serving metrics shared with the server this frontend feeds.
    pub fn metrics(&self) -> &Arc<Metrics> {
        &self.metrics
    }

    /// Whether the server behind this frontend records per-request spans.
    pub fn tracing(&self) -> bool {
        self.tracer.is_some()
    }

    /// Completed request spans with pipeline stage hops joined in — the
    /// same view as [`Server::trace_spans`], available from any clone (the
    /// live scrape endpoints hold a `Frontend`, not the server).
    pub fn trace_spans(&self) -> Vec<SpanRecord> {
        match &self.tracer {
            Some(tracer) => joined_spans(&self.metrics, tracer),
            None => Vec::new(),
        }
    }

    /// The `/trace.json` document (`{"truncated":N,"spans":[…]}`) for this
    /// server.  With tracing off it reports zero spans, not an error — a
    /// scraper can always tell "tracing disabled" (`truncated:0, spans:[]`)
    /// from "dropped history" (`truncated > 0`).
    pub fn trace_json(&self) -> String {
        let dropped = self.tracer.as_ref().map(|t| t.dropped_count()).unwrap_or(0);
        telemetry::trace_document(&self.trace_spans(), dropped)
    }
}

/// Join the tracer's completed spans with the per-stage busy intervals of
/// any attached pipeline (by batch sequence number — each matching
/// [`crate::pipeline::StageEvent`] becomes an `sN` segment, converted from
/// the pipeline's epoch to the tracer's).  Shared by [`Server::trace_spans`]
/// and [`Frontend::trace_spans`].
fn joined_spans(metrics: &Metrics, tracer: &Tracer) -> Vec<SpanRecord> {
    let mut spans = tracer.spans();
    for (model, stats) in metrics.pipelines() {
        let base = tracer.offset_us(stats.started());
        let events = stats.events.lock().unwrap_or_else(|e| e.into_inner());
        for span in spans.iter_mut().filter(|s| s.model == model) {
            let Some(seq) = span.seq else { continue };
            for e in events.iter().filter(|e| e.seq == seq) {
                span.segs.push(Seg {
                    label: format!("s{}", e.stage),
                    start_us: base + e.start_us,
                    end_us: base + e.end_us,
                });
            }
        }
    }
    spans
}

impl Server {
    /// Load the manifest, spawn the executor thread, return the handle.
    pub fn start(config: ServerConfig) -> anyhow::Result<Self> {
        let manifest = Manifest::load(&config.artifacts_dir)?;
        Self::start_with_manifest(manifest, config)
    }

    /// Start against an already-built manifest — the hook for
    /// [`Manifest::synthetic`] (registry-only serving, no artifacts on
    /// disk) and for tests that assemble manifests in memory.
    pub fn start_with_manifest(
        manifest: Manifest,
        mut config: ServerConfig,
    ) -> anyhow::Result<Self> {
        // a hand-built policy literal must not wedge the executor
        config.policy = config.policy.clamped();
        // the native substrate executes the policy's release size; only the
        // PJRT path is bound to a compiled artifact's batch
        #[cfg(feature = "pjrt")]
        let native_batch = matches!(config.engine, EngineKind::Native | EngineKind::Pipeline)
            .then_some(config.policy.max_batch.max(1));
        #[cfg(not(feature = "pjrt"))]
        let native_batch = Some(config.policy.max_batch.max(1));
        let router = Arc::new(Router::from_manifest_sized(&manifest, native_batch));
        let metrics = Arc::new(Metrics::new());
        let tracer = (config.trace || sched::env_flag("CIRCNN_TRACE"))
            .then(|| Tracer::new(metrics.registry()));
        let (tx, rx) = mpsc::sync_channel::<Request>(config.policy.max_queue);
        let exec_metrics = metrics.clone();
        let exec_tracer = tracer.clone();
        let executor = std::thread::Builder::new()
            .name("circnn-executor".into())
            .spawn(move || executor_loop(manifest, config, rx, exec_metrics, exec_tracer))?;
        Ok(Self {
            router,
            tx: Some(tx),
            metrics,
            tracer,
            executor: Some(executor),
        })
    }

    pub fn metrics(&self) -> &Metrics {
        &self.metrics
    }

    pub fn router(&self) -> &Router {
        &self.router
    }

    /// Whether this server is recording per-request spans.
    pub fn tracing(&self) -> bool {
        self.tracer.is_some()
    }

    /// Completed request spans, joined with the per-stage busy intervals of
    /// any attached pipeline (by batch sequence number — each matching
    /// [`crate::pipeline::StageEvent`] becomes an `sN` segment on the span,
    /// converted from the pipeline's epoch to the tracer's).  Empty when
    /// tracing is off or nothing has completed yet.
    pub fn trace_spans(&self) -> Vec<SpanRecord> {
        let Some(tracer) = &self.tracer else {
            return Vec::new();
        };
        joined_spans(&self.metrics, tracer)
    }

    /// ASCII waterfall of the completed spans ([`telemetry::render_waterfall`],
    /// with a `truncated: N` banner once the span ring has dropped history),
    /// or `None` when tracing is off.
    pub fn trace_waterfall(&self, width: usize) -> Option<String> {
        self.tracer
            .as_ref()
            .map(|t| telemetry::render_waterfall(&self.trace_spans(), width, t.dropped_count()))
    }

    /// One JSON document with everything observable about this server:
    /// `{"metrics": <registry exposition>, "spans": [<completed spans>],
    /// "trace_truncated": N}` — what `circnn serve --trace-dump PATH`
    /// writes.  `spans` stays a plain array (CI's validator iterates it);
    /// `trace_truncated` carries the span-ring drop count so a partial
    /// window is never mistaken for the full history.
    pub fn telemetry_json(&self) -> String {
        let dropped = self.tracer.as_ref().map(|t| t.dropped_count()).unwrap_or(0);
        format!(
            "{{\"metrics\":{},\"spans\":{},\"trace_truncated\":{}}}",
            self.metrics.export_json(),
            telemetry::spans_to_json(&self.trace_spans()),
            dropped,
        )
    }

    /// Blocking inference of one image.
    pub fn infer(&self, model: &str, image: &[f32]) -> Result<Response, InferError> {
        let rx = self.infer_async(model, image)?;
        rx.recv().map_err(|_| InferError::Shutdown)?
    }

    /// Enqueue one image; returns the response channel immediately.
    pub fn infer_async(
        &self,
        model: &str,
        image: &[f32],
    ) -> Result<mpsc::Receiver<Result<Response, InferError>>, InferError> {
        self.frontend()
            .ok_or(InferError::Shutdown)?
            .submit(model, image.to_vec())
    }

    /// A transport-agnostic submission handle sharing this server's
    /// router/metrics/tracer, or `None` once [`begin_drain`](Self::begin_drain)
    /// has closed the intake.
    pub fn frontend(&self) -> Option<Frontend> {
        Some(Frontend {
            router: self.router.clone(),
            tx: self.tx.as_ref()?.clone(),
            metrics: self.metrics.clone(),
            tracer: self.tracer.clone(),
        })
    }

    /// Close the request intake without tearing the server down: drop the
    /// server's own channel sender so — once every outstanding [`Frontend`]
    /// clone is gone too — the executor drains all queued batches (every
    /// admitted request still gets its answer) and exits.  Subsequent
    /// `infer*`/[`frontend`](Self::frontend) calls report `Shutdown`;
    /// metrics/telemetry stay readable, and a later
    /// [`shutdown`](Self::shutdown) just joins the executor.  The TCP
    /// front-end calls this between joining its readers and draining its
    /// writers.
    pub fn begin_drain(&mut self) {
        self.tx.take();
    }

    /// Graceful shutdown: drain in-flight work and join the executor.
    pub fn shutdown(mut self) {
        self.tx.take(); // close the channel; executor drains and exits
        if let Some(h) = self.executor.take() {
            let _ = h.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.tx.take();
        if let Some(h) = self.executor.take() {
            let _ = h.join();
        }
    }
}

/// Backend-specific execution state for one model.
enum ModelExec {
    #[cfg(feature = "pjrt")]
    Pjrt {
        artifact_path: PathBuf,
        input_shape: Vec<usize>,
        exec_batch: usize,
        /// classes per row of the artifact's declared output shape (the
        /// native path reads its head width off the logits instead)
        classes: usize,
    },
    Native {
        model: Box<NativeModel>,
        h: usize,
        w: usize,
        c: usize,
    },
    /// The native model behind the deep-pipelined engine: released batches
    /// stream into stage 0 and replies scatter from the last stage's
    /// worker (the sink).  The executor hands a batch off without running
    /// it; `submit` blocks only when this model is saturated (`depth`
    /// batches in flight), and then at most until the oldest clears one
    /// stage — strictly less executor stall than the serial path's inline
    /// forward.
    Pipeline {
        pipe: Pipeline<Vec<Pending<Request>>>,
        h: usize,
        w: usize,
        c: usize,
    },
    /// The model's execution state failed to initialize (params missing or
    /// malformed).  The router still admits its requests — they reach the
    /// executor and fail with the load error, instead of the misleading
    /// `UnknownModel` a silently-skipped model used to produce.
    Failed { reason: String },
}

/// State the executor keeps per model.
struct ModelState {
    queue: BatchQueue<Request>,
    exec: ModelExec,
    image_elems: usize,
    /// reused batch input buffer (hot-path allocation avoidance)
    scratch: Vec<f32>,
}

fn executor_loop(
    manifest: Manifest,
    config: ServerConfig,
    rx: mpsc::Receiver<Request>,
    metrics: Arc<Metrics>,
    tracer: Option<Arc<Tracer>>,
) {
    #[cfg(feature = "pjrt")]
    let use_pjrt = !matches!(config.engine, EngineKind::Native | EngineKind::Pipeline);
    #[cfg(not(feature = "pjrt"))]
    let use_pjrt = false;

    #[cfg(feature = "pjrt")]
    let engine = if use_pjrt {
        match Engine::cpu() {
            Ok(e) => Some(e),
            Err(err) => {
                // fail every request with a clear message
                drain_with_error(rx, &format!("PJRT init failed: {err}"));
                return;
            }
        }
    } else {
        None
    };

    let mut states: HashMap<String, ModelState> = HashMap::new();
    for m in &manifest.models {
        let arts = if config.use_pallas && !m.artifacts_pallas.is_empty() {
            &m.artifacts_pallas
        } else {
            &m.artifacts
        };
        let art = arts.iter().max_by_key(|a| a.batch);
        let image_elems: usize = m.input_shape.iter().product();
        let exec = if use_pjrt {
            match art {
                Some(art) => pjrt_exec(&manifest, art),
                // same contract as the native arm below: the router admits
                // this model, so don't vanish behind UnknownModel
                None => {
                    eprintln!(
                        "serve: {} has no compiled artifact; its requests will \
                         fail with an engine error",
                        m.name
                    );
                    ModelExec::Failed {
                        reason: format!("no compiled artifact for {}", m.name),
                    }
                }
            }
        } else {
            native_exec(&manifest, &config, &m.name, &metrics, tracer.as_ref())
        };
        let exec_batch = match &exec {
            #[cfg(feature = "pjrt")]
            ModelExec::Pjrt { exec_batch, .. } => *exec_batch,
            ModelExec::Native { .. }
            | ModelExec::Pipeline { .. }
            | ModelExec::Failed { .. } => config.policy.max_batch.max(1),
        };
        // a PJRT artifact executes a fixed batch size: cap this model's
        // release size at it so a larger policy.max_batch can neither
        // overflow the scratch buffer nor exceed the compiled batch
        let mut policy = config.policy;
        policy.max_batch = policy.max_batch.min(exec_batch).max(1);
        // a Failed model never assembles a batch, and the pipeline
        // assembles straight into each job's tensor — neither holds a
        // staging buffer
        let scratch = match &exec {
            ModelExec::Pipeline { .. } | ModelExec::Failed { .. } => Vec::new(),
            _ => vec![0.0; exec_batch * image_elems],
        };
        states.insert(
            m.name.clone(),
            ModelState { queue: BatchQueue::new(policy), exec, image_elems, scratch },
        );
    }

    #[cfg(feature = "pjrt")]
    let engine = engine.as_ref();
    #[cfg(not(feature = "pjrt"))]
    let engine = NoEngine;

    loop {
        // poll timeout: earliest queue deadline, else a coarse tick
        let now = Instant::now();
        let timeout = states
            .values()
            .filter_map(|s| s.queue.next_deadline(now))
            .min()
            .unwrap_or(Duration::from_millis(50));

        match rx.recv_timeout(timeout) {
            Ok(req) => {
                let Some(state) = states.get_mut(&req.model) else {
                    let _ = req
                        .resp
                        .send(Err(InferError::Route(RouteError::UnknownModel(
                            req.model.clone(),
                        ))));
                    continue;
                };
                // a Failed model's outcome is known now: answer immediately
                // instead of letting the request ride out the batch deadline
                if let ModelExec::Failed { reason } = &state.exec {
                    metrics.rejected.inc();
                    if let Some(tracer) = &tracer {
                        tracer.abandon(req.span_id);
                    }
                    let _ = req.resp.send(Err(InferError::Engine(reason.clone())));
                    continue;
                }
                match state.queue.push(req, Instant::now()) {
                    PushOutcome::Rejected(req) => {
                        metrics.rejected.inc();
                        if let Some(tracer) = &tracer {
                            tracer.abandon(req.span_id);
                        }
                        let _ = req.resp.send(Err(InferError::Rejected));
                    }
                    PushOutcome::BatchReady => {
                        execute_batch(engine, state, &metrics, tracer.as_deref());
                    }
                    PushOutcome::Queued => {}
                }
            }
            Err(mpsc::RecvTimeoutError::Timeout) => {}
            Err(mpsc::RecvTimeoutError::Disconnected) => {
                // drain remaining queued work, then exit
                for state in states.values_mut() {
                    while !state.queue.is_empty() {
                        execute_batch(engine, state, &metrics, tracer.as_deref());
                    }
                }
                metrics.queue_depth.set(0);
                metrics.refresh_inflight();
                return;
            }
        }

        // deadline-triggered partial batches
        let now = Instant::now();
        for state in states.values_mut() {
            if state.queue.ready(now) {
                execute_batch(engine, state, &metrics, tracer.as_deref());
            }
        }

        // refresh the live depth gauges once per poll iteration — the
        // snapshot ticker and the scrape endpoints read these, so a scrape
        // mid-burst sees the queue as it actually is, not as it averaged
        let depth: usize = states.values().map(|s| s.queue.len()).sum();
        metrics.queue_depth.set(depth as u64);
        metrics.refresh_inflight();
    }
}

#[cfg(feature = "pjrt")]
fn drain_with_error(rx: mpsc::Receiver<Request>, msg: &str) {
    while let Ok(req) = rx.recv() {
        let _ = req.resp.send(Err(InferError::Engine(msg.to_string())));
    }
}

/// Build the PJRT execution state for one artifact.
#[cfg(feature = "pjrt")]
fn pjrt_exec(manifest: &Manifest, art: &crate::runtime::manifest::ArtifactEntry) -> ModelExec {
    ModelExec::Pjrt {
        artifact_path: manifest.path_of(&art.file),
        input_shape: art.input_shape.clone(),
        exec_batch: art.batch,
        classes: art.output_shape.last().copied().unwrap_or(10),
    }
}

/// Stub: without the `pjrt` feature `use_pjrt` is statically false, so this
/// is never reached — it exists only to keep the call site well-typed.
#[cfg(not(feature = "pjrt"))]
fn pjrt_exec(_manifest: &Manifest, _art: &crate::runtime::manifest::ArtifactEntry) -> ModelExec {
    unreachable!("pjrt backend requested without the pjrt feature")
}

#[cfg(feature = "pjrt")]
type EngineRef<'a> = Option<&'a Engine>;

/// Zero-sized stand-in for the engine handle when PJRT is compiled out.
#[cfg(not(feature = "pjrt"))]
#[derive(Clone, Copy)]
struct NoEngine;
#[cfg(not(feature = "pjrt"))]
type EngineRef<'a> = NoEngine;

/// Fixed seed for the [`ServerConfig::init_random_fallback`] parameters —
/// deterministic, so two servers (e.g. serial vs pipelined in the
/// equivalence tests) serve bit-identical weights.
const INIT_RANDOM_SEED: u64 = 0x5EED;

/// Build the native-substrate execution state for one model: registry
/// program + trained params archive (or the deterministic random-init
/// fallback), wrapped in the layer pipeline when the config asks for it.
/// A load failure must not silently drop the model — the router already
/// admits its requests, so a `Failed` state answers them with the real
/// error.
fn native_exec(
    manifest: &Manifest,
    config: &ServerConfig,
    name: &str,
    metrics: &Arc<Metrics>,
    tracer: Option<&Arc<Tracer>>,
) -> ModelExec {
    let Some(model) = models::by_name(name) else {
        eprintln!(
            "serve: {name} not in the native registry; its requests will \
             fail with an engine error"
        );
        return ModelExec::Failed {
            reason: format!("model {name} is not in the native registry"),
        };
    };
    let path = manifest.dir.join("params").join(format!("{name}.npz"));
    let mut native = match NativeModel::load(&model, &path, Some(manifest.quant_bits as u32)) {
        Ok(native) => native,
        Err(err) if config.init_random_fallback => {
            eprintln!(
                "serve: {name}: {err:#}; serving deterministic random-init \
                 parameters instead (init_random_fallback)"
            );
            let mut native = NativeModel::init_random(&model, INIT_RANDOM_SEED);
            native.quant_bits = Some(manifest.quant_bits as u32);
            native
        }
        Err(err) => {
            eprintln!(
                "serve: {name}: {err:#}; its requests will fail with an \
                 engine error"
            );
            return ModelExec::Failed {
                reason: format!("native params for {name} failed to load: {err:#}"),
            };
        }
    };
    // one hook covers both the serial native arm and the pipeline (the
    // pipeline's stage workers run the same `NativeModel::run_ops` path)
    native.set_precision(config.precision, Some(manifest.fixed_bits as u32));
    publish_phase_charge(metrics.registry(), &model, config.precision);
    let (h, w, c) = model.input;
    if !matches!(config.engine, EngineKind::Pipeline) {
        return ModelExec::Native { model: Box::new(native), h, w, c };
    }
    // pipelined backend: per-layer stage workers over the same model; the
    // last stage's sink owns the reply scatter and its metrics bookkeeping
    let native = Arc::new(native);
    let sink_metrics = metrics.clone();
    let sink_tracer = tracer.cloned();
    let pipe = Pipeline::start(
        native.clone(),
        PipelinePlan::auto(&native),
        config.depth,
        move |tensor: Tensor, pending: Vec<Pending<Request>>| {
            // the native head defines its own class count (no padded rows)
            let classes = tensor.data.len() / pending.len().max(1);
            scatter_batch(&sink_metrics, sink_tracer.as_deref(), &tensor.data, classes, pending);
        },
    );
    metrics.attach_pipeline(name, pipe.stats().clone());
    ModelExec::Pipeline { pipe, h, w, c }
}

/// Publish the analytic per-layer FFT-work *charge* of `model` into the
/// registry as gauges, labelled by model/layer/precision.  Together with the
/// executed [`crate::circulant::sched::PhaseCounters`] parity (pinned in
/// `native::staged`), this makes the paper's FftWork accounting visible at
/// runtime next to the serving counters it explains.
fn publish_phase_charge(registry: &Arc<Registry>, model: &models::Model, precision: Precision) {
    let precision = format!("{precision:?}").to_lowercase();
    for (i, row) in model.accounting().iter().enumerate() {
        let labels = [
            ("model", model.name.to_string()),
            ("layer", format!("{i:02}_{}", row.kind)),
            ("precision", precision.clone()),
        ];
        registry
            .gauge_with("model_layer_ffts_per_image", &labels)
            .set(row.fft_work.ffts_total);
        registry
            .gauge_with("model_layer_iffts_per_image", &labels)
            .set(row.fft_work.iffts_total);
        registry
            .gauge_with("model_layer_mult_groups_per_image", &labels)
            .set(row.fft_work.mult_groups_total);
    }
}

/// Scatter one executed batch's logits back to its requests (argmax +
/// latency bookkeeping) — shared by the serial executor and the pipeline
/// sink.  `logits` may carry padded tail rows (PJRT); only the `pending`
/// prefix is scattered.
fn scatter_batch(
    metrics: &Metrics,
    tracer: Option<&Tracer>,
    logits: &[f32],
    classes: usize,
    pending: Vec<Pending<Request>>,
) {
    let occupied = pending.len();
    let labels = argmax_rows(logits, classes);
    let done = Instant::now();
    for (slot, p) in pending.into_iter().enumerate() {
        let latency = p.item.submitted.elapsed();
        metrics.responses.inc();
        metrics.record_latency(latency);
        if let Some(tracer) = tracer {
            tracer.finished(p.item.span_id, done);
        }
        let row = &logits[slot * classes..(slot + 1) * classes];
        let _ = p.item.resp.send(Ok(Response {
            label: labels[slot],
            logits: row.to_vec(),
            latency,
            batch_occupancy: occupied,
        }));
    }
}

fn execute_batch(
    engine: EngineRef<'_>,
    state: &mut ModelState,
    metrics: &Metrics,
    tracer: Option<&Tracer>,
) {
    #[cfg(not(feature = "pjrt"))]
    let _ = engine;
    let pending = state.queue.drain_batch();
    if pending.is_empty() {
        return;
    }
    if let ModelExec::Failed { reason } = &state.exec {
        // count these as shed load so the books stay balanced
        // (requests == responses + rejected) — no batch ever executes
        metrics.rejected.add(pending.len() as u64);
        for p in pending {
            if let Some(tracer) = tracer {
                tracer.abandon(p.item.span_id);
            }
            let _ = p.item.resp.send(Err(InferError::Engine(reason.clone())));
        }
        return;
    }
    let occupied = pending.len();
    // batch release: every drained request's queue wait lands in the
    // `queue_wait_us` histogram, and its span (if traced) moves from the
    // queue segment into exec
    let released = Instant::now();
    for p in &pending {
        metrics.record_queue_wait(released.duration_since(p.enqueued));
    }
    // span ids survive `pending` being moved into the pipeline below; the
    // pipeline arm stamps them with the batch seq once `submit` assigns it
    let span_ids: Vec<u64> = match tracer {
        Some(_) => pending.iter().map(|p| p.item.span_id).collect(),
        None => Vec::new(),
    };

    if let ModelExec::Pipeline { pipe, h, w, c } = &state.exec {
        // assemble straight into the job tensor (no scratch staging — the
        // pipeline pads nothing, so the extra copy would buy nothing) and
        // stream into stage 0.  `submit` returns immediately unless this
        // model already has `depth` batches in flight; then it blocks
        // until the oldest clears one stage — which stalls the executor
        // (and every model's deadlines) for at most that long, the same
        // head-of-line cost the serial path pays on *every* batch by
        // running the full forward inline.
        let mut imgs = Vec::with_capacity(occupied * state.image_elems);
        for p in &pending {
            imgs.extend_from_slice(&p.item.image);
        }
        match pipe.submit_tensor(
            Tensor { batch: occupied, h: *h, w: *w, c: *c, data: imgs },
            pending,
        ) {
            Ok(seq) => {
                // counted as executed only once the batch is in flight,
                // mirroring the serial path's books (requests ==
                // responses + rejected); the sink does the response-side
                // accounting
                metrics.batches.inc();
                metrics.batched_items.add(occupied as u64);
                if let Some(tracer) = tracer {
                    // the sink may have already finished a fast span; a
                    // late `released` on a completed span is a no-op
                    for id in &span_ids {
                        tracer.released(*id, released, Some(seq));
                    }
                }
            }
            Err(err) => {
                // stage workers gone (sink died / teardown raced us): the
                // payload comes back — fail its requests instead of
                // dropping them, and balance the books as shed load
                let reason = err.to_string();
                let pending = err.payload;
                metrics.rejected.add(pending.len() as u64);
                for p in pending {
                    if let Some(tracer) = tracer {
                        tracer.abandon(p.item.span_id);
                    }
                    let _ = p.item.resp.send(Err(InferError::Engine(reason.clone())));
                }
            }
        }
        return;
    }

    // assemble the batch into the reused scratch buffer (the occupied
    // prefix is fully overwritten, so only the PJRT pad tail needs zeroing)
    for (slot, p) in pending.iter().enumerate() {
        let dst = slot * state.image_elems;
        state.scratch[dst..dst + state.image_elems].copy_from_slice(&p.item.image);
    }

    let (result, padded) = match &state.exec {
        #[cfg(feature = "pjrt")]
        ModelExec::Pjrt { artifact_path, input_shape, exec_batch, .. } => {
            // lint:allow(unwrap): Pjrt exec state is only ever built when
            // the executor owns an engine (start() invariant)
            let engine = engine.expect("pjrt state without engine");
            state.scratch[occupied * state.image_elems..].fill(0.0);
            let r = engine
                .load(artifact_path)
                .and_then(|model| {
                    let lit = literal_f32(&state.scratch, input_shape)?;
                    model.run1(&[lit])
                })
                .and_then(|out| Ok(out.to_vec::<f32>()?))
                .map_err(|e| e.to_string());
            (r, exec_batch - occupied)
        }
        ModelExec::Native { model, h, w, c } => {
            // the native substrate takes the occupied batch as-is (no
            // padding); the conv/matmul phases shard it across cores
            let imgs = &state.scratch[..occupied * state.image_elems];
            (Ok(model.forward(imgs, occupied, *h, *w, *c)), 0)
        }
        ModelExec::Pipeline { .. } | ModelExec::Failed { .. } => {
            unreachable!("handled before batch assembly")
        }
    };

    metrics.batches.inc();
    metrics.batched_items.add(occupied as u64);
    metrics.padded_slots.add(padded as u64);

    match result {
        Ok(logits) => {
            if let Some(tracer) = tracer {
                // serial path: the batch was "released" and executed inline
                // on this thread — no pipeline seq to join stage hops on
                for id in &span_ids {
                    tracer.released(*id, released, None);
                }
            }
            // the native head defines its own class count; the artifact's
            // declared output shape only binds the PJRT path
            let classes = match &state.exec {
                ModelExec::Native { .. } => logits.len() / occupied,
                #[cfg(feature = "pjrt")]
                ModelExec::Pjrt { classes, .. } => *classes,
                ModelExec::Pipeline { .. } | ModelExec::Failed { .. } => {
                    unreachable!("handled before batch assembly")
                }
            };
            scatter_batch(metrics, tracer, &logits, classes, pending);
        }
        Err(err) => {
            // engine-failed requests are shed load, same bookkeeping as the
            // Failed-model path: requests == responses + rejected
            metrics.rejected.add(pending.len() as u64);
            for p in pending {
                if let Some(tracer) = tracer {
                    tracer.abandon(p.item.span_id);
                }
                let _ = p.item.resp.send(Err(InferError::Engine(err.clone())));
            }
        }
    }
}

#[cfg(test)]
mod tests {
    // Server tests require compiled artifacts (and, for the PJRT backend,
    // the xla runtime); they live in rust/tests/coordinator_load.rs.  The
    // pure logic (batcher, router, metrics) is tested in its own modules.
}
