//! Request router: validate and dispatch to per-model queues.
//!
//! The router is the admission front of the coordinator: it checks the
//! target model exists, the payload has the right geometry, and applies
//! queue backpressure.  Routing is by model name — each name maps to one
//! compiled artifact (≈ one bitstream), mirroring the paper's
//! reconfigurability story.

use std::collections::HashMap;

use crate::runtime::manifest::{Manifest, ModelEntry};

/// Routing error taxonomy (stable for clients/tests).
#[derive(Debug, Clone, PartialEq, Eq, thiserror::Error)]
pub enum RouteError {
    #[error("unknown model {0:?}")]
    UnknownModel(String),
    #[error("bad input size: expected {expected}, got {got}")]
    BadInputSize { expected: usize, got: usize },
    #[error("non-finite value in input at index {0}")]
    NonFinite(usize),
}

/// Immutable routing table derived from the manifest.
#[derive(Debug, Clone)]
pub struct Router {
    table: HashMap<String, RouteTarget>,
}

/// What the router knows about one model.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct RouteTarget {
    pub model: String,
    pub dataset: String,
    /// per-image element count (H*W*C)
    pub image_elems: usize,
    /// batch the executor runs: the artifact batch it pads to on the PJRT
    /// path, the policy's release size on the native path
    /// ([`Router::from_manifest_sized`])
    pub exec_batch: usize,
}

impl Router {
    /// Build the routing table from the manifest (artifact-sized exec
    /// batches — the PJRT story).
    pub fn from_manifest(man: &Manifest) -> Self {
        Self::from_manifest_sized(man, None)
    }

    /// Build the routing table with an explicit exec batch.  The native
    /// substrate executes whatever the batching policy releases rather
    /// than a compiled artifact's fixed batch, so the server passes its
    /// `policy.max_batch` here; `None` keeps the artifact-derived sizes.
    pub fn from_manifest_sized(man: &Manifest, exec_batch: Option<usize>) -> Self {
        let mut table = HashMap::new();
        for m in &man.models {
            let mut target = RouteTarget::from_entry(m);
            if let Some(b) = exec_batch {
                target.exec_batch = b;
            }
            table.insert(m.name.clone(), target);
        }
        Self { table }
    }

    pub fn models(&self) -> impl Iterator<Item = &str> {
        self.table.keys().map(|s| s.as_str())
    }

    pub fn target(&self, model: &str) -> Result<&RouteTarget, RouteError> {
        self.table
            .get(model)
            .ok_or_else(|| RouteError::UnknownModel(model.to_string()))
    }

    /// Validate one request payload for `model`.
    pub fn validate(&self, model: &str, image: &[f32]) -> Result<&RouteTarget, RouteError> {
        let target = self.target(model)?;
        if image.len() != target.image_elems {
            return Err(RouteError::BadInputSize {
                expected: target.image_elems,
                got: image.len(),
            });
        }
        if let Some(i) = image.iter().position(|v| !v.is_finite()) {
            return Err(RouteError::NonFinite(i));
        }
        Ok(target)
    }
}

impl RouteTarget {
    pub fn from_entry(m: &ModelEntry) -> Self {
        let image_elems: usize = m.input_shape.iter().product();
        // pad to the largest exported batch (the paper's interleaved batch)
        let exec_batch = m
            .artifacts
            .iter()
            .map(|a| a.batch)
            .max()
            .unwrap_or(m.serve_batch);
        Self {
            model: m.name.clone(),
            dataset: m.dataset.clone(),
            image_elems,
            exec_batch,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::manifest::{Accuracy, ArtifactEntry};

    fn entry(name: &str) -> ModelEntry {
        ModelEntry {
            name: name.into(),
            dataset: "mnist_s".into(),
            input_shape: vec![28, 28, 1],
            serve_batch: 64,
            accuracy: Accuracy {
                circulant_12bit: 0.9,
                circulant_f32: 0.9,
                dense_f32: 0.95,
            },
            paper_accuracy: 92.9,
            paper_kfps: 1.0,
            paper_kfps_per_w: 1.0,
            storage_reduction: 50.0,
            equivalent_ops_per_image: 1,
            artifacts: vec![
                ArtifactEntry {
                    batch: 1,
                    file: "a_b1.hlo.txt".into(),
                    input_shape: vec![1, 28, 28, 1],
                    output_shape: vec![1, 10],
                },
                ArtifactEntry {
                    batch: 64,
                    file: "a_b64.hlo.txt".into(),
                    input_shape: vec![64, 28, 28, 1],
                    output_shape: vec![64, 10],
                },
            ],
            artifacts_pallas: vec![],
            training: None,
        }
    }

    fn router() -> Router {
        let mut table = HashMap::new();
        table.insert("m".to_string(), RouteTarget::from_entry(&entry("m")));
        Router { table }
    }

    #[test]
    fn routes_known_model() {
        let r = router();
        let t = r.validate("m", &vec![0.0; 784]).unwrap();
        assert_eq!(t.exec_batch, 64);
        assert_eq!(t.image_elems, 784);
    }

    #[test]
    fn sized_table_advertises_the_native_batch() {
        // the native substrate executes the policy's release size, not the
        // compiled artifact's batch — the sized constructor reflects that
        let man = Manifest {
            dir: std::path::PathBuf::new(),
            quant_bits: 12,
            fixed_bits: 12,
            models: vec![entry("m")],
            dataset_checksums: std::collections::HashMap::new(),
        };
        let artifact_sized = Router::from_manifest(&man);
        assert_eq!(artifact_sized.target("m").unwrap().exec_batch, 64);
        let native_sized = Router::from_manifest_sized(&man, Some(16));
        assert_eq!(native_sized.target("m").unwrap().exec_batch, 16);
    }

    #[test]
    fn rejects_unknown_model() {
        assert_eq!(
            router().validate("nope", &[]),
            Err(RouteError::UnknownModel("nope".into()))
        );
    }

    #[test]
    fn rejects_bad_geometry() {
        assert_eq!(
            router().validate("m", &vec![0.0; 100]),
            Err(RouteError::BadInputSize {
                expected: 784,
                got: 100
            })
        );
    }

    #[test]
    fn rejects_non_finite() {
        let mut img = vec![0.0f32; 784];
        img[7] = f32::NAN;
        assert_eq!(router().validate("m", &img), Err(RouteError::NonFinite(7)));
    }

    #[test]
    fn prop_validation_is_total() {
        // router never panics on arbitrary inputs
        let r = router();
        crate::util::prop::forall(
            "router total",
            |rng| {
                let n = rng.below(1000) as usize;
                rng.normal_vec(n)
            },
            |img| {
                let _ = r.validate("m", img);
                let _ = r.validate("other", img);
                Ok(())
            },
        );
    }
}
