//! Dynamic batching: the paper's batch-processing knob as a serving policy.
//!
//! Pure logic (no threads, no engine) so the policy is unit- and
//! property-testable: requests accumulate per model; a batch is released
//! when it reaches `max_batch` (the paper's 50-100 design point, we default
//! to the artifact's 64) or when the oldest request has waited `max_delay`.

use std::collections::VecDeque;
use std::time::{Duration, Instant};

/// Batching policy knobs.
#[derive(Debug, Clone, Copy)]
pub struct BatchPolicy {
    /// release as soon as this many requests are queued
    pub max_batch: usize,
    /// release a partial batch once the oldest entry is this old
    pub max_delay: Duration,
    /// admission limit: queue length beyond which pushes are rejected
    /// (backpressure)
    pub max_queue: usize,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        Self {
            max_batch: 64,
            max_delay: Duration::from_millis(2),
            max_queue: 4096,
        }
    }
}

impl BatchPolicy {
    /// Construct a policy with the degenerate edges clamped away
    /// (see [`clamped`](Self::clamped)).
    pub fn new(max_batch: usize, max_delay: Duration, max_queue: usize) -> Self {
        Self { max_batch, max_delay, max_queue }.clamped()
    }

    /// Clamp the two silently-deadlocking edges:
    ///
    /// * `max_batch == 0` → every push reports `BatchReady` but
    ///   `drain_batch` removes zero items, so the queue fills and no
    ///   request is ever answered — clamped to 1;
    /// * `max_queue < max_batch` → a size-triggered release can never
    ///   assemble (admission rejects before the batch fills), leaving
    ///   every batch to the deadline path — clamped to `max_queue >=
    ///   max_batch`.
    ///
    /// [`new`](Self::new) and the server (`Server::start*`) apply this, so
    /// a hand-built policy literal cannot wedge the serving executor.
    /// `BatchQueue::new` takes the policy as given — property tests build
    /// deliberately extreme literals (e.g. `max_batch: usize::MAX` as a
    /// never-release queue) against the raw queue logic.
    pub fn clamped(mut self) -> Self {
        self.max_batch = self.max_batch.max(1);
        self.max_queue = self.max_queue.max(self.max_batch);
        self
    }
}

/// A queued unit of work.
#[derive(Debug)]
pub struct Pending<T> {
    pub item: T,
    pub enqueued: Instant,
}

/// Outcome of a push.  Rejection hands the item back so the caller can
/// reply with a backpressure error instead of silently dropping it.
#[derive(Debug, PartialEq, Eq)]
pub enum PushOutcome<T> {
    /// accepted; no batch ready yet
    Queued,
    /// accepted and the queue reached `max_batch` — caller should drain
    BatchReady,
    /// rejected: queue full (backpressure); the item is returned
    Rejected(T),
}

/// Per-model request queue implementing the policy.
#[derive(Debug)]
pub struct BatchQueue<T> {
    policy: BatchPolicy,
    queue: VecDeque<Pending<T>>,
}

impl<T> BatchQueue<T> {
    pub fn new(policy: BatchPolicy) -> Self {
        Self {
            policy,
            queue: VecDeque::new(),
        }
    }

    pub fn len(&self) -> usize {
        self.queue.len()
    }

    pub fn is_empty(&self) -> bool {
        self.queue.is_empty()
    }

    /// Push a request at time `now`.
    pub fn push(&mut self, item: T, now: Instant) -> PushOutcome<T> {
        if self.queue.len() >= self.policy.max_queue {
            return PushOutcome::Rejected(item);
        }
        self.queue.push_back(Pending {
            item,
            enqueued: now,
        });
        if self.queue.len() >= self.policy.max_batch {
            PushOutcome::BatchReady
        } else {
            PushOutcome::Queued
        }
    }

    /// True when a (possibly partial) batch should be released at `now`.
    pub fn ready(&self, now: Instant) -> bool {
        if self.queue.len() >= self.policy.max_batch {
            return true;
        }
        match self.queue.front() {
            Some(front) => now.duration_since(front.enqueued) >= self.policy.max_delay,
            None => false,
        }
    }

    /// Time until the deadline of the oldest entry (drives the executor's
    /// poll timeout); `None` when empty.
    pub fn next_deadline(&self, now: Instant) -> Option<Duration> {
        self.queue.front().map(|front| {
            self.policy
                .max_delay
                .saturating_sub(now.duration_since(front.enqueued))
        })
    }

    /// Remove and return up to `max_batch` requests.
    pub fn drain_batch(&mut self) -> Vec<Pending<T>> {
        let n = self.queue.len().min(self.policy.max_batch);
        self.queue.drain(..n).collect()
    }

    pub fn policy(&self) -> BatchPolicy {
        self.policy
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn policy(max_batch: usize, delay_ms: u64, max_queue: usize) -> BatchPolicy {
        BatchPolicy {
            max_batch,
            max_delay: Duration::from_millis(delay_ms),
            max_queue,
        }
    }

    #[test]
    fn fills_to_max_batch() {
        let mut q = BatchQueue::new(policy(4, 1000, 100));
        let t0 = Instant::now();
        assert_eq!(q.push(1, t0), PushOutcome::Queued);
        assert_eq!(q.push(2, t0), PushOutcome::Queued);
        assert_eq!(q.push(3, t0), PushOutcome::Queued);
        assert_eq!(q.push(4, t0), PushOutcome::BatchReady);
        let batch = q.drain_batch();
        assert_eq!(batch.len(), 4);
        assert!(q.is_empty());
    }

    #[test]
    fn deadline_releases_partial_batch() {
        let mut q = BatchQueue::new(policy(64, 2, 100));
        let t0 = Instant::now();
        q.push(1, t0);
        assert!(!q.ready(t0));
        assert!(q.ready(t0 + Duration::from_millis(3)));
        assert_eq!(q.drain_batch().len(), 1);
    }

    #[test]
    fn backpressure_rejects_when_full() {
        let mut q = BatchQueue::new(policy(64, 1, 2));
        let t0 = Instant::now();
        assert_eq!(q.push(1, t0), PushOutcome::Queued);
        assert_eq!(q.push(2, t0), PushOutcome::Queued);
        assert_eq!(q.push(3, t0), PushOutcome::Rejected(3));
        assert_eq!(q.len(), 2);
    }

    #[test]
    fn drain_caps_at_max_batch() {
        let mut q = BatchQueue::new(policy(2, 1000, 100));
        let t0 = Instant::now();
        for i in 0..5 {
            q.push(i, t0);
        }
        assert_eq!(q.drain_batch().len(), 2);
        assert_eq!(q.len(), 3);
    }

    #[test]
    fn next_deadline_counts_down() {
        let mut q = BatchQueue::new(policy(64, 10, 100));
        let t0 = Instant::now();
        assert!(q.next_deadline(t0).is_none());
        q.push(1, t0);
        let d = q.next_deadline(t0 + Duration::from_millis(4)).unwrap();
        assert!(d <= Duration::from_millis(6));
    }

    #[test]
    fn zero_max_batch_is_clamped_not_deadlocked() {
        // max_batch = 0 reports BatchReady on every push while drain_batch
        // removes nothing — the queue would fill and no request would ever
        // be answered; the constructor clamps the edge away
        let p = BatchPolicy::new(0, Duration::from_millis(1), 8);
        assert_eq!(p.max_batch, 1);
        let mut q = BatchQueue::new(p);
        let t0 = Instant::now();
        assert_eq!(q.push(7, t0), PushOutcome::BatchReady);
        assert_eq!(q.drain_batch().len(), 1, "a released batch must drain work");
        assert!(q.is_empty());
    }

    #[test]
    fn max_queue_below_max_batch_is_clamped() {
        // max_queue < max_batch could never assemble a size-triggered
        // batch: admission would reject the fill before it reached
        // max_batch, leaving every request to the deadline path
        let p = BatchPolicy::new(8, Duration::from_millis(1), 3);
        assert_eq!((p.max_batch, p.max_queue), (8, 8));
        let mut q = BatchQueue::new(p);
        let t0 = Instant::now();
        for i in 0..7 {
            assert_eq!(q.push(i, t0), PushOutcome::Queued, "push {i}");
        }
        assert_eq!(q.push(7, t0), PushOutcome::BatchReady);
        assert_eq!(q.drain_batch().len(), 8);
        // a valid policy (the Default) is untouched by the clamp
        let ok = BatchPolicy::default().clamped();
        assert_eq!((ok.max_batch, ok.max_queue), (64, 4096));
    }

    #[test]
    fn prop_queue_never_exceeds_max_queue() {
        crate::util::prop::forall(
            "bounded queue",
            |r| {
                let cap = 1 + r.below(20) as usize;
                let pushes = r.below(100) as usize;
                (cap, pushes)
            },
            |&(cap, pushes)| {
                let mut q = BatchQueue::new(policy(8, 1000, cap));
                let t0 = Instant::now();
                for i in 0..pushes {
                    q.push(i, t0);
                    if q.len() > cap {
                        return Err(format!("queue grew to {} > cap {cap}", q.len()));
                    }
                    if q.len() == 8 {
                        q.drain_batch();
                    }
                }
                Ok(())
            },
        );
    }
}
