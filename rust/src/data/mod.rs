//! Bit-exact Rust mirror of the Python synthetic datasets
//! (`python/compile/data.py`).
//!
//! Both sides generate data from closed-form splitmix64 streams
//! ([`crate::util::rng`]), so every f32 matches bit-for-bit: the serving
//! examples, the Rust training driver, and the Python training pipeline all
//! see the same samples.  The contract is pinned by the dataset checksums in
//! the artifact manifest (`rust/tests/integration.rs`).

use crate::util::rng::{combine, mix, u01_at, GAMMA};

pub const NUM_CLASSES: usize = 10;
pub const MODES: u64 = 10;
pub const NOISE_AMP: f32 = 1.0;
pub const TEST_INDEX_OFFSET: u64 = 1 << 20;

/// Dataset geometry (mirrors `data.DATASETS`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct DatasetSpec {
    pub name: &'static str,
    pub h: usize,
    pub w: usize,
    pub c: usize,
    pub grid: usize,
    pub factor: usize,
    seed: u64,
}

impl DatasetSpec {
    pub fn pixels(&self) -> usize {
        self.h * self.w * self.c
    }
}

pub const MNIST_S: DatasetSpec = DatasetSpec {
    name: "mnist_s", h: 28, w: 28, c: 1, grid: 7, factor: 4, seed: 101,
};
pub const SVHN_S: DatasetSpec = DatasetSpec {
    name: "svhn_s", h: 32, w: 32, c: 3, grid: 8, factor: 4, seed: 202,
};
pub const CIFAR_S: DatasetSpec = DatasetSpec {
    name: "cifar_s", h: 32, w: 32, c: 3, grid: 8, factor: 4, seed: 303,
};

/// Look up a dataset by name.
pub fn dataset(name: &str) -> Option<DatasetSpec> {
    match name {
        "mnist_s" => Some(MNIST_S),
        "svhn_s" => Some(SVHN_S),
        "cifar_s" => Some(CIFAR_S),
        _ => None,
    }
}

/// Prototype image for `(class, mode)` — coarse grid, nearest-upsampled
/// (mirrors `data.class_template`).
pub fn class_template(ds: &DatasetSpec, cls: u64, mode: u64) -> Vec<f32> {
    let seed = combine(&[ds.seed, 1, cls, mode]);
    let mut out = vec![0.0f32; ds.pixels()];
    for y in 0..ds.h {
        for x in 0..ds.w {
            for ch in 0..ds.c {
                let gy = (y / ds.factor).min(ds.grid - 1);
                let gx = (x / ds.factor).min(ds.grid - 1);
                let idx = ((gy * ds.grid) + gx) * ds.c + ch;
                out[(y * ds.w + x) * ds.c + ch] = u01_at(seed, idx as u64);
            }
        }
    }
    out
}

/// Deterministic sample `index`: `(image, label)` (mirrors `data.sample`).
pub fn sample(ds: &DatasetSpec, index: u64) -> (Vec<f32>, u32) {
    let cls = index % NUM_CLASSES as u64;
    let mode = (index / NUM_CLASSES as u64) % MODES;
    let template = class_template(ds, cls, mode);
    let seed = combine(&[ds.seed, 2, cls, index]);
    let contrast = 0.7f32 + 0.6f32 * u01_at(seed, 0);
    let brightness = -0.15f32 + 0.3f32 * u01_at(seed, 1);
    let mut img = vec![0.0f32; ds.pixels()];
    for (i, t) in template.iter().enumerate() {
        let noise = (u01_at(seed, 2 + i as u64) - 0.5f32) * NOISE_AMP;
        img[i] = (t * contrast + brightness + noise).clamp(0.0, 1.0);
    }
    (img, cls as u32)
}

/// `count` consecutive samples starting at `start`; `test` selects the
/// disjoint test split.  Images are concatenated row-major.
pub fn batch(ds: &DatasetSpec, start: u64, count: usize, test: bool) -> (Vec<f32>, Vec<u32>) {
    let base = start + if test { TEST_INDEX_OFFSET } else { 0 };
    let mut xs = Vec::with_capacity(count * ds.pixels());
    let mut ys = Vec::with_capacity(count);
    for i in 0..count as u64 {
        let (img, y) = sample(ds, base + i);
        xs.extend_from_slice(&img);
        ys.push(y);
    }
    (xs, ys)
}

/// Order-sensitive u64 checksum over the first `count` training images —
/// must equal `data.checksum` on the Python side (pinned in the manifest).
pub fn checksum(ds: &DatasetSpec, count: usize) -> u64 {
    let (xs, ys) = batch(ds, 0, count, false);
    let mut h: u64 = 0;
    for v in &xs {
        h = mix(h ^ (v.to_bits() as u64).wrapping_add(GAMMA));
    }
    for &y in &ys {
        h = mix(h ^ (y as u64).wrapping_add(GAMMA));
    }
    h
}

/// The paper's "prior pooling" input reduction for the MNIST MLPs
/// (mirrors `layers.prior_pool`): 1-D average pooling of the flattened
/// image to `out_dim` values with zero-padded tail.
pub fn prior_pool(img: &[f32], out_dim: usize) -> Vec<f32> {
    let dim = img.len();
    let win = dim.div_ceil(out_dim);
    let mut out = vec![0.0f32; out_dim];
    for (o, slot) in out.iter_mut().enumerate() {
        let lo = o * win;
        let mut sum = 0.0f32;
        for t in lo..(lo + win).min(dim) {
            sum += img[t];
        }
        *slot = sum / win as f32;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sample_deterministic_and_in_range() {
        for ds in [&MNIST_S, &SVHN_S, &CIFAR_S] {
            let (a, ya) = sample(ds, 12345);
            let (b, yb) = sample(ds, 12345);
            assert_eq!(a, b);
            assert_eq!(ya, yb);
            assert_eq!(ya, (12345 % 10) as u32);
            assert_eq!(a.len(), ds.pixels());
            assert!(a.iter().all(|&v| (0.0..=1.0).contains(&v)));
        }
    }

    #[test]
    fn splits_disjoint() {
        let (tr, _) = batch(&MNIST_S, 0, 2, false);
        let (te, _) = batch(&MNIST_S, 0, 2, true);
        assert_ne!(tr, te);
    }

    #[test]
    fn labels_balanced() {
        let (_, ys) = batch(&MNIST_S, 0, 100, false);
        let mut counts = [0usize; 10];
        for y in ys {
            counts[y as usize] += 1;
        }
        assert!(counts.iter().all(|&c| c == 10));
    }

    #[test]
    fn templates_differ() {
        assert_ne!(class_template(&MNIST_S, 0, 0), class_template(&MNIST_S, 1, 0));
        assert_ne!(class_template(&MNIST_S, 0, 0), class_template(&MNIST_S, 0, 1));
    }

    #[test]
    fn checksums_differ_between_datasets() {
        let a = checksum(&MNIST_S, 2);
        let b = checksum(&SVHN_S, 2);
        let c = checksum(&CIFAR_S, 2);
        assert!(a != b && b != c && a != c);
    }

    #[test]
    fn prior_pool_shape_and_means() {
        let img = vec![1.0f32; 784];
        let pooled = prior_pool(&img, 256);
        assert_eq!(pooled.len(), 256);
        // 784 -> win 4 -> first 196 windows full of ones
        assert!(pooled[..190].iter().all(|&v| (v - 1.0).abs() < 1e-6));
        assert!(pooled[200..].iter().all(|&v| v.abs() < 1e-6));
    }

    #[test]
    fn dataset_lookup() {
        assert_eq!(dataset("mnist_s"), Some(MNIST_S));
        assert!(dataset("nope").is_none());
    }
}
