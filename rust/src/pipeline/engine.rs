//! The multi-batch-in-flight execution engine: stage workers chained by
//! bounded channels, in-flight depth enforced by a token channel.
//!
//! Lifecycle: [`Pipeline::start`] spawns one worker thread per stage of
//! the plan; [`Pipeline::submit`] feeds a batch into stage 0 (blocking
//! while `depth` batches are in flight); the last stage hands each
//! finished batch to the caller's sink closure and releases its token.
//! Dropping (or [`Pipeline::shutdown`]) closes the input channel; workers
//! drain and exit stage by stage, so every submitted batch reaches the
//! sink before teardown completes.
//!
//! Submission is fallible: if the stage workers are gone (teardown raced
//! the submitter, or a sink panicked), [`Pipeline::submit`] returns a
//! [`SubmitError`] carrying the payload back instead of panicking on the
//! serving request path.

use std::error::Error;
use std::fmt;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc};
use std::thread::JoinHandle;

use crate::native::{NativeModel, Tensor};
use crate::pipeline::plan::PipelinePlan;
use crate::pipeline::stage::{stage_loop, Job, PipelineStats};

/// A running layer pipeline over one model.  `P` is the per-batch payload
/// the sink gets back (the server rides the pending request batch here;
/// tests ride indices).
pub struct Pipeline<P: Send + 'static> {
    input: Option<mpsc::SyncSender<Job<P>>>,
    tokens: Option<mpsc::SyncSender<()>>,
    workers: Vec<JoinHandle<()>>,
    stats: Arc<PipelineStats>,
    depth: usize,
    next_seq: AtomicU64,
}

/// The stage workers are gone — the batch could not enter the pipeline.
/// Carries the payload back so the caller can fail its pending requests
/// (or resubmit elsewhere) instead of losing them.
pub struct SubmitError<P> {
    /// the payload handed to [`Pipeline::submit`], returned untouched
    pub payload: P,
}

impl<P> SubmitError<P> {
    fn new(payload: P) -> Self {
        Self { payload }
    }
}

// manual impls: `P` is an arbitrary payload, so no derive bounds
impl<P> fmt::Debug for SubmitError<P> {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str("SubmitError(..)")
    }
}

impl<P> fmt::Display for SubmitError<P> {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str("pipeline stage workers shut down")
    }
}

impl<P> Error for SubmitError<P> {}

impl<P: Send + 'static> Pipeline<P> {
    /// Spawn the stage workers.  `depth` bounds the number of batches past
    /// [`submit`](Self::submit) and not yet through `sink` (default: one
    /// per stage — the classic full pipeline).  `sink` runs on the last
    /// stage's worker thread, once per batch, in submission order.
    pub fn start(
        model: Arc<NativeModel>,
        plan: PipelinePlan,
        depth: Option<usize>,
        sink: impl FnMut(Tensor, P) + Send + 'static,
    ) -> Self {
        let stages = plan.stages;
        assert!(!stages.is_empty(), "a pipeline needs at least one stage");
        let depth = depth.unwrap_or(stages.len()).max(1);
        let stats = Arc::new(PipelineStats::new(
            stages.iter().map(|s| s.label.clone()).collect(),
        ));
        // the token channel IS the in-flight bound: submit deposits one
        // token per batch (blocking at `depth`), the sink withdraws it
        let (token_tx, token_rx) = mpsc::sync_channel::<()>(depth);
        let (input_tx, first_rx) = mpsc::sync_channel::<Job<P>>(depth);

        let mut workers = Vec::with_capacity(stages.len());
        let last = stages.len() - 1;
        let mut rx = Some(first_rx);
        let mut sink = Some(sink);
        let mut token_rx = Some(token_rx);
        for (i, spec) in stages.into_iter().enumerate() {
            let model = model.clone();
            let stats = stats.clone();
            // lint:allow(unwrap): construction-time plumbing — exactly one
            // receiver exists per stage by loop structure
            let stage_rx = rx.take().expect("one receiver per stage");
            let builder = std::thread::Builder::new().name(format!("circnn-stage{i}"));
            let handle = if i < last {
                let (tx, next_rx) = mpsc::sync_channel::<Job<P>>(depth);
                rx = Some(next_rx);
                builder.spawn(move || {
                    stage_loop(&model, spec.ops, i, stage_rx, &stats, move |job| {
                        // a send fails only if downstream died; the batch
                        // is then dropped with its response channels, which
                        // surfaces as Shutdown at the clients
                        let _ = tx.send(job);
                    })
                })
            } else {
                // lint:allow(unwrap): construction-time — the last stage is
                // visited once, taking the one sink and the token receiver
                let mut sink = sink.take().expect("exactly one sink");
                // lint:allow(unwrap): same construction-time invariant
                let token_rx = token_rx.take().expect("token receiver on the last stage");
                builder.spawn(move || {
                    stage_loop(&model, spec.ops, i, stage_rx, &stats, move |job: Job<P>| {
                        sink(job.tensor, job.payload);
                        // this batch's token was deposited before it could
                        // enter stage 0, so the channel is never empty here
                        let _ = token_rx.recv();
                    })
                })
            };
            // lint:allow(unwrap): thread spawn fails only on resource
            // exhaustion at startup, before any request is in flight
            workers.push(handle.expect("spawn pipeline stage worker"));
        }

        Self {
            input: Some(input_tx),
            tokens: Some(token_tx),
            workers,
            stats,
            depth,
            next_seq: AtomicU64::new(0),
        }
    }

    /// Feed one batch into stage 0 and return its sequence number.
    /// **Blocks** while `depth` batches are already in flight — bounded
    /// backpressure, never unbounded buffering.  With a single submitter,
    /// sink completions arrive in submission order.  If the stage workers
    /// are gone the payload comes back in the [`SubmitError`].
    pub fn submit(
        &self,
        images: &[f32],
        batch: usize,
        h: usize,
        w: usize,
        c: usize,
        payload: P,
    ) -> Result<u64, SubmitError<P>> {
        assert_eq!(images.len(), batch * h * w * c, "image buffer size");
        self.submit_tensor(Tensor { batch, h, w, c, data: images.to_vec() }, payload)
    }

    /// [`submit`](Self::submit) without the copy: the caller hands over an
    /// already-assembled activation tensor (the server builds the batch
    /// straight into it).
    pub fn submit_tensor(&self, tensor: Tensor, payload: P) -> Result<u64, SubmitError<P>> {
        assert_eq!(
            tensor.data.len(),
            tensor.batch * tensor.h * tensor.w * tensor.c,
            "tensor buffer size"
        );
        let (Some(tokens), Some(input)) = (self.tokens.as_ref(), self.input.as_ref()) else {
            return Err(SubmitError::new(payload));
        };
        // deposit the in-flight token first; a closed token channel means
        // the last-stage worker (the sink's thread) is gone
        if tokens.send(()).is_err() {
            return Err(SubmitError::new(payload));
        }
        let seq = self.next_seq.fetch_add(1, Ordering::Relaxed);
        match input.send(Job { seq, tensor, payload }) {
            Ok(()) => Ok(seq),
            Err(mpsc::SendError(job)) => Err(SubmitError::new(job.payload)),
        }
    }

    /// Occupancy counters + event log (shared with `Metrics`).
    pub fn stats(&self) -> &Arc<PipelineStats> {
        &self.stats
    }

    pub fn stage_count(&self) -> usize {
        self.stats.stage_count()
    }

    /// The in-flight bound this pipeline enforces.
    pub fn depth(&self) -> usize {
        self.depth
    }

    /// Batches submitted so far.
    pub fn submitted(&self) -> u64 {
        self.next_seq.load(Ordering::Relaxed)
    }

    /// Graceful teardown: close the input, let every in-flight batch reach
    /// the sink, join the workers.  `Drop` does the same.
    pub fn shutdown(mut self) {
        self.close_and_join();
    }

    fn close_and_join(&mut self) {
        self.input.take();
        self.tokens.take();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

impl<P: Send + 'static> Drop for Pipeline<P> {
    fn drop(&mut self) {
        self.close_and_join();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data;
    use crate::models;
    use crate::native::QUANT_BITS;
    use crate::util::prop::forall;
    use std::sync::atomic::AtomicUsize;
    use std::sync::{Condvar, Mutex};
    use std::time::Duration;

    /// Collect sink outputs keyed by seq.
    fn collecting_sink(
        out: Arc<Mutex<Vec<(u64, Vec<f32>)>>>,
    ) -> impl FnMut(Tensor, u64) + Send + 'static {
        move |t, seq| out.lock().unwrap().push((seq, t.data))
    }

    #[test]
    fn prop_pipelined_batches_bitwise_equal_forward() {
        // the acceptance pin: across stage counts, in-flight depths, float
        // and 12-bit arithmetic, ragged batch streams — every batch out of
        // the pipeline equals NativeModel::forward bit for bit
        forall(
            "pipeline == forward (bitwise)",
            |r| {
                let name = ["mnist_mlp_1", "mnist_mlp_2", "mnist_lenet"]
                    [r.below(3) as usize];
                let max_stages = 1 + r.below(4) as usize;
                let depth = 1 + r.below(4) as usize;
                let quant = r.below(2) == 0;
                let batches: Vec<usize> =
                    (0..1 + r.below(3)).map(|_| 1 + r.below(3) as usize).collect();
                (name, max_stages, depth, quant, batches)
            },
            |&(name, max_stages, depth, quant, ref batches)| {
                let model = models::by_name(name).unwrap();
                let mut native = NativeModel::init_random(&model, 11);
                native.quant_bits = if quant { Some(QUANT_BITS) } else { None };
                let native = Arc::new(native);
                let (h, w, c) = model.input;
                let ds = data::dataset(model.dataset).unwrap();

                let plan = PipelinePlan::for_model(&native, max_stages);
                let got = Arc::new(Mutex::new(Vec::new()));
                let pipe = Pipeline::start(
                    native.clone(),
                    plan,
                    Some(depth),
                    collecting_sink(got.clone()),
                );
                let mut want = Vec::new();
                for (i, &b) in batches.iter().enumerate() {
                    let (xs, _) = data::batch(&ds, (i * 8) as u64, b, false);
                    let seq = pipe.submit(&xs, b, h, w, c, i as u64).expect("pipeline running");
                    assert_eq!(seq, i as u64);
                    want.push(native.forward(&xs, b, h, w, c));
                }
                pipe.shutdown(); // drains every in-flight batch to the sink
                let got = got.lock().unwrap();
                if got.len() != batches.len() {
                    return Err(format!(
                        "{} batches in, {} out of the sink",
                        batches.len(),
                        got.len()
                    ));
                }
                for (i, (seq, data)) in got.iter().enumerate() {
                    if *seq != i as u64 {
                        return Err(format!("completion order broke FIFO at {i}: seq {seq}"));
                    }
                    if data != &want[i] {
                        return Err(format!("batch {i} diverged from forward (bitwise)"));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn depth_one_single_stage_degenerates_to_serial() {
        // the CIRCNN_THREADS=1 shape: one stage, one batch in flight
        let model = models::by_name("mnist_mlp_1").unwrap();
        let native = Arc::new(NativeModel::init_random(&model, 3));
        let (h, w, c) = model.input;
        let ds = data::dataset(model.dataset).unwrap();
        let plan = PipelinePlan::for_model(&native, 1);
        assert_eq!(plan.stage_count(), 1);
        let got = Arc::new(Mutex::new(Vec::new()));
        let pipe = Pipeline::start(native.clone(), plan, Some(1), collecting_sink(got.clone()));
        let (xs, _) = data::batch(&ds, 0, 4, false);
        pipe.submit(&xs, 4, h, w, c, 0).unwrap();
        pipe.shutdown();
        let got = got.lock().unwrap();
        assert_eq!(got.len(), 1);
        assert_eq!(got[0].1, native.forward(&xs, 4, h, w, c));
    }

    #[test]
    fn residual_model_streams_bitwise_through_the_pipeline() {
        // cifar_wrn: the residual pairs ride inside single stages (the
        // planner never cuts them) and the multi-stage walk must still be
        // bitwise equal to forward
        let model = models::by_name("cifar_wrn").unwrap();
        let native = Arc::new(NativeModel::init_random(&model, 21));
        let (h, w, c) = model.input;
        let ds = data::dataset(model.dataset).unwrap();
        let plan = PipelinePlan::for_model(&native, usize::MAX);
        assert!(plan.stage_count() >= 4, "wrn should split into several stages");
        let got = Arc::new(Mutex::new(Vec::new()));
        let pipe = Pipeline::start(native.clone(), plan, None, collecting_sink(got.clone()));
        let (xs, _) = data::batch(&ds, 0, 2, false);
        pipe.submit(&xs, 2, h, w, c, 0).unwrap();
        pipe.shutdown();
        let got = got.lock().unwrap();
        assert_eq!(got.len(), 1);
        assert_eq!(got[0].1, native.forward(&xs, 2, h, w, c));
    }

    #[test]
    fn bounded_in_flight_blocks_stage_zero() {
        // with the sink gated shut, a depth-2 pipeline must admit at most
        // 2 batches; the 3rd submit blocks on the token channel instead of
        // buffering — then opening the gate drains everything
        let model = models::by_name("mnist_mlp_1").unwrap();
        let native = Arc::new(NativeModel::init_random(&model, 5));
        let (h, w, c) = model.input;
        let ds = data::dataset(model.dataset).unwrap();
        let (xs, _) = data::batch(&ds, 0, 1, false);

        const DEPTH: usize = 2;
        const TOTAL: usize = 5;
        let gate = Arc::new((Mutex::new(false), Condvar::new()));
        let done = Arc::new(AtomicUsize::new(0));
        let (sink_gate, sink_done) = (gate.clone(), done.clone());
        let plan = PipelinePlan::for_model(&native, 3);
        let pipe = Pipeline::start(
            native.clone(),
            plan,
            Some(DEPTH),
            move |_t: Tensor, _p: usize| {
                let (lock, cv) = &*sink_gate;
                let mut open = lock.lock().unwrap();
                while !*open {
                    open = cv.wait(open).unwrap();
                }
                drop(open);
                sink_done.fetch_add(1, Ordering::SeqCst);
            },
        );

        let submitted = Arc::new(AtomicUsize::new(0));
        std::thread::scope(|scope| {
            let pipe = &pipe;
            let counter = submitted.clone();
            scope.spawn(move || {
                for i in 0..TOTAL {
                    pipe.submit(&xs, 1, h, w, c, i).expect("pipeline running");
                    counter.fetch_add(1, Ordering::SeqCst);
                }
            });
            // give the submitter ample time to overrun the bound if it could
            std::thread::sleep(Duration::from_millis(150));
            let in_flight = submitted.load(Ordering::SeqCst);
            assert!(
                in_flight <= DEPTH,
                "{in_flight} submits completed with the sink gated: \
                 depth {DEPTH} bound not enforced"
            );
            assert_eq!(done.load(Ordering::SeqCst), 0);
            let (lock, cv) = &*gate;
            *lock.lock().unwrap() = true;
            cv.notify_all();
        });
        pipe.shutdown();
        assert_eq!(done.load(Ordering::SeqCst), TOTAL, "gated batches lost");
    }

    #[test]
    fn stats_account_every_batch_once() {
        let model = models::by_name("mnist_mlp_2").unwrap();
        let native = Arc::new(NativeModel::init_random(&model, 9));
        let (h, w, c) = model.input;
        let ds = data::dataset(model.dataset).unwrap();
        let plan = PipelinePlan::for_model(&native, usize::MAX);
        let stages = plan.stage_count();
        let pipe = Pipeline::start(native, plan, None, |_t: Tensor, _p: ()| {});
        assert_eq!(pipe.depth(), stages, "default depth = one batch per stage");
        let (xs, _) = data::batch(&ds, 0, 3, false);
        for _ in 0..4 {
            pipe.submit(&xs, 3, h, w, c, ()).unwrap();
        }
        assert_eq!(pipe.submitted(), 4);
        let stats = pipe.stats().clone();
        pipe.shutdown();
        for s in &stats.stages {
            assert_eq!(s.batches.load(Ordering::Relaxed), 4, "{}", s.label);
            assert_eq!(s.items.load(Ordering::Relaxed), 12, "{}", s.label);
        }
        let events = stats.events.lock().unwrap();
        assert_eq!(events.len(), 4 * stages);
        assert!(events.iter().all(|e| e.end_us >= e.start_us));
    }

    #[test]
    fn drop_with_batches_in_flight_drains_and_joins() {
        // implicit teardown (Drop, not shutdown()) with work still moving
        // through the stages: every submitted batch must reach the sink
        // before drop returns — both multi-stage and the single-stage
        // degenerate shape
        for max_stages in [usize::MAX, 1] {
            let model = models::by_name("mnist_mlp_2").unwrap();
            let native = Arc::new(NativeModel::init_random(&model, 17));
            let (h, w, c) = model.input;
            let ds = data::dataset(model.dataset).unwrap();
            let plan = PipelinePlan::for_model(&native, max_stages);
            let got = Arc::new(Mutex::new(Vec::new()));
            let pipe =
                Pipeline::start(native.clone(), plan, Some(2), collecting_sink(got.clone()));
            let (xs, _) = data::batch(&ds, 0, 2, false);
            for i in 0..6u64 {
                pipe.submit(&xs, 2, h, w, c, i).expect("pipeline running");
            }
            drop(pipe); // must block until the workers have drained + joined
            let got = got.lock().unwrap();
            assert_eq!(got.len(), 6, "batches lost on drop ({max_stages} stages cap)");
            let want = native.forward(&xs, 2, h, w, c);
            for (seq, data) in got.iter() {
                assert_eq!(data, &want, "batch {seq} diverged after drop-drain");
            }
        }
    }

    #[test]
    fn dead_sink_surfaces_as_submit_error_not_panic() {
        // a panicking sink kills the last-stage worker; the submitter must
        // get its payload back in a SubmitError instead of panicking, and
        // dropping the pipeline must still join cleanly
        let model = models::by_name("mnist_mlp_1").unwrap();
        let native = Arc::new(NativeModel::init_random(&model, 7));
        let (h, w, c) = model.input;
        let ds = data::dataset(model.dataset).unwrap();
        let plan = PipelinePlan::for_model(&native, 2);
        let pipe = Pipeline::start(
            native,
            plan,
            Some(2),
            |_t: Tensor, _p: u64| panic!("sink dies on purpose"),
        );
        let (xs, _) = data::batch(&ds, 0, 1, false);
        let mut refused = None;
        for i in 0..200u64 {
            match pipe.submit(&xs, 1, h, w, c, i) {
                Ok(_) => std::thread::sleep(Duration::from_millis(5)),
                Err(err) => {
                    refused = Some((i, err));
                    break;
                }
            }
        }
        let (i, err) = refused.expect("dead sink never refused a submit");
        assert_eq!(err.payload, i, "payload must come back with the error");
        assert_eq!(err.to_string(), "pipeline stage workers shut down");
        drop(pipe); // joins the panicked worker without propagating
    }
}
