//! Stage workers and their occupancy accounting.
//!
//! Each stage worker alternates between two states: **idle** (blocked on
//! its input channel — the pipeline-fill bubbles of the paper's Fig. 4)
//! and **busy** (running its op segment on one batch).  Both are measured
//! per stage with monotonic clocks and accumulated in [`StageStat`], so
//! `busy_fraction()` is the serving-side twin of the simulator's
//! `Trace::bubble_fraction` — computed from wall time actually spent, not
//! from a cycle model.  The first [`EVENT_CAP`] per-batch intervals are
//! also kept as [`StageEvent`]s for the timeline renderer
//! ([`super::timeline`]).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::Receiver;
use std::sync::Mutex;
use std::time::Instant;

use crate::native::{NativeModel, Tensor};

/// Bound on the retained per-batch event log (the counters keep
/// accumulating past it; only the timeline detail stops growing).
pub const EVENT_CAP: usize = 4096;

/// One batch flowing through the pipeline: the activation tensor plus an
/// opaque payload the sink gets back (the server rides the pending request
/// batch here).
#[derive(Debug)]
pub struct Job<P> {
    /// submission sequence number (FIFO through every stage)
    pub seq: u64,
    pub tensor: Tensor,
    pub payload: P,
}

/// Lock-free occupancy counters for one stage.
#[derive(Debug)]
pub struct StageStat {
    /// plan label, e.g. `"L02 bc_dense"`
    pub label: String,
    /// batches executed
    pub batches: AtomicU64,
    /// images executed (occupied batch slots)
    pub items: AtomicU64,
    /// time spent executing the op segment
    pub busy_us: AtomicU64,
    /// closed idle intervals: time spent blocked on the input channel or
    /// handing a batch downstream (pipeline-fill / backpressure bubbles)
    pub idle_us: AtomicU64,
    /// µs since pipeline start when the current idle interval opened;
    /// [`IDLE_NONE`] while the stage is busy.  Readers fold the open
    /// interval in ([`PipelineStats::busy_fraction`]), so occupancy decays
    /// while a stage sits quiescent instead of freezing at its last value.
    idle_since_us: AtomicU64,
}

/// Sentinel for "no idle interval open" (stage busy or not yet started).
const IDLE_NONE: u64 = u64::MAX;

impl StageStat {
    fn new(label: String) -> Self {
        Self {
            label,
            batches: AtomicU64::new(0),
            items: AtomicU64::new(0),
            busy_us: AtomicU64::new(0),
            idle_us: AtomicU64::new(0),
            idle_since_us: AtomicU64::new(IDLE_NONE),
        }
    }
}

/// One recorded busy interval: batch `seq` occupied stage `stage` from
/// `start_us` to `end_us` (µs since the pipeline started).
#[derive(Debug, Clone, Copy)]
pub struct StageEvent {
    pub stage: usize,
    pub seq: u64,
    pub start_us: u64,
    pub end_us: u64,
}

/// Shared occupancy state of one running pipeline (cheap to clone via
/// `Arc`; the coordinator's `Metrics` holds one per pipelined model).
#[derive(Debug)]
pub struct PipelineStats {
    started: Instant,
    pub stages: Vec<StageStat>,
    /// first [`EVENT_CAP`] per-batch busy intervals, in completion order
    pub events: Mutex<Vec<StageEvent>>,
}

impl PipelineStats {
    pub fn new(labels: Vec<String>) -> Self {
        Self {
            started: Instant::now(),
            stages: labels.into_iter().map(StageStat::new).collect(),
            events: Mutex::new(Vec::new()),
        }
    }

    pub fn stage_count(&self) -> usize {
        self.stages.len()
    }

    /// The instant the pipeline started — the epoch every recorded
    /// [`StageEvent`]'s `start_us`/`end_us` is relative to.  The server's
    /// trace join uses this to convert stage events into span-tracer
    /// offsets (`telemetry::Tracer` keeps its own epoch).
    pub fn started(&self) -> Instant {
        self.started
    }

    /// Stage `stage` starts waiting (input channel or downstream hand-off)
    /// at `now` — opens an idle interval.
    pub(crate) fn mark_idle(&self, stage: usize, now: Instant) {
        let rel = now.duration_since(self.started).as_micros() as u64;
        self.stages[stage].idle_since_us.store(rel, Ordering::Relaxed);
    }

    /// Stage `stage` got a batch at `now` — closes the open idle interval
    /// into `idle_us`.
    pub(crate) fn mark_busy(&self, stage: usize, now: Instant) {
        let s = &self.stages[stage];
        let since = s.idle_since_us.swap(IDLE_NONE, Ordering::Relaxed);
        if since != IDLE_NONE {
            let rel = now.duration_since(self.started).as_micros() as u64;
            s.idle_us.fetch_add(rel.saturating_sub(since), Ordering::Relaxed);
        }
    }

    /// busy / (busy + idle) for one stage, folding in the currently-open
    /// idle interval — a quiescent stage's occupancy decays toward zero
    /// instead of freezing at its last recorded value.  0.0 before the
    /// stage has seen any time.
    pub fn busy_fraction(&self, stage: usize) -> f64 {
        let s = &self.stages[stage];
        let busy = s.busy_us.load(Ordering::Relaxed) as f64;
        let mut idle = s.idle_us.load(Ordering::Relaxed) as f64;
        let since = s.idle_since_us.load(Ordering::Relaxed);
        if since != IDLE_NONE {
            let now = self.started.elapsed().as_micros() as u64;
            idle += now.saturating_sub(since) as f64;
        }
        if busy + idle == 0.0 {
            return 0.0;
        }
        busy / (busy + idle)
    }

    /// Record one executed batch on `stage`.
    pub(crate) fn record(&self, stage: usize, seq: u64, t0: Instant, t1: Instant, items: usize) {
        let s = &self.stages[stage];
        s.batches.fetch_add(1, Ordering::Relaxed);
        s.items.fetch_add(items as u64, Ordering::Relaxed);
        s.busy_us
            .fetch_add(t1.duration_since(t0).as_micros() as u64, Ordering::Relaxed);
        let mut events = self.events.lock().unwrap_or_else(|e| e.into_inner());
        if events.len() < EVENT_CAP {
            events.push(StageEvent {
                stage,
                seq,
                start_us: t0.duration_since(self.started).as_micros() as u64,
                end_us: t1.duration_since(self.started).as_micros() as u64,
            });
        }
    }

    /// [`busy_fraction`](Self::busy_fraction) in integer thousandths —
    /// the unit the registry's `*_permille` gauges carry.
    pub fn busy_permille(&self, stage: usize) -> u64 {
        (1000.0 * self.busy_fraction(stage)) as u64
    }

    /// The busiest stage's permille right now — the snapshot ticker's
    /// per-pipeline sampling hook (the bottleneck stage is the one the
    /// paper's pipeline-fill story cares about).  0 with no stages.
    pub fn max_busy_permille(&self) -> u64 {
        (0..self.stages.len()).map(|s| self.busy_permille(s)).max().unwrap_or(0)
    }

    /// Compact per-stage busy fractions, e.g. `"s0=83% s1=71% s2=64%"` —
    /// what `Metrics::summary()` appends for a pipelined model.
    pub fn occupancy_summary(&self) -> String {
        (0..self.stages.len())
            .map(|i| format!("s{i}={:.0}%", 100.0 * self.busy_fraction(i)))
            .collect::<Vec<_>>()
            .join(" ")
    }
}

/// The worker body shared by every stage: receive a batch, charge the wait
/// to idle, run the stage's op segment through the same owned-step walk
/// `forward` uses, charge the run to busy, hand the batch to `deliver`
/// (the next stage's channel, or the sink for the last stage).  Returns
/// when the input channel closes and is drained — shutdown cascades stage
/// by stage.
pub(crate) fn stage_loop<P>(
    model: &NativeModel,
    ops: std::ops::Range<usize>,
    idx: usize,
    rx: Receiver<Job<P>>,
    stats: &PipelineStats,
    mut deliver: impl FnMut(Job<P>),
) {
    stats.mark_idle(idx, Instant::now());
    while let Ok(mut job) = rx.recv() {
        let t0 = Instant::now();
        stats.mark_busy(idx, t0);
        let mut residuals: Vec<Tensor> = Vec::new();
        let items = job.tensor.batch;
        job.tensor = model.run_ops(ops.clone(), job.tensor, &mut residuals);
        debug_assert!(residuals.is_empty(), "stage cut inside a residual region");
        let t1 = Instant::now();
        stats.record(idx, job.seq, t0, t1, items);
        // idle reopens at t1, before deliver: time blocked handing the
        // batch downstream (full channel / slow sink — backpressure stall)
        // is a bubble, not work, so it lands in the idle interval or the
        // busy fraction would overstate occupancy exactly when the
        // pipeline is unbalanced
        stats.mark_idle(idx, t1);
        deliver(job);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    #[test]
    fn busy_fraction_is_zero_then_tracks_counters() {
        let stats = PipelineStats::new(vec!["L00 a".into(), "L01 b".into()]);
        assert_eq!(stats.stage_count(), 2);
        assert_eq!(stats.busy_fraction(0), 0.0);
        let t0 = stats.started;
        stats.record(0, 0, t0, t0 + Duration::from_micros(300), 4);
        stats.stages[0].idle_us.fetch_add(100, Ordering::Relaxed);
        let f = stats.busy_fraction(0);
        assert!((f - 0.75).abs() < 1e-9, "busy fraction {f}");
        assert_eq!(stats.busy_permille(0), 750);
        assert_eq!(stats.max_busy_permille(), 750, "busiest stage wins");
        assert_eq!(stats.stages[0].items.load(Ordering::Relaxed), 4);
        let s = stats.occupancy_summary();
        assert!(s.contains("s0=75%") && s.contains("s1=0%"), "{s}");
    }

    #[test]
    fn open_idle_interval_decays_occupancy() {
        // a stage that went quiet must not freeze at its last busy
        // fraction: the open idle interval counts from the reader side
        let stats = PipelineStats::new(vec!["L00 a".into()]);
        let t0 = stats.started;
        stats.record(0, 0, t0, t0 + Duration::from_micros(200), 1);
        assert_eq!(stats.busy_fraction(0), 1.0, "no idle recorded yet");
        stats.mark_idle(0, t0 + Duration::from_micros(200));
        std::thread::sleep(Duration::from_millis(10));
        let f = stats.busy_fraction(0);
        assert!(f < 0.5, "stale busy fraction {f} ignores the open idle interval");
        // closing the interval banks it into idle_us
        stats.mark_busy(0, Instant::now());
        assert!(stats.stages[0].idle_us.load(Ordering::Relaxed) >= 5_000);
        stats.mark_busy(0, Instant::now()); // no open interval: no-op
    }

    #[test]
    fn event_log_is_bounded() {
        let stats = PipelineStats::new(vec!["L00 a".into()]);
        let t = stats.started;
        for seq in 0..(EVENT_CAP + 10) as u64 {
            stats.record(0, seq, t, t + Duration::from_micros(1), 1);
        }
        assert_eq!(stats.events.lock().unwrap().len(), EVENT_CAP);
        // counters keep accumulating past the event cap
        assert_eq!(
            stats.stages[0].batches.load(Ordering::Relaxed),
            (EVENT_CAP + 10) as u64
        );
    }
}
