//! Stage planning: split a compiled op program into contiguous pipeline
//! stages.
//!
//! Two rules shape a plan:
//!
//! * a **residual pair stays within one stage** — the saved skip activation
//!   lives on a stage-local stack, so a cut inside `ResidualBegin ..
//!   ResidualEnd` would strand it on the wrong worker.  Cuts happen only at
//!   op boundaries where the residual nesting depth is zero.
//! * a **weight op anchors a stage** — the FFT/MAC-heavy layers are where
//!   the cycles go (and where the FPGA keeps per-stage resident weight
//!   spectra), so each gets its own worker; cheap ops (pools, reshapes,
//!   prior-pool) ride along with the nearest anchor.
//!
//! The stage count is then capped (default: [`sched::max_threads`], so
//! `CIRCNN_THREADS=1` degrades to one serial stage) by merging adjacent
//! stages evenly.

use std::ops::Range;

use crate::circulant::sched;
use crate::native::{NativeModel, Op};

/// One pipeline stage: a contiguous op segment of the model program.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct StageSpec {
    /// op indices this stage executes (`model.run_ops(ops.clone(), ..)`)
    pub ops: Range<usize>,
    /// display label, e.g. `"L02 bc_dense"` (first weight op of the stage)
    pub label: String,
}

/// A complete stage partition of one model program.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct PipelinePlan {
    pub stages: Vec<StageSpec>,
}

impl PipelinePlan {
    /// Plan `model` into at most `max_stages` stages (≥ 1; callers usually
    /// pass [`sched::max_threads`]).  Every op is covered exactly once and
    /// segment boundaries sit at residual depth zero.
    pub fn for_model(model: &NativeModel, max_stages: usize) -> Self {
        let ops = model.ops_slice();
        if ops.is_empty() {
            return Self { stages: vec![StageSpec { ops: 0..0, label: "L00 empty".into() }] };
        }

        // 1. indivisible units: maximal runs that begin and end at residual
        //    nesting depth zero (each depth-0 op is its own unit; a whole
        //    residual region is one unit)
        let mut units: Vec<Range<usize>> = Vec::new();
        let mut depth = 0usize;
        let mut start = 0usize;
        for (i, op) in ops.iter().enumerate() {
            match op {
                Op::ResidualBegin => depth += 1,
                Op::ResidualEnd => depth = depth.saturating_sub(1),
                _ => {}
            }
            if depth == 0 {
                units.push(start..i + 1);
                start = i + 1;
            }
        }
        debug_assert_eq!(start, ops.len(), "unbalanced residual markers");

        // 2. greedy anchoring: a unit containing a weight op opens a new
        //    stage once the current stage already has one; cheap units
        //    merge into the open stage (a cheap prefix rides with the
        //    first anchor)
        let has_weight = |r: &Range<usize>| ops[r.clone()].iter().any(|o| o.is_weight());
        let mut anchored: Vec<Range<usize>> = Vec::new();
        let mut cur: Option<(Range<usize>, bool)> = None;
        for unit in units {
            let w = has_weight(&unit);
            match cur.take() {
                None => cur = Some((unit, w)),
                Some((range, cur_w)) if cur_w && w => {
                    anchored.push(range);
                    cur = Some((unit, true));
                }
                Some((range, cur_w)) => cur = Some((range.start..unit.end, cur_w || w)),
            }
        }
        if let Some((range, _)) = cur {
            anchored.push(range);
        }

        // 3. cap at `max_stages` by even contiguous grouping
        let cap = max_stages.max(1).min(anchored.len());
        let mut stages = Vec::with_capacity(cap);
        for g in 0..cap {
            let lo = g * anchored.len() / cap;
            let hi = (g + 1) * anchored.len() / cap;
            let range = anchored[lo].start..anchored[hi - 1].end;
            let anchor = ops[range.clone()]
                .iter()
                .position(|o| o.is_weight())
                .map_or(range.start, |off| range.start + off);
            let label = format!("L{anchor:02} {}", ops[anchor].kind_name());
            stages.push(StageSpec { ops: range, label });
        }
        Self { stages }
    }

    /// Default stage cap: one worker per available thread
    /// ([`sched::max_threads`] — honors `CIRCNN_THREADS`).
    pub fn auto(model: &NativeModel) -> Self {
        Self::for_model(model, sched::max_threads())
    }

    pub fn stage_count(&self) -> usize {
        self.stages.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models;
    use crate::native::NativeModel;

    fn plan_of(name: &str, max_stages: usize) -> (NativeModel, PipelinePlan) {
        let model = models::by_name(name).unwrap();
        let native = NativeModel::init_random(&model, 1);
        let plan = PipelinePlan::for_model(&native, max_stages);
        (native, plan)
    }

    fn assert_covers(native: &NativeModel, plan: &PipelinePlan) {
        let mut next = 0;
        for s in &plan.stages {
            assert_eq!(s.ops.start, next, "stages must tile the program");
            assert!(s.ops.end > s.ops.start, "empty stage");
            next = s.ops.end;
        }
        assert_eq!(next, native.op_count(), "stages must cover every op");
    }

    #[test]
    fn every_registry_model_plans_at_every_cap() {
        for m in models::registry() {
            let native = NativeModel::init_random(&m, 2);
            for cap in [1, 2, 3, 8, usize::MAX] {
                let plan = PipelinePlan::for_model(&native, cap);
                assert_covers(&native, &plan);
                assert!(plan.stage_count() <= cap.max(1), "{}: cap violated", m.name);
            }
        }
    }

    #[test]
    fn weight_ops_anchor_stages_in_the_mlp() {
        // mnist_mlp_2: PriorPool, Flatten, BcDense, BcDense, Dense — the
        // cheap prefix rides with the first anchor, three stages total
        let (native, plan) = plan_of("mnist_mlp_2", usize::MAX);
        assert_covers(&native, &plan);
        assert_eq!(plan.stage_count(), 3);
        assert!(plan.stages[0].label.contains("bc_dense"), "{:?}", plan.stages);
        assert!(plan.stages[2].label.contains("dense"), "{:?}", plan.stages);
    }

    #[test]
    fn residual_pairs_are_never_cut() {
        // cifar_wrn holds two ResidualBegin/End pairs, two BcConvs inside
        // each — every stage boundary must sit at residual depth zero
        let (native, plan) = plan_of("cifar_wrn", usize::MAX);
        assert_covers(&native, &plan);
        for s in &plan.stages {
            let mut depth = 0i64;
            for op in &native.ops_slice()[s.ops.clone()] {
                match op {
                    Op::ResidualBegin => depth += 1,
                    Op::ResidualEnd => depth -= 1,
                    _ => {}
                }
                assert!(depth >= 0, "residual_end before its begin in a stage");
            }
            assert_eq!(depth, 0, "stage {} cuts a residual pair", s.label);
        }
    }

    #[test]
    fn cap_one_degenerates_to_a_single_serial_stage() {
        let (native, plan) = plan_of("svhn_cnn", 1);
        assert_eq!(plan.stage_count(), 1);
        assert_eq!(plan.stages[0].ops, 0..native.op_count());
    }
}
