//! ASCII occupancy timeline for a running (or finished) pipeline — the
//! serving-side analogue of [`crate::fpga::controller::render_timeline`]:
//! where the simulator *predicts* pipeline fill from the cycle model, this
//! renders the busy intervals the stage workers actually measured, so the
//! multi-batch-in-flight story becomes visible on real hardware.
//!
//! One row per stage; each busy interval is painted with the batch's
//! sequence digit (`seq % 10`), idle time stays `.` — a healthy pipeline
//! shows different digits stacked in the same column (batch N in stage 1
//! while batch N+1 occupies stage 0).
//!
//! This is the *per-stage* view; the *per-request* twin is the span
//! waterfall ([`crate::telemetry::render_waterfall`], `circnn serve
//! --trace`), which joins the same [`StageEvent`](super::StageEvent)s
//! onto each request's queue/exec span by batch sequence number.

use crate::pipeline::stage::PipelineStats;

/// Render the recorded events of `stats` into a `width`-column timeline
/// plus a per-stage occupancy legend.
pub fn render(stats: &PipelineStats, width: usize) -> String {
    let width = width.max(8);
    let events = stats.events.lock().unwrap_or_else(|e| e.into_inner());
    let span = events.iter().map(|e| e.end_us).max().unwrap_or(0).max(1);
    let scale = span as f64 / width as f64;
    let mut rows = vec![vec!['.'; width]; stats.stage_count()];
    for e in events.iter() {
        // a < width always, so a+1 <= width keeps the clamp well-ordered
        let a = ((e.start_us as f64 / scale) as usize).min(width - 1);
        let b = ((e.end_us as f64 / scale).ceil() as usize).clamp(a + 1, width);
        let ch = char::from(b'0' + (e.seq % 10) as u8);
        for slot in rows[e.stage].iter_mut().take(b).skip(a) {
            *slot = ch;
        }
    }
    let mut out = String::new();
    out.push_str(&format!(
        "pipeline: {} stages, {} batch-events over {span}us\n",
        stats.stage_count(),
        events.len(),
    ));
    drop(events);
    for (i, row) in rows.iter().enumerate() {
        out.push_str(&format!(
            "S{i} |{}| {:>3.0}% busy  {}\n",
            row.iter().collect::<String>(),
            100.0 * stats.busy_fraction(i),
            stats.stages[i].label,
        ));
    }
    out.push_str("     digits = batch seq % 10   . = idle (pipeline fill)\n");
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::{Duration, Instant};

    fn stats_with(events: &[(usize, u64, u64, u64)]) -> PipelineStats {
        let labels = (0..1 + events.iter().map(|e| e.0).max().unwrap_or(0))
            .map(|i| format!("L{i:02} test"))
            .collect();
        let stats = PipelineStats::new(labels);
        let t0 = Instant::now();
        for &(stage, seq, a, b) in events {
            stats.record(
                stage,
                seq,
                t0 + Duration::from_micros(a),
                t0 + Duration::from_micros(b),
                1,
            );
        }
        stats
    }

    #[test]
    fn renders_overlapping_batches_on_distinct_rows() {
        // batch 0 in stage 1 while batch 1 occupies stage 0 — the render
        // must show both digits, one per row
        let stats = stats_with(&[(0, 0, 0, 50), (1, 0, 50, 100), (0, 1, 50, 100)]);
        let text = render(&stats, 40);
        assert!(text.contains("S0 |"), "{text}");
        assert!(text.contains("S1 |"), "{text}");
        let s0 = text.lines().find(|l| l.starts_with("S0")).unwrap();
        let s1 = text.lines().find(|l| l.starts_with("S1")).unwrap();
        assert!(s0.contains('0') && s0.contains('1'), "{s0}");
        assert!(s1.contains('0'), "{s1}");
        assert!(text.contains("% busy"), "{text}");
    }

    #[test]
    fn empty_stats_render_without_panicking() {
        let stats = PipelineStats::new(vec!["L00 a".into()]);
        let text = render(&stats, 24);
        assert!(text.contains("0 batch-events"), "{text}");
        assert!(text.contains("S0 |"), "{text}");
    }

    #[test]
    fn width_is_clamped_and_events_stay_in_bounds() {
        let stats = stats_with(&[(0, 3, 0, 1_000_000), (0, 4, 1_000_000, 1_000_001)]);
        let text = render(&stats, 1); // clamps to the 8-column floor
        let s0 = text.lines().find(|l| l.starts_with("S0")).unwrap();
        assert_eq!(s0.split('|').nth(1).unwrap().chars().count(), 8);
    }
}
