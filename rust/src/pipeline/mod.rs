//! Deep-pipelined serving engine: the software twin of the paper's Fig.-4
//! pipeline, executing a [`NativeModel`](crate::native::NativeModel) as a
//! chain of per-layer **stage workers** with multiple batches in flight.
//!
//! The cycle simulator (`crate::fpga::controller`) *costs* the paper's
//! deeply pipelined datapath; until this subsystem the serving stack never
//! *ran* one — `coordinator::server`'s executor thread walked every layer
//! of a batch end to end while the cores of every other layer idled.  Here
//! the layer program is split into stages ([`plan::PipelinePlan`]), each
//! stage owns a worker thread with its own resident weight spectra and
//! scratch, and bounded channels stream batches down the chain: batch N
//! occupies layer ℓ+1 while batch N+1 occupies layer ℓ, exactly the
//! inter-layer pipelining CirCNN (Ding et al., MICRO'17) names as the
//! throughput lever for block-circulant datapaths.
//!
//! ```text
//!   submit ─► [stage 0] ─► [stage 1] ─► … ─► [stage S-1] ─► sink
//!   (≤ depth batches in flight, token-bounded: stage 0 *blocks* rather
//!    than buffering unboundedly — the serving-side backpressure story)
//! ```
//!
//! Guarantees:
//!
//! * **Bitwise identity.** Every stage runs the same owned-step walk
//!   ([`NativeModel::run_ops`](crate::native::NativeModel)) `forward` runs,
//!   so per-batch results equal `NativeModel::forward` bit for bit — across
//!   stage counts, in-flight depths and `CIRCNN_THREADS` settings
//!   (property-pinned in [`engine`]).  Within a stage, work still shards
//!   over [`crate::circulant::sched`].
//! * **FIFO ordering.** One submitter sees completions in submission order
//!   (each hop is a single-producer/single-consumer FIFO).
//! * **Bounded in-flight.** At most `depth` batches are past `submit` and
//!   not yet through the sink (default: one per stage).
//!
//! Per-stage occupancy (busy/idle fractions, per-batch events) is recorded
//! in [`stage::PipelineStats`] and rendered by [`timeline::render`] — the
//! serving-side analogue of `fpga::controller::render_timeline`, surfaced
//! through `coordinator::metrics`.
//!
//! Thread-budget caveat: the stage count is capped at
//! [`sched::max_threads`](crate::circulant::sched::max_threads), but each
//! stage's inner matmul/conv still budgets its *own* shards against the
//! full core count — concurrently busy stages can therefore oversubscribe
//! the machine (≈ stages × shards runnable threads) on workloads big
//! enough to shard inside every stage.  The small-problem shard cap keeps
//! the common serving regime (modest batches) one shard per stage; a
//! global thread budget shared between stage- and shard-level parallelism
//! is the named follow-up in ROADMAP.  `CIRCNN_THREADS=1` bounds both
//! levels today.

pub mod engine;
pub mod plan;
pub mod stage;
pub mod timeline;

pub use engine::{Pipeline, SubmitError};
pub use plan::{PipelinePlan, StageSpec};
pub use stage::{PipelineStats, StageEvent, StageStat};
