//! Softmax–cross-entropy head: the loss the native trainer minimizes and
//! its gradient, fused in one pass (the softmax never materializes the
//! normalized probabilities twice).
//!
//! Mirrors `python/compile/train.cross_entropy` (mean over the batch,
//! log-softmax with max-subtraction for stability); the gradient is the
//! classic `(softmax(logits) - onehot(label)) / batch`.

/// Mean cross-entropy over `(batch, classes)` logits plus the logit
/// gradient of the *mean* loss (so downstream weight gradients are already
/// batch-averaged).
pub fn softmax_xent(logits: &[f32], labels: &[u32], classes: usize) -> (f32, Vec<f32>) {
    let batch = labels.len();
    assert!(batch > 0, "empty batch has no loss");
    assert_eq!(logits.len(), batch * classes, "logit buffer size");
    let mut grad = vec![0.0f32; logits.len()];
    let inv_b = 1.0 / batch as f32;
    let mut loss = 0.0f64;
    for b in 0..batch {
        let row = &logits[b * classes..(b + 1) * classes];
        let y = labels[b] as usize;
        assert!(y < classes, "label {y} out of range");
        let max = row.iter().fold(f32::NEG_INFINITY, |m, &v| m.max(v));
        let mut denom = 0.0f32;
        for &v in row {
            denom += (v - max).exp();
        }
        loss += (denom.ln() + max - row[y]) as f64;
        let g = &mut grad[b * classes..(b + 1) * classes];
        for (gv, &v) in g.iter_mut().zip(row) {
            *gv = (v - max).exp() / denom * inv_b;
        }
        g[y] -= inv_b;
    }
    ((loss / batch as f64) as f32, grad)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::SplitMix;

    #[test]
    fn uniform_logits_lose_ln_classes() {
        let (loss, grad) = softmax_xent(&[0.0; 20], &[3, 7], 10);
        assert!((loss - (10.0f32).ln()).abs() < 1e-5);
        // gradient rows sum to zero (softmax sums to 1, onehot to 1)
        for row in grad.chunks(10) {
            assert!(row.iter().sum::<f32>().abs() < 1e-6);
        }
    }

    #[test]
    fn confident_correct_prediction_has_small_loss() {
        let mut logits = vec![0.0f32; 10];
        logits[4] = 20.0;
        let (loss, _) = softmax_xent(&logits, &[4], 10);
        assert!(loss < 1e-3, "loss {loss}");
    }

    #[test]
    fn gradient_matches_finite_differences() {
        let mut rng = SplitMix::new(5);
        let (batch, classes) = (3, 10);
        let logits = rng.normal_vec(batch * classes);
        let labels = [1u32, 9, 0];
        let (_, grad) = softmax_xent(&logits, &labels, classes);
        let eps = 1e-2f32;
        for t in 0..logits.len() {
            let mut lp = logits.clone();
            let (hi_l, lo_l) = (logits[t] + eps, logits[t] - eps);
            lp[t] = hi_l;
            let (hi, _) = softmax_xent(&lp, &labels, classes);
            lp[t] = lo_l;
            let (lo, _) = softmax_xent(&lp, &labels, classes);
            let want = (hi - lo) / (hi_l - lo_l);
            assert!(
                (grad[t] - want).abs() < 1e-3 + 1e-2 * want.abs(),
                "logit {t}: analytic {} vs numeric {want}",
                grad[t]
            );
        }
    }
}
