//! SGD with classical momentum — the native trainer's update rule.
//!
//! `v ← μ v + g`, `w ← w − lr · v`, per parameter tensor.  Velocity
//! buffers are registered once per tensor ([`Sgd::slot`]) and reused every
//! step, so the optimizer allocates nothing on the training path.  (The
//! Python pipeline uses Adam; SGD+momentum keeps the native subsystem
//! dependency-free and is what the paper's FPGA training sketch assumes.)

/// SGD + momentum over named parameter slots.
pub struct Sgd {
    pub lr: f32,
    pub momentum: f32,
    vel: Vec<Vec<f32>>,
}

impl Sgd {
    pub fn new(lr: f32, momentum: f32) -> Self {
        Self { lr, momentum, vel: Vec::new() }
    }

    /// Register a parameter tensor of `len` values; returns its slot id.
    pub fn slot(&mut self, len: usize) -> usize {
        self.vel.push(vec![0.0; len]);
        self.vel.len() - 1
    }

    /// One update of `params` from `grads` through slot `slot`'s velocity.
    pub fn update(&mut self, slot: usize, params: &mut [f32], grads: &[f32]) {
        let vel = &mut self.vel[slot];
        assert_eq!(vel.len(), params.len(), "slot/tensor size mismatch");
        assert_eq!(grads.len(), params.len(), "grad/tensor size mismatch");
        for ((v, p), &g) in vel.iter_mut().zip(params.iter_mut()).zip(grads) {
            *v = self.momentum * *v + g;
            *p -= self.lr * *v;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zero_momentum_is_plain_sgd() {
        let mut opt = Sgd::new(0.1, 0.0);
        let s = opt.slot(2);
        let mut w = [1.0f32, -1.0];
        opt.update(s, &mut w, &[2.0, -4.0]);
        assert_eq!(w, [0.8, -0.6]);
    }

    #[test]
    fn momentum_accumulates_velocity() {
        let mut opt = Sgd::new(1.0, 0.5);
        let s = opt.slot(1);
        let mut w = [0.0f32];
        opt.update(s, &mut w, &[1.0]); // v = 1,   w = -1
        opt.update(s, &mut w, &[1.0]); // v = 1.5, w = -2.5
        assert!((w[0] + 2.5).abs() < 1e-6);
    }

    #[test]
    fn slots_are_independent() {
        let mut opt = Sgd::new(1.0, 0.9);
        let a = opt.slot(1);
        let b = opt.slot(1);
        let (mut wa, mut wb) = ([0.0f32], [0.0f32]);
        opt.update(a, &mut wa, &[1.0]);
        opt.update(b, &mut wb, &[1.0]);
        assert_eq!(wa, wb, "fresh slots must behave identically");
    }
}
