//! Native FFT-domain training subsystem: O(n log n) backpropagation for
//! block-circulant layers on the pure-Rust substrate — no PJRT, no XLA.
//!
//! CirCNN's training derivation (Ding et al., 2017, Eqns. 2/3) shows both
//! gradients of a circulant block are themselves FFT→elementwise→IFFT
//! computations: `dL/dx = IFFT(conj(FFT(w)) o FFT(g))` (the transposed
//! matvec as a conjugate-spectrum product) and `dL/dw = IFFT(conj(FFT(x))
//! o FFT(g))` (a circular cross-correlation).  This module wires those
//! kernels (`circulant::block::{backward, input_spectra}`,
//! `native::conv::backward` — both running the weight-spectrum-resident
//! sweep ordering, so each `conj(W_ij)` spectrum and each frequency-domain
//! `gw_ij` accumulator is loaded once per shard and streamed across the
//! batch; the executed transform counts, and therefore the accounting
//! below, are ordering-invariant) into a full trainer: forward walks the same
//! `native` op program the inference engine executes — every activation
//! moved (not cloned) into a trace, BC input spectra kept hot in
//! caller-owned scratch — backward masks through the recorded activations
//! and updates in place with SGD+momentum, and a softmax–cross-entropy
//! head closes the loop over the bit-exact `data` synthetic datasets.
//!
//! ## What trains
//!
//! Block-circulant FC and CONV layers and the uncompressed dense
//! classifier heads.  Uncompressed conv *stems* stay frozen (they are the
//! registry's first layer everywhere; validated at construction so no
//! gradient ever needs to flow through a dense convolution).  Pooling,
//! flatten, prior-pool and residual joins backpropagate as pure gradient
//! transforms ([`backprop`]).
//!
//! ## FFT accounting convention (pinned by the train parity test)
//!
//! A train step on a batch of B images charges, per BC layer
//! ([`crate::models::FftWork::train_charge`]):
//!
//! * **FFTs** — `B·(ffts_total + iffts_total) + weight_blocks`: forward
//!   input spectra, backward gradient spectra (computed once per sample
//!   and shared by both Eqn.-2/3 products), plus one per-step re-FFT of
//!   each updated weight block (the paper's "offline" FFT(w) step becomes
//!   per-step under training).  Input spectra are charged once — the
//!   forward's planes stay resident and the weight gradient reuses them.
//! * **IFFTs** — `B·(iffts_total + ffts_total) + weight_blocks`: forward
//!   outputs, input gradients, and one irfft per weight block for `dL/dw`
//!   — the weight gradient accumulates in the *frequency domain* across
//!   the whole batch, so its transforms amortize over B instead of
//!   scaling with it (the training-side reuse the Structured Weight
//!   Matrices accelerator work builds on).
//! * **multiply groups** — `3·B·mult_groups_total`: forward `W∘X`,
//!   input-grad `conj(W)∘G`, weight-grad `conj(X)∘G`.  The input-gradient
//!   product is executed for every BC layer, including the lowest one
//!   (whose `dL/dx` is discarded): the charge stays uniform per layer.
//!
//! Per-layer executed counters are accumulated every step
//! ([`Trainer::layer_counters`]) and must equal this charge exactly.
//!
//! Gradient scratch (spectra planes, weight/bias gradient buffers, the
//! rotating input-gradient buffer) is `Workspace`-style: owned by the
//! trainer and resized in place, so steady-state steps allocate only the
//! activation tensors themselves (plus one skip-gradient clone per
//! residual join, mirroring the forward's residual-stack clone).

pub mod backprop;
pub mod loss;
pub mod optim;

use std::sync::Arc;
use std::time::Instant;

use anyhow::bail;

use crate::circulant::sched::PhaseCounters;
use crate::data;
use crate::models::Model;
use crate::native::conv::{self, ConvFwdCache, ConvShape};
use crate::native::{self, NativeModel, Op, Tensor};
use crate::telemetry::{Counter, Histogram, Registry};

use optim::Sgd;

/// Hyperparameters and loop shape of a training run.
#[derive(Debug, Clone, Copy)]
pub struct TrainConfig {
    pub steps: usize,
    pub batch: usize,
    pub lr: f32,
    pub momentum: f32,
    /// training-set prefix the minibatch loop cycles over
    pub train_size: usize,
    /// print a loss line every N steps (0 = silent)
    pub log_every: usize,
}

impl Default for TrainConfig {
    fn default() -> Self {
        Self { steps: 50, batch: 64, lr: 0.02, momentum: 0.9, train_size: 4096, log_every: 10 }
    }
}

/// Per-op reusable training scratch: BC input-spectra planes (FC), the
/// conv forward cache, and weight/bias gradient buffers.
struct LayerScratch {
    xr: Vec<f32>,
    xi: Vec<f32>,
    conv: ConvFwdCache,
    gw: Vec<f32>,
    gb: Vec<f32>,
}

impl LayerScratch {
    fn new() -> Self {
        Self {
            xr: Vec::new(),
            xi: Vec::new(),
            conv: ConvFwdCache::new(),
            gw: Vec::new(),
            gb: Vec::new(),
        }
    }
}

/// Pre-registered telemetry handles ([`Trainer::attach_telemetry`]): one
/// step-duration histogram plus, per op, the three executed-transform
/// counters — the runtime view of the same [`PhaseCounters`] evidence the
/// train parity test pins.
struct TrainTelemetry {
    steps: Counter,
    step_us: Histogram,
    /// per op: `[ffts, iffts, mult_groups]` running totals
    layers: Vec<[Counter; 3]>,
}

/// The native trainer: owns a float32 [`NativeModel`] and updates it in
/// place, step by step, entirely in the spectral domain.
pub struct Trainer {
    model: NativeModel,
    input: (usize, usize, usize),
    opt: Sgd,
    /// optimizer slots per op: (weight slot, bias slot)
    slots: Vec<Option<(usize, usize)>>,
    /// lowest op index with trainable parameters — backward stops there
    first_trainable: usize,
    /// executed transforms per op during the last step
    layer_counters: Vec<PhaseCounters>,
    scratch: Vec<LayerScratch>,
    /// rotating input-gradient buffer (reused across ops and steps)
    gbuf: Vec<f32>,
    serial: bool,
    /// publish step timing + executed transforms into a metrics registry
    /// (`None` = zero overhead: no clocks read, no counters touched)
    telemetry: Option<TrainTelemetry>,
}

impl Trainer {
    /// Fresh trainer over He-init random parameters for a registry model.
    pub fn new(model: &Model, seed: u64) -> anyhow::Result<Self> {
        Self::from_native(NativeModel::init_random(model, seed), model.input)
    }

    /// Wrap an existing float32 native model (e.g. loaded parameters for
    /// fine-tuning).  `input` is the `(h, w, c)` image geometry.
    pub fn from_native(model: NativeModel, input: (usize, usize, usize)) -> anyhow::Result<Self> {
        if model.quant_bits.is_some() {
            bail!("the native trainer is float32; compile the model with quant_bits = None");
        }
        let mut opt = Sgd::new(0.02, 0.9);
        let mut slots = Vec::with_capacity(model.ops.len());
        for op in &model.ops {
            slots.push(match op {
                Op::BcDense { bc, bias, .. } | Op::BcConv { bc, bias, .. } => {
                    Some((opt.slot(bc.w.len()), opt.slot(bias.len())))
                }
                Op::Dense { w, bias, .. } => Some((opt.slot(w.len()), opt.slot(bias.len()))),
                // uncompressed conv stems train frozen (no slot); validated
                // below so no gradient ever needs a dense-conv backward
                _ => None,
            });
        }
        let Some(first_trainable) = slots.iter().position(Option::is_some) else {
            bail!("model has no trainable layers");
        };
        for (i, op) in model.ops.iter().enumerate().skip(first_trainable) {
            if matches!(op, Op::Conv { .. } | Op::PriorPool { .. }) {
                bail!("op {i}: frozen stem ops (conv / prior-pool) must precede every trainable layer");
            }
        }
        let n_ops = model.ops.len();
        Ok(Self {
            model,
            input,
            opt,
            slots,
            first_trainable,
            layer_counters: vec![PhaseCounters::default(); n_ops],
            scratch: (0..n_ops).map(|_| LayerScratch::new()).collect(),
            gbuf: Vec::new(),
            serial: false,
            telemetry: None,
        })
    }

    /// Publish per-step timing (`train_step_us` histogram, log2 buckets)
    /// and per-layer executed transforms (`train_layer_*_total` counters,
    /// labelled by model/layer) into `registry` from every subsequent
    /// [`step`](Self::step).  Handles are registered once here, so the
    /// per-step cost is a few relaxed atomic adds.
    pub fn attach_telemetry(&mut self, registry: &Arc<Registry>, model_name: &str) {
        let layers = (0..self.model.ops.len())
            .map(|i| {
                let labels =
                    [("model", model_name.to_string()), ("layer", format!("{i:02}"))];
                [
                    registry.counter_with("train_layer_ffts_total", &labels),
                    registry.counter_with("train_layer_iffts_total", &labels),
                    registry.counter_with("train_layer_mult_groups_total", &labels),
                ]
            })
            .collect();
        self.telemetry = Some(TrainTelemetry {
            steps: registry.counter("train_steps_total"),
            step_us: registry.histogram("train_step_us"),
            layers,
        });
    }

    /// Route the FC forward/backward and the conv backward through the
    /// single-shard kernels (the bench baseline).  The conv forward keeps
    /// the shared pixel pipeline either way (`CIRCNN_THREADS=1` pins that
    /// one serial too).
    pub fn set_serial(&mut self, serial: bool) {
        self.serial = serial;
    }

    /// Override the optimizer hyperparameters (velocities are kept).
    pub fn set_hyperparams(&mut self, lr: f32, momentum: f32) {
        self.opt.lr = lr;
        self.opt.momentum = momentum;
    }

    /// The trained model (inference-ready: spectra are refreshed after
    /// every update).
    pub fn model(&self) -> &NativeModel {
        &self.model
    }

    /// Consume the trainer, keeping the trained model.
    pub fn into_model(self) -> NativeModel {
        self.model
    }

    /// Executed transforms per op during the last [`step`](Self::step) —
    /// the evidence the train parity test pins against
    /// [`crate::models::FftWork::train_charge`].
    pub fn layer_counters(&self) -> &[PhaseCounters] {
        &self.layer_counters
    }

    /// One SGD+momentum step on a minibatch `(xs, ys)`; returns the mean
    /// loss at the pre-update parameters.
    pub fn step(&mut self, xs: &[f32], ys: &[u32]) -> f32 {
        let (h, w, c) = self.input;
        let batch = ys.len();
        assert!(batch > 0, "empty batch");
        assert_eq!(xs.len(), batch * h * w * c, "image buffer size");
        let step_t0 = self.telemetry.as_ref().map(|_| Instant::now());
        for ctr in &mut self.layer_counters {
            *ctr = PhaseCounters::default();
        }

        // ---- forward: every activation moved into the trace, BC input
        // spectra cached in the per-layer scratch for backward reuse
        let mut acts: Vec<Tensor> = Vec::with_capacity(self.model.ops.len() + 1);
        acts.push(Tensor { batch, h, w, c, data: xs.to_vec() });
        let mut residuals: Vec<Tensor> = Vec::new();
        for i in 0..self.model.ops.len() {
            let x = acts.last().unwrap();
            let next = match &self.model.ops[i] {
                Op::BcDense { bc, bias, relu } => {
                    let kh = bc.k / 2 + 1;
                    let sc = &mut self.scratch[i];
                    sc.xr.resize(batch * bc.q * kh, 0.0);
                    sc.xi.resize(batch * bc.q * kh, 0.0);
                    let c1 = if self.serial {
                        bc.input_spectra_serial(&x.data, batch, &mut sc.xr, &mut sc.xi)
                    } else {
                        bc.input_spectra(&x.data, batch, &mut sc.xr, &mut sc.xi)
                    };
                    let m = bc.rows();
                    let mut out = vec![0.0f32; batch * m];
                    let c2 = if self.serial {
                        bc.matmul_from_spectra_serial(&sc.xr, &sc.xi, batch, &mut out)
                    } else {
                        bc.matmul_from_spectra(&sc.xr, &sc.xi, batch, &mut out)
                    };
                    native::finish_rows(&mut out, bias, m, *relu);
                    self.layer_counters[i].add(c1);
                    self.layer_counters[i].add(c2);
                    Tensor { batch, h: m, w: 1, c: 1, data: out }
                }
                Op::BcConv { bc, bias, r, same, relu } => {
                    let shape = ConvShape { h: x.h, w: x.w, c: x.c, r: *r, same: *same };
                    let o = conv::forward_cached(
                        bc,
                        &x.data,
                        batch,
                        shape,
                        bias,
                        *relu,
                        &mut self.scratch[i].conv,
                    );
                    self.layer_counters[i].add(o.counters);
                    Tensor { batch, h: o.oh, w: o.ow, c: bc.rows(), data: o.data }
                }
                op => self.model.step_ref(op, x, &mut residuals),
            };
            acts.push(next);
        }

        // ---- loss head
        let logits = acts.last().unwrap();
        let classes = logits.data.len() / batch;
        let (loss_val, mut g) = loss::softmax_xent(&logits.data, ys, classes);

        // ---- backward + in-place updates, stopping at the lowest
        // trainable op (gradients below it have no consumer)
        let mut spare = std::mem::take(&mut self.gbuf);
        let mut res_grads: Vec<Vec<f32>> = Vec::new();
        for i in (self.first_trainable..self.model.ops.len()).rev() {
            let xin = &acts[i];
            let out = &acts[i + 1];
            match &mut self.model.ops[i] {
                Op::BcDense { bc, bias, relu } => {
                    if *relu {
                        backprop::mask_relu(&mut g, &out.data);
                    }
                    let sc = &mut self.scratch[i];
                    sc.gb.resize(bias.len(), 0.0);
                    backprop::bias_grad(&g, bias.len(), &mut sc.gb);
                    sc.gw.resize(bc.w.len(), 0.0);
                    spare.clear();
                    spare.resize(batch * bc.cols(), 0.0);
                    let cb = if self.serial {
                        bc.backward_serial(&sc.xr, &sc.xi, &g, batch, &mut spare, &mut sc.gw)
                    } else {
                        bc.backward(&sc.xr, &sc.xi, &g, batch, &mut spare, &mut sc.gw)
                    };
                    self.layer_counters[i].add(cb);
                    let (ws, bs) = self.slots[i].expect("BC dense layers always train");
                    self.opt.update(ws, &mut bc.w, &sc.gw);
                    self.opt.update(bs, bias, &sc.gb);
                    // refresh the resident weight spectra for the next step
                    // — the charged per-step FFT(w) transforms
                    bc.precompute();
                    self.layer_counters[i].ffts += (bc.p * bc.q) as u64;
                    std::mem::swap(&mut g, &mut spare);
                }
                Op::BcConv { bc, bias, r, same, relu } => {
                    if *relu {
                        backprop::mask_relu(&mut g, &out.data);
                    }
                    let sc = &mut self.scratch[i];
                    sc.gb.resize(bias.len(), 0.0);
                    backprop::bias_grad(&g, bias.len(), &mut sc.gb);
                    sc.gw.resize(bc.w.len(), 0.0);
                    spare.clear();
                    spare.resize(batch * xin.per_image(), 0.0);
                    let shape = ConvShape { h: xin.h, w: xin.w, c: xin.c, r: *r, same: *same };
                    let cb = if self.serial {
                        conv::backward_serial(bc, &sc.conv, &g, batch, shape, &mut spare, &mut sc.gw)
                    } else {
                        conv::backward(bc, &sc.conv, &g, batch, shape, &mut spare, &mut sc.gw)
                    };
                    self.layer_counters[i].add(cb);
                    let (ws, bs) = self.slots[i].expect("BC conv layers always train");
                    self.opt.update(ws, &mut bc.w, &sc.gw);
                    self.opt.update(bs, bias, &sc.gb);
                    bc.precompute();
                    self.layer_counters[i].ffts += (bc.p * bc.q) as u64;
                    std::mem::swap(&mut g, &mut spare);
                }
                Op::Dense { w, n, m, bias, relu } => {
                    if *relu {
                        backprop::mask_relu(&mut g, &out.data);
                    }
                    let sc = &mut self.scratch[i];
                    sc.gw.resize(w.len(), 0.0);
                    sc.gb.resize(bias.len(), 0.0);
                    spare.clear();
                    spare.resize(batch * *n, 0.0);
                    backprop::dense_backward(
                        w,
                        *n,
                        *m,
                        &xin.data,
                        &g,
                        batch,
                        &mut spare,
                        &mut sc.gw,
                        &mut sc.gb,
                    );
                    let (ws, bs) = self.slots[i].expect("dense layers always train");
                    self.opt.update(ws, w, &sc.gw);
                    self.opt.update(bs, bias, &sc.gb);
                    std::mem::swap(&mut g, &mut spare);
                }
                Op::Flatten => {} // pure reshape: the gradient data is unchanged
                Op::AvgPool2 => {
                    spare.clear();
                    spare.resize(batch * xin.per_image(), 0.0);
                    backprop::avg_pool2_backward(
                        &g, batch, out.h, out.w, out.c, xin.h, xin.w, &mut spare,
                    );
                    std::mem::swap(&mut g, &mut spare);
                }
                Op::MaxPool2 => {
                    spare.clear();
                    spare.resize(batch * xin.per_image(), 0.0);
                    backprop::max_pool2_backward(
                        &g, &xin.data, batch, out.h, out.w, out.c, xin.h, xin.w, &mut spare,
                    );
                    std::mem::swap(&mut g, &mut spare);
                }
                Op::ResidualEnd => {
                    // out = relu(branch + skip): mask once, then the same
                    // gradient flows down the branch and (via the stack)
                    // joins back at the matching ResidualBegin
                    backprop::mask_relu(&mut g, &out.data);
                    res_grads.push(g.clone());
                }
                Op::ResidualBegin => {
                    let skip = res_grads.pop().expect("unmatched residual end in backward");
                    for (gv, s) in g.iter_mut().zip(&skip) {
                        *gv += s;
                    }
                }
                Op::Conv { .. } | Op::PriorPool { .. } => {
                    unreachable!("validated at construction: frozen stem ops precede trainable layers")
                }
            }
        }
        self.gbuf = spare;
        if let (Some(tel), Some(t0)) = (&self.telemetry, step_t0) {
            tel.steps.inc();
            tel.step_us.observe(t0.elapsed().as_micros() as u64);
            for (ctr, handles) in self.layer_counters.iter().zip(&tel.layers) {
                handles[0].add(ctr.ffts);
                handles[1].add(ctr.iffts);
                handles[2].add(ctr.mult_groups);
            }
        }
        loss_val
    }

    /// Minibatch loop over a dataset's training split, cycling the first
    /// `max(cfg.train_size, cfg.batch)` samples (at least one full batch);
    /// returns the loss history (loss-curve lines match the PJRT artifact
    /// driver's format).
    pub fn train(&mut self, ds: &data::DatasetSpec, cfg: &TrainConfig) -> Vec<f32> {
        assert!(cfg.batch > 0, "cfg.batch must be >= 1");
        self.set_hyperparams(cfg.lr, cfg.momentum);
        let n_batches = (cfg.train_size / cfg.batch).max(1);
        let mut losses = Vec::with_capacity(cfg.steps);
        for s in 0..cfg.steps {
            let lo = ((s % n_batches) * cfg.batch) as u64;
            let (xs, ys) = data::batch(ds, lo, cfg.batch, false);
            let loss = self.step(&xs, &ys);
            losses.push(loss);
            if cfg.log_every > 0 && (s % cfg.log_every == 0 || s + 1 == cfg.steps) {
                println!("  step {s:4}  loss {loss:.4}");
            }
        }
        losses
    }

    /// Accuracy on the disjoint test split.
    pub fn eval_accuracy(&self, ds: &data::DatasetSpec, count: usize, batch: usize) -> f64 {
        assert!(count > 0 && batch > 0, "count and batch must be >= 1");
        let (h, w, c) = self.input;
        let mut correct = 0usize;
        let mut done = 0usize;
        while done < count {
            let n = batch.min(count - done);
            let (xs, ys) = data::batch(ds, done as u64, n, true);
            let preds = self.model.classify(&xs, n, h, w, c);
            correct += preds.iter().zip(&ys).filter(|(p, y)| p == y).count();
            done += n;
        }
        correct as f64 / count as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::{self, Layer};

    #[test]
    fn smoke_fixed_seed_20_steps_loss_decreases_on_mnist_s() {
        // the acceptance smoke: overfit one fixed mnist_s minibatch for 20
        // steps; the loss must trend monotonically down
        let model = models::by_name("mnist_mlp_1").unwrap();
        let mut tr = Trainer::new(&model, 42).unwrap();
        tr.set_hyperparams(0.1, 0.9);
        let (xs, ys) = data::batch(&data::MNIST_S, 0, 64, false);
        let losses: Vec<f32> = (0..20).map(|_| tr.step(&xs, &ys)).collect();
        assert!(
            losses[19] < losses[0],
            "no loss decrease over 20 steps: {losses:?}"
        );
        let first: f32 = losses[..5].iter().sum();
        let last: f32 = losses[15..].iter().sum();
        assert!(
            last < 0.95 * first,
            "loss not trending down: first5 {first}, last5 {last} ({losses:?})"
        );
    }

    #[test]
    fn executed_counters_equal_the_training_charge() {
        // the acceptance parity: per BC layer, the transforms one train
        // step actually executes equal models::FftWork::train_charge —
        // across FC-only, conv (SAME + pools + frozen stem), and residual
        // topologies
        for name in ["mnist_mlp_2", "mnist_lenet", "svhn_cnn", "cifar_wrn"] {
            let model = models::by_name(name).unwrap();
            let mut tr = Trainer::new(&model, 7).unwrap();
            let ds = data::dataset(model.dataset).unwrap();
            let batch = 2;
            let (xs, ys) = data::batch(&ds, 0, batch, false);
            tr.step(&xs, &ys);
            let accounting = model.accounting();
            let mut rows = accounting.iter();
            for (i, layer) in model.layers.iter().enumerate() {
                let row = match layer {
                    Layer::BcDense { .. }
                    | Layer::BcConv { .. }
                    | Layer::Dense { .. }
                    | Layer::Conv { .. } => rows.next().expect("accounting row"),
                    _ => continue,
                };
                if matches!(layer, Layer::BcDense { .. } | Layer::BcConv { .. }) {
                    assert_eq!(
                        tr.layer_counters()[i],
                        row.fft_work.train_charge(batch as u64),
                        "{name} op {i}: executed training transforms != charge"
                    );
                }
            }
        }
    }

    #[test]
    fn serial_step_matches_parallel_loss_and_counters() {
        let model = models::by_name("mnist_mlp_2").unwrap();
        let mut par = Trainer::new(&model, 3).unwrap();
        let mut ser = Trainer::new(&model, 3).unwrap();
        ser.set_serial(true);
        let (xs, ys) = data::batch(&data::MNIST_S, 0, 16, false);
        // forward work is bitwise shard-invariant, so the first-step loss
        // must agree exactly; executed counters never depend on sharding
        let lp = par.step(&xs, &ys);
        let ls = ser.step(&xs, &ys);
        assert_eq!(lp.to_bits(), ls.to_bits(), "losses diverged: {lp} vs {ls}");
        assert_eq!(par.layer_counters(), ser.layer_counters());
    }

    #[test]
    fn trained_model_beats_chance_on_held_out_data() {
        // a short real run (cycling fresh minibatches) must land well above
        // the 10% chance floor on the disjoint test split
        let model = models::by_name("mnist_mlp_1").unwrap();
        let mut tr = Trainer::new(&model, 1).unwrap();
        let cfg = TrainConfig {
            steps: 40,
            batch: 32,
            lr: 0.05,
            train_size: 960,
            log_every: 0,
            ..TrainConfig::default()
        };
        tr.train(&data::MNIST_S, &cfg);
        let acc = tr.eval_accuracy(&data::MNIST_S, 256, 64);
        assert!(acc > 0.2, "test accuracy {acc} not above chance");
    }

    #[test]
    fn attached_telemetry_mirrors_the_executed_counters() {
        let model = models::by_name("mnist_mlp_1").unwrap();
        let mut tr = Trainer::new(&model, 5).unwrap();
        let registry = Arc::new(Registry::new());
        tr.attach_telemetry(&registry, "mnist_mlp_1");
        let (xs, ys) = data::batch(&data::MNIST_S, 0, 8, false);
        tr.step(&xs, &ys);
        tr.step(&xs, &ys);
        assert_eq!(registry.counter("train_steps_total").get(), 2);
        assert_eq!(registry.histogram("train_step_us").count(), 2);
        // both steps execute identical work, so each per-layer counter
        // holds exactly twice the last step's executed transforms
        for (i, ctr) in tr.layer_counters().iter().enumerate() {
            let labels = [("model", "mnist_mlp_1".to_string()), ("layer", format!("{i:02}"))];
            assert_eq!(
                registry.counter_with("train_layer_ffts_total", &labels).get(),
                2 * ctr.ffts,
                "op {i} fft counter"
            );
            assert_eq!(
                registry.counter_with("train_layer_mult_groups_total", &labels).get(),
                2 * ctr.mult_groups,
                "op {i} mult-group counter"
            );
        }
    }

    #[test]
    fn quantized_models_are_rejected() {
        let model = models::by_name("mnist_mlp_1").unwrap();
        let mut native = NativeModel::init_random(&model, 0);
        native.quant_bits = Some(12);
        assert!(Trainer::from_native(native, model.input).is_err());
    }
}
