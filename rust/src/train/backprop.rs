//! Backward rules for the non-spectral ops of the layer program: the dense
//! classifier head, 2x2 pooling, and the relu mask.  The spectral layers'
//! backwards live with their forwards (`circulant::block::backward`,
//! `native::conv::backward`); everything here is plain O(n) / O(n^2) CPU
//! work on the small head/pool tensors.

/// Relu mask: zero the gradient wherever the recorded *output* activation
/// is not positive.  (Post-relu outputs are >= 0; a zero output means the
/// pre-activation was clipped — or sat exactly at zero, where the
/// subgradient 0 is the standard choice.)
pub fn mask_relu(grad: &mut [f32], out: &[f32]) {
    debug_assert_eq!(grad.len(), out.len());
    for (g, &o) in grad.iter_mut().zip(out) {
        if o <= 0.0 {
            *g = 0.0;
        }
    }
}

/// Bias gradient: column sums of a `(rows, m)` gradient buffer into `gb`.
pub fn bias_grad(gys: &[f32], m: usize, gb: &mut [f32]) {
    debug_assert_eq!(gb.len(), m);
    gb.fill(0.0);
    for row in gys.chunks(m) {
        for (b, &g) in gb.iter_mut().zip(row) {
            *b += g;
        }
    }
}

/// Backward of the uncompressed dense head `y = x W + b` (python
/// convention, `W` is `(n, m)` row-major): `gx = gy W^T`,
/// `gw = Σ_batch x^T gy`, `gb = Σ_batch gy`.
#[allow(clippy::too_many_arguments)]
pub fn dense_backward(
    w: &[f32],
    n: usize,
    m: usize,
    xs: &[f32],
    gys: &[f32],
    batch: usize,
    gx: &mut [f32],
    gw: &mut [f32],
    gb: &mut [f32],
) {
    debug_assert_eq!(w.len(), n * m);
    debug_assert_eq!(xs.len(), batch * n);
    debug_assert_eq!(gys.len(), batch * m);
    debug_assert_eq!(gx.len(), batch * n);
    debug_assert_eq!(gw.len(), n * m);
    gw.fill(0.0);
    bias_grad(gys, m, gb);
    for b in 0..batch {
        let gy = &gys[b * m..(b + 1) * m];
        let x = &xs[b * n..(b + 1) * n];
        let gxr = &mut gx[b * n..(b + 1) * n];
        for i in 0..n {
            let wr = &w[i * m..(i + 1) * m];
            let mut acc = 0.0f32;
            for (&wv, &gv) in wr.iter().zip(gy) {
                acc += wv * gv;
            }
            gxr[i] = acc;
            let xv = x[i];
            if xv != 0.0 {
                // post-relu inputs are sparse, same skip as the forward
                for (gwv, &gv) in gw[i * m..(i + 1) * m].iter_mut().zip(gy) {
                    *gwv += xv * gv;
                }
            }
        }
    }
}

/// Backward of 2x2 average pooling: each output gradient spreads 1/4 to
/// its window (rows/columns beyond `2*oh`/`2*ow` were never read by the
/// forward and get zero gradient).
#[allow(clippy::too_many_arguments)]
pub fn avg_pool2_backward(
    gys: &[f32],
    batch: usize,
    oh: usize,
    ow: usize,
    c: usize,
    h: usize,
    w: usize,
    gx: &mut [f32],
) {
    debug_assert_eq!(gys.len(), batch * oh * ow * c);
    debug_assert_eq!(gx.len(), batch * h * w * c);
    gx.fill(0.0);
    for b in 0..batch {
        for oy in 0..oh {
            for ox in 0..ow {
                for ch in 0..c {
                    let g = 0.25 * gys[((b * oh + oy) * ow + ox) * c + ch];
                    for dy in 0..2 {
                        for dx in 0..2 {
                            gx[((b * h + 2 * oy + dy) * w + 2 * ox + dx) * c + ch] += g;
                        }
                    }
                }
            }
        }
    }
}

/// Backward of 2x2 max pooling: the whole gradient routes to the first
/// window element attaining the maximum (scan order (0,0), (0,1), (1,0),
/// (1,1) — the forward's `a.max(b).max(c).max(d)` ties resolve to any of
/// the equal values, so first-match is a valid subgradient).
#[allow(clippy::too_many_arguments)]
pub fn max_pool2_backward(
    gys: &[f32],
    xs: &[f32],
    batch: usize,
    oh: usize,
    ow: usize,
    c: usize,
    h: usize,
    w: usize,
    gx: &mut [f32],
) {
    debug_assert_eq!(gys.len(), batch * oh * ow * c);
    debug_assert_eq!(xs.len(), batch * h * w * c);
    debug_assert_eq!(gx.len(), batch * h * w * c);
    gx.fill(0.0);
    for b in 0..batch {
        for oy in 0..oh {
            for ox in 0..ow {
                for ch in 0..c {
                    let at = |dy: usize, dx: usize| {
                        ((b * h + 2 * oy + dy) * w + 2 * ox + dx) * c + ch
                    };
                    let mut best = at(0, 0);
                    for (dy, dx) in [(0, 1), (1, 0), (1, 1)] {
                        if xs[at(dy, dx)] > xs[best] {
                            best = at(dy, dx);
                        }
                    }
                    gx[best] += gys[((b * oh + oy) * ow + ox) * c + ch];
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::SplitMix;

    #[test]
    fn mask_relu_zeroes_clipped_lanes() {
        let mut g = [1.0f32, 2.0, 3.0, 4.0];
        mask_relu(&mut g, &[0.5, 0.0, 2.0, 0.0]);
        assert_eq!(g, [1.0, 0.0, 3.0, 0.0]);
    }

    #[test]
    fn dense_backward_matches_finite_differences() {
        let mut rng = SplitMix::new(11);
        let (n, m, batch) = (5, 4, 3);
        let w = rng.normal_vec(n * m);
        let xs = rng.normal_vec(batch * n);
        let us = rng.normal_vec(batch * m); // cotangent: L = Σ u · y
        let mut gx = vec![0.0; batch * n];
        let mut gw = vec![0.0; n * m];
        let mut gb = vec![0.0; m];
        dense_backward(&w, n, m, &xs, &us, batch, &mut gx, &mut gw, &mut gb);
        let loss = |w: &[f32], xs: &[f32]| -> f64 {
            let mut total = 0.0f64;
            for b in 0..batch {
                for j in 0..m {
                    let mut y = 0.0f64;
                    for i in 0..n {
                        y += xs[b * n + i] as f64 * w[i * m + j] as f64;
                    }
                    total += y * us[b * m + j] as f64;
                }
            }
            total
        };
        let eps = 1e-2f32;
        for t in 0..n * m {
            let mut wp = w.clone();
            let (hi_w, lo_w) = (w[t] + eps, w[t] - eps);
            wp[t] = hi_w;
            let hi = loss(&wp, &xs);
            wp[t] = lo_w;
            let lo = loss(&wp, &xs);
            let want = (hi - lo) / (hi_w - lo_w) as f64;
            assert!((gw[t] as f64 - want).abs() < 1e-3 + 1e-3 * want.abs(), "gw[{t}]");
        }
        for t in 0..batch * n {
            let mut xp = xs.clone();
            let (hi_x, lo_x) = (xs[t] + eps, xs[t] - eps);
            xp[t] = hi_x;
            let hi = loss(&w, &xp);
            xp[t] = lo_x;
            let lo = loss(&w, &xp);
            let want = (hi - lo) / (hi_x - lo_x) as f64;
            assert!((gx[t] as f64 - want).abs() < 1e-3 + 1e-3 * want.abs(), "gx[{t}]");
        }
        for (j, gbv) in gb.iter().enumerate() {
            let want: f32 = (0..batch).map(|b| us[b * m + j]).sum();
            assert!((gbv - want).abs() < 1e-5);
        }
    }

    #[test]
    fn avg_pool_backward_spreads_quarters() {
        // one 2x2 image, one channel: g_out = 1 -> each input gets 0.25
        let mut gx = vec![0.0; 4];
        avg_pool2_backward(&[1.0], 1, 1, 1, 1, 2, 2, &mut gx);
        assert_eq!(gx, vec![0.25; 4]);
    }

    #[test]
    fn avg_pool_backward_zeroes_odd_tail() {
        // 3x3 input pools to 1x1: the third row/column never contributed
        let mut gx = vec![9.0; 9];
        avg_pool2_backward(&[4.0], 1, 1, 1, 1, 3, 3, &mut gx);
        assert_eq!(&gx[..2], &[1.0, 1.0]);
        assert_eq!(gx[2], 0.0);
        assert_eq!(&gx[3..5], &[1.0, 1.0]);
        assert!(gx[5..].iter().all(|&v| v == 0.0));
    }

    #[test]
    fn max_pool_backward_routes_to_first_argmax() {
        // window [1, 3 / 3, 0]: max 3 first reached at (0,1)
        let xs = [1.0f32, 3.0, 3.0, 0.0];
        let mut gx = vec![0.0; 4];
        max_pool2_backward(&[2.0], &xs, 1, 1, 1, 1, 2, 2, &mut gx);
        assert_eq!(gx, vec![0.0, 2.0, 0.0, 0.0]);
    }
}
