//! The algorithm–hardware co-optimization loop of Fig. 5, as a first-class
//! feature: given a benchmark network, a device, and an accuracy
//! requirement, jointly select
//!
//!   * the **block sizes** (FC and CONV layers separately — the paper's
//!     "model selection and optimization": k controls the accuracy ↔
//!     compression trade-off),
//!   * the **fixed-point width** (the hardware datapath precision), and
//!   * the **batch size** (largest interleaved batch whose working set
//!     fits on-chip — the "hardware optimization" leg),
//!
//! maximizing simulated energy efficiency (kFPS/W) subject to the accuracy
//! constraint, with throughput as the tie-breaker.  The search is exact
//! enumeration: the design space is small (tens of points) and the cycle
//! simulator evaluates a point in ~100 ns (bench `fig6`), exactly why the
//! paper can afford the loop of Fig. 5.  Transform costs inside the
//! simulator follow the packed real-FFT model
//! (`models::fft_real_mults`, matching `FftPlan::real_mults`), so the
//! frontier reflects the same arithmetic the Rust substrate executes.
//!
//! Accuracy along the frontier comes from a *measured* model: the
//! block-size sweep the Python pipeline trains (`make sweep` →
//! `artifacts/sweep.json`, experiment S2), interpolated geometrically
//! between measured k points and penalized for sub-12-bit precision. When
//! the sweep artifact is absent a conservative built-in table (recorded
//! from the same sweep, seed-pinned) is used so the search stays
//! deterministic and artifact-optional.

use crate::fpga::device::Device;
use crate::fpga::report::DesignReport;
use crate::fpga::schedule::ScheduleConfig;
use crate::models::{Layer, Model};
use crate::util::json::Json;

/// Accuracy model: measured (k, accuracy) pairs for the block-size sweep
/// plus a precision penalty, both on the synthetic benchmark task.
#[derive(Debug, Clone)]
pub struct AccuracyModel {
    /// measured (k, accuracy) points, ascending k (k = FC block size)
    pub points: Vec<(usize, f64)>,
    /// accuracy lost per bit below 12 (measured 12-bit vs f32 deltas are
    /// ~0.1-0.5%; dropping bits costs roughly this much per bit)
    pub per_bit_penalty: f64,
}

/// Built-in fallback: the S2 sweep measured at session seeds (see
/// EXPERIMENTS.md §S2).
const BUILTIN_SWEEP: &[(usize, f64)] = &[
    (2, 0.9951),
    (4, 0.9961),
    (8, 0.9893),
    (16, 0.9736),
    (32, 0.9541),
    (64, 0.9385),
    (128, 0.9287),
];

impl Default for AccuracyModel {
    fn default() -> Self {
        Self { points: BUILTIN_SWEEP.to_vec(), per_bit_penalty: 0.004 }
    }
}

impl AccuracyModel {
    /// Load the measured sweep from `artifacts/sweep.json` when present.
    pub fn from_artifacts(dir: &std::path::Path) -> Self {
        let Ok(text) = std::fs::read_to_string(dir.join("sweep.json")) else {
            return Self::default();
        };
        let Ok(root) = Json::parse(&text) else {
            return Self::default();
        };
        let Some(arr) = root.get("block_size_sweep").and_then(|v| v.as_arr()) else {
            return Self::default();
        };
        let mut points = Vec::new();
        for e in arr {
            if let (Some(k), Some(a)) = (
                e.get("k").and_then(|v| v.as_usize()),
                e.get("accuracy").and_then(|v| v.as_f64()),
            ) {
                points.push((k, a));
            }
        }
        if points.len() < 2 {
            return Self::default();
        }
        points.sort_by_key(|&(k, _)| k);
        Self { points, per_bit_penalty: 0.004 }
    }

    /// Predicted accuracy at FC block size `k` and datapath width `bits`.
    ///
    /// Log-linear interpolation in k between measured points, clamped at
    /// the ends; bits below 12 pay `per_bit_penalty` each (12-bit itself is
    /// what the sweep measured — the paper's design point).
    pub fn predict(&self, k: usize, bits: u64) -> f64 {
        let base = if k <= self.points[0].0 {
            self.points[0].1
        } else if k >= self.points[self.points.len() - 1].0 {
            self.points[self.points.len() - 1].1
        } else {
            let mut acc = self.points[0].1;
            for w in self.points.windows(2) {
                let ((k0, a0), (k1, a1)) = (w[0], w[1]);
                if k >= k0 && k <= k1 {
                    let t = ((k as f64).ln() - (k0 as f64).ln())
                        / ((k1 as f64).ln() - (k0 as f64).ln());
                    acc = a0 + t * (a1 - a0);
                    break;
                }
            }
            acc
        };
        (base - self.per_bit_penalty * (12.0f64 - bits as f64).max(0.0)).clamp(0.0, 1.0)
    }
}

/// One evaluated design point of the Fig.-5 loop.
#[derive(Debug, Clone)]
pub struct DesignPoint {
    pub k_fc: usize,
    pub k_conv: usize,
    pub bits: u64,
    pub batch: u64,
    pub predicted_accuracy: f64,
    pub kfps: f64,
    pub kfps_per_w: f64,
    pub storage_reduction: f64,
    pub fits_on_chip: bool,
}

/// Search configuration.
#[derive(Debug, Clone)]
pub struct SearchSpace {
    pub fc_blocks: Vec<usize>,
    pub conv_blocks: Vec<usize>,
    pub bit_widths: Vec<u64>,
}

impl Default for SearchSpace {
    fn default() -> Self {
        Self {
            // the paper: "a proper block size ranges from 64 to 256 ...
            // for FC layers and may be smaller for CONV layers"; we sweep
            // wider to expose the frontier
            fc_blocks: vec![8, 16, 32, 64, 128, 256],
            conv_blocks: vec![2, 4, 8, 16],
            bit_widths: vec![8, 10, 12, 16],
        }
    }
}

/// Rescale a registry model's block sizes, keeping divisibility: each
/// BC layer gets the largest candidate ≤ requested that divides its dims.
pub fn with_block_sizes(model: &Model, k_fc: usize, k_conv: usize) -> Model {
    let mut m = model.clone();
    for layer in &mut m.layers {
        match layer {
            Layer::BcDense { n, m: om, k } => {
                *k = largest_dividing(k_fc, &[*n, *om]);
            }
            Layer::BcConv { c, p, k, .. } => {
                *k = largest_dividing(k_conv, &[*c, *p]);
            }
            _ => {}
        }
    }
    m
}

fn largest_dividing(want: usize, dims: &[usize]) -> usize {
    let mut k = want.next_power_of_two().min(256);
    while k > 1 {
        if dims.iter().all(|d| d % k == 0) {
            return k;
        }
        k /= 2;
    }
    1
}

/// Evaluate one (k_fc, k_conv, bits) triple on `device`; batch is chosen by
/// the memory model (the hardware-optimization leg).
pub fn evaluate(
    model: &Model,
    device: &Device,
    acc_model: &AccuracyModel,
    k_fc: usize,
    k_conv: usize,
    bits: u64,
) -> DesignPoint {
    let variant = with_block_sizes(model, k_fc, k_conv);
    let base = ScheduleConfig { bits, ..ScheduleConfig::default() };
    let batch = crate::fpga::memory::max_fitting_batch(
        &variant,
        device.bram_bytes,
        bits,
        64,
        base.half_spectrum,
        base.in_place,
    );
    let cfg = ScheduleConfig { batch, ..base };
    let rep = DesignReport::build(&variant, device, &cfg);
    DesignPoint {
        k_fc,
        k_conv,
        bits,
        batch,
        predicted_accuracy: acc_model.predict(k_fc, bits),
        kfps: rep.kfps,
        kfps_per_w: rep.kfps_per_w,
        storage_reduction: variant.storage_report(bits).reduction,
        fits_on_chip: rep.sched.memory.fits,
    }
}

/// Outcome of the co-optimization search.
#[derive(Debug, Clone)]
pub struct SearchResult {
    /// every evaluated feasible point
    pub frontier: Vec<DesignPoint>,
    /// best feasible point (max kFPS/W, kFPS tie-break), if any
    pub best: Option<DesignPoint>,
    pub min_accuracy: f64,
}

/// The Fig.-5 loop: enumerate the space, keep on-chip + accuracy-feasible
/// points, maximize energy efficiency.
pub fn optimize(
    model: &Model,
    device: &Device,
    space: &SearchSpace,
    acc_model: &AccuracyModel,
    min_accuracy: f64,
) -> SearchResult {
    let has_conv = model
        .layers
        .iter()
        .any(|l| matches!(l, Layer::BcConv { .. }));
    let conv_choices: &[usize] = if has_conv { &space.conv_blocks } else { &[4] };
    let mut frontier = Vec::new();
    for &k_fc in &space.fc_blocks {
        for &k_conv in conv_choices {
            for &bits in &space.bit_widths {
                let pt = evaluate(model, device, acc_model, k_fc, k_conv, bits);
                if pt.fits_on_chip && pt.predicted_accuracy >= min_accuracy {
                    frontier.push(pt);
                }
            }
        }
    }
    frontier.sort_by(|a, b| {
        b.kfps_per_w
            .partial_cmp(&a.kfps_per_w)
            .unwrap()
            .then(b.kfps.partial_cmp(&a.kfps).unwrap())
    });
    let best = frontier.first().cloned();
    SearchResult { frontier, best, min_accuracy }
}

/// Render a search result as the report the CLI prints.
pub fn render(model: &Model, device: &Device, res: &SearchResult) -> String {
    let mut out = String::new();
    out.push_str(&format!(
        "co-optimization (Fig. 5): {} on {}, accuracy >= {:.1}%\n",
        model.name,
        device.name,
        100.0 * res.min_accuracy
    ));
    out.push_str(&format!(
        "{:>6} {:>7} {:>5} {:>6} {:>9} {:>12} {:>12} {:>10}\n",
        "k_fc", "k_conv", "bits", "batch", "pred acc", "kFPS", "kFPS/W", "storage x"
    ));
    out.push_str(&"-".repeat(76));
    out.push('\n');
    for (i, p) in res.frontier.iter().take(12).enumerate() {
        out.push_str(&format!(
            "{:>6} {:>7} {:>5} {:>6} {:>8.2}% {:>12.1} {:>12.1} {:>9.1}x{}\n",
            p.k_fc,
            p.k_conv,
            p.bits,
            p.batch,
            100.0 * p.predicted_accuracy,
            p.kfps,
            p.kfps_per_w,
            p.storage_reduction,
            if i == 0 { "  <- selected" } else { "" }
        ));
    }
    if res.frontier.is_empty() {
        out.push_str("no feasible design point (accuracy bound too tight for this space)\n");
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fpga::device::CYCLONE_V;
    use crate::models;

    fn mlp() -> Model {
        models::by_name("mnist_mlp_1").unwrap()
    }

    #[test]
    fn accuracy_model_monotone_in_k_and_bits() {
        let am = AccuracyModel::default();
        // larger blocks -> equal-or-less accuracy over the measured knee
        for w in [8usize, 16, 32, 64].windows(2) {
            assert!(am.predict(w[0], 12) >= am.predict(w[1], 12), "k {} vs {}", w[0], w[1]);
        }
        // fewer bits -> less accuracy
        assert!(am.predict(64, 8) < am.predict(64, 12));
        // 16-bit pays no penalty relative to 12 (sweep measured at 12)
        assert_eq!(am.predict(64, 16), am.predict(64, 12));
        // interpolation stays within the bracketing measurements
        let a24 = am.predict(24, 12);
        assert!(a24 <= am.predict(16, 12) && a24 >= am.predict(32, 12));
    }

    #[test]
    fn with_block_sizes_respects_divisibility() {
        let m = with_block_sizes(&mlp(), 256, 4);
        for l in &m.layers {
            if let Layer::BcDense { n, m: om, k } = l {
                assert_eq!(n % k, 0);
                assert_eq!(om % k, 0);
                assert!(*k <= 256);
            }
        }
        // 256 doesn't divide a 256x256 layer evenly at k=256? it does —
        // but k is also capped by the dims themselves
        let lenet = models::by_name("mnist_lenet").unwrap();
        let v = with_block_sizes(&lenet, 256, 16);
        for l in &v.layers {
            if let Layer::BcConv { c, p, k, .. } = l {
                assert_eq!(c % k, 0);
                assert_eq!(p % k, 0);
            }
        }
    }

    #[test]
    fn optimize_finds_feasible_best_and_respects_constraint() {
        let am = AccuracyModel::default();
        let res = optimize(&mlp(), &CYCLONE_V, &SearchSpace::default(), &am, 0.95);
        let best = res.best.expect("a feasible point exists at 95%");
        assert!(best.predicted_accuracy >= 0.95);
        assert!(best.fits_on_chip);
        // frontier is sorted by efficiency
        for w in res.frontier.windows(2) {
            assert!(w[0].kfps_per_w >= w[1].kfps_per_w);
        }
    }

    #[test]
    fn tighter_accuracy_never_improves_efficiency() {
        let am = AccuracyModel::default();
        let loose = optimize(&mlp(), &CYCLONE_V, &SearchSpace::default(), &am, 0.90);
        let tight = optimize(&mlp(), &CYCLONE_V, &SearchSpace::default(), &am, 0.97);
        let (l, t) = (loose.best.unwrap(), tight.best.unwrap());
        assert!(
            l.kfps_per_w >= t.kfps_per_w,
            "the accuracy/efficiency trade-off must be monotone: {} < {}",
            l.kfps_per_w,
            t.kfps_per_w
        );
        // and the tight bound forces smaller blocks or more bits
        assert!(t.k_fc <= l.k_fc || t.bits >= l.bits);
    }

    #[test]
    fn infeasible_bound_returns_empty() {
        let am = AccuracyModel::default();
        let res = optimize(&mlp(), &CYCLONE_V, &SearchSpace::default(), &am, 0.9999);
        assert!(res.best.is_none());
        assert!(res.frontier.is_empty());
        assert!(render(&mlp(), &CYCLONE_V, &res).contains("no feasible"));
    }

    #[test]
    fn sweep_artifact_loads_when_present() {
        let am = AccuracyModel::from_artifacts(&crate::runtime::Manifest::default_dir());
        assert!(am.points.len() >= 2);
        // either the artifact's sweep or the builtin — both monotone-ish
        assert!(am.predict(2, 12) > am.predict(128, 12));
    }

    #[test]
    fn conv_models_search_conv_blocks() {
        let am = AccuracyModel::default();
        let lenet = models::by_name("mnist_lenet").unwrap();
        let res = optimize(&lenet, &CYCLONE_V, &SearchSpace::default(), &am, 0.90);
        assert!(res.best.is_some());
        // conv variants must appear in the frontier
        assert!(res.frontier.iter().any(|p| p.k_conv != res.frontier[0].k_conv));
    }
}
