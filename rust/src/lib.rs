//! # CirCNN-Flow
//!
//! Production reproduction of *"Towards Ultra-High Performance and Energy
//! Efficiency of Deep Learning Systems: An Algorithm-Hardware Co-Optimization
//! Framework"* (Wang et al., AAAI 2018).
//!
//! The crate is the Layer-3 (request-path) half of a three-layer stack:
//!
//! * **Layer 1** (`python/compile/kernels`): Pallas kernels for the paper's
//!   FFT→∘→IFFT datapath (build-time only).
//! * **Layer 2** (`python/compile`): JAX block-circulant models, trained and
//!   AOT-lowered to HLO text artifacts.
//! * **Layer 3** (this crate): a pure-Rust coordinator that loads the
//!   artifacts through PJRT ([`runtime`]), serves batched inference
//!   ([`coordinator`]), and regenerates every table and figure of the
//!   paper's evaluation through a cycle-level FPGA datapath simulator
//!   ([`fpga`]) and analytical baseline models ([`baselines`]).
//!
//! Python never runs on the request path: after `make artifacts` the binary
//! is self-contained.
//!
//! ## Module map
//!
//! | module | role |
//! |---|---|
//! | [`circulant`] | from-scratch FFT / block-circulant numerics (the algorithmic substrate, shared with the simulator) |
//! | [`codesign`] | the Fig.-5 algorithm-hardware co-optimization search |
//! | [`data`] | bit-exact Rust mirror of the Python synthetic datasets |
//! | [`models`] | registry of the six Table-1 networks + accounting |
//! | [`fpga`] | cycle-level simulator of the paper's FPGA datapath |
//! | [`baselines`] | TrueNorth / reference-FPGA / analog analytical models |
//! | [`native`] | pure-Rust inference engine (the FPGA datapath's functional twin; no PJRT) |
//! | [`runtime`] | PJRT engine: load + execute HLO artifacts |
//! | [`coordinator`] | router, dynamic batcher, three-phase scheduler |
//! | [`experiments`] | Table-1 / Fig-3 / Fig-6 / analog report generators |
//! | [`util`] | JSON, PRNG, property-test and bench harness kits |

pub mod baselines;
pub mod circulant;
pub mod codesign;
pub mod coordinator;
pub mod data;
pub mod experiments;
pub mod fpga;
pub mod models;
pub mod native;
pub mod runtime;
pub mod util;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;
