//! # CirCNN-Flow
//!
//! Production reproduction of *"Towards Ultra-High Performance and Energy
//! Efficiency of Deep Learning Systems: An Algorithm-Hardware Co-Optimization
//! Framework"* (Wang et al., AAAI 2018).
//!
//! The crate is the Layer-3 (request-path) half of a three-layer stack:
//!
//! * **Layer 1** (`python/compile/kernels`): Pallas kernels for the paper's
//!   FFT→∘→IFFT datapath (build-time only).
//! * **Layer 2** (`python/compile`): JAX block-circulant models, trained and
//!   AOT-lowered to HLO text artifacts.
//! * **Layer 3** (this crate): a pure-Rust coordinator that serves batched
//!   inference ([`coordinator`]) on either execution substrate — the
//!   native block-circulant engine ([`native`]) or, behind the
//!   off-by-default `pjrt` cargo feature, AOT HLO artifacts through PJRT
//!   ([`runtime`]) — and regenerates every table and figure of the paper's
//!   evaluation through a cycle-level FPGA datapath simulator ([`fpga`])
//!   and analytical baseline models ([`baselines`]).
//!
//! Python never runs on the request path: after `make artifacts` the binary
//! is self-contained.
//!
//! ## Module map
//!
//! | module | role |
//! |---|---|
//! | [`circulant`] | from-scratch FFT / block-circulant numerics: packed real-input FFT fast path (k/2-point complex FFT + untangle), crate-wide [`circulant::FftPlan::shared`] plan cache, NEON/AVX2 SIMD MAC engine (`circulant::fft::{complex_mul_acc, complex_conj_mul_acc}`, runtime-dispatched, bitwise-pinned to the scalar oracle, `CIRCNN_NO_SIMD=1` forces scalar), batch-major parallel `matmul` + weight-spectrum-resident training backward sharded over scoped threads ([`circulant::sched`] holds the shared shard policy/workspaces/counters); the **executed int16 fixed-point engine** — per-spectrum block-floating-point quantization ([`circulant::quant`]), i16 MAC kernels with i32 accumulators (`circulant::fft::complex_mul_acc_i16`, same dispatch/oracle discipline) and [`circulant::BlockCirculant::matmul_fixed`], selected end-to-end by [`circulant::Precision::Fixed16`] |
//! | [`codesign`] | the Fig.-5 algorithm-hardware co-optimization search |
//! | [`data`] | bit-exact Rust mirror of the Python synthetic datasets |
//! | [`models`] | registry of the six Table-1 networks + accounting; `fft_real_mults` is the packed-rfft cost model the simulator charges |
//! | [`fpga`] | cycle-level simulator of the paper's FPGA datapath |
//! | [`lint`] | repo-invariant static analysis (`circnn lint`): SAFETY comments, oracle-twin liveness, knob registry, bench-key contract, request-path unwrap hygiene — fixture-pinned, CI-blocking |
//! | [`baselines`] | TrueNorth / reference-FPGA / analog analytical models |
//! | [`native`] | pure-Rust inference engine (the FPGA datapath's functional twin; no PJRT); [`native::conv`] runs the BcConv pipeline batch-parallel with the weight-block-outer *spectrum-resident* MAC sweep (each weight spectrum loaded once per shard — the BRAM-reuse ordering), forward and backward; `NativeModel::set_precision` swaps every block-circulant layer onto the executed int16 BFP engine (`serve --precision fixed16`, `circnn precision`) |
//! | [`train`] | native FFT-domain training subsystem: O(n log n) spectral backprop (conjugate-spectrum `dL/dx`, frequency-accumulated `dL/dw`), SGD+momentum, softmax-CE head — `circnn train-demo` on default features |
//! | [`pipeline`] | deep-pipelined serving engine: the `NativeModel` op walk split into per-layer stage workers with multiple batches in flight (token-bounded depth, bitwise-identical to `forward`, per-stage occupancy timeline — the executable twin of `fpga::controller`'s pipeline-fill story) |
//! | [`runtime`] | artifact manifest (always) + PJRT engine (`pjrt` feature): load + execute HLO artifacts |
//! | [`telemetry`] | unified observability substrate: the process-wide metrics [`telemetry::Registry`] (atomic counters/gauges/log2 histograms, Prometheus-style text + JSON exposition, lint-checked snake_case naming contract), per-request span tracing ([`telemetry::Tracer`], ASCII waterfall + JSON dump via `serve --trace`, gated by the registered `CIRCNN_TRACE` knob), the time-series [`telemetry::snapshot`] ring (`CIRCNN_SNAP_MS` sampler, `*_watermark` gauges, ASCII sparklines) and the phase-level profiling hooks `coordinator`/`train` publish through |
//! | [`coordinator`] | router, dynamic batcher, executor over the native, pipelined-native or PJRT backend |
//! | [`net`] | TCP serving front-end (std::net only): length-framed binary protocol ([`net::protocol`], documented byte-for-byte in `docs/PROTOCOL.md`), per-connection incremental frame reader with layered admission control and explicit `Overloaded` shedding, graceful drain, in-band `Admin` scrape frames and the [`net::scrape`] HTTP/1.0 responder (`/metrics`, `/metrics.json`, `/trace.json`, `/healthz` via `serve --metrics-addr`) — plus the fixed-seed open-loop load harness `circnn loadgen` ([`net::loadgen`]: Poisson/bursty arrivals, warm/cold connection mixes, registry-derived percentiles, schedule `--record`/`--replay`, `--slo-p99-us` exit gate) |
//! | [`experiments`] | Table-1 / Fig-3 / Fig-6 / analog report generators |
//! | [`util`] | JSON, PRNG, property-test and bench harness kits (incl. machine-readable bench JSON) |
//!
//! ## Correctness discipline (machine-checked)
//!
//! Six PRs of kernel and pipeline work rest on invariants that `circnn
//! lint` ([`lint`]) now enforces mechanically — CI runs it as a blocking
//! job, and `cargo run -- lint` reproduces it locally:
//!
//! * **SAFETY comments + pinned oracles.** Every `unsafe` site carries a
//!   `// SAFETY:` justification (`#![deny(unsafe_op_in_unsafe_fn)]` is on
//!   crate-wide), and every `#[target_feature]` SIMD kernel has a
//!   `*_scalar` oracle that a test exercises against the dispatched name.
//! * **No dead oracle twins.** Every kept ordering twin (`*_serial`,
//!   `*_pixel_outer`, `*_sample_major`, `*_via_full`) is referenced by at
//!   least one test, so a refactor cannot silently orphan a pin.
//! * **Knob registry.** Every `CIRCNN_*` environment knob is read through
//!   the [`circulant::sched`] helpers and listed in
//!   [`circulant::sched::KNOBS`]; raw `std::env::var` reads elsewhere in
//!   the crate fail the lint.
//! * **Bench-key contract.** `*_speedup_*` keys in the bench JSON are
//!   CI-gated (fail below 1.0) and `*_ratio_*` keys never are; the lint
//!   checks the gate exists and no key mixes the two markers.
//! * **Request-path hygiene.** No `.unwrap()`/`.expect()` on the
//!   [`coordinator`]/[`pipeline`]/[`net`] request path and no unbounded
//!   channels in [`pipeline`] or [`net`] (lock-poisoning recovery and
//!   `lint:allow(unwrap)`-annotated construction invariants are the only
//!   exceptions).
//! * **Metric naming contract.** Every metric registered with the
//!   [`telemetry`] registry uses a literal `snake_case` name, unique
//!   crate-wide, and `*_hits`/`*_misses` pairs always ship together
//!   (the `metric-name` rule).
//! * **Docs freshness.** Every registered metric name and every
//!   `CIRCNN_*` knob in the [`circulant::sched::KNOBS`] registry must
//!   appear in `docs/OPERATIONS.md` — the operator's guide cannot
//!   silently fall behind the code (the `docs-fresh` rule).
//!
//! ## Documentation
//!
//! * `docs/PROTOCOL.md` — the TCP wire format, byte-for-byte (framing,
//!   field offsets, status codes, version negotiation), pinned by a
//!   round-trip test over its example frames.
//! * `docs/OPERATIONS.md` — the operator's guide: every `circnn serve` /
//!   `circnn loadgen` flag, every `CIRCNN_*` knob, every registered
//!   metric, and the load-shedding/SLO walkthrough (lint-enforced fresh).
//! * `docs/ARCHITECTURE.md` — the circulant → native → pipeline →
//!   coordinator → net dataflow, the bitwise-oracle/twin discipline, and
//!   the bench-key gating contract.
//!
//! Violations are reported as `file:line: [rule] message` with a non-zero
//! exit; the negative fixtures under `rust/tests/lint_fixtures/` pin that
//! each rule actually fires.

#![deny(unsafe_op_in_unsafe_fn)]

pub mod baselines;
pub mod circulant;
pub mod codesign;
pub mod coordinator;
pub mod data;
pub mod experiments;
pub mod fpga;
pub mod lint;
pub mod models;
pub mod native;
pub mod net;
pub mod pipeline;
pub mod runtime;
pub mod telemetry;
pub mod train;
pub mod util;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;
