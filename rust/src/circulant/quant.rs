//! Fixed-point quantization — the paper's 12-bit FPGA datapath precision.
//!
//! Mirrors `python/compile/layers.fake_quant`: symmetric uniform, per-tensor
//! max-abs scale.  [`Quantized`] additionally provides the packed integer
//! representation used for the storage accounting (Fig. 3's "bit
//! quantization" factor) and by the simulator's memory model.
//!
//! [`encode_spectrum_i16`] is the *executed* side of the story: the
//! block-floating-point (BFP) encoding behind the int16 MAC engine
//! (`Precision::Fixed16`).  Convention: one half-spectrum (its re and im
//! planes jointly) shares a single **power-of-two** scale `2^e`, with `e`
//! the smallest exponent such that `max_abs * 2^-e <= levels` where
//! `levels = 2^(bits-1) - 1`; mantissas are `round(v * 2^-e)` clamped to
//! `±levels` (never `-2^15`, so any product pair `a*c ± b*d` of two
//! encoded spectra fits i32).  Power-of-two scales mean the phase-2 MAC
//! needs only integer adds/multiplies plus arithmetic shifts — exactly the
//! FPGA datapath shape — and the one float rescale per output spectrum is
//! an exact `exp2` multiply.

/// Minimum symmetric quantization width: 2 bits is the narrowest grid with
/// a nonzero level ({-1, 0, +1}).  At `bits == 1` the level count
/// `2^(bits-1) - 1` is zero, which would make the scale infinite and the
/// grid NaN — callers asking for 1 bit get the documented 2-bit minimum.
pub const MIN_BITS: u32 = 2;

/// Quantize/dequantize in place (fake-quant): the value grid of a
/// `bits`-bit symmetric fixed-point representation.  `bits` below
/// [`MIN_BITS`] is clamped up to it.
pub fn fake_quant(x: &mut [f32], bits: u32) {
    let bits = bits.max(MIN_BITS);
    let levels = ((1u32 << (bits - 1)) - 1) as f32;
    let max_abs = x.iter().fold(0.0f32, |m, v| m.max(v.abs())).max(1e-8);
    let scale = max_abs / levels;
    for v in x.iter_mut() {
        *v = (*v / scale).round() * scale;
    }
}

/// A tensor stored as `bits`-bit integers + one f32 scale.
#[derive(Debug, Clone)]
pub struct Quantized {
    pub bits: u32,
    pub scale: f32,
    /// values in [-(2^(bits-1)-1), 2^(bits-1)-1], stored widened
    pub values: Vec<i16>,
}

impl Quantized {
    /// Quantize a float tensor (bits <= 16).
    pub fn encode(x: &[f32], bits: u32) -> Self {
        assert!((2..=16).contains(&bits), "bits must be in 2..=16");
        let levels = ((1u32 << (bits - 1)) - 1) as f32;
        let max_abs = x.iter().fold(0.0f32, |m, v| m.max(v.abs())).max(1e-8);
        let scale = max_abs / levels;
        let values = x
            .iter()
            .map(|v| (v / scale).round().clamp(-levels, levels) as i16)
            .collect();
        Self { bits, scale, values }
    }

    /// Dequantize back to floats.
    pub fn decode(&self) -> Vec<f32> {
        self.values.iter().map(|&v| v as f32 * self.scale).collect()
    }

    /// Storage in bytes at the nominal bit width (packed), as counted by
    /// the paper's storage-reduction figure.
    pub fn packed_bytes(&self) -> usize {
        (self.values.len() * self.bits as usize).div_ceil(8)
    }

    /// Worst-case absolute quantization error (scale / 2).
    pub fn max_error(&self) -> f32 {
        self.scale / 2.0
    }
}

/// Exponent assigned to an all-zero spectrum by [`encode_spectrum_i16`].
///
/// Arithmetically any exponent would do (every mantissa is zero), but the
/// fixed MAC takes `max` over tap exponents to pick the accumulator scale,
/// so a zero spectrum must not inflate that max: −126 sits below every
/// exponent the encoder can produce for nonzero data.
pub const ZERO_EXP: i32 = -126;

/// Block-floating-point encode of one half-spectrum into `i16` mantissas
/// with a shared power-of-two scale.
///
/// Encodes the `re`/`im` planes jointly: returns the smallest exponent `e`
/// with `max_abs * 2^-e <= levels` (`levels = 2^(bits-1) - 1`), writing
/// `round(v * 2^-e)` clamped to `±levels` into `qre`/`qim`.  The decoded
/// value of lane `t` is `qre[t] as f32 * 2^e` (resp. `qim`).  An all-zero
/// spectrum gets zero mantissas and the [`ZERO_EXP`] sentinel.
///
/// `bits` must be in `MIN_BITS..=16`; non-finite inputs are rejected by
/// debug assertion (weights and FFT outputs are finite by construction).
pub fn encode_spectrum_i16(
    re: &[f32],
    im: &[f32],
    bits: u32,
    qre: &mut [i16],
    qim: &mut [i16],
) -> i32 {
    assert!((MIN_BITS..=16).contains(&bits), "bits must be in 2..=16");
    let n = re.len();
    assert_eq!(im.len(), n);
    let (qre, qim) = (&mut qre[..n], &mut qim[..n]);
    let levels = ((1u32 << (bits - 1)) - 1) as f32;
    let max_abs = re
        .iter()
        .chain(im.iter())
        .fold(0.0f32, |m, v| m.max(v.abs()));
    debug_assert!(max_abs.is_finite(), "non-finite spectrum");
    if max_abs == 0.0 {
        qre.fill(0);
        qim.fill(0);
        return ZERO_EXP;
    }
    // smallest e with max_abs * 2^-e <= levels; the log2/ceil estimate can
    // be off by one in either direction at float precision, so fix up with
    // exact exp2 comparisons.  Clamped to -126 so exp2(-e) stays finite.
    let mut e = ((max_abs / levels).log2().ceil() as i32).max(-126);
    while max_abs * (-(e as f32)).exp2() > levels {
        e += 1;
    }
    while e > -126 && max_abs * (-((e - 1) as f32)).exp2() <= levels {
        e -= 1;
    }
    let inv = (-(e as f32)).exp2();
    for (dst, &v) in qre.iter_mut().zip(re) {
        *dst = (v * inv).round().clamp(-levels, levels) as i16;
    }
    for (dst, &v) in qim.iter_mut().zip(im) {
        *dst = (v * inv).round().clamp(-levels, levels) as i16;
    }
    e
}

/// Headroom shift for the phase-2 i32 accumulator: the number of extra
/// right-shift bits each tap product needs so that summing `taps` complex
/// products of two `bits`-wide BFP spectra cannot overflow i32.
///
/// Per tap `|a*c ± b*d| < 2 * levels^2 < 2^(2(bits-1)+1)`; accumulating
/// `taps` of them adds `ceil(log2(taps))` bits.  Anything at or under 31
/// bits fits, so the headroom is the excess over 31 (zero for the common
/// 12-bit × q<=36 configurations — headroom only kicks in near 16 bits).
pub fn acc_headroom(bits: u32, taps: usize) -> u32 {
    let per_tap = 2 * (bits - 1) + 1;
    let tap_bits = taps.max(1).next_power_of_two().trailing_zeros();
    (per_tap + tap_bits).saturating_sub(31)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::forall;

    #[test]
    fn prop_roundtrip_error_bounded() {
        forall(
            "quant error <= scale/2",
            |r| {
                let n = 1 + r.below(100) as usize;
                let bits = 4 + r.below(9) as u32;
                (r.normal_vec(n), bits)
            },
            |(x, bits)| {
                let q = Quantized::encode(x, *bits);
                let back = q.decode();
                let bound = q.max_error() + 1e-6;
                for (a, b) in x.iter().zip(&back) {
                    if (a - b).abs() > bound {
                        return Err(format!("error {} > bound {bound}", (a - b).abs()));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn fake_quant_matches_encode_decode() {
        let x = [0.5f32, -1.25, 0.33, 0.9999];
        let mut fq = x;
        fake_quant(&mut fq, 12);
        let ed = Quantized::encode(&x, 12).decode();
        for (a, b) in fq.iter().zip(&ed) {
            assert!((a - b).abs() < 1e-6);
        }
    }

    #[test]
    fn error_shrinks_with_bits() {
        let mut rng = crate::util::rng::SplitMix::new(2);
        let x = rng.normal_vec(512);
        let e4 = Quantized::encode(&x, 4).max_error();
        let e8 = Quantized::encode(&x, 8).max_error();
        let e12 = Quantized::encode(&x, 12).max_error();
        assert!(e4 > e8 && e8 > e12);
    }

    #[test]
    fn packed_bytes_12bit() {
        let q = Quantized::encode(&vec![0.1; 100], 12);
        assert_eq!(q.packed_bytes(), 150); // 100 * 12 / 8
    }

    #[test]
    fn zero_tensor_safe() {
        let q = Quantized::encode(&[0.0, 0.0], 12);
        assert_eq!(q.decode(), vec![0.0, 0.0]);
    }

    #[test]
    fn prop_fake_quant_finite_and_bounded_all_bit_widths() {
        // bits = 1 used to produce an infinite scale and a NaN grid; the
        // clamp to MIN_BITS must keep every width in {1..16} finite with
        // error bounded by half a grid step
        forall(
            "fake_quant finite, error <= scale/2, bits in 1..=16",
            |r| {
                let n = 1 + r.below(64) as usize;
                let bits = 1 + r.below(16) as u32;
                (r.normal_vec(n), bits)
            },
            |(x, bits)| {
                let eff_bits = (*bits).max(MIN_BITS);
                let levels = ((1u32 << (eff_bits - 1)) - 1) as f32;
                let max_abs = x.iter().fold(0.0f32, |m, v| m.max(v.abs())).max(1e-8);
                let bound = max_abs / levels / 2.0 + 1e-6;
                let mut q = x.clone();
                fake_quant(&mut q, *bits);
                for (a, b) in x.iter().zip(&q) {
                    if !b.is_finite() {
                        return Err(format!("non-finite grid value {b} at bits={bits}"));
                    }
                    if (a - b).abs() > bound {
                        return Err(format!("error {} > bound {bound}", (a - b).abs()));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn prop_bfp_spectrum_roundtrip_error_bounded() {
        // decoded-value error of the joint-plane power-of-two encoding is
        // at most half an ulp of the shared scale: 2^e / 2
        forall(
            "encode_spectrum_i16 error <= 2^e / 2",
            |r| {
                let n = 1 + r.below(64) as usize;
                let bits = 2 + r.below(15) as u32;
                // exercise a wide dynamic range, not just unit normals
                let scale = (r.next_f32() * 40.0 - 20.0).exp2();
                let re: Vec<f32> = r.normal_vec(n).iter().map(|v| v * scale).collect();
                let im: Vec<f32> = r.normal_vec(n).iter().map(|v| v * scale).collect();
                (re, im, bits)
            },
            |(re, im, bits)| {
                let n = re.len();
                let (mut qre, mut qim) = (vec![0i16; n], vec![0i16; n]);
                let e = encode_spectrum_i16(re, im, *bits, &mut qre, &mut qim);
                let levels = ((1u32 << (bits - 1)) - 1) as i32;
                let step = (e as f32).exp2();
                for (&q, &v) in qre.iter().chain(&qim).zip(re.iter().chain(im)) {
                    if i32::from(q).abs() > levels {
                        return Err(format!("mantissa {q} outside ±{levels}"));
                    }
                    let err = (f32::from(q) * step - v).abs();
                    if err > step / 2.0 + step * 5e-3 {
                        return Err(format!("decode error {err} > half step {step}"));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn acc_headroom_matches_worst_case_arithmetic() {
        // 12-bit spectra: 23 product bits + up to 256 taps still fits i32
        assert_eq!(acc_headroom(12, 36), 0);
        assert_eq!(acc_headroom(12, 256), 0);
        // 16-bit spectra: 31 product bits, so every extra tap bit shifts
        assert_eq!(acc_headroom(16, 1), 0);
        assert_eq!(acc_headroom(16, 2), 1);
        assert_eq!(acc_headroom(16, 36), 6);
        // exhaustive check against the direct i64 bound
        for bits in MIN_BITS..=16 {
            for taps in 1..=64usize {
                let h = acc_headroom(bits, taps);
                let levels = (1i64 << (bits - 1)) - 1;
                let worst = (2 * levels * levels >> h) * taps as i64;
                assert!(worst <= i64::from(i32::MAX) + 1, "overflow at bits={bits} taps={taps}");
            }
        }
    }

    #[test]
    fn bfp_exponent_is_tight_and_zero_spectrum_gets_sentinel() {
        let (mut qre, mut qim) = (vec![0i16; 4], vec![0i16; 4]);
        // all-zero spectrum: sentinel exponent, zero mantissas
        let e = encode_spectrum_i16(&[0.0; 4], &[0.0; 4], 12, &mut qre, &mut qim);
        assert_eq!(e, ZERO_EXP);
        assert!(qre.iter().chain(&qim).all(|&q| q == 0));
        // max_abs exactly `levels`: e = 0 is the smallest admissible scale
        let levels = ((1u32 << 11) - 1) as f32;
        let e = encode_spectrum_i16(&[levels, -1.0, 0.5, 0.0], &[0.0; 4], 12, &mut qre, &mut qim);
        assert_eq!(e, 0);
        assert_eq!(qre[0], levels as i16);
        // doubling the peak forces exactly one more exponent bit
        let e2 =
            encode_spectrum_i16(&[2.0 * levels, -1.0, 0.5, 0.0], &[0.0; 4], 12, &mut qre, &mut qim);
        assert_eq!(e2, 1);
    }
}
