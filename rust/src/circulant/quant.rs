//! Fixed-point quantization — the paper's 12-bit FPGA datapath precision.
//!
//! Mirrors `python/compile/layers.fake_quant`: symmetric uniform, per-tensor
//! max-abs scale.  [`Quantized`] additionally provides the packed integer
//! representation used for the storage accounting (Fig. 3's "bit
//! quantization" factor) and by the simulator's memory model.

/// Quantize/dequantize in place (fake-quant): the value grid of a
/// `bits`-bit symmetric fixed-point representation.
pub fn fake_quant(x: &mut [f32], bits: u32) {
    let levels = ((1u32 << (bits - 1)) - 1) as f32;
    let max_abs = x.iter().fold(0.0f32, |m, v| m.max(v.abs())).max(1e-8);
    let scale = max_abs / levels;
    for v in x.iter_mut() {
        *v = (*v / scale).round() * scale;
    }
}

/// A tensor stored as `bits`-bit integers + one f32 scale.
#[derive(Debug, Clone)]
pub struct Quantized {
    pub bits: u32,
    pub scale: f32,
    /// values in [-(2^(bits-1)-1), 2^(bits-1)-1], stored widened
    pub values: Vec<i16>,
}

impl Quantized {
    /// Quantize a float tensor (bits <= 16).
    pub fn encode(x: &[f32], bits: u32) -> Self {
        assert!((2..=16).contains(&bits), "bits must be in 2..=16");
        let levels = ((1u32 << (bits - 1)) - 1) as f32;
        let max_abs = x.iter().fold(0.0f32, |m, v| m.max(v.abs())).max(1e-8);
        let scale = max_abs / levels;
        let values = x
            .iter()
            .map(|v| (v / scale).round().clamp(-levels, levels) as i16)
            .collect();
        Self { bits, scale, values }
    }

    /// Dequantize back to floats.
    pub fn decode(&self) -> Vec<f32> {
        self.values.iter().map(|&v| v as f32 * self.scale).collect()
    }

    /// Storage in bytes at the nominal bit width (packed), as counted by
    /// the paper's storage-reduction figure.
    pub fn packed_bytes(&self) -> usize {
        (self.values.len() * self.bits as usize).div_ceil(8)
    }

    /// Worst-case absolute quantization error (scale / 2).
    pub fn max_error(&self) -> f32 {
        self.scale / 2.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::forall;

    #[test]
    fn prop_roundtrip_error_bounded() {
        forall(
            "quant error <= scale/2",
            |r| {
                let n = 1 + r.below(100) as usize;
                let bits = 4 + r.below(9) as u32;
                (r.normal_vec(n), bits)
            },
            |(x, bits)| {
                let q = Quantized::encode(x, *bits);
                let back = q.decode();
                let bound = q.max_error() + 1e-6;
                for (a, b) in x.iter().zip(&back) {
                    if (a - b).abs() > bound {
                        return Err(format!("error {} > bound {bound}", (a - b).abs()));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn fake_quant_matches_encode_decode() {
        let x = [0.5f32, -1.25, 0.33, 0.9999];
        let mut fq = x;
        fake_quant(&mut fq, 12);
        let ed = Quantized::encode(&x, 12).decode();
        for (a, b) in fq.iter().zip(&ed) {
            assert!((a - b).abs() < 1e-6);
        }
    }

    #[test]
    fn error_shrinks_with_bits() {
        let mut rng = crate::util::rng::SplitMix::new(2);
        let x = rng.normal_vec(512);
        let e4 = Quantized::encode(&x, 4).max_error();
        let e8 = Quantized::encode(&x, 8).max_error();
        let e12 = Quantized::encode(&x, 12).max_error();
        assert!(e4 > e8 && e8 > e12);
    }

    #[test]
    fn packed_bytes_12bit() {
        let q = Quantized::encode(&vec![0.1; 100], 12);
        assert_eq!(q.packed_bytes(), 150); // 100 * 12 / 8
    }

    #[test]
    fn zero_tensor_safe() {
        let q = Quantized::encode(&[0.0, 0.0], 12);
        assert_eq!(q.decode(), vec![0.0, 0.0]);
    }
}
