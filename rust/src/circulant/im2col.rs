//! im2col with the block-contiguous channel ordering — the CONV-layer
//! reformulation of the paper's Fig. 2, mirrored from
//! `python/compile/layers.im2col`.
//!
//! Patch vectors are ordered `(c_block, di, dj, c_in_block)` so that every
//! group of `k` consecutive values is one input block `x_j` of Eqn. (1)
//! (j enumerates `(c_block, di, dj)`), letting the CONV layer reuse the FC
//! spectral machinery unchanged.

/// VALID-padding im2col.  `x` is NHWC row-major `(h, w, c)` for one image
/// (`x.len() == h*w*c`), `c % k == 0`.  Output is row-major
/// `(oh*ow, (c/k)*r*r*k)`.
pub fn im2col(x: &[f32], h: usize, w: usize, c: usize, r: usize, k: usize) -> Vec<f32> {
    assert_eq!(x.len(), h * w * c);
    assert_eq!(c % k, 0, "k must divide the channel count");
    let qc = c / k;
    let (oh, ow) = (h - r + 1, w - r + 1);
    let patch = qc * r * r * k;
    let mut out = vec![0.0f32; oh * ow * patch];
    for oy in 0..oh {
        for ox in 0..ow {
            let row = (oy * ow + ox) * patch;
            let mut col = 0;
            for cb in 0..qc {
                for di in 0..r {
                    for dj in 0..r {
                        let src = ((oy + di) * w + (ox + dj)) * c + cb * k;
                        out[row + col..row + col + k].copy_from_slice(&x[src..src + k]);
                        col += k;
                    }
                }
            }
        }
    }
    out
}

/// SAME (zero) padding helper: pads `x (h, w, c)` so a VALID r-conv keeps
/// the spatial size; returns `(padded, new_h, new_w)`.
///
/// The r-1 pad rows/columns split asymmetrically for even `r`: the smaller
/// half `lo = (r-1)/2` goes before the content, the remainder after (the
/// TF SAME convention mirrored from `layers.pad_same`).
pub fn pad_same(x: &[f32], h: usize, w: usize, c: usize, r: usize) -> (Vec<f32>, usize, usize) {
    let lo = (r - 1) / 2;
    let (nh, nw) = (h + r - 1, w + r - 1);
    let mut out = vec![0.0f32; nh * nw * c];
    for y in 0..h {
        let dst = ((y + lo) * nw + lo) * c;
        let src = y * w * c;
        out[dst..dst + w * c].copy_from_slice(&x[src..src + w * c]);
    }
    (out, nh, nw)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::SplitMix;

    #[test]
    fn shapes_and_ordering() {
        // 1 channel-block of k=2 over a 3x3 image, r=2 -> 4 patches
        let h = 3;
        let w = 3;
        let c = 2;
        let k = 2;
        let r = 2;
        let x: Vec<f32> = (0..h * w * c).map(|v| v as f32).collect();
        let cols = im2col(&x, h, w, c, r, k);
        let patch = (c / k) * r * r * k; // 8
        assert_eq!(cols.len(), 4 * patch);
        // first patch, first tap (di=0,dj=0) = channels of pixel (0,0)
        assert_eq!(&cols[0..2], &[0.0, 1.0]);
        // second tap (di=0, dj=1) = pixel (0,1)
        assert_eq!(&cols[2..4], &[2.0, 3.0]);
        // third tap (di=1, dj=0) = pixel (1,0)
        assert_eq!(&cols[4..6], &[6.0, 7.0]);
    }

    #[test]
    fn conv_via_im2col_matches_direct() {
        // dense conv through im2col == direct nested-loop convolution
        let (h, w, c, r) = (5, 5, 2, 3);
        let p_out = 3;
        let mut rng = SplitMix::new(1);
        let x = rng.normal_vec(h * w * c);
        let f = rng.normal_vec(r * r * c * p_out); // layout (di, dj, c, p)
        let (oh, ow) = (h - r + 1, w - r + 1);

        // direct
        let mut direct = vec![0.0f32; oh * ow * p_out];
        for oy in 0..oh {
            for ox in 0..ow {
                for po in 0..p_out {
                    let mut acc = 0.0;
                    for di in 0..r {
                        for dj in 0..r {
                            for ch in 0..c {
                                let xv = x[((oy + di) * w + (ox + dj)) * c + ch];
                                let fv = f[((di * r + dj) * c + ch) * p_out + po];
                                acc += xv * fv;
                            }
                        }
                    }
                    direct[(oy * ow + ox) * p_out + po] = acc;
                }
            }
        }

        // im2col with k = c (single channel block): patch order (di,dj,ch)
        let cols = im2col(&x, h, w, c, r, c);
        let patch = r * r * c;
        let mut got = vec![0.0f32; oh * ow * p_out];
        for row in 0..oh * ow {
            for po in 0..p_out {
                let mut acc = 0.0;
                for t in 0..patch {
                    // cols order: (di, dj, ch); f order: (di, dj, ch, po)
                    acc += cols[row * patch + t] * f[t * p_out + po];
                }
                got[row * p_out + po] = acc;
            }
        }
        crate::util::prop::assert_all_close(&got, &direct, 1e-4, 1e-4).unwrap();
    }

    #[test]
    fn pad_same_centers_content() {
        let x = vec![1.0; 2 * 2 * 1];
        let (p, nh, nw) = pad_same(&x, 2, 2, 1, 3);
        assert_eq!((nh, nw), (4, 4));
        assert_eq!(p.iter().filter(|&&v| v != 0.0).count(), 4);
        assert_eq!(p[(1 * 4 + 1) * 1], 1.0); // (1,1) holds original (0,0)
    }

    #[test]
    fn pad_same_even_r_puts_the_remainder_on_the_high_side() {
        // r = 2: lo = (r-1)/2 = 0, so the content stays at the origin and
        // the single extra row/column of zeros lands after it
        let x = vec![1.0, 2.0, 3.0, 4.0]; // 2x2x1
        let (p, nh, nw) = pad_same(&x, 2, 2, 1, 2);
        assert_eq!((nh, nw), (3, 3));
        assert_eq!(&p[0..2], &[1.0, 2.0]); // row 0 starts with the content
        assert_eq!(&p[3..5], &[3.0, 4.0]);
        assert!((0..3).all(|x_| p[2 * 3 + x_] == 0.0), "high-side row is zero pad");
        assert!((0..3).all(|y| p[y * 3 + 2] == 0.0), "high-side column is zero pad");
    }
}
