//! Iterative radix-2 FFT on separated real/imag planes.
//!
//! The same dataflow the paper pipelines in FPGA fabric: bit-reversal
//! reorder followed by `log2(k)` butterfly stages; IFFT runs on the same
//! structure with conjugated twiddles and a final 1/k scale.  Twiddles and
//! the reversal permutation are precomputed per block size in [`FftPlan`]
//! (the FPGA's per-stage ROMs).

/// Precomputed plan for a k-point radix-2 FFT (k a power of two).
#[derive(Debug, Clone)]
pub struct FftPlan {
    pub k: usize,
    perm: Vec<u32>,
    /// per stage: (cos, sin) twiddles of length 2^stage (forward sign)
    stages: Vec<(Vec<f32>, Vec<f32>)>,
}

impl FftPlan {
    /// Build a plan for `k`-point transforms.  Panics if `k` is not a
    /// nonzero power of two (a configuration error, not a runtime input).
    pub fn new(k: usize) -> Self {
        assert!(k.is_power_of_two() && k > 0, "k must be a power of 2, got {k}");
        let bits = k.trailing_zeros() as usize;
        let mut perm = vec![0u32; k];
        for (i, slot) in perm.iter_mut().enumerate() {
            let mut rev = 0usize;
            for b in 0..bits {
                rev |= ((i >> b) & 1) << (bits - 1 - b);
            }
            *slot = rev as u32;
        }
        let mut stages = Vec::with_capacity(bits);
        for s in 0..bits {
            let half = 1usize << s;
            let mut cos = Vec::with_capacity(half);
            let mut sin = Vec::with_capacity(half);
            for t in 0..half {
                let ang = -2.0 * std::f64::consts::PI * t as f64 / (2.0 * half as f64);
                cos.push(ang.cos() as f32);
                sin.push(ang.sin() as f32);
            }
            stages.push((cos, sin));
        }
        Self { k, perm, stages }
    }

    /// Number of bins in the packed half-spectrum (k/2 + 1).
    #[inline]
    pub fn half_bins(&self) -> usize {
        self.k / 2 + 1
    }

    /// In-place unscaled forward FFT of one k-point signal.
    pub fn fft(&self, re: &mut [f32], im: &mut [f32]) {
        self.transform(re, im, false);
    }

    /// In-place inverse FFT (including the 1/k scale).
    pub fn ifft(&self, re: &mut [f32], im: &mut [f32]) {
        self.transform(re, im, true);
        let scale = 1.0 / self.k as f32;
        for v in re.iter_mut() {
            *v *= scale;
        }
        for v in im.iter_mut() {
            *v *= scale;
        }
    }

    fn transform(&self, re: &mut [f32], im: &mut [f32], inverse: bool) {
        let k = self.k;
        debug_assert_eq!(re.len(), k);
        debug_assert_eq!(im.len(), k);
        // bit-reversal permutation (swap once per pair)
        for i in 0..k {
            let j = self.perm[i] as usize;
            if j > i {
                re.swap(i, j);
                im.swap(i, j);
            }
        }
        for (s, (cos, sin)) in self.stages.iter().enumerate() {
            let half = 1usize << s;
            let m = half * 2;
            let mut base = 0;
            while base < k {
                for t in 0..half {
                    let (c, s_) = (cos[t], if inverse { -sin[t] } else { sin[t] });
                    let (i0, i1) = (base + t, base + t + half);
                    let (vr, vi) = (re[i1], im[i1]);
                    let tr = vr * c - vi * s_;
                    let ti = vr * s_ + vi * c;
                    let (ur, ui) = (re[i0], im[i0]);
                    re[i0] = ur + tr;
                    im[i0] = ui + ti;
                    re[i1] = ur - tr;
                    im[i1] = ui - ti;
                }
                base += m;
            }
        }
    }

    /// Real-input FFT packed to the half spectrum (k/2+1 bins) — the paper's
    /// conjugate-symmetry storage optimization.  `out_re`/`out_im` must have
    /// `half_bins()` elements; `scratch` holds 2k f32 of workspace.
    pub fn rfft_halfspec(
        &self,
        x: &[f32],
        out_re: &mut [f32],
        out_im: &mut [f32],
        scratch: &mut [f32],
    ) {
        let k = self.k;
        debug_assert_eq!(x.len(), k);
        debug_assert!(scratch.len() >= 2 * k);
        let (re, rest) = scratch.split_at_mut(k);
        let im = &mut rest[..k];
        re.copy_from_slice(x);
        im.fill(0.0);
        self.fft(re, im);
        out_re.copy_from_slice(&re[..self.half_bins()]);
        out_im.copy_from_slice(&im[..self.half_bins()]);
    }

    /// Hermitian-symmetric inverse: half spectrum -> real k-point signal.
    pub fn irfft_halfspec(
        &self,
        in_re: &[f32],
        in_im: &[f32],
        out: &mut [f32],
        scratch: &mut [f32],
    ) {
        let k = self.k;
        let kh = self.half_bins();
        debug_assert_eq!(in_re.len(), kh);
        debug_assert!(scratch.len() >= 2 * k);
        let (re, rest) = scratch.split_at_mut(k);
        let im = &mut rest[..k];
        re[..kh].copy_from_slice(in_re);
        im[..kh].copy_from_slice(in_im);
        // mirror bins 1..k/2-1 conjugated
        for t in 1..k - kh + 1 {
            re[kh - 1 + t] = in_re[kh - 1 - t];
            im[kh - 1 + t] = -in_im[kh - 1 - t];
        }
        self.ifft(re, im);
        out.copy_from_slice(&re[..k]);
    }

    /// Real multiplications in one k-point FFT under the paper's cost model
    /// (4 real mults per complex butterfly mult, k/2 butterflies per stage).
    pub fn real_mults(&self) -> u64 {
        let stages = self.k.trailing_zeros() as u64;
        2 * self.k as u64 * stages
    }
}

/// Element-wise complex multiply-accumulate on separated planes:
/// `acc += a o b` over `len` lanes.  This is phase 2 of the datapath.
#[inline]
pub fn complex_mul_acc(
    ar: &[f32],
    ai: &[f32],
    br: &[f32],
    bi: &[f32],
    acc_r: &mut [f32],
    acc_i: &mut [f32],
) {
    for t in 0..ar.len() {
        acc_r[t] += ar[t] * br[t] - ai[t] * bi[t];
        acc_i[t] += ar[t] * bi[t] + ai[t] * br[t];
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{assert_all_close, forall};
    use crate::util::rng::SplitMix;

    /// O(k^2) DFT oracle (mirrors ref.naive_dft).
    fn naive_dft(re: &[f32], im: &[f32], inverse: bool) -> (Vec<f32>, Vec<f32>) {
        let k = re.len();
        let sign = if inverse { 1.0 } else { -1.0 };
        let mut or_ = vec![0.0f32; k];
        let mut oi = vec![0.0f32; k];
        for out in 0..k {
            let (mut sr, mut si) = (0.0f64, 0.0f64);
            for t in 0..k {
                let ang = sign * 2.0 * std::f64::consts::PI * (out * t) as f64 / k as f64;
                let (c, s) = (ang.cos(), ang.sin());
                sr += re[t] as f64 * c - im[t] as f64 * s;
                si += re[t] as f64 * s + im[t] as f64 * c;
            }
            or_[out] = sr as f32;
            oi[out] = si as f32;
        }
        (or_, oi)
    }

    #[test]
    fn fft_matches_naive_dft() {
        for k in [2usize, 4, 8, 16, 64, 128, 256] {
            let mut rng = SplitMix::new(k as u64);
            let re0 = rng.normal_vec(k);
            let im0 = rng.normal_vec(k);
            let (mut re, mut im) = (re0.clone(), im0.clone());
            FftPlan::new(k).fft(&mut re, &mut im);
            let (er, ei) = naive_dft(&re0, &im0, false);
            assert_all_close(&re, &er, 1e-3, 1e-3).unwrap();
            assert_all_close(&im, &ei, 1e-3, 1e-3).unwrap();
        }
    }

    #[test]
    fn prop_fft_ifft_roundtrip() {
        forall(
            "fft→ifft identity",
            |r| {
                let k = 1usize << (1 + r.below(8)) as usize;
                (k, r.normal_vec(k), r.normal_vec(k))
            },
            |(k, re0, im0)| {
                let plan = FftPlan::new(*k);
                let (mut re, mut im) = (re0.clone(), im0.clone());
                plan.fft(&mut re, &mut im);
                plan.ifft(&mut re, &mut im);
                assert_all_close(&re, re0, 1e-3, 1e-3)?;
                assert_all_close(&im, im0, 1e-3, 1e-3)
            },
        );
    }

    #[test]
    fn prop_rfft_halfspec_roundtrip() {
        forall(
            "rfft→irfft identity",
            |r| {
                let k = 1usize << (1 + r.below(8)) as usize;
                (k, r.normal_vec(k))
            },
            |(k, x)| {
                let plan = FftPlan::new(*k);
                let kh = plan.half_bins();
                let mut scratch = vec![0.0; 2 * k];
                let (mut hr, mut hi) = (vec![0.0; kh], vec![0.0; kh]);
                plan.rfft_halfspec(x, &mut hr, &mut hi, &mut scratch);
                let mut back = vec![0.0; *k];
                plan.irfft_halfspec(&hr, &hi, &mut back, &mut scratch);
                assert_all_close(&back, x, 1e-3, 1e-3)
            },
        );
    }

    #[test]
    fn prop_fft_linearity() {
        forall(
            "fft linearity",
            |r| {
                let k = 1usize << (1 + r.below(6)) as usize;
                (k, r.normal_vec(k), r.normal_vec(k))
            },
            |(k, a, b)| {
                let plan = FftPlan::new(*k);
                let z = vec![0.0f32; *k];
                let (mut ar, mut ai) = (a.clone(), z.clone());
                plan.fft(&mut ar, &mut ai);
                let (mut br, mut bi) = (b.clone(), z.clone());
                plan.fft(&mut br, &mut bi);
                let sum: Vec<f32> = a.iter().zip(b).map(|(x, y)| x + 2.0 * y).collect();
                let (mut sr, mut si) = (sum, z);
                plan.fft(&mut sr, &mut si);
                let expect: Vec<f32> = ar.iter().zip(&br).map(|(x, y)| x + 2.0 * y).collect();
                assert_all_close(&sr, &expect, 1e-3, 1e-3)
            },
        );
    }

    #[test]
    fn delta_transforms_to_flat_spectrum() {
        let k = 16;
        let mut re = vec![0.0f32; k];
        let mut im = vec![0.0f32; k];
        re[0] = 1.0;
        FftPlan::new(k).fft(&mut re, &mut im);
        for t in 0..k {
            assert!((re[t] - 1.0).abs() < 1e-6 && im[t].abs() < 1e-6);
        }
    }

    #[test]
    fn parseval_energy() {
        let k = 128;
        let mut rng = SplitMix::new(9);
        let x = rng.normal_vec(k);
        let (mut re, mut im) = (x.clone(), vec![0.0; k]);
        FftPlan::new(k).fft(&mut re, &mut im);
        let te: f32 = x.iter().map(|v| v * v).sum();
        let fe: f32 = re.iter().zip(&im).map(|(r, i)| r * r + i * i).sum::<f32>() / k as f32;
        assert!((te - fe).abs() < 1e-2 * te.max(1.0));
    }

    #[test]
    #[should_panic(expected = "power of 2")]
    fn non_pow2_panics() {
        FftPlan::new(12);
    }

    #[test]
    fn real_mults_formula() {
        assert_eq!(FftPlan::new(8).real_mults(), 2 * 8 * 3);
        assert_eq!(FftPlan::new(128).real_mults(), 2 * 128 * 7);
    }
}
